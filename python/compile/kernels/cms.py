"""Layer-1 Pallas kernel: count-min-sketch epoch update + candidate query.

The FISH coordinator identifies recent hot keys by maintaining per-epoch
frequency statistics.  The compute hot-spot is a histogram / sketch update
over an epoch of ``N`` key ids.  On a GPU one would scatter-add with
shared-memory atomics; TPUs have neither atomics nor warp shuffles, so the
kernel recasts the scatter-add as a **one-hot matmul on the MXU**:

    row_d += ones(1, N) @ onehot(h_d(keys), W)            # (1,W)

The one-hot slab for a key tile lives in VMEM (BlockSpec-tiled along N);
the MXU performs the reduction.  Queries use the transpose of the same
trick: ``est = onehot(h_d(cands), W) @ row_d.T`` gathers row counts, and
the count-min estimate is the min over the D hash rows.

All ``pallas_call``s use ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is both the correctness
path (pytest vs ``ref.py``) and what gets lowered into the AOT HLO
artifact consumed by the Rust runtime.  DESIGN.md §6 records the VMEM /
MXU estimates for a real-TPU deployment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Multiply-shift hash constants (odd 32-bit), one (a, b) pair per CMS row.
# Keep in sync with rust/src/sketch/countmin.rs.
HASH_A = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F, 0x165667B1, 0xD3A2646D)
HASH_B = (0x68E31DA4, 0xB5297A4D, 0x1B56C4E9, 0x8F14ACD5, 0xCA6B27D9, 0x5F356495)


def row_hash(keys: jax.Array, row: int, width: int) -> jax.Array:
    """Bucket index of each key for CMS row ``row`` (width a power of two).

    uint32 multiply-shift: h(k) = ((a*k + b) >> (32 - log2 W)).  Matches the
    Rust implementation bit-for-bit so the coordinator can swap between the
    native and XLA identifiers without re-learning sketch contents.
    """
    shift = 32 - (width - 1).bit_length()
    k = keys.astype(jnp.uint32)
    h = k * jnp.uint32(HASH_A[row]) + jnp.uint32(HASH_B[row])
    return (h >> jnp.uint32(shift)).astype(jnp.int32)


def _update_kernel(keys_ref, sketch_ref, out_ref, *, depth: int, width: int,
                   tile: int):
    """Grid step ``i`` accumulates key tile ``i`` into all D sketch rows."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = sketch_ref[...]

    keys = keys_ref[...]  # (tile,) int32 — current BlockSpec tile
    ones = jnp.ones((1, tile), dtype=jnp.float32)
    for d in range(depth):
        buckets = row_hash(keys, d, width)  # (tile,)
        onehot = (buckets[:, None] == jnp.arange(width, dtype=jnp.int32)[None, :])
        onehot = onehot.astype(jnp.float32)  # (tile, W) VMEM slab
        # MXU reduction: (1,tile) @ (tile,W) -> (1,W)
        row_add = jnp.dot(ones, onehot, preferred_element_type=jnp.float32)
        out_ref[d, :] = out_ref[d, :] + row_add[0]


def cms_update(sketch: jax.Array, keys: jax.Array, *, tile: int = 128) -> jax.Array:
    """Add one epoch of ``keys`` (int32[N]) into ``sketch`` (f32[D,W]).

    N must be a multiple of ``tile``; the AOT path pads epochs with the
    sentinel key -1 which hashes like any other key — the Rust side masks
    sentinels out by subtracting the pad count, see model.epoch_stats.
    """
    depth, width = sketch.shape
    n = keys.shape[0]
    assert n % tile == 0, f"epoch {n} not a multiple of tile {tile}"
    grid = n // tile
    kernel = functools.partial(_update_kernel, depth=depth, width=width, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),       # key tile i
            pl.BlockSpec((depth, width), lambda i: (0, 0)),  # whole sketch
        ],
        out_specs=pl.BlockSpec((depth, width), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((depth, width), jnp.float32),
        interpret=True,
    )(keys, sketch)


def _query_kernel(cands_ref, sketch_ref, out_ref, *, depth: int, width: int):
    cands = cands_ref[...]  # (C,)
    c = cands.shape[0]
    est = jnp.full((c,), jnp.inf, dtype=jnp.float32)
    for d in range(depth):
        buckets = row_hash(cands, d, width)  # (C,)
        onehot = (buckets[:, None] == jnp.arange(width, dtype=jnp.int32)[None, :])
        onehot = onehot.astype(jnp.float32)  # (C, W)
        # gather row counts via MXU: (C,W) @ (W,1) -> (C,1)
        got = jnp.dot(onehot, sketch_ref[d, :][:, None],
                      preferred_element_type=jnp.float32)
        est = jnp.minimum(est, got[:, 0])
    out_ref[...] = est


def cms_query(sketch: jax.Array, cands: jax.Array) -> jax.Array:
    """Count-min estimate (min over rows) for candidate keys int32[C]."""
    depth, width = sketch.shape
    c = cands.shape[0]
    kernel = functools.partial(_query_kernel, depth=depth, width=width)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        interpret=True,
    )(cands, sketch)


def cms_decay(sketch: jax.Array, alpha: jax.Array) -> jax.Array:
    """Inter-epoch hotness decay: every counter ×= alpha (paper Alg. 1)."""

    def kernel(sketch_ref, alpha_ref, out_ref):
        out_ref[...] = sketch_ref[...] * alpha_ref[0]

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(sketch.shape, jnp.float32),
        interpret=True,
    )(sketch, alpha)
