"""Pure-jnp oracle for the Pallas CMS kernels (no pallas, no tricks).

pytest compares every kernel in cms.py against these; hypothesis sweeps
shapes, dtypes and key distributions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .cms import HASH_A, HASH_B


def row_hash_ref(keys: jax.Array, row: int, width: int) -> jax.Array:
    shift = 32 - (width - 1).bit_length()
    k = keys.astype(jnp.uint32)
    h = k * jnp.uint32(HASH_A[row]) + jnp.uint32(HASH_B[row])
    return (h >> jnp.uint32(shift)).astype(jnp.int32)


def cms_update_ref(sketch: jax.Array, keys: jax.Array) -> jax.Array:
    depth, width = sketch.shape
    out = sketch
    for d in range(depth):
        buckets = row_hash_ref(keys, d, width)
        hist = jnp.zeros((width,), jnp.float32).at[buckets].add(1.0)
        out = out.at[d, :].add(hist)
    return out


def cms_query_ref(sketch: jax.Array, cands: jax.Array) -> jax.Array:
    depth, width = sketch.shape
    est = jnp.full((cands.shape[0],), jnp.inf, jnp.float32)
    for d in range(depth):
        buckets = row_hash_ref(cands, d, width)
        est = jnp.minimum(est, sketch[d, buckets])
    return est


def cms_decay_ref(sketch: jax.Array, alpha: jax.Array) -> jax.Array:
    return sketch * alpha[0]


def epoch_stats_ref(sketch, keys, cands, alpha):
    """Reference for model.epoch_stats: decay -> update -> query."""
    decayed = cms_decay_ref(sketch, alpha)
    updated = cms_update_ref(decayed, keys)
    est = cms_query_ref(updated, cands)
    total = jnp.asarray(keys.shape[0], jnp.float32)
    return updated, est, total
