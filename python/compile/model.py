"""Layer-2 JAX model: the FISH per-epoch frequency-statistics pipeline.

One jitted function per (epoch size, sketch geometry) variant:

    epoch_stats(sketch, keys, cands, alpha)
        -> (new_sketch, cand_estimates, epoch_total)

Semantics (paper Alg. 1, epoch granularity):
  1. inter-epoch hotness decay: sketch *= alpha      (L1 cms_decay)
  2. intra-epoch counting: sketch += histogram(keys) (L1 cms_update)
  3. classification input: estimates for the candidate keys the
     coordinator is tracking                          (L1 cms_query)

The Rust coordinator pads short epochs with the sentinel key -1 and
corrects estimates on its side.  Lowered once by aot.py to HLO text;
never imported at request time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import cms

# (name, epoch N, candidates C, depth D, width W, tile)
VARIANTS = (
    ("epoch_stats_n256", 256, 64, 4, 2048, 128),
    ("epoch_stats_n1024", 1024, 128, 4, 2048, 128),
    ("epoch_stats_n4096", 4096, 256, 4, 4096, 128),
)


def epoch_stats(sketch, keys, cands, alpha, *, tile=128):
    """decay -> update -> query; shapes are static per AOT variant."""
    decayed = cms.cms_decay(sketch, alpha)
    updated = cms.cms_update(decayed, keys, tile=tile)
    est = cms.cms_query(updated, cands)
    total = jnp.asarray(keys.shape[0], jnp.float32)
    return updated, est, total


def make_variant(n: int, c: int, depth: int, width: int, tile: int):
    """Return (fn, example_args) for jax.jit(...).lower()."""

    def fn(sketch, keys, cands, alpha):
        return epoch_stats(sketch, keys, cands, alpha, tile=tile)

    args = (
        jax.ShapeDtypeStruct((depth, width), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((c,), jnp.int32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )
    return fn, args
