"""AOT entry point: lower every model variant to HLO *text* artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 Rust crate links) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and
round-trips cleanly — see /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts
Writes  artifacts/<variant>.hlo.txt plus artifacts/manifest.txt with the
shapes the Rust runtime validates against at load time.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="legacy single-file mode: also write the n1024 "
                         "variant to this exact path")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for name, n, c, depth, width, tile in model.VARIANTS:
        fn, example = model.make_variant(n, c, depth, width, tile)
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(
            f"{name} n={n} c={c} depth={depth} width={width} tile={tile}")
        print(f"wrote {path} ({len(text)} chars)")
        if args.out and name == "epoch_stats_n1024":
            with open(args.out, "w") as f:
                f.write(text)
            print(f"wrote {args.out}")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


if __name__ == "__main__":
    main()
