"""Kernel-vs-reference correctness: the CORE L1 signal.

hypothesis sweeps epoch sizes, sketch geometries and key distributions;
every pallas kernel (interpret=True) must match the pure-jnp oracle.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cms, ref

jax.config.update("jax_platform_name", "cpu")

GEOMS = [(1, 256), (2, 512), (4, 2048), (6, 1024)]


def rand_keys(rng, n, lo=-1, hi=2**31 - 1):
    return jnp.asarray(rng.integers(lo, hi, size=n, dtype=np.int64),
                       dtype=jnp.int32)


# ---------------------------------------------------------------- row_hash
@given(st.integers(0, 5), st.sampled_from([64, 256, 1024, 4096]),
       st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_row_hash_matches_ref(row, width, seed):
    rng = np.random.default_rng(seed)
    keys = rand_keys(rng, 37)
    got = cms.row_hash(keys, row, width)
    want = ref.row_hash_ref(keys, row, width)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(jnp.max(got)) < width and int(jnp.min(got)) >= 0


def test_row_hash_rust_vector():
    """Pinned vector shared with rust/src/sketch/countmin.rs tests."""
    keys = jnp.asarray([0, 1, 42, 123456, -1], dtype=jnp.int32)
    got = np.asarray(cms.row_hash(keys, 0, 2048))
    a, b = cms.HASH_A[0], cms.HASH_B[0]
    want = [((a * int(k) + b) % 2**32) >> 21 for k in
            np.asarray(keys, dtype=np.uint32)]
    np.testing.assert_array_equal(got, np.asarray(want, dtype=np.int32))


# -------------------------------------------------------------- cms_update
@pytest.mark.parametrize("depth,width", GEOMS)
@pytest.mark.parametrize("n", [128, 256, 1024])
def test_update_matches_ref(depth, width, n):
    rng = np.random.default_rng(depth * 1000 + n)
    sketch = jnp.asarray(rng.random((depth, width)), dtype=jnp.float32)
    keys = rand_keys(rng, n)
    got = cms.cms_update(sketch, keys)
    want = ref.cms_update_ref(sketch, keys)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-4)


@given(st.integers(1, 8), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_update_mass_conservation(tiles, seed):
    """Sum of each row increases by exactly N (every key lands once/row)."""
    rng = np.random.default_rng(seed)
    n = 128 * tiles
    sketch = jnp.zeros((4, 1024), jnp.float32)
    keys = rand_keys(rng, n)
    got = cms.cms_update(sketch, keys)
    np.testing.assert_allclose(np.asarray(got).sum(axis=1), n, atol=1e-3)


def test_update_skewed_keys():
    """Heavy repetition (the FISH hot-key case) accumulates correctly."""
    keys = jnp.asarray([7] * 200 + [11] * 56, dtype=jnp.int32)
    sketch = jnp.zeros((4, 2048), jnp.float32)
    got = cms.cms_update(sketch, keys)
    est = cms.cms_query(got, jnp.asarray([7, 11], jnp.int32))
    assert float(est[0]) >= 200.0  # CMS overestimates, never under
    assert float(est[1]) >= 56.0


def test_update_rejects_ragged_epoch():
    with pytest.raises(AssertionError):
        cms.cms_update(jnp.zeros((4, 2048), jnp.float32),
                       jnp.zeros((100,), jnp.int32))


# --------------------------------------------------------------- cms_query
@pytest.mark.parametrize("depth,width", GEOMS)
def test_query_matches_ref(depth, width):
    rng = np.random.default_rng(99)
    sketch = jnp.asarray(rng.random((depth, width)) * 100, dtype=jnp.float32)
    cands = rand_keys(rng, 64)
    got = cms.cms_query(sketch, cands)
    want = ref.cms_query_ref(sketch, cands)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_query_never_underestimates(seed):
    rng = np.random.default_rng(seed)
    keys = rand_keys(rng, 256, lo=0, hi=50)  # heavy collisions
    sketch = cms.cms_update(jnp.zeros((4, 256), jnp.float32), keys)
    uniq, counts = np.unique(np.asarray(keys), return_counts=True)
    est = cms.cms_query(sketch, jnp.asarray(uniq, jnp.int32))
    assert np.all(np.asarray(est) >= counts - 1e-3)


# --------------------------------------------------------------- cms_decay
@given(st.floats(0.0, 1.0), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_decay_matches_ref(alpha, seed):
    rng = np.random.default_rng(seed)
    sketch = jnp.asarray(rng.random((4, 512)) * 10, dtype=jnp.float32)
    a = jnp.asarray([alpha], jnp.float32)
    got = cms.cms_decay(sketch, a)
    want = ref.cms_decay_ref(sketch, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
