"""L2 model tests: epoch_stats pipeline + every AOT variant's shapes."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_epoch_stats_matches_ref():
    rng = np.random.default_rng(7)
    sketch = jnp.asarray(rng.random((4, 2048)), jnp.float32)
    keys = jnp.asarray(rng.integers(0, 1000, 1024), jnp.int32)
    cands = jnp.asarray(rng.integers(0, 1000, 128), jnp.int32)
    alpha = jnp.asarray([0.2], jnp.float32)
    got_s, got_e, got_t = model.epoch_stats(sketch, keys, cands, alpha)
    want_s, want_e, want_t = ref.epoch_stats_ref(sketch, keys, cands, alpha)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), atol=1e-3)
    np.testing.assert_allclose(np.asarray(got_e), np.asarray(want_e), atol=1e-3)
    assert float(got_t) == float(want_t) == 1024.0


def test_epoch_stats_decay_then_count_order():
    """Decay must apply to the *old* sketch only (paper Alg. 1 ordering)."""
    sketch = jnp.full((2, 256), 10.0, jnp.float32)
    keys = jnp.asarray([5] * 128, jnp.int32)
    cands = jnp.asarray([5], jnp.int32)
    alpha = jnp.asarray([0.5], jnp.float32)
    _, est, _ = model.epoch_stats(sketch, keys, cands, alpha)
    # old mass 10 halves to 5, then +128 fresh counts => estimate ~133
    assert abs(float(est[0]) - 133.0) < 1e-2


@pytest.mark.parametrize("name,n,c,depth,width,tile", model.VARIANTS)
def test_variant_lowers_and_runs(name, n, c, depth, width, tile):
    fn, example = model.make_variant(n, c, depth, width, tile)
    jitted = jax.jit(fn)
    rng = np.random.default_rng(3)
    sketch = jnp.zeros((depth, width), jnp.float32)
    keys = jnp.asarray(rng.integers(0, 100, n), jnp.int32)
    cands = jnp.asarray(rng.integers(0, 100, c), jnp.int32)
    out_s, out_e, out_t = jitted(sketch, keys, cands,
                                 jnp.asarray([0.2], jnp.float32))
    assert out_s.shape == (depth, width)
    assert out_e.shape == (c,)
    assert float(out_t) == float(n)
    # and it lowers to HLO text without a Mosaic custom-call
    lowered = jax.jit(fn).lower(*example)
    txt = str(lowered.compiler_ir("stablehlo"))
    assert "tpu_custom_call" not in txt and "mosaic" not in txt.lower()
