//! End-to-end driver (DESIGN.md §"End-to-end validation"): the full
//! three-layer system on a real small workload.
//!
//! * Layer 1/2: if `artifacts/` is built, FISH runs its frequency
//!   statistics on the AOT-compiled Pallas count-min kernel via PJRT
//!   (`--identifier xla-cms`); otherwise it falls back to the native
//!   identifier.
//! * Layer 3: the threaded runtime engine (our Storm stand-in) streams a
//!   real word-count workload — a time-evolving corpus synthesised from
//!   an embedded vocabulary with news-cycle catchphrase bursts — through
//!   32 sources × 64 workers with bounded-queue backpressure, and
//!   reports the paper's §6.6 metrics: latency percentiles, throughput,
//!   and memory overhead vs Shuffle Grouping.
//!
//! ```bash
//! make artifacts && cargo run --release --example wordcount_pipeline
//! ```

use fish::config::Config;
use fish::coordinator::{make_kind, Grouper, SchemeKind};
use fish::engine::Pipeline;
use fish::report::{ns, ratio, Table};
use fish::workload::{materialise, Trace};
use std::sync::Arc;

fn build_sources(cfg: &Config, kind: SchemeKind, use_xla: bool) -> Vec<Box<dyn Grouper>> {
    (0..cfg.sources)
        .map(|s| -> Box<dyn Grouper> {
            if kind == SchemeKind::Fish && use_xla {
                match fish::runtime::make_fish_xla(cfg) {
                    Ok(f) => return Box::new(f),
                    Err(e) => eprintln!("[wordcount] xla identifier unavailable ({e}); native fallback"),
                }
            }
            make_kind(kind, cfg, s)
        })
        .collect()
}

fn main() {
    // a real small workload: MemeTracker-like word stream, 400k tuples
    let tuples = 400_000;
    let mut cfg = Config::default();
    cfg.workload = "mt".into();
    cfg.tuples = tuples;
    cfg.sources = 8; // scaled from the paper's 32 (thread budget)
    cfg.workers = 64;
    cfg.service_ns = 2_000;
    cfg.interval = 2_000_000; // HWA re-estimation every 2ms wall clock
    cfg.interarrival_ns = 0; // as fast as possible

    let use_xla = std::path::Path::new("artifacts/manifest.txt").exists();
    println!(
        "wordcount pipeline: {} tuples (mt workload), {} sources x {} workers, identifier={}",
        tuples,
        cfg.sources,
        cfg.workers,
        if use_xla { "xla-cms (AOT Pallas CMS via PJRT)" } else { "native (artifacts not built)" }
    );

    let mut gen = fish::workload::by_name(&cfg.workload, cfg.tuples, cfg.zipf_z, cfg.seed);
    let trace: Arc<Trace> = Arc::new(materialise(gen.as_mut(), 0));
    println!("trace: {} tuples over {} distinct words\n", trace.len(), trace.key_space());

    let mut table = Table::new(
        "practical deployment (threaded runtime, paper Figs. 18-20)",
        &["scheme", "throughput", "mean", "p50", "p95", "p99", "mem vs FG"],
    );
    let mut sg_mem = None;
    let mut fish_row = None;
    for kind in [
        SchemeKind::Field,
        SchemeKind::Pkg,
        SchemeKind::Shuffle,
        SchemeKind::DChoices,
        SchemeKind::WChoices,
        SchemeKind::Fish,
    ] {
        let sources = build_sources(&cfg, kind, use_xla);
        let r = Pipeline::builder()
            .config(cfg.clone())
            .scheme(kind)
            .with_sources(sources)
            .trace(trace.clone())
            .per_tuple_ns(vec![cfg.service_ns as f64])
            .build_rt()
            .run();
        let (mean, p50, p95, p99) = r.latency.summary();
        if kind == SchemeKind::Shuffle {
            sg_mem = Some(r.memory_normalized());
        }
        if kind == SchemeKind::Fish {
            fish_row = Some((r.throughput, r.memory_normalized()));
        }
        table.row(&[
            kind.name().to_string(),
            format!("{:.0}/s", r.throughput),
            ns(mean as u64),
            ns(p50),
            ns(p95),
            ns(p99),
            ratio(r.memory_normalized()),
        ]);
    }
    table.print();

    if let (Some((thr, fish_mem)), Some(sg)) = (fish_row, sg_mem) {
        println!(
            "\nheadline: FISH throughput {:.0}/s at {:.1}% of SG's memory overhead",
            thr,
            100.0 * (fish_mem - 1.0).max(0.0) / (sg - 1.0).max(1e-9)
        );
    }
    println!("(record of this run lives in EXPERIMENTS.md §End-to-end)");
}
