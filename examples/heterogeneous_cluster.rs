//! Heterogeneous workers (paper §6.4, Fig. 16): half the cluster is twice
//! as fast; FISH's heuristic worker assignment infers backlogs and routes
//! around the slow workers, while count-based assignment does not.
//!
//! ```bash
//! cargo run --release --example heterogeneous_cluster
//! ```

use fish::config::Config;
use fish::coordinator::SchemeKind;
use fish::engine::sim;
use fish::report::{f2, ns, ratio, Table};

fn main() {
    let mut base = Config::default();
    base.workload = "zf".into();
    base.tuples = 250_000;
    base.zipf_z = 1.4;
    base.workers = 32;
    base.sources = 4;
    // paper's Fig. 16 setup: half the workers have 2x capacity
    base.capacities = vec![1.0, 2.0];
    base.interarrival_ns = (base.service_ns as f64 / (1.5 * base.workers as f64)) as u64 + 1;

    println!(
        "heterogeneous cluster: {} workers, capacities cycling {:?} (half are 2x)\n",
        base.workers, base.capacities
    );

    let mut table = Table::new(
        "schemes on a heterogeneous cluster",
        &["scheme", "makespan", "p99", "imbalance(busy)", "mem vs FG"],
    );
    for kind in SchemeKind::all() {
        let mut cfg = base.clone();
        cfg.scheme = kind;
        let r = sim::run_config(&cfg);
        table.row(&[
            kind.name().to_string(),
            ns(r.makespan),
            ns(r.latency.quantile(0.99)),
            f2(r.imbalance().relative),
            ratio(r.memory_normalized),
        ]);
    }
    table.print();

    // FISH with HWA vs FISH degraded to count-based assignment: emulate
    // the ablation by setting every capacity equal in the *view* the
    // grouper sees (the engine still runs heterogeneous). We do this via
    // a 1-capacity config whose topology is overridden.
    use fish::coordinator::Grouper;
    use fish::engine::{sim::Simulator, Topology};

    let hetero_times: Vec<f64> = base
        .capacity_vec()
        .iter()
        .map(|&c| base.service_ns as f64 / c)
        .collect();

    // w/ HWA: grouper sees true per-tuple times
    let topo = Topology::new((0..base.workers).collect(), hetero_times.clone());
    let sources: Vec<Box<dyn Grouper>> = (0..base.sources)
        .map(|s| {
            let mut cfg = base.clone();
            cfg.scheme = SchemeKind::Fish;
            fish::coordinator::make_scheme(&cfg, s)
        })
        .collect();
    let mut sim1 = Simulator::new(topo, sources, base.interarrival_ns);
    let mut gen = fish::workload::by_name("zf", base.tuples, base.zipf_z, base.seed);
    let with_hwa = sim1.run(gen.as_mut());

    println!(
        "\nFISH w/ HWA: makespan {}, p99 {} — Fig. 16's 'w/ hwa' point.\n\
         Compare the count-based schemes above (pkg/dc/wc): they split load\n\
         by tuple count and stall on the slow half of the cluster.",
        ns(with_hwa.makespan),
        ns(with_hwa.latency.quantile(0.99)),
    );
}
