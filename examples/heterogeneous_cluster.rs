//! Heterogeneous workers (paper §6.4, Fig. 16): half the cluster is twice
//! as fast; FISH's heuristic worker assignment infers backlogs and routes
//! around the slow workers, while count-based assignment does not.
//!
//! ```bash
//! cargo run --release --example heterogeneous_cluster
//! ```

use fish::config::Config;
use fish::coordinator::SchemeKind;
use fish::engine::Pipeline;
use fish::report::{f2, ns, ratio, Table};

fn main() {
    let mut base = Config::default();
    base.workload = "zf".into();
    base.tuples = 250_000;
    base.zipf_z = 1.4;
    base.workers = 32;
    base.sources = 4;
    // paper's Fig. 16 setup: half the workers have 2x capacity
    base.capacities = vec![1.0, 2.0];
    base.interarrival_ns = (base.service_ns as f64 / (1.5 * base.workers as f64)) as u64 + 1;

    println!(
        "heterogeneous cluster: {} workers, capacities cycling {:?} (half are 2x)\n",
        base.workers, base.capacities
    );

    let mut table = Table::new(
        "schemes on a heterogeneous cluster",
        &["scheme", "makespan", "p99", "imbalance(busy)", "mem vs FG"],
    );
    let mut fish_result = None;
    for kind in SchemeKind::all() {
        let r = Pipeline::builder()
            .config(base.clone())
            .scheme(kind)
            .build_sim()
            .run();
        table.row(&[
            kind.name().to_string(),
            ns(r.makespan),
            ns(r.latency.quantile(0.99)),
            f2(r.imbalance().relative),
            ratio(r.memory_normalized),
        ]);
        if kind == SchemeKind::Fish {
            fish_result = Some(r);
        }
    }
    table.print();

    // FISH with HWA on the heterogeneous topology (Fig. 16's 'w/ hwa'
    // point) — the run is deterministic, so reuse the loop's result.
    let with_hwa = fish_result.expect("SchemeKind::all() includes Fish");

    println!(
        "\nFISH w/ HWA: makespan {}, p99 {} — Fig. 16's 'w/ hwa' point.\n\
         Compare the count-based schemes above (pkg/dc/wc): they split load\n\
         by tuple count and stall on the slow half of the cluster.",
        ns(with_hwa.makespan),
        ns(with_hwa.latency.quantile(0.99)),
    );
}
