//! Dynamic worker churn (paper §5 + §6.5): remove and add workers
//! mid-stream and watch consistent hashing keep state migration small.
//!
//! ```bash
//! cargo run --release --example dynamic_scaling
//! ```

use fish::config::Config;
use fish::coordinator::fish::CandidateMode;
use fish::coordinator::{Fish, Grouper};
use fish::engine::{ChurnEvent, Pipeline};
use fish::report::{ratio, Table};

fn run_mode(mode: CandidateMode, churn: Vec<(usize, ChurnEvent)>, cfg: &Config) -> (usize, usize) {
    // ablation groupers are injected; the builder wires topology + churn
    let sources: Vec<Box<dyn Grouper>> = (0..cfg.sources)
        .map(|s| Box::new(Fish::from_config(cfg, s).with_mode(mode)) as Box<dyn Grouper>)
        .collect();
    let r = Pipeline::builder()
        .config(cfg.clone())
        .with_sources(sources)
        .churn(churn)
        .build_sim()
        .run();
    (r.entries, r.churn_migrations)
}

fn main() {
    let mut cfg = Config::default();
    cfg.workload = "zf".into();
    cfg.tuples = 300_000;
    cfg.zipf_z = 1.2;
    cfg.workers = 32;
    cfg.sources = 4;
    cfg.interarrival_ns = cfg.service_ns / cfg.workers as u64 + 1;

    println!(
        "dynamic scaling: {} tuples, {} workers, churn at the halfway point\n",
        cfg.tuples, cfg.workers
    );

    let mut table = Table::new(
        "consistent hashing vs modulo hashing under churn (paper Fig. 17)",
        &["scenario", "candidates", "state entries", "vs CH", "migrated entries"],
    );

    for (scenario, churn) in [
        ("remove 1 worker", vec![(150_000usize, ChurnEvent::Remove(7))]),
        ("add 1 worker", vec![(150_000usize, ChurnEvent::Add(32))]),
    ] {
        let (ch_entries, ch_migrated) = run_mode(CandidateMode::ConsistentHash, churn.clone(), &cfg);
        let (mod_entries, mod_migrated) = run_mode(CandidateMode::ModuloHash, churn.clone(), &cfg);
        table.row(&[
            scenario.into(),
            "consistent-hash".into(),
            ch_entries.to_string(),
            ratio(1.0),
            ch_migrated.to_string(),
        ]);
        table.row(&[
            scenario.into(),
            "modulo-hash".into(),
            mod_entries.to_string(),
            ratio(mod_entries as f64 / ch_entries as f64),
            mod_migrated.to_string(),
        ]);
    }
    table.print();

    println!(
        "\nExpected shape: modulo hashing reshuffles (almost) every key-to-worker\n\
         mapping on churn, inflating replicated state (paper: ~2x for low skew);\n\
         consistent hashing only remaps the arcs adjacent to the changed worker."
    );

    // ---- explicit state migration (rust/src/state) ---------------------
    // Demonstrate the §5 machinery directly: build worker state under CH
    // placement, kill a worker, compute + apply the migration plan.
    use fish::hashring::HashRing;
    use fish::state::{MigrationPlan, StateStore};

    let mut ring = HashRing::new(&(0..cfg.workers).collect::<Vec<_>>(), cfg.vnodes);
    let mut store = StateStore::new();
    let mut gen = fish::workload::by_name("zf", 100_000, 1.2, cfg.seed);
    for i in 0..100_000 {
        let k = gen.key_at(i);
        store.record(k, ring.owner(k).unwrap());
    }
    let victim = 7;
    let stranded = store.entries_on(victim);
    let grand = store.grand_total();
    ring.remove_worker(victim);
    let plan = MigrationPlan::compute(&store, &[victim], |k, _| ring.owner(k));
    plan.apply(&mut store);
    println!(
        "\nstate migration after losing worker {victim}: {} entries moved \
         (exactly the stranded {stranded}), aggregates conserved: {}",
        plan.cost(),
        store.grand_total() == grand
    );
}
