//! Trending keys under the two-stage topology: top-k queries over the
//! downstream merge stage.
//!
//! A time-evolving Zipf stream (hot set drifts mid-stream) runs through
//! FISH and through Field Grouping. Both produce the *same* merged
//! top-k — that is the aggregation oracle: whatever a scheme did to
//! split or not split keys, stage two reassembles exact counts. What
//! differs is the price: FG pins each hot key to one worker and its
//! queue explodes (makespan, p99 lag far behind), while FISH scatters
//! hot keys and pays only a little aggregation traffic to merge the
//! partials back.
//!
//! Stage two runs as a **sharded fabric** here (`--agg_shards`-style,
//! 4 key-range merge shards): flushes scatter across the shards, the
//! per-shard ledgers expose the aggregation stage's own imbalance, and
//! global top-k comes back two ways — exact (merged counts) and via the
//! scatter-gather [`TopKGather`] front-end, whose per-shard SpaceSaving
//! summaries answer in bounded memory with an explicit rank-error
//! bound. A standalone [`TopKSketch`] over the merged counts shows the
//! same machinery single-shard.
//!
//! ```bash
//! cargo run --release --example topk_trending
//! ```

use fish::aggregate::TopKSketch;
use fish::coordinator::SchemeKind;
use fish::engine::Pipeline;
use fish::report::{f2, ns, ratio, Table};

const TUPLES: usize = 150_000;
const WORKERS: usize = 16;
const SHARDS: usize = 4;
const TOP: usize = 10;

fn run(kind: SchemeKind) -> fish::engine::SimResult {
    Pipeline::builder()
        .workload("zf") // evolving Zipf: the hot set drifts mid-stream
        .scheme(kind)
        .sources(4)
        .workers(WORKERS)
        .tuples(TUPLES)
        .zipf_z(1.6)
        .agg_flush_ms(1)
        .agg_shards(SHARDS)
        // arrival rate ≈ aggregate service rate: keep workers busy
        .configure(|c| c.interarrival_ns = c.service_ns / c.workers as u64 + 1)
        .build_sim()
        .run()
}

fn main() {
    println!(
        "top-{TOP} trending keys: {TUPLES} evolving-Zipf tuples, {WORKERS} workers, 4 sources\n"
    );
    let fish_r = run(SchemeKind::Fish);
    let fg_r = run(SchemeKind::Field);

    // --- the oracle: both schemes merge to identical exact rankings ---
    let fish_top = fish_r.top_k(TOP);
    let fg_top = fg_r.top_k(TOP);
    assert_eq!(fish_top, fg_top, "two-stage merge must erase the scheme from the results");

    let mut t = Table::new(
        "exact merged top-k (identical under FISH and FG — the aggregation oracle)",
        &["rank", "key", "count"],
    );
    for (i, &(k, c)) in fish_top.iter().enumerate() {
        t.row(&[(i + 1).to_string(), k.to_string(), c.to_string()]);
    }
    t.print();

    // --- what the schemes paid for that same answer ---
    let mut cost = Table::new(
        "price per scheme: FG lags on execution, FISH pays a little merge traffic",
        &["scheme", "makespan", "p99 latency", "agg messages", "agg payload", "shard imb"],
    );
    for (name, r) in [("fish", &fish_r), ("fg", &fg_r)] {
        assert_eq!(r.shard_agg.n_shards(), SHARDS);
        cost.row(&[
            name.into(),
            ns(r.makespan),
            ns(r.latency.quantile(0.99)),
            r.agg.messages.to_string(),
            format!("{} B", r.agg.bytes),
            f2(r.shard_agg.imbalance().relative),
        ]);
    }
    cost.print();

    // --- scatter-gather: per-shard summaries answer the global query ---
    let gathered = fish_r.gather.top(TOP);
    let hits = gathered
        .top
        .iter()
        .filter(|(k, _)| fish_top.iter().any(|&(ek, _)| ek == *k))
        .count();
    println!(
        "TopKGather over {SHARDS} shards ({} tracked entries, rank-error bound {:.0}): \
         {hits}/{TOP} of the exact top-{TOP} recovered",
        fish_r.gather.entries(),
        gathered.error_bound,
    );
    assert!(hits >= TOP * 8 / 10, "scatter-gather lost the hot set: {hits}/{TOP}");
    println!(
        "FG/FISH makespan: {} — same answer, Field Grouping just arrives later\n",
        ratio(fg_r.makespan as f64 / fish_r.makespan as f64)
    );

    // --- bounded-memory trending: SpaceSaving over the flush mass ---
    // 256 counters over ~10^5 keys: SpaceSaving's overestimate bound
    // (total/capacity) sits well under the 10th-hottest key's mass.
    let mut sketch = TopKSketch::new(256);
    for &(k, c) in &fish_r.merged_counts {
        sketch.absorb(k, c);
    }
    let approx = sketch.top(TOP);
    let hits = approx
        .iter()
        .filter(|(k, _)| fish_top.iter().any(|&(ek, _)| ek == *k))
        .count();
    println!(
        "TopKSketch (256 tracked keys over {} merged): {hits}/{TOP} of the exact top-{TOP} recovered",
        fish_r.merged_counts.len()
    );
    assert!(hits >= TOP * 8 / 10, "bounded sketch lost the hot set: {hits}/{TOP}");
}
