//! Trending keys under the two-stage topology: top-k queries over the
//! downstream merge stage.
//!
//! A time-evolving Zipf stream (hot set drifts mid-stream) runs through
//! FISH and through Field Grouping. Both produce the *same* merged
//! top-k — that is the aggregation oracle: whatever a scheme did to
//! split or not split keys, stage two reassembles exact counts. What
//! differs is the price: FG pins each hot key to one worker and its
//! queue explodes (makespan, p99 lag far behind), while FISH scatters
//! hot keys and pays only a little aggregation traffic to merge the
//! partials back.
//!
//! Stage two runs as a **sharded fabric** here (`--agg_shards`-style,
//! 4 key-range merge shards): flushes scatter across the shards, the
//! per-shard ledgers expose the aggregation stage's own imbalance, and
//! global top-k comes back two ways — exact (merged counts) and via the
//! scatter-gather [`TopKGather`] front-end, whose per-shard SpaceSaving
//! summaries answer in bounded memory with an explicit rank-error
//! bound. A standalone [`TopKSketch`] over the merged counts shows the
//! same machinery single-shard.
//!
//! The fabric also runs **windowed** (`--agg_window_ms`-style, 1 ms
//! tumbling panes): the same runs retire per-window exact counts, and
//! because the workload's hot set inverts late in the stream, "trending
//! in the last window" diverges sharply from the all-time top-k — the
//! all-time ranking still rewards hot keys that went cold long ago,
//! the last pane answers for *now*. Sliding windows compose from the
//! panes.
//!
//! ```bash
//! cargo run --release --example topk_trending
//! ```

use fish::aggregate::TopKSketch;
use fish::coordinator::SchemeKind;
use fish::engine::Pipeline;
use fish::report::{f2, ns, ratio, Table};

const TUPLES: usize = 150_000;
const WORKERS: usize = 16;
const SHARDS: usize = 4;
const TOP: usize = 10;
const WINDOW_MS: u64 = 1;

fn run(kind: SchemeKind) -> fish::engine::SimResult {
    Pipeline::builder()
        .workload("zf") // evolving Zipf: the hot set drifts mid-stream
        .scheme(kind)
        .sources(4)
        .workers(WORKERS)
        .tuples(TUPLES)
        .zipf_z(1.6)
        .agg_flush_ms(1)
        .agg_shards(SHARDS)
        .agg_window_ms(WINDOW_MS)
        // arrival rate ≈ aggregate service rate: keep workers busy
        .configure(|c| c.interarrival_ns = c.service_ns / c.workers as u64 + 1)
        .build_sim()
        .run()
}

fn main() {
    println!(
        "top-{TOP} trending keys: {TUPLES} evolving-Zipf tuples, {WORKERS} workers, 4 sources\n"
    );
    let fish_r = run(SchemeKind::Fish);
    let fg_r = run(SchemeKind::Field);

    // --- the oracle: both schemes merge to identical exact rankings ---
    let fish_top = fish_r.top_k(TOP);
    let fg_top = fg_r.top_k(TOP);
    assert_eq!(fish_top, fg_top, "two-stage merge must erase the scheme from the results");

    let mut t = Table::new(
        "exact merged top-k (identical under FISH and FG — the aggregation oracle)",
        &["rank", "key", "count"],
    );
    for (i, &(k, c)) in fish_top.iter().enumerate() {
        t.row(&[(i + 1).to_string(), k.to_string(), c.to_string()]);
    }
    t.print();

    // --- what the schemes paid for that same answer ---
    let mut cost = Table::new(
        "price per scheme: FG lags on execution, FISH pays a little merge traffic",
        &["scheme", "makespan", "p99 latency", "agg messages", "agg payload", "shard imb"],
    );
    for (name, r) in [("fish", &fish_r), ("fg", &fg_r)] {
        assert_eq!(r.shard_agg.n_shards(), SHARDS);
        cost.row(&[
            name.into(),
            ns(r.makespan),
            ns(r.latency.quantile(0.99)),
            r.agg.messages.to_string(),
            format!("{} B", r.agg.bytes),
            f2(r.shard_agg.imbalance().relative),
        ]);
    }
    cost.print();

    // --- scatter-gather: per-shard summaries answer the global query ---
    let gathered = fish_r.gather.top(TOP);
    let hits = gathered
        .top
        .iter()
        .filter(|(k, _)| fish_top.iter().any(|&(ek, _)| ek == *k))
        .count();
    println!(
        "TopKGather over {SHARDS} shards ({} tracked entries, rank-error bound {:.0}): \
         {hits}/{TOP} of the exact top-{TOP} recovered",
        fish_r.gather.entries(),
        gathered.error_bound,
    );
    assert!(hits >= TOP * 8 / 10, "scatter-gather lost the hot set: {hits}/{TOP}");
    println!(
        "FG/FISH makespan: {} — same answer, Field Grouping just arrives later\n",
        ratio(fg_r.makespan as f64 / fish_r.makespan as f64)
    );

    // --- windowed: "trending now" vs the all-time ranking ---
    // The same runs retired 1 ms tumbling panes; the per-window oracle
    // holds pane by pane (FISH's windows == FG's windows), and because
    // the zf hot set inverts late in the stream, the last pane's top-k
    // has moved on from the all-time answer.
    assert!(!fish_r.windows.is_empty(), "windowed mode produced no panes");
    assert_eq!(fish_r.windows.len(), fg_r.windows.len());
    for (a, b) in fish_r.windows.iter().zip(&fg_r.windows) {
        assert_eq!(a.counts, b.counts, "windowed oracle broke at pane {}", a.window);
    }
    assert_eq!(
        fish_r.windows.iter().map(|w| w.total()).sum::<u64>(),
        TUPLES as u64,
        "panes must partition the stream"
    );
    let last = fish_r.windows.last().unwrap();
    let trending = last.top_k(TOP);
    assert_ne!(
        trending, fish_top,
        "hot-set inversion must separate trending from all-time top-k"
    );
    let mut wt = Table::new(
        &format!(
            "all-time top-{TOP} vs trending (last {WINDOW_MS} ms pane, {} panes retired)",
            fish_r.windows.len()
        ),
        &["rank", "all-time key", "count", "trending key", "count"],
    );
    for i in 0..TOP {
        wt.row(&[
            (i + 1).to_string(),
            fish_top[i].0.to_string(),
            fish_top[i].1.to_string(),
            trending[i].0.to_string(),
            trending[i].1.to_string(),
        ]);
    }
    wt.print();
    println!(
        "pane lifecycle: {} pane-shard retirements, peak {} open panes/shard, \
         peak {} open-pane entries, {} late reopens\n",
        fish_r.window_stats.panes_retired,
        fish_r.window_stats.max_open_panes,
        fish_r.window_stats.max_open_entries,
        fish_r.window_stats.late_reopens,
    );

    // sliding windows compose from panes: a 3 ms window sliding by 1 ms
    let slid = fish::aggregate::sliding(&fish_r.windows, 3);
    let last3 = slid.last().unwrap();
    assert_eq!(
        last3.total(),
        fish_r.windows.iter().rev().take(3).map(|w| w.total()).sum::<u64>()
    );
    println!(
        "sliding window [{:.1} ms, {:.1} ms): top key {} × {} (3 panes merged, gather bound {:.0})\n",
        last3.start_ns() as f64 / 1e6,
        last3.end_ns() as f64 / 1e6,
        last3.top_k(1)[0].0,
        last3.top_k(1)[0].1,
        last3.gather.top(TOP).error_bound,
    );

    // --- bounded-memory trending: SpaceSaving over the flush mass ---
    // 256 counters over ~10^5 keys: SpaceSaving's overestimate bound
    // (total/capacity) sits well under the 10th-hottest key's mass.
    let mut sketch = TopKSketch::new(256);
    for &(k, c) in &fish_r.merged_counts {
        sketch.absorb(k, c);
    }
    let approx = sketch.top(TOP);
    let hits = approx
        .iter()
        .filter(|(k, _)| fish_top.iter().any(|&(ek, _)| ek == *k))
        .count();
    println!(
        "TopKSketch (256 tracked keys over {} merged): {hits}/{TOP} of the exact top-{TOP} recovered",
        fish_r.merged_counts.len()
    );
    assert!(hits >= TOP * 8 / 10, "bounded sketch lost the hot set: {hits}/{TOP}");
}
