//! Quickstart: route a small time-evolving Zipf stream through every
//! grouping scheme and print the paper's two core metrics side by side.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fish::config::Config;
use fish::coordinator::SchemeKind;
use fish::engine::sim;
use fish::report::{ns, ratio, Table};

fn main() {
    let mut base = Config::default();
    base.workload = "zf".into();
    base.tuples = 200_000;
    base.zipf_z = 1.5;
    base.workers = 32;
    base.sources = 4;
    base.interarrival_ns = base.service_ns / base.workers as u64 + 1;

    println!(
        "FISH quickstart: {} tuples, zipf z={}, {} workers, {} sources\n",
        base.tuples, base.zipf_z, base.workers, base.sources
    );

    let mut table = Table::new(
        "grouping schemes on a time-evolving Zipf stream",
        &["scheme", "exec time", "vs SG", "p99 latency", "memory vs FG"],
    );

    let mut sg_makespan = None;
    for kind in SchemeKind::all() {
        let mut cfg = base.clone();
        cfg.scheme = kind;
        let r = sim::run_config(&cfg);
        if kind == SchemeKind::Shuffle {
            sg_makespan = Some(r.makespan);
        }
        let vs_sg = sg_makespan
            .map(|m| ratio(r.makespan as f64 / m as f64))
            .unwrap_or_else(|| "-".into());
        table.row(&[
            kind.name().to_string(),
            ns(r.makespan),
            vs_sg,
            ns(r.latency.quantile(0.99)),
            ratio(r.memory_normalized),
        ]);
    }
    table.print();

    println!(
        "\nExpected shape (paper Figs. 9–11): FISH ≈ SG execution time at\n\
         near-FG memory; FG suffers latency, SG suffers memory, PKG/D-C/W-C\n\
         sit in between and degrade as workers scale."
    );
}
