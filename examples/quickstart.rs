//! Quickstart: the batch-first `PipelineBuilder` API.
//!
//! A job is one fluent chain — workload, scheme, topology, batch size —
//! ending in `build_sim()` (deterministic simulator) or `build_rt()`
//! (threaded runtime):
//!
//! ```text
//! let result = Pipeline::builder()
//!     .workload("zf")            // zf | mt | am
//!     .scheme(SchemeKind::Fish)  // sg | fg | pkg | dc | wc | fish
//!     .sources(4)                // grouper instances (Storm tasks)
//!     .workers(32)               // downstream operator instances
//!     .batch(1024)               // tuples per route_batch() call
//!     .tuples(200_000)
//!     .build_sim()
//!     .run();
//! ```
//!
//! This example routes a small time-evolving Zipf stream through every
//! grouping scheme and prints the paper's two core metrics side by side.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fish::coordinator::SchemeKind;
use fish::engine::Pipeline;
use fish::report::{ns, ratio, Table};

fn main() {
    let tuples = 200_000;
    let workers = 32;
    println!("FISH quickstart: {tuples} tuples, zipf z=1.5, {workers} workers, 4 sources\n");

    let mut table = Table::new(
        "grouping schemes on a time-evolving Zipf stream",
        &["scheme", "exec time", "vs SG", "p99 latency", "memory vs FG"],
    );

    let mut sg_makespan = None;
    for kind in SchemeKind::all() {
        let r = Pipeline::builder()
            .workload("zf")
            .scheme(kind)
            .sources(4)
            .workers(workers)
            .batch(1024)
            .tuples(tuples)
            .zipf_z(1.5)
            // arrival rate ≈ aggregate service rate: keep workers busy
            .configure(|c| c.interarrival_ns = c.service_ns / c.workers as u64 + 1)
            .build_sim()
            .run();
        if kind == SchemeKind::Shuffle {
            sg_makespan = Some(r.makespan);
        }
        let vs_sg = sg_makespan
            .map(|m| ratio(r.makespan as f64 / m as f64))
            .unwrap_or_else(|| "-".into());
        table.row(&[
            kind.name().to_string(),
            ns(r.makespan),
            vs_sg,
            ns(r.latency.quantile(0.99)),
            ratio(r.memory_normalized),
        ]);
    }
    table.print();

    println!(
        "\nExpected shape (paper Figs. 9–11): FISH ≈ SG execution time at\n\
         near-FG memory; FG suffers latency, SG suffers memory, PKG/D-C/W-C\n\
         sit in between and degrade as workers scale."
    );
}
