#!/usr/bin/env python3
"""Validate a merged Chrome-trace timeline (`fish sim/deploy --trace-out`).

Usage:
    check_trace.py TRACE_JSON [--chain]
        [--expect-workers N] [--expect-shards N]
        [--metrics METRICS_JSONL]

Structural checks (always on):
  * the file is a Chrome-trace object (`traceEvents` list, non-empty);
  * every event's phase is one of M (metadata), X (complete span),
    i (instant), C (counter);
  * spans have a non-negative `dur`, instants carry `"s":"t"`,
    counters carry an integer `args.v`;
  * every (pid, tid) lane's timestamps are monotonically
    non-decreasing in file order — the exporter sorts per-thread
    events, so a regression here means a clock-domain mix-up;
  * every pid that emits events also emits exactly one `process_name`
    metadata line, and all events in one process agree on its clock
    label (virtual vs wall — mixing domains in a pid would render as
    nonsense in Perfetto).

With --expect-workers / --expect-shards, the merged timeline must
contain events from the coordinator (pid 0), from every worker child
(pid 100+i) and every shard child (pid 200+i) — the cross-process
export actually shipped each child's buffer home at Done time.

With --chain, the flush causal chain must be complete: the multiset of
`seq` keys on `flush_send` spans equals the multiset on
`merge_absorb`/`flush_dedup` events, and each key appears exactly once
on each side. Only sound on fault-free runs — chaos replay legitimately
dedups — so the CI chaos lane omits it.

With --metrics, the telemetry JSONL next to the trace is also checked:
every line parses, carries the fixed key set, and rows are sorted by
(ts_ns, src).

Exit status: 0 = valid, 1 = validation failure, 2 = bad input.
"""

import argparse
import json
import sys

PHASES = {"M", "X", "i", "C"}
METRIC_KEYS = [
    "src", "ts_ns", "tuples", "wire_bytes", "queue_depth", "open_panes",
    "open_entries", "absorbed", "imbalance_x1000", "replay_backlog",
]


def load_trace(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    events = doc.get("traceEvents") if isinstance(doc, dict) else None
    if not isinstance(events, list) or not events:
        print(f"error: {path} has no traceEvents[]", file=sys.stderr)
        sys.exit(2)
    return events


def check_events(events, failures):
    """Per-event shape + per-lane monotonicity + metadata coverage."""
    last_ts = {}          # (pid, tid) -> last seen ts
    event_pids = set()    # pids with at least one non-metadata event
    named = {}            # pid -> count of process_name metadata lines
    clocks = {}           # pid -> clock label from metadata
    spans = instants = counters = 0
    for i, e in enumerate(events):
        ph = e.get("ph")
        if ph not in PHASES:
            failures.append(f"event {i}: unknown phase {ph!r}")
            continue
        pid, tid = e.get("pid"), e.get("tid")
        if not isinstance(pid, int) or not isinstance(tid, int):
            failures.append(f"event {i}: non-integer pid/tid ({pid!r}, {tid!r})")
            continue
        if ph == "M":
            if e.get("name") == "process_name":
                named[pid] = named.get(pid, 0) + 1
                args = e.get("args") or {}
                if not args.get("name"):
                    failures.append(f"event {i}: process_name for pid {pid} "
                                    "has no args.name")
                clocks[pid] = args.get("clock")
            continue
        event_pids.add(pid)
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            failures.append(f"event {i}: bad ts {ts!r}")
            continue
        lane = (pid, tid)
        if ts < last_ts.get(lane, float("-inf")):
            failures.append(f"event {i}: ts {ts} regresses on lane "
                            f"pid={pid} tid={tid} (last {last_ts[lane]})")
        last_ts[lane] = ts
        if ph == "X":
            spans += 1
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                failures.append(f"event {i}: span {e.get('name')!r} has "
                                f"bad dur {dur!r}")
        elif ph == "i":
            instants += 1
            if e.get("s") != "t":
                failures.append(f"event {i}: instant {e.get('name')!r} "
                                f"missing thread scope (s={e.get('s')!r})")
        elif ph == "C":
            counters += 1
            v = (e.get("args") or {}).get("v")
            if not isinstance(v, int):
                failures.append(f"event {i}: counter {e.get('name')!r} has "
                                f"non-integer args.v {v!r}")

    for pid in sorted(event_pids):
        n = named.get(pid, 0)
        if n != 1:
            failures.append(f"pid {pid}: {n} process_name metadata lines "
                            "(want exactly 1)")
        elif not clocks.get(pid):
            failures.append(f"pid {pid}: process_name carries no clock label")
    for pid in sorted(named):
        if pid not in event_pids:
            failures.append(f"pid {pid}: metadata but no events")
    return event_pids, len(last_ts), spans, instants, counters


def check_processes(event_pids, workers, shards, failures):
    """Coordinator + every expected child contributed to the merge."""
    if 0 not in event_pids:
        failures.append("coordinator (pid 0) absent from the merged timeline")
    for i in range(workers):
        if 100 + i not in event_pids:
            failures.append(f"worker {i} (pid {100 + i}) absent — "
                            "its Done payload never shipped a trace blob?")
    for i in range(shards):
        if 200 + i not in event_pids:
            failures.append(f"shard {i} (pid {200 + i}) absent — "
                            "its Done payload never shipped a trace blob?")


def check_chain(events, failures):
    """flush_send seq keys must pair 1:1 with merge_absorb/flush_dedup."""
    sent, landed = {}, {}
    for e in events:
        seq = (e.get("args") or {}).get("seq")
        if seq is None:
            continue
        name = e.get("name")
        if name == "flush_send":
            sent[seq] = sent.get(seq, 0) + 1
        elif name in ("merge_absorb", "flush_dedup"):
            landed[seq] = landed.get(seq, 0) + 1
    if not sent:
        failures.append("--chain: no flush_send events with seq keys")
        return 0
    for seq, n in sorted(sent.items()):
        if n != 1:
            failures.append(f"--chain: flush seq {seq} sent {n} times")
        got = landed.pop(seq, 0)
        if got != 1:
            failures.append(f"--chain: flush seq {seq} sent once, "
                            f"landed {got} times")
    for seq, n in sorted(landed.items()):
        failures.append(f"--chain: seq {seq} landed {n} times but was "
                        "never sent")
    return len(sent)


def check_metrics(path, failures):
    """Telemetry JSONL: fixed key set, (ts_ns, src)-sorted rows."""
    try:
        with open(path) as f:
            lines = [ln for ln in f.read().splitlines() if ln.strip()]
    except OSError as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    if not lines:
        failures.append(f"--metrics: {path} is empty — sampler never fired")
        return 0
    prev = None
    for i, ln in enumerate(lines):
        try:
            row = json.loads(ln)
        except ValueError as e:
            failures.append(f"--metrics: line {i + 1} is not JSON: {e}")
            continue
        missing = [k for k in METRIC_KEYS if not isinstance(row.get(k), int)]
        if missing:
            failures.append(f"--metrics: line {i + 1} missing integer "
                            f"key(s) {missing}")
            continue
        key = (row["ts_ns"], row["src"])
        if prev is not None and key < prev:
            failures.append(f"--metrics: line {i + 1} out of (ts_ns, src) "
                            f"order: {key} after {prev}")
        prev = key
    return len(lines)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace")
    ap.add_argument("--chain", action="store_true",
                    help="require a complete flush_send ↔ merge_absorb "
                         "chain (fault-free runs only)")
    ap.add_argument("--expect-workers", type=int, default=0,
                    help="require events from worker pids 100..100+N-1")
    ap.add_argument("--expect-shards", type=int, default=0,
                    help="require events from shard pids 200..200+N-1")
    ap.add_argument("--metrics", metavar="JSONL",
                    help="also validate the --metrics-out JSONL")
    args = ap.parse_args()

    events = load_trace(args.trace)
    failures = []
    event_pids, lanes, spans, instants, counters = check_events(events, failures)
    if args.expect_workers or args.expect_shards:
        check_processes(event_pids, args.expect_workers, args.expect_shards,
                        failures)
    chained = check_chain(events, failures) if args.chain else 0
    metric_rows = check_metrics(args.metrics, failures) if args.metrics else 0

    if failures:
        print(f"trace gate FAILED for {args.trace}:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    parts = [f"{len(events)} events ({spans} spans, {instants} instants, "
             f"{counters} counter samples) across {len(event_pids)} "
             f"process(es), {lanes} thread lane(s)"]
    if args.chain:
        parts.append(f"{chained} flush chains complete")
    if args.metrics:
        parts.append(f"{metric_rows} telemetry rows")
    print(f"trace gate ok: {', '.join(parts)}")


if __name__ == "__main__":
    main()
