#!/usr/bin/env python3
"""Gate batched-routing performance against a checked-in baseline.

Usage:
    check_perf.py CURRENT_JSON BASELINE_JSON [--threshold 0.25]
    check_perf.py --lint LINT_JSON --lint-baseline scripts/lint_baseline.json
    check_perf.py --recovery RECOVERY_JSON \
        --recovery-baseline benches/baselines/recovery_smoke.json \
        [--recovery-threshold 0.5]
    check_perf.py --model MODEL_JSON --model-baseline scripts/model_baseline.json

CURRENT_JSON is the `BENCH_hotpath.json` a `cargo bench --bench hotpath`
run just emitted; BASELINE_JSON is `benches/baselines/hotpath_smoke.json`.

For every (scheme, workers) pair in the baseline, the *speedup* of
batched routing over per-tuple routing (tuple_ns / b1024_ns, computed on
the same machine in the same run) must not fall more than THRESHOLD
below the baseline speedup. Ratios — not raw ns/op — are compared, so
the gate is stable across runner hardware while still failing when the
batched hot path regresses relative to the per-tuple reference.

The aggregation path is gated the same way: for every op in the
baseline's agg_results[] (MergeStage absorb, shard-routing dispatch,
the windowed path — WindowedPartial::observe pane assignment and
WindowedMerge absorb + watermark retirement per entry — and the
transport wire codec: encode_data serialize and decode_frame
deserialize per tuple at engine batch size), its cost
*relative to PartialAgg::observe in the same run* (ratio_vs_observe)
must not rise more than AGG-THRESHOLD above the baseline ratio. Again
a same-machine ratio, so runner hardware cancels out; only the
two-stage path getting slower relative to its own stage one fails the
gate.

With --lint, the gate compares `fish lint --json` output against the
checked-in findings baseline instead: any (rule, file) pair present in
the current report but absent from the baseline fails the gate. The
baseline is empty — the tree lints clean — so in practice any new
finding fails; the indirection exists so a finding can be temporarily
baselined during a multi-PR refactor without disabling the job.

With --model, the gate reads `fish model --all --json` output: every
run must be ok (honest configs clean, every seeded mutation caught
with a counterexample), the honest sweeps must have explored at least
min_states distinct states (so the exhaustive check cannot silently
shrink to a trivial bound), and the whole suite must finish under
max_wall_ms (explicit-state checking is exponential in the bounds — a
model change that blows the state space out should be a deliberate
decision, not a CI slowdown nobody notices).

With --recovery, the gate reads the `--recovery-json` metrics a chaos
deploy run (`fish deploy --chaos ... --recovery-json PATH`) just wrote
and holds them against `benches/baselines/recovery_smoke.json`. Two
kinds of checks: the baseline's require{} minimums prove the kill
actually fired and recovery actually ran (restarts, snapshot restores,
replayed batches), and its max_* ceilings bound how expensive that
recovery was — wall-clock nanoseconds from kill to rejoin, and the
replayed-batch ratio (replayed / absorbed flush batches, the
wasted-work fraction). RECOVERY-THRESHOLD is multiplicative headroom
on the ceilings (0.5 = 50% over baseline) so a noisy CI runner does
not flake the lane while a real regression — a snapshot cadence bug
inflating replay, a reconnect stall — still fails.

Exit status: 0 = within threshold, 1 = regression, 2 = bad input.
"""

import argparse
import json
import sys


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def index_results(doc, path):
    results = doc.get("results")
    if not isinstance(results, list) or not results:
        print(f"error: {path} has no results[]", file=sys.stderr)
        sys.exit(2)
    out = {}
    for row in results:
        out[(row["scheme"], row["workers"])] = row
    return out


def index_agg(doc):
    """agg_results[] indexed by op name ({} when the section is absent)."""
    return {row["op"]: row for row in doc.get("agg_results") or []}


def check_lint(current_path, baseline_path):
    """Fail on any (rule, file) finding not present in the baseline."""
    current = load(current_path)
    baseline = load(baseline_path)
    if not isinstance(current.get("findings"), list):
        print(f"error: {current_path} has no findings[]", file=sys.stderr)
        sys.exit(2)
    baselined = {(row["rule"], row["file"])
                 for row in baseline.get("findings") or []}
    new = [row for row in current["findings"]
           if (row["rule"], row["file"]) not in baselined]
    scanned = current.get("files_scanned", "?")
    suppressed = current.get("suppressions", "?")
    if new:
        print("lint gate FAILED: findings not in the baseline:", file=sys.stderr)
        for row in new:
            print(f"  - {row['file']}:{row.get('line', '?')}: "
                  f"[{row['rule']}] {row.get('message', '')}", file=sys.stderr)
        sys.exit(1)
    print(f"lint gate ok: {scanned} files scanned, "
          f"{len(current['findings'])} finding(s) all baselined, "
          f"{suppressed} documented suppression(s)")


def check_recovery(current_path, baseline_path, threshold):
    """Gate chaos-lane recovery metrics against the checked-in bounds."""
    current = load(current_path)
    baseline = load(baseline_path)
    failures = []

    require = baseline.get("require") or {}
    ceilings = baseline.get("ceilings") or {}
    if not require and not ceilings:
        print(f"error: {baseline_path} has neither require{{}} nor ceilings{{}}",
              file=sys.stderr)
        sys.exit(2)

    print(f"{'metric':>18} {'current':>14} {'bound':>14}  status")
    for key, want in sorted(require.items()):
        got = current.get(key)
        if got is None:
            failures.append(f"{key}: missing from {current_path}")
            print(f"{key:>18} {'—':>14} {f'>= {want}':>14}  MISSING")
            continue
        ok = got >= want
        print(f"{key:>18} {got:>14} {f'>= {want}':>14}  {'ok' if ok else 'FAILED'}")
        if not ok:
            failures.append(
                f"{key} = {got}, chaos lane requires at least {want} — "
                "did the scripted kill fire and recovery run?")

    for key, base in sorted(ceilings.items()):
        ceiling = base * (1.0 + threshold)
        got = current.get(key)
        if got is None:
            failures.append(f"{key}: missing from {current_path}")
            print(f"{key:>18} {'—':>14} {ceiling:>14.3f}  MISSING")
            continue
        ok = got <= ceiling
        print(f"{key:>18} {got:>14.3f} {ceiling:>14.3f}  {'ok' if ok else 'EXCEEDED'}")
        if not ok:
            failures.append(
                f"{key} = {got:.3f} exceeded ceiling {ceiling:.3f} "
                f"(baseline {base:.3f}, headroom {threshold:.0%})")

    if failures:
        print("\nrecovery gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print(f"\nrecovery gate ok: {len(require)} liveness minimum(s) met, "
          f"{len(ceilings)} cost ceiling(s) within {threshold:.0%} headroom")


def check_model(current_path, baseline_path):
    """Gate `fish model --all --json` output against the model bounds."""
    current = load(current_path)
    baseline = load(baseline_path)
    runs = current.get("runs")
    if not isinstance(runs, list) or not runs:
        print(f"error: {current_path} has no runs[]", file=sys.stderr)
        sys.exit(2)
    min_states = baseline.get("min_states")
    max_wall_ms = baseline.get("max_wall_ms")
    if min_states is None or max_wall_ms is None:
        print(f"error: {baseline_path} needs min_states and max_wall_ms",
              file=sys.stderr)
        sys.exit(2)

    failures = []
    honest = [r for r in runs if r.get("mutation") is None]
    mutated = [r for r in runs if r.get("mutation") is not None]
    for r in runs:
        if r.get("ok"):
            continue
        if r.get("mutation") is None:
            failures.append(
                f"{r['protocol']} {r['config']}: honest run found a violation: "
                f"{r.get('violation')}")
        else:
            failures.append(
                f"{r['protocol']} {r['config']} [{r['mutation']}]: seeded "
                "mutation scanned clean — the checker missed the bug")
    if not mutated:
        failures.append("no mutation runs in the report — was --all passed?")

    total_states = current.get("total_states", 0)
    wall_ms = current.get("wall_ms")
    if total_states < min_states:
        failures.append(
            f"honest sweeps explored {total_states} states, below the "
            f"{min_states} floor — the exhaustive check shrank")
    if wall_ms is None:
        failures.append(f"wall_ms missing from {current_path}")
    elif wall_ms > max_wall_ms:
        failures.append(
            f"model suite took {wall_ms} ms, over the {max_wall_ms} ms "
            "ceiling — a state-space blow-up should be a deliberate choice")

    if failures:
        print("model gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print(f"model gate ok: {len(honest)} honest run(s) clean "
          f"({total_states} states explored, floor {min_states}), "
          f"{len(mutated)} seeded mutation(s) caught, "
          f"{wall_ms} ms (ceiling {max_wall_ms})")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", nargs="?")
    ap.add_argument("baseline", nargs="?")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed relative speedup regression (default 0.25)")
    ap.add_argument("--agg-threshold", type=float, default=1.0,
                    help="max allowed relative rise of an aggregation-path "
                         "ratio_vs_observe (default 1.0 = 100%%; these "
                         "micro-ratios are noisier than routing speedups)")
    ap.add_argument("--lint", metavar="LINT_JSON",
                    help="gate `fish lint --json` output instead of perf")
    ap.add_argument("--lint-baseline", metavar="BASELINE_JSON",
                    default="scripts/lint_baseline.json",
                    help="checked-in lint findings baseline "
                         "(default scripts/lint_baseline.json)")
    ap.add_argument("--model", metavar="MODEL_JSON",
                    help="gate `fish model --all --json` output instead "
                         "of perf")
    ap.add_argument("--model-baseline", metavar="BASELINE_JSON",
                    default="scripts/model_baseline.json",
                    help="checked-in model-check bounds "
                         "(default scripts/model_baseline.json)")
    ap.add_argument("--recovery", metavar="RECOVERY_JSON",
                    help="gate `fish deploy --recovery-json` output "
                         "instead of perf")
    ap.add_argument("--recovery-baseline", metavar="BASELINE_JSON",
                    default="benches/baselines/recovery_smoke.json",
                    help="checked-in recovery bounds "
                         "(default benches/baselines/recovery_smoke.json)")
    ap.add_argument("--recovery-threshold", type=float, default=0.5,
                    help="multiplicative headroom over the baseline "
                         "ceilings (default 0.5 = 50%%)")
    args = ap.parse_args()

    if args.lint:
        check_lint(args.lint, args.lint_baseline)
        return
    if args.model:
        check_model(args.model, args.model_baseline)
        return
    if args.recovery:
        check_recovery(args.recovery, args.recovery_baseline,
                       args.recovery_threshold)
        return
    if not args.current or not args.baseline:
        ap.error("CURRENT_JSON and BASELINE_JSON are required "
                 "without --lint/--recovery")

    current_doc = load(args.current)
    baseline_doc = load(args.baseline)
    current = index_results(current_doc, args.current)
    baseline = index_results(baseline_doc, args.baseline)

    failures = []
    print(f"{'scheme':>8} {'workers':>8} {'baseline':>9} {'current':>9} {'floor':>9}  status")
    for key, base_row in sorted(baseline.items()):
        scheme, workers = key
        base = base_row["speedup_b1024"]
        floor = base * (1.0 - args.threshold)
        cur_row = current.get(key)
        if cur_row is None:
            failures.append(f"{scheme}/{workers}w: missing from current results")
            print(f"{scheme:>8} {workers:>8} {base:>9.3f} {'—':>9} {floor:>9.3f}  MISSING")
            continue
        cur = cur_row["speedup_b1024"]
        ok = cur >= floor
        print(f"{scheme:>8} {workers:>8} {base:>9.3f} {cur:>9.3f} {floor:>9.3f}  "
              f"{'ok' if ok else 'REGRESSED'}")
        if not ok:
            failures.append(
                f"{scheme}/{workers}w: batched-routing speedup {cur:.3f} fell below "
                f"{floor:.3f} (baseline {base:.3f}, threshold {args.threshold:.0%})")

    # aggregation-path gate: op cost relative to PartialAgg::observe must
    # not rise more than --agg-threshold above the baseline ratio
    agg_base = index_agg(baseline_doc)
    agg_cur = index_agg(current_doc)
    gated_ops = 0
    if agg_base:
        print(f"\n{'op':>16} {'baseline':>9} {'current':>9} {'ceiling':>9}  status")
        for op, base_row in sorted(agg_base.items()):
            base = base_row["ratio_vs_observe"]
            ceiling = base * (1.0 + args.agg_threshold)
            cur_row = agg_cur.get(op)
            if cur_row is None:
                failures.append(f"agg/{op}: missing from current agg_results")
                print(f"{op:>16} {base:>9.3f} {'—':>9} {ceiling:>9.3f}  MISSING")
                continue
            cur = cur_row["ratio_vs_observe"]
            ok = cur <= ceiling
            gated_ops += 1
            print(f"{op:>16} {base:>9.3f} {cur:>9.3f} {ceiling:>9.3f}  "
                  f"{'ok' if ok else 'REGRESSED'}")
            if not ok:
                failures.append(
                    f"agg/{op}: ratio vs observe {cur:.3f} rose above "
                    f"{ceiling:.3f} (baseline {base:.3f}, threshold "
                    f"{args.agg_threshold:.0%})")

    if failures:
        print("\nperf-smoke FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print("\nperf-smoke ok: batched routing within threshold for "
          f"{len(baseline)} scheme/worker pairs, aggregation path within "
          f"threshold for {gated_ops} ops")


if __name__ == "__main__":
    main()
