//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build is fully offline (no crates.io), so this vendored shim
//! provides the small surface the repo uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] macros and the [`Context`] extension
//! trait. Errors carry a rendered message (no backtraces, no chains) —
//! enough for CLI diagnostics and test assertions.

use std::fmt;

/// A type-erased error: any `std::error::Error` converts into it, and
/// the macros build one from a format string.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error while converting it into [`Error`].
pub trait Context<T> {
    /// Wrap the error with a static context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 3;
        let b = anyhow!("n = {n}, sum = {}", 1 + 2);
        assert_eq!(b.to_string(), "n = 3, sum = 3");
        let c = anyhow!(io_err());
        assert_eq!(c.to_string(), "boom");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "boom");
    }

    #[test]
    fn context_wraps() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "reading x: boom");
    }

    #[test]
    fn bail_returns() {
        fn inner() -> Result<u32> {
            bail!("nope {}", 7);
        }
        assert_eq!(inner().unwrap_err().to_string(), "nope 7");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_bounds<T: Send + Sync>() {}
        assert_bounds::<Error>();
    }
}
