//! Minimal offline SHA-1 (RFC 3174), exposing the tiny subset of the
//! `sha1`/`digest` crate API the repo uses: `Sha1::digest(bytes)`.
//!
//! The consistent-hash ring hashes worker virtual nodes with SHA-1 per
//! the paper's choice; inputs are 16-byte ids, so performance of this
//! straightforward implementation is irrelevant (ring builds only).

/// Hash functions that can digest a message in one shot.
pub trait Digest {
    /// Digest output type.
    type Output;
    /// Hash `data` in one call.
    fn digest(data: &[u8]) -> Self::Output;
}

/// The SHA-1 hash function.
pub struct Sha1;

impl Digest for Sha1 {
    type Output = [u8; 20];

    fn digest(data: &[u8]) -> [u8; 20] {
        sha1(data)
    }
}

fn sha1(data: &[u8]) -> [u8; 20] {
    let mut h: [u32; 5] = [0x6745_2301, 0xEFCD_AB89, 0x98BA_DCFE, 0x1032_5476, 0xC3D2_E1F0];

    // pad: 0x80, zeros to 56 mod 64, then the bit length big-endian
    let bit_len = (data.len() as u64).wrapping_mul(8);
    let mut msg = data.to_vec();
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bit_len.to_be_bytes());

    let mut w = [0u32; 80];
    for chunk in msg.chunks_exact(64) {
        for (i, word) in chunk.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([word[0], word[1], word[2], word[3]]);
        }
        for i in 16..80 {
            w[i] = (w[i - 3] ^ w[i - 8] ^ w[i - 14] ^ w[i - 16]).rotate_left(1);
        }
        let (mut a, mut b, mut c, mut d, mut e) = (h[0], h[1], h[2], h[3], h[4]);
        for (i, &wi) in w.iter().enumerate() {
            let (f, k) = match i {
                0..=19 => ((b & c) | ((!b) & d), 0x5A82_7999u32),
                20..=39 => (b ^ c ^ d, 0x6ED9_EBA1),
                40..=59 => ((b & c) | (b & d) | (c & d), 0x8F1B_BCDC),
                _ => (b ^ c ^ d, 0xCA62_C1D6),
            };
            let temp = a
                .rotate_left(5)
                .wrapping_add(f)
                .wrapping_add(e)
                .wrapping_add(k)
                .wrapping_add(wi);
            e = d;
            d = c;
            c = b.rotate_left(30);
            b = a;
            a = temp;
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
    }

    let mut out = [0u8; 20];
    for (i, word) in h.iter().enumerate() {
        out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn rfc3174_vectors() {
        assert_eq!(
            hex(&Sha1::digest(b"")),
            "da39a3ee5e6b4b0d3255bfef95601890afd80709"
        );
        assert_eq!(
            hex(&Sha1::digest(b"abc")),
            "a9993e364706816aba3e25717850c26c9cd0d89d"
        );
        assert_eq!(
            hex(&Sha1::digest(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")),
            "84983e441c3bd26ebaae4aa1f95129e5e54670f1"
        );
    }

    #[test]
    fn long_input_crosses_block_boundaries() {
        // 1000 'a's — reference value from any SHA-1 implementation
        let data = vec![b'a'; 1000];
        assert_eq!(
            hex(&Sha1::digest(&data)),
            "291e9a6c66994949b57ba5e650361e98fc36b1ba"
        );
    }
}
