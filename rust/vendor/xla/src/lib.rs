//! Offline stub of the `xla` (xla-rs / PJRT) bindings.
//!
//! The container building this repo has no XLA/PJRT shared libraries,
//! so this crate provides the exact API surface `fish::runtime` uses and
//! reports "unavailable" at runtime: [`PjRtClient::cpu`] returns an
//! error, which every caller already handles (the CLI prints a note,
//! benches and tests skip the `xla-cms` backend gracefully).
//!
//! Swapping this path dependency for the real bindings re-enables the
//! AOT Pallas `epoch_stats` path with no source changes.

use std::fmt;

/// Stub error: everything fails with an "unavailable" message.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT is not compiled into this build (offline stub; \
         link the real xla crate to enable the Pallas backend)"
    )))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    /// CPU PJRT client — unavailable in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Platform name (unreachable at runtime: no client can exist).
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation — unavailable in the stub.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with literal arguments — unavailable in the stub.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal — unavailable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host literal (stub: shapeless placeholder).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    /// Reshape — unavailable in the stub.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    /// Destructure a tuple literal — unavailable in the stub.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    /// Copy out as a typed vector — unavailable in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text — unavailable in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("offline stub"));
    }

    #[test]
    fn literal_ops_fail_cleanly() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_tuple().is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
