//! Shard snapshot codec: the persistent half of exactly-once recovery.
//!
//! A merge shard periodically serializes everything a restarted
//! replacement needs to converge byte-identically (docs/RECOVERY.md):
//! the per-worker expected flush sequence numbers (its
//! [`crate::aggregate::FlushSequencer`] state), per-worker watermark
//! high-water marks, the full windowed-merge state
//! ([`crate::aggregate::MergeSnapshot`]: open panes, retired panes,
//! both stat ledgers), the shard-level gather sketch (tracked entries
//! plus inherited error — [`crate::aggregate::TopKSketch::from_parts`]
//! rebuilds it exactly), any flush batches parked ahead of a sequence
//! gap, the flush-latency histogram, and the recovery ledger itself.
//!
//! The byte format follows the wire codec's conventions — little
//! endian, u32 counts up front, allocation guarded by
//! remaining-byte lower bounds, and **every strict prefix of a valid
//! encoding fails with [`WireError::Truncated`]** (property-tested at
//! every byte offset, like the wire frames). Parked flush batches are
//! embedded as full `Flush` wire frames, so the snapshot and wire
//! codecs cannot drift apart on the one payload they share.
//!
//! [`ShardSnapshot::persist`] is crash-safe against SIGKILL: bytes go
//! to a sibling temp file, `sync_all`, then an atomic rename — a
//! reader sees either the previous complete snapshot or the new one,
//! never a torn write.

use crate::aggregate::{MergeSnapshot, PaneState};
use crate::metrics::{AggStats, Histogram, RecoveryStats, WindowStats};
use crate::Key;
use crate::transport::wire::{
    self, decode_frame, encode_flush, FlushMsg, Frame, Reader, WireError,
};
use std::fs;
use std::io;
use std::path::Path;

/// 4-byte snapshot magic ("FSHS": FISH Snapshot).
pub const SNAP_MAGIC: [u8; 4] = *b"FSHS";
/// Current snapshot-format version.
pub const SNAP_VERSION: u8 = 1;

/// The snapshot cadence rule: a shard that has accepted
/// `accepted_since` flush batches since its last snapshot is due for
/// the next one when the count reaches `every`; `every == 0` disables
/// snapshotting entirely.
///
/// A one-line rule, but it is the *persistence trigger* of the
/// exactly-once protocol, so it is shared verbatim by the rt shard
/// loop, the simulator, and the recovery model checker
/// ([`crate::analysis::recovery`]) — the model explores exactly the
/// cadence the engines run.
#[inline]
pub fn snapshot_due(accepted_since: u64, every: u64) -> bool {
    every > 0 && accepted_since >= every
}

/// Everything one merge shard persists per snapshot.
#[derive(Debug, Clone)]
pub struct ShardSnapshot {
    /// Shard index (sanity-checked by the loader's caller).
    pub shard: u64,
    /// Per-worker next expected flush seq — the `Resume` answers a
    /// restarted shard gives, and the dedup threshold for replays.
    pub expected_seq: Vec<u64>,
    /// Per-worker event-time watermark high-water marks (the shard
    /// watermark is their minimum over started workers).
    pub worker_wm: Vec<u64>,
    /// The windowed-merge state (open + retired panes, ledgers).
    pub merge: MergeSnapshot,
    /// Tracked entries of the shard-level gather sketch, ascending by
    /// key. Pane sketches inside `merge` cover per-window top-k; this
    /// one is the all-time sketch the gather stage folds, and it is
    /// *not* reconstructible from replay — batches below the expected
    /// seq are never re-sent.
    pub sketch_entries: Vec<(Key, f64)>,
    /// The gather sketch's inherited merge error.
    pub sketch_error: f64,
    /// Flush batches parked ahead of a sequence gap at snapshot time.
    pub buffered: Vec<FlushMsg>,
    /// Flush→merge transit latency histogram.
    pub latency: Histogram,
    /// The shard's recovery ledger (cumulative across restarts).
    pub recovery: RecoveryStats,
}

fn put_agg_stats(buf: &mut Vec<u8>, s: &AggStats) {
    wire::put_u64(buf, s.flushes);
    wire::put_u64(buf, s.messages);
    wire::put_u64(buf, s.bytes);
    wire::put_u64(buf, s.merge_ns);
    wire::put_u64(buf, s.max_merge_ns);
}

fn get_agg_stats(r: &mut Reader<'_>) -> Result<AggStats, WireError> {
    Ok(AggStats {
        flushes: r.u64()?,
        messages: r.u64()?,
        bytes: r.u64()?,
        merge_ns: r.u64()?,
        max_merge_ns: r.u64()?,
    })
}

fn put_window_stats(buf: &mut Vec<u8>, s: &WindowStats) {
    wire::put_u64(buf, s.panes_opened);
    wire::put_u64(buf, s.panes_retired);
    wire::put_u64(buf, s.late_reopens);
    wire::put_u64(buf, s.late_reopen_mass);
    wire::put_u64(buf, s.max_open_panes);
    wire::put_u64(buf, s.max_open_entries);
}

fn get_window_stats(r: &mut Reader<'_>) -> Result<WindowStats, WireError> {
    Ok(WindowStats {
        panes_opened: r.u64()?,
        panes_retired: r.u64()?,
        late_reopens: r.u64()?,
        late_reopen_mass: r.u64()?,
        max_open_panes: r.u64()?,
        max_open_entries: r.u64()?,
    })
}

fn put_pane(buf: &mut Vec<u8>, p: &PaneState) {
    wire::put_u64(buf, p.window);
    wire::put_u32(buf, p.counts.len() as u32);
    for &(k, c) in &p.counts {
        wire::put_u64(buf, k);
        wire::put_u64(buf, c);
    }
    put_agg_stats(buf, &p.stats);
    wire::put_u32(buf, p.sketch_entries.len() as u32);
    for &(k, w) in &p.sketch_entries {
        wire::put_u64(buf, k);
        wire::put_f64(buf, w);
    }
    wire::put_f64(buf, p.sketch_error);
}

fn get_pane(r: &mut Reader<'_>) -> Result<PaneState, WireError> {
    let window = r.u64()?;
    let n_counts = r.u32()? as usize;
    if r.remaining() < n_counts.saturating_mul(16) {
        return Err(WireError::Truncated);
    }
    let mut counts = Vec::with_capacity(n_counts);
    for _ in 0..n_counts {
        counts.push((r.u64()?, r.u64()?));
    }
    let stats = get_agg_stats(r)?;
    let n_sketch = r.u32()? as usize;
    if r.remaining() < n_sketch.saturating_mul(16) {
        return Err(WireError::Truncated);
    }
    let mut sketch_entries = Vec::with_capacity(n_sketch);
    for _ in 0..n_sketch {
        sketch_entries.push((r.u64()?, r.f64()?));
    }
    let sketch_error = r.f64()?;
    Ok(PaneState { window, counts, stats, sketch_entries, sketch_error })
}

fn get_panes(r: &mut Reader<'_>) -> Result<Vec<PaneState>, WireError> {
    let n = r.u32()? as usize;
    // 44 bytes (window + two counts + stats) is the tightest per-pane
    // lower bound — enough to reject absurd counts before allocating
    if r.remaining() < n.saturating_mul(44) {
        return Err(WireError::Truncated);
    }
    let mut panes = Vec::with_capacity(n);
    for _ in 0..n {
        panes.push(get_pane(r)?);
    }
    Ok(panes)
}

fn get_u64s(r: &mut Reader<'_>, n: usize) -> Result<Vec<u64>, WireError> {
    if r.remaining() < n.saturating_mul(8) {
        return Err(WireError::Truncated);
    }
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        v.push(r.u64()?);
    }
    Ok(v)
}

impl ShardSnapshot {
    /// Serialize to the snapshot byte format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(&SNAP_MAGIC);
        buf.push(SNAP_VERSION);
        wire::put_u64(&mut buf, self.shard);
        wire::put_u32(&mut buf, self.expected_seq.len() as u32);
        for &s in &self.expected_seq {
            wire::put_u64(&mut buf, s);
        }
        for &w in &self.worker_wm {
            wire::put_u64(&mut buf, w);
        }
        wire::put_u64(&mut buf, self.merge.watermark);
        wire::put_u32(&mut buf, self.merge.open.len() as u32);
        for p in &self.merge.open {
            put_pane(&mut buf, p);
        }
        wire::put_u32(&mut buf, self.merge.retired.len() as u32);
        for p in &self.merge.retired {
            put_pane(&mut buf, p);
        }
        put_agg_stats(&mut buf, &self.merge.retired_stats);
        put_window_stats(&mut buf, &self.merge.window_stats);
        wire::put_u32(&mut buf, self.sketch_entries.len() as u32);
        for &(k, w) in &self.sketch_entries {
            wire::put_u64(&mut buf, k);
            wire::put_f64(&mut buf, w);
        }
        wire::put_f64(&mut buf, self.sketch_error);
        wire::put_u32(&mut buf, self.buffered.len() as u32);
        for msg in &self.buffered {
            let mut frame = Vec::new();
            encode_flush(msg, &mut frame);
            wire::put_u32(&mut buf, frame.len() as u32);
            buf.extend_from_slice(&frame);
        }
        let mut hist = Vec::new();
        self.latency.to_bytes(&mut hist);
        wire::put_u32(&mut buf, hist.len() as u32);
        buf.extend_from_slice(&hist);
        let rec = &self.recovery;
        for v in [
            rec.replayed_batches,
            rec.deduped_batches,
            rec.buffered_batches,
            rec.replayed_tuples,
            rec.snapshots,
            rec.snapshot_bytes,
            rec.restores,
            rec.worker_restarts,
            rec.shard_restarts,
            rec.recovery_wall_ns,
        ] {
            wire::put_u64(&mut buf, v);
        }
        buf
    }

    /// Decode a snapshot; every strict prefix of a valid encoding is
    /// [`WireError::Truncated`], trailing bytes are rejected.
    pub fn from_bytes(bytes: &[u8]) -> Result<ShardSnapshot, WireError> {
        let mut r = Reader::new(bytes);
        if r.take(4)? != SNAP_MAGIC {
            return Err(WireError::BadMagic);
        }
        let version = r.u8()?;
        if version != SNAP_VERSION {
            return Err(WireError::VersionMismatch { got: version, want: SNAP_VERSION });
        }
        let shard = r.u64()?;
        let n_workers = r.u32()? as usize;
        let expected_seq = get_u64s(&mut r, n_workers)?;
        let worker_wm = get_u64s(&mut r, n_workers)?;
        let watermark = r.u64()?;
        let open = get_panes(&mut r)?;
        let retired = get_panes(&mut r)?;
        let retired_stats = get_agg_stats(&mut r)?;
        let window_stats = get_window_stats(&mut r)?;
        let n_sketch = r.u32()? as usize;
        if r.remaining() < n_sketch.saturating_mul(16) {
            return Err(WireError::Truncated);
        }
        let mut sketch_entries = Vec::with_capacity(n_sketch);
        for _ in 0..n_sketch {
            sketch_entries.push((r.u64()?, r.f64()?));
        }
        let sketch_error = r.f64()?;
        let n_buffered = r.u32()? as usize;
        if r.remaining() < n_buffered.saturating_mul(4 + wire::HEADER_LEN) {
            return Err(WireError::Truncated);
        }
        let mut buffered = Vec::with_capacity(n_buffered);
        for _ in 0..n_buffered {
            let len = r.u32()? as usize;
            let frame_bytes = r.take(len)?;
            match decode_frame(frame_bytes)? {
                (Frame::Flush(msg), used) if used == len => buffered.push(msg),
                _ => {
                    return Err(WireError::Io(io::Error::new(
                        io::ErrorKind::InvalidData,
                        "non-flush frame parked in snapshot",
                    )))
                }
            }
        }
        let hist_len = r.u32()? as usize;
        let latency =
            Histogram::from_bytes(r.take(hist_len)?).ok_or(WireError::Truncated)?;
        let recovery = RecoveryStats {
            replayed_batches: r.u64()?,
            deduped_batches: r.u64()?,
            buffered_batches: r.u64()?,
            replayed_tuples: r.u64()?,
            snapshots: r.u64()?,
            snapshot_bytes: r.u64()?,
            restores: r.u64()?,
            worker_restarts: r.u64()?,
            shard_restarts: r.u64()?,
            recovery_wall_ns: r.u64()?,
        };
        if r.remaining() != 0 {
            return Err(WireError::Io(io::Error::new(
                io::ErrorKind::InvalidData,
                "trailing bytes after snapshot",
            )));
        }
        Ok(ShardSnapshot {
            shard,
            expected_seq,
            worker_wm,
            merge: MergeSnapshot { watermark, open, retired, retired_stats, window_stats },
            sketch_entries,
            sketch_error,
            buffered,
            latency,
            recovery,
        })
    }

    /// Persist atomically: write a sibling temp file, `sync_all`, then
    /// rename over `path`. Survives SIGKILL at any point — a reader
    /// sees the previous complete snapshot or this one, never a torn
    /// write. Returns the serialized size in bytes.
    pub fn persist(&self, path: &Path) -> io::Result<u64> {
        let bytes = self.to_bytes();
        let tmp = path.with_extension("tmp");
        {
            let mut f = fs::File::create(&tmp)?;
            io::Write::write_all(&mut f, &bytes)?;
            f.sync_all()?;
        }
        fs::rename(&tmp, path)?;
        Ok(bytes.len() as u64)
    }

    /// Load the snapshot at `path`; `Ok(None)` when no snapshot was
    /// ever persisted (a shard restarting before its first snapshot
    /// starts fresh and relies on full replay).
    pub fn load(path: &Path) -> io::Result<Option<ShardSnapshot>> {
        let bytes = match fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e),
        };
        ShardSnapshot::from_bytes(&bytes)
            .map(Some)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("bad snapshot: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregate::{Count, WindowedMerge};

    fn specimen() -> ShardSnapshot {
        let mut m = WindowedMerge::new(Count, 1_000, 8).with_lateness(250);
        m.absorb(0, vec![(1, 5), (9, 2)]);
        m.absorb(1, vec![(3, 1)]);
        m.advance(2_600); // retires pane 0 and 1
        m.absorb(2, vec![(1, 4), (7, 7)]);
        let mut latency = Histogram::new();
        for ns in [100u64, 5_000, 5_000, 90_000] {
            latency.record(ns);
        }
        ShardSnapshot {
            shard: 1,
            expected_seq: vec![3, 0, 7],
            worker_wm: vec![2_600, 0, 3_100],
            merge: m.snapshot(),
            sketch_entries: vec![(1, 9.0), (3, 1.0), (7, 7.0)],
            sketch_error: 0.25,
            buffered: vec![FlushMsg {
                worker: 2,
                seq: 8,
                emit_ns: 123,
                watermark: 3_200,
                panes: vec![(3, vec![(4, 1)])],
            }],
            latency,
            recovery: RecoveryStats {
                replayed_batches: 2,
                deduped_batches: 1,
                snapshots: 4,
                snapshot_bytes: 1_000,
                ..Default::default()
            },
        }
    }

    #[test]
    fn snapshot_round_trips_byte_identically() {
        let snap = specimen();
        let bytes = snap.to_bytes();
        let back = ShardSnapshot::from_bytes(&bytes).expect("decode");
        // re-encoding the decoded snapshot must reproduce the bytes —
        // stronger than field equality, and covers the ledgers, which
        // deliberately do not implement PartialEq
        assert_eq!(back.to_bytes(), bytes);
        assert_eq!(back.shard, snap.shard);
        assert_eq!(back.expected_seq, snap.expected_seq);
        assert_eq!(back.worker_wm, snap.worker_wm);
        assert_eq!(back.sketch_entries, snap.sketch_entries);
        assert_eq!(back.sketch_error, snap.sketch_error);
        assert_eq!(back.buffered, snap.buffered);
        assert_eq!(back.recovery, snap.recovery);
        assert_eq!(back.latency.count(), snap.latency.count());
        assert_eq!(back.merge.watermark, snap.merge.watermark);
        assert_eq!(back.merge.open.len(), 1);
        assert_eq!(back.merge.retired.len(), 2);
    }

    #[test]
    fn every_strict_prefix_is_truncated() {
        let bytes = specimen().to_bytes();
        for cut in 0..bytes.len() {
            assert!(
                matches!(ShardSnapshot::from_bytes(&bytes[..cut]), Err(WireError::Truncated)),
                "prefix of {cut}/{} bytes must be Truncated",
                bytes.len()
            );
        }
    }

    #[test]
    fn corrupt_magic_version_and_trailing_bytes_are_rejected() {
        let bytes = specimen().to_bytes();
        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        assert!(matches!(ShardSnapshot::from_bytes(&bad_magic), Err(WireError::BadMagic)));
        let mut bad_version = bytes.clone();
        bad_version[4] = SNAP_VERSION + 1;
        assert!(matches!(
            ShardSnapshot::from_bytes(&bad_version),
            Err(WireError::VersionMismatch { .. })
        ));
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(ShardSnapshot::from_bytes(&trailing).is_err());
    }

    #[test]
    fn persist_is_atomic_and_load_round_trips() {
        let snap = specimen();
        let path = std::env::temp_dir()
            .join(format!("fish-snap-test-{}.snap", std::process::id()));
        assert!(ShardSnapshot::load(&path).expect("missing file is Ok(None)").is_none());
        let bytes = snap.persist(&path).expect("persist");
        assert_eq!(bytes, snap.to_bytes().len() as u64);
        assert!(!path.with_extension("tmp").exists(), "temp file renamed away");
        let back = ShardSnapshot::load(&path).expect("load").expect("present");
        assert_eq!(back.to_bytes(), snap.to_bytes());
        // persist over an existing snapshot replaces it atomically
        let mut next = snap.clone();
        next.expected_seq[0] += 1;
        next.persist(&path).expect("re-persist");
        let newest = ShardSnapshot::load(&path).expect("load").expect("present");
        assert_eq!(newest.expected_seq[0], snap.expected_seq[0] + 1);
        let _ = std::fs::remove_file(&path);
    }
}
