//! Worker key-state management and churn-time migration (paper §5).
//!
//! Each worker holds per-key aggregation state (word-count partials).
//! When the worker set changes, state stranded on removed workers — and,
//! for non-consistent mappings, state whose owner moved — must be
//! migrated. [`StateStore`] tracks the cluster's state placement;
//! [`MigrationPlan`] computes and applies the minimal move set for a
//! mapping change, and its size is the §6.5 migration-cost metric.
//!
//! [`snapshot`] is the other durability axis: periodic merge-shard
//! snapshots (sequencer + panes + ledgers) that let a crashed shard
//! process rejoin the mesh and converge byte-identically
//! (docs/RECOVERY.md).

pub mod snapshot;

pub use snapshot::{snapshot_due, ShardSnapshot};

use crate::{Key, WorkerId};
use std::collections::HashMap;

/// Per-worker key state (aggregation partials).
#[derive(Debug, Clone, Default)]
pub struct StateStore {
    /// worker → key → partial aggregate.
    shards: HashMap<WorkerId, HashMap<Key, u64>>,
}

impl StateStore {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Account one tuple of `key` processed on `worker`.
    pub fn record(&mut self, key: Key, worker: WorkerId) {
        *self.shards.entry(worker).or_default().entry(key).or_insert(0) += 1;
    }

    /// Partial aggregate of `key` on `worker`.
    pub fn get(&self, key: Key, worker: WorkerId) -> u64 {
        self.shards
            .get(&worker)
            .and_then(|m| m.get(&key))
            .copied()
            .unwrap_or(0)
    }

    /// Total aggregate of `key` across all workers (the merged answer a
    /// top-k sink would read).
    pub fn total(&self, key: Key) -> u64 {
        self.shards.values().filter_map(|m| m.get(&key)).sum()
    }

    /// Total state entries across the cluster (the memory metric).
    pub fn entries(&self) -> usize {
        self.shards.values().map(|m| m.len()).sum()
    }

    /// Entries held by `worker`.
    pub fn entries_on(&self, worker: WorkerId) -> usize {
        self.shards.get(&worker).map(|m| m.len()).unwrap_or(0)
    }

    /// Workers currently holding state.
    pub fn workers(&self) -> impl Iterator<Item = WorkerId> + '_ {
        self.shards.keys().copied()
    }

    /// Grand total across all keys and workers (conservation checks).
    pub fn grand_total(&self) -> u64 {
        self.shards.values().flat_map(|m| m.values()).sum()
    }
}

/// One state move: `key`'s partial on `from` relocates to `to`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Move {
    /// The key whose state moves.
    pub key: Key,
    /// Source worker.
    pub from: WorkerId,
    /// Destination worker.
    pub to: WorkerId,
}

/// A computed migration: the moves required so every key's state lives
/// only on workers that can still receive that key's tuples.
#[derive(Debug, Clone, Default)]
pub struct MigrationPlan {
    /// Moves in application order.
    pub moves: Vec<Move>,
}

impl MigrationPlan {
    /// Plan the migration after a membership change.
    ///
    /// `placement(key, from)` returns the worker that should now own the
    /// state `from` held for `key` — typically the consistent-hash
    /// successor for FISH, or `H(key) mod n` for modulo schemes. State
    /// already correctly placed yields no move.
    pub fn compute(
        store: &StateStore,
        dead: &[WorkerId],
        placement: impl Fn(Key, WorkerId) -> Option<WorkerId>,
    ) -> MigrationPlan {
        let mut moves = Vec::new();
        for &from in dead {
            if let Some(shard) = store.shards.get(&from) {
                for &key in shard.keys() {
                    if let Some(to) = placement(key, from) {
                        if to != from {
                            moves.push(Move { key, from, to });
                        }
                    }
                }
            }
        }
        MigrationPlan { moves }
    }

    /// Entries that must cross the network (the Fig. 17 cost).
    pub fn cost(&self) -> usize {
        self.moves.len()
    }

    /// Apply to the store: merge each moved partial into the target.
    pub fn apply(&self, store: &mut StateStore) {
        for m in &self.moves {
            let value = store
                .shards
                .get_mut(&m.from)
                .and_then(|s| s.remove(&m.key));
            if let Some(v) = value {
                *store
                    .shards
                    .entry(m.to)
                    .or_default()
                    .entry(m.key)
                    .or_insert(0) += v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashring::HashRing;

    fn store_with(pairs: &[(Key, WorkerId, u64)]) -> StateStore {
        let mut s = StateStore::new();
        for &(k, w, n) in pairs {
            for _ in 0..n {
                s.record(k, w);
            }
        }
        s
    }

    #[test]
    fn record_and_totals() {
        let s = store_with(&[(1, 0, 3), (1, 1, 2), (2, 0, 1)]);
        assert_eq!(s.get(1, 0), 3);
        assert_eq!(s.total(1), 5);
        assert_eq!(s.entries(), 3);
        assert_eq!(s.entries_on(0), 2);
        assert_eq!(s.grand_total(), 6);
    }

    #[test]
    fn plan_moves_only_dead_worker_state() {
        let s = store_with(&[(1, 0, 3), (2, 1, 4), (3, 1, 1)]);
        let plan = MigrationPlan::compute(&s, &[1], |_k, _| Some(2));
        assert_eq!(plan.cost(), 2);
        assert!(plan.moves.iter().all(|m| m.from == 1 && m.to == 2));
    }

    #[test]
    fn apply_conserves_aggregates() {
        let mut s = store_with(&[(1, 0, 3), (1, 1, 2), (2, 1, 7)]);
        let before_total_1 = s.total(1);
        let before_grand = s.grand_total();
        let plan = MigrationPlan::compute(&s, &[1], |_k, _| Some(0));
        plan.apply(&mut s);
        assert_eq!(s.total(1), before_total_1, "key-1 aggregate conserved");
        assert_eq!(s.grand_total(), before_grand);
        assert_eq!(s.entries_on(1), 0, "dead worker drained");
        assert_eq!(s.get(1, 0), 5, "partials merged");
    }

    #[test]
    fn consistent_hash_placement_yields_small_plans() {
        // CH successor placement should move exactly the dead worker's
        // entries and nothing else — while a mod-n replacement would
        // reshuffle everything (that cost shows up in Fig. 17).
        let workers: Vec<WorkerId> = (0..8).collect();
        let mut ring = HashRing::new(&workers, 64);
        let mut s = StateStore::new();
        let mut rng = crate::util::Rng::new(4);
        for _ in 0..5_000 {
            let k = rng.gen_range(500);
            let w = ring.owner(k).unwrap();
            s.record(k, w);
        }
        let victim = 3;
        ring.remove_worker(victim);
        let moved = MigrationPlan::compute(&s, &[victim], |k, _| ring.owner(k));
        assert_eq!(moved.cost(), s.entries_on(victim));
        let mut s2 = s.clone();
        moved.apply(&mut s2);
        assert_eq!(s2.entries_on(victim), 0);
        assert_eq!(s2.grand_total(), s.grand_total());
        // every migrated key landed on its CH successor
        for m in &moved.moves {
            assert_eq!(Some(m.to), ring.owner(m.key));
        }
    }

    #[test]
    fn empty_plan_for_healthy_cluster() {
        let s = store_with(&[(1, 0, 1)]);
        let plan = MigrationPlan::compute(&s, &[], |_, w| Some(w));
        assert_eq!(plan.cost(), 0);
    }
}
