//! Shuffle Grouping (SG): round-robin tuple distribution.
//!
//! The latency-optimal baseline — perfectly even load, but every worker
//! ends up holding state for (almost) every key, so memory overhead grows
//! linearly with the worker count (paper Fig. 3).

use super::{ClusterView, Grouper, SchemeKind};
use crate::{Key, WorkerId};

/// Round-robin grouper. Each source starts at a different offset so
/// multiple sources don't synchronise their bursts onto the same worker.
#[derive(Debug, Clone)]
pub struct ShuffleGrouping {
    next: usize,
}

impl ShuffleGrouping {
    /// `source` staggers the starting offset.
    pub fn new(source: usize) -> Self {
        ShuffleGrouping { next: source }
    }
}

impl Grouper for ShuffleGrouping {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Shuffle
    }

    #[inline]
    fn route(&mut self, _key: Key, view: &ClusterView<'_>) -> WorkerId {
        let w = view.workers[self.next % view.workers.len()];
        self.next = (self.next + 1) % view.workers.len();
        w
    }

    fn route_batch(&mut self, keys: &[Key], out: &mut [WorkerId], view: &ClusterView<'_>) {
        debug_assert_eq!(keys.len(), out.len());
        // hoisted: worker-count load (the scheme is key-oblivious)
        let n = view.workers.len();
        let mut next = self.next;
        for slot in out.iter_mut() {
            *slot = view.workers[next % n];
            next = (next + 1) % n;
        }
        self.next = next;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view<'a>(workers: &'a [usize], times: &'a [f64]) -> ClusterView<'a> {
        ClusterView { now: 0, workers, per_tuple_time: times, n_slots: times.len() }
    }

    #[test]
    fn perfectly_even() {
        let workers: Vec<usize> = (0..8).collect();
        let times = vec![1.0; 8];
        let v = view(&workers, &times);
        let mut g = ShuffleGrouping::new(0);
        let mut counts = [0usize; 8];
        for k in 0..8_000u64 {
            counts[g.route(k, &v)] += 1;
        }
        assert!(counts.iter().all(|&c| c == 1000));
    }

    #[test]
    fn batch_matches_sequential() {
        let workers: Vec<usize> = (0..5).collect();
        let times = vec![1.0; 5];
        let v = view(&workers, &times);
        let mut a = ShuffleGrouping::new(3);
        let mut b = ShuffleGrouping::new(3);
        let keys: Vec<u64> = (0..1_000).collect();
        let seq: Vec<usize> = keys.iter().map(|&k| a.route(k, &v)).collect();
        let mut got = vec![0usize; keys.len()];
        b.route_batch(&keys, &mut got, &v);
        assert_eq!(got, seq);
    }

    #[test]
    fn survives_membership_change() {
        let mut g = ShuffleGrouping::new(5);
        let workers: Vec<usize> = (0..4).collect();
        let times = vec![1.0; 4];
        let v = view(&workers, &times);
        for k in 0..100 {
            assert!(g.route(k, &v) < 4);
        }
        let fewer = [0usize, 2];
        let v2 = view(&fewer, &times);
        for k in 0..100 {
            let w = g.route(k, &v2);
            assert!(w == 0 || w == 2);
        }
    }
}
