//! W-Choices (W-C) — Nasir et al., ICDE 2016 [15].
//!
//! Like D-Choices but head keys may go to *any* worker (d = |workers|).
//! Best-in-class load balance among the lifetime schemes, at the price of
//! replicating every detected-hot key's state on the entire cluster —
//! the memory-scalability failure mode the FISH paper measures in Fig. 3.

use super::dchoices::{DChoices, HeavyHitters};
use super::{ClusterView, Grouper, SchemeKind};
use crate::{Key, WorkerId};

/// W-Choices grouper.
#[derive(Debug, Clone)]
pub struct WChoices {
    hh: HeavyHitters,
    sent: Vec<u64>,
    seed: u64,
}

impl WChoices {
    /// See [`DChoices::new`] for the parameters.
    pub fn new(n_slots: usize, key_capacity: usize, theta: f64, seed: u64) -> Self {
        WChoices {
            hh: HeavyHitters::new(key_capacity, theta),
            sent: vec![0; n_slots],
            seed,
        }
    }

    /// The per-tuple decision, shared by `route` and `route_batch`
    /// (callers must have sized `self.sent` first).
    #[inline]
    fn route_one(&mut self, key: Key, workers: &[WorkerId]) -> WorkerId {
        let hot = self.hh.observe_is_hot(key);
        let w = if hot {
            // entire worker set: least locally-loaded
            *workers
                .iter()
                .min_by_key(|&&w| self.sent[w])
                .expect("non-empty worker set")
        } else {
            DChoices::pick_least_sent(&self.sent, key, self.seed, workers, 2)
        };
        self.sent[w] += 1;
        w
    }
}

impl Grouper for WChoices {
    fn kind(&self) -> SchemeKind {
        SchemeKind::WChoices
    }

    #[inline]
    fn route(&mut self, key: Key, view: &ClusterView<'_>) -> WorkerId {
        if self.sent.len() < view.n_slots {
            self.sent.resize(view.n_slots, 0);
        }
        self.route_one(key, view.workers)
    }

    fn route_batch(&mut self, keys: &[Key], out: &mut [WorkerId], view: &ClusterView<'_>) {
        debug_assert_eq!(keys.len(), out.len());
        // hoisted: counter sizing; hot-key min-scan stays per-tuple
        // (it reads the counters the loop itself mutates)
        if self.sent.len() < view.n_slots {
            self.sent.resize(view.n_slots, 0);
        }
        for (key, slot) in keys.iter().zip(out.iter_mut()) {
            *slot = self.route_one(*key, view.workers);
        }
    }

    fn on_membership_change(&mut self, view: &ClusterView<'_>) {
        if self.sent.len() < view.n_slots {
            self.sent.resize(view.n_slots, 0);
        }
    }

    fn tracked_entries(&self) -> usize {
        self.hh.sketch.entries()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view<'a>(workers: &'a [usize], times: &'a [f64]) -> ClusterView<'a> {
        ClusterView { now: 0, workers, per_tuple_time: times, n_slots: times.len() }
    }

    #[test]
    fn hot_key_spreads_to_all_workers() {
        let workers: Vec<usize> = (0..16).collect();
        let times = vec![1.0; 16];
        let v = view(&workers, &times);
        let mut g = WChoices::new(16, 100, 2.0 / 16.0, 1);
        let mut seen = std::collections::HashSet::new();
        let mut rng = crate::util::Rng::new(2);
        for _ in 0..30_000 {
            let k = if rng.gen_bool(0.6) { 0 } else { 1 + rng.gen_range(5_000) };
            let w = g.route(k, &v);
            if k == 0 {
                seen.insert(w);
            }
        }
        assert_eq!(seen.len(), 16, "hot key should reach all workers");
    }

    #[test]
    fn batch_matches_sequential() {
        let workers: Vec<usize> = (0..8).collect();
        let times = vec![1.0; 8];
        let v = view(&workers, &times);
        let mut a = WChoices::new(8, 64, 0.05, 9);
        let mut b = WChoices::new(8, 64, 0.05, 9);
        let mut rng = crate::util::Rng::new(12);
        let keys: Vec<u64> = (0..5_000)
            .map(|_| if rng.gen_bool(0.5) { 42 } else { rng.gen_range(1_000) })
            .collect();
        let seq: Vec<usize> = keys.iter().map(|&k| a.route(k, &v)).collect();
        let mut got = vec![0usize; keys.len()];
        b.route_batch(&keys, &mut got, &v);
        assert_eq!(got, seq);
    }

    #[test]
    fn hot_load_is_balanced() {
        let workers: Vec<usize> = (0..8).collect();
        let times = vec![1.0; 8];
        let v = view(&workers, &times);
        let mut g = WChoices::new(8, 10, 0.05, 3);
        let mut counts = [0u64; 8];
        for _ in 0..40_000 {
            counts[g.route(42, &v)] += 1; // single ultra-hot key
        }
        let imb = crate::metrics::Imbalance::of_counts(&counts);
        assert!(imb.relative < 0.02, "imbalance {}", imb.relative);
    }
}
