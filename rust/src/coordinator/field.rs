//! Field Grouping (FG): key-hash routing.
//!
//! One worker per key — memory-optimal (no replication) but badly
//! imbalanced on skewed streams (paper Fig. 2): a single hot key pins its
//! whole load on one worker.

use super::{ClusterView, Grouper, SchemeKind};
use crate::util::hash::hash_to;
use crate::{Key, WorkerId};

/// Hash-family seed for the FG key hash.
const FG_SEED: u64 = 0xF1E1D;

/// Hash-by-key grouper: `worker = H(key) mod |workers|`.
#[derive(Debug, Clone, Default)]
pub struct FieldGrouping;

impl FieldGrouping {
    /// Stateless; nothing to configure.
    pub fn new() -> Self {
        FieldGrouping
    }
}

impl Grouper for FieldGrouping {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Field
    }

    #[inline]
    fn route(&mut self, key: Key, view: &ClusterView<'_>) -> WorkerId {
        view.workers[hash_to(key, FG_SEED, view.workers.len())]
    }

    fn route_batch(&mut self, keys: &[Key], out: &mut [WorkerId], view: &ClusterView<'_>) {
        debug_assert_eq!(keys.len(), out.len());
        // hoisted: worker-count load (stateless pure hash per key)
        let n = view.workers.len();
        for (key, slot) in keys.iter().zip(out.iter_mut()) {
            *slot = view.workers[hash_to(*key, FG_SEED, n)];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_key_same_worker() {
        let workers: Vec<usize> = (0..16).collect();
        let times = vec![1.0; 16];
        let v = ClusterView { now: 0, workers: &workers, per_tuple_time: &times, n_slots: 16 };
        let mut g = FieldGrouping::new();
        for k in 0..1000u64 {
            let w1 = g.route(k, &v);
            let w2 = g.route(k, &v);
            assert_eq!(w1, w2);
        }
    }

    #[test]
    fn batch_matches_sequential() {
        let workers: Vec<usize> = (0..16).collect();
        let times = vec![1.0; 16];
        let v = ClusterView { now: 0, workers: &workers, per_tuple_time: &times, n_slots: 16 };
        let mut g = FieldGrouping::new();
        let keys: Vec<u64> = (0..2_000).map(|i| i * 31).collect();
        let seq: Vec<usize> = keys.iter().map(|&k| g.route(k, &v)).collect();
        let mut got = vec![0usize; keys.len()];
        g.route_batch(&keys, &mut got, &v);
        assert_eq!(got, seq);
    }

    #[test]
    fn keys_spread_across_workers() {
        let workers: Vec<usize> = (0..16).collect();
        let times = vec![1.0; 16];
        let v = ClusterView { now: 0, workers: &workers, per_tuple_time: &times, n_slots: 16 };
        let mut g = FieldGrouping::new();
        let mut counts = [0usize; 16];
        for k in 0..16_000u64 {
            counts[g.route(k, &v)] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "count {c}");
        }
    }
}
