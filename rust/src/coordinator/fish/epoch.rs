//! Epoch-based recent hot-key identification — paper Algorithm 1.
//!
//! Intra-epoch: SpaceSaving counting over a bounded key set `K`
//! (`K_max` entries; ReplaceMin on overflow). Inter-epoch: once every
//! `N_epoch` tuples, every counter is multiplied by the decay factor `α`
//! — epoch-level (not tuple-level) time-aware decay, which is the
//! paper's computational-overhead win over classic time-aware counting.
//!
//! [`Identifier`] abstracts the backend so the XLA-accelerated
//! count-min variant ([`crate::runtime::XlaIdentifier`]) can slot into
//! [`super::Fish`] unchanged.

use crate::sketch::SpaceSaving;
use crate::Key;

/// Frequency-statistics backend consumed by FISH.
pub trait Identifier: Send {
    /// Count one occurrence (handles epoch boundaries internally).
    fn observe(&mut self, key: Key);
    /// Decayed frequency estimate of `key` (0 when untracked).
    fn estimate(&self, key: Key) -> f64;
    /// Highest tracked frequency (`f_top` in Alg. 2).
    fn f_top(&self) -> f64;
    /// Total decayed mass (denominator for relative frequencies).
    fn total(&self) -> f64;
    /// Internal tracked entries (control-plane memory metric).
    fn entries(&self) -> usize;
    /// Completed epochs so far (diagnostics / ablation).
    fn epochs(&self) -> u64;
}

/// The native Algorithm-1 identifier.
#[derive(Debug, Clone)]
pub struct EpochIdentifier {
    sketch: SpaceSaving,
    epoch_len: usize,
    alpha: f64,
    counter: usize,
    epochs: u64,
    /// decayed total mass: decays with the same α so relative
    /// frequencies stay calibrated.
    total: f64,
}

impl EpochIdentifier {
    /// `key_capacity` = `K_max`, `epoch_len` = `N_epoch`, `alpha` = `α`.
    pub fn new(key_capacity: usize, epoch_len: usize, alpha: f64) -> Self {
        assert!(epoch_len > 0, "epoch length must be positive");
        assert!((0.0..=1.0).contains(&alpha), "alpha must be in [0,1]");
        EpochIdentifier {
            sketch: SpaceSaving::new(key_capacity),
            epoch_len,
            alpha,
            counter: 0,
            epochs: 0,
            total: 0.0,
        }
    }

    /// A "no epoch" ablation variant (paper Fig. 14 `w/o epoch`):
    /// lifetime counting, never decayed — equivalent to α = 1 with an
    /// infinite epoch.
    pub fn lifetime(key_capacity: usize) -> Self {
        EpochIdentifier::new(key_capacity, usize::MAX, 1.0)
    }

    /// Configured decay factor.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Configured epoch length.
    pub fn epoch_len(&self) -> usize {
        self.epoch_len
    }
}

impl Identifier for EpochIdentifier {
    fn observe(&mut self, key: Key) {
        // Inter-epoch decaying (Alg. 1 lines 4–7)
        if self.counter == self.epoch_len {
            self.sketch.decay(self.alpha);
            self.total *= self.alpha;
            self.counter = 0;
            self.epochs += 1;
        }
        // Intra-epoch counting (Alg. 1 lines 8–17)
        self.sketch.observe(key);
        self.total += 1.0;
        self.counter += 1;
    }

    fn estimate(&self, key: Key) -> f64 {
        self.sketch.estimate(key)
    }

    fn f_top(&self) -> f64 {
        self.sketch.top_count() // O(1): maintained incrementally
    }

    fn total(&self) -> f64 {
        self.total
    }

    fn entries(&self) -> usize {
        self.sketch.entries()
    }

    fn epochs(&self) -> u64 {
        self.epochs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn decays_once_per_epoch() {
        let mut id = EpochIdentifier::new(16, 10, 0.5);
        for _ in 0..10 {
            id.observe(1);
        }
        assert_eq!(id.estimate(1), 10.0);
        assert_eq!(id.epochs(), 0);
        id.observe(1); // crosses the boundary: decay then count
        assert_eq!(id.epochs(), 1);
        assert_eq!(id.estimate(1), 6.0); // 10*0.5 + 1
        assert!((id.total() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn recent_hot_key_overtakes_stale_one() {
        // the defining behaviour for time-evolving streams: a formerly
        // hot key's mass decays away while the new hot key rises.
        let mut id = EpochIdentifier::new(64, 100, 0.2);
        for _ in 0..1_000 {
            id.observe(1); // old hot key
        }
        let old_peak = id.estimate(1);
        for _ in 0..500 {
            id.observe(2); // new hot key
        }
        assert!(id.estimate(2) > id.estimate(1));
        assert!(id.estimate(1) < old_peak * 0.01);
        assert_eq!(id.f_top(), id.estimate(2));
    }

    #[test]
    fn lifetime_variant_never_decays() {
        let mut id = EpochIdentifier::lifetime(16);
        for _ in 0..100_000 {
            id.observe(3);
        }
        assert_eq!(id.estimate(3), 100_000.0);
        assert_eq!(id.epochs(), 0);
    }

    #[test]
    fn relative_frequency_stays_calibrated() {
        // estimate/total of a steady 30% key should hover near 0.3
        // regardless of decay.
        let mut id = EpochIdentifier::new(128, 1_000, 0.2);
        let mut rng = Rng::new(8);
        for _ in 0..50_000 {
            let k = if rng.gen_bool(0.3) { 7 } else { 100 + rng.gen_range(50) };
            id.observe(k);
        }
        let rel = id.estimate(7) / id.total();
        assert!((rel - 0.3).abs() < 0.05, "relative {rel}");
    }

    #[test]
    fn alpha_zero_forgets_everything_each_epoch() {
        let mut id = EpochIdentifier::new(16, 10, 0.0);
        for _ in 0..10 {
            id.observe(1);
        }
        id.observe(2); // boundary: all history dropped
        assert_eq!(id.estimate(1), 0.0);
        assert_eq!(id.estimate(2), 1.0);
        assert!((id.total() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn entries_bounded() {
        let mut id = EpochIdentifier::new(32, 1000, 0.2);
        let mut rng = Rng::new(10);
        for _ in 0..100_000 {
            id.observe(rng.gen_range(1_000_000));
        }
        assert!(id.entries() <= 32);
    }
}
