//! Identifier baselines from the paper's §2.4 taxonomy, used by the
//! identifier-ablation bench to reproduce the §4.1 efficiency argument:
//!
//! * [`TupleDecayIdentifier`] — classic time-aware counting ([16]–[18]):
//!   per-**tuple** decay of every tracked counter. Accurate, but each
//!   update touches all `K_max` counters — the "large amount of
//!   computation" FISH's epoch-level decay removes (the paper claims
//!   three orders of magnitude fewer decay updates at `N_epoch = 1000`).
//! * [`WindowIdentifier`] — sliding-window counting ([19]–[23]): exact
//!   recent frequencies, but memory is linear in the window length.
//!
//! Both implement [`Identifier`], so they drop into FISH unchanged.

use super::epoch::Identifier;
use crate::sketch::{SlidingWindow, SpaceSaving};
use crate::Key;

/// Time-aware counting with per-tuple decay (the paper's computational
/// strawman). Counters live in a SpaceSaving set like Alg. 1, but the
/// decay multiplier applies on **every tuple** instead of every epoch.
#[derive(Debug, Clone)]
pub struct TupleDecayIdentifier {
    sketch: SpaceSaving,
    /// per-tuple decay factor, calibrated so that after `N_epoch` tuples
    /// the aggregate decay equals the epoch identifier's α:
    /// `alpha_tuple = α^(1/N_epoch)`.
    alpha_tuple: f64,
    total: f64,
    /// Decay multiplications performed (the §4.1 cost metric).
    pub decay_ops: u64,
}

impl TupleDecayIdentifier {
    /// Calibrated against an epoch identifier with (`alpha`, `epoch_len`).
    pub fn new(key_capacity: usize, alpha: f64, epoch_len: usize) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "per-tuple calibration needs alpha in (0,1]");
        TupleDecayIdentifier {
            sketch: SpaceSaving::new(key_capacity),
            alpha_tuple: alpha.powf(1.0 / epoch_len as f64),
            total: 0.0,
            decay_ops: 0,
        }
    }
}

impl Identifier for TupleDecayIdentifier {
    fn observe(&mut self, key: Key) {
        // tuple-level time-aware update: decay EVERY counter, then count.
        self.sketch.decay(self.alpha_tuple);
        self.decay_ops += self.sketch.len() as u64;
        self.total = self.total * self.alpha_tuple + 1.0;
        self.sketch.observe(key);
    }

    fn estimate(&self, key: Key) -> f64 {
        self.sketch.estimate(key)
    }

    fn f_top(&self) -> f64 {
        self.sketch.top_count()
    }

    fn total(&self) -> f64 {
        self.total
    }

    fn entries(&self) -> usize {
        self.sketch.entries()
    }

    fn epochs(&self) -> u64 {
        0 // no epochs: decay is continuous
    }
}

/// Sliding-window identification (exact recent counts, linear memory).
#[derive(Debug, Clone)]
pub struct WindowIdentifier {
    window: SlidingWindow,
}

impl WindowIdentifier {
    /// Window of `window` tuples (the paper's baselines need windows of
    /// epoch-scale length or larger for comparable recency).
    pub fn new(window: usize) -> Self {
        WindowIdentifier { window: SlidingWindow::new(window) }
    }
}

impl Identifier for WindowIdentifier {
    fn observe(&mut self, key: Key) {
        self.window.observe(key);
    }

    fn estimate(&self, key: Key) -> f64 {
        self.window.count(key) as f64
    }

    fn f_top(&self) -> f64 {
        self.window.top_count() as f64
    }

    fn total(&self) -> f64 {
        self.window.len() as f64
    }

    fn entries(&self) -> usize {
        self.window.entries()
    }

    fn epochs(&self) -> u64 {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fish::EpochIdentifier;

    #[test]
    fn tuple_decay_tracks_epoch_identifier() {
        // calibrated decays should agree on relative hotness
        let mut epoch = EpochIdentifier::new(64, 100, 0.2);
        let mut tuple = TupleDecayIdentifier::new(64, 0.2, 100);
        let mut rng = crate::util::Rng::new(1);
        for _ in 0..5_000 {
            let k = if rng.gen_bool(0.3) { 1 } else { 10 + rng.gen_range(500) };
            epoch.observe(k);
            tuple.observe(k);
        }
        let rel_e = epoch.estimate(1) / epoch.total();
        let rel_t = tuple.estimate(1) / tuple.total();
        assert!((rel_e - rel_t).abs() < 0.1, "epoch {rel_e} vs tuple {rel_t}");
    }

    #[test]
    fn tuple_decay_costs_orders_of_magnitude_more() {
        // the paper's §4.1 claim: epoch-level decay cuts decay updates by
        // ~N_epoch/1 (three orders of magnitude at N_epoch = 1000).
        let cap = 100;
        let n = 50_000;
        let mut tuple = TupleDecayIdentifier::new(cap, 0.2, 1_000);
        let mut rng = crate::util::Rng::new(2);
        for _ in 0..n {
            tuple.observe(rng.gen_range(10_000));
        }
        // epoch identifier: one decay pass (≤ cap multiplications) per epoch
        let epoch_ops = (n as u64 / 1_000) * cap as u64;
        assert!(
            tuple.decay_ops > epoch_ops * 500,
            "tuple {} vs epoch {} decay ops",
            tuple.decay_ops,
            epoch_ops
        );
    }

    #[test]
    fn window_is_exact_but_memory_hungry() {
        let mut wid = WindowIdentifier::new(10_000);
        let mut eid = EpochIdentifier::new(100, 1_000, 0.2);
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..20_000 {
            let k = rng.gen_range(5_000);
            wid.observe(k);
            eid.observe(k);
        }
        assert!(wid.entries() > eid.entries() * 20, "window {} vs epoch {}", wid.entries(), eid.entries());
    }

    #[test]
    fn both_work_inside_fish() {
        use crate::coordinator::{ClusterView, Grouper};
        for id in [
            Box::new(TupleDecayIdentifier::new(64, 0.2, 100)) as Box<dyn Identifier>,
            Box::new(WindowIdentifier::new(1_000)),
        ] {
            let workers: Vec<usize> = (0..8).collect();
            let mut fish =
                crate::coordinator::Fish::new(id, 0.25 / 8.0, 2, 1_000, 32, &workers);
            let times = vec![1.0; 8];
            for i in 0..2_000u64 {
                let view = ClusterView {
                    now: i,
                    workers: &workers,
                    per_tuple_time: &times,
                    n_slots: 8,
                };
                let w = fish.route(i % 50, &view);
                assert!(w < 8);
            }
        }
    }
}
