//! Classification of Hot Key (CHK) — paper Algorithm 2.
//!
//! Maps a key's recent frequency to a candidate-worker count:
//!
//! ```text
//! if f_k > θ·total:
//!     index = ⌊log2(f_top / f_k)⌋
//!     d     = W_num / 2^index          (halving ladder: hotter → wider)
//!     d     = max(d, d_min)
//!     M_k   = max(M_k, d)              (monotone per-key memo)
//!     return M_k
//! else:
//!     return 2                         (PKG-style for the cold tail)
//! ```
//!
//! The memo `M` prevents assignment thrashing when a hot key's frequency
//! oscillates: the candidate set only widens, never narrows, so worker
//! state built for that key stays useful (paper §4.1.2). `M` evicts
//! entries whose keys have stayed cold for `MEMO_TTL_EPOCHS`-worth of
//! classifications to keep control-plane memory bounded.

use crate::Key;
use std::collections::HashMap;

/// Classification strategy — [`ChkMode::Ladder`] is the paper's Alg. 2;
/// the other two are the Fig. 15 ablation baselines ("w/W-C", "w/D-C").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChkMode {
    /// Frequency-proportional halving ladder (the paper's CHK).
    Ladder,
    /// Every hot key gets the whole cluster (W-Choices-style).
    AllWorkers,
    /// Every hot key gets the same fixed `d` (D-Choices-style).
    FixedD(usize),
}

/// Hot-key classifier with the monotone assignment memo.
#[derive(Debug, Clone)]
pub struct Chk {
    theta: f64,
    d_min: usize,
    mode: ChkMode,
    /// M: key → (assigned d, last-hot stamp).
    memo: HashMap<Key, (usize, u64)>,
    /// Classification counter used as the memo staleness clock.
    clock: u64,
    /// Sweep period for expiring cold memo entries.
    sweep_every: u64,
}

/// Cold entries older than this many classifications are evicted.
const MEMO_TTL: u64 = 2_000_000;

impl Chk {
    /// `theta` = hot threshold (relative frequency), `d_min` = minimum
    /// worker count for a hot key.
    pub fn new(theta: f64, d_min: usize) -> Self {
        assert!(theta > 0.0, "theta must be positive");
        assert!(d_min >= 1);
        Chk {
            theta,
            d_min,
            mode: ChkMode::Ladder,
            memo: HashMap::new(),
            clock: 0,
            sweep_every: MEMO_TTL,
        }
    }

    /// Switch classification strategy (Fig. 15 ablation).
    pub fn with_mode(mut self, mode: ChkMode) -> Self {
        self.mode = mode;
        self
    }

    /// Configured threshold θ.
    pub fn theta(&self) -> f64 {
        self.theta
    }

    /// Classify: returns the candidate-worker count `d` for this tuple.
    ///
    /// `f_k` / `f_top` are the key's and the hottest key's decayed
    /// frequencies; `total` the decayed stream mass; `n_workers` = W_num.
    pub fn classify(
        &mut self,
        key: Key,
        f_k: f64,
        f_top: f64,
        total: f64,
        n_workers: usize,
    ) -> usize {
        self.clock += 1;
        if self.clock % self.sweep_every == 0 {
            let horizon = self.clock.saturating_sub(MEMO_TTL);
            self.memo.retain(|_, (_, stamp)| *stamp >= horizon);
        }
        if total <= 0.0 || f_k <= self.theta * total {
            return 2;
        }
        let d = match self.mode {
            ChkMode::Ladder => {
                // Alg. 2 lines 3–4: halving ladder from the hottest key.
                let ratio =
                    if f_k > 0.0 { (f_top / f_k).max(1.0) } else { f64::INFINITY };
                let index = ratio.log2().floor() as u32;
                (n_workers >> index.min(63)).max(self.d_min).min(n_workers.max(1))
            }
            ChkMode::AllWorkers => n_workers.max(1),
            ChkMode::FixedD(d) => d.clamp(2, n_workers.max(1)),
        };
        // Alg. 2 lines 7–10: monotone memo.
        let entry = self.memo.entry(key).or_insert((0, self.clock));
        entry.1 = self.clock;
        if entry.0 < d {
            entry.0 = d;
        }
        entry.0
    }

    /// Number of memoised hot keys (control-plane memory metric).
    pub fn memo_entries(&self) -> usize {
        self.memo.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_key_gets_two() {
        let mut chk = Chk::new(0.01, 2);
        assert_eq!(chk.classify(1, 5.0, 100.0, 10_000.0, 64), 2);
        assert_eq!(chk.memo_entries(), 0);
    }

    #[test]
    fn hottest_key_gets_all_workers() {
        let mut chk = Chk::new(0.01, 2);
        // f_k == f_top → index 0 → d = W
        assert_eq!(chk.classify(1, 500.0, 500.0, 1_000.0, 64), 64);
    }

    #[test]
    fn halving_ladder() {
        let mut chk = Chk::new(0.001, 2);
        let total = 10_000.0;
        let f_top = 1_000.0;
        // f_top/f_k = 2 → index 1 → 64/2 = 32
        assert_eq!(chk.classify(10, 500.0, f_top, total, 64), 32);
        // f_top/f_k = 4 → index 2 → 16
        assert_eq!(chk.classify(11, 250.0, f_top, total, 64), 16);
        // f_top/f_k = 8.x → index 3 → 8
        assert_eq!(chk.classify(12, 120.0, f_top, total, 64), 8);
    }

    #[test]
    fn d_min_floor_applies() {
        let mut chk = Chk::new(0.0001, 4);
        let d = chk.classify(9, 3.0, 3_000.0, 10_000.0, 64);
        assert!(d >= 4, "d={d}");
    }

    #[test]
    fn memo_is_monotone() {
        let mut chk = Chk::new(0.001, 2);
        let total = 10_000.0;
        let wide = chk.classify(5, 1_000.0, 1_000.0, total, 64);
        assert_eq!(wide, 64);
        // frequency collapses but stays hot: memo keeps d at 64
        let later = chk.classify(5, 20.0, 1_000.0, total, 64);
        assert_eq!(later, 64);
        // cold now: back to 2 (memo bypassed, not shrunk)
        let cold = chk.classify(5, 0.5, 1_000.0, total, 64);
        assert_eq!(cold, 2);
        // hot again: memo remembered 64
        assert_eq!(chk.classify(5, 15.0, 1_000.0, total, 64), 64);
    }

    #[test]
    fn memo_expires_stale_keys() {
        let mut chk = Chk::new(0.001, 2);
        chk.sweep_every = 10; // accelerate for the test
        chk.classify(5, 100.0, 100.0, 1_000.0, 8);
        assert_eq!(chk.memo_entries(), 1);
        for i in 0..(MEMO_TTL + 20) {
            chk.classify(1_000 + i, 0.1, 100.0, 1_000.0, 8); // cold churn
        }
        assert_eq!(chk.memo_entries(), 0, "stale memo entry not evicted");
    }

    #[test]
    fn empty_stream_is_safe() {
        let mut chk = Chk::new(0.01, 2);
        assert_eq!(chk.classify(1, 0.0, 0.0, 0.0, 8), 2);
    }
}
