//! Heuristic Worker Assignment (HWA) — paper Algorithm 3.
//!
//! The source *infers* each worker's backlog instead of querying it.
//! `C_w` tracks the estimated number of unprocessed tuples on worker `w`:
//! incremented on every assignment (Alg. 3 line 18), and re-estimated
//! every interval `T` by subtracting the work the worker completed
//! (Eq. 1 with the assignments already folded into `C_w`):
//!
//! ```text
//! C_w ← max(C_w − T / P_w, 0)        every T
//! T_w = C_w · P_w                    estimated waiting time (Eq. 2)
//! ```
//!
//! Selection picks the candidate minimising `T_w` — Observation 2 (a
//! worker's per-tuple time `P_w` is stable) is what makes the inference
//! sound without any communication.

use super::super::ClusterView;
use crate::WorkerId;

/// Backlog estimator + candidate selector.
#[derive(Debug, Clone)]
pub struct Hwa {
    /// Estimated unprocessed tuples per worker id.
    backlog: Vec<f64>,
    /// Assignments per worker since construction (diagnostics, `N_w`).
    assigned: Vec<u64>,
    /// Re-estimation interval `T`.
    interval: u64,
    /// Timestamp of the last re-estimation (`t_pri`).
    last_update: u64,
}

impl Hwa {
    /// `interval` — the paper's `T` (10 s on the cluster; scaled in ns /
    /// virtual ticks here).
    pub fn new(interval: u64) -> Self {
        assert!(interval > 0);
        Hwa { backlog: Vec::new(), assigned: Vec::new(), interval, last_update: 0 }
    }

    /// Grow per-worker arrays.
    pub fn ensure_slots(&mut self, n: usize) {
        if self.backlog.len() < n {
            self.backlog.resize(n, 0.0);
            self.assigned.resize(n, 0);
        }
    }

    /// Estimated waiting time `T_w` (Eq. 2).
    #[inline]
    pub fn waiting_time(&self, w: WorkerId, per_tuple_time: &[f64]) -> f64 {
        self.backlog.get(w).copied().unwrap_or(0.0) * per_tuple_time[w]
    }

    /// Estimated backlog `C_w`.
    pub fn backlog(&self, w: WorkerId) -> f64 {
        self.backlog.get(w).copied().unwrap_or(0.0)
    }

    /// Total assignments recorded for `w` (`N_w`).
    pub fn assigned(&self, w: WorkerId) -> u64 {
        self.assigned.get(w).copied().unwrap_or(0)
    }

    /// Re-estimate all backlogs (Alg. 3 lines 3–10) if `T` has elapsed.
    #[inline]
    fn maybe_update(&mut self, view: &ClusterView<'_>) {
        if view.now.saturating_sub(self.last_update) <= self.interval {
            return;
        }
        let elapsed = (view.now - self.last_update) as f64;
        for &w in view.workers {
            let p = view.per_tuple_time[w];
            if p <= 0.0 {
                self.backlog[w] = 0.0;
                continue;
            }
            // Eq. 1: outstanding work minus what the worker processed.
            let remaining = self.backlog[w] * p - elapsed;
            self.backlog[w] = if remaining > 0.0 { remaining / p } else { 0.0 };
        }
        self.last_update = view.now;
    }

    /// Alg. 3: pick the candidate with the smallest inferred waiting
    /// time, then account the new tuple on it.
    pub fn select(&mut self, candidates: &[WorkerId], view: &ClusterView<'_>) -> WorkerId {
        self.begin(view);
        self.select_prepared(candidates, view)
    }

    /// The per-view prologue of [`Hwa::select`] (slot sizing + interval
    /// re-estimation), hoisted so a batch loop pays it once. Calling it
    /// again under the same `view` is a no-op, which is what makes
    /// batched selection identical to sequential [`Hwa::select`] calls.
    pub fn begin(&mut self, view: &ClusterView<'_>) {
        self.ensure_slots(view.n_slots);
        self.maybe_update(view);
    }

    /// [`Hwa::select`] minus the prologue — callers must have run
    /// [`Hwa::begin`] with the same `view` first.
    #[inline]
    pub fn select_prepared(&mut self, candidates: &[WorkerId], view: &ClusterView<'_>) -> WorkerId {
        assert!(!candidates.is_empty(), "HWA needs at least one candidate");
        // primary key: inferred waiting time T_w = C_w · P_w; tie-break
        // on raw backlog C_w so the selector still balances when the
        // capacity samples are degenerate (e.g. P_w = 0 before the first
        // sampling round).
        let mut appro = candidates[0];
        let mut best = (self.waiting_time(appro, view.per_tuple_time), self.backlog[appro]);
        for &w in &candidates[1..] {
            let cand = (self.waiting_time(w, view.per_tuple_time), self.backlog[w]);
            if cand < best {
                best = cand;
                appro = w;
            }
        }
        self.backlog[appro] += 1.0; // line 18
        self.assigned[appro] += 1;
        appro
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view<'a>(workers: &'a [usize], times: &'a [f64], now: u64) -> ClusterView<'a> {
        ClusterView { now, workers, per_tuple_time: times, n_slots: times.len() }
    }

    #[test]
    fn prefers_faster_worker_under_equal_backlog() {
        // paper Fig. 7: W3/W4 twice as fast as W1/W2.
        let workers = [0usize, 1, 2, 3];
        let times = [10.0, 10.0, 5.0, 5.0]; // P_w
        let mut hwa = Hwa::new(100);
        let v = view(&workers, &times, 0);
        let mut counts = [0u64; 4];
        for _ in 0..1_000 {
            counts[hwa.select(&workers, &v)] += 1;
        }
        // fast workers should absorb ~2x the tuples of slow ones
        let fast = counts[2] + counts[3];
        let slow = counts[0] + counts[1];
        let ratio = fast as f64 / slow as f64;
        assert!((1.6..2.5).contains(&ratio), "fast/slow ratio {ratio}");
    }

    #[test]
    fn paper_fig7_worked_example() {
        // W1: 400 tuples @ P=1 → wait 50 at t=500 means backlog 50.
        // We reproduce the *selection*: backlogs 50,40,50·2? — from the
        // figure: waits are 50, 40, 100, 60 → W2 chosen.
        let workers = [0usize, 1, 2, 3];
        let times = [1.0, 1.0, 2.0, 2.0];
        let mut hwa = Hwa::new(1_000_000);
        hwa.ensure_slots(4);
        hwa.backlog = vec![50.0, 40.0, 50.0, 30.0]; // waits: 50 40 100 60
        let v = view(&workers, &times, 0);
        let w = hwa.select(&workers, &v);
        assert_eq!(w, 1, "Alg. 3 must select W2 (shortest waiting time)");
    }

    #[test]
    fn backlog_drains_over_interval() {
        let workers = [0usize];
        let times = [2.0];
        let mut hwa = Hwa::new(10);
        let v0 = view(&workers, &times, 0);
        for _ in 0..100 {
            hwa.select(&workers, &v0);
        }
        assert!((hwa.backlog(0) - 100.0).abs() < 1e-9);
        // 40 ticks later the worker processed 20 tuples (P=2)
        let v1 = view(&workers, &times, 40);
        hwa.select(&workers, &v1);
        assert!((hwa.backlog(0) - (100.0 - 20.0 + 1.0)).abs() < 1e-9);
        // far future: fully drained (clamped at 0) + the new tuple
        let v2 = view(&workers, &times, 1_000_000);
        hwa.select(&workers, &v2);
        assert!((hwa.backlog(0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn no_update_within_interval() {
        let workers = [0usize, 1];
        let times = [1.0, 1.0];
        let mut hwa = Hwa::new(1_000);
        let v = view(&workers, &times, 0);
        hwa.select(&workers, &v);
        let before = hwa.backlog(0) + hwa.backlog(1);
        let v2 = view(&workers, &times, 500); // < interval
        hwa.select(&workers, &v2);
        let after = hwa.backlog(0) + hwa.backlog(1);
        assert!((after - before - 1.0).abs() < 1e-9, "no drain expected");
    }

    #[test]
    fn prepared_selection_matches_select() {
        let workers = [0usize, 1, 2, 3];
        let times = [10.0, 10.0, 5.0, 5.0];
        let mut a = Hwa::new(100);
        let mut b = Hwa::new(100);
        for step in 0..500u64 {
            let v = view(&workers, &times, step * 3);
            let wa = a.select(&workers, &v);
            b.begin(&v);
            let wb = b.select_prepared(&workers, &v);
            assert_eq!(wa, wb, "step {step}");
        }
    }

    #[test]
    fn balances_homogeneous_candidates() {
        let workers: Vec<usize> = (0..4).collect();
        let times = vec![1.0; 4];
        let mut hwa = Hwa::new(u64::MAX >> 1);
        let v = view(&workers, &times, 0);
        let mut counts = [0u64; 4];
        for _ in 0..4_000 {
            counts[hwa.select(&workers, &v)] += 1;
        }
        for &c in &counts {
            assert_eq!(c, 1_000);
        }
    }
}
