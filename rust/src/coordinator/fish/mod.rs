//! FISH — the paper's grouping scheme (Sections 4 and 5).
//!
//! Pipeline per tuple:
//!
//! 1. [`epoch`] — epoch-based recent hot-key identification (Alg. 1):
//!    feed the key to the intra-epoch counter; at epoch boundaries apply
//!    inter-epoch hotness decay.
//! 2. [`chk`] — Classification of Hot Key (Alg. 2): map the key's recent
//!    frequency to a candidate-worker count `d` (2 for non-hot keys).
//! 3. candidate materialisation — the first `d` distinct workers
//!    clockwise on the consistent-hash ring (§5), so worker churn only
//!    perturbs adjacent candidate sets.
//! 4. [`assign`] — Heuristic Worker Assignment (Alg. 3): pick the
//!    candidate with the smallest inferred waiting time `C_w · P_w`,
//!    with per-interval backlog re-estimation (Eq. 1) instead of
//!    source↔worker communication.

pub mod assign;
pub mod baselines;
pub mod chk;
pub mod epoch;

pub use assign::Hwa;
pub use baselines::{TupleDecayIdentifier, WindowIdentifier};
pub use chk::{Chk, ChkMode};
pub use epoch::{EpochIdentifier, Identifier};

use super::{ClusterView, Grouper, SchemeKind};
use crate::config::Config;
use crate::hashring::HashRing;
use crate::{Key, WorkerId};

/// How FISH materialises a key's `d` candidate workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CandidateMode {
    /// Consistent-hash ring walk (paper §5) — churn-stable.
    ConsistentHash,
    /// Plain `HASH(key, i) mod n` family — the §5 strawman whose
    /// mappings all shift on membership change (Fig. 17 "w/o CH").
    ModuloHash,
}

/// The FISH grouper.
pub struct Fish {
    identifier: Box<dyn Identifier>,
    chk: Chk,
    hwa: Hwa,
    ring: HashRing,
    mode: CandidateMode,
    /// Fig. 16 ablation: assign by local sent-counts instead of HWA.
    count_based: bool,
    /// Local sent-count per worker (used by the ablation path).
    sent: Vec<u64>,
    /// Scratch candidate buffer (avoids per-tuple allocation).
    cand_buf: Vec<WorkerId>,
    /// Hot-key candidate cache: key → (d, candidates). Hot keys repeat
    /// on almost every tuple and their ring walk is O(d²); the cache
    /// collapses that to a lookup (§Perf). Cleared on membership change.
    cand_cache: std::collections::HashMap<Key, (usize, Vec<WorkerId>)>,
}

impl Fish {
    /// Build from an explicit identifier backend (native or XLA).
    pub fn new(
        identifier: Box<dyn Identifier>,
        theta: f64,
        d_min: usize,
        interval: u64,
        vnodes: usize,
        workers: &[WorkerId],
    ) -> Self {
        Fish {
            identifier,
            chk: Chk::new(theta, d_min),
            hwa: Hwa::new(interval),
            ring: HashRing::new(workers, vnodes),
            mode: CandidateMode::ConsistentHash,
            count_based: false,
            sent: Vec::new(),
            cand_buf: Vec::with_capacity(16),
            cand_cache: std::collections::HashMap::new(),
        }
    }

    /// Switch the candidate materialisation strategy (Fig. 17 ablation).
    pub fn with_mode(mut self, mode: CandidateMode) -> Self {
        self.mode = mode;
        self
    }

    /// Swap the classification strategy (Fig. 15 ablation: "w/W-C",
    /// "w/D-C" hot-key handling inside the FISH pipeline).
    pub fn with_chk_mode(mut self, mode: chk::ChkMode) -> Self {
        self.chk = Chk::new(self.chk.theta(), 2).with_mode(mode);
        self
    }

    /// Disable HWA (Fig. 16 ablation): candidates are picked by local
    /// assigned-tuple counts, the prior work's strategy.
    pub fn with_count_based_assignment(mut self) -> Self {
        self.count_based = true;
        self
    }

    /// Build with the native (pure-Rust Alg. 1) identifier from `cfg`.
    pub fn from_config(cfg: &Config, _source: usize) -> Self {
        let identifier: Box<dyn Identifier> =
            Box::new(EpochIdentifier::new(cfg.key_capacity, cfg.epoch, cfg.alpha));
        let workers: Vec<WorkerId> = (0..cfg.workers).collect();
        Fish::new(
            identifier,
            cfg.theta(),
            cfg.d_min,
            cfg.interval,
            cfg.vnodes,
            &workers,
        )
    }

    /// Access the identifier (ablation benches swap estimates out).
    pub fn identifier(&self) -> &dyn Identifier {
        self.identifier.as_ref()
    }

    /// Access the CHK memo table size (for memory reporting).
    pub fn memo_entries(&self) -> usize {
        self.chk.memo_entries()
    }

    /// Per-view prologue hoisted out of the batch loop: size the
    /// per-worker arrays and run HWA's interval re-estimation once.
    /// Idempotent under an unchanged `view`, so batched routing stays
    /// identical to sequential [`Grouper::route`] calls.
    fn prepare(&mut self, view: &ClusterView<'_>) {
        if self.count_based {
            if self.sent.len() < view.n_slots {
                self.sent.resize(view.n_slots, 0);
            }
        } else {
            self.hwa.begin(view);
        }
    }

    /// The per-tuple pipeline (Algs. 1–3) after [`Fish::prepare`].
    fn route_prepared(&mut self, key: Key, view: &ClusterView<'_>) -> WorkerId {
        // 1. recent hot-key identification (Alg. 1)
        self.identifier.observe(key);

        // 2. classification (Alg. 2)
        let f_k = self.identifier.estimate(key);
        let f_top = self.identifier.f_top();
        let total = self.identifier.total();
        let d = self.chk.classify(key, f_k, f_top, total, view.workers.len());

        // 3. candidates via consistent hashing (§5)
        self.cand_buf.clear();
        if d >= view.workers.len() {
            self.cand_buf.extend_from_slice(view.workers);
        } else {
            match self.mode {
                CandidateMode::ConsistentHash => {
                    if d > 2 {
                        // hot key: serve the walk from the cache
                        match self.cand_cache.get(&key) {
                            Some((cd, v)) if *cd == d => {
                                self.cand_buf.extend_from_slice(v);
                            }
                            _ => {
                                self.ring.candidates_into(key, d, &mut self.cand_buf);
                                if self.cand_cache.len() > 8_192 {
                                    self.cand_cache.clear(); // bound memory
                                }
                                self.cand_cache.insert(key, (d, self.cand_buf.clone()));
                            }
                        }
                    } else {
                        self.ring.candidates_into(key, d, &mut self.cand_buf);
                    }
                }
                CandidateMode::ModuloHash => {
                    // hash-family mod n: every mapping shifts when n does.
                    for i in 0..d as u64 {
                        let w = view.workers
                            [crate::util::hash::hash_to(key, 0xC0DE ^ i, view.workers.len())];
                        if !self.cand_buf.contains(&w) {
                            self.cand_buf.push(w);
                        }
                    }
                }
            }
        }

        // 4. heuristic worker assignment (Alg. 3) — or the count-based
        //    strategy of prior work under the Fig. 16 ablation.
        if self.count_based {
            let w = *self
                .cand_buf
                .iter()
                .min_by_key(|&&w| self.sent[w])
                .expect("non-empty candidates");
            self.sent[w] += 1;
            w
        } else {
            self.hwa.select_prepared(&self.cand_buf, view)
        }
    }
}

impl Grouper for Fish {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Fish
    }

    fn route(&mut self, key: Key, view: &ClusterView<'_>) -> WorkerId {
        self.prepare(view);
        self.route_prepared(key, view)
    }

    fn route_batch(&mut self, keys: &[Key], out: &mut [WorkerId], view: &ClusterView<'_>) {
        debug_assert_eq!(keys.len(), out.len());
        // hoisted: slot sizing + HWA interval re-estimation (Eq. 1) run
        // once per batch; identification, CHK and assignment stay
        // per-tuple because they track the stream.
        self.prepare(view);
        for (key, slot) in keys.iter().zip(out.iter_mut()) {
            *slot = self.route_prepared(*key, view);
        }
    }

    fn on_membership_change(&mut self, view: &ClusterView<'_>) {
        // reconcile the ring with the live worker set; consistent hashing
        // keeps unaffected candidate sets stable (paper Fig. 8).
        let current: Vec<WorkerId> = self.ring.workers().to_vec();
        for w in &current {
            if !view.workers.contains(w) {
                self.ring.remove_worker(*w);
            }
        }
        for w in view.workers {
            if !current.contains(w) {
                self.ring.add_worker(*w);
            }
        }
        self.cand_cache.clear(); // ring moved: cached walks are stale
        self.hwa.ensure_slots(view.n_slots);
    }

    fn tracked_entries(&self) -> usize {
        self.identifier.entries() + self.chk.memo_entries()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Imbalance;
    use crate::util::Rng;

    fn view<'a>(workers: &'a [usize], times: &'a [f64], now: u64) -> ClusterView<'a> {
        ClusterView { now, workers, per_tuple_time: times, n_slots: times.len() }
    }

    fn default_fish(workers: usize) -> Fish {
        let mut cfg = Config::default();
        cfg.workers = workers;
        Fish::from_config(&cfg, 0)
    }

    #[test]
    fn hot_key_fans_out_cold_key_stays_narrow() {
        let n = 32;
        let workers: Vec<usize> = (0..n).collect();
        let times = vec![1.0; n];
        let mut fish = default_fish(n);
        let mut rng = Rng::new(1);
        let mut hot_workers = std::collections::HashSet::new();
        let mut cold_workers: std::collections::HashMap<u64, std::collections::HashSet<usize>> =
            Default::default();
        for i in 0..60_000u64 {
            let v = view(&workers, &times, i);
            let k = if rng.gen_bool(0.4) { 0 } else { 1 + rng.gen_range(20_000) };
            let w = fish.route(k, &v);
            if k == 0 {
                hot_workers.insert(w);
            } else {
                cold_workers.entry(k).or_default().insert(w);
            }
        }
        assert!(hot_workers.len() > 4, "hot key fan-out {}", hot_workers.len());
        let wide = cold_workers.values().filter(|s| s.len() > 2).count();
        assert!(
            wide < cold_workers.len() / 10,
            "{wide}/{} cold keys exceeded 2 workers",
            cold_workers.len()
        );
    }

    #[test]
    fn balances_single_hot_key() {
        let n = 8;
        let workers: Vec<usize> = (0..n).collect();
        let times = vec![1.0; n];
        let mut fish = default_fish(n);
        let mut counts = vec![0u64; n];
        for i in 0..50_000u64 {
            let v = view(&workers, &times, i);
            counts[fish.route(99, &v)] += 1;
        }
        let imb = Imbalance::of_counts(&counts);
        assert!(imb.relative < 0.35, "imbalance {}", imb.relative);
    }

    #[test]
    fn batch_matches_sequential() {
        let n = 16;
        let workers: Vec<usize> = (0..n).collect();
        let times = vec![1.0; n];
        let mut a = default_fish(n);
        let mut b = default_fish(n);
        let mut rng = Rng::new(21);
        // several batches under distinct views, hot + cold mix
        for step in 0..20u64 {
            let v = view(&workers, &times, step * 1_000);
            let keys: Vec<u64> = (0..512)
                .map(|_| if rng.gen_bool(0.4) { 3 } else { 10 + rng.gen_range(5_000) })
                .collect();
            let seq: Vec<usize> = keys.iter().map(|&k| a.route(k, &v)).collect();
            let mut got = vec![0usize; keys.len()];
            b.route_batch(&keys, &mut got, &v);
            assert_eq!(got, seq, "step {step}");
        }
    }

    #[test]
    fn count_based_batch_matches_sequential() {
        let n = 8;
        let workers: Vec<usize> = (0..n).collect();
        let times = vec![1.0; n];
        let mut cfg = Config::default();
        cfg.workers = n;
        let mut a = Fish::from_config(&cfg, 0).with_count_based_assignment();
        let mut b = Fish::from_config(&cfg, 0).with_count_based_assignment();
        let mut rng = Rng::new(23);
        let v = view(&workers, &times, 0);
        let keys: Vec<u64> = (0..4_000)
            .map(|_| if rng.gen_bool(0.5) { 1 } else { rng.gen_range(800) })
            .collect();
        let seq: Vec<usize> = keys.iter().map(|&k| a.route(k, &v)).collect();
        let mut got = vec![0usize; keys.len()];
        b.route_batch(&keys, &mut got, &v);
        assert_eq!(got, seq);
    }

    #[test]
    fn adapts_to_hot_set_drift() {
        // After the hot key changes, the new hot key must fan out too —
        // the whole point of epoch-based identification.
        let n = 16;
        let workers: Vec<usize> = (0..n).collect();
        let times = vec![1.0; n];
        let mut fish = default_fish(n);
        let mut rng = Rng::new(4);
        for i in 0..30_000u64 {
            let v = view(&workers, &times, i);
            let k = if rng.gen_bool(0.4) { 5 } else { 100 + rng.gen_range(10_000) };
            fish.route(k, &v);
        }
        // phase 2: key 7 becomes hot
        let mut fanout = std::collections::HashSet::new();
        for i in 30_000..70_000u64 {
            let v = view(&workers, &times, i);
            let k = if rng.gen_bool(0.4) { 7 } else { 100 + rng.gen_range(10_000) };
            let w = fish.route(k, &v);
            if k == 7 && i > 40_000 {
                fanout.insert(w);
            }
        }
        assert!(fanout.len() > 3, "new hot key fan-out {}", fanout.len());
    }

    #[test]
    fn membership_change_keeps_routing_total() {
        let workers: Vec<usize> = (0..8).collect();
        let times = vec![1.0; 8];
        let mut fish = default_fish(8);
        for i in 0..5_000u64 {
            let v = view(&workers, &times, i);
            fish.route(i % 100, &v);
        }
        // worker 3 dies
        let alive: Vec<usize> = (0..8).filter(|&w| w != 3).collect();
        let v = view(&alive, &times, 5_000);
        fish.on_membership_change(&v);
        for i in 0..5_000u64 {
            let v = view(&alive, &times, 5_000 + i);
            let w = fish.route(i % 100, &v);
            assert_ne!(w, 3, "routed to dead worker");
        }
    }

    #[test]
    fn tracked_entries_bounded() {
        let workers: Vec<usize> = (0..16).collect();
        let times = vec![1.0; 16];
        let mut cfg = Config::default();
        cfg.workers = 16;
        cfg.key_capacity = 256;
        let mut fish = Fish::from_config(&cfg, 0);
        let mut rng = Rng::new(6);
        for i in 0..100_000u64 {
            let v = view(&workers, &times, i);
            fish.route(rng.gen_range(1_000_000), &v);
        }
        // identifier bounded by K_max; memo only holds hot keys
        assert!(fish.tracked_entries() < 256 + 1_000);
    }
}
