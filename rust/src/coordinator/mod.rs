//! The Layer-3 coordination contribution: stream grouping schemes,
//! exposed through a **batch-first** routing API.
//!
//! A [`Grouper`] runs at each *source* and decides which worker
//! processes each tuple. The engines (simulator and runtime) drive one
//! grouper instance per source — exactly like Storm, where grouping
//! state is local to the emitting task and no source↔worker state
//! synchronisation happens on the data path.
//!
//! ## Batch-first routing
//!
//! Both engines drain tuples in micro-batches and route through
//! [`Grouper::route_batch`], which takes a slice of keys and fills a
//! slice of worker assignments under one [`ClusterView`]. Per-tuple
//! [`Grouper::route`] remains as the semantic definition (and the
//! default `route_batch` implementation simply loops over it), but the
//! batch entry point is the hot path: schemes hoist per-call work —
//! slot-array sizing, HWA interval re-estimation, worker-count loads —
//! out of the inner loop, and the runtime engine ships one per-worker
//! chunk per batch instead of one channel send per tuple. A property
//! test (`rust/tests/prop_coordinator.rs`) pins `route_batch` to be
//! element-wise identical to sequential `route` calls for every scheme.
//!
//! Construction goes through [`crate::engine::Pipeline`] (the builder
//! both engines, the CLI, the examples and the benches share);
//! [`make_scheme`] / [`make_kind`] remain the low-level factories.
//!
//! Implemented schemes (paper §2.2): [`shuffle`] SG, [`field`] FG,
//! [`pkg`] PKG, [`dchoices`] D-C, [`wchoices`] W-C, and [`fish`] FISH.

pub mod dchoices;
pub mod field;
pub mod fish;
pub mod pkg;
pub mod rebalance;
pub mod shuffle;
pub mod wchoices;

pub use dchoices::DChoices;
pub use field::FieldGrouping;
pub use fish::Fish;
pub use pkg::PartialKeyGrouping;
pub use rebalance::RebalanceGrouping;
pub use shuffle::ShuffleGrouping;
pub use wchoices::WChoices;

use crate::config::Config;
use crate::{Key, WorkerId};
use std::str::FromStr;

/// What a source can see of the cluster when routing (no communication
/// with workers — this is the point of the paper's heuristic inference).
#[derive(Debug, Clone, Copy)]
pub struct ClusterView<'a> {
    /// Current time (ns in the runtime engine, virtual ticks in the sim).
    pub now: u64,
    /// Alive worker ids, ascending.
    pub workers: &'a [WorkerId],
    /// `P_w`: sampled mean per-tuple processing time, indexed by worker id.
    /// Entries for dead workers may be stale; index only via `workers`.
    pub per_tuple_time: &'a [f64],
    /// Array sizing: `max worker id + 1`.
    pub n_slots: usize,
}

/// A stream grouping scheme instance (one per source).
pub trait Grouper: Send {
    /// Scheme identity (for reports).
    fn kind(&self) -> SchemeKind;

    /// Route one tuple: pick the worker that will process `key`.
    fn route(&mut self, key: Key, view: &ClusterView<'_>) -> WorkerId;

    /// Route a batch of tuples under one cluster view: fill `out[i]`
    /// with the worker for `keys[i]`.
    ///
    /// This is the engines' hot path. Implementations MUST be
    /// observationally identical to sequential [`Grouper::route`] calls
    /// with the same `view` (property-tested for every scheme); they
    /// differ only in hoisting per-call work out of the inner loop.
    fn route_batch(&mut self, keys: &[Key], out: &mut [WorkerId], view: &ClusterView<'_>) {
        debug_assert_eq!(keys.len(), out.len(), "route_batch: keys/out length mismatch");
        for (key, slot) in keys.iter().zip(out.iter_mut()) {
            *slot = self.route(*key, view);
        }
    }

    /// Worker-set membership changed (scale up/down, failure). Default:
    /// schemes that derive placement purely from `view.workers` need no
    /// bookkeeping.
    fn on_membership_change(&mut self, _view: &ClusterView<'_>) {}

    /// Tracked internal entries (counters, memos) — the *control-plane*
    /// memory of the scheme, reported alongside state replication.
    fn tracked_entries(&self) -> usize {
        0
    }
}

/// Enumeration of all schemes (CLI / config selection).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchemeKind {
    /// Shuffle grouping — round robin.
    Shuffle,
    /// Field grouping — hash by key.
    Field,
    /// Partial-key grouping — power of two choices.
    Pkg,
    /// D-Choices — lifetime heavy hitters on d workers.
    DChoices,
    /// W-Choices — lifetime heavy hitters on all workers.
    WChoices,
    /// FISH — epoch-based identification + CHK + heuristic assignment.
    Fish,
    /// Operator-migration baseline (related-work §7, not in the paper's
    /// evaluated set — excluded from [`SchemeKind::all`]).
    Rebalance,
}

impl SchemeKind {
    /// Short name used in figures and CLI.
    pub fn name(&self) -> &'static str {
        match self {
            SchemeKind::Shuffle => "sg",
            SchemeKind::Field => "fg",
            SchemeKind::Pkg => "pkg",
            SchemeKind::DChoices => "dc",
            SchemeKind::WChoices => "wc",
            SchemeKind::Fish => "fish",
            SchemeKind::Rebalance => "rebalance",
        }
    }

    /// All schemes, figure order.
    pub fn all() -> [SchemeKind; 6] {
        [
            SchemeKind::Field,
            SchemeKind::Pkg,
            SchemeKind::Shuffle,
            SchemeKind::DChoices,
            SchemeKind::WChoices,
            SchemeKind::Fish,
        ]
    }
}

impl FromStr for SchemeKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "sg" | "shuffle" => Ok(SchemeKind::Shuffle),
            "fg" | "field" => Ok(SchemeKind::Field),
            "pkg" => Ok(SchemeKind::Pkg),
            "dc" | "d-choices" | "dchoices" => Ok(SchemeKind::DChoices),
            "wc" | "w-choices" | "wchoices" => Ok(SchemeKind::WChoices),
            "fish" => Ok(SchemeKind::Fish),
            "rebalance" => Ok(SchemeKind::Rebalance),
            other => Err(format!(
                "unknown scheme '{other}' (sg|fg|pkg|dc|wc|fish|rebalance)"
            )),
        }
    }
}

impl std::fmt::Display for SchemeKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Build a grouper for `cfg.scheme`, seeded per `source` so independent
/// sources make decorrelated random choices (as independent Storm tasks
/// would). The FISH identifier backend follows `cfg.identifier`
/// (`native` here; `xla-cms` is constructed by [`crate::runtime`] since
/// it needs a PJRT client).
pub fn make_scheme(cfg: &Config, source: usize) -> Box<dyn Grouper> {
    make_kind(cfg.scheme, cfg, source)
}

/// Build a specific scheme kind with `cfg`'s parameters.
pub fn make_kind(kind: SchemeKind, cfg: &Config, source: usize) -> Box<dyn Grouper> {
    let seed = cfg.seed ^ (source as u64).wrapping_mul(0xA076_1D64_78BD_642F);
    match kind {
        SchemeKind::Shuffle => Box::new(ShuffleGrouping::new(source)),
        SchemeKind::Field => Box::new(FieldGrouping::new()),
        SchemeKind::Pkg => Box::new(PartialKeyGrouping::new(cfg.workers)),
        SchemeKind::DChoices => Box::new(DChoices::new(
            cfg.workers,
            cfg.key_capacity,
            cfg.theta(),
            seed,
        )),
        SchemeKind::WChoices => Box::new(WChoices::new(
            cfg.workers,
            cfg.key_capacity,
            cfg.theta(),
            seed,
        )),
        SchemeKind::Fish => Box::new(Fish::from_config(cfg, source)),
        SchemeKind::Rebalance => Box::new(RebalanceGrouping::new(
            cfg.workers,
            cfg.key_capacity,
            (cfg.epoch as u64).max(1),
            cfg.rebalance_threshold,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_names_roundtrip() {
        for k in SchemeKind::all() {
            assert_eq!(k.name().parse::<SchemeKind>().unwrap(), k);
        }
        assert!("bogus".parse::<SchemeKind>().is_err());
    }

    #[test]
    fn factory_builds_every_scheme() {
        let cfg = Config::default();
        for k in SchemeKind::all() {
            let g = make_kind(k, &cfg, 0);
            assert_eq!(g.kind(), k);
        }
    }

    #[test]
    fn default_route_batch_matches_sequential() {
        // Rebalance inherits the default `route_batch`; pin it to the
        // per-tuple definition.
        let mut cfg = Config::default();
        cfg.workers = 8;
        let mut a = make_kind(SchemeKind::Rebalance, &cfg, 0);
        let mut b = make_kind(SchemeKind::Rebalance, &cfg, 0);
        let ids: Vec<usize> = (0..8).collect();
        let times = vec![1.0; 8];
        let view = ClusterView { now: 0, workers: &ids, per_tuple_time: &times, n_slots: 8 };
        let keys: Vec<Key> = (0..4_000u64).map(|i| i % 37).collect();
        let seq: Vec<WorkerId> = keys.iter().map(|&k| a.route(k, &view)).collect();
        let mut got = vec![0usize; keys.len()];
        b.route_batch(&keys, &mut got, &view);
        assert_eq!(got, seq);
    }

    #[test]
    fn rebalance_threshold_comes_from_config() {
        let mut cfg = Config::default();
        cfg.rebalance_threshold = 0.75;
        // builds without panicking and identifies as rebalance; the
        // threshold's behavioural effect is covered in rebalance.rs
        let g = make_kind(SchemeKind::Rebalance, &cfg, 0);
        assert_eq!(g.kind(), SchemeKind::Rebalance);
    }
}
