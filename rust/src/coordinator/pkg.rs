//! Partial Key Grouping (PKG) — Nasir et al., ICDE 2015 [14].
//!
//! Each key hashes to exactly two candidate workers (two independent hash
//! family members); the tuple goes to whichever candidate this source has
//! sent fewer tuples so far (power of two choices on *local* counts — no
//! worker communication). Bounds replication at 2 entries/key but cannot
//! rebalance a single ultra-hot key across more than two workers
//! (paper Fig. 2: latency blows up at scale).

use super::{ClusterView, Grouper, SchemeKind};
use crate::util::hash::hash_to;
use crate::{Key, WorkerId};

/// Power-of-two-choices grouper with local load counts.
#[derive(Debug, Clone)]
pub struct PartialKeyGrouping {
    /// Tuples this source has sent to each worker id.
    sent: Vec<u64>,
}

impl PartialKeyGrouping {
    /// `n_slots` sizes the local counter array (max worker id + 1).
    pub fn new(n_slots: usize) -> Self {
        PartialKeyGrouping { sent: vec![0; n_slots] }
    }

    #[inline]
    fn ensure_slots(&mut self, n: usize) {
        if self.sent.len() < n {
            self.sent.resize(n, 0);
        }
    }

    /// The two candidate workers for `key` among `workers`.
    #[inline]
    pub fn choices(key: Key, workers: &[WorkerId]) -> (WorkerId, WorkerId) {
        let a = workers[hash_to(key, 1, workers.len())];
        let b = workers[hash_to(key, 2, workers.len())];
        (a, b)
    }

    /// The per-tuple decision, shared by `route` and `route_batch`
    /// (callers must have run [`PartialKeyGrouping::ensure_slots`]).
    #[inline]
    fn route_one(&mut self, key: Key, workers: &[WorkerId]) -> WorkerId {
        let (a, b) = Self::choices(key, workers);
        let w = if self.sent[a] <= self.sent[b] { a } else { b };
        self.sent[w] += 1;
        w
    }
}

impl Grouper for PartialKeyGrouping {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Pkg
    }

    #[inline]
    fn route(&mut self, key: Key, view: &ClusterView<'_>) -> WorkerId {
        self.ensure_slots(view.n_slots);
        self.route_one(key, view.workers)
    }

    fn route_batch(&mut self, keys: &[Key], out: &mut [WorkerId], view: &ClusterView<'_>) {
        debug_assert_eq!(keys.len(), out.len());
        // hoisted: counter-array sizing check
        self.ensure_slots(view.n_slots);
        for (key, slot) in keys.iter().zip(out.iter_mut()) {
            *slot = self.route_one(*key, view.workers);
        }
    }

    fn on_membership_change(&mut self, view: &ClusterView<'_>) {
        self.ensure_slots(view.n_slots);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view<'a>(workers: &'a [usize], times: &'a [f64]) -> ClusterView<'a> {
        ClusterView { now: 0, workers, per_tuple_time: times, n_slots: times.len() }
    }

    #[test]
    fn at_most_two_workers_per_key() {
        let workers: Vec<usize> = (0..16).collect();
        let times = vec![1.0; 16];
        let v = view(&workers, &times);
        let mut g = PartialKeyGrouping::new(16);
        for k in 0..200u64 {
            let mut seen = std::collections::HashSet::new();
            for _ in 0..50 {
                seen.insert(g.route(k, &v));
            }
            assert!(seen.len() <= 2, "key {k} hit {} workers", seen.len());
        }
    }

    #[test]
    fn batch_matches_sequential() {
        let workers: Vec<usize> = (0..8).collect();
        let times = vec![1.0; 8];
        let v = view(&workers, &times);
        let mut a = PartialKeyGrouping::new(8);
        let mut b = PartialKeyGrouping::new(8);
        let mut rng = crate::util::Rng::new(4);
        let keys: Vec<u64> = (0..3_000).map(|_| rng.gen_range(50)).collect();
        let seq: Vec<usize> = keys.iter().map(|&k| a.route(k, &v)).collect();
        let mut got = vec![0usize; keys.len()];
        b.route_batch(&keys, &mut got, &v);
        assert_eq!(got, seq);
    }

    #[test]
    fn uniform_keys_balance_well() {
        let workers: Vec<usize> = (0..8).collect();
        let times = vec![1.0; 8];
        let v = view(&workers, &times);
        let mut g = PartialKeyGrouping::new(8);
        let mut counts = [0u64; 8];
        let mut rng = crate::util::Rng::new(1);
        for _ in 0..80_000 {
            counts[g.route(rng.gen_range(10_000), &v)] += 1;
        }
        let imb = crate::metrics::Imbalance::of_counts(&counts);
        assert!(imb.relative < 0.05, "relative imbalance {}", imb.relative);
    }

    #[test]
    fn single_hot_key_splits_evenly_between_its_two() {
        let workers: Vec<usize> = (0..8).collect();
        let times = vec![1.0; 8];
        let v = view(&workers, &times);
        let mut g = PartialKeyGrouping::new(8);
        let (a, b) = PartialKeyGrouping::choices(7, &workers);
        let mut counts = [0u64; 8];
        for _ in 0..10_000 {
            counts[g.route(7, &v)] += 1;
        }
        if a == b {
            assert_eq!(counts[a], 10_000);
        } else {
            assert_eq!(counts[a] + counts[b], 10_000);
            assert!((counts[a] as i64 - counts[b] as i64).abs() <= 1);
        }
    }
}
