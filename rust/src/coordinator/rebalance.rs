//! Rebalance grouping — the operator-migration family of §7 ([7]–[13]):
//! key-hash routing plus a reactive rebalancing routine.
//!
//! Every `check_every` tuples the source inspects its local per-worker
//! load; if `max/mean − 1` exceeds `imbalance_threshold`, the hottest
//! keys of the most loaded worker are remapped to the least loaded one
//! through an explicit **routing table**. This reproduces the two costs
//! the paper's related-work critique names: the routing table's memory
//! footprint grows with the number of remapped keys, and every migration
//! implies moving the key's state between workers.
//!
//! Not part of the paper's evaluated scheme set; included as the §7
//! comparison baseline (`--scheme rebalance`).

use super::{ClusterView, Grouper, SchemeKind};
use crate::sketch::SpaceSaving;
use crate::util::hash::hash_to;
use crate::{Key, WorkerId};
use std::collections::HashMap;

/// FG + reactive key migration.
pub struct RebalanceGrouping {
    /// Explicit overrides: key → worker (the routing table).
    routing: HashMap<Key, WorkerId>,
    /// Local per-worker tuple counts.
    sent: Vec<u64>,
    /// Hot-key tracker to pick migration victims.
    hot: SpaceSaving,
    check_every: u64,
    imbalance_threshold: f64,
    tuples: u64,
    /// Migrations performed (state-move cost metric).
    pub migrations: u64,
}

impl RebalanceGrouping {
    /// `check_every` tuples between imbalance checks;
    /// `imbalance_threshold` on `max/mean − 1`.
    pub fn new(n_slots: usize, key_capacity: usize, check_every: u64, imbalance_threshold: f64) -> Self {
        assert!(check_every > 0);
        RebalanceGrouping {
            routing: HashMap::new(),
            sent: vec![0; n_slots],
            hot: SpaceSaving::new(key_capacity),
            check_every,
            imbalance_threshold,
            tuples: 0,
            migrations: 0,
        }
    }

    fn base_route(&self, key: Key, workers: &[WorkerId]) -> WorkerId {
        workers[hash_to(key, 0xF1E1D, workers.len())]
    }

    /// Reactive rebalance: move the most loaded worker's hottest keys to
    /// the least loaded worker.
    fn maybe_rebalance(&mut self, view: &ClusterView<'_>) {
        let loads: Vec<(WorkerId, u64)> =
            view.workers.iter().map(|&w| (w, self.sent[w])).collect();
        let total: u64 = loads.iter().map(|(_, l)| l).sum();
        if total == 0 {
            return;
        }
        let mean = total as f64 / loads.len() as f64;
        let (max_w, max_l) = *loads.iter().max_by_key(|(_, l)| *l).unwrap();
        if max_l as f64 / mean - 1.0 <= self.imbalance_threshold {
            return;
        }
        let (min_w, _) = *loads.iter().min_by_key(|(_, l)| *l).unwrap();
        // migrate the hottest keys currently mapped to max_w
        let candidates: Vec<Key> = self
            .hot
            .top_n(8)
            .into_iter()
            .map(|(k, _)| k)
            .filter(|&k| {
                self.routing
                    .get(&k)
                    .copied()
                    .unwrap_or_else(|| self.base_route(k, view.workers))
                    == max_w
            })
            .collect();
        for k in candidates {
            self.routing.insert(k, min_w);
            self.migrations += 1;
        }
    }
}

impl Grouper for RebalanceGrouping {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Rebalance
    }

    fn route(&mut self, key: Key, view: &ClusterView<'_>) -> WorkerId {
        if self.sent.len() < view.n_slots {
            self.sent.resize(view.n_slots, 0);
        }
        self.hot.observe(key);
        self.tuples += 1;
        if self.tuples % self.check_every == 0 {
            self.maybe_rebalance(view);
        }
        let mut w = self
            .routing
            .get(&key)
            .copied()
            .unwrap_or_else(|| self.base_route(key, view.workers));
        if !view.workers.contains(&w) {
            // mapped worker died: fall back to base route and repair
            w = self.base_route(key, view.workers);
            self.routing.remove(&key);
        }
        self.sent[w] += 1;
        w
    }

    fn on_membership_change(&mut self, view: &ClusterView<'_>) {
        if self.sent.len() < view.n_slots {
            self.sent.resize(view.n_slots, 0);
        }
        // drop overrides that point at dead workers
        let alive: std::collections::HashSet<WorkerId> =
            view.workers.iter().copied().collect();
        self.routing.retain(|_, w| alive.contains(w));
    }

    fn tracked_entries(&self) -> usize {
        // the §7 critique: the routing table is control-plane memory that
        // grows with migrated keys
        self.routing.len() + self.hot.entries()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view<'a>(workers: &'a [usize], times: &'a [f64]) -> ClusterView<'a> {
        ClusterView { now: 0, workers, per_tuple_time: times, n_slots: times.len() }
    }

    #[test]
    fn migrates_hot_key_off_overloaded_worker() {
        let workers: Vec<usize> = (0..4).collect();
        let times = vec![1.0; 4];
        let v = view(&workers, &times);
        let mut g = RebalanceGrouping::new(4, 64, 1_000, 0.5);
        let hot_key = 7u64;
        let home = g.base_route(hot_key, &workers);
        let mut rng = crate::util::Rng::new(2);
        let mut late_routes = Vec::new();
        for i in 0..30_000 {
            let k = if rng.gen_bool(0.6) { hot_key } else { rng.gen_range(10_000) };
            let w = g.route(k, &v);
            if i > 20_000 && k == hot_key {
                late_routes.push(w);
            }
        }
        assert!(g.migrations > 0, "no rebalance happened");
        assert!(
            late_routes.iter().any(|&w| w != home),
            "hot key never migrated off worker {home}"
        );
    }

    #[test]
    fn routing_table_repairs_after_worker_death() {
        let workers: Vec<usize> = (0..4).collect();
        let times = vec![1.0; 4];
        let v = view(&workers, &times);
        let mut g = RebalanceGrouping::new(4, 64, 100, 0.1);
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..5_000 {
            let k = if rng.gen_bool(0.5) { 3 } else { rng.gen_range(1_000) };
            g.route(k, &v);
        }
        let alive = [0usize, 1, 2];
        let v2 = view(&alive, &times);
        g.on_membership_change(&v2);
        for i in 0..2_000u64 {
            let w = g.route(i % 50, &v2);
            assert!(w != 3, "routed to dead worker");
        }
    }

    #[test]
    fn control_memory_grows_with_migrations() {
        let workers: Vec<usize> = (0..8).collect();
        let times = vec![1.0; 8];
        let v = view(&workers, &times);
        let mut g = RebalanceGrouping::new(8, 256, 500, 0.05);
        let mut rng = crate::util::Rng::new(7);
        for _ in 0..50_000 {
            // rotating hot keys force repeated migrations
            let k = if rng.gen_bool(0.5) { rng.gen_range(5) } else { rng.gen_range(100_000) };
            g.route(k, &v);
        }
        assert!(g.tracked_entries() > 0);
        assert!(g.migrations >= 1);
    }
}
