//! D-Choices (D-C) — Nasir et al., ICDE 2016 [15].
//!
//! Lifetime SpaceSaving heavy-hitter detection (no decay — this is
//! exactly the "entire processing lifetime" view the FISH paper critiques
//! for time-evolving data). Keys whose *lifetime* relative frequency
//! exceeds θ are spread over `d` hash choices (one `d` for the whole head
//! set, per the original scheme); all other keys use PKG's two choices.
//! Among candidates the source picks the one with the fewest locally-sent
//! tuples (greedy-d).

use super::{ClusterView, Grouper, SchemeKind};
use crate::sketch::SpaceSaving;
use crate::util::hash::hash_to;
use crate::{Key, WorkerId};

/// Shared head-key machinery for D-C and W-C.
#[derive(Debug, Clone)]
pub(crate) struct HeavyHitters {
    pub sketch: SpaceSaving,
    pub theta: f64,
    pub total: f64,
}

impl HeavyHitters {
    pub fn new(key_capacity: usize, theta: f64) -> Self {
        HeavyHitters { sketch: SpaceSaving::new(key_capacity), theta, total: 0.0 }
    }

    /// Observe and report whether `key` is currently a lifetime heavy
    /// hitter (relative frequency > θ).
    #[inline]
    pub fn observe_is_hot(&mut self, key: Key) -> bool {
        self.sketch.observe(key);
        self.total += 1.0;
        self.sketch.estimate(key) > self.theta * self.total
    }

    /// Relative frequency of the hottest tracked key.
    pub fn top_rel(&self) -> f64 {
        if self.total == 0.0 {
            0.0
        } else {
            self.sketch.top_count() / self.total
        }
    }
}

/// D-Choices grouper.
#[derive(Debug, Clone)]
pub struct DChoices {
    hh: HeavyHitters,
    sent: Vec<u64>,
    seed: u64,
}

impl DChoices {
    /// `key_capacity` = the scheme's "maximum set of keys" (the paper's
    /// motivating study tests 100 and 1000); `theta` the hot threshold.
    pub fn new(n_slots: usize, key_capacity: usize, theta: f64, seed: u64) -> Self {
        DChoices {
            hh: HeavyHitters::new(key_capacity, theta),
            sent: vec![0; n_slots],
            seed,
        }
    }

    /// The single `d` used for every head key: smallest d such that the
    /// hottest key's per-worker share `f_top/d` drops under θ (the load
    /// level at which PKG-style balance is provable), clamped to
    /// `[2, |workers|]`. Matches the original scheme's "one d for the
    /// whole head, derived from the key distribution".
    pub(crate) fn head_d(top_rel: f64, theta: f64, n_workers: usize) -> usize {
        let cap = n_workers.max(1);
        if top_rel <= theta {
            return 2.min(cap);
        }
        ((top_rel / theta).ceil() as usize).max(2).min(cap)
    }

    #[inline]
    pub(crate) fn pick_least_sent(
        sent: &[u64],
        key: Key,
        seed: u64,
        workers: &[WorkerId],
        d: usize,
    ) -> WorkerId {
        // d hash-family candidates (distinct family seeds; collisions just
        // reduce the effective choice count, as in the original papers).
        let mut best = workers[hash_to(key, seed ^ 1, workers.len())];
        for i in 2..=d as u64 {
            let c = workers[hash_to(key, seed ^ i, workers.len())];
            if sent[c] < sent[best] {
                best = c;
            }
        }
        best
    }

    /// The per-tuple decision, shared by `route` and `route_batch`
    /// (callers must have sized `self.sent` first).
    #[inline]
    fn route_one(&mut self, key: Key, workers: &[WorkerId]) -> WorkerId {
        let hot = self.hh.observe_is_hot(key);
        let d = if hot {
            Self::head_d(self.hh.top_rel(), self.hh.theta, workers.len())
        } else {
            2
        };
        let w = Self::pick_least_sent(&self.sent, key, self.seed, workers, d);
        self.sent[w] += 1;
        w
    }
}

impl Grouper for DChoices {
    fn kind(&self) -> SchemeKind {
        SchemeKind::DChoices
    }

    #[inline]
    fn route(&mut self, key: Key, view: &ClusterView<'_>) -> WorkerId {
        if self.sent.len() < view.n_slots {
            self.sent.resize(view.n_slots, 0);
        }
        self.route_one(key, view.workers)
    }

    fn route_batch(&mut self, keys: &[Key], out: &mut [WorkerId], view: &ClusterView<'_>) {
        debug_assert_eq!(keys.len(), out.len());
        // hoisted: counter sizing check; the sketch update and head-d
        // derivation stay per-tuple (they track the stream)
        if self.sent.len() < view.n_slots {
            self.sent.resize(view.n_slots, 0);
        }
        for (key, slot) in keys.iter().zip(out.iter_mut()) {
            *slot = self.route_one(*key, view.workers);
        }
    }

    fn on_membership_change(&mut self, view: &ClusterView<'_>) {
        if self.sent.len() < view.n_slots {
            self.sent.resize(view.n_slots, 0);
        }
    }

    fn tracked_entries(&self) -> usize {
        self.hh.sketch.entries()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view<'a>(workers: &'a [usize], times: &'a [f64]) -> ClusterView<'a> {
        ClusterView { now: 0, workers, per_tuple_time: times, n_slots: times.len() }
    }

    #[test]
    fn head_d_formula() {
        assert_eq!(DChoices::head_d(0.001, 0.01, 64), 2);
        assert_eq!(DChoices::head_d(0.10, 0.01, 64), 10);
        assert_eq!(DChoices::head_d(0.9, 0.001, 64), 64); // clamped
    }

    #[test]
    fn hot_key_uses_more_than_two_workers() {
        let workers: Vec<usize> = (0..32).collect();
        let times = vec![1.0; 32];
        let v = view(&workers, &times);
        let mut g = DChoices::new(32, 100, 2.0 / 32.0, 7);
        let mut seen = std::collections::HashSet::new();
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..50_000 {
            // 50% hot key 0, rest uniform tail
            let k = if rng.gen_bool(0.5) { 0 } else { 1 + rng.gen_range(10_000) };
            let w = g.route(k, &v);
            if k == 0 {
                seen.insert(w);
            }
        }
        assert!(seen.len() > 2, "hot key only used {} workers", seen.len());
    }

    #[test]
    fn batch_matches_sequential() {
        let workers: Vec<usize> = (0..16).collect();
        let times = vec![1.0; 16];
        let v = view(&workers, &times);
        let mut a = DChoices::new(16, 100, 2.0 / 16.0, 7);
        let mut b = DChoices::new(16, 100, 2.0 / 16.0, 7);
        let mut rng = crate::util::Rng::new(6);
        let keys: Vec<u64> = (0..5_000)
            .map(|_| if rng.gen_bool(0.4) { 0 } else { rng.gen_range(2_000) })
            .collect();
        let seq: Vec<usize> = keys.iter().map(|&k| a.route(k, &v)).collect();
        let mut got = vec![0usize; keys.len()];
        b.route_batch(&keys, &mut got, &v);
        assert_eq!(got, seq);
    }

    #[test]
    fn cold_keys_stay_on_two() {
        let workers: Vec<usize> = (0..16).collect();
        let times = vec![1.0; 16];
        let v = view(&workers, &times);
        let mut g = DChoices::new(16, 100, 2.0 / 16.0, 7);
        let mut per_key: std::collections::HashMap<u64, std::collections::HashSet<usize>> =
            Default::default();
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..40_000 {
            let k = rng.gen_range(5_000); // no key is hot
            let w = g.route(k, &v);
            per_key.entry(k).or_default().insert(w);
        }
        let over = per_key.values().filter(|s| s.len() > 2).count();
        // SpaceSaving noise can transiently flag a few keys; the bulk
        // must stay on ≤ 2 workers.
        assert!(over < per_key.len() / 20, "{over}/{} keys exceeded 2", per_key.len());
    }

    #[test]
    fn tracked_entries_bounded_by_capacity() {
        let workers: Vec<usize> = (0..8).collect();
        let times = vec![1.0; 8];
        let v = view(&workers, &times);
        let mut g = DChoices::new(8, 100, 0.01, 1);
        let mut rng = crate::util::Rng::new(9);
        for _ in 0..20_000 {
            g.route(rng.gen_range(1_000_000), &v);
        }
        assert!(g.tracked_entries() <= 100);
    }
}
