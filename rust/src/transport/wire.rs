//! Length-prefixed binary wire format for the transport subsystem.
//!
//! Every frame is a fixed 12-byte header followed by a little-endian
//! payload:
//!
//! ```text
//! offset  size  field
//! 0       4     magic "FSHW"
//! 4       1     format version (currently 1)
//! 5       1     frame kind
//! 6       2     reserved (zero)
//! 8       4     payload length, u32 LE
//! ```
//!
//! Encoding appends into a caller-owned `Vec<u8>` so hot paths reuse a
//! single buffer per lane; decoding borrows the input slice and only
//! allocates the output collections. [`read_frame`] distinguishes a
//! clean end-of-stream (`Ok(None)` — the peer closed exactly on a
//! frame boundary) from a mid-frame truncation
//! ([`WireError::Truncated`]).

use crate::Key;
use std::fmt;
use std::io::Read;

/// 4-byte frame magic.
pub const MAGIC: [u8; 4] = *b"FSHW";
/// Current wire-format version.
pub const VERSION: u8 = 1;
/// Fixed frame-header length in bytes.
pub const HEADER_LEN: usize = 12;
/// Payload bytes per encoded [`Msg`] (key, emit_ns, ts).
pub const MSG_BYTES: usize = 24;

const KIND_DATA: u8 = 1;
const KIND_FLUSH: u8 = 2;
const KIND_CREDIT: u8 = 3;
const KIND_HELLO: u8 = 4;
const KIND_EOF: u8 = 5;
const KIND_DONE: u8 = 6;
const KIND_RESUME: u8 = 7;

/// One routed tuple in flight from a source to a worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Msg {
    /// Interned key id.
    pub key: Key,
    /// Source emit time in ns on the run's shared clock (end-to-end
    /// latency is completion time minus this).
    pub emit_ns: u64,
    /// Event-time timestamp from the trace (drives pane assignment).
    pub ts: u64,
}

/// One partial-aggregate flush from a worker to a merge shard.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlushMsg {
    /// Originating worker index.
    pub worker: usize,
    /// Per-(worker, shard) monotonic sequence number (0-based). Each
    /// worker numbers the flushes it sends to each shard independently;
    /// the shard's merge path accepts exactly seq == expected, buffers
    /// ahead-of-expected frames, and drops replayed ones — the dedup
    /// half of the exactly-once guarantee (docs/RECOVERY.md).
    pub seq: u64,
    /// Flush emit time in ns (flush→merge transit latency baseline).
    pub emit_ns: u64,
    /// The worker's event-time watermark at flush time (`u64::MAX` on
    /// the final end-of-stream flush).
    pub watermark: u64,
    /// Per-pane deltas: `(window id, (key, count) entries)`. Empty on
    /// a watermark-only flush.
    pub panes: Vec<(u64, Vec<(Key, u64)>)>,
}

/// A decoded transport frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// A batch of routed tuples (source → worker).
    Data(Vec<Msg>),
    /// A partial-aggregate flush (worker → shard).
    Flush(FlushMsg),
    /// Flow-control credit return: the receiver freed `n` tuples of
    /// window space (worker → source).
    Credit(u64),
    /// Launch handshake: a child process reports its role, index and
    /// the data address it listens on (child → coordinator).
    Hello {
        /// 1 = worker, 2 = shard.
        role: u8,
        /// Worker or shard index.
        index: u64,
        /// Address peers pass to `Duplex::connect`.
        addr: String,
    },
    /// Explicit end-of-stream marker (a socket close on a frame
    /// boundary means the same thing).
    Eof,
    /// Opaque result blob a child returns to the coordinator.
    Done(Vec<u8>),
    /// Flush-stream resume point (shard → worker, sent once right
    /// after a flush connection is accepted): the next flush sequence
    /// number the shard expects from `worker`. 0 on a fresh stream; a
    /// recovered shard answers with its snapshot's acked seq + 1 so the
    /// worker replays exactly the lost suffix of its flush log.
    Resume {
        /// Worker index the shard is addressing.
        worker: u64,
        /// Next expected flush sequence number on this stream.
        next_seq: u64,
    },
}

/// Wire decode / IO error.
#[derive(Debug)]
pub enum WireError {
    /// The input ended mid-header or mid-payload.
    Truncated,
    /// The 4-byte magic did not match [`MAGIC`].
    BadMagic,
    /// The version byte did not match [`VERSION`].
    VersionMismatch {
        /// Version byte on the wire.
        got: u8,
        /// Version this build speaks.
        want: u8,
    },
    /// Unknown frame-kind byte.
    BadKind(u8),
    /// Underlying socket/file error.
    Io(std::io::Error),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::BadMagic => write!(f, "bad frame magic"),
            WireError::VersionMismatch { got, want } => {
                write!(f, "wire version mismatch: got {got}, want {want}")
            }
            WireError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::Io(e) => write!(f, "wire io error: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Truncated
        } else {
            WireError::Io(e)
        }
    }
}

#[inline]
pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub(crate) fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

/// Append a frame header with a zero length field; returns the payload
/// start offset for [`end_frame`] to patch.
fn begin_frame(kind: u8, buf: &mut Vec<u8>) -> usize {
    buf.extend_from_slice(&MAGIC);
    buf.push(VERSION);
    buf.push(kind);
    buf.extend_from_slice(&[0, 0]);
    put_u32(buf, 0);
    buf.len()
}

/// Patch the payload length of the frame opened at `payload_start`.
fn end_frame(payload_start: usize, buf: &mut Vec<u8>) {
    let len = (buf.len() - payload_start) as u32;
    buf[payload_start - 4..payload_start].copy_from_slice(&len.to_le_bytes());
}

/// Append a `Data` frame carrying `msgs`.
pub fn encode_data(msgs: &[Msg], buf: &mut Vec<u8>) {
    let start = begin_frame(KIND_DATA, buf);
    buf.reserve(4 + msgs.len() * MSG_BYTES);
    put_u32(buf, msgs.len() as u32);
    for m in msgs {
        put_u64(buf, m.key);
        put_u64(buf, m.emit_ns);
        put_u64(buf, m.ts);
    }
    end_frame(start, buf);
}

/// Append a `Flush` frame.
pub fn encode_flush(msg: &FlushMsg, buf: &mut Vec<u8>) {
    let start = begin_frame(KIND_FLUSH, buf);
    put_u64(buf, msg.worker as u64);
    put_u64(buf, msg.seq);
    put_u64(buf, msg.emit_ns);
    put_u64(buf, msg.watermark);
    put_u32(buf, msg.panes.len() as u32);
    for (window, entries) in &msg.panes {
        put_u64(buf, *window);
        put_u32(buf, entries.len() as u32);
        for &(key, count) in entries {
            put_u64(buf, key);
            put_u64(buf, count);
        }
    }
    end_frame(start, buf);
}

/// Append a `Credit` frame returning `n` tuples of window space.
pub fn encode_credit(n: u64, buf: &mut Vec<u8>) {
    let start = begin_frame(KIND_CREDIT, buf);
    put_u64(buf, n);
    end_frame(start, buf);
}

/// Append a `Hello` handshake frame.
pub fn encode_hello(role: u8, index: u64, addr: &str, buf: &mut Vec<u8>) {
    let start = begin_frame(KIND_HELLO, buf);
    buf.push(role);
    put_u64(buf, index);
    put_u32(buf, addr.len() as u32);
    buf.extend_from_slice(addr.as_bytes());
    end_frame(start, buf);
}

/// Append an `Eof` frame.
pub fn encode_eof(buf: &mut Vec<u8>) {
    let start = begin_frame(KIND_EOF, buf);
    end_frame(start, buf);
}

/// Append a `Resume` frame telling `worker` the next flush sequence
/// number this shard expects.
pub fn encode_resume(worker: u64, next_seq: u64, buf: &mut Vec<u8>) {
    let start = begin_frame(KIND_RESUME, buf);
    put_u64(buf, worker);
    put_u64(buf, next_seq);
    end_frame(start, buf);
}

/// Append a `Done` frame wrapping an opaque result blob.
pub fn encode_done(payload: &[u8], buf: &mut Vec<u8>) {
    let start = begin_frame(KIND_DONE, buf);
    buf.extend_from_slice(payload);
    end_frame(start, buf);
}

/// Little-endian payload reader over a borrowed byte slice; every
/// accessor fails with [`WireError::Truncated`] instead of panicking,
/// so malformed frames can never crash a receiver.
pub struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Start reading at the front of `bytes`.
    pub fn new(bytes: &'a [u8]) -> Self {
        Reader { bytes, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }

    /// Consume the next `n` bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated);
        }
        let out = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// Consume one byte.
    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    /// Consume a little-endian u32.
    pub fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Consume a little-endian u64.
    pub fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Consume an f64 stored as its little-endian bit pattern.
    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Consume a u32-length-prefixed UTF-8 string.
    pub fn str_u32(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| {
            WireError::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "non-utf8 string on the wire",
            ))
        })
    }
}

/// Parse a frame header: returns `(kind, payload length)`. The kind
/// byte is validated later, by payload decode, so `Credit`-only
/// readers can skip frames they do not understand if they choose to.
pub fn parse_header(header: &[u8]) -> Result<(u8, usize), WireError> {
    if header.len() < HEADER_LEN {
        return Err(WireError::Truncated);
    }
    if header[..4] != MAGIC {
        return Err(WireError::BadMagic);
    }
    if header[4] != VERSION {
        return Err(WireError::VersionMismatch { got: header[4], want: VERSION });
    }
    let len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]) as usize;
    Ok((header[5], len))
}

/// Decode a payload of the given kind (header already stripped).
pub(crate) fn decode_payload(kind: u8, payload: &[u8]) -> Result<Frame, WireError> {
    let mut r = Reader::new(payload);
    match kind {
        KIND_DATA => {
            let n = r.u32()? as usize;
            if r.remaining() < n.saturating_mul(MSG_BYTES) {
                return Err(WireError::Truncated);
            }
            let mut msgs = Vec::with_capacity(n);
            for _ in 0..n {
                msgs.push(Msg { key: r.u64()?, emit_ns: r.u64()?, ts: r.u64()? });
            }
            Ok(Frame::Data(msgs))
        }
        KIND_FLUSH => {
            let worker = r.u64()? as usize;
            let seq = r.u64()?;
            let emit_ns = r.u64()?;
            let watermark = r.u64()?;
            let n_panes = r.u32()? as usize;
            // 12 bytes (window + entry count) is the tightest per-pane
            // lower bound — enough to reject absurd counts before
            // allocating
            if r.remaining() < n_panes.saturating_mul(12) {
                return Err(WireError::Truncated);
            }
            let mut panes = Vec::with_capacity(n_panes);
            for _ in 0..n_panes {
                let window = r.u64()?;
                let n = r.u32()? as usize;
                if r.remaining() < n.saturating_mul(16) {
                    return Err(WireError::Truncated);
                }
                let mut entries = Vec::with_capacity(n);
                for _ in 0..n {
                    entries.push((r.u64()?, r.u64()?));
                }
                panes.push((window, entries));
            }
            Ok(Frame::Flush(FlushMsg { worker, seq, emit_ns, watermark, panes }))
        }
        KIND_CREDIT => Ok(Frame::Credit(r.u64()?)),
        KIND_HELLO => {
            let role = r.u8()?;
            let index = r.u64()?;
            let addr = r.str_u32()?;
            Ok(Frame::Hello { role, index, addr })
        }
        KIND_EOF => Ok(Frame::Eof),
        KIND_DONE => Ok(Frame::Done(payload.to_vec())),
        KIND_RESUME => {
            let worker = r.u64()?;
            let next_seq = r.u64()?;
            Ok(Frame::Resume { worker, next_seq })
        }
        other => Err(WireError::BadKind(other)),
    }
}

/// Decode one frame from the front of `bytes`; returns the frame and
/// the total bytes consumed (header + payload), so a caller can walk
/// a buffer of back-to-back frames.
pub fn decode_frame(bytes: &[u8]) -> Result<(Frame, usize), WireError> {
    let (kind, len) = parse_header(bytes)?;
    if bytes.len() < HEADER_LEN + len {
        return Err(WireError::Truncated);
    }
    let frame = decode_payload(kind, &bytes[HEADER_LEN..HEADER_LEN + len])?;
    Ok((frame, HEADER_LEN + len))
}

/// Read one frame from a blocking reader, reusing `scratch` for the
/// payload. Returns `Ok(None)` on a clean end-of-stream (EOF exactly
/// on a frame boundary); EOF in the middle of a frame is
/// [`WireError::Truncated`].
pub fn read_frame<R: Read>(r: &mut R, scratch: &mut Vec<u8>) -> Result<Option<Frame>, WireError> {
    let mut header = [0u8; HEADER_LEN];
    // read the first byte by hand so a clean close is distinguishable
    // from a mid-frame one
    loop {
        match r.read(&mut header[..1]) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    r.read_exact(&mut header[1..])?;
    let (kind, len) = parse_header(&header)?;
    scratch.clear();
    scratch.resize(len, 0);
    r.read_exact(scratch)?;
    decode_payload(kind, scratch).map(Some)
}

/// Number of stream tuples a frame carries (for the wire ledger).
pub fn frame_tuples(frame: &Frame) -> usize {
    match frame {
        Frame::Data(msgs) => msgs.len(),
        Frame::Flush(f) => f.panes.iter().map(|(_, entries)| entries.len()).sum(),
        // control frames carry no stream tuples; a new frame kind must
        // decide its tuple accounting here explicitly
        Frame::Credit(_)
        | Frame::Hello { .. }
        | Frame::Eof
        | Frame::Done(_)
        | Frame::Resume { .. } => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(encode: impl FnOnce(&mut Vec<u8>)) -> Frame {
        let mut buf = Vec::new();
        encode(&mut buf);
        let (frame, used) = decode_frame(&buf).expect("decode");
        assert_eq!(used, buf.len(), "frame must consume exactly its bytes");
        frame
    }

    #[test]
    fn data_frame_round_trips() {
        let msgs: Vec<Msg> = (0..17)
            .map(|i| Msg { key: i * 7, emit_ns: i * 1000, ts: i * 31 })
            .collect();
        match roundtrip(|b| encode_data(&msgs, b)) {
            Frame::Data(back) => assert_eq!(back, msgs),
            other => panic!("wrong frame: {other:?}"),
        }
        // empty batches are legal (loopback liveness probes)
        match roundtrip(|b| encode_data(&[], b)) {
            Frame::Data(back) => assert!(back.is_empty()),
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn flush_frame_round_trips_including_watermark_only() {
        let full = FlushMsg {
            worker: 3,
            seq: 41,
            emit_ns: 1_234_567,
            watermark: 999,
            panes: vec![(0, vec![(1, 5), (9, 2)]), (2, vec![(4, 1)])],
        };
        match roundtrip(|b| encode_flush(&full, b)) {
            Frame::Flush(back) => assert_eq!(back, full),
            other => panic!("wrong frame: {other:?}"),
        }
        let wm_only =
            FlushMsg { worker: 0, seq: u64::MAX, emit_ns: 7, watermark: u64::MAX, panes: vec![] };
        match roundtrip(|b| encode_flush(&wm_only, b)) {
            Frame::Flush(back) => assert_eq!(back, wm_only),
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn control_frames_round_trip() {
        assert_eq!(roundtrip(|b| encode_credit(42, b)), Frame::Credit(42));
        assert_eq!(roundtrip(encode_eof), Frame::Eof);
        assert_eq!(
            roundtrip(|b| encode_hello(2, 5, "tcp:127.0.0.1:9000", b)),
            Frame::Hello { role: 2, index: 5, addr: "tcp:127.0.0.1:9000".into() }
        );
        assert_eq!(
            roundtrip(|b| encode_done(&[9, 8, 7], b)),
            Frame::Done(vec![9, 8, 7])
        );
        assert_eq!(
            roundtrip(|b| encode_resume(4, 129, b)),
            Frame::Resume { worker: 4, next_seq: 129 }
        );
    }

    #[test]
    fn truncated_and_corrupt_frames_are_rejected() {
        let mut buf = Vec::new();
        encode_data(&[Msg { key: 1, emit_ns: 2, ts: 3 }], &mut buf);
        for cut in [0, 1, HEADER_LEN - 1, HEADER_LEN + 3, buf.len() - 1] {
            assert!(
                matches!(decode_frame(&buf[..cut]), Err(WireError::Truncated)),
                "cut at {cut} must be Truncated"
            );
        }
        let mut bad_magic = buf.clone();
        bad_magic[0] = b'X';
        assert!(matches!(decode_frame(&bad_magic), Err(WireError::BadMagic)));
        let mut bad_kind = buf.clone();
        bad_kind[5] = 99;
        assert!(matches!(decode_frame(&bad_kind), Err(WireError::BadKind(99))));
        // a data payload whose count field promises more tuples than
        // the payload holds is truncation, not a huge allocation
        let mut lying = Vec::new();
        encode_data(&[], &mut lying);
        lying[HEADER_LEN..HEADER_LEN + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode_frame(&lying), Err(WireError::Truncated)));
    }

    #[test]
    fn version_mismatch_is_reported() {
        let mut buf = Vec::new();
        encode_credit(1, &mut buf);
        buf[4] = VERSION + 1;
        match decode_frame(&buf) {
            Err(WireError::VersionMismatch { got, want }) => {
                assert_eq!(got, VERSION + 1);
                assert_eq!(want, VERSION);
            }
            other => panic!("expected version mismatch, got {other:?}"),
        }
    }

    #[test]
    fn back_to_back_frames_decode_sequentially() {
        let mut buf = Vec::new();
        encode_credit(1, &mut buf);
        encode_data(&[Msg { key: 5, emit_ns: 6, ts: 7 }], &mut buf);
        encode_eof(&mut buf);
        let mut off = 0;
        let mut frames = Vec::new();
        while off < buf.len() {
            let (frame, used) = decode_frame(&buf[off..]).expect("decode");
            frames.push(frame);
            off += used;
        }
        assert_eq!(frames.len(), 3);
        assert_eq!(frames[0], Frame::Credit(1));
        assert_eq!(frames[2], Frame::Eof);
    }

    #[test]
    fn read_frame_distinguishes_clean_eof_from_truncation() {
        let mut buf = Vec::new();
        encode_credit(3, &mut buf);
        let mut scratch = Vec::new();

        let mut clean = std::io::Cursor::new(buf.clone());
        assert_eq!(read_frame(&mut clean, &mut scratch).unwrap(), Some(Frame::Credit(3)));
        assert_eq!(read_frame(&mut clean, &mut scratch).unwrap(), None);

        let mut cut = std::io::Cursor::new(buf[..buf.len() - 2].to_vec());
        assert!(matches!(read_frame(&mut cut, &mut scratch), Err(WireError::Truncated)));
    }

    #[test]
    fn frame_tuples_counts_stream_tuples_only() {
        let data = Frame::Data(vec![Msg { key: 0, emit_ns: 0, ts: 0 }; 4]);
        assert_eq!(frame_tuples(&data), 4);
        let flush = Frame::Flush(FlushMsg {
            worker: 0,
            seq: 0,
            emit_ns: 0,
            watermark: 0,
            panes: vec![(0, vec![(1, 2), (2, 3)]), (1, vec![(1, 1)])],
        });
        assert_eq!(frame_tuples(&flush), 3);
        assert_eq!(frame_tuples(&Frame::Credit(10)), 0);
        assert_eq!(frame_tuples(&Frame::Resume { worker: 0, next_seq: 5 }), 0);
    }
}
