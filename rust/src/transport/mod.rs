//! Distributed transport subsystem: how tuples and flush batches move
//! between sources, workers and merge shards — in-process or across
//! process boundaries.
//!
//! The rt engine's two data paths (source→worker tuple lanes and
//! worker→shard flush lanes) are written against the four lane traits
//! here, so the same topology runs over any backend:
//!
//! - [`loopback`] — in-process `mpsc` channels plus shared atomic
//!   credit counters; byte-identical to the pre-transport engine and
//!   still the default.
//! - [`socket`] — UDS or TCP streams carrying the [`wire`]
//!   length-prefixed binary format, with per-peer credit windows
//!   (credits travel upstream as `Credit` frames) replacing the
//!   bounded-channel backpressure. The design mirrors
//!   timely-dataflow's `communication/` allocators: one duplex stream
//!   per peer pair, a reader thread per stream, send-side blocking on
//!   exhausted credit.
//! - [`launch`] — the multi-process launcher behind
//!   `fish deploy --processes N`: a coordinator spawns one process
//!   per worker and per shard, children bind data listeners and
//!   report them over a control connection, and results return as
//!   serialized `Done` frames.
//!
//! Merged counts, per-window snapshots and exact top-k are
//! transport-invariant: absorb order only perturbs sketch internals
//! and timing ledgers, never the oracle-compared outputs.

pub mod launch;
pub mod loopback;
pub mod socket;
pub mod wire;

pub use wire::{FlushMsg, Frame, Msg, WireError};

use std::fmt;
use std::io;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Why a lane send failed. Socket lanes surface the underlying I/O or
/// wire-decode failure; loopback lanes only ever report [`Closed`]
/// (the peer hung up). Senders treat every variant the same way —
/// stop streaming to that peer — but the variant carried makes deploy
/// failures diagnosable instead of a bare `false`.
///
/// [`Closed`]: LaneError::Closed
#[derive(Debug)]
pub enum LaneError {
    /// The socket write or read failed at the OS level.
    Io(io::Error),
    /// The peer sent bytes that do not decode as a frame.
    Wire(WireError),
    /// The peer closed its end of the lane (clean shutdown or drop).
    Closed,
    /// The peer sent a well-formed frame that this lane never carries
    /// (e.g. a `Data` frame arriving on a sender's credit channel).
    Protocol(&'static str),
}

impl fmt::Display for LaneError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LaneError::Io(e) => write!(f, "lane i/o error: {e}"),
            LaneError::Wire(e) => write!(f, "lane wire error: {e}"),
            LaneError::Closed => f.write_str("lane closed by peer"),
            LaneError::Protocol(what) => write!(f, "lane protocol violation: {what}"),
        }
    }
}

impl std::error::Error for LaneError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LaneError::Io(e) => Some(e),
            LaneError::Wire(e) => Some(e),
            LaneError::Closed | LaneError::Protocol(_) => None,
        }
    }
}

impl From<io::Error> for LaneError {
    fn from(e: io::Error) -> LaneError {
        LaneError::Io(e)
    }
}

impl From<WireError> for LaneError {
    fn from(e: WireError) -> LaneError {
        LaneError::Wire(e)
    }
}

/// Which lane implementation carries source→worker and worker→shard
/// traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// In-process channels + atomic credits (the classic engine).
    #[default]
    Loopback,
    /// Unix-domain stream sockets (unix only).
    Uds,
    /// TCP over 127.0.0.1.
    Tcp,
}

impl TransportKind {
    /// Parse a config/CLI spelling.
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "loopback" | "channel" => Some(TransportKind::Loopback),
            "uds" | "unix" => Some(TransportKind::Uds),
            "tcp" => Some(TransportKind::Tcp),
            _ => None,
        }
    }

    /// Canonical name (the `parse` spelling).
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Loopback => "loopback",
            TransportKind::Uds => "uds",
            TransportKind::Tcp => "tcp",
        }
    }
}

impl fmt::Display for TransportKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Timestamp source for emit/latency accounting. Single-process runs
/// share one monotonic epoch across threads; multi-process runs use
/// the unix clock against a coordinator-chosen epoch, so an emit
/// stamp taken in one process compares against a completion stamp
/// taken in another.
#[derive(Debug, Clone, Copy)]
pub enum Clock {
    /// Monotonic, relative to a process-local start instant.
    Mono(Instant),
    /// Unix wall clock, relative to a coordinator-chosen epoch (ns).
    Unix {
        /// Unix time (ns) all stamps are measured from.
        epoch_unix_ns: u64,
    },
}

impl Clock {
    /// Monotonic clock starting now.
    pub fn mono() -> Clock {
        Clock::Mono(Instant::now())
    }

    /// Unix-epoch clock against a coordinator-chosen epoch.
    pub fn unix(epoch_unix_ns: u64) -> Clock {
        Clock::Unix { epoch_unix_ns }
    }

    /// Current unix time in ns (0 if the system clock reads pre-1970).
    pub fn now_unix_ns() -> u64 {
        SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0)
    }

    /// Nanoseconds since this clock's epoch.
    pub fn now_ns(&self) -> u64 {
        match self {
            Clock::Mono(epoch) => epoch.elapsed().as_nanos() as u64,
            Clock::Unix { epoch_unix_ns } => Self::now_unix_ns().saturating_sub(*epoch_unix_ns),
        }
    }
}

/// What a tuple-lane receive produced.
#[derive(Debug)]
pub enum TupleRecv {
    /// A batch of routed tuples.
    Chunk(Vec<Msg>),
    /// The timeout elapsed with no chunk.
    Timeout,
    /// Every sender is gone and the queue is drained.
    Closed,
}

/// Source-side tuple lane endpoint (source → worker).
pub trait TupleTx: Send {
    /// Blocking, credit-gated send. Blocks while the peer's credit
    /// window is exhausted; errs when the receiver is gone or the
    /// lane broke (the source should stop streaming to it).
    fn send(&mut self, chunk: Vec<Msg>) -> Result<(), LaneError>;

    /// Signal end-of-stream (socket lanes write an `Eof` frame;
    /// loopback lanes rely on channel drop).
    fn close(&mut self) {}
}

/// Worker-side tuple lane endpoint (every source merged).
pub trait TupleRx: Send {
    /// Blocking receive; `None` timeout waits indefinitely.
    fn recv(&mut self, timeout: Option<Duration>) -> TupleRecv;

    /// Return `n` processed-tuple credits toward the sender of the
    /// most recently delivered chunk.
    fn ack(&mut self, n: usize);
}

/// Worker-side flush lane endpoint (worker → shard). Flush traffic is
/// low-rate (bounded by the flush cadence) and rides uncredited.
pub trait FlushTx: Send {
    /// Send one flush batch; errs when the shard is gone.
    fn send(&mut self, msg: FlushMsg) -> Result<(), LaneError>;

    /// Sequence number the first flush on this lane must carry. 0 on a
    /// fresh stream (loopback always); socket lanes report the shard's
    /// `Resume` answer, so a respawned worker continues exactly where
    /// its predecessor's stream left off.
    fn resume_from(&self) -> u64 {
        0
    }

    /// Flush any recovery/replay state and signal end-of-stream
    /// (socket lanes reconnect-and-replay if the shard died, then
    /// write `Eof`; loopback lanes rely on channel drop).
    fn close(&mut self) {}
}

/// Shard-side flush lane endpoint (every worker merged).
pub trait FlushRx: Send {
    /// Blocking receive; `None` once every worker closed its lane.
    fn recv(&mut self) -> Option<FlushMsg>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parses_and_prints() {
        assert_eq!(TransportKind::parse("loopback"), Some(TransportKind::Loopback));
        assert_eq!(TransportKind::parse("uds"), Some(TransportKind::Uds));
        assert_eq!(TransportKind::parse("unix"), Some(TransportKind::Uds));
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
        for kind in [TransportKind::Loopback, TransportKind::Uds, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(kind.name()), Some(kind));
            assert_eq!(format!("{kind}"), kind.name());
        }
        assert_eq!(TransportKind::default(), TransportKind::Loopback);
    }

    #[test]
    fn clocks_advance_monotonically() {
        let mono = Clock::mono();
        let a = mono.now_ns();
        let b = mono.now_ns();
        assert!(b >= a);

        let epoch = Clock::now_unix_ns();
        let unix = Clock::unix(epoch);
        let c = unix.now_ns();
        let d = unix.now_ns();
        assert!(d >= c);
        // an epoch in the future saturates to zero instead of wrapping
        let future = Clock::unix(u64::MAX);
        assert_eq!(future.now_ns(), 0);
    }
}
