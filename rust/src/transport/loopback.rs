//! In-process loopback lanes: `std::sync::mpsc` channels plus shared
//! atomic credit counters — exactly the mechanism the rt engine used
//! before the transport trait existed, so loopback runs stay
//! byte-identical to the pre-transport engine (and pay no
//! serialization cost; the wire ledger stays zero).

use super::wire::{FlushMsg, Msg};
use super::{FlushRx, FlushTx, LaneError, TupleRecv, TupleRx, TupleTx};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::time::Duration;

/// Source-side loopback endpoint. The credit window (`queue_depth`
/// in-flight tuples) is **per worker**, shared by every source
/// through one atomic counter — the same global bound the
/// pre-transport engine enforced.
pub struct LoopbackTupleTx {
    tx: SyncSender<Vec<Msg>>,
    inflight: Arc<AtomicUsize>,
    queue_depth: usize,
    spins: u64,
}

impl TupleTx for LoopbackTupleTx {
    fn send(&mut self, chunk: Vec<Msg>) -> Result<(), LaneError> {
        if chunk.is_empty() {
            return Ok(());
        }
        // credit spin: wait until the worker's in-flight window has
        // room, probing channel liveness occasionally so a dead
        // worker cannot hang the source forever.
        //
        // Ordering audit (the grant/ack pair, see docs/DETERMINISM.md):
        // this Acquire load pairs with the Release `fetch_sub` in
        // `ack()` — once the source observes the window open, it also
        // observes every write the worker made while processing the
        // acked tuples. Relaxed would let the credit return become
        // visible before those writes, reordering the window open past
        // the work it accounts for.
        while self.inflight.load(Ordering::Acquire) + chunk.len() > self.queue_depth {
            std::hint::spin_loop();
            self.spins = self.spins.wrapping_add(1);
            if self.spins % (1 << 20) == 0 && self.tx.send(Vec::new()).is_err() {
                return Err(LaneError::Closed);
            }
        }
        // AcqRel: the spend must neither float above the credit check
        // (Acquire half) nor below the channel send it pays for
        // (Release half) — otherwise two sources could both observe
        // room and overfill the window.
        self.inflight.fetch_add(chunk.len(), Ordering::AcqRel);
        if self.tx.send(chunk).is_err() {
            return Err(LaneError::Closed);
        }
        Ok(())
    }
}

/// Worker-side loopback endpoint.
pub struct LoopbackTupleRx {
    rx: Receiver<Vec<Msg>>,
    inflight: Arc<AtomicUsize>,
}

impl TupleRx for LoopbackTupleRx {
    fn recv(&mut self, timeout: Option<Duration>) -> TupleRecv {
        match timeout {
            Some(d) => match self.rx.recv_timeout(d) {
                Ok(chunk) => TupleRecv::Chunk(chunk),
                Err(RecvTimeoutError::Timeout) => TupleRecv::Timeout,
                Err(RecvTimeoutError::Disconnected) => TupleRecv::Closed,
            },
            None => match self.rx.recv() {
                Ok(chunk) => TupleRecv::Chunk(chunk),
                Err(_) => TupleRecv::Closed,
            },
        }
    }

    fn ack(&mut self, n: usize) {
        // Release: publishes the worker's processing of the acked
        // tuples to the Acquire credit check in `send` (the other
        // half of the grant/ack pair documented there).
        self.inflight.fetch_sub(n, Ordering::Release);
    }
}

/// Build the full source→worker loopback mesh: per worker, one
/// bounded channel and one shared credit counter; per source, one tx
/// clone per worker. Returns `(per-source tx vectors, per-worker
/// receivers)`.
pub fn tuple_lanes(
    n_sources: usize,
    n_workers: usize,
    queue_depth: usize,
) -> (Vec<Vec<Box<dyn TupleTx>>>, Vec<Box<dyn TupleRx>>) {
    let mut txs: Vec<Vec<Box<dyn TupleTx>>> =
        (0..n_sources).map(|_| Vec::with_capacity(n_workers)).collect();
    let mut rxs: Vec<Box<dyn TupleRx>> = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        let (tx, rx) = sync_channel::<Vec<Msg>>(queue_depth);
        let inflight = Arc::new(AtomicUsize::new(0));
        for src in txs.iter_mut() {
            src.push(Box::new(LoopbackTupleTx {
                tx: tx.clone(),
                inflight: Arc::clone(&inflight),
                queue_depth,
                spins: 0,
            }));
        }
        drop(tx);
        rxs.push(Box::new(LoopbackTupleRx { rx, inflight }));
    }
    (txs, rxs)
}

/// Worker-side loopback flush endpoint.
pub struct LoopbackFlushTx {
    tx: Sender<FlushMsg>,
}

impl FlushTx for LoopbackFlushTx {
    fn send(&mut self, msg: FlushMsg) -> Result<(), LaneError> {
        self.tx.send(msg).map_err(|_| LaneError::Closed)
    }
}

/// Shard-side loopback flush endpoint.
pub struct LoopbackFlushRx {
    rx: Receiver<FlushMsg>,
}

impl FlushRx for LoopbackFlushRx {
    fn recv(&mut self) -> Option<FlushMsg> {
        self.rx.recv().ok()
    }
}

/// Build the worker→shard loopback mesh: one unbounded channel per
/// shard, one tx clone per worker. Returns `(per-worker tx vectors,
/// per-shard receivers)`.
pub fn flush_lanes(
    n_workers: usize,
    n_shards: usize,
) -> (Vec<Vec<Box<dyn FlushTx>>>, Vec<Box<dyn FlushRx>>) {
    let mut txs: Vec<Vec<Box<dyn FlushTx>>> =
        (0..n_workers).map(|_| Vec::with_capacity(n_shards)).collect();
    let mut rxs: Vec<Box<dyn FlushRx>> = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        let (tx, rx) = channel::<FlushMsg>();
        for w in txs.iter_mut() {
            w.push(Box::new(LoopbackFlushTx { tx: tx.clone() }));
        }
        drop(tx);
        rxs.push(Box::new(LoopbackFlushRx { rx }));
    }
    (txs, rxs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tuple_lanes_deliver_and_credit() {
        let (mut txs, mut rxs) = tuple_lanes(2, 1, 8);
        let mut rx = rxs.remove(0);
        let chunk: Vec<Msg> = (0..3).map(|i| Msg { key: i, emit_ns: 0, ts: 0 }).collect();
        assert!(txs[0][0].send(chunk.clone()).is_ok());
        assert!(txs[1][0].send(chunk.clone()).is_ok());
        let mut got = 0;
        for _ in 0..2 {
            match rx.recv(None) {
                TupleRecv::Chunk(c) => {
                    got += c.len();
                    rx.ack(c.len());
                }
                other => panic!("expected chunk, got {other:?}"),
            }
        }
        assert_eq!(got, 6);
        drop(txs);
        assert!(matches!(rx.recv(None), TupleRecv::Closed));
        assert!(matches!(
            rx.recv(Some(Duration::from_millis(1))),
            TupleRecv::Closed
        ));
    }

    #[test]
    fn send_fails_once_the_worker_is_gone() {
        let (mut txs, rxs) = tuple_lanes(1, 1, 4);
        drop(rxs);
        assert!(matches!(
            txs[0][0].send(vec![Msg { key: 1, emit_ns: 0, ts: 0 }]),
            Err(LaneError::Closed)
        ));
    }

    #[test]
    fn flush_lanes_close_when_all_workers_drop() {
        let (mut txs, mut rxs) = flush_lanes(2, 1);
        let flush = FlushMsg { worker: 0, seq: 0, emit_ns: 1, watermark: 2, panes: vec![] };
        assert!(txs[0][0].send(flush.clone()).is_ok());
        assert!(txs[1][0].send(flush).is_ok());
        drop(txs);
        let mut rx = rxs.remove(0);
        assert!(rx.recv().is_some());
        assert!(rx.recv().is_some());
        assert!(rx.recv().is_none());
    }
}
