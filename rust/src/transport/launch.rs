//! Multi-process launcher: the machinery behind `fish deploy
//! --processes N`.
//!
//! The coordinator process keeps the sources (groupers need the trace
//! and the cluster view) and spawns one **worker** process per worker
//! and one **shard** process per merge shard, re-executing its own
//! binary with the hidden `__worker` / `__shard` subcommands. The
//! handshake is three moves over a control connection carrying the
//! same [`wire`] frames as the data path:
//!
//! 1. Shard children spawn first. Each binds its flush listener,
//!    connects back to the coordinator's control listener, and
//!    announces `Hello { role: 2, index, addr }`.
//! 2. Worker children spawn with the shard addresses on their command
//!    line. Each binds its tuple listener, says `Hello { role: 1 }`,
//!    connects a flush stream to every shard, and accepts one tuple
//!    stream per source.
//! 3. The coordinator connects the source→worker tuple streams and
//!    runs the source threads. From here the topology is exactly the
//!    in-process engine — the children run [`rt::worker_loop`] and
//!    [`rt::shard_loop`] verbatim — except every lane crosses a
//!    process boundary.
//!
//! When a child finishes it serializes its results (histograms,
//! merged windows, sketches, wire ledger, recovery counters) into an
//! opaque `Done` frame on the control connection; the coordinator
//! deserializes and assembles them with the same
//! [`rt::assemble_shards`] fold the threaded engine uses. Latency
//! stamps cross process boundaries via the unix [`Clock`] against a
//! coordinator-chosen epoch.
//!
//! **Chaos** (`--chaos kill-worker:<n>,kill-shard:<t>`): with a
//! [`ChaosPlan`] armed, every lane runs restart-aware — sources dial
//! workers through [`AddrCell`]s and keep unacked replay windows,
//! workers dial shards through [`AddrCell`]s and keep seq-stamped
//! flush logs, and shard children snapshot through the
//! [`ShardSnapshot`] codec on a cadence. A supervisor thread then
//! SIGKILLs the victim shard (and/or waits for the victim worker's
//! scripted crash), respawns the child re-executing this binary with
//! `--resume`, and relays the respawn's fresh address: `Hello{role:2}`
//! frames down the worker control connections for a shard, an
//! [`AddrCell`] bump for a worker. Replays, dedups, snapshot and
//! restore work, and coordinator-measured recovery wall time land in
//! [`RtResult::recovery`] (docs/RECOVERY.md).

use super::socket::{self, AddrCell, Duplex, Listener, SocketFlushTx, SocketTupleRx, SocketTupleTx};
use super::wire::{self, Frame, Reader, WireError};
use super::{Clock, FlushTx, TransportKind, TupleTx};
use crate::aggregate::{ShardRouter, TopKSketch, WindowResult, WindowedOutput};
use crate::coordinator::Grouper;
use crate::engine::rt::{self, RtOptions, RtResult};
use crate::metrics::{
    AggStats, Histogram, RecoveryLedger, RecoveryStats, WindowStats, WireLedger, WireStats,
};
use crate::obs::export::{blobs_read_from, blobs_to_bytes};
use crate::obs::sample::{samples_read_from, samples_to_bytes};
use crate::obs::{self, ClockDomain, Sample, Sampler, TraceBlob, TraceBuf, DEFAULT_INTERVAL_NS};
use crate::state::ShardSnapshot;
use crate::workload::Trace;
use std::io::{self, Write};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Pick the socket transport a multi-process run uses when the config
/// still says `loopback` (which cannot cross a process boundary).
pub fn process_kind(kind: TransportKind) -> TransportKind {
    match kind {
        TransportKind::Loopback => {
            if cfg!(unix) {
                TransportKind::Uds
            } else {
                TransportKind::Tcp
            }
        }
        k => k,
    }
}

/// Transport kind an address minted by [`socket::listen`] belongs to
/// (children derive their own listener kind from the control address).
fn kind_of_addr(addr: &str) -> TransportKind {
    if addr.starts_with("tcp:") {
        TransportKind::Tcp
    } else {
        TransportKind::Uds
    }
}

fn wire_io(e: WireError) -> io::Error {
    match e {
        WireError::Io(e) => e,
        other => io::Error::new(io::ErrorKind::InvalidData, other.to_string()),
    }
}

fn proto_err(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

// ---- tiny `--key value` argv parser for the child subcommands -------

fn arg<'a>(args: &'a [String], key: &str) -> io::Result<&'a str> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
        .ok_or_else(|| proto_err(format!("missing child argument {key}")))
}

fn arg_u64(args: &[String], key: &str) -> io::Result<u64> {
    arg(args, key)?
        .parse::<u64>()
        .map_err(|e| proto_err(format!("bad child argument {key}: {e}")))
}

fn arg_opt<'a>(args: &'a [String], key: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn arg_opt_u64(args: &[String], key: &str) -> io::Result<Option<u64>> {
    match arg_opt(args, key) {
        None => Ok(None),
        Some(v) => v
            .parse::<u64>()
            .map(Some)
            .map_err(|e| proto_err(format!("bad child argument {key}: {e}"))),
    }
}

// ---- chaos plan ------------------------------------------------------

/// Snapshot cadence (accepted flush batches) shard children run at
/// while chaos is armed.
pub const CHAOS_SNAPSHOT_EVERY: u64 = 8;

/// Flush rounds a `kill-worker:mid` victim survives before its
/// scripted crash.
const KILL_WORKER_MID_FLUSHES: u64 = 2;

/// Wall delay a `kill-shard:mid` uses when the stream length is
/// unknown (unpaced sources).
const KILL_SHARD_FALLBACK_NS: u64 = 10_000_000;

/// Parsed `--chaos` spec: which scripted kills a deploy run performs.
/// `Default` (both `None`) means fault-free — every lane then runs the
/// plain non-logging path.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChaosPlan {
    /// Worker 0 crashes cooperatively after this many flush rounds
    /// (`kill-worker:<n>`; `mid` = after 2 rounds).
    pub kill_worker_after_flushes: Option<u64>,
    /// Shard 0 is killed this many wall ns after the sources start
    /// (`kill-shard:<ms>`; `mid` = half the paced stream duration).
    pub kill_shard_after_ns: Option<u64>,
}

impl ChaosPlan {
    /// Whether any kill is scripted (gates all recovery machinery).
    pub fn armed(&self) -> bool {
        self.kill_worker_after_flushes.is_some() || self.kill_shard_after_ns.is_some()
    }

    /// Parse a `--chaos` spec: comma-separated `kill-worker:<n|mid>` /
    /// `kill-shard:<ms|mid>` entries. `stream_ns` is the paced stream
    /// duration (`tuples × interarrival`), which anchors `mid`; 0 means
    /// unpaced and `kill-shard:mid` falls back to a fixed early delay.
    pub fn parse(spec: &str, stream_ns: u64) -> Result<ChaosPlan, String> {
        let mut plan = ChaosPlan::default();
        for entry in spec.split(',').filter(|e| !e.is_empty()) {
            let (kind, val) = entry
                .split_once(':')
                .ok_or_else(|| format!("chaos entry `{entry}` is not kind:value"))?;
            match kind {
                "kill-worker" => {
                    let n = if val == "mid" {
                        KILL_WORKER_MID_FLUSHES
                    } else {
                        val.parse::<u64>()
                            .map_err(|e| format!("bad kill-worker count `{val}`: {e}"))?
                    };
                    plan.kill_worker_after_flushes = Some(n.max(1));
                }
                "kill-shard" => {
                    let ns = if val == "mid" {
                        if stream_ns > 0 {
                            stream_ns / 2
                        } else {
                            KILL_SHARD_FALLBACK_NS
                        }
                    } else {
                        val.parse::<u64>()
                            .map_err(|e| format!("bad kill-shard delay `{val}`: {e}"))?
                            .saturating_mul(1_000_000)
                    };
                    plan.kill_shard_after_ns = Some(ns);
                }
                other => return Err(format!("unknown chaos kind `{other}`")),
            }
        }
        Ok(plan)
    }
}

// ---- Done-payload serialization -------------------------------------
// Opaque blobs inside `Done` frames; the coordinator and the children
// are always the same binary, so this format needs no versioning
// beyond the wire header's.

fn put_histogram(h: &Histogram, buf: &mut Vec<u8>) {
    let mut hb = Vec::new();
    h.to_bytes(&mut hb);
    wire::put_u32(buf, hb.len() as u32);
    buf.extend_from_slice(&hb);
}

fn get_histogram(r: &mut Reader) -> Result<Histogram, WireError> {
    let len = r.u32()? as usize;
    let bytes = r.take(len)?;
    Histogram::from_bytes(bytes).ok_or(WireError::Truncated)
}

fn put_sketch(s: &TopKSketch, buf: &mut Vec<u8>) {
    wire::put_u32(buf, s.capacity() as u32);
    let entries: Vec<(crate::Key, f64)> = s.tracked().collect();
    wire::put_u32(buf, entries.len() as u32);
    for (key, est) in entries {
        wire::put_u64(buf, key);
        wire::put_f64(buf, est);
    }
    wire::put_f64(buf, s.merged_error());
}

fn get_sketch(r: &mut Reader) -> Result<TopKSketch, WireError> {
    let capacity = r.u32()? as usize;
    let n = r.u32()? as usize;
    if r.remaining() < n.saturating_mul(16) {
        return Err(WireError::Truncated);
    }
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        let key = r.u64()?;
        let est = r.f64()?;
        entries.push((key, est));
    }
    let merged_error = r.f64()?;
    Ok(TopKSketch::from_parts(capacity, &entries, merged_error))
}

fn put_counts(counts: &[(crate::Key, u64)], buf: &mut Vec<u8>) {
    wire::put_u32(buf, counts.len() as u32);
    for &(k, c) in counts {
        wire::put_u64(buf, k);
        wire::put_u64(buf, c);
    }
}

fn get_counts(r: &mut Reader) -> Result<Vec<(crate::Key, u64)>, WireError> {
    let n = r.u32()? as usize;
    if r.remaining() < n.saturating_mul(16) {
        return Err(WireError::Truncated);
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let k = r.u64()?;
        let c = r.u64()?;
        out.push((k, c));
    }
    Ok(out)
}

fn put_wire_stats(w: &WireStats, buf: &mut Vec<u8>) {
    for v in [
        w.frames_out,
        w.bytes_out,
        w.tuples_out,
        w.encode_ns,
        w.frames_in,
        w.bytes_in,
        w.tuples_in,
        w.decode_ns,
    ] {
        wire::put_u64(buf, v);
    }
}

fn get_wire_stats(r: &mut Reader) -> Result<WireStats, WireError> {
    Ok(WireStats {
        frames_out: r.u64()?,
        bytes_out: r.u64()?,
        tuples_out: r.u64()?,
        encode_ns: r.u64()?,
        frames_in: r.u64()?,
        bytes_in: r.u64()?,
        tuples_in: r.u64()?,
        decode_ns: r.u64()?,
    })
}

fn put_recovery_stats(s: &RecoveryStats, buf: &mut Vec<u8>) {
    for v in [
        s.replayed_batches,
        s.deduped_batches,
        s.buffered_batches,
        s.replayed_tuples,
        s.snapshots,
        s.snapshot_bytes,
        s.restores,
        s.worker_restarts,
        s.shard_restarts,
        s.recovery_wall_ns,
    ] {
        wire::put_u64(buf, v);
    }
}

fn get_recovery_stats(r: &mut Reader) -> Result<RecoveryStats, WireError> {
    Ok(RecoveryStats {
        replayed_batches: r.u64()?,
        deduped_batches: r.u64()?,
        buffered_batches: r.u64()?,
        replayed_tuples: r.u64()?,
        snapshots: r.u64()?,
        snapshot_bytes: r.u64()?,
        restores: r.u64()?,
        worker_restarts: r.u64()?,
        shard_restarts: r.u64()?,
        recovery_wall_ns: r.u64()?,
    })
}

fn put_u64s(v: &[u64], buf: &mut Vec<u8>) {
    wire::put_u32(buf, v.len() as u32);
    for &x in v {
        wire::put_u64(buf, x);
    }
}

fn get_u64s(r: &mut Reader) -> Result<Vec<u64>, WireError> {
    let n = r.u32()? as usize;
    if r.remaining() < n.saturating_mul(8) {
        return Err(WireError::Truncated);
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(r.u64()?);
    }
    Ok(out)
}

/// What one worker child reports back.
struct WorkerDone {
    latency: Histogram,
    count: u64,
    state_len: usize,
    wire: WireStats,
    recovery: RecoveryStats,
    trace: Vec<TraceBlob>,
    samples: Vec<Sample>,
}

fn put_worker_done(d: &WorkerDone, buf: &mut Vec<u8>) {
    wire::put_u64(buf, d.count);
    wire::put_u64(buf, d.state_len as u64);
    put_histogram(&d.latency, buf);
    put_wire_stats(&d.wire, buf);
    put_recovery_stats(&d.recovery, buf);
    // trace + telemetry ride last, unconditionally (empty vecs encode
    // as a zero count), so truncation detection stays byte-precise
    blobs_to_bytes(&d.trace, buf);
    samples_to_bytes(&d.samples, buf);
}

fn get_worker_done(payload: &[u8]) -> Result<WorkerDone, WireError> {
    let mut r = Reader::new(payload);
    let count = r.u64()?;
    let state_len = r.u64()? as usize;
    let latency = get_histogram(&mut r)?;
    let wire = get_wire_stats(&mut r)?;
    let recovery = get_recovery_stats(&mut r)?;
    let trace = blobs_read_from(&mut r).ok_or(WireError::Truncated)?;
    let samples = samples_read_from(&mut r).ok_or(WireError::Truncated)?;
    Ok(WorkerDone { latency, count, state_len, wire, recovery, trace, samples })
}

/// What one shard child reports back: the exact [`rt::shard_loop`]
/// output, plus the child's wire ledger.
struct ShardDone {
    out: WindowedOutput,
    sketch: TopKSketch,
    lat: Histogram,
    absorbed: Vec<u64>,
    recovery: RecoveryStats,
    wire: WireStats,
    trace: Vec<TraceBlob>,
    samples: Vec<Sample>,
}

fn put_agg_stats(s: &AggStats, buf: &mut Vec<u8>) {
    for v in [s.flushes, s.messages, s.bytes, s.merge_ns, s.max_merge_ns] {
        wire::put_u64(buf, v);
    }
}

fn get_agg_stats(r: &mut Reader) -> Result<AggStats, WireError> {
    Ok(AggStats {
        flushes: r.u64()?,
        messages: r.u64()?,
        bytes: r.u64()?,
        merge_ns: r.u64()?,
        max_merge_ns: r.u64()?,
    })
}

fn put_window_stats(s: &WindowStats, buf: &mut Vec<u8>) {
    for v in [
        s.panes_opened,
        s.panes_retired,
        s.late_reopens,
        s.late_reopen_mass,
        s.max_open_panes,
        s.max_open_entries,
    ] {
        wire::put_u64(buf, v);
    }
}

fn get_window_stats(r: &mut Reader) -> Result<WindowStats, WireError> {
    Ok(WindowStats {
        panes_opened: r.u64()?,
        panes_retired: r.u64()?,
        late_reopens: r.u64()?,
        late_reopen_mass: r.u64()?,
        max_open_panes: r.u64()?,
        max_open_entries: r.u64()?,
    })
}

fn put_shard_done(d: &ShardDone, buf: &mut Vec<u8>) {
    wire::put_u32(buf, d.out.windows.len() as u32);
    for w in &d.out.windows {
        wire::put_u64(buf, w.window);
        put_counts(&w.counts, buf);
        put_sketch(&w.sketch, buf);
    }
    put_counts(&d.out.all_time, buf);
    put_agg_stats(&d.out.stats, buf);
    put_window_stats(&d.out.window_stats, buf);
    put_sketch(&d.sketch, buf);
    put_histogram(&d.lat, buf);
    put_u64s(&d.absorbed, buf);
    put_recovery_stats(&d.recovery, buf);
    put_wire_stats(&d.wire, buf);
    blobs_to_bytes(&d.trace, buf);
    samples_to_bytes(&d.samples, buf);
}

fn get_shard_done(payload: &[u8]) -> Result<ShardDone, WireError> {
    let mut r = Reader::new(payload);
    let n_windows = r.u32()? as usize;
    let mut windows = Vec::with_capacity(n_windows.min(payload.len() / 8 + 1));
    for _ in 0..n_windows {
        let window = r.u64()?;
        let counts = get_counts(&mut r)?;
        let sketch = get_sketch(&mut r)?;
        windows.push(WindowResult { window, counts, sketch });
    }
    let all_time = get_counts(&mut r)?;
    let stats = get_agg_stats(&mut r)?;
    let window_stats = get_window_stats(&mut r)?;
    let sketch = get_sketch(&mut r)?;
    let lat = get_histogram(&mut r)?;
    let absorbed = get_u64s(&mut r)?;
    let recovery = get_recovery_stats(&mut r)?;
    let wire = get_wire_stats(&mut r)?;
    let trace = blobs_read_from(&mut r).ok_or(WireError::Truncated)?;
    let samples = samples_read_from(&mut r).ok_or(WireError::Truncated)?;
    Ok(ShardDone {
        out: WindowedOutput { windows, all_time, stats, window_stats },
        sketch,
        lat,
        absorbed,
        recovery,
        wire,
        trace,
        samples,
    })
}

// ---- control-connection helpers --------------------------------------

fn read_hello(conn: &mut Duplex) -> io::Result<(u8, usize, String)> {
    let mut scratch = Vec::new();
    match wire::read_frame(conn, &mut scratch).map_err(wire_io)? {
        Some(Frame::Hello { role, index, addr }) => Ok((role, index as usize, addr)),
        Some(_) => Err(proto_err("expected Hello frame from child")),
        None => Err(proto_err("child exited before saying Hello")),
    }
}

fn read_done(conn: &mut Duplex) -> io::Result<Vec<u8>> {
    let mut scratch = Vec::new();
    match wire::read_frame(conn, &mut scratch).map_err(wire_io)? {
        Some(Frame::Done(payload)) => Ok(payload),
        Some(_) => Err(proto_err("expected Done frame from child")),
        None => Err(proto_err("child exited before reporting results")),
    }
}

fn send_hello(conn: &mut Duplex, role: u8, index: usize, addr: &str) -> io::Result<()> {
    let mut buf = Vec::new();
    wire::encode_hello(role, index as u64, addr, &mut buf);
    conn.write_all(&buf)?;
    conn.flush()
}

fn send_done(conn: &mut Duplex, payload: &[u8]) -> io::Result<()> {
    let mut buf = Vec::new();
    wire::encode_done(payload, &mut buf);
    conn.write_all(&buf)?;
    conn.flush()
}

// ---- child entry points ----------------------------------------------

/// Forward coordinator announcements of respawned shards into the
/// worker's shard [`AddrCell`]s: each `Hello { role: 2, index, addr }`
/// on the control stream bumps cell `index`, and the flush lanes'
/// reconnect loops pick the fresh address up mid-retry. Exits when the
/// coordinator closes the control connection.
fn shard_addr_relay(mut conn: Duplex, cells: Vec<AddrCell>) {
    let mut scratch = Vec::new();
    loop {
        match wire::read_frame(&mut conn, &mut scratch) {
            Ok(Some(Frame::Hello { role: 2, index, addr })) => {
                if let Some(cell) = cells.get(index as usize) {
                    cell.set(&addr);
                }
            }
            Ok(Some(_)) => continue,
            Ok(None) | Err(_) => return,
        }
    }
}

/// Entry point for the hidden `__worker` subcommand (argv after the
/// subcommand name). Runs [`rt::worker_loop`] against socket lanes and
/// reports a `Done` frame on the control connection. With `--recover 1`
/// the flush lanes are restart-aware (seq logs + [`AddrCell`] re-dial)
/// and a relay thread tracks shard respawns; `--crash-after-flushes N`
/// scripts the chaos victim's cooperative crash.
pub fn worker_child(args: &[String]) -> io::Result<()> {
    let control = arg(args, "--control")?.to_string();
    let index = arg_u64(args, "--index")? as usize;
    let n_sources = arg_u64(args, "--sources")? as usize;
    let cost = f64::from_bits(arg_u64(args, "--cost-bits")?);
    let agg_flush_ns = arg_u64(args, "--flush-ns")?;
    let agg_window_ns = arg_u64(args, "--window-ns")?;
    let queue_depth = arg_u64(args, "--queue")? as usize;
    let epoch = arg_u64(args, "--epoch")?;
    let shard_addrs: Vec<&str> = arg(args, "--shards")?.split(',').collect();
    let recover = arg_opt_u64(args, "--recover")?.unwrap_or(0) == 1;
    let crash_after_flushes = arg_opt_u64(args, "--crash-after-flushes")?;
    let traced = arg_opt_u64(args, "--trace")?.unwrap_or(0) == 1;

    let kind = kind_of_addr(&control);
    let (listener, addr) = socket::listen(kind, &format!("w{index}"))?;
    let mut control = Duplex::connect(&control)?;
    send_hello(&mut control, 1, index, &addr)?;

    let ledger = Arc::new(WireLedger::new());
    let recovery = Arc::new(RecoveryLedger::new());
    let mut flush_txs: Vec<Box<dyn FlushTx>> = Vec::with_capacity(shard_addrs.len());
    if recover {
        let cells: Vec<AddrCell> = shard_addrs.iter().map(|sa| AddrCell::new(sa)).collect();
        for cell in &cells {
            flush_txs.push(Box::new(SocketFlushTx::connect(
                cell,
                index as u64,
                Arc::clone(&ledger),
                Arc::clone(&recovery),
            )?));
        }
        let relay = control.try_clone()?;
        thread::spawn(move || shard_addr_relay(relay, cells));
    } else {
        for sa in &shard_addrs {
            let conn = Duplex::connect(sa)?;
            flush_txs.push(Box::new(SocketFlushTx::handshake(
                conn,
                index as u64,
                Arc::clone(&ledger),
            )?));
        }
    }
    let mut conns = Vec::with_capacity(n_sources);
    for _ in 0..n_sources {
        conns.push(listener.accept()?);
    }
    let rx = Box::new(SocketTupleRx::new(conns, queue_depth, &ledger)?);

    let router = ShardRouter::new(shard_addrs.len());
    let clock = Clock::unix(epoch);
    // pid 100+i mirrors the in-process engine's worker tid scheme, so
    // merged timelines read the same whichever engine produced them
    let pid = 100 + index as u32;
    let mut obs_buf = if traced {
        TraceBuf::active(pid, pid, ClockDomain::Wall)
    } else {
        TraceBuf::disabled()
    };
    let mut sampler = if traced {
        Sampler::active(pid, DEFAULT_INTERVAL_NS)
    } else {
        Sampler::disabled()
    };
    let (latency, count, state_len) = rt::worker_loop(
        index,
        cost,
        agg_flush_ns,
        agg_window_ns,
        clock,
        &router,
        rx,
        flush_txs,
        crash_after_flushes,
        &mut obs_buf,
        &mut sampler,
    );

    let done = WorkerDone {
        latency,
        count,
        state_len,
        wire: ledger.snapshot(),
        recovery: recovery.snapshot(),
        trace: if obs_buf.is_active() { vec![obs_buf.to_blob()] } else { Vec::new() },
        samples: sampler.into_samples(),
    };
    let mut payload = Vec::new();
    put_worker_done(&done, &mut payload);
    send_done(&mut control, &payload)
}

/// Entry point for the hidden `__shard` subcommand. Runs
/// [`rt::shard_loop`] against a socket flush lane and reports a `Done`
/// frame on the control connection. With `--snapshot-every N` /
/// `--snapshot-path P` the shard persists [`ShardSnapshot`]s on a
/// cadence; with `--resume 1` it loads the snapshot at `P` first (a
/// respawned victim rejoining the mesh) and answers the workers'
/// handshakes from the restored sequencer cursors, so every lane
/// replays exactly the `seq >= next_seq` suffix.
pub fn shard_child(args: &[String]) -> io::Result<()> {
    let control = arg(args, "--control")?.to_string();
    let index = arg_u64(args, "--index")? as usize;
    let n_workers = arg_u64(args, "--workers")? as usize;
    let agg_window_ns = arg_u64(args, "--window-ns")?;
    let agg_lateness_ns = arg_u64(args, "--lateness-ns")?;
    let epoch = arg_u64(args, "--epoch")?;
    let snapshot_every = arg_opt_u64(args, "--snapshot-every")?.unwrap_or(0);
    let snapshot_path = arg_opt(args, "--snapshot-path").map(PathBuf::from);
    let resume = arg_opt_u64(args, "--resume")?.unwrap_or(0) == 1;
    let traced = arg_opt_u64(args, "--trace")?.unwrap_or(0) == 1;

    // a respawned victim restores from its last persisted snapshot; a
    // victim killed before its first snapshot cold-starts (the workers
    // then replay their full logs — still exactly-once, just slower)
    let resume_snap: Option<ShardSnapshot> = if resume {
        match snapshot_path.as_ref().map(std::fs::read) {
            Some(Ok(bytes)) => ShardSnapshot::from_bytes(&bytes).ok(),
            _ => None,
        }
    } else {
        None
    };
    let resume_seqs =
        resume_snap.as_ref().map(|s| s.expected_seq.clone()).unwrap_or_else(|| vec![0; n_workers]);

    let kind = kind_of_addr(&control);
    let (listener, addr) = socket::listen(kind, &format!("s{index}"))?;
    let mut control = Duplex::connect(&control)?;
    send_hello(&mut control, 2, index, &addr)?;

    let ledger = Arc::new(WireLedger::new());
    let mut conns = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        conns.push(listener.accept()?);
    }
    let rx = Box::new(socket::SocketFlushRx::new(conns, resume_seqs, &ledger)?);

    let clock = Clock::unix(epoch);
    let recovery = Arc::new(RecoveryLedger::new());
    let ctl = rt::ShardControl {
        shard: index as u64,
        ledger: Arc::clone(&recovery),
        snapshot_every,
        snapshot_path,
        resume: resume_snap,
    };
    let pid = 200 + index as u32;
    let mut obs_buf = if traced {
        TraceBuf::active(pid, pid, ClockDomain::Wall)
    } else {
        TraceBuf::disabled()
    };
    let mut sampler = if traced {
        Sampler::active(pid, DEFAULT_INTERVAL_NS)
    } else {
        Sampler::disabled()
    };
    let out = rt::shard_loop(
        n_workers,
        agg_window_ns,
        agg_lateness_ns,
        clock,
        rx,
        ctl,
        &mut obs_buf,
        &mut sampler,
    );

    let done = ShardDone {
        out: out.out,
        sketch: out.sketch,
        lat: out.latency,
        absorbed: out.absorbed,
        recovery: out.recovery,
        wire: ledger.snapshot(),
        trace: if obs_buf.is_active() { vec![obs_buf.to_blob()] } else { Vec::new() },
        samples: sampler.into_samples(),
    };
    let mut payload = Vec::new();
    put_shard_done(&done, &mut payload);
    send_done(&mut control, &payload)
}

// ---- coordinator -----------------------------------------------------

fn spawn_child(bin: &std::path::Path, subcmd: &str, args: &[String]) -> io::Result<Child> {
    Command::new(bin)
        .arg(subcmd)
        .args(args)
        .stdin(Stdio::null())
        .spawn()
}

/// What the chaos supervisor hands back after its scripted kills: the
/// respawned children (joined with the originals at shutdown), the
/// fresh control connections to swap in for the victims' dangling
/// ones, and the coordinator-side recovery ledger (restart counts +
/// kill→rejoin wall time).
#[derive(Default)]
struct Supervision {
    children: Vec<Child>,
    worker_swap: Option<(usize, Duplex)>,
    shard_swap: Option<(usize, Duplex)>,
    stats: RecoveryStats,
    blobs: Vec<TraceBlob>,
}

/// Execute a [`ChaosPlan`] against live victims. Runs on its own
/// thread while the sources pump: waits out the worker victim's
/// cooperative crash (then respawns it and bumps its [`AddrCell`] so
/// the source lanes re-dial and replay), then hard-kills the shard
/// victim at its deadline (respawning it with `--resume 1` and
/// relaying the fresh address to every worker over the cloned control
/// connections). Kill→`Hello` wall time lands in
/// [`RecoveryStats::recovery_wall_ns`].
fn supervise(
    listener: Listener,
    bin: std::path::PathBuf,
    plan: ChaosPlan,
    worker_victim: Option<(Child, Vec<String>)>,
    shard_victim: Option<(Child, Vec<String>)>,
    worker_cells: Vec<AddrCell>,
    mut worker_controls: Vec<Duplex>,
    epoch_clock: Clock,
    traced: bool,
) -> io::Result<Supervision> {
    let begun = Instant::now();
    let mut sup = Supervision::default();
    // supervisor thread = coordinator pid 0, tid 1 (sources are 10+s)
    let mut obs_buf = if traced {
        TraceBuf::active(0, 1, ClockDomain::Wall)
    } else {
        TraceBuf::disabled()
    };

    if let Some((mut child, respawn_args)) = worker_victim {
        // cooperative crash: the victim exits at a flush boundary on
        // its own schedule — just reap it
        let _ = child.wait();
        let clock = Instant::now();
        obs_buf.instant("kill_worker", epoch_clock.now_ns());
        sup.stats.worker_restarts += 1;
        sup.children.push(spawn_child(&bin, "__worker", &respawn_args)?);
        let mut conn = listener.accept()?;
        let (role, index, addr) = read_hello(&mut conn)?;
        if role != 1 {
            return Err(proto_err(format!("expected respawned worker hello, got role {role}")));
        }
        if let Some(cell) = worker_cells.get(index) {
            cell.set(&addr);
        }
        // a later shard respawn must be announced on the NEW control
        // conn — the original's relay thread died with the victim
        if index < worker_controls.len() {
            if let Ok(fresh) = conn.try_clone() {
                worker_controls[index] = fresh;
            }
        }
        obs_buf.instant("worker_respawned", epoch_clock.now_ns());
        sup.stats.recovery_wall_ns += clock.elapsed().as_nanos() as u64;
        sup.worker_swap = Some((index, conn));
    }

    if let Some((mut child, respawn_args)) = shard_victim {
        let deadline = Duration::from_nanos(plan.kill_shard_after_ns.unwrap_or(0));
        if let Some(rest) = deadline.checked_sub(begun.elapsed()) {
            thread::sleep(rest);
        }
        let _ = child.kill();
        let _ = child.wait();
        let clock = Instant::now();
        obs_buf.instant("kill_shard", epoch_clock.now_ns());
        sup.stats.shard_restarts += 1;
        sup.children.push(spawn_child(&bin, "__shard", &respawn_args)?);
        let mut conn = listener.accept()?;
        let (role, index, addr) = read_hello(&mut conn)?;
        if role != 2 {
            return Err(proto_err(format!("expected respawned shard hello, got role {role}")));
        }
        // announce the respawn; a worker that already finished may have
        // closed its control stream, which is fine — ignore the error
        for wc in worker_controls.iter_mut() {
            let _ = send_hello(wc, 2, index, &addr);
        }
        obs_buf.instant("shard_respawned", epoch_clock.now_ns());
        sup.stats.recovery_wall_ns += clock.elapsed().as_nanos() as u64;
        sup.shard_swap = Some((index, conn));
    }

    if obs_buf.is_active() {
        sup.blobs.push(obs_buf.to_blob());
    }
    Ok(sup)
}

/// Run the topology as `n_workers + agg_shards` child processes plus
/// source threads in this one: the multi-process counterpart of
/// [`rt::run`], returning the same [`RtResult`]. The transport is
/// [`RtOptions::transport`] with `loopback` promoted to a socket kind
/// via [`process_kind`]. Merged counts, per-window snapshots and
/// exact top-k match the in-process engine for the same trace.
///
/// An armed [`ChaosPlan`] scripts mid-run kills: every worker gets
/// restart-aware lanes (`--recover 1`), shards snapshot on the
/// [`CHAOS_SNAPSHOT_EVERY`] cadence, and a supervisor thread executes
/// the kills and re-splices the respawned victims while the stream
/// keeps flowing. The result must still verify byte-identically
/// against the fault-free reference — that is the point.
pub fn run_multiprocess(
    trace: &Arc<Trace>,
    mut sources: Vec<Box<dyn Grouper>>,
    n_workers: usize,
    opts: &RtOptions,
    chaos: &ChaosPlan,
) -> io::Result<RtResult> {
    assert!(!sources.is_empty() && n_workers > 0);
    let kind = process_kind(opts.transport);
    let n_sources = sources.len();
    let n_shards = opts.agg_shards.max(1);
    let queue_depth = opts.queue_depth.max(1);
    let batch = opts.batch.max(1).min(queue_depth);
    let per_tuple = rt::per_tuple_table(opts, n_workers);
    let bin = std::env::current_exe()?;

    let epoch = Clock::now_unix_ns();
    let clock = Clock::unix(epoch);
    // one flag decides tracing for the whole fabric: children inherit
    // it via `--trace 1` and stamp against the shared epoch clock, so
    // the merged timeline is one aligned wall-clock domain
    let traced = obs::enabled();
    let (control_listener, control_addr) = socket::listen(kind, "ctl")?;

    // chaos wiring: victim indices are fixed (worker 0 / shard 0) so
    // runs are reproducible; all shards snapshot when a shard kill is
    // armed, and all workers get restart-aware lanes under any plan
    let kill_worker = chaos.kill_worker_after_flushes;
    let kill_shard = chaos.kill_shard_after_ns;
    let recover = chaos.armed();
    let snap_paths: Vec<std::path::PathBuf> = if kill_shard.is_some() {
        (0..n_shards)
            .map(|i| {
                std::env::temp_dir().join(format!("fish-snap-{}-s{i}.bin", std::process::id()))
            })
            .collect()
    } else {
        Vec::new()
    };

    // 1. shard children: spawn, then collect their Hello { addr }s
    let mut shard_children: Vec<Child> = Vec::with_capacity(n_shards);
    let mut shard_respawn: Vec<String> = Vec::new();
    for i in 0..n_shards {
        let mut args = vec![
            "--control".into(),
            control_addr.clone(),
            "--index".into(),
            i.to_string(),
            "--workers".into(),
            n_workers.to_string(),
            "--window-ns".into(),
            opts.agg_window_ns.to_string(),
            "--lateness-ns".into(),
            opts.agg_lateness_ns.to_string(),
            "--epoch".into(),
            epoch.to_string(),
        ];
        if traced {
            args.push("--trace".into());
            args.push("1".into());
        }
        if let Some(path) = snap_paths.get(i) {
            args.push("--snapshot-every".into());
            args.push(CHAOS_SNAPSHOT_EVERY.to_string());
            args.push("--snapshot-path".into());
            args.push(path.to_string_lossy().into_owned());
        }
        if i == 0 && kill_shard.is_some() {
            shard_respawn = args.clone();
            shard_respawn.push("--resume".into());
            shard_respawn.push("1".into());
        }
        shard_children.push(spawn_child(&bin, "__shard", &args)?);
    }
    let mut shard_conns: Vec<Option<Duplex>> = (0..n_shards).map(|_| None).collect();
    let mut shard_addrs: Vec<String> = vec![String::new(); n_shards];
    for _ in 0..n_shards {
        let mut conn = control_listener.accept()?;
        let (role, index, addr) = read_hello(&mut conn)?;
        if role != 2 || index >= n_shards {
            return Err(proto_err(format!("unexpected hello: role {role} index {index}")));
        }
        if shard_conns[index].is_some() {
            return Err(proto_err(format!("duplicate hello from shard {index}")));
        }
        shard_addrs[index] = addr;
        shard_conns[index] = Some(conn);
    }

    // 2. worker children: spawn with the shard addresses, collect Hellos
    let mut worker_children: Vec<Child> = Vec::with_capacity(n_workers);
    let mut worker_respawn: Vec<String> = Vec::new();
    for w in 0..n_workers {
        let mut args = vec![
            "--control".into(),
            control_addr.clone(),
            "--index".into(),
            w.to_string(),
            "--sources".into(),
            n_sources.to_string(),
            "--cost-bits".into(),
            per_tuple[w].to_bits().to_string(),
            "--flush-ns".into(),
            opts.agg_flush_ns.to_string(),
            "--window-ns".into(),
            opts.agg_window_ns.to_string(),
            "--queue".into(),
            queue_depth.to_string(),
            "--epoch".into(),
            epoch.to_string(),
            "--shards".into(),
            shard_addrs.join(","),
        ];
        if traced {
            args.push("--trace".into());
            args.push("1".into());
        }
        if recover {
            args.push("--recover".into());
            args.push("1".into());
        }
        if w == 0 {
            if let Some(n) = kill_worker {
                // the respawn must NOT crash again
                worker_respawn = args.clone();
                args.push("--crash-after-flushes".into());
                args.push(n.to_string());
            }
        }
        worker_children.push(spawn_child(&bin, "__worker", &args)?);
    }
    let mut worker_conns: Vec<Option<Duplex>> = (0..n_workers).map(|_| None).collect();
    let mut worker_addrs: Vec<String> = vec![String::new(); n_workers];
    for _ in 0..n_workers {
        let mut conn = control_listener.accept()?;
        let (role, index, addr) = read_hello(&mut conn)?;
        if role != 1 || index >= n_workers {
            return Err(proto_err(format!("unexpected hello: role {role} index {index}")));
        }
        if worker_conns[index].is_some() {
            return Err(proto_err(format!("duplicate hello from worker {index}")));
        }
        worker_addrs[index] = addr;
        worker_conns[index] = Some(conn);
    }

    // 3. hand the victims (index 0 each) and the control listener to
    // the supervisor; it executes the plan while the stream flows
    let coord_recovery = Arc::new(RecoveryLedger::new());
    let worker_cells: Vec<AddrCell> =
        worker_addrs.iter().map(|a| AddrCell::new(a)).collect();
    let supervisor = if recover {
        let worker_victim = if kill_worker.is_some() {
            Some((worker_children.remove(0), std::mem::take(&mut worker_respawn)))
        } else {
            None
        };
        let shard_victim = if kill_shard.is_some() {
            Some((shard_children.remove(0), std::mem::take(&mut shard_respawn)))
        } else {
            None
        };
        let mut worker_controls = Vec::with_capacity(n_workers);
        if shard_victim.is_some() {
            for (w, conn) in worker_conns.iter().enumerate() {
                let conn =
                    conn.as_ref().ok_or_else(|| proto_err(format!("worker {w} has no conn")))?;
                worker_controls.push(conn.try_clone()?);
            }
        }
        let plan = chaos.clone();
        let cells = worker_cells.clone();
        let bin = bin.clone();
        Some(thread::spawn(move || {
            supervise(
                control_listener,
                bin,
                plan,
                worker_victim,
                shard_victim,
                cells,
                worker_controls,
                clock,
                traced,
            )
        }))
    } else {
        None
    };

    // 4. sources stay home: one tuple stream per (source, worker) pair,
    // then the exact source_loop the threaded engine runs
    let ledger = Arc::new(WireLedger::new());
    let mut source_handles = Vec::with_capacity(n_sources);
    for (s, grouper) in sources.drain(..).enumerate() {
        let mut txs: Vec<Box<dyn TupleTx>> = Vec::with_capacity(n_workers);
        for (w, addr) in worker_addrs.iter().enumerate() {
            let conn = Duplex::connect(addr)?;
            if kill_worker.is_some() {
                txs.push(Box::new(SocketTupleTx::with_recovery(
                    conn,
                    queue_depth,
                    Arc::clone(&ledger),
                    worker_cells[w].clone(),
                    Arc::clone(&coord_recovery),
                )));
            } else {
                txs.push(Box::new(SocketTupleTx::new(conn, queue_depth, Arc::clone(&ledger))));
            }
        }
        let trace = Arc::clone(trace);
        let per_tuple = per_tuple.clone();
        let workers_list: Vec<usize> = (0..n_workers).collect();
        let gap = opts.interarrival_ns * n_sources as u64;
        source_handles.push(thread::spawn(move || {
            // coordinator pid 0; source tids 10+s match the in-process
            // engine's thread-id scheme
            let mut obs_buf = if traced {
                TraceBuf::active(0, 10 + s as u32, ClockDomain::Wall)
            } else {
                TraceBuf::disabled()
            };
            rt::source_loop(
                s,
                n_sources,
                grouper,
                &trace,
                batch,
                gap,
                clock,
                &per_tuple,
                &workers_list,
                txs,
                &mut obs_buf,
            );
            obs_buf
        }));
    }
    let mut trace_blobs: Vec<TraceBlob> = Vec::new();
    let mut samples: Vec<Sample> = Vec::new();
    for h in source_handles {
        let obs_buf = h.join().expect("source thread panicked");
        if obs_buf.is_active() {
            trace_blobs.push(obs_buf.to_blob());
        }
    }

    // 5. the supervisor has finished its plan by now (kills land
    // mid-stream); splice the respawned victims' control conns in so
    // the harvest reads their Done frames, not the dead originals'
    let mut sup = Supervision::default();
    if let Some(handle) = supervisor {
        sup = handle.join().map_err(|_| proto_err("supervisor thread panicked"))??;
    }
    if let Some((w, conn)) = sup.worker_swap.take() {
        worker_conns[w] = Some(conn);
    }
    if let Some((s, conn)) = sup.shard_swap.take() {
        shard_conns[s] = Some(conn);
    }
    trace_blobs.append(&mut sup.blobs);

    // 6. harvest: workers finish once the sources close, shards once
    // the workers drop their flush streams — read in causal order
    let mut wire = ledger.snapshot();
    let mut recovery = coord_recovery.snapshot();
    recovery.absorb(&sup.stats);
    let mut latency = Histogram::new();
    let mut counts = Vec::with_capacity(n_workers);
    let mut states = Vec::with_capacity(n_workers);
    for (w, conn) in worker_conns.iter_mut().enumerate() {
        let conn = conn
            .as_mut()
            .ok_or_else(|| proto_err(format!("worker {w} never said hello")))?;
        let done = get_worker_done(&read_done(conn)?).map_err(wire_io)?;
        latency.merge(&done.latency);
        counts.push(done.count);
        states.push(done.state_len);
        wire.absorb(&done.wire);
        recovery.absorb(&done.recovery);
        trace_blobs.extend(done.trace);
        samples.extend(done.samples);
    }
    let mut shard_outs = Vec::with_capacity(n_shards);
    for (s, conn) in shard_conns.iter_mut().enumerate() {
        let conn = conn
            .as_mut()
            .ok_or_else(|| proto_err(format!("shard {s} never said hello")))?;
        let done = get_shard_done(&read_done(conn)?).map_err(wire_io)?;
        wire.absorb(&done.wire);
        trace_blobs.extend(done.trace);
        samples.extend(done.samples);
        shard_outs.push(rt::ShardOutput {
            out: done.out,
            sketch: done.sketch,
            latency: done.lat,
            absorbed: done.absorbed,
            recovery: done.recovery,
        });
    }
    for child in shard_children.iter_mut().chain(&mut worker_children).chain(&mut sup.children) {
        let _ = child.wait();
    }
    for path in &snap_paths {
        let _ = std::fs::remove_file(path);
    }

    let assembled = rt::assemble_shards(opts.agg_window_ns, shard_outs);
    recovery.absorb(&assembled.recovery);
    if kill_worker.is_some() {
        // the victim's first incarnation died without reporting; its
        // Count partials make shard-side absorbed mass exactly the
        // tuples it processed across both lives (replays deduped)
        if let Some(&mass) = assembled.absorbed.first() {
            counts[0] = mass;
        }
    }
    let agg = assembled.shard_agg.total();
    let wall_ns = clock.now_ns();
    let total: u64 = counts.iter().sum();
    let entries: usize = states.iter().sum();
    let mut seen = std::collections::HashSet::new();
    for t in trace.tuples() {
        seen.insert(t.key);
    }

    Ok(RtResult {
        latency,
        worker_counts: counts,
        worker_state: states,
        wall_ns,
        throughput: total as f64 / (wall_ns as f64 / 1e9),
        entries,
        distinct_keys: seen.len(),
        merged: assembled.merged,
        agg,
        shard_agg: assembled.shard_agg,
        agg_latency: assembled.agg_latency,
        gather: assembled.gather,
        windows: assembled.windows,
        window_stats: assembled.window_stats,
        wire,
        recovery,
        trace_blobs,
        samples,
    })
}

/// Compare a multi-process (or socket-transport) run against an
/// in-process reference on every transport-invariant output: merged
/// counts, tuple totals, per-window snapshots and exact top-k.
/// Returns the first discrepancy as an error string (`deploy
/// --verify` prints it and exits nonzero).
pub fn verify_against_reference(run: &RtResult, reference: &RtResult) -> Result<(), String> {
    if run.merged != reference.merged {
        return Err(format!(
            "merged counts diverge: {} vs {} entries",
            run.merged.len(),
            reference.merged.len()
        ));
    }
    let (a, b): (u64, u64) =
        (run.worker_counts.iter().sum(), reference.worker_counts.iter().sum());
    if a != b {
        return Err(format!("tuple totals diverge: {a} vs {b}"));
    }
    if run.top_k(10) != reference.top_k(10) {
        return Err("top-10 diverges".into());
    }
    if run.windows.len() != reference.windows.len() {
        return Err(format!(
            "window counts diverge: {} vs {} panes",
            run.windows.len(),
            reference.windows.len()
        ));
    }
    for (w, r) in run.windows.iter().zip(&reference.windows) {
        if w.window != r.window || w.counts != r.counts {
            return Err(format!("window {} diverges", r.window));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn done_payloads_round_trip() {
        let mut lat = Histogram::new();
        for v in [10, 20, 30, 40_000] {
            lat.record(v);
        }
        let wire_stats = WireStats {
            frames_out: 7,
            bytes_out: 700,
            tuples_out: 70,
            encode_ns: 7_000,
            ..Default::default()
        };
        let recovery = RecoveryStats {
            replayed_batches: 3,
            deduped_batches: 2,
            replayed_tuples: 41,
            worker_restarts: 1,
            recovery_wall_ns: 5_000_000,
            ..Default::default()
        };
        let mut obs_buf = TraceBuf::active(100, 100, ClockDomain::Wall);
        obs_buf.span_seq("flush_send", 1_000, 2_000, 7);
        let blob = obs_buf.to_blob();
        let sample = Sample { src: 100, ts_ns: 5_000, tuples: 42, ..Sample::default() };
        let done = WorkerDone {
            latency: lat.clone(),
            count: 1234,
            state_len: 99,
            wire: wire_stats,
            recovery: recovery.clone(),
            trace: vec![blob.clone()],
            samples: vec![sample],
        };
        let mut payload = Vec::new();
        put_worker_done(&done, &mut payload);
        let back = get_worker_done(&payload).expect("round trip");
        assert_eq!(back.count, 1234);
        assert_eq!(back.state_len, 99);
        assert_eq!(back.latency.count(), 4);
        assert_eq!(back.wire.frames_out, 7);
        assert_eq!(back.wire.bytes_out, 700);
        assert_eq!(back.recovery.replayed_batches, 3);
        assert_eq!(back.recovery.replayed_tuples, 41);
        assert_eq!(back.recovery.recovery_wall_ns, 5_000_000);
        assert_eq!(back.trace, vec![blob]);
        assert_eq!(back.samples.len(), 1);
        assert_eq!(back.samples[0].src, 100);
        assert_eq!(back.samples[0].ts_ns, 5_000);
        assert_eq!(back.samples[0].tuples, 42);

        let mut sketch = TopKSketch::new(8);
        sketch.absorb(5, 50);
        sketch.absorb(9, 12);
        let out = WindowedOutput {
            windows: vec![WindowResult {
                window: 3,
                counts: vec![(1, 10), (5, 50)],
                sketch: sketch.clone(),
            }],
            all_time: vec![(1, 10), (5, 50), (9, 12)],
            stats: AggStats {
                flushes: 2,
                messages: 5,
                bytes: 80,
                merge_ns: 1_000,
                max_merge_ns: 900,
            },
            window_stats: WindowStats {
                panes_opened: 4,
                panes_retired: 4,
                late_reopens: 1,
                late_reopen_mass: 17,
                max_open_panes: 2,
                max_open_entries: 30,
            },
        };
        let done = ShardDone {
            out,
            sketch,
            lat,
            absorbed: vec![70, 0, 2],
            recovery,
            wire: WireStats::default(),
            trace: Vec::new(),
            samples: Vec::new(),
        };
        let mut payload = Vec::new();
        put_shard_done(&done, &mut payload);
        let back = get_shard_done(&payload).expect("round trip");
        assert_eq!(back.out.windows.len(), 1);
        assert_eq!(back.out.windows[0].window, 3);
        assert_eq!(back.out.windows[0].counts, vec![(1, 10), (5, 50)]);
        assert_eq!(back.out.all_time, vec![(1, 10), (5, 50), (9, 12)]);
        assert_eq!(back.out.stats.messages, 5);
        assert_eq!(back.out.window_stats.late_reopen_mass, 17);
        assert_eq!(back.sketch.capacity(), 8);
        assert_eq!(back.lat.count(), 4);
        assert_eq!(back.absorbed, vec![70, 0, 2]);
        assert_eq!(back.recovery.deduped_batches, 2);
        assert_eq!(back.recovery.worker_restarts, 1);
        assert!(back.trace.is_empty());
        assert!(back.samples.is_empty());

        // corrupting the payload surfaces as an error, not a panic
        assert!(get_shard_done(&payload[..payload.len() - 3]).is_err());
        assert!(get_worker_done(&payload[..2]).is_err());
    }

    #[test]
    fn chaos_plan_parses_kill_specs() {
        assert_eq!(ChaosPlan::parse("", 1_000_000_000), Ok(ChaosPlan::default()));
        assert!(!ChaosPlan::default().armed());

        let plan = ChaosPlan::parse("kill-worker:mid", 0).expect("parse");
        assert_eq!(plan.kill_worker_after_flushes, Some(KILL_WORKER_MID_FLUSHES));
        assert!(plan.armed());

        let plan = ChaosPlan::parse("kill-worker:0", 0).expect("parse");
        assert_eq!(plan.kill_worker_after_flushes, Some(1), "clamped to at least one flush");

        let plan = ChaosPlan::parse("kill-shard:mid", 2_000_000_000).expect("parse");
        assert_eq!(plan.kill_shard_after_ns, Some(1_000_000_000));
        let plan = ChaosPlan::parse("kill-shard:mid", 0).expect("parse");
        assert_eq!(plan.kill_shard_after_ns, Some(KILL_SHARD_FALLBACK_NS), "unpaced fallback");

        let plan =
            ChaosPlan::parse("kill-worker:3,kill-shard:25", 1_000_000_000).expect("parse");
        assert_eq!(plan.kill_worker_after_flushes, Some(3));
        assert_eq!(plan.kill_shard_after_ns, Some(25_000_000), "ms scaled to ns");

        assert!(ChaosPlan::parse("kill-gather:5", 0).is_err());
        assert!(ChaosPlan::parse("kill-worker:soon", 0).is_err());
    }

    #[test]
    fn process_kind_promotes_loopback_to_a_socket_transport() {
        assert_ne!(process_kind(TransportKind::Loopback), TransportKind::Loopback);
        assert_eq!(process_kind(TransportKind::Tcp), TransportKind::Tcp);
        assert_eq!(process_kind(TransportKind::Uds), TransportKind::Uds);
    }
}
