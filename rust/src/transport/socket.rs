//! Socket transport: UDS / TCP lanes with credit-based flow control.
//!
//! Each source→worker pair gets its own duplex stream with a credit
//! window of `queue_depth` tuples. The source spends credit as it
//! sends `Data` frames and, when the window is exhausted, blocks
//! reading `Credit` frames off the same stream; the worker returns
//! credit as it acks processed tuples, batched into quanta of half
//! the window so credit traffic stays constant per window, and always
//! flushes owed credit before blocking — which is what makes the
//! protocol deadlock-free. Worker→shard flush lanes are plain streams
//! without credits: flush traffic is low-rate and bounded by cadence.
//!
//! Flush lanes open with a `Hello`/`Resume` handshake: the worker
//! identifies itself, the shard answers with the next flush sequence
//! number it expects (0 on a fresh mesh, its snapshot cursor on a
//! recovered one). Endpoints built from an [`AddrCell`] are
//! restart-aware — they log what they send and, when the peer's
//! address generation moves or a write fails, re-dial and replay the
//! unacked suffix so a respawned peer converges on the exact stream
//! its predecessor was owed (docs/RECOVERY.md).
//!
//! Each receive side runs one reader thread per peer stream and
//! merges decoded frames into a single in-process queue, mirroring
//! timely-dataflow's per-peer recv threads.

use super::wire::{self, FlushMsg, Frame, Msg, WireError};
use super::{FlushRx, FlushTx, LaneError, TransportKind, TupleRecv, TupleRx, TupleTx};
use crate::aggregate::resume_cursor;
use crate::metrics::{RecoveryLedger, WireLedger};
use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Reconnect dial attempts before a restart-aware lane gives up on its
/// peer coming back (attempts × backoff ≈ the recovery deadline).
const RECONNECT_ATTEMPTS: u32 = 1_500;

/// Pause between reconnect dial attempts.
const RECONNECT_BACKOFF: Duration = Duration::from_millis(10);

/// A bidirectional byte stream over TCP or UDS.
#[derive(Debug)]
pub enum Duplex {
    /// TCP stream (Nagle disabled — frames are latency-sensitive).
    Tcp(TcpStream),
    /// Unix-domain stream.
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Duplex {
    /// Clone the underlying stream (shared file description, so one
    /// half can read while the other writes).
    pub fn try_clone(&self) -> io::Result<Duplex> {
        match self {
            Duplex::Tcp(s) => s.try_clone().map(Duplex::Tcp),
            #[cfg(unix)]
            Duplex::Unix(s) => s.try_clone().map(Duplex::Unix),
        }
    }

    /// Connect to an address minted by [`listen`] (`tcp:IP:PORT` or
    /// `uds:PATH`).
    pub fn connect(addr: &str) -> io::Result<Duplex> {
        if let Some(hostport) = addr.strip_prefix("tcp:") {
            let s = TcpStream::connect(hostport)?;
            let _ = s.set_nodelay(true);
            return Ok(Duplex::Tcp(s));
        }
        #[cfg(unix)]
        {
            if let Some(path) = addr.strip_prefix("uds:") {
                return UnixStream::connect(path).map(Duplex::Unix);
            }
        }
        Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("unsupported transport address: {addr}"),
        ))
    }
}

impl Read for Duplex {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Duplex::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Duplex::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Duplex {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Duplex::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Duplex::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Duplex::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Duplex::Unix(s) => s.flush(),
        }
    }
}

/// A listening socket plus its connect address. UDS listeners unlink
/// their socket file on drop.
pub enum Listener {
    /// TCP listener on 127.0.0.1.
    Tcp(TcpListener),
    /// Unix-domain listener and the path it owns.
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

static LISTENER_SEQ: AtomicU64 = AtomicU64::new(0);

/// Bind a fresh listener for `kind`: TCP on an OS-assigned 127.0.0.1
/// port, UDS on a unique socket path under the system temp dir.
/// Returns the listener and the address peers pass to
/// [`Duplex::connect`].
pub fn listen(kind: TransportKind, tag: &str) -> io::Result<(Listener, String)> {
    match kind {
        TransportKind::Loopback => Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            "loopback transport has no listener",
        )),
        TransportKind::Tcp => {
            let l = TcpListener::bind("127.0.0.1:0")?;
            let addr = format!("tcp:{}", l.local_addr()?);
            Ok((Listener::Tcp(l), addr))
        }
        TransportKind::Uds => {
            #[cfg(unix)]
            {
                let seq = LISTENER_SEQ.fetch_add(1, Ordering::Relaxed);
                let path = std::env::temp_dir()
                    .join(format!("fish-{}-{tag}-{seq}.sock", std::process::id()));
                let _ = std::fs::remove_file(&path);
                let l = UnixListener::bind(&path)?;
                let addr = format!("uds:{}", path.display());
                Ok((Listener::Unix(l, path), addr))
            }
            #[cfg(not(unix))]
            {
                let _ = tag;
                Err(io::Error::new(
                    io::ErrorKind::Unsupported,
                    "uds transport requires unix",
                ))
            }
        }
    }
}

impl Listener {
    /// Accept one peer connection.
    pub fn accept(&self) -> io::Result<Duplex> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nodelay(true);
                Ok(Duplex::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l, _) => l.accept().map(|(s, _)| Duplex::Unix(s)),
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Read one frame, charging payload decode time and traffic to
/// `ledger`. Clean EOF is `Ok(None)`.
fn read_frame_timed(
    conn: &mut Duplex,
    scratch: &mut Vec<u8>,
    ledger: &WireLedger,
) -> Result<Option<Frame>, WireError> {
    let mut header = [0u8; wire::HEADER_LEN];
    loop {
        match conn.read(&mut header[..1]) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    conn.read_exact(&mut header[1..])?;
    let (kind, len) = wire::parse_header(&header)?;
    scratch.clear();
    scratch.resize(len, 0);
    conn.read_exact(scratch)?;
    let t0 = Instant::now();
    let frame = wire::decode_payload(kind, scratch)?;
    ledger.record_in(
        (wire::HEADER_LEN + len) as u64,
        wire::frame_tuples(&frame) as u64,
        t0.elapsed().as_nanos() as u64,
    );
    Ok(Some(frame))
}

fn wire_to_io(e: WireError) -> io::Error {
    match e {
        WireError::Io(e) => e,
        other => io::Error::new(io::ErrorKind::InvalidData, format!("{other:?}")),
    }
}

/// A shared, restart-aware peer address. The coordinator (or a relay
/// thread fed by it) publishes a respawned peer's fresh listen address
/// with [`AddrCell::set`], which also bumps a generation counter; lane
/// endpoints compare the generation they connected under against the
/// cell's to learn — deterministically, without waiting for a socket
/// error — that the peer restarted and a reconnect/replay is due.
#[derive(Clone, Debug)]
pub struct AddrCell {
    inner: Arc<Mutex<(String, u64)>>,
}

impl AddrCell {
    /// Cell holding `addr` at generation 0.
    pub fn new(addr: &str) -> AddrCell {
        AddrCell { inner: Arc::new(Mutex::new((addr.to_string(), 0))) }
    }

    /// Publish a replacement address and bump the generation.
    pub fn set(&self, addr: &str) {
        let mut inner = self.lock();
        inner.0 = addr.to_string();
        inner.1 += 1;
    }

    /// Current address.
    pub fn get(&self) -> String {
        self.lock().0.clone()
    }

    /// Current generation (bumped once per [`AddrCell::set`]).
    pub fn generation(&self) -> u64 {
        self.lock().1
    }

    /// Address and generation, read together.
    pub fn snapshot(&self) -> (String, u64) {
        self.lock().clone()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, (String, u64)> {
        // a poisoned cell still holds a usable (addr, generation) pair
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Source-side socket endpoint for one source→worker stream.
///
/// Built with [`SocketTupleTx::with_recovery`] the lane survives a
/// worker respawn: every sent tuple is held in an unacked window until
/// the worker's credit (which acks processed tuples in FIFO order)
/// retires it, and when the worker's [`AddrCell`] generation moves or
/// the stream dies, the lane drains the old stream's final credits,
/// re-dials, and replays the unacked window into the fresh worker's
/// credit window.
pub struct SocketTupleTx {
    conn: Duplex,
    credit: usize,
    queue_depth: usize,
    buf: Vec<u8>,
    scratch: Vec<u8>,
    ledger: Arc<WireLedger>,
    closed: bool,
    addr: Option<AddrCell>,
    gen: u64,
    unacked: VecDeque<Msg>,
    recovery: Option<Arc<RecoveryLedger>>,
}

impl SocketTupleTx {
    /// Wrap a connected stream with an initial credit window of
    /// `queue_depth` tuples (the receive side must be built with the
    /// same depth). Chunks larger than the window can never be
    /// admitted; the engine clamps batch ≤ queue_depth.
    pub fn new(conn: Duplex, queue_depth: usize, ledger: Arc<WireLedger>) -> Self {
        SocketTupleTx {
            conn,
            credit: queue_depth.max(1),
            queue_depth,
            buf: Vec::new(),
            scratch: Vec::new(),
            ledger,
            closed: false,
            addr: None,
            gen: 0,
            unacked: VecDeque::new(),
            recovery: None,
        }
    }

    /// Like [`SocketTupleTx::new`], but restart-aware: `addr` is the
    /// worker's published address cell and `recovery` meters replays.
    pub fn with_recovery(
        conn: Duplex,
        queue_depth: usize,
        ledger: Arc<WireLedger>,
        addr: AddrCell,
        recovery: Arc<RecoveryLedger>,
    ) -> Self {
        let gen = addr.generation();
        let mut tx = SocketTupleTx::new(conn, queue_depth, ledger);
        tx.addr = Some(addr);
        tx.gen = gen;
        tx.recovery = Some(recovery);
        tx
    }

    /// The peer respawned since this lane last (re)connected.
    fn stale(&self) -> bool {
        match &self.addr {
            Some(cell) => cell.generation() != self.gen,
            None => false,
        }
    }

    /// Credit return: open the window and retire the acked prefix of
    /// the unacked replay window (credits ack processed tuples FIFO).
    fn grant(&mut self, n: u64) {
        self.credit += n as usize;
        let retire = (n as usize).min(self.unacked.len());
        for _ in 0..retire {
            self.unacked.pop_front();
        }
    }

    /// Credit-gated write of one chunk (no replay bookkeeping).
    fn transmit(&mut self, chunk: &[Msg]) -> Result<(), LaneError> {
        // window exhausted: block on the upstream credit channel
        // until the worker acknowledges enough processed tuples
        while self.credit < chunk.len() {
            match wire::read_frame(&mut self.conn, &mut self.scratch) {
                Ok(Some(Frame::Credit(n))) => self.grant(n),
                // the worker hung up before granting enough credit —
                // clean close either way, no more tuples can be sent
                Ok(Some(Frame::Eof)) | Ok(None) => {
                    self.closed = true;
                    return Err(LaneError::Closed);
                }
                // only Credit ever travels worker→source on this
                // stream; anything else is a peer bug
                Ok(Some(
                    Frame::Data(_) | Frame::Flush(_) | Frame::Hello { .. } | Frame::Done(_)
                    | Frame::Resume { .. },
                )) => {
                    self.closed = true;
                    return Err(LaneError::Protocol("non-credit frame on credit channel"));
                }
                Err(e) => {
                    self.closed = true;
                    return Err(LaneError::Wire(e));
                }
            }
        }
        let t0 = Instant::now();
        self.buf.clear();
        wire::encode_data(chunk, &mut self.buf);
        let encode_ns = t0.elapsed().as_nanos() as u64;
        self.ledger
            .record_out(self.buf.len() as u64, chunk.len() as u64, encode_ns);
        self.credit -= chunk.len();
        if let Err(e) = self.conn.write_all(&self.buf) {
            self.closed = true;
            return Err(LaneError::Io(e));
        }
        Ok(())
    }

    /// Drain the dying stream's last credit grants. Tuples the old
    /// worker processed at a flush boundary were already flushed
    /// downstream; their credits retire them from the unacked window
    /// so the replay cannot double-count them.
    fn drain_final_credits(&mut self) {
        loop {
            match wire::read_frame(&mut self.conn, &mut self.scratch) {
                Ok(Some(Frame::Credit(n))) => self.grant(n),
                Ok(Some(Frame::Eof)) | Ok(None) | Err(_) => break,
                Ok(Some(
                    Frame::Data(_) | Frame::Flush(_) | Frame::Hello { .. } | Frame::Done(_)
                    | Frame::Resume { .. },
                )) => break,
            }
        }
    }

    /// Re-dial the (possibly still respawning) worker and replay the
    /// unacked window into its fresh credit window.
    fn reconnect_and_replay(&mut self) -> Result<(), LaneError> {
        let cell = match &self.addr {
            Some(cell) => cell.clone(),
            None => return Err(LaneError::Closed),
        };
        self.drain_final_credits();
        let mut attempts = 0u32;
        loop {
            // re-read the cell every attempt: the coordinator may still
            // be respawning the worker, and the fresh address lands
            // mid-loop
            let (target, gen) = cell.snapshot();
            match Duplex::connect(&target) {
                Ok(conn) => {
                    self.conn = conn;
                    self.gen = gen;
                    break;
                }
                Err(e) => {
                    attempts += 1;
                    if attempts >= RECONNECT_ATTEMPTS {
                        return Err(LaneError::Io(e));
                    }
                    thread::sleep(RECONNECT_BACKOFF);
                }
            }
        }
        self.closed = false;
        self.credit = self.queue_depth.max(1);
        if let Some(r) = &self.recovery {
            r.record_replayed_tuples(self.unacked.len() as u64);
        }
        let backlog: Vec<Msg> = self.unacked.drain(..).collect();
        let step = self.queue_depth.max(1);
        let mut idx = 0;
        while idx < backlog.len() {
            let end = (idx + step).min(backlog.len());
            self.unacked.extend(backlog[idx..end].iter().cloned());
            if let Err(e) = self.transmit(&backlog[idx..end]) {
                // keep the unreplayed tail queued, in order, for the
                // next recovery round
                self.unacked.extend(backlog[end..].iter().cloned());
                return Err(e);
            }
            idx = end;
        }
        Ok(())
    }
}

impl TupleTx for SocketTupleTx {
    fn send(&mut self, chunk: Vec<Msg>) -> Result<(), LaneError> {
        if chunk.is_empty() {
            return Ok(());
        }
        if self.recovery.is_none() {
            if self.closed {
                return Err(LaneError::Closed);
            }
            return self.transmit(&chunk);
        }
        // restart-aware: remember the chunk until credit acks it, and
        // fail over to the respawned worker instead of erroring
        self.unacked.extend(chunk.iter().cloned());
        if self.closed || self.stale() {
            return self.reconnect_and_replay();
        }
        match self.transmit(&chunk) {
            Ok(()) => Ok(()),
            Err(_) => self.reconnect_and_replay(),
        }
    }

    fn close(&mut self) {
        if (self.closed || self.stale())
            && self.recovery.is_some()
            && self.reconnect_and_replay().is_err()
        {
            return;
        }
        if self.closed {
            return;
        }
        self.buf.clear();
        wire::encode_eof(&mut self.buf);
        if self.conn.write_all(&self.buf).is_err()
            && self.recovery.is_some()
            && self.reconnect_and_replay().is_ok()
        {
            // a respawned worker needs this source's end-of-stream too
            self.buf.clear();
            wire::encode_eof(&mut self.buf);
            let _ = self.conn.write_all(&self.buf);
        }
        let _ = self.conn.flush();
        self.closed = true;
    }
}

/// Worker-side socket endpoint merging every source stream. One
/// reader thread per stream decodes `Data` frames into a shared
/// queue; acks accumulate per stream and return upstream as `Credit`
/// frames.
pub struct SocketTupleRx {
    rx: Receiver<(usize, Vec<Msg>)>,
    conns: Vec<Duplex>,
    pending: Vec<usize>,
    last_conn: usize,
    quantum: usize,
    buf: Vec<u8>,
}

impl SocketTupleRx {
    /// Build from accepted per-source streams, spawning one reader
    /// thread per stream.
    pub fn new(
        conns: Vec<Duplex>,
        queue_depth: usize,
        ledger: &Arc<WireLedger>,
    ) -> io::Result<SocketTupleRx> {
        let (tx, rx) = channel::<(usize, Vec<Msg>)>();
        let mut write_halves = Vec::with_capacity(conns.len());
        for (id, conn) in conns.into_iter().enumerate() {
            write_halves.push(conn.try_clone()?);
            let tx = tx.clone();
            let ledger = Arc::clone(ledger);
            thread::spawn(move || {
                let mut conn = conn;
                let mut scratch = Vec::new();
                loop {
                    match read_frame_timed(&mut conn, &mut scratch, &ledger) {
                        Ok(Some(Frame::Data(msgs))) => {
                            if tx.send((id, msgs)).is_err() {
                                break;
                            }
                        }
                        // Eof frame or clean socket close ends this
                        // source's stream
                        Ok(Some(Frame::Eof)) | Ok(None) => break,
                        // frames that never travel source→worker: the
                        // peer is confused — stop reading from it
                        Ok(Some(
                            Frame::Flush(_) | Frame::Credit(_) | Frame::Hello { .. }
                            | Frame::Done(_) | Frame::Resume { .. },
                        )) => break,
                        // decode or i/o failure: the stream is dead
                        Err(_) => break,
                    }
                }
            });
        }
        drop(tx);
        let n = write_halves.len();
        Ok(SocketTupleRx {
            rx,
            conns: write_halves,
            pending: vec![0; n],
            last_conn: 0,
            quantum: queue_depth.max(2) / 2,
            buf: Vec::new(),
        })
    }

    fn flush_credit(&mut self, id: usize) {
        if self.pending[id] == 0 {
            return;
        }
        self.buf.clear();
        wire::encode_credit(self.pending[id] as u64, &mut self.buf);
        // a failed credit write means the source is gone; nothing to do
        let _ = self.conns[id].write_all(&self.buf);
        self.pending[id] = 0;
    }

    fn flush_all_credits(&mut self) {
        for id in 0..self.pending.len() {
            self.flush_credit(id);
        }
    }
}

impl TupleRx for SocketTupleRx {
    fn recv(&mut self, timeout: Option<Duration>) -> TupleRecv {
        // return owed credit before blocking so a window-starved
        // source can always make progress
        self.flush_all_credits();
        let delivered = match timeout {
            Some(d) => match self.rx.recv_timeout(d) {
                Ok(pair) => pair,
                Err(RecvTimeoutError::Timeout) => return TupleRecv::Timeout,
                Err(RecvTimeoutError::Disconnected) => return TupleRecv::Closed,
            },
            None => match self.rx.recv() {
                Ok(pair) => pair,
                Err(_) => return TupleRecv::Closed,
            },
        };
        self.last_conn = delivered.0;
        TupleRecv::Chunk(delivered.1)
    }

    fn ack(&mut self, n: usize) {
        self.pending[self.last_conn] += n;
        if self.pending[self.last_conn] >= self.quantum {
            self.flush_credit(self.last_conn);
        }
    }
}

/// Worker-side socket endpoint for one worker→shard stream.
///
/// Every lane opens with a handshake: the worker identifies itself
/// with `Hello{role: 1, index}` and the shard answers
/// `Resume{next_seq}` — 0 on a fresh mesh, its snapshot cursor on a
/// recovered one. Lanes built with [`SocketFlushTx::connect`] are
/// restart-aware: each flush is appended to a replay log, and when the
/// shard's [`AddrCell`] generation moves or a write fails, the lane
/// re-dials, repeats the handshake, and replays exactly the
/// `seq >= next_seq` suffix of the log. The shard-side sequencer drops
/// anything it already absorbed, so over-replay is safe.
pub struct SocketFlushTx {
    conn: Duplex,
    buf: Vec<u8>,
    scratch: Vec<u8>,
    ledger: Arc<WireLedger>,
    worker: u64,
    /// The shard's `Resume` answer from the most recent handshake.
    next_seq: u64,
    addr: Option<AddrCell>,
    gen: u64,
    log: Vec<FlushMsg>,
    recovery: Option<Arc<RecoveryLedger>>,
}

impl SocketFlushTx {
    /// Wrap an already-connected stream as worker `worker` and run the
    /// handshake. The lane does not survive a shard restart.
    pub fn handshake(conn: Duplex, worker: u64, ledger: Arc<WireLedger>) -> io::Result<Self> {
        let mut tx = SocketFlushTx {
            conn,
            buf: Vec::new(),
            scratch: Vec::new(),
            ledger,
            worker,
            next_seq: 0,
            addr: None,
            gen: 0,
            log: Vec::new(),
            recovery: None,
        };
        tx.handshake_conn()?;
        Ok(tx)
    }

    /// Dial the shard through its [`AddrCell`], run the handshake, and
    /// arm restart recovery: flushes are logged and replayed across
    /// shard respawns, metered through `recovery`.
    pub fn connect(
        addr: &AddrCell,
        worker: u64,
        ledger: Arc<WireLedger>,
        recovery: Arc<RecoveryLedger>,
    ) -> io::Result<Self> {
        let (target, gen) = addr.snapshot();
        let conn = Duplex::connect(&target)?;
        let mut tx = SocketFlushTx {
            conn,
            buf: Vec::new(),
            scratch: Vec::new(),
            ledger,
            worker,
            next_seq: 0,
            addr: Some(addr.clone()),
            gen,
            log: Vec::new(),
            recovery: Some(recovery),
        };
        tx.handshake_conn()?;
        Ok(tx)
    }

    /// Identify this worker with a `Hello`, then read the shard's
    /// `Resume` answer into `next_seq`.
    fn handshake_conn(&mut self) -> io::Result<()> {
        self.buf.clear();
        // role 1 = worker: the shard must know which resume cursor this
        // stream belongs to before any flush arrives
        wire::encode_hello(1, self.worker, "", &mut self.buf);
        self.conn.write_all(&self.buf)?;
        self.conn.flush()?;
        match wire::read_frame(&mut self.conn, &mut self.scratch) {
            Ok(Some(Frame::Resume { worker, next_seq })) if worker == self.worker => {
                self.next_seq = next_seq;
                Ok(())
            }
            Ok(Some(
                Frame::Resume { .. } | Frame::Data(_) | Frame::Flush(_) | Frame::Credit(_)
                | Frame::Hello { .. } | Frame::Eof | Frame::Done(_),
            ))
            | Ok(None) => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "flush lane expected this worker's Resume handshake answer",
            )),
            Err(e) => Err(wire_to_io(e)),
        }
    }

    /// The shard respawned since this lane last (re)connected.
    fn stale(&self) -> bool {
        match &self.addr {
            Some(cell) => cell.generation() != self.gen,
            None => false,
        }
    }

    fn write_flush(&mut self, msg: &FlushMsg) -> Result<(), LaneError> {
        let t0 = Instant::now();
        self.buf.clear();
        wire::encode_flush(msg, &mut self.buf);
        let encode_ns = t0.elapsed().as_nanos() as u64;
        let tuples: usize = msg.panes.iter().map(|(_, e)| e.len()).sum();
        self.ledger
            .record_out(self.buf.len() as u64, tuples as u64, encode_ns);
        self.conn.write_all(&self.buf).map_err(LaneError::Io)
    }

    /// Re-dial the (possibly still respawning) shard, repeat the
    /// handshake, and replay the `seq >= next_seq` suffix of the log.
    fn reconnect_and_replay(&mut self) -> Result<(), LaneError> {
        let cell = match &self.addr {
            Some(cell) => cell.clone(),
            None => return Err(LaneError::Closed),
        };
        let mut attempts = 0u32;
        loop {
            // re-read the cell every attempt: the coordinator may still
            // be respawning the shard, and the fresh address lands
            // mid-loop
            let (target, gen) = cell.snapshot();
            match Duplex::connect(&target) {
                Ok(conn) => {
                    self.conn = conn;
                    self.gen = gen;
                    break;
                }
                Err(e) => {
                    attempts += 1;
                    if attempts >= RECONNECT_ATTEMPTS {
                        return Err(LaneError::Io(e));
                    }
                    thread::sleep(RECONNECT_BACKOFF);
                }
            }
        }
        self.handshake_conn().map_err(LaneError::Io)?;
        for i in 0..self.log.len() {
            if self.log[i].seq < self.next_seq {
                continue;
            }
            let msg = self.log[i].clone();
            self.write_flush(&msg)?;
            if let Some(r) = &self.recovery {
                r.record_replayed_batch();
            }
        }
        Ok(())
    }
}

impl FlushTx for SocketFlushTx {
    fn send(&mut self, msg: FlushMsg) -> Result<(), LaneError> {
        if self.recovery.is_none() {
            return self.write_flush(&msg);
        }
        self.log.push(msg);
        if self.stale() {
            return self.reconnect_and_replay();
        }
        let msg = self.log[self.log.len() - 1].clone();
        match self.write_flush(&msg) {
            Ok(()) => Ok(()),
            Err(_) => self.reconnect_and_replay(),
        }
    }

    fn resume_from(&self) -> u64 {
        self.next_seq
    }

    fn close(&mut self) {
        if self.stale() && self.reconnect_and_replay().is_err() {
            return;
        }
        self.buf.clear();
        wire::encode_eof(&mut self.buf);
        if self.conn.write_all(&self.buf).is_err()
            && self.recovery.is_some()
            && self.reconnect_and_replay().is_ok()
        {
            // a respawned shard needs this worker's end-of-stream too
            self.buf.clear();
            wire::encode_eof(&mut self.buf);
            let _ = self.conn.write_all(&self.buf);
        }
        let _ = self.conn.flush();
    }
}

/// Shard-side socket endpoint merging every worker stream.
///
/// Each accepted stream opens with the worker's `Hello`; the reader
/// thread answers `Resume{next_seq}` from `resume` (all zeros on a
/// fresh mesh; a recovered shard passes its snapshot's sequencer
/// cursors) before entering the flush loop. Workers may connect in any
/// order — the `Hello` index, not the accept order, selects the
/// cursor.
pub struct SocketFlushRx {
    rx: Receiver<FlushMsg>,
}

impl SocketFlushRx {
    /// Build from accepted per-worker streams, spawning one reader
    /// thread per stream. `resume[w]` is the next flush sequence
    /// number expected from worker `w`.
    pub fn new(
        conns: Vec<Duplex>,
        resume: Vec<u64>,
        ledger: &Arc<WireLedger>,
    ) -> io::Result<SocketFlushRx> {
        let (tx, rx) = channel::<FlushMsg>();
        for conn in conns {
            let tx = tx.clone();
            let ledger = Arc::clone(ledger);
            let resume = resume.clone();
            thread::spawn(move || {
                let mut conn = conn;
                let mut scratch = Vec::new();
                // handshake: the worker identifies itself; answer with
                // its resume cursor (handshake frames stay off the
                // wire ledger on both sides)
                let worker = match wire::read_frame(&mut conn, &mut scratch) {
                    Ok(Some(Frame::Hello { role: 1, index, .. })) => index,
                    // anything else is not a worker flush stream
                    Ok(Some(
                        Frame::Hello { .. } | Frame::Data(_) | Frame::Flush(_)
                        | Frame::Credit(_) | Frame::Eof | Frame::Done(_)
                        | Frame::Resume { .. },
                    ))
                    | Ok(None)
                    | Err(_) => return,
                };
                // the shared Resume rule: first seq this shard has not
                // absorbed, 0 for workers the cursors never covered
                let next = resume_cursor(&resume, worker as usize);
                let mut buf = Vec::new();
                wire::encode_resume(worker, next, &mut buf);
                if conn.write_all(&buf).is_err() {
                    return;
                }
                loop {
                    match read_frame_timed(&mut conn, &mut scratch, &ledger) {
                        Ok(Some(Frame::Flush(f))) => {
                            if tx.send(f).is_err() {
                                break;
                            }
                        }
                        // Eof frame or clean close ends this worker's
                        // flush stream
                        Ok(Some(Frame::Eof)) | Ok(None) => break,
                        // frames that never travel worker→shard
                        Ok(Some(
                            Frame::Data(_) | Frame::Credit(_) | Frame::Hello { .. }
                            | Frame::Done(_) | Frame::Resume { .. },
                        )) => break,
                        Err(_) => break,
                    }
                }
            });
        }
        Ok(SocketFlushRx { rx })
    }
}

impl FlushRx for SocketFlushRx {
    fn recv(&mut self) -> Option<FlushMsg> {
        self.rx.recv().ok()
    }
}

/// Build a full source→worker socket mesh inside one process: per
/// worker, bind a listener, then connect one client stream per source
/// and accept its server side. This is the loopback≡socket oracle
/// path — same engine, real sockets, no process spawn.
pub fn tuple_mesh(
    kind: TransportKind,
    n_sources: usize,
    n_workers: usize,
    queue_depth: usize,
    ledger: &Arc<WireLedger>,
) -> io::Result<(Vec<Vec<Box<dyn TupleTx>>>, Vec<Box<dyn TupleRx>>)> {
    let mut txs: Vec<Vec<Box<dyn TupleTx>>> =
        (0..n_sources).map(|_| Vec::with_capacity(n_workers)).collect();
    let mut rxs: Vec<Box<dyn TupleRx>> = Vec::with_capacity(n_workers);
    for w in 0..n_workers {
        let (listener, addr) = listen(kind, &format!("tup{w}"))?;
        let mut accepted = Vec::with_capacity(n_sources);
        for src in txs.iter_mut() {
            let client = Duplex::connect(&addr)?;
            accepted.push(listener.accept()?);
            src.push(Box::new(SocketTupleTx::new(client, queue_depth, Arc::clone(ledger))));
        }
        rxs.push(Box::new(SocketTupleRx::new(accepted, queue_depth, ledger)?));
    }
    Ok((txs, rxs))
}

/// Build the worker→shard socket mesh inside one process.
pub fn flush_mesh(
    kind: TransportKind,
    n_workers: usize,
    n_shards: usize,
    ledger: &Arc<WireLedger>,
) -> io::Result<(Vec<Vec<Box<dyn FlushTx>>>, Vec<Box<dyn FlushRx>>)> {
    let mut txs: Vec<Vec<Box<dyn FlushTx>>> =
        (0..n_workers).map(|_| Vec::with_capacity(n_shards)).collect();
    let mut rxs: Vec<Box<dyn FlushRx>> = Vec::with_capacity(n_shards);
    for s in 0..n_shards {
        let (listener, addr) = listen(kind, &format!("fl{s}"))?;
        let mut clients = Vec::with_capacity(n_workers);
        let mut accepted = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            clients.push(Duplex::connect(&addr)?);
            accepted.push(listener.accept()?);
        }
        // build the Rx first: its reader threads answer the blocking
        // Tx-side handshakes below, so this cannot deadlock
        rxs.push(Box::new(SocketFlushRx::new(accepted, vec![0; n_workers], ledger)?));
        for (w, client) in clients.into_iter().enumerate() {
            txs[w].push(Box::new(SocketFlushTx::handshake(
                client,
                w as u64,
                Arc::clone(ledger),
            )?));
        }
    }
    Ok((txs, rxs))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds() -> Vec<TransportKind> {
        #[cfg(unix)]
        {
            vec![TransportKind::Tcp, TransportKind::Uds]
        }
        #[cfg(not(unix))]
        {
            vec![TransportKind::Tcp]
        }
    }

    #[test]
    fn tuple_mesh_streams_under_credit_pressure() {
        for kind in kinds() {
            let ledger = Arc::new(WireLedger::new());
            let (mut txs, mut rxs) = tuple_mesh(kind, 1, 1, 4, &ledger).unwrap();
            let mut rx = rxs.pop().unwrap();
            // worker drains + acks everything on its own thread
            let handle = thread::spawn(move || {
                let mut total = 0usize;
                loop {
                    match rx.recv(None) {
                        TupleRecv::Chunk(chunk) => {
                            total += chunk.len();
                            rx.ack(chunk.len());
                        }
                        TupleRecv::Closed => break,
                        TupleRecv::Timeout => unreachable!(),
                    }
                }
                total
            });
            // 30 chunks of 3 tuples through a 4-tuple credit window
            // forces many credit round-trips
            let tx = &mut txs[0][0];
            for i in 0..30u64 {
                let chunk: Vec<Msg> =
                    (0..3).map(|j| Msg { key: i * 3 + j, emit_ns: 0, ts: 0 }).collect();
                assert!(tx.send(chunk).is_ok(), "send {i} failed for {kind}");
            }
            tx.close();
            drop(txs);
            assert_eq!(handle.join().unwrap(), 90, "{kind} lost tuples");
            let stats = ledger.snapshot();
            assert_eq!(stats.tuples_out, 90);
            assert_eq!(stats.tuples_in, 90);
            assert!(stats.bytes_out > 0 && stats.frames_out >= 30);
        }
    }

    #[test]
    fn flush_mesh_delivers_and_closes() {
        for kind in kinds() {
            let ledger = Arc::new(WireLedger::new());
            let (mut txs, mut rxs) = flush_mesh(kind, 2, 1, &ledger).unwrap();
            // fresh mesh: every lane's handshake resumes from 0
            assert_eq!(txs[0][0].resume_from(), 0);
            assert_eq!(txs[1][0].resume_from(), 0);
            let flush = FlushMsg {
                worker: 1,
                seq: 0,
                emit_ns: 5,
                watermark: 10,
                panes: vec![(0, vec![(7, 3)])],
            };
            assert!(txs[0][0].send(flush.clone()).is_ok());
            assert!(txs[1][0].send(flush.clone()).is_ok());
            drop(txs);
            let mut rx = rxs.pop().unwrap();
            let a = rx.recv().expect("first flush");
            let b = rx.recv().expect("second flush");
            assert_eq!(a.panes, flush.panes);
            assert_eq!(b.panes, flush.panes);
            assert!(rx.recv().is_none(), "{kind} flush lane failed to close");
        }
    }

    fn seq_flush(seq: u64) -> FlushMsg {
        FlushMsg {
            worker: 0,
            seq,
            emit_ns: seq,
            watermark: seq,
            panes: vec![(0, vec![(1, 1)])],
        }
    }

    #[test]
    fn flush_lane_replays_suffix_after_shard_restart() {
        for kind in kinds() {
            let ledger = Arc::new(WireLedger::new());
            let recovery = Arc::new(RecoveryLedger::new());
            let (listener, addr) = listen(kind, "fchaos").unwrap();
            let cell = AddrCell::new(&addr);
            let c_cell = cell.clone();
            let c_ledger = Arc::clone(&ledger);
            let c_recovery = Arc::clone(&recovery);
            let client = thread::spawn(move || {
                let mut tx =
                    SocketFlushTx::connect(&c_cell, 0, c_ledger, c_recovery).unwrap();
                assert_eq!(tx.resume_from(), 0);
                for seq in 0..3 {
                    tx.send(seq_flush(seq)).unwrap();
                }
                // the "coordinator" (main thread) respawns the shard
                while c_cell.generation() == 0 {
                    thread::sleep(Duration::from_millis(2));
                }
                // stale generation → reconnect, handshake, replay
                tx.send(seq_flush(3)).unwrap();
                tx.close();
            });
            let conn = listener.accept().unwrap();
            let mut rx = SocketFlushRx::new(vec![conn], vec![0], &ledger).unwrap();
            for want in 0..3 {
                assert_eq!(rx.recv().unwrap().seq, want, "{kind}");
            }
            // shard "dies" having durably absorbed only seq 0: the
            // respawn hands out resume cursor 1, so the worker must
            // replay 1 and 2 before delivering 3
            drop(rx);
            drop(listener);
            let (listener2, addr2) = listen(kind, "fchaos2").unwrap();
            cell.set(&addr2);
            let conn2 = listener2.accept().unwrap();
            let mut rx2 = SocketFlushRx::new(vec![conn2], vec![1], &ledger).unwrap();
            let mut seqs = Vec::new();
            while let Some(m) = rx2.recv() {
                seqs.push(m.seq);
            }
            assert_eq!(seqs, vec![1, 2, 3], "{kind} replayed the wrong suffix");
            client.join().unwrap();
            assert_eq!(recovery.snapshot().replayed_batches, 3);
        }
    }

    #[test]
    fn tuple_lane_replays_unacked_after_worker_restart() {
        for kind in kinds() {
            let ledger = Arc::new(WireLedger::new());
            let recovery = Arc::new(RecoveryLedger::new());
            let (listener, addr) = listen(kind, "tchaos").unwrap();
            let cell = AddrCell::new(&addr);
            let client = Duplex::connect(&cell.get()).unwrap();
            let server = listener.accept().unwrap();
            drop(listener);
            let mut tx = SocketTupleTx::with_recovery(
                client,
                8,
                Arc::clone(&ledger),
                cell.clone(),
                Arc::clone(&recovery),
            );
            // worker v1: absorb (and credit-ack) one chunk, then die
            let srv = thread::spawn(move || {
                let mut conn = server;
                let mut scratch = Vec::new();
                match wire::read_frame(&mut conn, &mut scratch) {
                    Ok(Some(Frame::Data(msgs))) => {
                        let mut buf = Vec::new();
                        wire::encode_credit(msgs.len() as u64, &mut buf);
                        conn.write_all(&buf).unwrap();
                        msgs.len()
                    }
                    other => panic!("expected data, got {other:?}"),
                }
            });
            let chunk = |lo: u64, hi: u64| -> Vec<Msg> {
                (lo..hi).map(|key| Msg { key, emit_ns: 0, ts: 0 }).collect()
            };
            tx.send(chunk(0, 3)).unwrap();
            assert_eq!(srv.join().unwrap(), 3);
            // worker v2 on a fresh address; the acked chunk must not be
            // replayed, the unacked one must
            let (listener2, addr2) = listen(kind, "tchaos2").unwrap();
            cell.set(&addr2);
            let srv2 = thread::spawn(move || {
                let mut conn = listener2.accept().unwrap();
                let mut scratch = Vec::new();
                let mut keys = Vec::new();
                loop {
                    match wire::read_frame(&mut conn, &mut scratch) {
                        Ok(Some(Frame::Data(msgs))) => {
                            keys.extend(msgs.iter().map(|m| m.key));
                        }
                        Ok(Some(Frame::Eof)) | Ok(None) => break,
                        other => panic!("unexpected frame: {other:?}"),
                    }
                }
                keys
            });
            tx.send(chunk(3, 6)).unwrap();
            tx.close();
            assert_eq!(srv2.join().unwrap(), vec![3, 4, 5], "{kind}");
            assert_eq!(recovery.snapshot().replayed_tuples, 3);
        }
    }
}
