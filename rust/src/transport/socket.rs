//! Socket transport: UDS / TCP lanes with credit-based flow control.
//!
//! Each source→worker pair gets its own duplex stream with a credit
//! window of `queue_depth` tuples. The source spends credit as it
//! sends `Data` frames and, when the window is exhausted, blocks
//! reading `Credit` frames off the same stream; the worker returns
//! credit as it acks processed tuples, batched into quanta of half
//! the window so credit traffic stays constant per window, and always
//! flushes owed credit before blocking — which is what makes the
//! protocol deadlock-free. Worker→shard flush lanes are plain streams
//! without credits: flush traffic is low-rate and bounded by cadence.
//!
//! Each receive side runs one reader thread per peer stream and
//! merges decoded frames into a single in-process queue, mirroring
//! timely-dataflow's per-peer recv threads.

use super::wire::{self, FlushMsg, Frame, Msg, WireError};
use super::{FlushRx, FlushTx, LaneError, TransportKind, TupleRecv, TupleRx, TupleTx};
use crate::metrics::WireLedger;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// A bidirectional byte stream over TCP or UDS.
#[derive(Debug)]
pub enum Duplex {
    /// TCP stream (Nagle disabled — frames are latency-sensitive).
    Tcp(TcpStream),
    /// Unix-domain stream.
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Duplex {
    /// Clone the underlying stream (shared file description, so one
    /// half can read while the other writes).
    pub fn try_clone(&self) -> io::Result<Duplex> {
        match self {
            Duplex::Tcp(s) => s.try_clone().map(Duplex::Tcp),
            #[cfg(unix)]
            Duplex::Unix(s) => s.try_clone().map(Duplex::Unix),
        }
    }

    /// Connect to an address minted by [`listen`] (`tcp:IP:PORT` or
    /// `uds:PATH`).
    pub fn connect(addr: &str) -> io::Result<Duplex> {
        if let Some(hostport) = addr.strip_prefix("tcp:") {
            let s = TcpStream::connect(hostport)?;
            let _ = s.set_nodelay(true);
            return Ok(Duplex::Tcp(s));
        }
        #[cfg(unix)]
        {
            if let Some(path) = addr.strip_prefix("uds:") {
                return UnixStream::connect(path).map(Duplex::Unix);
            }
        }
        Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("unsupported transport address: {addr}"),
        ))
    }
}

impl Read for Duplex {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Duplex::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Duplex::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Duplex {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Duplex::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Duplex::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Duplex::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Duplex::Unix(s) => s.flush(),
        }
    }
}

/// A listening socket plus its connect address. UDS listeners unlink
/// their socket file on drop.
pub enum Listener {
    /// TCP listener on 127.0.0.1.
    Tcp(TcpListener),
    /// Unix-domain listener and the path it owns.
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

static LISTENER_SEQ: AtomicU64 = AtomicU64::new(0);

/// Bind a fresh listener for `kind`: TCP on an OS-assigned 127.0.0.1
/// port, UDS on a unique socket path under the system temp dir.
/// Returns the listener and the address peers pass to
/// [`Duplex::connect`].
pub fn listen(kind: TransportKind, tag: &str) -> io::Result<(Listener, String)> {
    match kind {
        TransportKind::Loopback => Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            "loopback transport has no listener",
        )),
        TransportKind::Tcp => {
            let l = TcpListener::bind("127.0.0.1:0")?;
            let addr = format!("tcp:{}", l.local_addr()?);
            Ok((Listener::Tcp(l), addr))
        }
        TransportKind::Uds => {
            #[cfg(unix)]
            {
                let seq = LISTENER_SEQ.fetch_add(1, Ordering::Relaxed);
                let path = std::env::temp_dir()
                    .join(format!("fish-{}-{tag}-{seq}.sock", std::process::id()));
                let _ = std::fs::remove_file(&path);
                let l = UnixListener::bind(&path)?;
                let addr = format!("uds:{}", path.display());
                Ok((Listener::Unix(l, path), addr))
            }
            #[cfg(not(unix))]
            {
                let _ = tag;
                Err(io::Error::new(
                    io::ErrorKind::Unsupported,
                    "uds transport requires unix",
                ))
            }
        }
    }
}

impl Listener {
    /// Accept one peer connection.
    pub fn accept(&self) -> io::Result<Duplex> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nodelay(true);
                Ok(Duplex::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l, _) => l.accept().map(|(s, _)| Duplex::Unix(s)),
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Read one frame, charging payload decode time and traffic to
/// `ledger`. Clean EOF is `Ok(None)`.
fn read_frame_timed(
    conn: &mut Duplex,
    scratch: &mut Vec<u8>,
    ledger: &WireLedger,
) -> Result<Option<Frame>, WireError> {
    let mut header = [0u8; wire::HEADER_LEN];
    loop {
        match conn.read(&mut header[..1]) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    conn.read_exact(&mut header[1..])?;
    let (kind, len) = wire::parse_header(&header)?;
    scratch.clear();
    scratch.resize(len, 0);
    conn.read_exact(scratch)?;
    let t0 = Instant::now();
    let frame = wire::decode_payload(kind, scratch)?;
    ledger.record_in(
        (wire::HEADER_LEN + len) as u64,
        wire::frame_tuples(&frame) as u64,
        t0.elapsed().as_nanos() as u64,
    );
    Ok(Some(frame))
}

/// Source-side socket endpoint for one source→worker stream.
pub struct SocketTupleTx {
    conn: Duplex,
    credit: usize,
    buf: Vec<u8>,
    scratch: Vec<u8>,
    ledger: Arc<WireLedger>,
    closed: bool,
}

impl SocketTupleTx {
    /// Wrap a connected stream with an initial credit window of
    /// `queue_depth` tuples (the receive side must be built with the
    /// same depth). Chunks larger than the window can never be
    /// admitted; the engine clamps batch ≤ queue_depth.
    pub fn new(conn: Duplex, queue_depth: usize, ledger: Arc<WireLedger>) -> Self {
        SocketTupleTx {
            conn,
            credit: queue_depth.max(1),
            buf: Vec::new(),
            scratch: Vec::new(),
            ledger,
            closed: false,
        }
    }
}

impl TupleTx for SocketTupleTx {
    fn send(&mut self, chunk: Vec<Msg>) -> Result<(), LaneError> {
        if self.closed {
            return Err(LaneError::Closed);
        }
        if chunk.is_empty() {
            return Ok(());
        }
        // window exhausted: block on the upstream credit channel
        // until the worker acknowledges enough processed tuples
        while self.credit < chunk.len() {
            match wire::read_frame(&mut self.conn, &mut self.scratch) {
                Ok(Some(Frame::Credit(n))) => self.credit += n as usize,
                // the worker hung up before granting enough credit —
                // clean close either way, no more tuples can be sent
                Ok(Some(Frame::Eof)) | Ok(None) => {
                    self.closed = true;
                    return Err(LaneError::Closed);
                }
                // only Credit ever travels worker→source on this
                // stream; anything else is a peer bug
                Ok(Some(
                    Frame::Data(_) | Frame::Flush(_) | Frame::Hello { .. } | Frame::Done(_),
                )) => {
                    self.closed = true;
                    return Err(LaneError::Protocol("non-credit frame on credit channel"));
                }
                Err(e) => {
                    self.closed = true;
                    return Err(LaneError::Wire(e));
                }
            }
        }
        let t0 = Instant::now();
        self.buf.clear();
        wire::encode_data(&chunk, &mut self.buf);
        let encode_ns = t0.elapsed().as_nanos() as u64;
        self.ledger
            .record_out(self.buf.len() as u64, chunk.len() as u64, encode_ns);
        self.credit -= chunk.len();
        if let Err(e) = self.conn.write_all(&self.buf) {
            self.closed = true;
            return Err(LaneError::Io(e));
        }
        Ok(())
    }

    fn close(&mut self) {
        if self.closed {
            return;
        }
        self.buf.clear();
        wire::encode_eof(&mut self.buf);
        let _ = self.conn.write_all(&self.buf);
        let _ = self.conn.flush();
        self.closed = true;
    }
}

/// Worker-side socket endpoint merging every source stream. One
/// reader thread per stream decodes `Data` frames into a shared
/// queue; acks accumulate per stream and return upstream as `Credit`
/// frames.
pub struct SocketTupleRx {
    rx: Receiver<(usize, Vec<Msg>)>,
    conns: Vec<Duplex>,
    pending: Vec<usize>,
    last_conn: usize,
    quantum: usize,
    buf: Vec<u8>,
}

impl SocketTupleRx {
    /// Build from accepted per-source streams, spawning one reader
    /// thread per stream.
    pub fn new(
        conns: Vec<Duplex>,
        queue_depth: usize,
        ledger: &Arc<WireLedger>,
    ) -> io::Result<SocketTupleRx> {
        let (tx, rx) = channel::<(usize, Vec<Msg>)>();
        let mut write_halves = Vec::with_capacity(conns.len());
        for (id, conn) in conns.into_iter().enumerate() {
            write_halves.push(conn.try_clone()?);
            let tx = tx.clone();
            let ledger = Arc::clone(ledger);
            thread::spawn(move || {
                let mut conn = conn;
                let mut scratch = Vec::new();
                loop {
                    match read_frame_timed(&mut conn, &mut scratch, &ledger) {
                        Ok(Some(Frame::Data(msgs))) => {
                            if tx.send((id, msgs)).is_err() {
                                break;
                            }
                        }
                        // Eof frame or clean socket close ends this
                        // source's stream
                        Ok(Some(Frame::Eof)) | Ok(None) => break,
                        // frames that never travel source→worker: the
                        // peer is confused — stop reading from it
                        Ok(Some(
                            Frame::Flush(_) | Frame::Credit(_) | Frame::Hello { .. }
                            | Frame::Done(_),
                        )) => break,
                        // decode or i/o failure: the stream is dead
                        Err(_) => break,
                    }
                }
            });
        }
        drop(tx);
        let n = write_halves.len();
        Ok(SocketTupleRx {
            rx,
            conns: write_halves,
            pending: vec![0; n],
            last_conn: 0,
            quantum: queue_depth.max(2) / 2,
            buf: Vec::new(),
        })
    }

    fn flush_credit(&mut self, id: usize) {
        if self.pending[id] == 0 {
            return;
        }
        self.buf.clear();
        wire::encode_credit(self.pending[id] as u64, &mut self.buf);
        // a failed credit write means the source is gone; nothing to do
        let _ = self.conns[id].write_all(&self.buf);
        self.pending[id] = 0;
    }

    fn flush_all_credits(&mut self) {
        for id in 0..self.pending.len() {
            self.flush_credit(id);
        }
    }
}

impl TupleRx for SocketTupleRx {
    fn recv(&mut self, timeout: Option<Duration>) -> TupleRecv {
        // return owed credit before blocking so a window-starved
        // source can always make progress
        self.flush_all_credits();
        let delivered = match timeout {
            Some(d) => match self.rx.recv_timeout(d) {
                Ok(pair) => pair,
                Err(RecvTimeoutError::Timeout) => return TupleRecv::Timeout,
                Err(RecvTimeoutError::Disconnected) => return TupleRecv::Closed,
            },
            None => match self.rx.recv() {
                Ok(pair) => pair,
                Err(_) => return TupleRecv::Closed,
            },
        };
        self.last_conn = delivered.0;
        TupleRecv::Chunk(delivered.1)
    }

    fn ack(&mut self, n: usize) {
        self.pending[self.last_conn] += n;
        if self.pending[self.last_conn] >= self.quantum {
            self.flush_credit(self.last_conn);
        }
    }
}

/// Worker-side socket endpoint for one worker→shard stream.
pub struct SocketFlushTx {
    conn: Duplex,
    buf: Vec<u8>,
    ledger: Arc<WireLedger>,
}

impl SocketFlushTx {
    /// Wrap a connected stream.
    pub fn new(conn: Duplex, ledger: Arc<WireLedger>) -> Self {
        SocketFlushTx { conn, buf: Vec::new(), ledger }
    }
}

impl FlushTx for SocketFlushTx {
    fn send(&mut self, msg: FlushMsg) -> Result<(), LaneError> {
        let t0 = Instant::now();
        self.buf.clear();
        wire::encode_flush(&msg, &mut self.buf);
        let encode_ns = t0.elapsed().as_nanos() as u64;
        let tuples: usize = msg.panes.iter().map(|(_, e)| e.len()).sum();
        self.ledger
            .record_out(self.buf.len() as u64, tuples as u64, encode_ns);
        self.conn.write_all(&self.buf).map_err(LaneError::Io)
    }
}

/// Shard-side socket endpoint merging every worker stream.
pub struct SocketFlushRx {
    rx: Receiver<FlushMsg>,
}

impl SocketFlushRx {
    /// Build from accepted per-worker streams, spawning one reader
    /// thread per stream.
    pub fn new(conns: Vec<Duplex>, ledger: &Arc<WireLedger>) -> io::Result<SocketFlushRx> {
        let (tx, rx) = channel::<FlushMsg>();
        for conn in conns {
            let tx = tx.clone();
            let ledger = Arc::clone(ledger);
            thread::spawn(move || {
                let mut conn = conn;
                let mut scratch = Vec::new();
                loop {
                    match read_frame_timed(&mut conn, &mut scratch, &ledger) {
                        Ok(Some(Frame::Flush(f))) => {
                            if tx.send(f).is_err() {
                                break;
                            }
                        }
                        // Eof frame or clean close ends this worker's
                        // flush stream
                        Ok(Some(Frame::Eof)) | Ok(None) => break,
                        // frames that never travel worker→shard
                        Ok(Some(
                            Frame::Data(_) | Frame::Credit(_) | Frame::Hello { .. }
                            | Frame::Done(_),
                        )) => break,
                        Err(_) => break,
                    }
                }
            });
        }
        Ok(SocketFlushRx { rx })
    }
}

impl FlushRx for SocketFlushRx {
    fn recv(&mut self) -> Option<FlushMsg> {
        self.rx.recv().ok()
    }
}

/// Build a full source→worker socket mesh inside one process: per
/// worker, bind a listener, then connect one client stream per source
/// and accept its server side. This is the loopback≡socket oracle
/// path — same engine, real sockets, no process spawn.
pub fn tuple_mesh(
    kind: TransportKind,
    n_sources: usize,
    n_workers: usize,
    queue_depth: usize,
    ledger: &Arc<WireLedger>,
) -> io::Result<(Vec<Vec<Box<dyn TupleTx>>>, Vec<Box<dyn TupleRx>>)> {
    let mut txs: Vec<Vec<Box<dyn TupleTx>>> =
        (0..n_sources).map(|_| Vec::with_capacity(n_workers)).collect();
    let mut rxs: Vec<Box<dyn TupleRx>> = Vec::with_capacity(n_workers);
    for w in 0..n_workers {
        let (listener, addr) = listen(kind, &format!("tup{w}"))?;
        let mut accepted = Vec::with_capacity(n_sources);
        for src in txs.iter_mut() {
            let client = Duplex::connect(&addr)?;
            accepted.push(listener.accept()?);
            src.push(Box::new(SocketTupleTx::new(client, queue_depth, Arc::clone(ledger))));
        }
        rxs.push(Box::new(SocketTupleRx::new(accepted, queue_depth, ledger)?));
    }
    Ok((txs, rxs))
}

/// Build the worker→shard socket mesh inside one process.
pub fn flush_mesh(
    kind: TransportKind,
    n_workers: usize,
    n_shards: usize,
    ledger: &Arc<WireLedger>,
) -> io::Result<(Vec<Vec<Box<dyn FlushTx>>>, Vec<Box<dyn FlushRx>>)> {
    let mut txs: Vec<Vec<Box<dyn FlushTx>>> =
        (0..n_workers).map(|_| Vec::with_capacity(n_shards)).collect();
    let mut rxs: Vec<Box<dyn FlushRx>> = Vec::with_capacity(n_shards);
    for s in 0..n_shards {
        let (listener, addr) = listen(kind, &format!("fl{s}"))?;
        let mut accepted = Vec::with_capacity(n_workers);
        for w in txs.iter_mut() {
            let client = Duplex::connect(&addr)?;
            accepted.push(listener.accept()?);
            w.push(Box::new(SocketFlushTx::new(client, Arc::clone(ledger))));
        }
        rxs.push(Box::new(SocketFlushRx::new(accepted, ledger)?));
    }
    Ok((txs, rxs))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds() -> Vec<TransportKind> {
        #[cfg(unix)]
        {
            vec![TransportKind::Tcp, TransportKind::Uds]
        }
        #[cfg(not(unix))]
        {
            vec![TransportKind::Tcp]
        }
    }

    #[test]
    fn tuple_mesh_streams_under_credit_pressure() {
        for kind in kinds() {
            let ledger = Arc::new(WireLedger::new());
            let (mut txs, mut rxs) = tuple_mesh(kind, 1, 1, 4, &ledger).unwrap();
            let mut rx = rxs.pop().unwrap();
            // worker drains + acks everything on its own thread
            let handle = thread::spawn(move || {
                let mut total = 0usize;
                loop {
                    match rx.recv(None) {
                        TupleRecv::Chunk(chunk) => {
                            total += chunk.len();
                            rx.ack(chunk.len());
                        }
                        TupleRecv::Closed => break,
                        TupleRecv::Timeout => unreachable!(),
                    }
                }
                total
            });
            // 30 chunks of 3 tuples through a 4-tuple credit window
            // forces many credit round-trips
            let tx = &mut txs[0][0];
            for i in 0..30u64 {
                let chunk: Vec<Msg> =
                    (0..3).map(|j| Msg { key: i * 3 + j, emit_ns: 0, ts: 0 }).collect();
                assert!(tx.send(chunk).is_ok(), "send {i} failed for {kind}");
            }
            tx.close();
            drop(txs);
            assert_eq!(handle.join().unwrap(), 90, "{kind} lost tuples");
            let stats = ledger.snapshot();
            assert_eq!(stats.tuples_out, 90);
            assert_eq!(stats.tuples_in, 90);
            assert!(stats.bytes_out > 0 && stats.frames_out >= 30);
        }
    }

    #[test]
    fn flush_mesh_delivers_and_closes() {
        for kind in kinds() {
            let ledger = Arc::new(WireLedger::new());
            let (mut txs, mut rxs) = flush_mesh(kind, 2, 1, &ledger).unwrap();
            let flush = FlushMsg {
                worker: 1,
                emit_ns: 5,
                watermark: 10,
                panes: vec![(0, vec![(7, 3)])],
            };
            assert!(txs[0][0].send(flush.clone()).is_ok());
            assert!(txs[1][0].send(flush.clone()).is_ok());
            drop(txs);
            let mut rx = rxs.pop().unwrap();
            let a = rx.recv().expect("first flush");
            let b = rx.recv().expect("second flush");
            assert_eq!(a.panes, flush.panes);
            assert_eq!(b.panes, flush.panes);
            assert!(rx.recv().is_none(), "{kind} flush lane failed to close");
        }
    }
}
