//! Time-evolving Zipf stream — the paper's synthetic ZF dataset (§6.1).
//!
//! Spec from the paper: 50M tuples, 10^5 unique keys, exponent
//! z ∈ {1.0, …, 2.0}:
//!   * first 0.8·N tuples:  Pr[i] ∝ i^-z            (head = low key ids)
//!   * last  0.2·N tuples:  Pr[i] ∝ (k - i + 1)^-z  (head flips to the
//!     other end of the id space — an abrupt hot-set inversion), with
//!     k = 10^4 and N = 5M per paper text.
//!
//! `phases` generalises this to any number of hot-set rotations so the
//! ablation benches can vary drift rate.

use super::zipf::Zipf;
use super::Generator;
use crate::util::Rng;
use crate::Key;

/// Strategy for mapping a sampled Zipf rank to a key id in one phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseMap {
    /// key = rank (hot keys are the smallest ids).
    Identity,
    /// key = (k - 1 - rank) mod key_space within the window `k`
    /// (the paper's `(k - i + 1)` inversion).
    Reversed { k: usize },
    /// key = (rank + offset) mod key_space (rotating hot set).
    Rotated { offset: usize },
}

/// One contiguous phase of the stream.
#[derive(Debug, Clone)]
pub struct Phase {
    /// Number of tuples in this phase.
    pub len: usize,
    /// Rank→key mapping for this phase.
    pub map: PhaseMap,
}

/// Time-evolving Zipf generator.
pub struct EvolvingZipf {
    zipf: Zipf,
    phases: Vec<Phase>,
    /// Cumulative phase boundaries (end index of each phase).
    bounds: Vec<usize>,
    key_space: usize,
    rng: Rng,
    /// Sequential cursor cache: (next index, rng snapshot) — `key_at` is
    /// O(1) when called with monotonically increasing `i` (the common
    /// engine replay pattern) and re-seeds deterministically otherwise.
    cursor: usize,
    seed: u64,
}

impl EvolvingZipf {
    /// Generic constructor.
    pub fn new(key_space: usize, z: f64, phases: Vec<Phase>, seed: u64) -> Self {
        assert!(!phases.is_empty());
        let mut bounds = Vec::with_capacity(phases.len());
        let mut acc = 0;
        for p in &phases {
            acc += p.len;
            bounds.push(acc);
        }
        EvolvingZipf {
            zipf: Zipf::new(key_space, z),
            phases,
            bounds,
            key_space,
            rng: Rng::new(seed),
            cursor: 0,
            seed,
        }
    }

    /// The paper's exact ZF spec scaled to `tuples` total:
    /// 80% identity-mapped Zipf, 20% reversed within k = key_space / 10.
    pub fn paper_spec(tuples: usize, z: f64, seed: u64) -> Self {
        let key_space = 100_000;
        let head = (tuples as f64 * 0.8) as usize;
        let phases = vec![
            Phase { len: head, map: PhaseMap::Identity },
            Phase { len: tuples - head, map: PhaseMap::Reversed { k: key_space / 10 } },
        ];
        EvolvingZipf::new(key_space, z, phases, seed)
    }

    /// A rotating-hot-set variant: `n_phases` equal phases, each rotating
    /// the head by `key_space / n_phases`. Used by drift-rate ablations.
    pub fn rotating(tuples: usize, key_space: usize, z: f64, n_phases: usize, seed: u64) -> Self {
        assert!(n_phases > 0);
        let per = tuples / n_phases;
        let mut phases = Vec::new();
        for p in 0..n_phases {
            let len = if p == n_phases - 1 { tuples - per * (n_phases - 1) } else { per };
            phases.push(Phase {
                len,
                map: PhaseMap::Rotated { offset: p * (key_space / n_phases) },
            });
        }
        EvolvingZipf::new(key_space, z, phases, seed)
    }

    fn phase_of(&self, i: usize) -> &Phase {
        let pi = match self.bounds.binary_search(&i) {
            Ok(p) => p + 1,
            Err(p) => p,
        };
        &self.phases[pi.min(self.phases.len() - 1)]
    }

    #[inline]
    fn map_rank(&self, map: PhaseMap, rank: usize) -> Key {
        match map {
            PhaseMap::Identity => rank as Key,
            PhaseMap::Reversed { k } => {
                // paper: Pr[i] ∝ (k - i + 1)^-z, i.e. hottest rank maps to
                // key k-1, next to k-2, ... wrapping into the key space.
                let k = k.max(1);
                ((k - 1 + self.key_space - rank % self.key_space) % self.key_space) as Key
            }
            PhaseMap::Rotated { offset } => ((rank + offset) % self.key_space) as Key,
        }
    }
}

impl Generator for EvolvingZipf {
    fn len(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    fn key_space(&self) -> usize {
        self.key_space
    }

    fn key_at(&mut self, i: usize) -> Key {
        if i != self.cursor {
            // random access: rebuild the rng deterministically by skipping.
            // Sequential replay (the hot path) never takes this branch.
            let mut rng = Rng::new(self.seed);
            for _ in 0..i {
                let _ = self.zipf.sample(&mut rng);
            }
            self.rng = rng;
            self.cursor = i;
        }
        let rank = self.zipf.sample(&mut self.rng);
        self.cursor += 1;
        let map = self.phase_of(i).map;
        self.map_rank(map, rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_spec_hot_set_inverts() {
        let mut g = EvolvingZipf::paper_spec(100_000, 1.5, 1);
        let mut head_counts = std::collections::HashMap::new();
        let mut tail_counts = std::collections::HashMap::new();
        for i in 0..80_000 {
            *head_counts.entry(g.key_at(i)).or_insert(0usize) += 1;
        }
        for i in 80_000..100_000 {
            *tail_counts.entry(g.key_at(i)).or_insert(0usize) += 1;
        }
        let hot_head = head_counts.iter().max_by_key(|(_, &c)| c).unwrap();
        let hot_tail = tail_counts.iter().max_by_key(|(_, &c)| c).unwrap();
        assert!(*hot_head.0 < 10, "phase-1 hottest should be a small id");
        assert!(*hot_tail.0 >= 9_000, "phase-2 hottest should be near k-1={}, got {}", 9_999, hot_tail.0);
    }

    #[test]
    fn deterministic_replay() {
        let mut a = EvolvingZipf::paper_spec(10_000, 1.2, 7);
        let mut b = EvolvingZipf::paper_spec(10_000, 1.2, 7);
        let va: Vec<Key> = (0..10_000).map(|i| a.key_at(i)).collect();
        let vb: Vec<Key> = (0..10_000).map(|i| b.key_at(i)).collect();
        assert_eq!(va, vb);
    }

    #[test]
    fn random_access_matches_sequential() {
        let mut a = EvolvingZipf::paper_spec(5_000, 1.0, 3);
        let seq: Vec<Key> = (0..5_000).map(|i| a.key_at(i)).collect();
        let mut b = EvolvingZipf::paper_spec(5_000, 1.0, 3);
        assert_eq!(b.key_at(4_321), seq[4_321]);
        assert_eq!(b.key_at(100), seq[100]);
        assert_eq!(b.key_at(101), seq[101]); // sequential after a jump
    }

    #[test]
    fn rotating_phases_shift_head() {
        let mut g = EvolvingZipf::rotating(30_000, 9_000, 1.8, 3, 5);
        let mode = |from: usize, to: usize, g: &mut EvolvingZipf| {
            let mut c = std::collections::HashMap::new();
            for i in from..to {
                *c.entry(g.key_at(i)).or_insert(0usize) += 1;
            }
            *c.iter().max_by_key(|(_, &n)| n).unwrap().0
        };
        let m1 = mode(0, 10_000, &mut g);
        let m2 = mode(10_000, 20_000, &mut g);
        let m3 = mode(20_000, 30_000, &mut g);
        assert!(m1 < 100);
        assert!((3_000..3_100).contains(&(m2 as usize)));
        assert!((6_000..6_100).contains(&(m3 as usize)));
    }

    #[test]
    fn keys_within_space() {
        let mut g = EvolvingZipf::paper_spec(20_000, 2.0, 11);
        for i in 0..20_000 {
            assert!((g.key_at(i) as usize) < g.key_space());
        }
    }
}
