//! Time-evolving stream workload generators and trace I/O.
//!
//! The paper evaluates on two real datasets (MemeTracker, Amazon Movie
//! Reviews) plus a synthetic time-evolving Zipf stream. The real datasets
//! are not redistributable here, so `corpus` synthesises traces that
//! reproduce their operative properties — short-interval Zipf skew with
//! hot-set drift — at configurable scale (DESIGN.md §5).

pub mod corpus;
pub mod evolving;
pub mod trace;
pub mod zipf;

pub use evolving::EvolvingZipf;
pub use trace::{Trace, Tuple};
pub use zipf::Zipf;

use crate::util::Rng;

/// Anything that can produce a key stream. All generators are
/// deterministic given their seed.
pub trait Generator {
    /// Total tuples this generator will emit.
    fn len(&self) -> usize;
    /// True when `len() == 0`.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Number of distinct keys in the key space.
    fn key_space(&self) -> usize;
    /// Emit the `i`-th tuple's key (generators are random-access so the
    /// engines can replay without materialising 50M-tuple traces).
    fn key_at(&mut self, i: usize) -> crate::Key;
}

/// Build the named workload at the given scale.
///
/// Names mirror the paper: `zf` (synthetic Zipf, `z` = skew), `mt`
/// (MemeTracker-like), `am` (Amazon-Movie-like).
pub fn by_name(name: &str, tuples: usize, z: f64, seed: u64) -> Box<dyn Generator + Send> {
    match name {
        "zf" => Box::new(EvolvingZipf::paper_spec(tuples, z, seed)),
        "mt" => Box::new(corpus::MemeTrackerLike::new(tuples, seed)),
        "am" => Box::new(corpus::AmazonMovieLike::new(tuples, seed)),
        other => panic!("unknown workload '{other}' (expected zf|mt|am)"),
    }
}

/// Materialise a generator into a [`Trace`].
pub fn materialise(gen: &mut (dyn Generator + Send), interarrival_ns: u64) -> Trace {
    let n = gen.len();
    let mut tuples = Vec::with_capacity(n);
    for i in 0..n {
        tuples.push(Tuple {
            ts: i as u64 * interarrival_ns,
            key: gen.key_at(i),
        });
    }
    Trace::new(tuples, gen.key_space())
}

/// Convenience: fresh RNG namespaced to the workload layer.
pub(crate) fn wl_rng(seed: u64, stream: u64) -> Rng {
    Rng::new(seed ^ 0x574C_0000_0000_0000).fork(stream)
}
