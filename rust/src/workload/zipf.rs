//! Zipf sampler over a finite key space.
//!
//! Pr[rank i] ∝ i^-z, i ∈ [1, k]. Implemented with a precomputed CDF and
//! binary search — O(log k) per sample, exact, deterministic.

use crate::util::Rng;

/// Finite Zipf distribution sampler.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Distribution over `k` ranks with exponent `z >= 0`.
    pub fn new(k: usize, z: f64) -> Self {
        assert!(k > 0, "zipf needs a non-empty key space");
        assert!(z >= 0.0 && z.is_finite(), "zipf exponent must be finite >= 0");
        let mut cdf = Vec::with_capacity(k);
        let mut acc = 0.0f64;
        for i in 1..=k {
            acc += (i as f64).powf(-z);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        // guard against fp round-off on the tail
        *cdf.last_mut().unwrap() = 1.0;
        Zipf { cdf }
    }

    /// Number of ranks.
    pub fn k(&self) -> usize {
        self.cdf.len()
    }

    /// Sample a rank in `[0, k)` (rank 0 is the hottest).
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.gen_f64();
        // first index with cdf[i] >= u
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i,
        }
    }

    /// Exact probability of rank `i` (0-based).
    pub fn pmf(&self, i: usize) -> f64 {
        if i == 0 {
            self.cdf[0]
        } else {
            self.cdf[i] - self.cdf[i - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pmf_sums_to_one() {
        let z = Zipf::new(1000, 1.5);
        let sum: f64 = (0..1000).map(|i| z.pmf(i)).sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ranks_monotone_decreasing() {
        let z = Zipf::new(100, 1.2);
        for i in 1..100 {
            assert!(z.pmf(i) <= z.pmf(i - 1) + 1e-12);
        }
    }

    #[test]
    fn z_zero_is_uniform() {
        let z = Zipf::new(10, 0.0);
        for i in 0..10 {
            assert!((z.pmf(i) - 0.1).abs() < 1e-9);
        }
    }

    #[test]
    fn empirical_matches_pmf_head() {
        let z = Zipf::new(1000, 1.0);
        let mut rng = Rng::new(123);
        let n = 200_000;
        let mut counts = vec![0usize; 1000];
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        for i in 0..5 {
            let emp = counts[i] as f64 / n as f64;
            let rel = (emp - z.pmf(i)).abs() / z.pmf(i);
            assert!(rel < 0.05, "rank {i}: emp {emp} vs pmf {}", z.pmf(i));
        }
    }

    #[test]
    fn samples_in_range() {
        let z = Zipf::new(7, 2.0);
        let mut rng = Rng::new(9);
        for _ in 0..10_000 {
            assert!(z.sample(&mut rng) < 7);
        }
    }
}
