//! Synthetic stand-ins for the paper's real-world datasets (DESIGN.md §5).
//!
//! * **MemeTrackerLike** — the MT dataset is a keyword stream from
//!   blog/news quotes: Zipf-skewed word frequencies whose *catchphrase*
//!   head churns with the news cycle. We model it as a base Zipf
//!   vocabulary (0.39M words at full scale) overlaid with bursty
//!   catchphrases: each time slice promotes a fresh random set of keys
//!   whose burst intensity rises then decays (a news-cycle envelope).
//! * **AmazonMovieLike** — the AM dataset keys tuples by product id;
//!   popularity follows release waves (sharp rise, long decay). We model
//!   products whose release times are spread over the stream and whose
//!   popularity at time t follows a log-normal-ish wave, on top of a
//!   Zipf catalogue-popularity base.
//!
//! Both generators reproduce the two properties FISH exploits
//! (Observation 1): (1) within any bounded interval the key frequencies
//! are heavily skewed; (2) the identity of the head set drifts over time.

use super::zipf::Zipf;
use super::Generator;
use crate::util::Rng;
use crate::Key;

/// Default key-space scale divisor: full-scale MT has 0.39M keys / 49.21M
/// tuples; by default we keep the keys-per-tuple ratio at reduced scale.
fn scaled_keys(tuples: usize, full_tuples: f64, full_keys: f64, floor: usize) -> usize {
    let ratio = full_keys / full_tuples;
    ((tuples as f64 * ratio) as usize).max(floor)
}

/// MemeTracker-like bursty keyword stream.
pub struct MemeTrackerLike {
    len: usize,
    key_space: usize,
    base: Zipf,
    /// catchphrase schedule: per slice, the promoted key set
    slices: Vec<Vec<Key>>,
    slice_len: usize,
    burst_zipf: Zipf,
    /// probability a tuple comes from the burst overlay vs the base
    burst_frac: f64,
    rng: Rng,
    cursor: usize,
    seed: u64,
}

impl MemeTrackerLike {
    /// Create a stream of `tuples` tuples (key space scales with size).
    ///
    /// The news-cycle length scales with the stream (~32 cycles per
    /// stream) so the *drift rate* — hot-set changes per stream — matches
    /// the full-size dataset's behaviour at any scale.
    pub fn new(tuples: usize, seed: u64) -> Self {
        let slice = (tuples / 32).max(2_000);
        Self::with_params(tuples, scaled_keys(tuples, 49.21e6, 0.39e6, 2_000), slice, 16, 0.45, seed)
    }

    /// Full parameter control (used by ablation benches).
    ///
    /// * `slice_len` — tuples per news-cycle slice
    /// * `burst_keys` — catchphrases promoted per slice
    /// * `burst_frac` — fraction of tuples drawn from the burst overlay
    pub fn with_params(
        tuples: usize,
        key_space: usize,
        slice_len: usize,
        burst_keys: usize,
        burst_frac: f64,
        seed: u64,
    ) -> Self {
        let mut rng = super::wl_rng(seed, 1);
        let n_slices = tuples.div_ceil(slice_len.max(1)).max(1);
        let mut slices = Vec::with_capacity(n_slices);
        for _ in 0..n_slices {
            let set: Vec<Key> = (0..burst_keys)
                .map(|_| rng.gen_range(key_space as u64))
                .collect();
            slices.push(set);
        }
        MemeTrackerLike {
            len: tuples,
            key_space,
            base: Zipf::new(key_space, 1.05),
            slices,
            slice_len: slice_len.max(1),
            burst_zipf: Zipf::new(burst_keys.max(1), 1.3),
            burst_frac,
            rng: super::wl_rng(seed, 2),
            cursor: 0,
            seed,
        }
    }

    fn sample_at(&mut self, i: usize) -> Key {
        let slice = (i / self.slice_len).min(self.slices.len() - 1);
        // news-cycle envelope: burst share ramps 0→peak→0 across the slice
        let pos = (i % self.slice_len) as f64 / self.slice_len as f64;
        let envelope = 1.0 - (2.0 * pos - 1.0).abs(); // triangle 0→1→0
        let p_burst = self.burst_frac * (0.4 + 0.6 * envelope);
        if self.rng.gen_bool(p_burst) {
            let r = self.burst_zipf.sample(&mut self.rng);
            self.slices[slice][r]
        } else {
            self.base.sample(&mut self.rng) as Key
        }
    }
}

impl Generator for MemeTrackerLike {
    fn len(&self) -> usize {
        self.len
    }

    fn key_space(&self) -> usize {
        self.key_space
    }

    fn key_at(&mut self, i: usize) -> Key {
        if i != self.cursor {
            let mut fresh = Self::with_params(
                self.len,
                self.key_space,
                self.slice_len,
                self.burst_zipf.k(),
                self.burst_frac,
                self.seed,
            );
            for j in 0..i {
                let _ = fresh.sample_at(j);
            }
            self.rng = fresh.rng;
            self.cursor = i;
        }
        let k = self.sample_at(i);
        self.cursor += 1;
        k
    }
}

/// Amazon-Movie-Review-like product-popularity stream.
pub struct AmazonMovieLike {
    len: usize,
    key_space: usize,
    base: Zipf,
    /// product release position (fraction of stream) per wave product
    releases: Vec<(Key, f64)>,
    wave_frac: f64,
    rng: Rng,
    cursor: usize,
    seed: u64,
}

impl AmazonMovieLike {
    /// Create a stream of `tuples` review events (~64 release waves per
    /// stream, mirroring the full dataset's popularity-wave density).
    pub fn new(tuples: usize, seed: u64) -> Self {
        Self::with_params(tuples, scaled_keys(tuples, 7.91e6, 0.25e6, 2_000), 64, 0.5, seed)
    }

    /// * `wave_products` — number of release-wave (hot) products
    /// * `wave_frac` — fraction of tuples drawn from release waves
    pub fn with_params(
        tuples: usize,
        key_space: usize,
        wave_products: usize,
        wave_frac: f64,
        seed: u64,
    ) -> Self {
        let mut rng = super::wl_rng(seed, 11);
        let releases: Vec<(Key, f64)> = (0..wave_products)
            .map(|_| (rng.gen_range(key_space as u64), rng.gen_f64() * 0.9))
            .collect();
        AmazonMovieLike {
            len: tuples,
            key_space,
            base: Zipf::new(key_space, 0.9),
            releases,
            wave_frac,
            rng: super::wl_rng(seed, 12),
            cursor: 0,
            seed,
        }
    }

    /// Popularity envelope of a release at stream position `pos`:
    /// zero before release, sharp rise, exponential-ish decay.
    fn wave_weight(release: f64, pos: f64) -> f64 {
        if pos < release {
            0.0
        } else {
            let age = (pos - release) * 20.0; // ~5% of stream = one decay unit
            age.min(1.0) * (-age * 0.8).exp()
        }
    }

    fn sample_at(&mut self, i: usize) -> Key {
        let pos = i as f64 / self.len.max(1) as f64;
        if self.rng.gen_bool(self.wave_frac) {
            // weighted pick among active waves; fall back to base if none
            let weights: Vec<f64> = self
                .releases
                .iter()
                .map(|&(_, r)| Self::wave_weight(r, pos))
                .collect();
            let total: f64 = weights.iter().sum();
            if total > 1e-12 {
                let mut u = self.rng.gen_f64() * total;
                for (j, w) in weights.iter().enumerate() {
                    u -= w;
                    if u <= 0.0 {
                        return self.releases[j].0;
                    }
                }
                return self.releases.last().unwrap().0;
            }
        }
        self.base.sample(&mut self.rng) as Key
    }
}

impl Generator for AmazonMovieLike {
    fn len(&self) -> usize {
        self.len
    }

    fn key_space(&self) -> usize {
        self.key_space
    }

    fn key_at(&mut self, i: usize) -> Key {
        if i != self.cursor {
            let mut fresh = Self::with_params(
                self.len,
                self.key_space,
                self.releases.len(),
                self.wave_frac,
                self.seed,
            );
            for j in 0..i {
                let _ = fresh.sample_at(j);
            }
            self.rng = fresh.rng;
            self.cursor = i;
        }
        let k = self.sample_at(i);
        self.cursor += 1;
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn head_share(counts: &HashMap<Key, usize>, top: usize, n: usize) -> f64 {
        let mut v: Vec<usize> = counts.values().copied().collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v.iter().take(top).sum::<usize>() as f64 / n as f64
    }

    #[test]
    fn mt_interval_skew_and_drift() {
        let mut g = MemeTrackerLike::new(200_000, 4);
        let mut interval_heads: Vec<Vec<Key>> = Vec::new();
        for w in 0..4 {
            let mut counts = HashMap::new();
            for i in w * 50_000..(w + 1) * 50_000 {
                *counts.entry(g.key_at(i)).or_insert(0usize) += 1;
            }
            // Observation 1: bounded-interval skew — top-20 keys dominate
            assert!(
                head_share(&counts, 20, 50_000) > 0.15,
                "window {w} lacks skew"
            );
            let mut v: Vec<(Key, usize)> = counts.into_iter().collect();
            v.sort_unstable_by(|a, b| b.1.cmp(&a.1));
            interval_heads.push(v.into_iter().take(10).map(|(k, _)| k).collect());
        }
        // hot-set drift: consecutive windows share few head keys
        let overlap: usize = interval_heads[0]
            .iter()
            .filter(|k| interval_heads[3].contains(k))
            .count();
        assert!(overlap < 8, "head set did not drift (overlap {overlap})");
    }

    #[test]
    fn am_waves_rise_and_decay() {
        let mut g = AmazonMovieLike::new(200_000, 8);
        let mut per_window: Vec<HashMap<Key, usize>> = Vec::new();
        for w in 0..4 {
            let mut counts = HashMap::new();
            for i in w * 50_000..(w + 1) * 50_000 {
                *counts.entry(g.key_at(i)).or_insert(0usize) += 1;
            }
            per_window.push(counts);
        }
        // each window is skewed
        for (w, counts) in per_window.iter().enumerate() {
            assert!(head_share(counts, 20, 50_000) > 0.15, "window {w} lacks skew");
        }
    }

    #[test]
    fn deterministic_and_in_range() {
        let mut a = MemeTrackerLike::new(20_000, 1);
        let mut b = MemeTrackerLike::new(20_000, 1);
        for i in 0..20_000 {
            let k = a.key_at(i);
            assert_eq!(k, b.key_at(i));
            assert!((k as usize) < a.key_space());
        }
        let mut c = AmazonMovieLike::new(20_000, 1);
        let mut d = AmazonMovieLike::new(20_000, 1);
        for i in 0..20_000 {
            let k = c.key_at(i);
            assert_eq!(k, d.key_at(i));
            assert!((k as usize) < c.key_space());
        }
    }

    #[test]
    fn random_access_consistency() {
        let mut a = AmazonMovieLike::new(5_000, 2);
        let seq: Vec<Key> = (0..5_000).map(|i| a.key_at(i)).collect();
        let mut b = AmazonMovieLike::new(5_000, 2);
        assert_eq!(b.key_at(1234), seq[1234]);
        assert_eq!(b.key_at(1235), seq[1235]);
    }
}
