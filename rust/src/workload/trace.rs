//! Materialised traces and a simple binary trace-file format, so
//! experiments can be replayed byte-identically across engines/schemes.

use crate::Key;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// One stream tuple: arrival timestamp (ns since stream start) + key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tuple {
    /// Arrival time in nanoseconds from stream start.
    pub ts: u64,
    /// Interned key id.
    pub key: Key,
}

/// A fully materialised stream trace.
#[derive(Debug, Clone)]
pub struct Trace {
    tuples: Vec<Tuple>,
    key_space: usize,
}

const MAGIC: &[u8; 8] = b"FISHTRC1";

impl Trace {
    /// Wrap a tuple vector.
    pub fn new(tuples: Vec<Tuple>, key_space: usize) -> Self {
        Trace { tuples, key_space }
    }

    /// Tuples in arrival order.
    pub fn tuples(&self) -> &[Tuple] {
        &self.tuples
    }

    /// Number of tuples.
    pub fn len(&self) -> usize {
        self.tuples.len()
    }

    /// True when the trace has no tuples.
    pub fn is_empty(&self) -> bool {
        self.tuples.is_empty()
    }

    /// Size of the key space this trace draws from.
    pub fn key_space(&self) -> usize {
        self.key_space
    }

    /// Write the binary format: magic, key_space, n, then (ts, key) LE pairs.
    pub fn save(&self, path: &Path) -> io::Result<()> {
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&(self.key_space as u64).to_le_bytes())?;
        w.write_all(&(self.tuples.len() as u64).to_le_bytes())?;
        for t in &self.tuples {
            w.write_all(&t.ts.to_le_bytes())?;
            w.write_all(&t.key.to_le_bytes())?;
        }
        w.flush()
    }

    /// Read the binary format written by [`Trace::save`].
    pub fn load(path: &Path) -> io::Result<Trace> {
        let mut r = BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "bad trace magic"));
        }
        let mut buf8 = [0u8; 8];
        r.read_exact(&mut buf8)?;
        let key_space = u64::from_le_bytes(buf8) as usize;
        r.read_exact(&mut buf8)?;
        let n = u64::from_le_bytes(buf8) as usize;
        let mut tuples = Vec::with_capacity(n);
        for _ in 0..n {
            r.read_exact(&mut buf8)?;
            let ts = u64::from_le_bytes(buf8);
            r.read_exact(&mut buf8)?;
            let key = u64::from_le_bytes(buf8);
            tuples.push(Tuple { ts, key });
        }
        Ok(Trace { tuples, key_space })
    }

    /// Parse a whitespace text stream (one word per token) into a trace,
    /// interning words to dense key ids and dropping `stopwords`. This is
    /// the word-count ingestion path used by `examples/wordcount_pipeline`.
    pub fn from_text<R: Read>(reader: R, stopwords: &[&str], interarrival_ns: u64) -> Trace {
        let mut intern: std::collections::HashMap<String, Key> = std::collections::HashMap::new();
        let mut tuples = Vec::new();
        let stop: std::collections::HashSet<&str> = stopwords.iter().copied().collect();
        let br = BufReader::new(reader);
        let mut i = 0u64;
        for line in br.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => break,
            };
            for word in line.split(|c: char| !c.is_alphanumeric()) {
                if word.is_empty() {
                    continue;
                }
                let w = word.to_ascii_lowercase();
                if stop.contains(w.as_str()) || w.len() < 2 {
                    continue;
                }
                let next_id = intern.len() as Key;
                let id = *intern.entry(w).or_insert(next_id);
                tuples.push(Tuple { ts: i * interarrival_ns, key: id });
                i += 1;
            }
        }
        let key_space = intern.len();
        Trace { tuples, key_space }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let t = Trace::new(
            (0..1000).map(|i| Tuple { ts: i * 10, key: (i * 7) % 97 }).collect(),
            97,
        );
        let dir = std::env::temp_dir().join("fish_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back.key_space(), 97);
        assert_eq!(back.tuples(), t.tuples());
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("fish_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTATRACE___").unwrap();
        assert!(Trace::load(&path).is_err());
    }

    #[test]
    fn from_text_interns_and_filters() {
        let text = "The cat sat. The CAT ran! a";
        let t = Trace::from_text(text.as_bytes(), &["the"], 100);
        // tokens kept: cat sat cat ran  (the/a dropped; 'a' too short)
        assert_eq!(t.len(), 4);
        assert_eq!(t.key_space(), 3); // cat, sat, ran
        assert_eq!(t.tuples()[0].key, t.tuples()[2].key); // cat == cat
        assert_eq!(t.tuples()[1].ts, 100);
    }
}
