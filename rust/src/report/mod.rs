//! Experiment reporting: aligned console tables + CSV files.
//!
//! Every bench target prints the paper's rows with this module and drops
//! a CSV under `bench_out/` so EXPERIMENTS.md can reference raw series.

use std::io::Write;
use std::path::Path;

/// A simple column-aligned table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: format heterogeneous cells.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let v: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&v)
    }

    /// Render to a string with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Write CSV (headers + rows) to `path`, creating parent dirs.
    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        self.save_csv_with_meta(path, &[])
    }

    /// [`Table::save_csv`], prefixed with `# key=value` comment lines —
    /// run metadata (scale, seed, git SHA) that travels with the series.
    pub fn save_csv_with_meta(
        &self,
        path: &Path,
        meta: &[(String, String)],
    ) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        for (k, v) in meta {
            writeln!(f, "# {k}={v}")?;
        }
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            let esc: Vec<String> = row
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            writeln!(f, "{}", esc.join(","))?;
        }
        Ok(())
    }
}

/// Format a float with 2 decimals (most figure cells).
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format a ratio as `N.NNx`.
pub fn ratio(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format nanoseconds human-readably.
pub fn ns(v: u64) -> String {
    if v >= 1_000_000_000 {
        format!("{:.2}s", v as f64 / 1e9)
    } else if v >= 1_000_000 {
        format!("{:.2}ms", v as f64 / 1e6)
    } else if v >= 1_000 {
        format!("{:.2}us", v as f64 / 1e3)
    } else {
        format!("{v}ns")
    }
}

/// Standard output directory for bench CSVs.
pub fn bench_out() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("FISH_BENCH_OUT").unwrap_or_else(|_| "bench_out".to_string()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_and_counts() {
        let mut t = Table::new("demo", &["scheme", "latency"]);
        t.row(&["fish".into(), "1.07x".into()]);
        t.row(&["w-choices-long".into(), "13.57x".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.lines().count() == 5);
        // columns aligned: both data lines end at same width
        let lines: Vec<&str> = s.lines().skip(3).collect();
        assert_eq!(lines[0].split_whitespace().count(), 2);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_meta_lines_precede_headers() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["1".into()]);
        let dir = std::env::temp_dir().join("fish_report_meta_test");
        let p = dir.join("t.csv");
        t.save_csv_with_meta(
            &p,
            &[("seed".into(), "42".into()), ("git_sha".into(), "abc".into())],
        )
        .unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert_eq!(body, "# seed=42\n# git_sha=abc\na\n1\n");
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["va,l\"ue".into()]);
        let dir = std::env::temp_dir().join("fish_report_test");
        let p = dir.join("t.csv");
        t.save_csv(&p).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert_eq!(body, "a\n\"va,l\"\"ue\"\n");
    }

    #[test]
    fn formatters() {
        assert_eq!(f2(1.234), "1.23");
        assert_eq!(ratio(2.0), "2.00x");
        assert_eq!(ns(500), "500ns");
        assert_eq!(ns(1_500), "1.50us");
        assert_eq!(ns(2_000_000), "2.00ms");
        assert_eq!(ns(3_000_000_000), "3.00s");
    }
}
