//! Frequency-statistics substrates.
//!
//! * [`spacesaving`] — the bounded counter set of paper Alg. 1 (intra-epoch
//!   counting with ReplaceMin + inter-epoch decay). Also serves the
//!   aggregation layer's approximate top-k queries via weighted observes
//!   ([`SpaceSaving::observe_weighted`], see [`crate::aggregate::TopKSketch`]).
//! * [`countmin`] — a count-min sketch bit-compatible with the Pallas
//!   kernel (`python/compile/kernels/cms.py`), used by the XLA-backed
//!   identifier and by tests that cross-check the two layers.
//! * [`window`] — exact count-based [`SlidingWindow`], the §2.4
//!   window-based counting baseline (linear memory in the window), now
//!   also the ground-truth cross-check for the aggregation layer's
//!   pane-based tumbling/sliding windows
//!   ([`crate::aggregate::WindowedMerge`], `--agg_window_ms`) in the
//!   windowed oracle tests.

pub mod countmin;
pub mod spacesaving;
pub mod window;

pub use countmin::CountMin;
pub use spacesaving::SpaceSaving;
pub use window::SlidingWindow;
