//! Sliding-window exact frequency counter — the baseline family the
//! paper critiques in §2.4 ([19]–[23]): accurate recent counts, but the
//! window contents must be buffered, so memory grows linearly with the
//! window size.
//!
//! Used by the identifier-ablation bench to reproduce the paper's
//! accuracy/memory trade-off argument, and as a ground-truth oracle for
//! recent-frequency accuracy tests (a window IS the definition of
//! "recent frequency").

use crate::Key;
use std::collections::{HashMap, VecDeque};

/// Exact counts over the last `window` tuples.
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    window: usize,
    buf: VecDeque<Key>,
    counts: HashMap<Key, u64>,
}

impl SlidingWindow {
    /// Counter over the trailing `window` tuples.
    pub fn new(window: usize) -> Self {
        assert!(window > 0);
        SlidingWindow {
            window,
            buf: VecDeque::with_capacity(window),
            counts: HashMap::new(),
        }
    }

    /// Window length.
    pub fn window(&self) -> usize {
        self.window
    }

    /// Observe one key, evicting the tuple that falls out of the window.
    pub fn observe(&mut self, key: Key) {
        if self.buf.len() == self.window {
            let old = self.buf.pop_front().expect("non-empty window");
            match self.counts.get_mut(&old) {
                Some(c) if *c > 1 => *c -= 1,
                Some(_) => {
                    self.counts.remove(&old);
                }
                None => unreachable!("window key missing from counts"),
            }
        }
        self.buf.push_back(key);
        *self.counts.entry(key).or_insert(0) += 1;
    }

    /// Exact count of `key` within the window.
    pub fn count(&self, key: Key) -> u64 {
        self.counts.get(&key).copied().unwrap_or(0)
    }

    /// Highest in-window count.
    pub fn top_count(&self) -> u64 {
        // max() is an order-independent fold. lint: sorted-ok
        self.counts.values().copied().max().unwrap_or(0)
    }

    /// Tuples currently buffered.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True before any tuple arrives.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Memory footprint in entries: the buffered tuples *plus* the count
    /// map — the linear cost the paper's §2.4 critique is about.
    pub fn entries(&self) -> usize {
        self.buf.len() + self.counts.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_within_window() {
        let mut w = SlidingWindow::new(5);
        for k in [1u64, 2, 1, 3, 1] {
            w.observe(k);
        }
        assert_eq!(w.count(1), 3);
        assert_eq!(w.count(2), 1);
        assert_eq!(w.top_count(), 3);
    }

    #[test]
    fn eviction_is_exact() {
        let mut w = SlidingWindow::new(3);
        for k in [1u64, 1, 1, 2, 2, 2] {
            w.observe(k);
        }
        assert_eq!(w.count(1), 0, "old key fully evicted");
        assert_eq!(w.count(2), 3);
        assert_eq!(w.len(), 3);
    }

    #[test]
    fn memory_linear_in_window() {
        let mut small = SlidingWindow::new(100);
        let mut big = SlidingWindow::new(10_000);
        let mut rng = crate::util::Rng::new(1);
        for _ in 0..20_000 {
            let k = rng.gen_range(1_000);
            small.observe(k);
            big.observe(k);
        }
        assert!(big.entries() > small.entries() * 20);
    }

    #[test]
    fn matches_naive_recount() {
        let mut w = SlidingWindow::new(50);
        let mut hist: Vec<Key> = Vec::new();
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..2_000 {
            let k = rng.gen_range(20);
            w.observe(k);
            hist.push(k);
            let start = hist.len().saturating_sub(50);
            let naive = hist[start..].iter().filter(|&&x| x == 7).count() as u64;
            assert_eq!(w.count(7), naive);
        }
    }
}
