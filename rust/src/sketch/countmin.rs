//! Count-min sketch, bit-compatible with the Pallas kernel.
//!
//! The hash family (uint32 multiply-shift, constants `HASH_A`/`HASH_B`)
//! matches `python/compile/kernels/cms.py` **exactly**, so the Rust
//! native path and the AOT XLA path can be swapped without re-learning
//! sketch state — `rust/tests/integration_runtime.rs` asserts bit
//! equality between the two.

use crate::Key;

/// Multiply-shift constants — keep in sync with cms.py.
pub const HASH_A: [u32; 6] = [
    0x9E37_79B1, 0x85EB_CA77, 0xC2B2_AE3D, 0x27D4_EB2F, 0x1656_67B1, 0xD3A2_646D,
];
/// Additive constants — keep in sync with cms.py.
pub const HASH_B: [u32; 6] = [
    0x68E3_1DA4, 0xB529_7A4D, 0x1B56_C4E9, 0x8F14_ACD5, 0xCA6B_27D9, 0x5F35_6495,
];

/// Count-min sketch with f32 counters (matches the kernel dtype).
#[derive(Debug, Clone)]
pub struct CountMin {
    depth: usize,
    width: usize,
    shift: u32,
    rows: Vec<f32>, // depth × width, row-major
}

impl CountMin {
    /// `depth` ≤ 6 hash rows, `width` a power of two.
    pub fn new(depth: usize, width: usize) -> Self {
        assert!(depth >= 1 && depth <= HASH_A.len(), "depth 1..=6");
        assert!(width.is_power_of_two() && width >= 2, "width must be a power of two");
        CountMin {
            depth,
            width,
            shift: 32 - width.trailing_zeros(),
            rows: vec![0.0; depth * width],
        }
    }

    /// Number of hash rows.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Buckets per row.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Bucket of `key` in `row` — identical to cms.row_hash (the key is
    /// truncated to its low 32 bits exactly like the int32 kernel input).
    #[inline]
    pub fn bucket(&self, key: Key, row: usize) -> usize {
        let k = key as u32;
        let h = k.wrapping_mul(HASH_A[row]).wrapping_add(HASH_B[row]);
        (h >> self.shift) as usize
    }

    /// Add one occurrence of `key`.
    #[inline]
    pub fn add(&mut self, key: Key) {
        for d in 0..self.depth {
            let b = self.bucket(key, d);
            self.rows[d * self.width + b] += 1.0;
        }
    }

    /// Count-min estimate (min over rows). Never underestimates.
    #[inline]
    pub fn estimate(&self, key: Key) -> f32 {
        let mut est = f32::INFINITY;
        for d in 0..self.depth {
            let b = self.bucket(key, d);
            est = est.min(self.rows[d * self.width + b]);
        }
        est
    }

    /// Multiply every counter by `alpha` (inter-epoch decay).
    pub fn decay(&mut self, alpha: f32) {
        for c in self.rows.iter_mut() {
            *c *= alpha;
        }
    }

    /// Reset all counters to zero.
    pub fn clear(&mut self) {
        self.rows.iter_mut().for_each(|c| *c = 0.0);
    }

    /// Raw row-major counters (runtime interchange with the XLA path).
    pub fn rows(&self) -> &[f32] {
        &self.rows
    }

    /// Replace the counters wholesale (after an XLA epoch_stats call).
    pub fn set_rows(&mut self, rows: Vec<f32>) {
        assert_eq!(rows.len(), self.depth * self.width);
        self.rows = rows;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinned_hash_vector_matches_python() {
        // Same vector as python/tests/test_kernel.py::test_row_hash_rust_vector
        let cm = CountMin::new(1, 2048);
        let keys: [i32; 5] = [0, 1, 42, 123_456, -1];
        let expect: Vec<usize> = keys
            .iter()
            .map(|&k| {
                let k = k as u32 as u64;
                (((HASH_A[0] as u64 * k + HASH_B[0] as u64) % (1u64 << 32)) >> 21) as usize
            })
            .collect();
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(cm.bucket(k as u32 as Key, 0), expect[i]);
        }
    }

    #[test]
    fn never_underestimates() {
        let mut cm = CountMin::new(4, 256);
        let mut truth = std::collections::HashMap::new();
        let mut rng = crate::util::Rng::new(2);
        for _ in 0..20_000 {
            let k = rng.gen_range(64); // heavy collisions on 256 buckets
            *truth.entry(k).or_insert(0u32) += 1;
            cm.add(k);
        }
        for (&k, &c) in &truth {
            assert!(cm.estimate(k) >= c as f32);
        }
    }

    #[test]
    fn exact_when_sparse() {
        let mut cm = CountMin::new(4, 4096);
        for _ in 0..100 {
            cm.add(7);
        }
        cm.add(9);
        assert_eq!(cm.estimate(7), 100.0);
        assert_eq!(cm.estimate(9), 1.0);
    }

    #[test]
    fn decay_and_clear() {
        let mut cm = CountMin::new(2, 64);
        for _ in 0..10 {
            cm.add(1);
        }
        cm.decay(0.5);
        assert_eq!(cm.estimate(1), 5.0);
        cm.clear();
        assert_eq!(cm.estimate(1), 0.0);
    }

    #[test]
    fn rows_roundtrip() {
        let mut cm = CountMin::new(2, 64);
        cm.add(3);
        let rows = cm.rows().to_vec();
        let mut cm2 = CountMin::new(2, 64);
        cm2.set_rows(rows);
        assert_eq!(cm2.estimate(3), 1.0);
    }

    #[test]
    #[should_panic]
    fn rejects_non_pow2_width() {
        let _ = CountMin::new(2, 100);
    }
}
