//! SpaceSaving bounded counter set — paper Algorithm 1's `K` set.
//!
//! Stores at most `K_max` (key, counter) pairs. On overflow the
//! minimum-count key is evicted and the newcomer inherits `c_min + 1`
//! (ReplaceMin): the paper keeps the evictee's mass so fresh keys are not
//! perpetually churned out (§4.1.1). `decay(α)` multiplies every counter
//! by α — called once per epoch by the identifier (inter-epoch hotness
//! decaying).
//!
//! Implementation: hash map key → slot, plus a **lazy min-heap** for
//! eviction. Each count change stamps its slot; heap entries carry the
//! stamp they were pushed with and are discarded as stale on pop. This
//! makes the hot path O(log K) amortised instead of the naive O(K)
//! min-scan per eviction (the §Perf pass measured that scan dominating
//! FISH's route() at K_max = 1000). Decay preserves relative order, so
//! the heap is rebuilt once per decay (once per epoch) in O(K).

use crate::Key;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// One tracked key.
#[derive(Debug, Clone, Copy)]
struct Slot {
    key: Key,
    count: f64,
    /// Bumped on every count change; validates heap entries.
    stamp: u64,
}

/// Heap entry: (count as orderable bits, slot index, stamp-at-push).
/// Counts are non-negative, so IEEE-754 bit order == numeric order.
type HeapEntry = Reverse<(u64, usize, u64)>;

/// Bounded top-K counter set with decay (SpaceSaving + ReplaceMin).
#[derive(Debug, Clone)]
pub struct SpaceSaving {
    cap: usize,
    slots: Vec<Slot>,
    index: HashMap<Key, usize>,
    /// Lazy min-heap over slots (stale entries skipped on pop).
    heap: BinaryHeap<HeapEntry>,
    /// Exact maximum count, maintained incrementally (counts only grow
    /// by +1 or scale uniformly, so O(1) updates keep it exact).
    max_count: f64,
}

impl SpaceSaving {
    /// Create a counter set with capacity `K_max`.
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "SpaceSaving capacity must be positive");
        SpaceSaving {
            cap,
            slots: Vec::with_capacity(cap),
            index: HashMap::with_capacity(cap * 2),
            heap: BinaryHeap::with_capacity(cap * 2),
            max_count: 0.0,
        }
    }

    /// `force` pushes unconditionally (inserts/replacements — the slot
    /// must stay visible to eviction); non-forced pushes (hot-key bumps)
    /// are skipped when the new count already exceeds the heap top: such
    /// a slot cannot be the minimum until a decay rebuild, and hiding a
    /// *hot* key from eviction is exactly the bias SpaceSaving wants.
    #[inline]
    fn push_heap(&mut self, i: usize, force: bool) {
        let bits = self.slots[i].count.to_bits();
        if !force {
            if let Some(&Reverse((top_bits, _, _))) = self.heap.peek() {
                if bits > top_bits {
                    return;
                }
            }
        }
        self.heap.push(Reverse((bits, i, self.slots[i].stamp)));
        // bound tombstone growth: rebuild when 8x oversized
        if self.heap.len() > self.cap * 8 + 16 {
            self.rebuild_heap();
        }
    }

    fn rebuild_heap(&mut self) {
        self.heap.clear();
        self.heap.extend(
            self.slots
                .iter()
                .enumerate()
                .map(|(i, s)| Reverse((s.count.to_bits(), i, s.stamp))),
        );
    }

    /// Capacity `K_max`.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Number of tracked keys.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when no key is tracked.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Observe one occurrence of `key` (paper Alg. 1 lines 8–17).
    #[inline]
    pub fn observe(&mut self, key: Key) {
        self.observe_weighted(key, 1.0);
    }

    /// Observe `w` occurrences of `key` at once — the shape flushed
    /// aggregation partials arrive in (one `(key, n)` delta instead of
    /// `n` unit observes). Equivalent to `w` calls to [`Self::observe`]
    /// for tracked keys; on eviction the newcomer inherits `c_min + w`,
    /// preserving the overestimate guarantee.
    #[inline]
    pub fn observe_weighted(&mut self, key: Key, w: f64) {
        debug_assert!(w > 0.0, "weight must be positive, got {w}");
        if let Some(&i) = self.index.get(&key) {
            self.slots[i].count += w;
            self.slots[i].stamp += 1;
            if self.slots[i].count > self.max_count {
                self.max_count = self.slots[i].count;
            }
            self.push_heap(i, false); // bump: skippable when above the min
            return;
        }
        if self.slots.len() < self.cap {
            let i = self.slots.len();
            self.slots.push(Slot { key, count: w, stamp: 0 });
            self.index.insert(key, i);
            if self.max_count < w {
                self.max_count = w;
            }
            self.push_heap(i, true);
        } else {
            self.replace_min(key, w);
        }
    }

    /// ReplaceMin subroutine: evict the min-count key; the newcomer gets
    /// `c_min + w`. O(log K) amortised via the lazy heap.
    fn replace_min(&mut self, key: Key, w: f64) {
        let i = loop {
            match self.heap.peek() {
                None => self.rebuild_heap(), // all entries were stale
                Some(&Reverse((bits, i, stamp))) => {
                    if self.slots[i].stamp == stamp && self.slots[i].count.to_bits() == bits {
                        break i; // valid current minimum
                    }
                    self.heap.pop(); // stale tombstone
                }
            }
        };
        self.heap.pop();
        let old = self.slots[i];
        self.index.remove(&old.key);
        self.slots[i] = Slot { key, count: old.count + w, stamp: old.stamp + 1 };
        self.index.insert(key, i);
        if self.slots[i].count > self.max_count {
            self.max_count = self.slots[i].count;
        }
        self.push_heap(i, true);
    }

    /// Inter-epoch decay: every counter ×= `alpha` (paper Alg. 1 lines
    /// 23–26). `alpha == 0` clears all history mass (counts drop to 0 but
    /// keys stay tracked until replaced). O(K); called once per epoch.
    pub fn decay(&mut self, alpha: f64) {
        debug_assert!((0.0..=1.0).contains(&alpha));
        for s in self.slots.iter_mut() {
            s.count *= alpha;
            s.stamp += 1;
        }
        self.max_count *= alpha;
        // uniform scaling preserves order; refresh the heap wholesale
        self.rebuild_heap();
    }

    /// Estimated count of `key` (0 if untracked).
    pub fn estimate(&self, key: Key) -> f64 {
        self.index.get(&key).map(|&i| self.slots[i].count).unwrap_or(0.0)
    }

    /// True if `key` is currently tracked.
    pub fn contains(&self, key: Key) -> bool {
        self.index.contains_key(&key)
    }

    /// Highest counter value (`f_top` in Alg. 2), 0 when empty. O(1) —
    /// maintained incrementally (the §Perf pass removed the O(K) scan).
    pub fn top_count(&self) -> f64 {
        if self.slots.is_empty() {
            0.0
        } else {
            self.max_count
        }
    }

    /// Sum of all counters (denominator for relative frequencies).
    pub fn total(&self) -> f64 {
        self.slots.iter().map(|s| s.count).sum()
    }

    /// Iterate `(key, count)` in unspecified order.
    pub fn iter(&self) -> impl Iterator<Item = (Key, f64)> + '_ {
        self.slots.iter().map(|s| (s.key, s.count))
    }

    /// The `n` highest-count entries, descending.
    pub fn top_n(&self, n: usize) -> Vec<(Key, f64)> {
        let mut v: Vec<(Key, f64)> = self.iter().collect();
        v.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v.truncate(n);
        v
    }

    /// Memory footprint in tracked entries (for the scalability metric).
    pub fn entries(&self) -> usize {
        self.slots.len()
    }

    /// True once the counter set is full — from then on estimates may
    /// overestimate (ReplaceMin inheritance) by up to
    /// [`SpaceSaving::min_count`].
    pub fn at_capacity(&self) -> bool {
        self.slots.len() == self.cap
    }

    /// Smallest tracked count (0 when empty). Without decay this is
    /// nondecreasing, so it bounds every past ReplaceMin inheritance:
    /// any estimate `e` satisfies `true ≤ e ≤ true + min_count()`, and
    /// any *untracked* key's true count is ≤ `min_count()`. O(K) scan —
    /// query/report path, not the per-observe hot path.
    pub fn min_count(&self) -> f64 {
        if self.slots.is_empty() {
            0.0
        } else {
            self.slots.iter().map(|s| s.count).fold(f64::INFINITY, f64::min)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_exact_under_capacity() {
        let mut ss = SpaceSaving::new(10);
        for _ in 0..5 {
            ss.observe(1);
        }
        for _ in 0..3 {
            ss.observe(2);
        }
        assert_eq!(ss.estimate(1), 5.0);
        assert_eq!(ss.estimate(2), 3.0);
        assert_eq!(ss.estimate(99), 0.0);
        assert_eq!(ss.len(), 2);
    }

    #[test]
    fn replace_min_inherits_count_plus_one() {
        let mut ss = SpaceSaving::new(2);
        ss.observe(1); // c1=1
        ss.observe(1); // c1=2
        ss.observe(2); // c2=1
        ss.observe(3); // evicts key 2 (min=1): c3 = 2
        assert!(!ss.contains(2));
        assert_eq!(ss.estimate(3), 2.0);
        assert_eq!(ss.estimate(1), 2.0);
        assert_eq!(ss.len(), 2);
    }

    #[test]
    fn never_exceeds_capacity() {
        let mut ss = SpaceSaving::new(16);
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..10_000 {
            ss.observe(rng.gen_range(1000));
        }
        assert!(ss.len() <= 16);
    }

    #[test]
    fn overestimate_property() {
        // SpaceSaving estimate >= true count for tracked keys.
        let mut ss = SpaceSaving::new(8);
        let mut truth = std::collections::HashMap::new();
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..5_000 {
            // skewed stream: key 0 hot
            let k = if rng.gen_bool(0.5) { 0 } else { rng.gen_range(100) };
            *truth.entry(k).or_insert(0u64) += 1;
            ss.observe(k);
        }
        for (k, c) in ss.iter() {
            assert!(c + 1e-9 >= truth.get(&k).copied().unwrap_or(0) as f64 || c >= 1.0);
        }
        // the genuinely hot key must be tracked with ~correct mass
        let t0 = truth[&0] as f64;
        assert!(ss.estimate(0) >= t0);
    }

    #[test]
    fn decay_scales_counts() {
        let mut ss = SpaceSaving::new(4);
        for _ in 0..10 {
            ss.observe(7);
        }
        ss.decay(0.2);
        assert!((ss.estimate(7) - 2.0).abs() < 1e-9);
        ss.decay(0.0);
        assert_eq!(ss.estimate(7), 0.0);
        assert!(ss.contains(7)); // key survives until replaced
    }

    #[test]
    fn top_n_and_totals() {
        let mut ss = SpaceSaving::new(8);
        for (k, n) in [(1u64, 5usize), (2, 3), (3, 9)] {
            for _ in 0..n {
                ss.observe(k);
            }
        }
        assert_eq!(ss.top_count(), 9.0);
        assert_eq!(ss.total(), 17.0);
        let top = ss.top_n(2);
        assert_eq!(top[0], (3, 9.0));
        assert_eq!(top[1], (1, 5.0));
    }

    #[test]
    fn weighted_observe_equals_repeated_unit_observes() {
        let mut unit = SpaceSaving::new(4);
        let mut weighted = SpaceSaving::new(4);
        for (k, n) in [(1u64, 5usize), (2, 3), (3, 9)] {
            for _ in 0..n {
                unit.observe(k);
            }
            weighted.observe_weighted(k, n as f64);
        }
        for k in [1u64, 2, 3] {
            assert_eq!(unit.estimate(k), weighted.estimate(k), "key {k}");
        }
        assert_eq!(unit.top_count(), weighted.top_count());
    }

    #[test]
    fn weighted_eviction_inherits_cmin_plus_weight() {
        let mut ss = SpaceSaving::new(2);
        ss.observe_weighted(1, 10.0);
        ss.observe_weighted(2, 4.0);
        ss.observe_weighted(3, 6.0); // evicts key 2 (min=4): c3 = 10
        assert!(!ss.contains(2));
        assert_eq!(ss.estimate(3), 10.0);
        assert_eq!(ss.len(), 2);
    }

    #[test]
    fn hot_keys_survive_churn() {
        // A genuinely hot key must never be evicted by tail churn.
        let mut ss = SpaceSaving::new(32);
        let mut rng = crate::util::Rng::new(17);
        for i in 0..50_000u64 {
            if i % 3 == 0 {
                ss.observe(42);
            } else {
                ss.observe(1000 + rng.gen_range(100_000));
            }
        }
        assert!(ss.contains(42));
        assert!(ss.estimate(42) >= 50_000.0 / 3.0 - 1.0);
    }
}
