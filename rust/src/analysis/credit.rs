//! The credit-based flow-control protocol as a [`Protocol`]
//! implementation for the model checker in [`super::model`].
//!
//! The protocol under check (see `transport/socket.rs` and
//! `docs/DETERMINISM.md`):
//!
//! * each sender starts with `window` credits and spends them on
//!   fixed-size data chunks (a chunk is atomic — a sender with credit
//!   left over but less than one chunk is *blocked*, exactly like the
//!   real sender that must ship `opts.chunk` tuples per frame);
//! * the receiver acks consumed tuples in quanta of
//!   `window.max(2) / 2`, returning credit in whole quanta and holding
//!   the sub-quantum remainder;
//! * before the receiver would block waiting for data it **flushes all
//!   owed credit**, remainder included. This rule makes the protocol
//!   deadlock-free — quantized acks alone can strand up to
//!   `quantum - 1` credits while the sender is blocked needing a full
//!   chunk.
//!
//! Invariants checked on every reachable state:
//!
//! * `credit-overflow` — sender credit never exceeds the window;
//! * `credit-conservation` — per stream, `sender credit + in-flight
//!   data + receiver-owed + grants in flight == window` (no leak, no
//!   double grant);
//! * `fifo-delivery` — chunks arrive in sequence order per stream
//!   (an out-of-order pop poisons the lane, which the invariant then
//!   reports — delivery otherwise proceeds so credit conservation
//!   stays observable).
//!
//! Deadlock freedom and liveness-to-quiescence come from the framework
//! ([`Violation::Deadlock`] on stuck non-final states). [`CreditMutation`]
//! deliberately breaks one rule at a time so `rust/tests/credit_model.rs`
//! can prove the checker *detects* each violation class rather than
//! vacuously passing.
//!
//! [`Violation::Deadlock`]: super::model::Violation::Deadlock

use std::collections::VecDeque;

use super::model::{
    explore, CheckOptions, Counterexample, ModelStats, PropertyViolation, Protocol,
};

/// A bounded credit-protocol configuration to exhaustively check.
#[derive(Debug, Clone)]
pub struct CreditConfig {
    /// Concurrent senders feeding one receiver (streams are
    /// credit-independent; interleavings are shared).
    pub n_senders: usize,
    /// Credit window per stream (the receiver-side queue depth).
    pub window: u32,
    /// Tuples each sender must deliver for the run to terminate.
    pub tuples_per_sender: u32,
    /// Fixed data-chunk size (the final chunk may be smaller). Must be
    /// ≤ `window` or even the honest protocol cannot make progress.
    pub chunk: u32,
    /// Protocol rule to deliberately break ([`CreditMutation::None`]
    /// checks the honest protocol).
    pub mutation: CreditMutation,
}

/// A deliberate protocol bug, used to prove the checker catches each
/// violation class (mutation testing for the model itself).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CreditMutation {
    /// The protocol as implemented.
    None,
    /// Receiver never flushes sub-quantum credit remainders before
    /// blocking — the bug class the `flush_all_credits()` rule
    /// prevents. Expected: deadlock.
    SkipCreditFlush,
    /// Receiver grants every ack twice. Expected: `credit-conservation`
    /// (or `credit-overflow`) violation.
    DoubleGrant,
    /// Receiver drops one credit from every grant. Expected:
    /// `credit-conservation` violation (accounting breaks low).
    DropCredit,
    /// Network delivers the newest in-flight chunk first. Expected:
    /// `fifo-delivery` violation.
    ReorderData,
}

/// Per-stream protocol state: small unsigned counters plus FIFO
/// queues, so whole states hash cheaply.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct Lane {
    /// Credits the sender may spend.
    credit: u32,
    /// Tuples the sender has not yet put on the wire.
    to_send: u32,
    /// In-flight data chunks: `(size, first_seq)`, FIFO.
    channel: VecDeque<(u32, u32)>,
    /// Next sequence number the receiver expects (== tuples delivered).
    delivered: u32,
    /// Tuples consumed but not yet acked (credit the receiver owes).
    pending: u32,
    /// Credit grants in flight back to the sender, FIFO.
    grants: VecDeque<u32>,
    /// `(expected_seq, got_seq)` of an out-of-order delivery observed
    /// on this lane; `None` in every honest reachable state.
    reorder_fault: Option<(u32, u32)>,
}

/// The credit protocol over a bounded config.
pub struct CreditProtocol {
    cfg: CreditConfig,
    quantum: u32,
}

impl CreditProtocol {
    /// Wrap `cfg`, validating the bounds that make exploration
    /// meaningful.
    pub fn new(cfg: CreditConfig) -> CreditProtocol {
        assert!(cfg.n_senders > 0, "need at least one sender");
        assert!(cfg.window > 0 && cfg.chunk > 0, "window and chunk must be positive");
        assert!(cfg.chunk <= cfg.window, "chunk > window cannot make progress even unmutated");
        let quantum = cfg.window.max(2) / 2;
        CreditProtocol { cfg, quantum }
    }

    fn push_grant(&self, lane: &mut Lane, granted: u32) {
        let granted = match self.cfg.mutation {
            CreditMutation::DoubleGrant => granted * 2,
            CreditMutation::DropCredit => granted.saturating_sub(1),
            _ => granted,
        };
        if granted > 0 {
            lane.grants.push_back(granted);
        }
    }
}

impl Protocol for CreditProtocol {
    type State = Vec<Lane>;

    fn name(&self) -> String {
        let mut n = format!(
            "credit n={} window={} tuples={} chunk={}",
            self.cfg.n_senders, self.cfg.window, self.cfg.tuples_per_sender, self.cfg.chunk
        );
        if self.cfg.mutation != CreditMutation::None {
            n.push_str(&format!(" mutation={:?}", self.cfg.mutation));
        }
        n
    }

    fn initial(&self) -> Vec<Lane> {
        vec![
            Lane {
                credit: self.cfg.window,
                to_send: self.cfg.tuples_per_sender,
                channel: VecDeque::new(),
                delivered: 0,
                pending: 0,
                grants: VecDeque::new(),
                reorder_fault: None,
            };
            self.cfg.n_senders
        ]
    }

    fn successors(&self, state: &Vec<Lane>, out: &mut Vec<(String, Vec<Lane>)>) {
        for i in 0..state.len() {
            let lane = &state[i];

            // send: one fixed-size chunk, atomically, if credit covers it
            if lane.to_send > 0 {
                let size = self.cfg.chunk.min(lane.to_send);
                if lane.credit >= size {
                    let mut next = state.clone();
                    let l = &mut next[i];
                    let first_seq = self.cfg.tuples_per_sender - l.to_send;
                    l.credit -= size;
                    l.to_send -= size;
                    l.channel.push_back((size, first_seq));
                    out.push((format!("send {i}"), next));
                }
            }

            // deliver: receiver consumes one in-flight chunk and acks
            // in whole quanta, holding the remainder
            if !lane.channel.is_empty() {
                let mut next = state.clone();
                let l = &mut next[i];
                let (size, first_seq) =
                    if self.cfg.mutation == CreditMutation::ReorderData && l.channel.len() > 1 {
                        l.channel.pop_back().expect("checked non-empty")
                    } else {
                        l.channel.pop_front().expect("checked non-empty")
                    };
                if first_seq != l.delivered {
                    l.reorder_fault = Some((l.delivered, first_seq));
                }
                l.delivered += size;
                l.pending += size;
                let quantized = (l.pending / self.quantum) * self.quantum;
                if quantized > 0 {
                    l.pending -= quantized;
                    self.push_grant(&mut next[i], quantized);
                }
                out.push((format!("deliver {i}"), next));
            }

            // flush: receiver returns ALL owed credit (the
            // before-blocking rule); removed under SkipCreditFlush
            if lane.pending > 0 && self.cfg.mutation != CreditMutation::SkipCreditFlush {
                let mut next = state.clone();
                let owed = next[i].pending;
                next[i].pending = 0;
                self.push_grant(&mut next[i], owed);
                out.push((format!("flush {i}"), next));
            }

            // grant arrival: a credit frame reaches the sender
            if !lane.grants.is_empty() {
                let mut next = state.clone();
                let l = &mut next[i];
                let g = l.grants.pop_front().expect("checked non-empty");
                l.credit += g;
                out.push((format!("grant {i}"), next));
            }
        }
    }

    fn invariants(&self, state: &Vec<Lane>) -> Result<(), PropertyViolation> {
        for (i, lane) in state.iter().enumerate() {
            if let Some((expected, got)) = lane.reorder_fault {
                return Err(PropertyViolation {
                    property: "fifo-delivery",
                    detail: format!("stream {i}: expected seq {expected}, got {got}"),
                });
            }
            if lane.credit > self.cfg.window {
                return Err(PropertyViolation {
                    property: "credit-overflow",
                    detail: format!(
                        "stream {i}: credit {} > window {}",
                        lane.credit, self.cfg.window
                    ),
                });
            }
            let inflight: u32 = lane.channel.iter().map(|&(size, _)| size).sum();
            let grants: u32 = lane.grants.iter().sum();
            let accounted = lane.credit + inflight + lane.pending + grants;
            if accounted != self.cfg.window {
                return Err(PropertyViolation {
                    property: "credit-conservation",
                    detail: format!(
                        "stream {i}: window {}, accounted {accounted}",
                        self.cfg.window
                    ),
                });
            }
        }
        Ok(())
    }

    fn is_final(&self, state: &Vec<Lane>) -> bool {
        state.iter().all(|l| l.delivered == self.cfg.tuples_per_sender)
    }
}

/// Exhaustively check one credit configuration. Deterministic: same
/// config + options ⇒ same stats, byte-identical counterexample.
pub fn check_credit(cfg: &CreditConfig, opts: &CheckOptions) -> Result<ModelStats, Counterexample> {
    explore(&CreditProtocol::new(cfg.clone()), opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::model::Violation;

    fn cfg(n: usize, window: u32, tuples: u32, chunk: u32, mutation: CreditMutation) -> CreditConfig {
        CreditConfig { n_senders: n, window, tuples_per_sender: tuples, chunk, mutation }
    }

    #[test]
    fn honest_single_stream_has_pinned_stats() {
        let stats =
            check_credit(&cfg(1, 2, 4, 1, CreditMutation::None), &CheckOptions::default())
                .expect("honest run");
        assert_eq!(stats, ModelStats { states: 22, transitions: 30, depth: 12, finals: 3 });
    }

    #[test]
    fn honest_protocol_terminates() {
        let opts = CheckOptions { check_termination: true, ..Default::default() };
        check_credit(&cfg(1, 2, 4, 1, CreditMutation::None), &opts).expect("acyclic");
        check_credit(&cfg(2, 3, 4, 2, CreditMutation::None), &opts).expect("acyclic");
    }

    #[test]
    fn reorder_poisons_and_is_reported_with_the_delivering_edge() {
        let err =
            check_credit(&cfg(1, 4, 8, 2, CreditMutation::ReorderData), &CheckOptions::default())
                .unwrap_err();
        match &err.violation {
            Violation::Property(p) => assert_eq!(p.property, "fifo-delivery"),
            v => panic!("expected fifo violation, got {v:?}"),
        }
        assert_eq!(err.trace, vec!["send 0", "send 0", "deliver 0"]);
    }
}
