//! `fish lint` — the repo's determinism & robustness rule engine.
//!
//! A deliberately small, line-oriented analyzer (no parser, no
//! dependencies — the build is offline) that walks a source tree and
//! enforces the rules in `docs/DETERMINISM.md`:
//!
//! | rule                     | scope                          | catches |
//! |--------------------------|--------------------------------|---------|
//! | `unsorted-map-iteration` | `aggregate/ sketch/ report/`   | order-dependent `HashMap`/`HashSet` iteration on flush/merge/report/sketch-admission paths |
//! | `unwrap-in-io`           | `transport/`, `engine/rt.rs`   | `unwrap()`/`expect()` on I/O paths that must degrade, not panic |
//! | `relaxed-credit-atomic`  | `transport/`                   | `Ordering::Relaxed` on credit/watermark/ack atomics |
//! | `raw-clock`              | everywhere but the `Clock` home| `SystemTime::now()` bypassing the shared clock |
//! | `frame-exhaustive`       | everywhere                     | wire-frame `match`es with a bare `_` arm that would swallow a new frame kind; `FlushMsg` literals that don't name their exactly-once `seq` explicitly |
//! | `obs-clock`              | `obs/`                         | `Instant::now()`/`SystemTime::now()` inside the tracing layer — timestamps must be passed in from the engine clock (virtual ticks or `transport::Clock`), or traces lose cross-process alignment and sim determinism |
//! | `hotpath-alloc`          | `coordinator/ aggregate/`      | allocation inside the per-batch hot functions (`route_batch`, the absorb family): `String` clones, `to_string()`/`to_owned()`, `format!`, fresh `Vec`/`HashMap` construction, `collect()` — at millions of tuples/sec allocator traffic dominates (the ROADMAP "allocation-free hot path" inventory) |
//! | `snapshot-exhaustive`    | everywhere                     | `ShardSnapshot` construction or destructuring that hides fields behind `..` — a new piece of shard state must not silently skip serialization (the failure class the `FlushMsg` seq rule caught on the wire) |
//!
//! Two rules have escape hatches, both comment markers on (or
//! immediately above) the flagged line, and both counted and reported:
//! `// lint: sorted-ok` waives a map-iteration finding at sites that
//! sort the drained batch before it crosses a stage boundary or fold
//! it through an order-independent operation; `// lint: alloc-ok`
//! waives a hot-path allocation at sites that are genuinely amortized
//! (e.g. a once-per-window pane open). The other rules have none —
//! their findings are fixed, not waived.
//!
//! Test regions (`#[cfg(test)]` items), comments and string literals
//! are ignored. The engine favours zero false positives on the idioms
//! this repo uses over completeness; it is self-tested against
//! seeded-regression fixtures in `rust/tests/fixtures/lint/` and
//! against the real tree (which must scan clean).

use std::collections::BTreeSet;
use std::fmt;
use std::io;
use std::path::Path;

/// Directory components whose files are flush/merge/report/
/// sketch-admission paths for the map-iteration rule.
const SORTED_DIRS: &[&str] = &["aggregate", "sketch", "report"];

/// Map methods whose iteration order is the hasher's, not the caller's.
const UNORDERED_METHODS: &[&str] = &["drain", "iter", "iter_mut", "keys", "values", "into_iter"];

/// Keywords that mark an atomic as part of the credit/watermark
/// protocol for the relaxed-ordering rule.
const CREDIT_WORDS: &[&str] = &["credit", "inflight", "watermark", "grant", "ack", "pending"];

/// The escape-comment marker for the map-iteration rule.
const ESCAPE_MARK: &str = "lint: sorted-ok";

/// Directory components whose files carry the per-batch routing and
/// absorb hot path for the allocation rule.
const HOT_DIRS: &[&str] = &["coordinator", "aggregate"];

/// Hot-path function names: the per-batch routing and absorb entry
/// points that run once per batch (or once per tuple) at full rate.
/// The rule scans only these function bodies — cold paths (setup,
/// snapshot, report) allocate freely.
const HOT_FNS: &[&str] = &["route_batch", "absorb", "absorb_batch", "absorb_on"];

/// Allocation-site tokens flagged inside hot functions, with a short
/// human label for the message.
const ALLOC_TOKENS: &[(&str, &str)] = &[
    (".to_string(", "String allocation"),
    (".to_owned(", "String allocation"),
    ("String::from(", "String allocation"),
    ("format!(", "String allocation"),
    (".clone()", "clone"),
    ("Vec::new(", "fresh Vec"),
    ("Vec::with_capacity(", "fresh Vec"),
    ("vec![", "fresh Vec"),
    ("HashMap::new(", "fresh map"),
    ("HashMap::with_capacity(", "fresh map"),
    ("HashSet::new(", "fresh set"),
    ("BTreeMap::new(", "fresh map"),
    (".collect(", "collecting allocation"),
];

/// The escape-comment marker for the hot-path allocation rule.
const ALLOC_MARK: &str = "lint: alloc-ok";

/// One rule violation at one source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Rule identifier (stable, kebab-case).
    pub rule: &'static str,
    /// Path relative to the linted root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// What is wrong and what to do instead.
    pub message: String,
    /// The offending source line, trimmed.
    pub snippet: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Everything one lint run produced.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Violations, sorted by (file, line, rule) — deterministic output.
    pub findings: Vec<Finding>,
    /// `.rs` files scanned.
    pub files_scanned: usize,
    /// Would-be findings waived by an escape marker (`// lint:
    /// sorted-ok` on the map-iteration rule, `// lint: alloc-ok` on
    /// the hot-path allocation rule).
    pub suppressions: usize,
}

impl LintReport {
    /// Serialize as a single-line JSON object (hand-rolled — offline
    /// build, no serde). Shape:
    /// `{"findings":[{"rule":..,"file":..,"line":..,"message":..}],
    ///   "files_scanned":N,"suppressions":N}`.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"findings\":[");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"rule\":\"{}\",\"file\":\"{}\",\"line\":{},\"message\":\"{}\"}}",
                json_escape(f.rule),
                json_escape(&f.file),
                f.line,
                json_escape(&f.message)
            ));
        }
        out.push_str(&format!(
            "],\"files_scanned\":{},\"suppressions\":{}}}",
            self.files_scanned, self.suppressions
        ));
        out
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One preprocessed source line.
struct LineInfo {
    /// The line with comments and string-literal contents removed.
    code: String,
    /// The raw line (for snippets and escape-comment detection).
    raw: String,
    /// Inside a `#[cfg(test)]` item.
    in_test: bool,
}

/// Strip comments and string/char-literal contents from one line,
/// tracking block-comment AND string-literal state across lines.
/// Quotes are kept (so `"x"` becomes `""`), which preserves
/// tokenization without letting literal contents trip pattern rules.
/// A string left open at end of line (the `"...\` multi-line-literal
/// idiom) keeps stripping on the following lines until its closing
/// quote — otherwise continuation lines would leak literal contents
/// (and their braces) into the code stream, corrupting both token
/// rules and the `#[cfg(test)]` brace balance.
fn strip_line(line: &str, in_block_comment: &mut bool, in_string: &mut bool) -> String {
    let bytes: Vec<char> = line.chars().collect();
    let mut out = String::with_capacity(line.len());
    let mut i = 0;
    while i < bytes.len() {
        if *in_block_comment {
            if bytes[i] == '*' && i + 1 < bytes.len() && bytes[i + 1] == '/' {
                *in_block_comment = false;
                i += 2;
            } else {
                i += 1;
            }
            continue;
        }
        if *in_string {
            if bytes[i] == '\\' {
                i += 2;
            } else if bytes[i] == '"' {
                *in_string = false;
                out.push('"');
                i += 1;
            } else {
                i += 1;
            }
            continue;
        }
        match bytes[i] {
            '/' if i + 1 < bytes.len() && bytes[i + 1] == '/' => break, // line comment
            '/' if i + 1 < bytes.len() && bytes[i + 1] == '*' => {
                *in_block_comment = true;
                i += 2;
            }
            '"' => {
                out.push('"');
                i += 1;
                let mut closed = false;
                while i < bytes.len() {
                    if bytes[i] == '\\' {
                        i += 2;
                    } else if bytes[i] == '"' {
                        closed = true;
                        break;
                    } else {
                        i += 1;
                    }
                }
                if closed {
                    out.push('"');
                    i += 1;
                } else {
                    *in_string = true; // continues on the next line
                }
            }
            '\'' => {
                // char literal vs lifetime: a literal is 'x' or '\x';
                // anything else (e.g. `&'static`, `<'a>`) passes through
                if i + 2 < bytes.len() && bytes[i + 1] == '\\' {
                    let mut j = i + 2;
                    while j < bytes.len() && bytes[j] != '\'' {
                        j += 1;
                    }
                    out.push_str("''");
                    i = j + 1;
                } else if i + 2 < bytes.len() && bytes[i + 2] == '\'' {
                    out.push_str("''");
                    i += 3;
                } else {
                    out.push('\'');
                    i += 1;
                }
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    out
}

/// Preprocess a file: strip comments/strings and mark `#[cfg(test)]`
/// regions by brace balancing.
fn preprocess(text: &str) -> Vec<LineInfo> {
    let mut lines = Vec::new();
    let mut in_block_comment = false;
    let mut in_string = false;
    let mut depth: i64 = 0;
    let mut pending_test = false;
    let mut test_until_depth: Option<i64> = None;
    for raw in text.lines() {
        let code = strip_line(raw, &mut in_block_comment, &mut in_string);
        let is_test_attr = code.contains("#[cfg(test)]");
        if is_test_attr {
            pending_test = true;
        }
        let opens = code.matches('{').count() as i64;
        let closes = code.matches('}').count() as i64;
        if pending_test && opens > 0 && test_until_depth.is_none() {
            test_until_depth = Some(depth);
            pending_test = false;
        }
        let in_test = pending_test || test_until_depth.is_some() || is_test_attr;
        depth += opens - closes;
        if let Some(d) = test_until_depth {
            if depth <= d {
                test_until_depth = None;
            }
        }
        lines.push(LineInfo { code, raw: raw.to_string(), in_test });
    }
    lines
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Last identifier in `s`, if `s` ends with one (ignoring trailing
/// whitespace).
fn trailing_ident(s: &str) -> Option<&str> {
    let t = s.trim_end();
    let start = t
        .char_indices()
        .rev()
        .take_while(|&(_, c)| is_ident_char(c))
        .last()
        .map(|(i, _)| i)?;
    let ident = &t[start..];
    if ident.is_empty() || ident.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        None
    } else {
        Some(ident)
    }
}

/// Identifiers declared (or initialized) as `HashMap`/`HashSet` in
/// this file: `name: HashMap<..>` field/binding annotations and
/// `let [mut] name = HashMap::new()`-style initializers.
fn collect_map_names(lines: &[LineInfo]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for info in lines {
        let code = &info.code;
        for ty in ["HashMap", "HashSet"] {
            let mut from = 0;
            while let Some(rel) = code[from..].find(ty) {
                let at = from + rel;
                from = at + ty.len();
                // word boundary after the type name
                let after = code[at + ty.len()..].chars().next();
                if matches!(after, Some(c) if is_ident_char(c)) {
                    continue;
                }
                // strip a qualifying path (`std::collections::HashMap`)
                let mut head = &code[..at];
                while head.ends_with("::") {
                    head = &head[..head.len() - 2];
                    while head.chars().next_back().is_some_and(is_ident_char) {
                        head = &head[..head.len() - 1];
                    }
                }
                let trimmed = head.trim_end();
                if let Some(before_colon) = trimmed.strip_suffix(':') {
                    // `name: HashMap<..>` annotation — the colon must
                    // directly precede the type, so return positions
                    // like `(x: u32) -> HashMap<..>` don't mis-bind
                    if !before_colon.ends_with(':') {
                        if let Some(name) = trailing_ident(before_colon) {
                            names.insert(name.to_string());
                        }
                    }
                } else if let Some(before_eq) = trimmed.strip_suffix('=') {
                    // `let [mut] name = HashMap::new()` initializer
                    if before_eq.contains("let ") {
                        if let Some(name) = trailing_ident(before_eq) {
                            names.insert(name.to_string());
                        }
                    }
                }
            }
        }
    }
    names
}

/// Occurrences of `name.method(` with a word boundary before `name`.
fn calls_method(code: &str, name: &str, method: &str) -> bool {
    let needle = format!("{name}.{method}(");
    let mut from = 0;
    while let Some(rel) = code[from..].find(&needle) {
        let at = from + rel;
        let boundary = at == 0 || !is_ident_char(code[..at].chars().next_back().unwrap_or(' '));
        if boundary {
            return true;
        }
        from = at + needle.len();
    }
    false
}

/// True when the line iterates `name` via `for .. in [&[mut]] name`.
fn for_iterates(code: &str, name: &str) -> bool {
    let mut from = 0;
    while let Some(rel) = code[from..].find(" in ") {
        let at = from + rel;
        from = at + 4;
        let rest = code[at + 4..].trim_start().trim_start_matches("&mut ").trim_start_matches('&');
        let ident: String = rest.chars().take_while(|&c| is_ident_char(c)).collect();
        if ident == name {
            // `for k in name`, `in name {`, `in name.x` — only flag
            // direct iteration, not field access like `name.len()`
            let after = &rest[ident.len()..];
            if !after.starts_with('.') {
                return true;
            }
        }
    }
    false
}

/// Escape check: `mark` on the flagged line or the one above (checked
/// on raw text — the marker lives in a comment).
fn escaped_by(lines: &[LineInfo], idx: usize, mark: &str) -> bool {
    lines[idx].raw.contains(mark) || (idx > 0 && lines[idx - 1].raw.contains(mark))
}

/// The map-iteration escape.
fn escaped(lines: &[LineInfo], idx: usize) -> bool {
    escaped_by(lines, idx, ESCAPE_MARK)
}

fn in_dirs(relpath: &str, dirs: &[&str]) -> bool {
    let mut components: Vec<&str> = relpath.split('/').collect();
    components.pop(); // the file name itself is not a directory
    components.iter().any(|c| dirs.contains(c))
}

/// Rule 1: unsorted `HashMap`/`HashSet` iteration on flush/merge/
/// report/sketch-admission paths. Returns `(findings, suppressions)`.
fn rule_unsorted_map(relpath: &str, lines: &[LineInfo]) -> (Vec<Finding>, usize) {
    if !in_dirs(relpath, SORTED_DIRS) {
        return (Vec::new(), 0);
    }
    let names = collect_map_names(lines);
    if names.is_empty() {
        return (Vec::new(), 0);
    }
    let mut findings = Vec::new();
    let mut suppressions = 0;
    for (idx, info) in lines.iter().enumerate() {
        if info.in_test {
            continue;
        }
        for name in &names {
            let method_hit = UNORDERED_METHODS
                .iter()
                .copied()
                .find(|&m| calls_method(&info.code, name, m));
            let for_hit = for_iterates(&info.code, name);
            if method_hit.is_none() && !for_hit {
                continue;
            }
            if escaped(lines, idx) {
                suppressions += 1;
                continue;
            }
            let how = match method_hit {
                Some(m) => format!("`{name}.{m}()`"),
                None => format!("`for .. in {name}`"),
            };
            findings.push(Finding {
                rule: "unsorted-map-iteration",
                file: relpath.to_string(),
                line: idx + 1,
                message: format!(
                    "{how} iterates a hash map in hasher order on a flush/merge path; \
                     sort before the batch crosses a stage boundary, or mark the site \
                     `// lint: sorted-ok` with a justification"
                ),
                snippet: info.raw.trim().to_string(),
            });
        }
    }
    (findings, suppressions)
}

/// Rule 2: `unwrap()`/`expect()` on transport / rt I/O paths.
fn rule_unwrap_in_io(relpath: &str, lines: &[LineInfo]) -> Vec<Finding> {
    let applies = in_dirs(relpath, &["transport"]) || relpath == "engine/rt.rs";
    if !applies {
        return Vec::new();
    }
    let mut findings = Vec::new();
    for (idx, info) in lines.iter().enumerate() {
        if info.in_test {
            continue;
        }
        // joining a thread that can only die by panicking is the one
        // place propagating the panic is the right move
        if info.code.contains(".join()") {
            continue;
        }
        let hit = if info.code.contains(".unwrap()") {
            Some("unwrap()")
        } else if info.code.contains(".expect(") {
            Some("expect(..)")
        } else {
            None
        };
        if let Some(what) = hit {
            findings.push(Finding {
                rule: "unwrap-in-io",
                file: relpath.to_string(),
                line: idx + 1,
                message: format!(
                    "`{what}` on an I/O path panics the lane instead of degrading; \
                     propagate through `LaneError`/`io::Result` so peers see a clean close"
                ),
                snippet: info.raw.trim().to_string(),
            });
        }
    }
    findings
}

/// Rule 3: `Ordering::Relaxed` on credit-protocol atomics.
fn rule_relaxed_credit(relpath: &str, lines: &[LineInfo]) -> Vec<Finding> {
    if !in_dirs(relpath, &["transport"]) {
        return Vec::new();
    }
    let mut findings = Vec::new();
    for (idx, info) in lines.iter().enumerate() {
        if info.in_test || !info.code.contains("Ordering::Relaxed") {
            continue;
        }
        let lower = info.code.to_lowercase();
        if let Some(word) = CREDIT_WORDS.iter().copied().find(|&w| lower.contains(w)) {
            findings.push(Finding {
                rule: "relaxed-credit-atomic",
                file: relpath.to_string(),
                line: idx + 1,
                message: format!(
                    "`Ordering::Relaxed` on a {word}-protocol atomic: grant/ack pairs \
                     must be Acquire/Release so the window open cannot reorder past the \
                     work it accounts for"
                ),
                snippet: info.raw.trim().to_string(),
            });
        }
    }
    findings
}

/// Rule 4: raw `SystemTime::now()` outside the shared `Clock`.
fn rule_raw_clock(relpath: &str, lines: &[LineInfo]) -> Vec<Finding> {
    // transport/mod.rs is where Clock wraps the system clock
    if relpath == "transport/mod.rs" {
        return Vec::new();
    }
    let mut findings = Vec::new();
    for (idx, info) in lines.iter().enumerate() {
        if info.in_test || !info.code.contains("SystemTime::now") {
            continue;
        }
        findings.push(Finding {
            rule: "raw-clock",
            file: relpath.to_string(),
            line: idx + 1,
            message: "raw `SystemTime::now()` bypasses the shared `transport::Clock`; \
                      cross-process timestamps must come from one epoch"
                .to_string(),
            snippet: info.raw.trim().to_string(),
        });
    }
    findings
}

/// Rule 5: wire-frame `match`es must not have a bare `_` arm — a new
/// frame kind must be classified explicitly at every decode site.
fn rule_frame_exhaustive(relpath: &str, lines: &[LineInfo]) -> Vec<Finding> {
    let mut findings = Vec::new();
    let frame_marker = "Frame::";
    for (start, info) in lines.iter().enumerate() {
        if info.in_test || !has_match_keyword(&info.code) {
            continue;
        }
        // walk the block by brace balance, starting at the match's `{`
        let mut depth = 0i64;
        let mut opened = false;
        let mut mentions_frame = false;
        let mut wildcard_at: Option<usize> = None;
        let mut idx = start;
        while idx < lines.len() {
            let code = &lines[idx].code;
            let scan_from = if idx == start {
                code.find("match").map(|p| p + 5).unwrap_or(0)
            } else {
                0
            };
            if code.contains(frame_marker) {
                mentions_frame = true;
            }
            if let Some(arrow) = code.find("=>") {
                if code[..arrow].trim() == "_" {
                    wildcard_at.get_or_insert(idx);
                }
            }
            for c in code[scan_from..].chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            idx += 1;
        }
        if mentions_frame {
            if let Some(w) = wildcard_at {
                findings.push(Finding {
                    rule: "frame-exhaustive",
                    file: relpath.to_string(),
                    line: w + 1,
                    message: "bare `_` arm in a wire-frame `match` silently swallows \
                              future frame kinds; enumerate every `Frame` variant (an \
                              explicit error arm is fine)"
                        .to_string(),
                    snippet: lines[w].raw.trim().to_string(),
                });
            }
        }
    }
    findings
}

/// Rule 5, second face: every `FlushMsg` literal must name its `seq`
/// field explicitly. A construction that hides it behind `..` (struct
/// update) ships a silently-defaulted sequence number, and the shard
/// sequencer will dedup or park the batch — exactly-once breaks
/// without any error. Same rule id as the `match` face: both guard
/// the flush frame's contract.
fn rule_flush_seq(relpath: &str, lines: &[LineInfo]) -> Vec<Finding> {
    let mut findings = Vec::new();
    for (start, info) in lines.iter().enumerate() {
        if info.in_test {
            continue;
        }
        // find a literal `FlushMsg {` on this line: skip type positions
        // (declaration, impl header, return type, annotations)
        let mut at = None;
        let mut search = 0;
        while let Some(rel) = find_token(&info.code[search..], "FlushMsg") {
            let site = search + rel;
            search = site + "FlushMsg".len();
            let before = info.code[..site].trim_end();
            if before.ends_with("->")
                || trailing_ident(before) == Some("struct")
                || trailing_ident(before) == Some("impl")
            {
                continue;
            }
            if info.code[search..].trim_start().starts_with('{') {
                at = Some(site);
                break;
            }
        }
        let Some(at) = at else { continue };
        // walk the literal's braces collecting its body text
        let mut depth = 0i64;
        let mut body = String::new();
        let mut idx = start;
        let mut from = at;
        'walk: while idx < lines.len() {
            for ch in lines[idx].code[from..].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        if depth > 1 {
                            body.push(ch);
                        }
                    }
                    '}' => {
                        depth -= 1;
                        if depth == 0 {
                            break 'walk;
                        }
                        body.push(ch);
                    }
                    _ => {
                        if depth >= 1 {
                            body.push(ch);
                        }
                    }
                }
            }
            body.push(' ');
            idx += 1;
            from = 0;
            if idx >= lines.len() {
                break;
            }
        }
        if find_token(&body, "seq").is_none() {
            findings.push(Finding {
                rule: "frame-exhaustive",
                file: relpath.to_string(),
                line: start + 1,
                message: "`FlushMsg` construction without an explicit `seq` field — a \
                          defaulted sequence number breaks exactly-once dedup at the \
                          shard sequencer; name `seq` even when it is 0"
                    .to_string(),
                snippet: lines[start].raw.trim().to_string(),
            });
        }
    }
    findings
}

/// Rule 6: no raw clock reads inside the observability layer. The
/// recorder is clock-agnostic by contract — timestamps are passed in
/// by the engines (virtual ticks in sim, `transport::Clock` epoch
/// nanoseconds in rt/deploy). An `Instant::now()` hiding inside
/// `obs/` would silently break sim trace determinism and
/// cross-process timeline alignment.
fn rule_obs_clock(relpath: &str, lines: &[LineInfo]) -> Vec<Finding> {
    if !in_dirs(relpath, &["obs"]) {
        return Vec::new();
    }
    let mut findings = Vec::new();
    for (idx, info) in lines.iter().enumerate() {
        if info.in_test {
            continue;
        }
        let hit = if info.code.contains("Instant::now") {
            Some("Instant::now()")
        } else if info.code.contains("SystemTime::now") {
            Some("SystemTime::now()")
        } else {
            None
        };
        if let Some(what) = hit {
            findings.push(Finding {
                rule: "obs-clock",
                file: relpath.to_string(),
                line: idx + 1,
                message: format!(
                    "`{what}` inside the tracing layer: `obs` never reads a clock — \
                     take the timestamp as a parameter from the engine (virtual ticks \
                     in sim, `transport::Clock` in rt/deploy) so traces stay \
                     deterministic and cross-process timelines align"
                ),
                snippet: info.raw.trim().to_string(),
            });
        }
    }
    findings
}

/// True when `code` declares one of the hot-path functions: the
/// `fn` keyword directly followed by a [`HOT_FNS`] name and then `(`
/// or `<`. Call sites (`self.absorb(..)`) and longer identifiers
/// (`absorb_flush`) don't match.
fn hot_fn_decl(code: &str) -> bool {
    for &name in HOT_FNS {
        let mut from = 0;
        while let Some(rel) = code[from..].find(name) {
            let at = from + rel;
            from = at + name.len();
            let before_ok =
                at == 0 || !is_ident_char(code[..at].chars().next_back().unwrap_or(' '));
            let after = code[at + name.len()..].chars().next();
            if !before_ok || !matches!(after, Some('(') | Some('<')) {
                continue;
            }
            let head = code[..at].trim_end();
            if head.ends_with("fn")
                && !head[..head.len() - 2].chars().next_back().is_some_and(is_ident_char)
            {
                return true;
            }
        }
    }
    false
}

/// Mark the lines belonging to hot-function bodies, by the same
/// brace-balance walk [`preprocess`] uses for `#[cfg(test)]` regions:
/// a hot signature arms `pending`; its opening `{` starts the region,
/// which ends when depth returns to the level before that brace. A
/// bodyless trait declaration (`fn absorb(..);`) has nothing to scan
/// and disarms.
fn mark_hot_fn_regions(lines: &[LineInfo]) -> Vec<bool> {
    let mut hot = vec![false; lines.len()];
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut until: Option<i64> = None;
    for (idx, info) in lines.iter().enumerate() {
        let code = &info.code;
        if until.is_none() && hot_fn_decl(code) {
            pending = true;
        }
        if pending && until.is_none() {
            match (code.find('{'), code.find(';')) {
                (None, Some(_)) => pending = false,
                (Some(b), Some(s)) if s < b => pending = false,
                (Some(_), _) => {
                    until = Some(depth);
                    pending = false;
                    hot[idx] = true;
                }
                (None, None) => hot[idx] = true,
            }
        } else {
            hot[idx] = until.is_some();
        }
        depth += code.matches('{').count() as i64;
        depth -= code.matches('}').count() as i64;
        if let Some(d) = until {
            if depth <= d {
                until = None;
            }
        }
    }
    hot
}

/// Rule 7: no hidden allocation inside the routing/absorb hot path.
/// `route_batch` and the absorb family run once per batch at full
/// rate — at millions of tuples per second, a `String` clone or a
/// fresh `Vec`/`HashMap` per call turns the allocator into the
/// bottleneck (the ROADMAP "allocation-free hot path" item). Scoped
/// to `coordinator/` and `aggregate/`, the dirs that own those entry
/// points. Escape hatch: `// lint: alloc-ok` for genuinely amortized
/// sites (e.g. a once-per-window pane open). Returns
/// `(findings, suppressions)`.
fn rule_hotpath_alloc(relpath: &str, lines: &[LineInfo]) -> (Vec<Finding>, usize) {
    if !in_dirs(relpath, HOT_DIRS) {
        return (Vec::new(), 0);
    }
    let hot = mark_hot_fn_regions(lines);
    let mut findings = Vec::new();
    let mut suppressions = 0;
    for (idx, info) in lines.iter().enumerate() {
        if info.in_test || !hot[idx] {
            continue;
        }
        let Some(&(token, what)) = ALLOC_TOKENS.iter().find(|(t, _)| info.code.contains(t))
        else {
            continue;
        };
        if escaped_by(lines, idx, ALLOC_MARK) {
            suppressions += 1;
            continue;
        }
        findings.push(Finding {
            rule: "hotpath-alloc",
            file: relpath.to_string(),
            line: idx + 1,
            message: format!(
                "`{token}` — {what} inside a hot-path function \
                 (route_batch/absorb family): this runs once per batch at full \
                 rate, so allocator traffic dominates; hoist the allocation out \
                 of the per-batch path or reuse a buffer, or mark the line \
                 `// lint: alloc-ok` with a justification if it is amortized"
            ),
            snippet: info.raw.trim().to_string(),
        });
    }
    (findings, suppressions)
}

/// Rule 8: `ShardSnapshot` constructions and destructurings must name
/// every field. A `..` rest pattern (or `..base` struct update) in a
/// snapshot literal means a newly added piece of shard state compiles
/// clean while silently skipping serialization — exactly the failure
/// class the `FlushMsg` seq rule catches on the wire, applied to the
/// recovery path. No escape hatch.
fn rule_snapshot_exhaustive(relpath: &str, lines: &[LineInfo]) -> Vec<Finding> {
    let mut findings = Vec::new();
    for (start, info) in lines.iter().enumerate() {
        if info.in_test {
            continue;
        }
        // find `ShardSnapshot {` on this line, skipping type positions
        let mut at = None;
        let mut search = 0;
        while let Some(rel) = find_token(&info.code[search..], "ShardSnapshot") {
            let site = search + rel;
            search = site + "ShardSnapshot".len();
            let before = info.code[..site].trim_end();
            if before.ends_with("->")
                || trailing_ident(before) == Some("struct")
                || trailing_ident(before) == Some("impl")
            {
                continue;
            }
            if info.code[search..].trim_start().starts_with('{') {
                at = Some(site);
                break;
            }
        }
        let Some(at) = at else { continue };
        // collect only the literal's top-level body: nested blocks
        // become spaces so a range inside a nested expression can't
        // look like a rest pattern
        let mut depth = 0i64;
        let mut body = String::new();
        let mut idx = start;
        let mut from = at;
        'walk: while idx < lines.len() {
            for ch in lines[idx].code[from..].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        if depth > 1 {
                            body.push(' ');
                        }
                    }
                    '}' => {
                        depth -= 1;
                        if depth == 0 {
                            break 'walk;
                        }
                        body.push(' ');
                    }
                    c => {
                        if depth == 1 {
                            body.push(c);
                        }
                    }
                }
            }
            body.push(' ');
            idx += 1;
            from = 0;
        }
        // a rest pattern / struct update is `..` at the start of the
        // body or right after a field separator; ranges like `0..n`
        // have a value character before them
        let chars: Vec<char> = body.chars().collect();
        let mut hidden = false;
        let mut i = 0;
        while i + 1 < chars.len() {
            if chars[i] == '.' && chars[i + 1] == '.' {
                let prev = chars[..i].iter().rev().find(|c| !c.is_whitespace());
                if matches!(prev, None | Some(',')) {
                    hidden = true;
                    break;
                }
                i += 2;
            } else {
                i += 1;
            }
        }
        if hidden {
            findings.push(Finding {
                rule: "snapshot-exhaustive",
                file: relpath.to_string(),
                line: start + 1,
                message: "`ShardSnapshot` with fields hidden behind `..` — a newly \
                          added piece of shard state would compile clean while \
                          silently skipping serialization and recovery; name every \
                          field so adding one forces this site to be revisited"
                    .to_string(),
                snippet: lines[start].raw.trim().to_string(),
            });
        }
    }
    findings
}

/// Byte offset of `word` in `code` as a standalone identifier (not a
/// substring of a longer one), if present.
fn find_token(code: &str, word: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(rel) = code[from..].find(word) {
        let at = from + rel;
        from = at + word.len();
        let before_ok =
            at == 0 || !is_ident_char(code[..at].chars().next_back().unwrap_or(' '));
        let after_ok = !code[at + word.len()..].chars().next().is_some_and(is_ident_char);
        if before_ok && after_ok {
            return Some(at);
        }
    }
    None
}

/// `match` as a keyword (not `matches!`, not inside an identifier).
fn has_match_keyword(code: &str) -> bool {
    let mut from = 0;
    while let Some(rel) = code[from..].find("match") {
        let at = from + rel;
        from = at + 5;
        let before_ok =
            at == 0 || !is_ident_char(code[..at].chars().next_back().unwrap_or(' '));
        let after = code[at + 5..].chars().next();
        let after_ok = matches!(after, Some(c) if c.is_whitespace() || c == '(');
        if before_ok && after_ok {
            return true;
        }
    }
    false
}

/// Lint one file's source. `relpath` is the `/`-separated path
/// relative to the linted root (it selects which rules apply).
/// Returns the findings plus the number of suppressed map-iteration
/// findings.
pub fn lint_source(relpath: &str, text: &str) -> (Vec<Finding>, usize) {
    let lines = preprocess(text);
    let (mut findings, mut suppressions) = rule_unsorted_map(relpath, &lines);
    let (alloc_findings, alloc_suppressions) = rule_hotpath_alloc(relpath, &lines);
    findings.extend(alloc_findings);
    suppressions += alloc_suppressions;
    findings.extend(rule_unwrap_in_io(relpath, &lines));
    findings.extend(rule_relaxed_credit(relpath, &lines));
    findings.extend(rule_raw_clock(relpath, &lines));
    findings.extend(rule_obs_clock(relpath, &lines));
    findings.extend(rule_frame_exhaustive(relpath, &lines));
    findings.extend(rule_flush_seq(relpath, &lines));
    findings.extend(rule_snapshot_exhaustive(relpath, &lines));
    (findings, suppressions)
}

/// Lint every `.rs` file under `root` (recursively, deterministic
/// order).
pub fn lint_tree(root: &Path) -> io::Result<LintReport> {
    let mut files = Vec::new();
    collect_rs_files(root, root, &mut files)?;
    files.sort();
    let mut report = LintReport::default();
    for rel in files {
        let text = std::fs::read_to_string(root.join(&rel))?;
        let rel_slash = rel.replace('\\', "/");
        let (findings, suppressions) = lint_source(&rel_slash, &text);
        report.findings.extend(findings);
        report.suppressions += suppressions;
        report.files_scanned += 1;
    }
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(report)
}

fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<String>) -> io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            if let Ok(rel) = path.strip_prefix(root) {
                out.push(rel.to_string_lossy().into_owned());
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings_for(relpath: &str, src: &str) -> Vec<Finding> {
        lint_source(relpath, src).0
    }

    #[test]
    fn unsorted_drain_on_flush_path_is_flagged() {
        let src = "use std::collections::HashMap;\n\
                   pub struct P { state: HashMap<u64, u64> }\n\
                   impl P {\n\
                       pub fn flush(&mut self) -> Vec<(u64, u64)> {\n\
                           self.state.drain().collect()\n\
                       }\n\
                   }\n";
        let f = findings_for("aggregate/bad.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "unsorted-map-iteration");
        assert_eq!(f[0].line, 5);
        // same file outside the allowlisted dirs: no finding
        assert!(findings_for("engine/ok.rs", src).is_empty());
    }

    #[test]
    fn sorted_ok_escape_waives_and_counts() {
        let src = "use std::collections::HashMap;\n\
                   pub struct P { state: HashMap<u64, u64> }\n\
                   impl P {\n\
                       pub fn flush(&mut self) -> Vec<(u64, u64)> {\n\
                           // sorted on the next line. lint: sorted-ok\n\
                           let mut v: Vec<_> = self.state.drain().collect();\n\
                           v.sort_unstable();\n\
                           v\n\
                       }\n\
                   }\n";
        let (f, suppressed) = lint_source("aggregate/ok.rs", src);
        assert!(f.is_empty(), "{f:?}");
        assert_eq!(suppressed, 1);
    }

    #[test]
    fn entry_and_get_are_not_iteration() {
        let src = "use std::collections::HashMap;\n\
                   pub struct P { state: HashMap<u64, u64> }\n\
                   impl P {\n\
                       pub fn bump(&mut self, k: u64) {\n\
                           *self.state.entry(k).or_insert(0) += 1;\n\
                           let _ = self.state.get(&k);\n\
                           let _ = self.state.len();\n\
                       }\n\
                   }\n";
        assert!(findings_for("aggregate/ok.rs", src).is_empty());
    }

    #[test]
    fn for_loop_over_map_is_flagged_but_vec_is_not() {
        let src = "use std::collections::HashMap;\n\
                   fn f() {\n\
                       let mut m: HashMap<u64, u64> = HashMap::new();\n\
                       m.insert(1, 2);\n\
                       let v = vec![1u64];\n\
                       for x in &v { let _ = x; }\n\
                       for (k, c) in &m { let _ = (k, c); }\n\
                   }\n";
        let f = findings_for("sketch/bad.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 7);
    }

    #[test]
    fn unwrap_rule_scopes_to_transport_and_rt() {
        let src = "fn f(x: std::io::Result<u8>) -> u8 { x.unwrap() }\n";
        assert_eq!(findings_for("transport/x.rs", src).len(), 1);
        assert_eq!(findings_for("engine/rt.rs", src).len(), 1);
        assert!(findings_for("engine/sim.rs", src).is_empty());
        // join lines are exempt: a panicking thread must propagate
        let join = "fn g(h: std::thread::JoinHandle<u8>) -> u8 { h.join().unwrap() }\n";
        assert!(findings_for("transport/x.rs", join).is_empty());
        // unwrap_or is not unwrap
        let or = "fn h(x: Option<u8>) -> u8 { x.unwrap_or(0) }\n";
        assert!(findings_for("transport/x.rs", or).is_empty());
    }

    #[test]
    fn relaxed_rule_needs_a_credit_word() {
        let bad = "fn f(c: &std::sync::atomic::AtomicUsize) {\n\
                       c.fetch_add(1, Ordering::Relaxed); // credit grant\n\
                   }\n";
        // the comment is stripped, so make the identifier carry the word
        let bad = bad.replace("(c:", "(credit:").replace("c.fetch_add", "credit.fetch_add");
        assert_eq!(findings_for("transport/x.rs", &bad).len(), 1);
        let benign = "static SEQ: std::sync::atomic::AtomicU64 =\n\
                      std::sync::atomic::AtomicU64::new(0);\n\
                      fn f() { SEQ.fetch_add(1, Ordering::Relaxed); }\n";
        assert!(findings_for("transport/x.rs", benign).is_empty());
        // rule is scoped to transport/
        assert!(findings_for("engine/rt.rs", &bad).is_empty());
    }

    #[test]
    fn raw_clock_allowed_only_in_clock_home() {
        let src = "fn now() -> std::time::SystemTime { std::time::SystemTime::now() }\n";
        assert_eq!(findings_for("engine/sim.rs", src).len(), 1);
        assert!(findings_for("transport/mod.rs", src).is_empty());
    }

    #[test]
    fn obs_clock_rule_scopes_to_obs() {
        let src = "fn stamp() -> std::time::Instant { std::time::Instant::now() }\n";
        let f = findings_for("obs/mod.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "obs-clock");
        assert_eq!(f[0].line, 1);
        // Instant::now elsewhere is allowed (wall timing in main, benches)
        assert!(findings_for("engine/rt.rs", src).is_empty());
        // SystemTime in obs/ trips this rule *and* raw-clock: both contracts hold
        let st = "fn t() { let _ = std::time::SystemTime::now(); }\n";
        let f = findings_for("obs/sample.rs", st);
        assert!(f.iter().any(|x| x.rule == "obs-clock"), "{f:?}");
        assert!(f.iter().any(|x| x.rule == "raw-clock"), "{f:?}");
        // test regions are exempt, comments are stripped
        let test_src = "// Instant::now() discussed in a comment\n\
                        #[cfg(test)]\n\
                        mod tests {\n\
                            fn g() { let _ = std::time::Instant::now(); }\n\
                        }\n";
        assert!(findings_for("obs/mod.rs", test_src).is_empty());
    }

    #[test]
    fn frame_match_with_wildcard_is_flagged() {
        let bad = "fn f(frame: &Frame) -> usize {\n\
                       match frame {\n\
                           Frame::Data(m) => m.len(),\n\
                           _ => 0,\n\
                       }\n\
                   }\n";
        let f = findings_for("transport/x.rs", bad);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "frame-exhaustive");
        assert_eq!(f[0].line, 4);
        // an explicit catch arm (`other =>`, `Some(_) =>`) is fine
        let ok = bad.replace("_ =>", "other =>");
        assert!(findings_for("transport/x.rs", &ok).is_empty());
        // wildcard in a frameless match is fine
        let frameless = "fn g(x: u8) -> u8 { match x { 1 => 2, _ => 0 } }\n";
        assert!(findings_for("transport/x.rs", frameless).is_empty());
    }

    #[test]
    fn flush_literal_hiding_seq_behind_struct_update_is_flagged() {
        let bad = "fn f(w: usize) -> FlushMsg {\n\
                       FlushMsg { worker: w, emit_ns: 1, watermark: 2, panes: vec![], \
                       ..Default::default() }\n\
                   }\n";
        let f = findings_for("engine/rt.rs", bad);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "frame-exhaustive");
        assert_eq!(f[0].line, 2);

        // naming seq — explicitly or via shorthand — is the fix
        let explicit = "fn f(w: usize, seq: u64) -> FlushMsg {\n\
                            FlushMsg { worker: w, seq, emit_ns: 1, watermark: 2, \
                            panes: vec![] }\n\
                        }\n";
        assert!(findings_for("engine/rt.rs", explicit).is_empty());

        // multi-line literals are walked to their closing brace
        let multi = "fn f(w: usize) -> FlushMsg {\n\
                         FlushMsg {\n\
                             worker: w,\n\
                             seq: 0,\n\
                             emit_ns: 1,\n\
                             watermark: 2,\n\
                             panes: vec![],\n\
                         }\n\
                     }\n";
        assert!(findings_for("engine/rt.rs", multi).is_empty());
        let multi_bad = multi.replace("seq: 0,\n", "");
        let f = findings_for("engine/rt.rs", &multi_bad);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 2);

        // type positions are not construction sites
        let types = "struct FlushMsg { seq_hidden: u64 }\n\
                     impl FlushMsg { fn n(&self) -> u64 { 0 } }\n\
                     fn g(m: FlushMsg) -> usize { m.panes.len() }\n";
        assert!(findings_for("transport/wire.rs", types).is_empty());

        // `seqs` is not `seq`; a literal after a type annotation on the
        // same line is still checked
        let annotated = "fn h(seqs: &[u64]) {\n\
                             let m: FlushMsg = FlushMsg { worker: 0, emit_ns: 1, \
                             watermark: 2, panes: vec![], ..base(seqs) };\n\
                             drop(m);\n\
                         }\n";
        let f = findings_for("engine/sim.rs", annotated);
        assert_eq!(f.len(), 1, "{f:?}");
    }

    #[test]
    fn comments_strings_and_test_regions_are_ignored() {
        let src = "// SystemTime::now() in a comment\n\
                   fn f() -> &'static str { \"SystemTime::now()\" }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn g() { let _ = std::time::SystemTime::now(); }\n\
                   }\n";
        assert!(findings_for("engine/sim.rs", src).is_empty());
    }

    #[test]
    fn hotpath_alloc_flags_only_hot_fn_bodies_in_hot_dirs() {
        let src = "fn setup() -> Vec<u64> { (0..4).collect() }\n\
                   fn absorb(&mut self, batch: &[u64]) {\n\
                       let tag = batch.len().to_string();\n\
                       drop(tag);\n\
                   }\n\
                   fn report_line(&self) -> String { format!(\"ok\") }\n";
        let f = findings_for("aggregate/x.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "hotpath-alloc");
        assert_eq!(f[0].line, 3);
        // the same source outside the hot dirs is not scanned
        assert!(findings_for("engine/x.rs", src).is_empty());
    }

    #[test]
    fn alloc_ok_escape_waives_and_counts() {
        let src = "fn route_batch(&mut self, batch: &[u64]) {\n\
                       // pane open: once per window, amortized. lint: alloc-ok\n\
                       let fresh: Vec<u64> = Vec::new();\n\
                       drop(fresh);\n\
                   }\n";
        let (findings, suppressions) = lint_source("coordinator/x.rs", src);
        assert!(findings.is_empty(), "{findings:?}");
        assert_eq!(suppressions, 1);
        // without the marker the same line is a finding
        let bare = src.replace(" lint: alloc-ok", "");
        let (findings, suppressions) = lint_source("coordinator/x.rs", &bare);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].rule, "hotpath-alloc");
        assert_eq!(suppressions, 0);
    }

    #[test]
    fn bodyless_trait_absorb_decl_does_not_open_a_hot_region() {
        let src = "trait Sink {\n\
                       fn absorb(&mut self, batch: &[u64]);\n\
                   }\n\
                   fn cold() -> Vec<u64> { Vec::new() }\n";
        assert!(findings_for("aggregate/x.rs", src).is_empty());
        // call sites and longer identifiers are not declarations
        let calls = "fn drive(&mut self) {\n\
                         self.inner.absorb(&[1]);\n\
                         let v: Vec<u64> = Vec::new();\n\
                         drop(v);\n\
                     }\n\
                     fn absorb_flush_cold() -> Vec<u64> { Vec::new() }\n";
        assert!(findings_for("aggregate/x.rs", calls).is_empty());
    }

    #[test]
    fn snapshot_literal_hiding_fields_is_flagged() {
        let bad = "fn f(base: ShardSnapshot) -> ShardSnapshot {\n\
                       ShardSnapshot { shard: 0, ..base }\n\
                   }\n";
        let f = findings_for("state/x.rs", bad);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "snapshot-exhaustive");
        assert_eq!(f[0].line, 2);

        // a destructuring rest pattern is the same hazard
        let pat = "fn g(s: ShardSnapshot) -> usize {\n\
                       let ShardSnapshot { expected_seq, .. } = s;\n\
                       expected_seq.len()\n\
                   }\n";
        let f = findings_for("engine/x.rs", pat);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "snapshot-exhaustive");

        // naming every field is clean; a range in a field value is not
        // a rest pattern; type positions are skipped
        let ok = "struct ShardSnapshot { shard: u64, expected_seq: Vec<u64> }\n\
                  impl ShardSnapshot { fn n(&self) -> u64 { self.shard } }\n\
                  fn h(xs: &[u64]) -> ShardSnapshot {\n\
                      ShardSnapshot { shard: xs[0], expected_seq: xs[1..].to_vec() }\n\
                  }\n";
        assert!(findings_for("state/x.rs", ok).is_empty());
    }

    #[test]
    fn report_json_is_well_formed() {
        let report = LintReport {
            findings: vec![Finding {
                rule: "raw-clock",
                file: "a/b.rs".into(),
                line: 3,
                message: "say \"no\"".into(),
                snippet: "x".into(),
            }],
            files_scanned: 2,
            suppressions: 0,
        };
        let json = report.to_json();
        assert!(json.contains("\"files_scanned\":2"));
        assert!(json.contains("\\\"no\\\""));
        assert!(json.starts_with('{') && json.ends_with('}'));
    }
}
