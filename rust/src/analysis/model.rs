//! Explicit-state model-checking framework for the repo's protocols.
//!
//! The checker grew out of the single-purpose credit-flow model: the
//! exploration engine (BFS over every reachable interleaving, invariant
//! checks on every generated state, counterexample traces, deterministic
//! stats) is protocol-agnostic, so it now lives here behind the
//! [`Protocol`] trait and the protocols plug in:
//!
//! * [`crate::analysis::credit`] — the credit-based flow control the
//!   socket and loopback lanes implement (grant/consume/ack with
//!   half-window quanta, flush-all-credits-before-blocking);
//! * [`crate::analysis::recovery`] — the exactly-once flush/recovery
//!   protocol (`FlushSequencer` dedup cursors, snapshot-every-K
//!   persistence, crash + `Resume` + replay), built directly on the
//!   production cursor/restore rules so model and code cannot drift.
//!
//! A protocol supplies its state type, initial state, enabled
//! transitions (each with a human-readable label), state invariants and
//! a quiescence predicate. Within the bounded configuration the checker
//! proves:
//!
//! * **safety** — every reachable state satisfies every invariant;
//! * **liveness-to-quiescence** — no reachable state is stuck: a state
//!   with no enabled transition must be quiescent
//!   ([`Protocol::is_final`]), otherwise it is a
//!   [`Violation::Deadlock`];
//! * **termination** (optional) — the transition graph is acyclic, so
//!   every run reaches quiescence in finitely many steps
//!   ([`CheckOptions::check_termination`]).
//!
//! Violations come back as a [`Counterexample`]: the shortest trace
//! (BFS ⇒ minimal length) of transition labels from the initial state
//! to the violation, printable as a readable interleaving via
//! [`Counterexample::render`] and re-parseable via
//! [`Counterexample::parse`] (byte-stable round trip — pinned by
//! `rust/tests/recovery_model.rs`).
//!
//! [`ModelStats`] are exploration-order-independent graph properties
//! (reachable states, sum of out-degrees, BFS radius, quiescent-state
//! count), so exact per-config values are pinned in the self-tests: a
//! silently-shrunk state space — a broken enabled-transition guard —
//! fails loudly instead of vacuously passing.

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::hash::Hash;

/// A protocol specified as an explicit-state transition system.
///
/// Implementations must be deterministic: `successors` must push the
/// same labelled transitions in the same order for equal states, and
/// labels must be stable — they are the counterexample vocabulary.
pub trait Protocol {
    /// One global protocol state. `Eq + Hash` give the visited set;
    /// `Clone` lets the checker fan a state out to its successors.
    type State: Clone + Eq + Hash + fmt::Debug;

    /// Protocol name plus bounded-config summary, for reports
    /// (e.g. `credit n=2 window=3 tuples=4 chunk=2`).
    fn name(&self) -> String;

    /// The single initial state.
    fn initial(&self) -> Self::State;

    /// Push every enabled transition from `state` as `(label, next)`.
    /// An empty set means the state is terminal — a deadlock unless
    /// [`Protocol::is_final`] holds.
    fn successors(&self, state: &Self::State, out: &mut Vec<(String, Self::State)>);

    /// Check every state invariant; the first broken property becomes
    /// the counterexample's verdict.
    fn invariants(&self, state: &Self::State) -> Result<(), PropertyViolation>;

    /// Quiescence: the protocol has finished everything it set out to
    /// do. Terminal non-final states are deadlocks; final states may
    /// still have successors (e.g. an unspent crash budget).
    fn is_final(&self, state: &Self::State) -> bool;
}

/// One broken state invariant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PropertyViolation {
    /// Stable property identifier (kebab-case), e.g. `no-lost-flush`.
    pub property: &'static str,
    /// What exactly is wrong in the violating state.
    pub detail: String,
}

/// Why a check failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Violation {
    /// A non-final state with no enabled transition.
    Deadlock,
    /// A reachable state breaks a protocol invariant.
    Property(PropertyViolation),
    /// The transition graph has a cycle — a run exists that never
    /// reaches quiescence (termination check only).
    Cycle,
    /// Exploration hit [`CheckOptions::max_states`] before finishing;
    /// nothing proven either way.
    StateSpaceExceeded {
        /// States explored before giving up.
        explored: u64,
    },
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Violation::Deadlock => {
                write!(f, "deadlock: no enabled transition in a non-quiescent state")
            }
            Violation::Property(p) => {
                write!(f, "property {} violated: {}", p.property, p.detail)
            }
            Violation::Cycle => {
                write!(f, "cycle: a run exists that never reaches quiescence")
            }
            Violation::StateSpaceExceeded { explored } => {
                write!(f, "state space exceeded after {explored} states")
            }
        }
    }
}

impl std::error::Error for Violation {}

/// A violation plus the shortest interleaving that reaches it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Counterexample {
    /// What broke.
    pub violation: Violation,
    /// Transition labels from the initial state to the violating
    /// state, in order. Empty when the initial state itself violates.
    pub trace: Vec<String>,
}

impl Counterexample {
    /// Render as a readable numbered interleaving. The output is
    /// byte-stable (same counterexample ⇒ same bytes) and round-trips
    /// through [`Counterexample::parse`].
    pub fn render(&self) -> String {
        let mut out = format!("counterexample: {}\n", self.violation);
        for (i, step) in self.trace.iter().enumerate() {
            out.push_str(&format!("  {:>3}. {}\n", i + 1, step));
        }
        out
    }

    /// Parse a [`Counterexample::render`]ing back into its parts:
    /// `(violation line, trace labels)`. Returns `None` for anything
    /// that is not a rendered counterexample (wrong header, broken
    /// numbering).
    pub fn parse(text: &str) -> Option<(String, Vec<String>)> {
        let mut lines = text.lines();
        let head = lines.next()?.strip_prefix("counterexample: ")?.to_string();
        let mut trace = Vec::new();
        for line in lines {
            let body = line.trim_start();
            let (num, label) = body.split_once(". ")?;
            if num.parse::<usize>().ok()? != trace.len() + 1 {
                return None;
            }
            trace.push(label.to_string());
        }
        Some((head, trace))
    }
}

impl fmt::Display for Counterexample {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

/// Exploration-order-independent statistics of one exhaustive check.
///
/// All four are graph properties of the reachable transition system —
/// independent of visitation order — so exact values are pinned
/// per-config in the self-tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ModelStats {
    /// Distinct reachable states.
    pub states: u64,
    /// Transitions examined (sum of out-degrees over reachable states;
    /// counts edges into already-visited states too).
    pub transitions: u64,
    /// BFS radius: the longest shortest-path from the initial state.
    pub depth: u64,
    /// Reachable quiescent ([`Protocol::is_final`]) states.
    pub finals: u64,
}

/// Exploration bounds and optional extra proofs.
#[derive(Debug, Clone, Copy)]
pub struct CheckOptions {
    /// Abort with [`Violation::StateSpaceExceeded`] beyond this many
    /// distinct states — a misconfiguration guard, not a soundness
    /// limit (within the bound the search is exhaustive).
    pub max_states: u64,
    /// Additionally prove the transition graph acyclic (every run
    /// terminates). Costs a second full traversal; reserve it for the
    /// smaller configs.
    pub check_termination: bool,
}

impl Default for CheckOptions {
    fn default() -> Self {
        CheckOptions { max_states: 5_000_000, check_termination: false }
    }
}

/// Exhaustively check `protocol` within `opts`.
///
/// Breadth-first over every reachable state: invariants are checked on
/// each state as it is generated (so a reported trace is a
/// shortest-length interleaving), terminal non-final states are
/// deadlocks, and — when requested — a depth-first pass proves the
/// graph acyclic. Fully deterministic: same protocol, same options ⇒
/// same stats and byte-identical counterexample.
pub fn explore<P: Protocol>(
    protocol: &P,
    opts: &CheckOptions,
) -> Result<ModelStats, Counterexample> {
    let init = protocol.initial();
    if let Err(p) = protocol.invariants(&init) {
        return Err(Counterexample { violation: Violation::Property(p), trace: Vec::new() });
    }

    // parent[id] = (parent id, label of the edge in), for trace
    // reconstruction; id 0 is the initial state
    let mut seen: HashMap<P::State, usize> = HashMap::new();
    let mut parent: Vec<(usize, String)> = vec![(usize::MAX, String::new())];
    let mut depth_of: Vec<u64> = vec![0];
    let mut frontier: VecDeque<P::State> = VecDeque::new();

    fn trace_to(parent: &[(usize, String)], mut id: usize) -> Vec<String> {
        let mut steps = Vec::new();
        while id != 0 {
            let (pid, label) = &parent[id];
            steps.push(label.clone());
            id = *pid;
        }
        steps.reverse();
        steps
    }

    let mut stats = ModelStats { states: 1, transitions: 0, depth: 0, finals: 0 };
    if protocol.is_final(&init) {
        stats.finals += 1;
    }
    seen.insert(init.clone(), 0);
    frontier.push_back(init);

    let mut succ: Vec<(String, P::State)> = Vec::new();
    while let Some(state) = frontier.pop_front() {
        let sid = seen[&state];
        succ.clear();
        protocol.successors(&state, &mut succ);
        if succ.is_empty() && !protocol.is_final(&state) {
            return Err(Counterexample {
                violation: Violation::Deadlock,
                trace: trace_to(&parent, sid),
            });
        }
        for (label, next) in succ.drain(..) {
            stats.transitions += 1;
            if let Err(p) = protocol.invariants(&next) {
                let mut trace = trace_to(&parent, sid);
                trace.push(label);
                return Err(Counterexample { violation: Violation::Property(p), trace });
            }
            if !seen.contains_key(&next) {
                let nid = parent.len();
                parent.push((sid, label));
                let d = depth_of[sid] + 1;
                depth_of.push(d);
                stats.depth = stats.depth.max(d);
                if protocol.is_final(&next) {
                    stats.finals += 1;
                }
                stats.states += 1;
                if stats.states > opts.max_states {
                    return Err(Counterexample {
                        violation: Violation::StateSpaceExceeded { explored: stats.states },
                        trace: Vec::new(),
                    });
                }
                seen.insert(next.clone(), nid);
                frontier.push_back(next);
            }
        }
    }

    if opts.check_termination {
        assert_acyclic(protocol)?;
    }
    Ok(stats)
}

/// Prove the reachable transition graph is a DAG by iterative
/// three-color DFS; a back edge yields [`Violation::Cycle`] with the
/// DFS path into the cycle as the trace.
fn assert_acyclic<P: Protocol>(protocol: &P) -> Result<(), Counterexample> {
    #[derive(Clone, Copy, PartialEq)]
    enum Color {
        Grey,
        Black,
    }
    let mut color: HashMap<P::State, Color> = HashMap::new();
    // each frame: (state, its successors, next successor index, label in)
    #[allow(clippy::type_complexity)]
    let mut stack: Vec<(P::State, Vec<(String, P::State)>, usize, String)> = Vec::new();
    let init = protocol.initial();
    let mut succ = Vec::new();
    protocol.successors(&init, &mut succ);
    color.insert(init.clone(), Color::Grey);
    stack.push((init, succ, 0, String::new()));
    while let Some(frame) = stack.last_mut() {
        if frame.2 >= frame.1.len() {
            color.insert(frame.0.clone(), Color::Black);
            stack.pop();
            continue;
        }
        let (label, next) = frame.1[frame.2].clone();
        frame.2 += 1;
        match color.get(&next) {
            Some(Color::Grey) => {
                // back edge: the grey target is on the stack — the
                // trace is the DFS path so far plus the closing edge
                let mut trace: Vec<String> = stack.iter().skip(1).map(|f| f.3.clone()).collect();
                trace.push(label);
                return Err(Counterexample { violation: Violation::Cycle, trace });
            }
            Some(Color::Black) => continue,
            None => {
                let mut succ = Vec::new();
                protocol.successors(&next, &mut succ);
                color.insert(next.clone(), Color::Grey);
                stack.push((next, succ, 0, label));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy protocol: a counter walks 0..=n; invariant `counter <= n`;
    /// final at n. `stuck_at` gives that value no successors;
    /// `loop_at` makes it step to itself; `overflow` walks past n.
    struct Walk {
        n: u32,
        stuck_at: Option<u32>,
        loop_at: Option<u32>,
        overflow: bool,
    }

    impl Protocol for Walk {
        type State = u32;
        fn name(&self) -> String {
            format!("walk n={}", self.n)
        }
        fn initial(&self) -> u32 {
            0
        }
        fn successors(&self, s: &u32, out: &mut Vec<(String, u32)>) {
            if Some(*s) == self.stuck_at {
                return;
            }
            if Some(*s) == self.loop_at {
                out.push((format!("loop at {s}"), *s));
                return;
            }
            let top = if self.overflow { self.n + 1 } else { self.n };
            if *s < top {
                out.push((format!("step to {}", s + 1), s + 1));
            }
        }
        fn invariants(&self, s: &u32) -> Result<(), PropertyViolation> {
            if *s > self.n {
                return Err(PropertyViolation {
                    property: "bounded-counter",
                    detail: format!("counter reached {s}, bound is {}", self.n),
                });
            }
            Ok(())
        }
        fn is_final(&self, s: &u32) -> bool {
            *s == self.n
        }
    }

    fn walk(n: u32) -> Walk {
        Walk { n, stuck_at: None, loop_at: None, overflow: false }
    }

    #[test]
    fn clean_walk_has_pinned_stats() {
        let stats = explore(&walk(5), &CheckOptions::default()).expect("clean");
        assert_eq!(stats, ModelStats { states: 6, transitions: 5, depth: 5, finals: 1 });
        // the termination pass changes nothing on an acyclic graph
        let opts = CheckOptions { check_termination: true, ..Default::default() };
        assert_eq!(explore(&walk(5), &opts).expect("acyclic"), stats);
    }

    #[test]
    fn deadlock_is_reported_with_shortest_trace() {
        let err = explore(&Walk { stuck_at: Some(3), ..walk(5) }, &CheckOptions::default())
            .unwrap_err();
        assert_eq!(err.violation, Violation::Deadlock);
        assert_eq!(err.trace, vec!["step to 1", "step to 2", "step to 3"]);
    }

    #[test]
    fn property_violation_carries_the_edge_that_broke_it() {
        let err =
            explore(&Walk { overflow: true, ..walk(3) }, &CheckOptions::default()).unwrap_err();
        match &err.violation {
            Violation::Property(p) => {
                assert_eq!(p.property, "bounded-counter");
                assert!(p.detail.contains("counter reached 4"), "{}", p.detail);
            }
            v => panic!("expected property violation, got {v:?}"),
        }
        assert_eq!(err.trace.last().map(String::as_str), Some("step to 4"));
    }

    #[test]
    fn cycle_detection_fires_only_under_termination_check() {
        let looping = Walk { loop_at: Some(2), ..walk(5) };
        // plain BFS dedups the self-loop and terminates cleanly: the
        // states past the loop are simply unreachable, never final
        let stats = explore(&looping, &CheckOptions::default()).expect("bfs tolerates loop");
        assert_eq!(stats, ModelStats { states: 3, transitions: 3, depth: 2, finals: 0 });
        // the termination pass proves the non-quiescent run exists
        let opts = CheckOptions { check_termination: true, ..Default::default() };
        let err = explore(&looping, &opts).unwrap_err();
        assert_eq!(err.violation, Violation::Cycle);
        assert_eq!(err.trace.last().map(String::as_str), Some("loop at 2"));
    }

    #[test]
    fn state_space_guard_trips() {
        let opts = CheckOptions { max_states: 3, ..Default::default() };
        let err = explore(&walk(10), &opts).unwrap_err();
        assert!(matches!(err.violation, Violation::StateSpaceExceeded { explored: 4 }));
    }

    #[test]
    fn render_parse_round_trip_is_byte_stable() {
        let ce = Counterexample {
            violation: Violation::Property(PropertyViolation {
                property: "no-lost-flush",
                detail: "shard 0 cursor for worker 1 is 2 but seq 0 was never absorbed".into(),
            }),
            trace: vec![
                "w1 flushes seq 0 to s0".into(),
                "s0 crashes and restores cold".into(),
            ],
        };
        let rendered = ce.render();
        let (head, labels) = Counterexample::parse(&rendered).expect("parses");
        assert_eq!(head, ce.violation.to_string());
        assert_eq!(labels, ce.trace);
        // reassembling from the parsed parts reproduces the exact bytes
        let mut again = format!("counterexample: {head}\n");
        for (i, l) in labels.iter().enumerate() {
            again.push_str(&format!("  {:>3}. {}\n", i + 1, l));
        }
        assert_eq!(again, rendered);
    }

    #[test]
    fn empty_trace_renders_header_only() {
        let ce = Counterexample {
            violation: Violation::StateSpaceExceeded { explored: 11 },
            trace: Vec::new(),
        };
        assert_eq!(ce.render(), "counterexample: state space exceeded after 11 states\n");
        let (head, labels) = Counterexample::parse(&ce.render()).expect("parses");
        assert_eq!(head, ce.violation.to_string());
        assert!(labels.is_empty());
    }
}
