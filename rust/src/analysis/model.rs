//! Explicit-state model checker for the credit-based flow-control
//! protocol the transport lanes implement.
//!
//! The protocol under check (see `transport/socket.rs` and
//! `docs/DETERMINISM.md`):
//!
//! * each sender starts with `window` credits and spends them on
//!   fixed-size data chunks (a chunk is atomic — a sender with credit
//!   left over but less than one chunk is *blocked*, exactly like the
//!   real sender that must ship `opts.chunk` tuples per frame);
//! * the receiver acks consumed tuples in quanta of
//!   `window.max(2) / 2`, returning credit in whole quanta and
//!   holding the sub-quantum remainder;
//! * before the receiver would block waiting for data it **flushes
//!   all owed credit**, remainder included. This is the rule that
//!   makes the protocol deadlock-free — quantized acks alone can
//!   strand up to `quantum - 1` credits while the sender is blocked
//!   needing a full chunk.
//!
//! [`check`] enumerates *every* interleaving of send / deliver /
//! credit-flush / grant-arrival transitions over a bounded
//! configuration (breadth-first over the state graph with a visited
//! set), asserting at each reachable state:
//!
//! * **deadlock freedom** — a state with no enabled transition has
//!   delivered every tuple;
//! * **credit conservation** — per stream, `sender credit + in-flight
//!   data + receiver-owed + grants in flight == window` (no leak, no
//!   double grant);
//! * **no overflow** — sender credit never exceeds the window;
//! * **FIFO delivery** — tuples arrive in sequence order per stream.
//!
//! [`Mutation`] deliberately breaks one protocol rule at a time so
//! tests can prove the checker *detects* each violation class rather
//! than vacuously passing: `rust/tests/credit_model.rs` runs the
//! honest protocol exhaustively and asserts every mutation is caught.
//!
//! The checker is pure `std`, deterministic (fixed exploration order,
//! no time, no randomness) and small: states are a few `u32`s per
//! stream, so bounded configs in the tens of thousands of states
//! check in milliseconds even in debug builds.

use std::collections::{HashSet, VecDeque};
use std::fmt;

/// A bounded protocol configuration to exhaustively check.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Concurrent senders feeding one receiver (streams are
    /// credit-independent; interleavings are shared).
    pub n_senders: usize,
    /// Credit window per stream (the receiver-side queue depth).
    pub window: u32,
    /// Tuples each sender must deliver for the run to terminate.
    pub tuples_per_sender: u32,
    /// Fixed data-chunk size (the final chunk may be smaller). Must
    /// be ≤ `window` or even the honest protocol cannot make progress.
    pub chunk: u32,
    /// Protocol rule to deliberately break ([`Mutation::None`] checks
    /// the honest protocol).
    pub mutation: Mutation,
    /// Abort with [`Violation::StateSpaceExceeded`] past this many
    /// distinct states — a misconfiguration guard, not a soundness
    /// limit (within the bound the search is exhaustive).
    pub max_states: usize,
}

/// A deliberate protocol bug, used to prove the checker catches each
/// violation class (mutation testing for the model itself).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mutation {
    /// The protocol as implemented.
    None,
    /// Receiver never flushes sub-quantum credit remainders before
    /// blocking — the bug class the `flush_all_credits()` rule
    /// prevents. Expected: [`Violation::Deadlock`].
    SkipCreditFlush,
    /// Receiver grants every ack twice. Expected:
    /// [`Violation::CreditLost`] (conservation breaks high) or
    /// [`Violation::CreditOverflow`].
    DoubleGrant,
    /// Receiver drops one credit from every grant. Expected:
    /// [`Violation::CreditLost`] (conservation breaks low).
    DropCredit,
    /// Network delivers the newest in-flight chunk first. Expected:
    /// [`Violation::OutOfOrder`].
    ReorderData,
}

/// Aggregate counts from an exhaustive run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelStats {
    /// Distinct reachable states.
    pub states: usize,
    /// Explored transitions (edges, including ones to already-visited
    /// states).
    pub transitions: usize,
}

/// A protocol property violated in some reachable state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Violation {
    /// No transition enabled, tuples still undelivered.
    Deadlock { state: String },
    /// Per-stream credit accounting no longer sums to the window.
    CreditLost { sender: usize, window: u32, accounted: u32 },
    /// Sender credit exceeds the window.
    CreditOverflow { sender: usize, credit: u32, window: u32 },
    /// A chunk arrived out of sequence order.
    OutOfOrder { sender: usize, expected_seq: u32, got_seq: u32 },
    /// `max_states` exceeded before the frontier emptied.
    StateSpaceExceeded { explored: usize },
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Violation::Deadlock { state } => write!(f, "deadlock: no enabled transition in {state}"),
            Violation::CreditLost { sender, window, accounted } => write!(
                f,
                "credit conservation broken on stream {sender}: window {window}, accounted {accounted}"
            ),
            Violation::CreditOverflow { sender, credit, window } => write!(
                f,
                "credit overflow on stream {sender}: credit {credit} > window {window}"
            ),
            Violation::OutOfOrder { sender, expected_seq, got_seq } => write!(
                f,
                "out-of-order delivery on stream {sender}: expected seq {expected_seq}, got {got_seq}"
            ),
            Violation::StateSpaceExceeded { explored } => {
                write!(f, "state space exceeded the configured bound after {explored} states")
            }
        }
    }
}

impl std::error::Error for Violation {}

/// Per-stream protocol state. Everything is small unsigned counters,
/// so a whole state hashes as a short `Vec<u32>`.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Lane {
    /// Credits the sender may spend.
    credit: u32,
    /// Tuples the sender has not yet put on the wire.
    to_send: u32,
    /// In-flight data chunks: `(size, first_seq)`, FIFO.
    channel: VecDeque<(u32, u32)>,
    /// Next sequence number the receiver expects (== tuples
    /// delivered).
    delivered: u32,
    /// Tuples consumed but not yet acked (credit the receiver owes).
    pending: u32,
    /// Credit grants in flight back to the sender, FIFO.
    grants: VecDeque<u32>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct State {
    lanes: Vec<Lane>,
}

impl State {
    fn initial(cfg: &ModelConfig) -> State {
        State {
            lanes: vec![
                Lane {
                    credit: cfg.window,
                    to_send: cfg.tuples_per_sender,
                    channel: VecDeque::new(),
                    delivered: 0,
                    pending: 0,
                    grants: VecDeque::new(),
                };
                cfg.n_senders
            ],
        }
    }

    /// Canonical hashable encoding.
    fn key(&self) -> Vec<u32> {
        let mut k = Vec::with_capacity(self.lanes.len() * 8);
        for lane in &self.lanes {
            k.push(lane.credit);
            k.push(lane.to_send);
            k.push(lane.delivered);
            k.push(lane.pending);
            k.push(lane.channel.len() as u32);
            for &(size, seq) in &lane.channel {
                k.push(size);
                k.push(seq);
            }
            k.push(lane.grants.len() as u32);
            for &g in &lane.grants {
                k.push(g);
            }
        }
        k
    }

    fn all_delivered(&self, cfg: &ModelConfig) -> bool {
        self.lanes.iter().all(|l| l.delivered == cfg.tuples_per_sender)
    }

    fn describe(&self) -> String {
        let mut s = String::new();
        for (i, lane) in self.lanes.iter().enumerate() {
            if i > 0 {
                s.push_str("; ");
            }
            s.push_str(&format!(
                "stream {i}: credit={} to_send={} inflight={:?} delivered={} pending={} grants={:?}",
                lane.credit, lane.to_send, lane.channel, lane.delivered, lane.pending, lane.grants
            ));
        }
        s
    }

    /// Every state reachable in one transition. Errors on a FIFO
    /// violation observed while delivering.
    fn successors(&self, cfg: &ModelConfig, quantum: u32) -> Result<Vec<State>, Violation> {
        let mut out = Vec::new();
        for i in 0..self.lanes.len() {
            let lane = &self.lanes[i];

            // send: one fixed-size chunk, atomically, if credit covers it
            if lane.to_send > 0 {
                let size = cfg.chunk.min(lane.to_send);
                if lane.credit >= size {
                    let mut next = self.clone();
                    let l = &mut next.lanes[i];
                    let first_seq = cfg.tuples_per_sender - l.to_send;
                    l.credit -= size;
                    l.to_send -= size;
                    l.channel.push_back((size, first_seq));
                    out.push(next);
                }
            }

            // deliver: receiver consumes one in-flight chunk and acks
            // in whole quanta, holding the remainder
            if !lane.channel.is_empty() {
                let mut next = self.clone();
                let l = &mut next.lanes[i];
                let (size, first_seq) = if cfg.mutation == Mutation::ReorderData && l.channel.len() > 1
                {
                    l.channel.pop_back().expect("checked non-empty")
                } else {
                    l.channel.pop_front().expect("checked non-empty")
                };
                if first_seq != l.delivered {
                    return Err(Violation::OutOfOrder {
                        sender: i,
                        expected_seq: l.delivered,
                        got_seq: first_seq,
                    });
                }
                l.delivered += size;
                l.pending += size;
                let quantized = (l.pending / quantum) * quantum;
                if quantized > 0 {
                    l.pending -= quantized;
                    push_grant(l, quantized, cfg.mutation);
                }
                out.push(next);
            }

            // flush: receiver returns ALL owed credit (the
            // before-blocking rule); removed under SkipCreditFlush
            if lane.pending > 0 && cfg.mutation != Mutation::SkipCreditFlush {
                let mut next = self.clone();
                let l = &mut next.lanes[i];
                let owed = l.pending;
                l.pending = 0;
                push_grant(l, owed, cfg.mutation);
                out.push(next);
            }

            // grant arrival: a credit frame reaches the sender
            if !lane.grants.is_empty() {
                let mut next = self.clone();
                let l = &mut next.lanes[i];
                let g = l.grants.pop_front().expect("checked non-empty");
                l.credit += g;
                out.push(next);
            }
        }
        Ok(out)
    }

    fn check_invariants(&self, cfg: &ModelConfig) -> Result<(), Violation> {
        for (i, lane) in self.lanes.iter().enumerate() {
            if lane.credit > cfg.window {
                return Err(Violation::CreditOverflow {
                    sender: i,
                    credit: lane.credit,
                    window: cfg.window,
                });
            }
            let inflight: u32 = lane.channel.iter().map(|&(size, _)| size).sum();
            let grants: u32 = lane.grants.iter().sum();
            let accounted = lane.credit + inflight + lane.pending + grants;
            if accounted != cfg.window {
                return Err(Violation::CreditLost { sender: i, window: cfg.window, accounted });
            }
        }
        Ok(())
    }
}

fn push_grant(lane: &mut Lane, granted: u32, mutation: Mutation) {
    let granted = match mutation {
        Mutation::DoubleGrant => granted * 2,
        Mutation::DropCredit => granted.saturating_sub(1),
        _ => granted,
    };
    if granted > 0 {
        lane.grants.push_back(granted);
    }
}

/// Exhaustively explore every interleaving of `cfg`, checking the
/// protocol invariants at each reachable state. Deterministic: same
/// config, same result, same [`ModelStats`].
pub fn check(cfg: &ModelConfig) -> Result<ModelStats, Violation> {
    assert!(cfg.n_senders > 0, "need at least one sender");
    assert!(cfg.window > 0 && cfg.chunk > 0, "window and chunk must be positive");
    assert!(
        cfg.chunk <= cfg.window,
        "chunk > window cannot make progress even unmutated"
    );
    let quantum = cfg.window.max(2) / 2;
    let init = State::initial(cfg);
    init.check_invariants(cfg)?;
    let mut visited: HashSet<Vec<u32>> = HashSet::new();
    visited.insert(init.key());
    let mut frontier = VecDeque::new();
    frontier.push_back(init);
    let mut stats = ModelStats { states: 1, transitions: 0 };
    while let Some(state) = frontier.pop_front() {
        let successors = state.successors(cfg, quantum)?;
        if successors.is_empty() && !state.all_delivered(cfg) {
            return Err(Violation::Deadlock { state: state.describe() });
        }
        for next in successors {
            stats.transitions += 1;
            next.check_invariants(cfg)?;
            if visited.insert(next.key()) {
                stats.states += 1;
                if stats.states > cfg.max_states {
                    return Err(Violation::StateSpaceExceeded { explored: stats.states });
                }
                frontier.push_back(next);
            }
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n_senders: usize, window: u32, tuples: u32, chunk: u32, mutation: Mutation) -> ModelConfig {
        ModelConfig {
            n_senders,
            window,
            tuples_per_sender: tuples,
            chunk,
            mutation,
            max_states: 2_000_000,
        }
    }

    #[test]
    fn honest_protocol_small_config_passes() {
        let stats = check(&cfg(1, 2, 4, 1, Mutation::None)).expect("honest run");
        assert!(stats.states > 1);
        assert!(stats.transitions >= stats.states - 1);
    }

    #[test]
    fn skip_credit_flush_deadlocks() {
        // window 5, chunk 5: the quantized ack returns 4, stranding 1
        // credit at the receiver while the sender needs a full chunk
        let err = check(&cfg(1, 5, 10, 5, Mutation::SkipCreditFlush)).unwrap_err();
        assert!(matches!(err, Violation::Deadlock { .. }), "{err}");
        // the honest protocol flushes the remainder and completes
        check(&cfg(1, 5, 10, 5, Mutation::None)).expect("flush saves it");
    }

    #[test]
    fn determinism_same_config_same_stats() {
        let a = check(&cfg(2, 3, 4, 2, Mutation::None)).expect("run a");
        let b = check(&cfg(2, 3, 4, 2, Mutation::None)).expect("run b");
        assert_eq!(a, b);
    }

    #[test]
    fn state_space_guard_trips() {
        let mut c = cfg(2, 3, 6, 1, Mutation::None);
        c.max_states = 10;
        let err = check(&c).unwrap_err();
        assert!(matches!(err, Violation::StateSpaceExceeded { .. }), "{err}");
    }
}
