//! Determinism & concurrency analysis suite.
//!
//! FISH's headline guarantee is byte-identical load-balanced results
//! across runs, transports and process topologies — and the two worst
//! bugs this repo has shipped were *nondeterminism* bugs (unsorted
//! `HashMap` drain order corrupting at-capacity SpaceSaving admission;
//! rt flush-cadence drift), a class ordinary tests only catch by luck.
//! This module machine-checks the rules that keep the guarantee:
//!
//! * [`lint`] — a source-level rule engine behind `fish lint`. It
//!   walks `rust/src/` and enforces the repo-specific determinism and
//!   robustness rules written down in `docs/DETERMINISM.md`: no
//!   unsorted `HashMap`/`HashSet` iteration on flush/merge/report/
//!   sketch-admission paths (escape hatch: `// lint: sorted-ok` at
//!   sites that sort immediately or fold order-independently), no
//!   `unwrap()`/`expect()` in transport + rt I/O paths, no
//!   `Ordering::Relaxed` on credit/watermark atomics, no raw
//!   `SystemTime::now()` outside the shared [`crate::transport::Clock`],
//!   and exhaustive `Frame` matches at every decode site.
//! * [`model`] — an explicit-state model checker for the credit-based
//!   flow-control protocol the socket and loopback lanes implement
//!   (grant/consume/ack with half-window quanta and
//!   flush-all-credits-before-blocking). It exhaustively enumerates
//!   bounded interleavings of senders, receiver and credit returns,
//!   asserting deadlock freedom, credit conservation (no leak, no
//!   double grant) and per-stream FIFO delivery — and it detects the
//!   violation when any of those protocol rules is deliberately
//!   broken (see `rust/tests/credit_model.rs`).
//!
//! Everything here is `std`-only and runs offline — the lint engine is
//! a line-oriented analyzer, not a full parser; its rules are written
//! to have zero false positives on idioms this repo actually uses, and
//! it is self-tested against seeded-regression fixtures in
//! `rust/tests/fixtures/lint/`.

pub mod lint;
pub mod model;

pub use lint::{lint_source, lint_tree, Finding, LintReport};
pub use model::{check, Mutation, ModelConfig, ModelStats, Violation};
