//! Determinism & concurrency analysis suite.
//!
//! FISH's headline guarantee is byte-identical load-balanced results
//! across runs, transports and process topologies — and the two worst
//! bugs this repo has shipped were *nondeterminism* bugs (unsorted
//! `HashMap` drain order corrupting at-capacity SpaceSaving admission;
//! rt flush-cadence drift), a class ordinary tests only catch by luck.
//! This module machine-checks the rules that keep the guarantee:
//!
//! * [`lint`] — a source-level rule engine behind `fish lint`. It
//!   walks `rust/src/` and enforces the repo-specific determinism and
//!   robustness rules written down in `docs/DETERMINISM.md`: no
//!   unsorted `HashMap`/`HashSet` iteration on flush/merge/report/
//!   sketch-admission paths (escape hatch: `// lint: sorted-ok` at
//!   sites that sort immediately or fold order-independently), no
//!   `unwrap()`/`expect()` in transport + rt I/O paths, no
//!   `Ordering::Relaxed` on credit/watermark atomics, no raw
//!   `SystemTime::now()` outside the shared [`crate::transport::Clock`],
//!   exhaustive `Frame` matches at every decode site, no hidden
//!   allocation in the routing/absorb hot paths (escape hatch:
//!   `// lint: alloc-ok`), and no `ShardSnapshot` literal or pattern
//!   that hides fields behind `..`.
//! * [`model`] — an explicit-state model-checking framework (`fish
//!   model`): pluggable [`model::Protocol`] trait, exhaustive BFS over
//!   every bounded interleaving with invariant checks on each state,
//!   liveness-to-quiescence, optional termination proofs, and
//!   shortest-trace counterexamples rendered as readable
//!   interleavings.
//! * [`credit`] — the credit-based flow-control protocol the socket
//!   and loopback lanes implement (grant/consume/ack with half-window
//!   quanta, flush-all-credits-before-blocking), proved deadlock-free
//!   and credit-conserving over bounded configs
//!   (`rust/tests/credit_model.rs`).
//! * [`recovery`] — the exactly-once flush/recovery protocol: workers
//!   × shards with seq-numbered flush lanes, the production
//!   [`crate::aggregate::FlushSequencer`] embedded in the model states,
//!   snapshot-every-K persistence, crash transitions at every protocol
//!   step, `Resume` + unacked-suffix replay — proved exactly-once and
//!   lossless over bounded configs (`rust/tests/recovery_model.rs`,
//!   docs/MODEL.md).
//!
//! Everything here is `std`-only and runs offline — the lint engine is
//! a line-oriented analyzer, not a full parser; its rules are written
//! to have zero false positives on idioms this repo actually uses, and
//! it is self-tested against seeded-regression fixtures in
//! `rust/tests/fixtures/lint/`. Both protocol models are seeded with
//! deliberate bugs (mutation testing for the checker itself): every
//! mutation must produce a deterministic counterexample trace.

pub mod credit;
pub mod lint;
pub mod model;
pub mod recovery;

pub use credit::{check_credit, CreditConfig, CreditMutation};
pub use lint::{lint_source, lint_tree, Finding, LintReport};
pub use model::{
    explore, CheckOptions, Counterexample, ModelStats, PropertyViolation, Protocol, Violation,
};
pub use recovery::{check_recovery, RecoveryConfig, RecoveryMutation};
