//! The exactly-once flush/recovery protocol as a [`Protocol`] for the
//! model checker — N workers × M shards with per-(worker, shard)
//! sequence counters, shard-side sequencer cursors, snapshot-every-K
//! persistence, crash transitions at every protocol step, the `Resume`
//! handshake, and unacked-suffix replay (docs/RECOVERY.md,
//! docs/MODEL.md).
//!
//! The model does **not** re-implement the protocol's decision rules:
//! it embeds the production [`FlushSequencer`] directly inside its
//! hashed states (so `offer`'s accept/park/dedup cursor logic is what
//! gets explored), restores crashed shards through the production
//! [`FlushSequencer::restore_replaying`], answers `Resume` with the
//! production [`resume_cursor`], and triggers persistence with the
//! production [`snapshot_due`]. A change to any of those rules changes
//! the explored state space and the pinned stats in
//! `rust/tests/recovery_model.rs` — code and model cannot drift apart
//! silently.
//!
//! ## Transitions (one interleaving step each)
//!
//! * worker `w` folds one source tuple into its stage-one partial;
//! * worker `w` flushes one seq-numbered batch to its round-robin
//!   shard (blocked while that lane awaits its `Resume` handshake);
//! * worker `w` crashes: its unflushed delta dies and the source
//!   re-feeds those tuples (the source-lane replay rule);
//! * shard `s` delivers one in-flight batch from worker `w` through
//!   the sequencer — absorbs (next-in-seq, plus any parked successors
//!   it unblocks), dedups (replay), or parks (ahead of a gap);
//! * worker `w` re-handshakes a stale lane: the shard answers with its
//!   [`resume_cursor`] and the worker replays its unacked log suffix;
//! * shard `s` begins a snapshot (two-phase: the temp-file write
//!   captures cursors + parked batches + absorb state) when
//!   [`snapshot_due`] says so;
//! * shard `s` commits the snapshot (the atomic rename);
//! * shard `s` crashes: restore from the last *committed* snapshot
//!   (cold if none) via [`FlushSequencer::restore_replaying`], every
//!   lane into it goes stale until its `Resume`.
//!
//! Kill budgets (`worker_kills`, `shard_kills`) bound the crash
//! transitions so the state space stays finite; a kill is enabled at
//! *every* protocol step until the budget is spent — including between
//! snapshot begin and commit, the torn-snapshot window.
//!
//! ## Properties
//!
//! * `tuple-conservation` — per worker, `input + pending + flushed`
//!   never changes (a crash re-feeds, never invents or drops);
//! * `exactly-once-absorb` — no shard absorbs the same (worker, seq)
//!   twice, and never absorbs seqs beyond the input;
//! * `no-lost-flush` — a sequencer cursor never passes a seq that was
//!   not absorbed, and at quiescence every shard has absorbed exactly
//!   the batches every worker sent it;
//! * `monotone-cursor` — snapshotted cursors never run ahead of the
//!   live sequencer (restore can only rewind, never skip);
//! * deadlock freedom and (on the smaller configs) termination come
//!   from the framework.
//!
//! [`RecoveryMutation`] seeds one protocol bug at a time — each must
//! produce a deterministic counterexample interleaving, pinned in
//! `rust/tests/recovery_model.rs`.

use std::collections::VecDeque;

use super::model::{
    explore, CheckOptions, Counterexample, ModelStats, PropertyViolation, Protocol,
};
use crate::aggregate::merge::{resume_cursor, FlushSequencer, SeqDecision};
use crate::state::snapshot::snapshot_due;

/// A bounded recovery-protocol configuration to exhaustively check.
#[derive(Debug, Clone)]
pub struct RecoveryConfig {
    /// Workers flushing seq-numbered batches.
    pub n_workers: usize,
    /// Merge shards, each with its own sequencer + snapshot chain.
    pub n_shards: usize,
    /// Source tuples each worker must fold and flush (each tuple
    /// becomes one flush batch).
    pub tuples_per_worker: u64,
    /// Snapshot cadence: a shard snapshots after absorbing this many
    /// batches ([`snapshot_due`]); 0 disables snapshots.
    pub snapshot_every: u64,
    /// Crash budget per worker.
    pub worker_kills: u32,
    /// Crash budget per shard.
    pub shard_kills: u32,
    /// Protocol rule to deliberately break ([`RecoveryMutation::None`]
    /// checks the honest protocol).
    pub mutation: RecoveryMutation,
}

/// A deliberate recovery-protocol bug, used to prove the checker
/// catches each violation class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryMutation {
    /// The protocol as implemented.
    None,
    /// The snapshot rename lands but the body was never fsynced: the
    /// restored file has the cursors but neither the parked batches
    /// nor the absorb state. Expected: `no-lost-flush` (the cursor
    /// claims seqs the restored shard never absorbed).
    SkipSnapshotFsync,
    /// The `Resume` answer is off by one (cursor + 1): the worker
    /// skips the first unacked batch. Expected: `no-lost-flush` at
    /// quiescence.
    ResumeOffByOne,
    /// The worker ignores the `Resume` answer and replays from its own
    /// send cursor — i.e. replays nothing. Expected: `no-lost-flush`
    /// at quiescence.
    ReplayFromWrongCursor,
    /// The snapshot writer truncates the dedup cursors to at most 1
    /// (a bounded "dedup window"): after restore, replayed seqs above
    /// the truncated cursor are absorbed again. Expected:
    /// `exactly-once-absorb`.
    DedupWindowTruncation,
}

/// One worker's source-side state.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct WorkerState {
    /// Tuples the source still has to feed this worker.
    input: u64,
    /// Tuples folded into the stage-one partial, not yet flushed.
    pending: u64,
    /// Remaining crash budget.
    kills: u32,
}

/// One (worker, shard) flush lane.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct LaneState {
    /// Batches this worker has sent on this lane == next seq to send
    /// == length of its durable per-lane flush log.
    sent: u64,
    /// In-flight seqs, FIFO (the lane is a reliable ordered stream).
    chan: VecDeque<u64>,
    /// True after the shard crashed: the lane sends nothing until its
    /// `Resume` handshake replays the unacked suffix.
    stale: bool,
}

/// What one snapshot captured (the model twin of `ShardSnapshot`:
/// cursors + parked batches + absorb state).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct SnapState {
    /// Per-worker expected-seq cursors at snapshot time.
    expected: Vec<u64>,
    /// Batches parked ahead of a gap, ascending `(worker, seq)`.
    parked: Vec<(usize, u64)>,
    /// Per-worker per-seq absorb counts at snapshot time.
    absorbed: Vec<Vec<u8>>,
}

/// One shard's state: the production sequencer plus the absorb ledger
/// the invariants read, and the two-phase snapshot chain.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct ShardState {
    /// The *production* cursor logic, explored as-is. The payload is
    /// `(worker, seq)` so restore-accepted batches stay attributed.
    sequencer: FlushSequencer<(usize, u64)>,
    /// absorbed[w][q] = times this shard absorbed seq q from worker w.
    absorbed: Vec<Vec<u8>>,
    /// Batches absorbed since the last snapshot ([`snapshot_due`]).
    since_snapshot: u64,
    /// Last committed (renamed) snapshot — what a crash restores.
    committed: Option<SnapState>,
    /// Snapshot begun but not yet committed (the temp-file window).
    writing: Option<SnapState>,
    /// Remaining crash budget.
    kills: u32,
}

/// The global protocol state.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct RecoveryState {
    workers: Vec<WorkerState>,
    /// lanes[worker][shard].
    lanes: Vec<Vec<LaneState>>,
    shards: Vec<ShardState>,
}

/// The recovery protocol over a bounded config.
pub struct RecoveryProtocol {
    cfg: RecoveryConfig,
}

impl RecoveryProtocol {
    /// Wrap `cfg`, validating the bounds that make exploration
    /// meaningful.
    pub fn new(cfg: RecoveryConfig) -> RecoveryProtocol {
        assert!(cfg.n_workers > 0 && cfg.n_shards > 0, "need workers and shards");
        assert!(cfg.tuples_per_worker > 0, "an empty run proves nothing");
        RecoveryProtocol { cfg }
    }

    fn absorb_one(absorbed: &mut [Vec<u8>], worker: usize, seq: u64) {
        let counts = &mut absorbed[worker];
        while counts.len() <= seq as usize {
            counts.push(0);
        }
        counts[seq as usize] += 1;
    }

    fn quiescent(&self, st: &RecoveryState) -> bool {
        if st.workers.iter().any(|w| w.input != 0 || w.pending != 0) {
            return false;
        }
        if st.lanes.iter().any(|per_s| per_s.iter().any(|l| !l.chan.is_empty() || l.stale)) {
            return false;
        }
        !st.shards.iter().any(|s| s.writing.is_some() || s.sequencer.buffered() > 0)
    }
}

impl Protocol for RecoveryProtocol {
    type State = RecoveryState;

    fn name(&self) -> String {
        let c = &self.cfg;
        let mut n = format!(
            "recovery workers={} shards={} tuples={} snapshot_every={} worker_kills={} shard_kills={}",
            c.n_workers, c.n_shards, c.tuples_per_worker, c.snapshot_every, c.worker_kills,
            c.shard_kills
        );
        if c.mutation != RecoveryMutation::None {
            n.push_str(&format!(" mutation={:?}", c.mutation));
        }
        n
    }

    fn initial(&self) -> RecoveryState {
        let c = &self.cfg;
        RecoveryState {
            workers: (0..c.n_workers)
                .map(|_| WorkerState {
                    input: c.tuples_per_worker,
                    pending: 0,
                    kills: c.worker_kills,
                })
                .collect(),
            lanes: (0..c.n_workers)
                .map(|_| {
                    (0..c.n_shards)
                        .map(|_| LaneState { sent: 0, chan: VecDeque::new(), stale: false })
                        .collect()
                })
                .collect(),
            shards: (0..c.n_shards)
                .map(|_| ShardState {
                    sequencer: FlushSequencer::new(c.n_workers),
                    absorbed: vec![Vec::new(); c.n_workers],
                    since_snapshot: 0,
                    committed: None,
                    writing: None,
                    kills: c.shard_kills,
                })
                .collect(),
        }
    }

    fn successors(&self, st: &RecoveryState, out: &mut Vec<(String, RecoveryState)>) {
        let c = &self.cfg;
        let (n_w, n_s) = (c.n_workers, c.n_shards);

        for w in 0..n_w {
            let wk = &st.workers[w];

            // fold: the source feeds one tuple into the stage-one partial
            if wk.input > 0 {
                let mut next = st.clone();
                next.workers[w].input -= 1;
                next.workers[w].pending += 1;
                out.push((format!("w{w} folds a tuple"), next));
            }

            // flush: ship one seq-numbered batch to the round-robin
            // shard; blocked while that lane awaits its Resume
            if wk.pending > 0 {
                let total_sent: u64 = st.lanes[w].iter().map(|l| l.sent).sum();
                let s = (total_sent % n_s as u64) as usize;
                let lane = &st.lanes[w][s];
                if !lane.stale {
                    let seq = lane.sent;
                    let mut next = st.clone();
                    next.workers[w].pending -= 1;
                    let l = &mut next.lanes[w][s];
                    l.sent += 1;
                    l.chan.push_back(seq);
                    out.push((format!("w{w} flushes seq {seq} to s{s}"), next));
                }
            }

            // worker crash: the unflushed delta dies with the process
            // and the source re-feeds exactly those tuples
            if wk.kills > 0 {
                let mut next = st.clone();
                let nw = &mut next.workers[w];
                nw.input += nw.pending;
                let refed = nw.pending;
                nw.pending = 0;
                nw.kills -= 1;
                out.push((format!("w{w} crashes, source re-feeds {refed} tuples"), next));
            }
        }

        for s in 0..n_s {
            let sh = &st.shards[s];

            // deliver: the shard pops one in-flight batch per lane and
            // runs it through the production sequencer
            for w in 0..n_w {
                let lane = &st.lanes[w][s];
                if let Some(&seq) = lane.chan.front() {
                    let mut next = st.clone();
                    next.lanes[w][s].chan.pop_front();
                    let nsh = &mut next.shards[s];
                    let verb = match nsh.sequencer.offer(w, seq, (w, seq)) {
                        SeqDecision::Accept(batch) => {
                            nsh.since_snapshot += batch.len() as u64;
                            for (bw, bq) in batch {
                                Self::absorb_one(&mut nsh.absorbed, bw, bq);
                            }
                            "absorbs"
                        }
                        SeqDecision::Replayed => "dedups",
                        SeqDecision::Buffered => "parks",
                    };
                    out.push((format!("s{s} {verb} w{w} seq {seq}"), next));
                }
            }

            // resume: a stale lane re-handshakes; the shard answers
            // with the shared resume_cursor rule and the worker replays
            // its unacked log suffix [cursor, sent)
            for w in 0..n_w {
                let lane = &st.lanes[w][s];
                if lane.stale {
                    let mut cur = resume_cursor(sh.sequencer.expected_all(), w);
                    match c.mutation {
                        RecoveryMutation::ResumeOffByOne => cur += 1,
                        RecoveryMutation::ReplayFromWrongCursor => cur = lane.sent,
                        _ => {}
                    }
                    let mut next = st.clone();
                    let l = &mut next.lanes[w][s];
                    l.chan = (cur.min(l.sent)..l.sent).collect();
                    l.stale = false;
                    out.push((format!("w{w} resumes lane to s{s}, replays from seq {cur}"), next));
                }
            }

            // snapshot begin: write the temp file (cursors + parked +
            // absorb state) when the shared cadence rule says so
            if sh.writing.is_none() && snapshot_due(sh.since_snapshot, c.snapshot_every) {
                let expected = sh.sequencer.expected_all().to_vec();
                let snapped = if c.mutation == RecoveryMutation::DedupWindowTruncation {
                    expected.iter().map(|&e| e.min(1)).collect()
                } else {
                    expected.clone()
                };
                let mut next = st.clone();
                next.shards[s].writing = Some(SnapState {
                    expected: snapped,
                    parked: sh.sequencer.parked().iter().map(|&(w, q, _)| (w, q)).collect(),
                    absorbed: sh.absorbed.clone(),
                });
                out.push((format!("s{s} begins snapshot at cursors {expected:?}"), next));
            }

            // snapshot commit: the atomic rename makes it the restore
            // point
            if let Some(writing) = &sh.writing {
                let committed = if c.mutation == RecoveryMutation::SkipSnapshotFsync {
                    // the rename lands but the unsynced body is lost:
                    // cursors survive, parked batches and absorb state
                    // do not
                    SnapState {
                        expected: writing.expected.clone(),
                        parked: Vec::new(),
                        absorbed: vec![Vec::new(); n_w],
                    }
                } else {
                    writing.clone()
                };
                let mut next = st.clone();
                let nsh = &mut next.shards[s];
                nsh.committed = Some(committed);
                nsh.writing = None;
                nsh.since_snapshot = 0;
                out.push((format!("s{s} commits snapshot"), next));
            }

            // shard crash: restore from the last committed snapshot
            // (cold if none) through the shared restore rule; every
            // lane into this shard goes stale until its Resume
            if sh.kills > 0 {
                let (base_expected, base_parked, base_absorbed, how) = match &sh.committed {
                    None => (vec![0; n_w], Vec::new(), vec![Vec::new(); n_w], "cold"),
                    Some(snap) => (
                        snap.expected.clone(),
                        snap.parked.clone(),
                        snap.absorbed.clone(),
                        "from snapshot",
                    ),
                };
                let (restored, accepted) = FlushSequencer::restore_replaying(
                    base_expected,
                    base_parked.into_iter().map(|(w, q)| (w, q, (w, q))),
                );
                let mut absorbed = base_absorbed;
                for (bw, bq) in accepted {
                    Self::absorb_one(&mut absorbed, bw, bq);
                }
                let mut next = st.clone();
                next.shards[s] = ShardState {
                    sequencer: restored,
                    absorbed,
                    since_snapshot: 0,
                    committed: sh.committed.clone(),
                    writing: None,
                    kills: sh.kills - 1,
                };
                for w in 0..n_w {
                    let l = &mut next.lanes[w][s];
                    l.chan.clear();
                    l.stale = true;
                }
                out.push((format!("s{s} crashes and restores {how}"), next));
            }
        }
    }

    fn invariants(&self, st: &RecoveryState) -> Result<(), PropertyViolation> {
        let c = &self.cfg;
        let t = c.tuples_per_worker;

        // tuple conservation: crashes re-feed, never invent or drop
        for (w, wk) in st.workers.iter().enumerate() {
            let flushed: u64 = st.lanes[w].iter().map(|l| l.sent).sum();
            if wk.input + wk.pending + flushed != t {
                return Err(PropertyViolation {
                    property: "tuple-conservation",
                    detail: format!(
                        "worker {w}: input {} + pending {} + flushed {flushed} != {t}",
                        wk.input, wk.pending
                    ),
                });
            }
        }

        // per-shard absorb ledger vs sequencer cursors
        for (s, sh) in st.shards.iter().enumerate() {
            for w in 0..c.n_workers {
                let counts = &sh.absorbed[w];
                let exp = sh.sequencer.expected(w);
                for (q, &cnt) in counts.iter().enumerate() {
                    if cnt > 1 {
                        return Err(PropertyViolation {
                            property: "exactly-once-absorb",
                            detail: format!("shard {s} absorbed worker {w} seq {q} {cnt} times"),
                        });
                    }
                    if (q as u64) < exp && cnt == 0 {
                        return Err(PropertyViolation {
                            property: "no-lost-flush",
                            detail: format!(
                                "shard {s} cursor for worker {w} is {exp} but seq {q} was never absorbed"
                            ),
                        });
                    }
                }
                if (counts.len() as u64) < exp {
                    return Err(PropertyViolation {
                        property: "no-lost-flush",
                        detail: format!(
                            "shard {s} cursor for worker {w} is {exp} but seqs {}.. were never absorbed",
                            counts.len()
                        ),
                    });
                }
                if counts.len() as u64 > t {
                    return Err(PropertyViolation {
                        property: "exactly-once-absorb",
                        detail: format!("shard {s} absorbed seqs beyond the input for worker {w}"),
                    });
                }
            }
        }

        // at quiescence the protocol must have converged: every shard
        // absorbed exactly the batches every worker sent it
        if self.quiescent(st) {
            for (s, sh) in st.shards.iter().enumerate() {
                for w in 0..c.n_workers {
                    let exp = sh.sequencer.expected(w);
                    let sent = st.lanes[w][s].sent;
                    if exp != sent {
                        return Err(PropertyViolation {
                            property: "no-lost-flush",
                            detail: format!(
                                "quiescent but shard {s} absorbed {exp} of {sent} batches from worker {w}"
                            ),
                        });
                    }
                }
            }
        }

        // snapshotted cursors never run ahead of the live sequencer:
        // restore can only rewind, never skip. (Checked last so the
        // twin-pinned counterexamples above are unaffected; it never
        // fires under the honest protocol or the seeded mutations.)
        for (s, sh) in st.shards.iter().enumerate() {
            for snap in [&sh.committed, &sh.writing].into_iter().flatten() {
                for (w, &snapped) in snap.expected.iter().enumerate() {
                    if snapped > sh.sequencer.expected(w) {
                        return Err(PropertyViolation {
                            property: "monotone-cursor",
                            detail: format!(
                                "shard {s} snapshot cursor for worker {w} is {snapped}, ahead of live {}",
                                sh.sequencer.expected(w)
                            ),
                        });
                    }
                }
            }
        }

        Ok(())
    }

    fn is_final(&self, st: &RecoveryState) -> bool {
        // every state in the explored graph is invariant-clean (the
        // checker errors out otherwise), so quiescence alone is the
        // final-state predicate
        self.quiescent(st)
    }
}

/// Exhaustively check one recovery configuration. Deterministic: same
/// config + options ⇒ same stats, byte-identical counterexample.
pub fn check_recovery(
    cfg: &RecoveryConfig,
    opts: &CheckOptions,
) -> Result<ModelStats, Counterexample> {
    explore(&RecoveryProtocol::new(cfg.clone()), opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(
        workers: usize,
        shards: usize,
        tuples: u64,
        every: u64,
        wk: u32,
        sk: u32,
        mutation: RecoveryMutation,
    ) -> RecoveryConfig {
        RecoveryConfig {
            n_workers: workers,
            n_shards: shards,
            tuples_per_worker: tuples,
            snapshot_every: every,
            worker_kills: wk,
            shard_kills: sk,
            mutation,
        }
    }

    #[test]
    fn crash_free_single_lane_is_clean_and_terminates() {
        let opts = CheckOptions { check_termination: true, ..Default::default() };
        let stats = check_recovery(&cfg(1, 1, 2, 1, 0, 0, RecoveryMutation::None), &opts)
            .expect("clean");
        // fold/flush/deliver/snapshot interleavings only: tiny, acyclic
        assert!(stats.states > 1 && stats.finals >= 1);
    }

    #[test]
    fn single_lane_crash_recovery_is_clean() {
        let stats = check_recovery(
            &cfg(1, 1, 2, 1, 1, 1, RecoveryMutation::None),
            &CheckOptions::default(),
        )
        .expect("clean under crashes");
        assert!(stats.finals >= 1, "recovery must still reach quiescence");
    }
}
