//! PJRT bridge: load and execute the AOT-compiled `epoch_stats` HLO
//! artifacts from the coordinator hot path.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); this
//! module makes the Rust binary self-contained afterwards:
//!
//! ```text
//! PjRtClient::cpu() → HloModuleProto::from_text_file(artifacts/…)
//!                   → client.compile() → exe.execute(...)  per epoch
//! ```
//!
//! [`XlaIdentifier`] implements [`crate::coordinator::fish::Identifier`]
//! on top of the compiled kernel, so `--identifier xla-cms` swaps FISH's
//! frequency statistics onto the Pallas count-min path without touching
//! the rest of the coordinator.

pub mod client;
pub mod epoch_stats;
pub mod identifier;
pub mod service;

pub use client::{EpochStatsExe, Runtime, VariantSpec};
pub use epoch_stats::EpochStatsState;
pub use identifier::{make_fish_xla, XlaIdentifier};
pub use service::{EpochReply, ServiceSpec, XlaEpochService};
