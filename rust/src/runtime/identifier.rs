//! [`XlaIdentifier`]: FISH's recent-hot-key identification running on the
//! AOT-compiled Pallas count-min kernel.
//!
//! Division of labour:
//! * **membership** (which keys are worth tracking) — a small native
//!   SpaceSaving set, exactly Alg. 1's `K`;
//! * **counts** — the CMS sketch updated once per epoch by the XLA
//!   executable via [`super::XlaEpochService`] (decay ×α + histogram add
//!   + candidate query in one fused module, running on its own thread
//!   because PJRT handles are `!Send`);
//! * **intra-epoch freshness** — a per-epoch exact partial count so
//!   estimates do not go stale between kernel firings.
//!
//! `estimate(k) = CMS(k, last boundary) + partial(k, since boundary)` —
//! an upper-bound estimator exactly like the native path's SpaceSaving
//! counts (both only ever *over*-estimate).

use super::service::XlaEpochService;
use crate::coordinator::fish::Identifier;
use crate::sketch::SpaceSaving;
use crate::Key;
use std::collections::HashMap;

/// XLA-backed identifier (swap-in for [`crate::coordinator::fish::EpochIdentifier`]).
pub struct XlaIdentifier {
    service: XlaEpochService,
    buffer: Vec<i32>,
    /// Candidate membership — Alg. 1's bounded K set.
    membership: SpaceSaving,
    /// Boundary estimates for the queried candidates.
    cms_est: HashMap<Key, f64>,
    /// Exact counts within the current (incomplete) epoch.
    partial: HashMap<Key, f64>,
    f_top: f64,
    total_mass: f64,
    epochs: u64,
}

impl XlaIdentifier {
    /// Spawn a service against `artifacts_dir` and build the identifier.
    /// `key_capacity` = K_max, `epoch_hint` picks the artifact (the
    /// actual epoch length is the artifact's static N), `alpha` = α.
    pub fn new(
        artifacts_dir: &str,
        key_capacity: usize,
        epoch_hint: usize,
        alpha: f64,
    ) -> anyhow::Result<Self> {
        let service = XlaEpochService::spawn(artifacts_dir, epoch_hint, alpha)?;
        let n = service.spec().epoch_len;
        Ok(XlaIdentifier {
            service,
            buffer: Vec::with_capacity(n),
            membership: SpaceSaving::new(key_capacity),
            cms_est: HashMap::new(),
            partial: HashMap::new(),
            f_top: 0.0,
            total_mass: 0.0,
            epochs: 0,
        })
    }

    /// The artifact's static epoch length.
    pub fn epoch_len(&self) -> usize {
        self.service.spec().epoch_len
    }
}

impl Identifier for XlaIdentifier {
    fn observe(&mut self, key: Key) {
        self.membership.observe(key);
        *self.partial.entry(key).or_insert(0.0) += 1.0;
        self.buffer.push(key as u32 as i32);

        if self.buffer.len() < self.epoch_len() {
            return;
        }
        // epoch boundary: one fused XLA call (decay + update + query)
        let cands: Vec<Key> = self
            .membership
            .top_n(self.service.spec().cand_capacity)
            .into_iter()
            .map(|(k, _)| k)
            .collect();
        let keys = std::mem::take(&mut self.buffer);
        match self.service.run_epoch(keys, cands) {
            Ok(reply) => {
                self.cms_est.clear();
                self.f_top = 0.0;
                for (k, e) in reply.est {
                    let e = e as f64;
                    self.cms_est.insert(k, e);
                    if e > self.f_top {
                        self.f_top = e;
                    }
                }
                self.total_mass = reply.total_mass;
                self.epochs = reply.epochs;
                self.partial.clear();
            }
            Err(e) => {
                // PJRT failure is unrecoverable mid-stream; surface loudly.
                panic!("XLA epoch_stats execution failed: {e:#}");
            }
        }
    }

    fn estimate(&self, key: Key) -> f64 {
        self.cms_est.get(&key).copied().unwrap_or(0.0)
            + self.partial.get(&key).copied().unwrap_or(0.0)
    }

    fn f_top(&self) -> f64 {
        // boundary top plus the largest intra-epoch riser
        let partial_top = self
            .partial
            .iter()
            .map(|(k, v)| v + self.cms_est.get(k).copied().unwrap_or(0.0))
            .fold(0.0, f64::max);
        self.f_top.max(partial_top)
    }

    fn total(&self) -> f64 {
        self.total_mass + self.buffer.len() as f64
    }

    fn entries(&self) -> usize {
        self.membership.entries() + self.cms_est.len() + self.partial.len()
    }

    fn epochs(&self) -> u64 {
        self.epochs
    }
}

/// Build a FISH grouper with the XLA identifier from `cfg`
/// (`--identifier xla-cms` path).
pub fn make_fish_xla(cfg: &crate::config::Config) -> anyhow::Result<crate::coordinator::Fish> {
    let id = XlaIdentifier::new(&cfg.artifacts_dir, cfg.key_capacity, cfg.epoch, cfg.alpha)?;
    let workers: Vec<crate::WorkerId> = (0..cfg.workers).collect();
    Ok(crate::coordinator::Fish::new(
        Box::new(id),
        cfg.theta(),
        cfg.d_min,
        cfg.interval,
        cfg.vnodes,
        &workers,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn available() -> bool {
        std::path::Path::new("artifacts/manifest.txt").exists()
    }

    #[test]
    fn xla_identifier_tracks_hot_key() {
        if !available() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let mut id = XlaIdentifier::new("artifacts", 64, 256, 0.5).unwrap();
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..1_500 {
            let k = if rng.gen_bool(0.4) { 9 } else { 100 + rng.gen_range(5_000) };
            id.observe(k);
        }
        assert!(id.epochs() >= 4);
        let rel = id.estimate(9) / id.total();
        assert!(rel > 0.2, "hot key relative estimate {rel}");
        assert!(id.f_top() >= id.estimate(9));
    }

    #[test]
    fn xla_identifier_decays_stale_keys() {
        if !available() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let mut id = XlaIdentifier::new("artifacts", 64, 256, 0.2).unwrap();
        for _ in 0..1_024 {
            id.observe(1);
        }
        let peak = id.estimate(1);
        for _ in 0..2_048 {
            id.observe(2);
        }
        assert!(id.estimate(2) > id.estimate(1));
        assert!(id.estimate(1) < peak * 0.2, "stale key did not decay");
    }

    #[test]
    fn identifier_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<XlaIdentifier>();
    }
}
