//! PJRT client wrapper: artifact discovery, compilation, execution.

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Shape metadata of one AOT variant (parsed from `manifest.txt`, kept in
/// sync with `python/compile/model.py::VARIANTS`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VariantSpec {
    /// Artifact stem, e.g. `epoch_stats_n1024`.
    pub name: String,
    /// Epoch size N (keys per update call).
    pub n: usize,
    /// Candidate count C (queries per call).
    pub c: usize,
    /// Sketch depth D.
    pub depth: usize,
    /// Sketch width W.
    pub width: usize,
}

impl VariantSpec {
    /// Parse one manifest line: `name n=.. c=.. depth=.. width=.. tile=..`.
    pub fn parse(line: &str) -> Result<VariantSpec> {
        let mut parts = line.split_whitespace();
        let name = parts.next().ok_or_else(|| anyhow!("empty manifest line"))?.to_string();
        let mut n = None;
        let mut c = None;
        let mut depth = None;
        let mut width = None;
        for kv in parts {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| anyhow!("bad manifest token '{kv}'"))?;
            let v: usize = v.parse().with_context(|| format!("manifest value '{kv}'"))?;
            match k {
                "n" => n = Some(v),
                "c" => c = Some(v),
                "depth" => depth = Some(v),
                "width" => width = Some(v),
                "tile" => {}
                other => bail!("unknown manifest key '{other}'"),
            }
        }
        Ok(VariantSpec {
            name,
            n: n.ok_or_else(|| anyhow!("manifest missing n"))?,
            c: c.ok_or_else(|| anyhow!("manifest missing c"))?,
            depth: depth.ok_or_else(|| anyhow!("manifest missing depth"))?,
            width: width.ok_or_else(|| anyhow!("manifest missing width"))?,
        })
    }
}

/// A compiled `epoch_stats` executable plus its shapes.
pub struct EpochStatsExe {
    /// Shape metadata.
    pub spec: VariantSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl EpochStatsExe {
    /// Run one epoch: decay by `alpha`, add `keys` (len == spec.n; pad
    /// with the sentinel key `PAD_KEY`), query `cands` (len == spec.c).
    /// Returns (new sketch rows, candidate estimates, epoch total).
    pub fn run(
        &self,
        sketch: &[f32],
        keys: &[i32],
        cands: &[i32],
        alpha: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let spec = &self.spec;
        if sketch.len() != spec.depth * spec.width {
            bail!("sketch len {} != {}x{}", sketch.len(), spec.depth, spec.width);
        }
        if keys.len() != spec.n {
            bail!("keys len {} != n {}", keys.len(), spec.n);
        }
        if cands.len() != spec.c {
            bail!("cands len {} != c {}", cands.len(), spec.c);
        }
        let sketch_lit = xla::Literal::vec1(sketch)
            .reshape(&[spec.depth as i64, spec.width as i64])?;
        let keys_lit = xla::Literal::vec1(keys);
        let cands_lit = xla::Literal::vec1(cands);
        let alpha_lit = xla::Literal::vec1(&[alpha]);
        let result = self
            .exe
            .execute::<xla::Literal>(&[sketch_lit, keys_lit, cands_lit, alpha_lit])?[0][0]
            .to_literal_sync()?;
        // lowered with return_tuple=True → 3-tuple
        let elems = result.to_tuple()?;
        if elems.len() != 3 {
            bail!("expected 3 outputs, got {}", elems.len());
        }
        let new_sketch = elems[0].to_vec::<f32>()?;
        let est = elems[1].to_vec::<f32>()?;
        let total = elems[2].to_vec::<f32>()?;
        Ok((new_sketch, est, total.first().copied().unwrap_or(0.0)))
    }
}

/// The PJRT runtime: owns the client and the compiled variants.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    variants: Vec<VariantSpec>,
}

impl Runtime {
    /// Create a CPU PJRT client and read the artifact manifest.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let artifacts_dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = artifacts_dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {} (run `make artifacts`)", manifest.display()))?;
        let variants = text
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(VariantSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        if variants.is_empty() {
            bail!("no variants in {}", manifest.display());
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, artifacts_dir, variants })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Available variant specs.
    pub fn variants(&self) -> &[VariantSpec] {
        &self.variants
    }

    /// The variant whose epoch size `n` best matches (exact, else the
    /// smallest n ≥ requested, else the largest available).
    pub fn pick_variant(&self, n_epoch: usize) -> &VariantSpec {
        self.variants
            .iter()
            .filter(|v| v.n >= n_epoch)
            .min_by_key(|v| v.n)
            .unwrap_or_else(|| self.variants.iter().max_by_key(|v| v.n).unwrap())
    }

    /// Compile (HLO text → PJRT executable) one variant by name.
    pub fn compile(&self, name: &str) -> Result<EpochStatsExe> {
        let spec = self
            .variants
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| anyhow!("unknown variant '{name}'"))?
            .clone();
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        Ok(EpochStatsExe { spec, exe })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_line_parses() {
        let v = VariantSpec::parse("epoch_stats_n1024 n=1024 c=128 depth=4 width=2048 tile=128")
            .unwrap();
        assert_eq!(v.n, 1024);
        assert_eq!(v.c, 128);
        assert_eq!(v.depth, 4);
        assert_eq!(v.width, 2048);
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(VariantSpec::parse("").is_err());
        assert!(VariantSpec::parse("x n=1 c=2 depth=3").is_err()); // missing width
        assert!(VariantSpec::parse("x n=abc c=2 depth=3 width=4").is_err());
        assert!(VariantSpec::parse("x bogus=1 n=1 c=1 depth=1 width=2").is_err());
    }
}
