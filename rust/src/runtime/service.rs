//! XLA epoch-stats service thread.
//!
//! PJRT handles (`PjRtClient`, `PjRtLoadedExecutable`) are `!Send`, but
//! groupers must be `Send` (the runtime engine moves them into source
//! threads). So the compiled executable lives on a dedicated service
//! thread that owns the whole [`super::EpochStatsState`]; identifiers
//! talk to it over channels. One service per identifier — the request
//! rate is one round-trip per epoch (every `N` tuples), so the channel
//! hop is far off the per-tuple hot path.

use super::client::Runtime;
use super::epoch_stats::EpochStatsState;
use crate::Key;
use anyhow::{anyhow, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// One epoch batch for the service.
struct Request {
    keys: Vec<i32>,
    cands: Vec<Key>,
    reply: Sender<Result<EpochReply>>,
}

/// Service response at an epoch boundary.
#[derive(Debug, Clone)]
pub struct EpochReply {
    /// (candidate, CMS estimate) aligned to the request's candidates.
    pub est: Vec<(Key, f32)>,
    /// Decayed total mass after this epoch.
    pub total_mass: f64,
    /// Completed epochs.
    pub epochs: u64,
}

/// Static shape info the identifier needs up front.
#[derive(Debug, Clone, Copy)]
pub struct ServiceSpec {
    /// Epoch length `N` of the compiled artifact.
    pub epoch_len: usize,
    /// Candidate capacity `C`.
    pub cand_capacity: usize,
}

/// Handle to a running epoch-stats service thread.
pub struct XlaEpochService {
    tx: Sender<Request>,
    spec: ServiceSpec,
    handle: Option<JoinHandle<()>>,
}

impl XlaEpochService {
    /// Spawn the service: builds the PJRT client, compiles the variant
    /// picked by `epoch_hint`, then serves epoch batches until dropped.
    pub fn spawn(artifacts_dir: &str, epoch_hint: usize, alpha: f64) -> Result<XlaEpochService> {
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<ServiceSpec>>();
        let dir = artifacts_dir.to_string();
        let handle = std::thread::Builder::new()
            .name("xla-epoch-stats".into())
            .spawn(move || service_main(dir, epoch_hint, alpha, rx, ready_tx))
            .map_err(|e| anyhow!("spawning xla service: {e}"))?;
        let spec = ready_rx
            .recv()
            .map_err(|_| anyhow!("xla service died during startup"))??;
        Ok(XlaEpochService { tx, spec, handle: Some(handle) })
    }

    /// Artifact shape info.
    pub fn spec(&self) -> ServiceSpec {
        self.spec
    }

    /// Synchronously process one epoch batch (pads internally if short).
    pub fn run_epoch(&self, keys: Vec<i32>, cands: Vec<Key>) -> Result<EpochReply> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request { keys, cands, reply: reply_tx })
            .map_err(|_| anyhow!("xla service is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("xla service dropped the reply"))?
    }
}

impl Drop for XlaEpochService {
    fn drop(&mut self) {
        // closing tx ends the service loop
        let (dead_tx, _) = channel();
        let _ = std::mem::replace(&mut self.tx, dead_tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn service_main(
    dir: String,
    epoch_hint: usize,
    alpha: f64,
    rx: Receiver<Request>,
    ready: Sender<Result<ServiceSpec>>,
) {
    let state = (|| -> Result<EpochStatsState> {
        let rt = Runtime::new(&dir)?;
        let spec = rt.pick_variant(epoch_hint).clone();
        let exe = rt.compile(&spec.name)?;
        Ok(EpochStatsState::new(exe, alpha as f32))
    })();
    let mut state = match state {
        Ok(s) => {
            let _ = ready.send(Ok(ServiceSpec {
                epoch_len: s.epoch_len(),
                cand_capacity: s.cand_capacity(),
            }));
            s
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        let result = run_one(&mut state, req.keys, &req.cands);
        let _ = req.reply.send(result);
    }
}

fn run_one(state: &mut EpochStatsState, keys: Vec<i32>, cands: &[Key]) -> Result<EpochReply> {
    let est = state.ingest_batch(&keys, cands)?;
    Ok(EpochReply {
        est,
        total_mass: state.total_mass(),
        epochs: state.epochs(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_roundtrip_and_decay() {
        let Ok(svc) = XlaEpochService::spawn("artifacts", 256, 0.5) else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let n = svc.spec().epoch_len;
        let keys: Vec<i32> = vec![7; n];
        let r1 = svc.run_epoch(keys.clone(), vec![7]).unwrap();
        assert_eq!(r1.epochs, 1);
        assert!((r1.est[0].1 - n as f32).abs() < 1e-2);
        let r2 = svc.run_epoch(keys, vec![7]).unwrap();
        assert!((r2.est[0].1 - 1.5 * n as f32).abs() / (1.5 * n as f32) < 0.01);
        assert_eq!(r2.epochs, 2);
    }

    #[test]
    fn service_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<XlaEpochService>();
    }

    #[test]
    fn spawn_fails_cleanly_without_artifacts() {
        let err = XlaEpochService::spawn("/nonexistent/dir", 256, 0.5);
        assert!(err.is_err());
    }
}
