//! Buffered epoch state machine around the compiled `epoch_stats` kernel.
//!
//! Accumulates keys until a full epoch (the artifact's static `N`), then
//! fires one PJRT execution: decay → CMS update → candidate query. The
//! sketch lives on the Rust side between calls (`Vec<f32>` row-major,
//! bit-compatible with [`crate::sketch::CountMin`]).

use super::client::EpochStatsExe;
use anyhow::Result;
use crate::Key;

/// Epoch-buffered CMS state driven by the XLA executable.
pub struct EpochStatsState {
    exe: EpochStatsExe,
    sketch: Vec<f32>,
    buffer: Vec<i32>,
    alpha: f32,
    /// Decayed total mass (maintained analytically: ×α then +N per epoch).
    total_mass: f64,
    /// Completed epochs.
    epochs: u64,
}

impl EpochStatsState {
    /// Fresh state for one compiled variant.
    pub fn new(exe: EpochStatsExe, alpha: f32) -> Self {
        let size = exe.spec.depth * exe.spec.width;
        let n = exe.spec.n;
        EpochStatsState {
            exe,
            sketch: vec![0.0; size],
            buffer: Vec::with_capacity(n),
            alpha,
            total_mass: 0.0,
            epochs: 0,
        }
    }

    /// Epoch size `N` of the underlying artifact.
    pub fn epoch_len(&self) -> usize {
        self.exe.spec.n
    }

    /// Candidate query capacity `C` of the artifact.
    pub fn cand_capacity(&self) -> usize {
        self.exe.spec.c
    }

    /// Completed epochs.
    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    /// Decayed total mass.
    pub fn total_mass(&self) -> f64 {
        self.total_mass
    }

    /// Raw sketch rows (row-major D×W).
    pub fn sketch(&self) -> &[f32] {
        &self.sketch
    }

    /// Keys buffered in the current (incomplete) epoch.
    pub fn pending(&self) -> usize {
        self.buffer.len()
    }

    /// Ingest a whole epoch batch at once (the service-thread entry
    /// point): buffers `keys` (≤ remaining capacity) and flushes.
    pub fn ingest_batch(&mut self, keys: &[i32], cands: &[Key]) -> Result<Vec<(Key, f32)>> {
        assert!(
            self.buffer.len() + keys.len() <= self.epoch_len(),
            "batch overflows the epoch: {} + {} > {}",
            self.buffer.len(),
            keys.len(),
            self.epoch_len()
        );
        self.buffer.extend_from_slice(keys);
        self.flush(cands)
    }

    /// Buffer one key. When the buffer reaches `N`, runs the kernel with
    /// `cands` (padded/truncated to `C`) and returns `Some(estimates)`
    /// aligned with the *first* `min(cands.len(), C)` candidates.
    pub fn observe(&mut self, key: Key, cands: &[Key]) -> Result<Option<Vec<(Key, f32)>>> {
        self.buffer.push(key as u32 as i32);
        if self.buffer.len() < self.epoch_len() {
            return Ok(None);
        }
        self.flush(cands).map(Some)
    }

    /// Force an epoch boundary now (used at stream end). The buffered
    /// prefix is padded with a repeat of the last key's *sentinel-free*
    /// content: we pad by repeating `PAD`, a reserved id whose CMS mass
    /// never gets queried; CMS overestimation from pad collisions is
    /// bounded exactly like any other collision.
    pub fn flush(&mut self, cands: &[Key]) -> Result<Vec<(Key, f32)>> {
        const PAD: i32 = -1;
        let n = self.epoch_len();
        let pad_count = n - self.buffer.len();
        self.buffer.resize(n, PAD);

        let c = self.cand_capacity();
        let mut cand_ids: Vec<i32> = cands
            .iter()
            .take(c)
            .map(|&k| k as u32 as i32)
            .collect();
        let real_cands = cand_ids.len();
        cand_ids.resize(c, PAD);

        let (new_sketch, est, total) =
            self.exe
                .run(&self.sketch, &self.buffer, &cand_ids, self.alpha)?;
        self.sketch = new_sketch;
        self.total_mass = self.total_mass * self.alpha as f64 + (n - pad_count) as f64;
        self.epochs += 1;
        self.buffer.clear();
        debug_assert_eq!(total as usize, n);

        Ok(cands
            .iter()
            .take(real_cands)
            .copied()
            .zip(est.into_iter())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    //! Requires `make artifacts`; skipped gracefully when absent so
    //! `cargo test` works on a fresh checkout.
    use super::super::client::Runtime;
    use super::*;
    use crate::sketch::CountMin;

    fn runtime() -> Option<Runtime> {
        Runtime::new("artifacts").ok()
    }

    #[test]
    fn xla_epoch_matches_native_countmin() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let spec = rt.pick_variant(256).clone();
        let exe = rt.compile(&spec.name).unwrap();
        let mut state = EpochStatsState::new(exe, 0.5);
        let mut native = CountMin::new(spec.depth, spec.width);

        let mut rng = crate::util::Rng::new(11);
        let keys: Vec<Key> = (0..spec.n).map(|_| rng.gen_range(500)).collect();
        let cands: Vec<Key> = (0..8).collect();

        let mut result = None;
        for &k in &keys {
            native.add(k);
            result = state.observe(k, &cands).unwrap();
        }
        let est = result.expect("epoch should have fired");
        // α applies to the PRE-epoch sketch (all zeros) so counts match 1:1
        for (k, e) in est {
            let want = native.estimate(k);
            assert!(
                (e - want).abs() < 1e-3,
                "key {k}: xla {e} vs native {want}"
            );
        }
        assert_eq!(state.epochs(), 1);
        assert_eq!(state.total_mass(), spec.n as f64);
    }

    #[test]
    fn decay_applies_between_epochs() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let spec = rt.pick_variant(256).clone();
        let exe = rt.compile(&spec.name).unwrap();
        let mut state = EpochStatsState::new(exe, 0.5);
        let cands: Vec<Key> = vec![7];
        // epoch 1: key 7 every tuple
        for _ in 0..spec.n {
            state.observe(7, &cands).unwrap();
        }
        // epoch 2: key 7 again every tuple → estimate ≈ N·0.5 + N
        let mut last = None;
        for _ in 0..spec.n {
            last = state.observe(7, &cands).unwrap();
        }
        let est = last.unwrap()[0].1;
        let want = spec.n as f32 * 1.5;
        assert!((est - want).abs() / want < 0.01, "est {est} want {want}");
        assert_eq!(state.epochs(), 2);
    }

    #[test]
    fn flush_pads_partial_epoch() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let spec = rt.pick_variant(256).clone();
        let exe = rt.compile(&spec.name).unwrap();
        let mut state = EpochStatsState::new(exe, 1.0);
        for _ in 0..10 {
            state.observe(3, &[3]).unwrap();
        }
        let est = state.flush(&[3]).unwrap();
        assert!(est[0].1 >= 10.0); // CMS never underestimates
        assert_eq!(state.total_mass(), 10.0); // pads excluded from mass
        assert_eq!(state.pending(), 0);
    }
}
