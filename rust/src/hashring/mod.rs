//! Consistent hashing with virtual nodes (paper §5).

pub mod ring;

pub use ring::HashRing;
