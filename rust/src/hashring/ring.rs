//! Consistent-hash ring with virtual nodes — paper §5.
//!
//! Keys and workers hash onto a 2^32 ring (SHA-1, per the paper's choice
//! of RFC 3174 [35]); a key is owned by the first worker clockwise.
//! `vnodes` virtual nodes per worker smooth small-cluster imbalance
//! (paper Fig. 8(d)). Worker addition/removal remaps only the arc
//! between the affected virtual nodes — the monotonicity property the
//! paper needs so state migration stays small.
//!
//! `candidates(key, d)` returns the `d` distinct workers clockwise from
//! the key's position: this is how CHK's per-key candidate sets stay
//! stable under worker churn (paper §4.1.2 "we assign workers for each
//! key through a consistent hash").

use crate::{Key, WorkerId};
use sha1::{Digest, Sha1};

/// Ring point: (position, worker).
type Point = (u32, WorkerId);

/// Consistent-hash ring with virtual nodes.
#[derive(Debug, Clone)]
pub struct HashRing {
    points: Vec<Point>, // sorted by position
    vnodes: usize,
    workers: Vec<WorkerId>,
}

fn sha1_u32(bytes: &[u8]) -> u32 {
    let digest = Sha1::digest(bytes);
    u32::from_be_bytes([digest[0], digest[1], digest[2], digest[3]])
}

impl HashRing {
    /// Build a ring over `workers` with `vnodes` virtual nodes each.
    pub fn new(workers: &[WorkerId], vnodes: usize) -> Self {
        assert!(vnodes > 0, "need at least one virtual node per worker");
        let mut ring = HashRing { points: Vec::new(), vnodes, workers: Vec::new() };
        for &w in workers {
            ring.add_worker(w);
        }
        ring
    }

    /// Virtual nodes per worker.
    pub fn vnodes(&self) -> usize {
        self.vnodes
    }

    /// Current worker set (insertion order).
    pub fn workers(&self) -> &[WorkerId] {
        &self.workers
    }

    fn vnode_pos(worker: WorkerId, replica: usize) -> u32 {
        let mut buf = [0u8; 16];
        buf[..8].copy_from_slice(&(worker as u64).to_le_bytes());
        buf[8..].copy_from_slice(&(replica as u64).to_le_bytes());
        sha1_u32(&buf)
    }

    /// Key lookup position. Worker vnodes use SHA-1 (per the paper's
    /// choice, RFC 3174); key lookups run on every routed tuple, so they
    /// use a multiplicative 64-bit mix instead — identical uniformity on
    /// the 2^32 ring at ~10× less cost (§Perf pass; SHA-1 of 8 bytes was
    /// a measurable slice of FISH's route()).
    #[inline]
    fn key_pos(key: Key) -> u32 {
        (crate::util::hash::mix64(key ^ 0x52_49_4E_47) >> 32) as u32
    }

    /// Add a worker's virtual nodes to the ring (paper Fig. 8(c)).
    pub fn add_worker(&mut self, worker: WorkerId) {
        if self.workers.contains(&worker) {
            return;
        }
        self.workers.push(worker);
        for r in 0..self.vnodes {
            let pos = Self::vnode_pos(worker, r);
            let idx = self.points.partition_point(|&(p, w)| (p, w) < (pos, worker));
            self.points.insert(idx, (pos, worker));
        }
    }

    /// Remove a worker (paper Fig. 8(b)).
    pub fn remove_worker(&mut self, worker: WorkerId) {
        self.workers.retain(|&w| w != worker);
        self.points.retain(|&(_, w)| w != worker);
    }

    /// Owner of `key`: first worker clockwise from the key position.
    pub fn owner(&self, key: Key) -> Option<WorkerId> {
        if self.points.is_empty() {
            return None;
        }
        let pos = Self::key_pos(key);
        let idx = self.points.partition_point(|&(p, _)| p < pos);
        let idx = if idx == self.points.len() { 0 } else { idx };
        Some(self.points[idx].1)
    }

    /// The first `d` *distinct* workers clockwise from `key`'s position.
    /// Returns fewer when the ring has fewer than `d` workers.
    pub fn candidates(&self, key: Key, d: usize) -> Vec<WorkerId> {
        let mut out = Vec::with_capacity(d.min(self.workers.len()));
        self.candidates_into(key, d, &mut out);
        out
    }

    /// Allocation-free variant of [`HashRing::candidates`]: fills `out`
    /// (cleared first). The FISH hot path reuses one buffer per grouper.
    pub fn candidates_into(&self, key: Key, d: usize, out: &mut Vec<WorkerId>) {
        out.clear();
        if self.points.is_empty() || d == 0 {
            return;
        }
        let pos = Self::key_pos(key);
        let start = self.points.partition_point(|&(p, _)| p < pos);
        for i in 0..self.points.len() {
            let (_, w) = self.points[(start + i) % self.points.len()];
            if !out.contains(&w) {
                out.push(w);
                if out.len() == d {
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn owner_is_deterministic_and_total() {
        let ring = HashRing::new(&[0, 1, 2, 3], 32);
        for k in 0..1000u64 {
            let a = ring.owner(k).unwrap();
            let b = ring.owner(k).unwrap();
            assert_eq!(a, b);
            assert!(a < 4);
        }
    }

    #[test]
    fn removal_only_remaps_owned_arcs() {
        // Monotonicity: keys not owned by the removed worker keep owners.
        let mut ring = HashRing::new(&[0, 1, 2, 3, 4, 5, 6, 7], 64);
        let before: HashMap<u64, WorkerId> =
            (0..5_000u64).map(|k| (k, ring.owner(k).unwrap())).collect();
        ring.remove_worker(3);
        for (k, w) in &before {
            let now = ring.owner(*k).unwrap();
            if *w != 3 {
                assert_eq!(now, *w, "key {k} moved needlessly");
            } else {
                assert_ne!(now, 3);
            }
        }
    }

    #[test]
    fn addition_steals_bounded_fraction() {
        let mut ring = HashRing::new(&(0..8).collect::<Vec<_>>(), 64);
        let before: HashMap<u64, WorkerId> =
            (0..5_000u64).map(|k| (k, ring.owner(k).unwrap())).collect();
        ring.add_worker(8);
        let moved = (0..5_000u64)
            .filter(|k| ring.owner(*k).unwrap() != before[k])
            .count();
        // new worker should own ≈ 1/9 of keys; everything that moved must
        // have moved TO the new worker.
        for k in 0..5_000u64 {
            let now = ring.owner(k).unwrap();
            if now != before[&k] {
                assert_eq!(now, 8);
            }
        }
        let frac = moved as f64 / 5_000.0;
        assert!(frac < 0.25, "moved {frac}");
    }

    #[test]
    fn vnodes_balance_small_clusters() {
        // Paper Fig. 8(d): virtual nodes even out a 2-worker ring.
        let few = HashRing::new(&[0, 1], 1);
        let many = HashRing::new(&[0, 1], 128);
        let share = |ring: &HashRing| {
            let n = (0..20_000u64).filter(|&k| ring.owner(k) == Some(0)).count();
            n as f64 / 20_000.0
        };
        let imb_few = (share(&few) - 0.5).abs();
        let imb_many = (share(&many) - 0.5).abs();
        assert!(imb_many < 0.05, "vnode ring imbalance {imb_many}");
        assert!(imb_many <= imb_few + 0.01);
    }

    #[test]
    fn candidates_distinct_ordered_stable() {
        let ring = HashRing::new(&(0..16).collect::<Vec<_>>(), 32);
        for k in 0..500u64 {
            let c = ring.candidates(k, 5);
            assert_eq!(c.len(), 5);
            let set: std::collections::HashSet<_> = c.iter().collect();
            assert_eq!(set.len(), 5);
            assert_eq!(c[0], ring.owner(k).unwrap());
        }
        // d > workers clamps
        assert_eq!(ring.candidates(1, 99).len(), 16);
    }

    #[test]
    fn candidate_sets_survive_unrelated_churn() {
        // Removing one worker must not reshuffle candidate sets that
        // didn't contain it (the property CHK relies on).
        let mut ring = HashRing::new(&(0..12).collect::<Vec<_>>(), 64);
        let before: Vec<Vec<WorkerId>> =
            (0..2_000u64).map(|k| ring.candidates(k, 3)).collect();
        ring.remove_worker(7);
        for (k, prev) in before.iter().enumerate() {
            if !prev.contains(&7) {
                assert_eq!(ring.candidates(k as u64, 3), *prev);
            }
        }
    }

    #[test]
    fn empty_ring_behaviour() {
        let mut ring = HashRing::new(&[], 8);
        assert_eq!(ring.owner(1), None);
        assert!(ring.candidates(1, 2).is_empty());
        ring.add_worker(0);
        assert_eq!(ring.owner(1), Some(0));
        ring.remove_worker(0);
        assert_eq!(ring.owner(1), None);
    }
}
