//! Trace shipping and Chrome-trace-event export.
//!
//! [`TraceBlob`] is the owned mirror of a [`TraceBuf`]: event names
//! become `String`s so blobs can cross process boundaries (appended to
//! the existing `Done` payloads by `transport::launch`) and be merged by
//! the coordinator. [`chrome_trace_json`] renders merged blobs as one
//! Chrome trace (the JSON-array-of-events format Perfetto and
//! `chrome://tracing` open directly).
//!
//! Determinism: the renderer uses integer-only math and formatting —
//! timestamps are nanoseconds rendered as fixed-point microseconds
//! (`ns/1000.ns%1000`), never floats — and blobs/events are fully
//! sorted, so a deterministic run produces byte-identical JSON
//! (oracle-tested in `rust/tests/trace_oracle.rs`).

use super::{ClockDomain, Event, EventKind, TraceBuf, NO_SEQ};
use crate::transport::wire::Reader;

/// Owned mirror of [`Event`] (name is a `String`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OwnedEvent {
    pub kind: EventKind,
    pub name: String,
    pub ts_ns: u64,
    pub dur_ns: u64,
    pub seq: u64,
    pub val: u64,
}

/// One thread's trace, detached from its buffer: the unit of shipping
/// and merging.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceBlob {
    pub pid: u32,
    pub tid: u32,
    pub domain: ClockDomain,
    pub dropped: u64,
    pub events: Vec<OwnedEvent>,
}

impl TraceBlob {
    /// Snapshot a buffer (empty blob for a disabled buffer).
    pub fn from_buf(buf: &TraceBuf) -> TraceBlob {
        TraceBlob {
            pid: buf.pid(),
            tid: buf.tid(),
            domain: buf.domain(),
            dropped: buf.dropped(),
            events: buf
                .events()
                .iter()
                .map(|e: &Event| OwnedEvent {
                    kind: e.kind,
                    name: e.name.to_string(),
                    ts_ns: e.ts_ns,
                    dur_ns: e.dur_ns,
                    seq: e.seq,
                    val: e.val,
                })
                .collect(),
        }
    }

    /// Serialize (little-endian, length-prefixed strings); the inverse
    /// is [`TraceBlob::from_bytes`].
    pub fn to_bytes(&self, buf: &mut Vec<u8>) {
        buf.extend_from_slice(&self.pid.to_le_bytes());
        buf.extend_from_slice(&self.tid.to_le_bytes());
        buf.push(match self.domain {
            ClockDomain::Virtual => 0,
            ClockDomain::Wall => 1,
        });
        buf.extend_from_slice(&self.dropped.to_le_bytes());
        buf.extend_from_slice(&(self.events.len() as u32).to_le_bytes());
        for e in &self.events {
            buf.push(match e.kind {
                EventKind::Span => 0,
                EventKind::Instant => 1,
                EventKind::Counter => 2,
            });
            buf.extend_from_slice(&(e.name.len() as u32).to_le_bytes());
            buf.extend_from_slice(e.name.as_bytes());
            buf.extend_from_slice(&e.ts_ns.to_le_bytes());
            buf.extend_from_slice(&e.dur_ns.to_le_bytes());
            buf.extend_from_slice(&e.seq.to_le_bytes());
            buf.extend_from_slice(&e.val.to_le_bytes());
        }
    }

    /// Rebuild from [`TraceBlob::to_bytes`]; `None` on truncation or a
    /// bad tag, never a panic.
    pub fn from_bytes(bytes: &[u8]) -> Option<TraceBlob> {
        let mut r = Reader::new(bytes);
        let blob = Self::read_from(&mut r)?;
        if r.remaining() != 0 {
            return None;
        }
        Some(blob)
    }

    fn read_from(r: &mut Reader) -> Option<TraceBlob> {
        let pid = r.u32().ok()?;
        let tid = r.u32().ok()?;
        let domain = match r.u8().ok()? {
            0 => ClockDomain::Virtual,
            1 => ClockDomain::Wall,
            _ => return None,
        };
        let dropped = r.u64().ok()?;
        let n = r.u32().ok()? as usize;
        let mut events = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let kind = match r.u8().ok()? {
                0 => EventKind::Span,
                1 => EventKind::Instant,
                2 => EventKind::Counter,
                _ => return None,
            };
            let name = r.str_u32().ok()?;
            events.push(OwnedEvent {
                kind,
                name,
                ts_ns: r.u64().ok()?,
                dur_ns: r.u64().ok()?,
                seq: r.u64().ok()?,
                val: r.u64().ok()?,
            });
        }
        Some(TraceBlob { pid, tid, domain, dropped, events })
    }
}

/// Serialize a set of blobs (count-prefixed) — the form appended to
/// `Done` payloads.
pub fn blobs_to_bytes(blobs: &[TraceBlob], buf: &mut Vec<u8>) {
    buf.extend_from_slice(&(blobs.len() as u32).to_le_bytes());
    for b in blobs {
        b.to_bytes(buf);
    }
}

/// Inverse of [`blobs_to_bytes`], consuming from an in-progress reader.
pub fn blobs_read_from(r: &mut Reader) -> Option<Vec<TraceBlob>> {
    let n = r.u32().ok()? as usize;
    let mut out = Vec::with_capacity(n.min(1 << 10));
    for _ in 0..n {
        out.push(TraceBlob::read_from(r)?);
    }
    Some(out)
}

/// Human name for a process id under the engine's pid scheme:
/// 0 = coordinator (and the whole sim), 100+i = worker i, 200+i =
/// merge shard i.
pub fn process_name(pid: u32) -> String {
    match pid {
        0 => "coordinator".to_string(),
        100..=199 => format!("worker {}", pid - 100),
        200..=299 => format!("shard {}", pid - 200),
        other => format!("process {other}"),
    }
}

/// Nanoseconds as a fixed-point microsecond JSON number ("12.345"):
/// integer math only, so rendering is byte-deterministic.
fn us(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

fn esc(s: &str) -> String {
    // event names are engine-chosen identifiers; escape defensively
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Render merged blobs as Chrome-trace-event JSON (object form, one
/// event per line). Blobs are sorted by (pid, tid) and events within a
/// blob by (ts, name, kind, seq, val, dur), so per-(pid,tid) timestamps
/// are monotonically non-decreasing and the output is byte-identical
/// for identical inputs regardless of merge order.
pub fn chrome_trace_json(blobs: &[TraceBlob]) -> String {
    let mut blobs: Vec<&TraceBlob> = blobs.iter().collect();
    blobs.sort_by_key(|b| (b.pid, b.tid));

    let mut lines: Vec<String> = Vec::new();
    let mut named_pids: Vec<u32> = Vec::new();
    for b in &blobs {
        if !named_pids.contains(&b.pid) {
            named_pids.push(b.pid);
            lines.push(format!(
                "{{\"ph\":\"M\",\"pid\":{},\"tid\":0,\"name\":\"process_name\",\
                 \"args\":{{\"name\":\"{}\",\"clock\":\"{}\"}}}}",
                b.pid,
                esc(&process_name(b.pid)),
                b.domain.label()
            ));
        }
        let mut evs: Vec<&OwnedEvent> = b.events.iter().collect();
        evs.sort_by(|a, z| {
            (a.ts_ns, &a.name, a.kind, a.seq, a.val, a.dur_ns)
                .cmp(&(z.ts_ns, &z.name, z.kind, z.seq, z.val, z.dur_ns))
        });
        for e in evs {
            let mut args = String::new();
            if e.seq != NO_SEQ {
                args.push_str(&format!("\"seq\":{}", e.seq));
            }
            match e.kind {
                EventKind::Span => {
                    if e.val != 0 {
                        if !args.is_empty() {
                            args.push(',');
                        }
                        args.push_str(&format!("\"val\":{}", e.val));
                    }
                    lines.push(format!(
                        "{{\"ph\":\"X\",\"pid\":{},\"tid\":{},\"name\":\"{}\",\
                         \"ts\":{},\"dur\":{},\"args\":{{{}}}}}",
                        b.pid,
                        b.tid,
                        esc(&e.name),
                        us(e.ts_ns),
                        us(e.dur_ns),
                        args
                    ));
                }
                EventKind::Instant => {
                    if e.val != 0 {
                        if !args.is_empty() {
                            args.push(',');
                        }
                        args.push_str(&format!("\"val\":{}", e.val));
                    }
                    lines.push(format!(
                        "{{\"ph\":\"i\",\"pid\":{},\"tid\":{},\"name\":\"{}\",\
                         \"ts\":{},\"s\":\"t\",\"args\":{{{}}}}}",
                        b.pid,
                        b.tid,
                        esc(&e.name),
                        us(e.ts_ns),
                        args
                    ));
                }
                EventKind::Counter => {
                    lines.push(format!(
                        "{{\"ph\":\"C\",\"pid\":{},\"tid\":{},\"name\":\"{}\",\
                         \"ts\":{},\"args\":{{\"v\":{}}}}}",
                        b.pid,
                        b.tid,
                        esc(&e.name),
                        us(e.ts_ns),
                        e.val
                    ));
                }
            }
        }
    }

    let mut out = String::from("{\"traceEvents\":[\n");
    out.push_str(&lines.join(",\n"));
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::TraceBuf;

    fn sample_blob() -> TraceBlob {
        let mut b = TraceBuf::active(100, 100, ClockDomain::Wall);
        b.span("route_batch", 1_000, 2_500);
        b.span_seq("flush_send", 3_000, 3_700, 42);
        b.instant("snapshot", 4_000);
        b.instant_full("panes_retired", 4_500, NO_SEQ, 3);
        b.count("queue_depth", 5_000, 17);
        b.to_blob()
    }

    #[test]
    fn blob_bytes_round_trip() {
        let blob = sample_blob();
        let mut bytes = Vec::new();
        blob.to_bytes(&mut bytes);
        let back = TraceBlob::from_bytes(&bytes).expect("round trip");
        assert_eq!(back, blob);
        // truncation is rejected at every cut point, never a panic
        for cut in 0..bytes.len() {
            assert!(TraceBlob::from_bytes(&bytes[..cut]).is_none(), "cut at {cut}");
        }
        // trailing garbage is rejected too
        bytes.push(0);
        assert!(TraceBlob::from_bytes(&bytes).is_none());
    }

    #[test]
    fn blob_set_round_trips_through_reader() {
        let a = sample_blob();
        let mut empty = TraceBlob::from_buf(&TraceBuf::disabled());
        empty.pid = 200;
        empty.tid = 200;
        let mut bytes = Vec::new();
        blobs_to_bytes(&[a.clone(), empty.clone()], &mut bytes);
        let mut r = Reader::new(&bytes);
        let back = blobs_read_from(&mut r).expect("round trip");
        assert_eq!(back, vec![a, empty]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn chrome_json_is_merge_order_invariant_and_valid_shape() {
        let mut w = TraceBuf::active(100, 100, ClockDomain::Wall);
        w.span("flush_send", 10_000, 11_000);
        let mut s = TraceBuf::active(200, 200, ClockDomain::Wall);
        s.span_seq("merge_absorb", 12_000, 13_000, 9);
        let ab = chrome_trace_json(&[w.to_blob(), s.to_blob()]);
        let ba = chrome_trace_json(&[s.to_blob(), w.to_blob()]);
        assert_eq!(ab, ba, "render must not depend on merge order");
        assert!(ab.starts_with("{\"traceEvents\":[\n"));
        assert!(ab.ends_with("\n]}\n"));
        assert!(ab.contains("\"name\":\"process_name\""));
        assert!(ab.contains("\"name\":\"worker 0\""));
        assert!(ab.contains("\"name\":\"shard 0\""));
        assert!(ab.contains("\"ts\":10.000"));
        assert!(ab.contains("\"dur\":1.000"));
        assert!(ab.contains("\"seq\":9"));
        assert!(!ab.contains("NaN"));
    }

    #[test]
    fn events_sort_monotonically_within_a_thread() {
        let mut b = TraceBuf::active(0, 1, ClockDomain::Virtual);
        // recorded out of order (spans are pushed at end time)
        b.span("outer", 100, 900);
        b.span("inner", 200, 300);
        b.instant("mark", 50);
        let json = chrome_trace_json(&[b.to_blob()]);
        let ts: Vec<f64> = json
            .lines()
            .filter(|l| l.contains("\"ts\":"))
            .map(|l| {
                let i = l.find("\"ts\":").unwrap() + 5;
                let rest = &l[i..];
                let end = rest.find(',').unwrap();
                rest[..end].parse::<f64>().unwrap()
            })
            .collect();
        for pair in ts.windows(2) {
            assert!(pair[0] <= pair[1], "timestamps must be sorted: {ts:?}");
        }
    }
}
