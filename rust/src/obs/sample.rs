//! Per-epoch time series: the periodic telemetry sampler.
//!
//! Each engine actor (sim main loop, rt workers/shards, multi-process
//! children) owns a [`Sampler`] that records a [`Sample`] row at its
//! flush/absorb boundaries once `interval_ns` has elapsed. Counters
//! (`tuples`, `wire_bytes`, `absorbed`) are *cumulative* totals at the
//! sample timestamp — rates are derived from consecutive deltas at
//! render time; the gauge fields (`queue_depth`, `open_panes`,
//! `open_entries`, `imbalance_x1000`, `replay_backlog`) are
//! point-in-time readings. Everything is integer-valued so JSONL output
//! is byte-deterministic in the sim's virtual clock domain.
//!
//! `src` uses the same id scheme as trace pids: 0 = coordinator/sim,
//! 100+i = worker i, 200+i = merge shard i.

use crate::transport::wire::Reader;

/// One telemetry row (see module docs for counter-vs-gauge semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Sample {
    pub src: u32,
    pub ts_ns: u64,
    /// Cumulative tuples processed by this actor.
    pub tuples: u64,
    /// Cumulative wire bytes sent + received by this actor.
    pub wire_bytes: u64,
    /// Gauge: tuples queued and unacknowledged toward this actor.
    pub queue_depth: u64,
    /// Gauge: open event-time panes held by this actor.
    pub open_panes: u64,
    /// Gauge: live aggregation entries (keys across open panes).
    pub open_entries: u64,
    /// Cumulative flush batches absorbed (merge shards).
    pub absorbed: u64,
    /// Gauge: max/mean absorb-mass imbalance across shards, x1000
    /// (coordinator only; 1000 = perfectly balanced).
    pub imbalance_x1000: u64,
    /// Gauge: flush batches logged but not yet re-deliverable (recovery).
    pub replay_backlog: u64,
}

impl Sample {
    fn to_bytes(&self, buf: &mut Vec<u8>) {
        buf.extend_from_slice(&self.src.to_le_bytes());
        for v in [
            self.ts_ns,
            self.tuples,
            self.wire_bytes,
            self.queue_depth,
            self.open_panes,
            self.open_entries,
            self.absorbed,
            self.imbalance_x1000,
            self.replay_backlog,
        ] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn read_from(r: &mut Reader) -> Option<Sample> {
        Some(Sample {
            src: r.u32().ok()?,
            ts_ns: r.u64().ok()?,
            tuples: r.u64().ok()?,
            wire_bytes: r.u64().ok()?,
            queue_depth: r.u64().ok()?,
            open_panes: r.u64().ok()?,
            open_entries: r.u64().ok()?,
            absorbed: r.u64().ok()?,
            imbalance_x1000: r.u64().ok()?,
            replay_backlog: r.u64().ok()?,
        })
    }

    /// One JSONL line (fixed key order, integers only).
    pub fn jsonl_line(&self) -> String {
        format!(
            "{{\"src\":{},\"ts_ns\":{},\"tuples\":{},\"wire_bytes\":{},\
             \"queue_depth\":{},\"open_panes\":{},\"open_entries\":{},\
             \"absorbed\":{},\"imbalance_x1000\":{},\"replay_backlog\":{}}}",
            self.src,
            self.ts_ns,
            self.tuples,
            self.wire_bytes,
            self.queue_depth,
            self.open_panes,
            self.open_entries,
            self.absorbed,
            self.imbalance_x1000,
            self.replay_backlog
        )
    }
}

/// Serialize a sample set (count-prefixed) — appended to `Done`
/// payloads next to the trace blobs.
pub fn samples_to_bytes(samples: &[Sample], buf: &mut Vec<u8>) {
    buf.extend_from_slice(&(samples.len() as u32).to_le_bytes());
    for s in samples {
        s.to_bytes(buf);
    }
}

/// Inverse of [`samples_to_bytes`], consuming from an in-progress reader.
pub fn samples_read_from(r: &mut Reader) -> Option<Vec<Sample>> {
    let n = r.u32().ok()? as usize;
    let mut out = Vec::with_capacity(n.min(1 << 12));
    for _ in 0..n {
        out.push(Sample::read_from(r)?);
    }
    Some(out)
}

/// Render merged samples as JSONL, sorted by (ts_ns, src) so the output
/// does not depend on merge order.
pub fn jsonl(samples: &[Sample]) -> String {
    let mut rows: Vec<&Sample> = samples.iter().collect();
    rows.sort_by_key(|s| (s.ts_ns, s.src));
    let mut out = String::new();
    for s in rows {
        out.push_str(&s.jsonl_line());
        out.push('\n');
    }
    out
}

/// Periodic sampler: `due` at flush/absorb boundaries, `record` pushes
/// a row and re-arms. Disabled samplers cost one branch per `due`.
#[derive(Debug)]
pub struct Sampler {
    src: u32,
    interval_ns: u64,
    next_ns: u64,
    samples: Vec<Sample>,
    active: bool,
}

/// Default sampling interval: 10ms of engine time (virtual or wall).
pub const DEFAULT_INTERVAL_NS: u64 = 10_000_000;

impl Sampler {
    /// Inert sampler: `due` is always false, `record` is ignored.
    pub fn disabled() -> Self {
        Sampler {
            src: 0,
            interval_ns: u64::MAX,
            next_ns: u64::MAX,
            samples: Vec::new(),
            active: false,
        }
    }

    /// Recording sampler for actor `src`, firing every `interval_ns`.
    pub fn active(src: u32, interval_ns: u64) -> Self {
        Sampler {
            src,
            interval_ns: interval_ns.max(1),
            next_ns: 0,
            samples: Vec::new(),
            active: true,
        }
    }

    /// Recording iff the process-wide default (`obs::set_enabled`) is on.
    pub fn for_cli(src: u32, interval_ns: u64) -> Self {
        if super::enabled() {
            Self::active(src, interval_ns)
        } else {
            Self::disabled()
        }
    }

    #[inline(always)]
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Has the sampling interval elapsed at `now`?
    #[inline(always)]
    pub fn due(&self, now_ns: u64) -> bool {
        self.active && now_ns >= self.next_ns
    }

    /// Actor id under the pid scheme, for filling [`Sample::src`].
    pub fn src(&self) -> u32 {
        self.src
    }

    /// Push one row (caller fills the fields; `src` is overwritten) and
    /// re-arm the interval past the row's timestamp.
    pub fn record(&mut self, mut s: Sample) {
        if !self.active {
            return;
        }
        s.src = self.src;
        // re-arm on the interval grid so a late sample doesn't fire a
        // burst of catch-up rows
        let next = self.next_ns.max(s.ts_ns.saturating_add(1));
        let rem = next % self.interval_ns;
        self.next_ns =
            if rem == 0 { next } else { next.saturating_add(self.interval_ns - rem) };
        self.samples.push(s);
    }

    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    pub fn into_samples(self) -> Vec<Sample> {
        self.samples
    }
}

/// Format an integer rate with a compact suffix (k/M) for report rows.
fn fmt_rate(v: f64) -> String {
    if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}k", v / 1e3)
    } else {
        format!("{v:.0}")
    }
}

fn min_avg_max(vals: &[f64]) -> Option<(f64, f64, f64)> {
    if vals.is_empty() {
        return None;
    }
    let mut min = f64::INFINITY;
    let mut max = f64::NEG_INFINITY;
    let mut sum = 0.0;
    for &v in vals {
        min = min.min(v);
        max = max.max(v);
        sum += v;
    }
    Some((min, sum / vals.len() as f64, max))
}

/// Sparkline-style min/avg/max summary rows for the report tables.
///
/// Rates come from consecutive same-`src` deltas of the cumulative
/// counters; gauges are summarized directly. Rows whose series is all
/// zero are omitted, so non-windowed or single-process runs don't print
/// dead rows.
pub fn summary_rows(samples: &[Sample]) -> Vec<(String, String)> {
    let mut rows: Vec<&Sample> = samples.iter().collect();
    rows.sort_by_key(|s| (s.src, s.ts_ns));

    let mut tuple_rates = Vec::new();
    let mut byte_rates = Vec::new();
    for pair in rows.windows(2) {
        let (a, b) = (pair[0], pair[1]);
        if a.src != b.src || b.ts_ns <= a.ts_ns {
            continue;
        }
        let dt = (b.ts_ns - a.ts_ns) as f64 / 1e9;
        tuple_rates.push(b.tuples.saturating_sub(a.tuples) as f64 / dt);
        byte_rates.push(b.wire_bytes.saturating_sub(a.wire_bytes) as f64 / dt);
    }

    let gauge = |f: fn(&Sample) -> u64| -> Vec<f64> { rows.iter().map(|s| f(s) as f64).collect() };

    let mut out: Vec<(String, String)> = Vec::new();
    let mut push_rate = |label: &str, vals: &[f64]| {
        if let Some((min, avg, max)) = min_avg_max(vals) {
            if max > 0.0 {
                out.push((
                    label.to_string(),
                    format!("{} / {} / {}", fmt_rate(min), fmt_rate(avg), fmt_rate(max)),
                ));
            }
        }
    };
    push_rate("tuples/s (min/avg/max)", &tuple_rates);
    push_rate("wire bytes/s (min/avg/max)", &byte_rates);
    for (label, f) in [
        ("queue depth (min/avg/max)", (|s| s.queue_depth) as fn(&Sample) -> u64),
        ("open panes (min/avg/max)", |s| s.open_panes),
        ("open entries (min/avg/max)", |s| s.open_entries),
        ("shard imbalance x1000 (min/avg/max)", |s| s.imbalance_x1000),
        ("replay backlog (min/avg/max)", |s| s.replay_backlog),
    ] {
        push_rate(label, &gauge(f));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sampler_is_inert() {
        let mut s = Sampler::disabled();
        assert!(!s.due(u64::MAX - 1));
        s.record(Sample { ts_ns: 5, tuples: 1, ..Sample::default() });
        assert!(s.samples().is_empty());
    }

    #[test]
    fn sampler_fires_on_the_interval_grid() {
        let mut s = Sampler::active(100, 10);
        assert!(s.due(0));
        s.record(Sample { ts_ns: 0, tuples: 10, ..Sample::default() });
        assert!(!s.due(5));
        assert!(s.due(10));
        s.record(Sample { ts_ns: 13, tuples: 25, ..Sample::default() });
        // re-armed past 13 on the grid: next fire at 20
        assert!(!s.due(19));
        assert!(s.due(20));
        assert_eq!(s.samples().len(), 2);
        assert_eq!(s.samples()[0].src, 100, "src is stamped by the sampler");
    }

    #[test]
    fn bytes_round_trip_and_reject_truncation() {
        let rows = vec![
            Sample { src: 0, ts_ns: 10, tuples: 100, wire_bytes: 5000, ..Sample::default() },
            Sample { src: 200, ts_ns: 20, absorbed: 7, open_panes: 3, ..Sample::default() },
        ];
        let mut bytes = Vec::new();
        samples_to_bytes(&rows, &mut bytes);
        let mut r = Reader::new(&bytes);
        let back = samples_read_from(&mut r).expect("round trip");
        assert_eq!(back, rows);
        assert_eq!(r.remaining(), 0);
        for cut in 0..bytes.len() {
            let mut r = Reader::new(&bytes[..cut]);
            assert!(samples_read_from(&mut r).is_none(), "cut at {cut}");
        }
    }

    #[test]
    fn jsonl_is_sorted_and_integer_only() {
        let rows = vec![
            Sample { src: 200, ts_ns: 20, absorbed: 7, ..Sample::default() },
            Sample { src: 100, ts_ns: 20, tuples: 50, ..Sample::default() },
            Sample { src: 0, ts_ns: 10, tuples: 100, ..Sample::default() },
        ];
        let text = jsonl(&rows);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("\"ts_ns\":10"));
        assert!(lines[1].contains("\"src\":100"), "ties broken by src");
        assert!(lines[2].contains("\"src\":200"));
        assert!(!text.contains('.'), "virtual-domain JSONL must be integer-only");
    }

    #[test]
    fn summary_rates_use_consecutive_deltas_per_src() {
        let rows = vec![
            Sample { src: 0, ts_ns: 1_000_000_000, tuples: 1000, ..Sample::default() },
            Sample { src: 0, ts_ns: 2_000_000_000, tuples: 3000, ..Sample::default() },
            Sample { src: 0, ts_ns: 3_000_000_000, tuples: 9000, ..Sample::default() },
        ];
        let out = summary_rows(&rows);
        let rate = out.iter().find(|(l, _)| l.starts_with("tuples/s")).expect("rate row");
        // deltas: 2000/s and 6000/s -> min 2.0k avg 4.0k max 6.0k
        assert_eq!(rate.1, "2.0k / 4.0k / 6.0k");
        // all-zero series are omitted
        assert!(!out.iter().any(|(l, _)| l.starts_with("replay backlog")));
    }
}
