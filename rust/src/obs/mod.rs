//! Lock-light tracing + telemetry: per-thread span/event recorder.
//!
//! Every engine thread (sim main loop, rt sources/workers/shards, and the
//! multi-process children) owns a private [`TraceBuf`] — a fixed-capacity
//! ring of [`Event`]s with nanosecond timestamps. There are no locks and
//! no shared state on the record path: buffers are merged only after the
//! run, into [`export::TraceBlob`]s and one Chrome-trace-event JSON
//! (`--trace-out`, openable in Perfetto — see `docs/OBSERVABILITY.md`).
//!
//! Clock discipline: this module never reads a clock. Timestamps are
//! *passed in* by the caller — virtual ticks in the simulator
//! ([`ClockDomain::Virtual`]), shared-epoch wall nanoseconds from
//! `transport::Clock` in rt/deploy ([`ClockDomain::Wall`]) so
//! multi-process timelines align. The `fish lint` `obs-clock` rule
//! enforces that nothing under `rust/src/obs/` calls `Instant::now` or
//! `SystemTime::now` directly.
//!
//! Overhead discipline: every recording call starts with an `#[inline]`
//! branch on the buffer's `active` flag, and the [`span!`]/[`count!`]
//! macros evaluate their arguments only under that branch — a disabled
//! buffer costs one predictable branch per call site. The disabled-path
//! cost on the routing and merge-absorb hot paths is measured in
//! `benches/hotpath.rs` and gated by `scripts/check_perf.py`.

pub mod export;
pub mod sample;

pub use export::{chrome_trace_json, TraceBlob};
pub use sample::{Sample, Sampler, DEFAULT_INTERVAL_NS};

use std::sync::atomic::{AtomicBool, Ordering};

/// Process-wide default for *newly constructed* CLI-path buffers.
///
/// Flipped once by `main` when `--trace-out`/`--metrics-out` is given,
/// *before* any engine threads start; it is consulted only at
/// [`TraceBuf`] construction time, never on the record path, so parallel
/// tests that build their buffers explicitly are unaffected by it.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Set the process-wide default for newly constructed buffers.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// Read the process-wide default (see [`set_enabled`]).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::SeqCst)
}

/// Which clock a buffer's timestamps come from. Traces from the two
/// domains are never merged into one timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockDomain {
    /// Simulator virtual time (`i * interarrival_ns`): deterministic,
    /// byte-identical run-to-run.
    Virtual,
    /// `transport::Clock` epoch nanoseconds: one epoch is chosen by the
    /// coordinator and shared with every child process.
    Wall,
}

impl ClockDomain {
    /// Stable lowercase label used in exports and reports.
    pub fn label(&self) -> &'static str {
        match self {
            ClockDomain::Virtual => "virtual",
            ClockDomain::Wall => "wall",
        }
    }
}

/// Event flavor, mirroring the Chrome trace phases we emit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// Complete span (`ph:"X"`): `ts_ns` start, `dur_ns` length.
    Span,
    /// Point-in-time marker (`ph:"i"`).
    Instant,
    /// Counter sample (`ph:"C"`): `val` is the series value at `ts_ns`.
    Counter,
}

/// `seq` value meaning "this event is not part of a causal chain".
pub const NO_SEQ: u64 = u64::MAX;

/// One recorded event. `name` stays `&'static str` on the hot path;
/// the owned mirror for serialization is [`export::OwnedEvent`].
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub kind: EventKind,
    pub name: &'static str,
    pub ts_ns: u64,
    pub dur_ns: u64,
    /// Causal-chain key ([`chain_id`]) or [`NO_SEQ`].
    pub seq: u64,
    /// Counter value / span payload size; 0 when unused.
    pub val: u64,
}

/// Pack a flush chain key: `FlushMsg.seq` is only monotonic per
/// (worker, shard) lane, so the cross-process chain id is the triple.
/// Layout: worker in the top 20 bits, shard in 12, seq in the low 32.
#[inline]
pub fn chain_id(worker: u64, shard: u64, seq: u64) -> u64 {
    debug_assert!(worker < (1 << 20) && shard < (1 << 12) && seq < (1 << 32));
    (worker << 44) | ((shard & 0xfff) << 32) | (seq & 0xffff_ffff)
}

/// Default ring capacity per buffer (events, not bytes). At ~56 bytes an
/// event this is ~3.5 MiB per thread fully loaded; overflow drops the
/// *newest* events and counts them, so the recorded prefix stays causal.
pub const DEFAULT_CAP: usize = 1 << 16;

/// Per-thread ring-buffered event recorder. Not `Sync` — one owner.
#[derive(Debug)]
pub struct TraceBuf {
    pid: u32,
    tid: u32,
    domain: ClockDomain,
    events: Vec<Event>,
    /// LIFO stack for [`TraceBuf::begin`]/[`TraceBuf::end`] pairing.
    open: Vec<(&'static str, u64)>,
    dropped: u64,
    cap: usize,
    active: bool,
}

impl TraceBuf {
    /// Inert buffer: every record call is a single branch, nothing is
    /// stored, `to_blob` yields an empty blob.
    pub fn disabled() -> Self {
        TraceBuf {
            pid: 0,
            tid: 0,
            domain: ClockDomain::Virtual,
            events: Vec::new(),
            open: Vec::new(),
            dropped: 0,
            cap: 0,
            active: false,
        }
    }

    /// Recording buffer with the default ring capacity.
    pub fn active(pid: u32, tid: u32, domain: ClockDomain) -> Self {
        Self::with_cap(pid, tid, domain, DEFAULT_CAP)
    }

    /// Recording buffer with an explicit ring capacity.
    pub fn with_cap(pid: u32, tid: u32, domain: ClockDomain, cap: usize) -> Self {
        TraceBuf {
            pid,
            tid,
            domain,
            events: Vec::with_capacity(cap.min(1 << 12)),
            open: Vec::new(),
            dropped: 0,
            cap,
            active: true,
        }
    }

    /// Recording iff the process-wide default ([`set_enabled`]) is on:
    /// the constructor used by the engine/CLI plumbing.
    pub fn for_cli(pid: u32, tid: u32, domain: ClockDomain) -> Self {
        if enabled() {
            Self::active(pid, tid, domain)
        } else {
            Self::disabled()
        }
    }

    /// The branch every record call and macro site takes first.
    #[inline(always)]
    pub fn is_active(&self) -> bool {
        self.active
    }

    #[inline]
    fn push(&mut self, ev: Event) {
        if self.events.len() >= self.cap {
            self.dropped += 1;
        } else {
            self.events.push(ev);
        }
    }

    /// Record a complete span. `end < start` clamps to zero duration —
    /// durations are never negative.
    #[inline]
    pub fn span(&mut self, name: &'static str, start_ns: u64, end_ns: u64) {
        self.span_full(name, start_ns, end_ns, NO_SEQ, 0);
    }

    /// Complete span carrying a causal-chain key (see [`chain_id`]).
    #[inline]
    pub fn span_seq(&mut self, name: &'static str, start_ns: u64, end_ns: u64, seq: u64) {
        self.span_full(name, start_ns, end_ns, seq, 0);
    }

    /// Complete span with both chain key and payload value.
    #[inline]
    pub fn span_full(&mut self, name: &'static str, start_ns: u64, end_ns: u64, seq: u64, val: u64) {
        if !self.active {
            return;
        }
        self.push(Event {
            kind: EventKind::Span,
            name,
            ts_ns: start_ns,
            dur_ns: end_ns.saturating_sub(start_ns),
            seq,
            val,
        });
    }

    /// Point-in-time marker.
    #[inline]
    pub fn instant(&mut self, name: &'static str, ts_ns: u64) {
        self.instant_full(name, ts_ns, NO_SEQ, 0);
    }

    /// Marker carrying a causal-chain key.
    #[inline]
    pub fn instant_seq(&mut self, name: &'static str, ts_ns: u64, seq: u64) {
        self.instant_full(name, ts_ns, seq, 0);
    }

    /// Marker with chain key and value (e.g. "panes_retired", val = n).
    #[inline]
    pub fn instant_full(&mut self, name: &'static str, ts_ns: u64, seq: u64, val: u64) {
        if !self.active {
            return;
        }
        self.push(Event { kind: EventKind::Instant, name, ts_ns, dur_ns: 0, seq, val });
    }

    /// Counter sample: the series `name` has value `val` at `ts_ns`.
    #[inline]
    pub fn count(&mut self, name: &'static str, ts_ns: u64, val: u64) {
        if !self.active {
            return;
        }
        self.push(Event { kind: EventKind::Counter, name, ts_ns, dur_ns: 0, seq: NO_SEQ, val });
    }

    /// Open a span; every `begin` must be closed by a matching
    /// [`TraceBuf::end`] with the same name (LIFO nesting).
    #[inline]
    pub fn begin(&mut self, name: &'static str, ts_ns: u64) {
        if !self.active {
            return;
        }
        self.open.push((name, ts_ns));
    }

    /// Close the innermost open span. A name mismatch or an `end`
    /// without a `begin` records nothing and counts as a drop (the
    /// span-pairing test pins both counters to zero).
    #[inline]
    pub fn end(&mut self, name: &'static str, ts_ns: u64) {
        if !self.active {
            return;
        }
        match self.open.pop() {
            Some((open_name, start)) if open_name == name => self.span(name, start, ts_ns),
            Some(other) => {
                self.open.push(other);
                self.dropped += 1;
            }
            None => self.dropped += 1,
        }
    }

    /// Number of spans currently open (begun, not ended).
    pub fn open_spans(&self) -> usize {
        self.open.len()
    }

    /// Events dropped on ring overflow or begin/end mispairing.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Recorded events, in record order.
    pub fn events(&self) -> &[Event] {
        &self.events
    }

    pub fn pid(&self) -> u32 {
        self.pid
    }

    pub fn tid(&self) -> u32 {
        self.tid
    }

    pub fn domain(&self) -> ClockDomain {
        self.domain
    }

    /// Owned snapshot for shipping/merging (empty for disabled buffers).
    pub fn to_blob(&self) -> TraceBlob {
        TraceBlob::from_buf(self)
    }
}

/// `obs::span!(buf, "name", start, end)` / with `seq = k` — records a
/// complete span; arguments are evaluated only when the buffer is
/// active, so a disabled buffer costs exactly one branch.
#[macro_export]
macro_rules! obs_span {
    ($buf:expr, $name:expr, $start:expr, $end:expr) => {
        if $buf.is_active() {
            $buf.span($name, $start, $end);
        }
    };
    ($buf:expr, $name:expr, $start:expr, $end:expr, seq = $seq:expr) => {
        if $buf.is_active() {
            $buf.span_seq($name, $start, $end, $seq);
        }
    };
}

/// `obs::count!(buf, "name", ts, val)` — records a counter sample;
/// arguments are evaluated only when the buffer is active.
#[macro_export]
macro_rules! obs_count {
    ($buf:expr, $name:expr, $ts:expr, $val:expr) => {
        if $buf.is_active() {
            $buf.count($name, $ts, $val);
        }
    };
}

// Make the crate-root macros callable as `obs::span!` / `obs::count!`.
pub use crate::obs_count as count;
pub use crate::obs_span as span;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_buffer_records_nothing() {
        let mut b = TraceBuf::disabled();
        assert!(!b.is_active());
        b.span("x", 0, 10);
        b.instant("y", 5);
        b.count("z", 5, 1);
        b.begin("w", 0);
        b.end("w", 1);
        assert!(b.events().is_empty());
        assert_eq!(b.dropped(), 0);
        assert!(b.to_blob().events.is_empty());
    }

    #[test]
    fn spans_never_have_negative_durations() {
        let mut b = TraceBuf::active(0, 0, ClockDomain::Virtual);
        b.span("backwards", 100, 40); // end < start clamps to 0
        b.span("ok", 40, 100);
        assert_eq!(b.events()[0].dur_ns, 0);
        assert_eq!(b.events()[1].dur_ns, 60);
    }

    #[test]
    fn begin_end_pairs_and_counts_mispairs() {
        let mut b = TraceBuf::active(1, 2, ClockDomain::Wall);
        b.begin("outer", 10);
        b.begin("inner", 20);
        b.end("inner", 30);
        b.end("outer", 50);
        assert_eq!(b.open_spans(), 0);
        assert_eq!(b.dropped(), 0);
        let names: Vec<_> = b.events().iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["inner", "outer"]);
        assert_eq!(b.events()[0].dur_ns, 10);
        assert_eq!(b.events()[1].dur_ns, 40);
        // mispaired end: recorded as a drop, stack untouched
        b.begin("a", 60);
        b.end("b", 70);
        assert_eq!(b.open_spans(), 1);
        assert_eq!(b.dropped(), 1);
        // end with empty stack
        b.end("a", 80);
        b.end("a", 90);
        assert_eq!(b.open_spans(), 0);
        assert_eq!(b.dropped(), 2);
    }

    #[test]
    fn ring_overflow_drops_newest_and_counts() {
        let mut b = TraceBuf::with_cap(0, 0, ClockDomain::Virtual, 4);
        for i in 0..10u64 {
            b.instant("tick", i);
        }
        assert_eq!(b.events().len(), 4);
        assert_eq!(b.dropped(), 6);
        // the *oldest* events survived (causal prefix)
        assert_eq!(b.events()[0].ts_ns, 0);
        assert_eq!(b.events()[3].ts_ns, 3);
        assert_eq!(b.to_blob().dropped, 6);
    }

    #[test]
    fn macros_skip_argument_evaluation_when_disabled() {
        let hits = std::cell::Cell::new(0u32);
        let tick = |n: u64| {
            hits.set(hits.get() + 1);
            n
        };
        let mut b = TraceBuf::disabled();
        span!(b, "s", tick(1), tick(2));
        count!(b, "c", tick(3), 1);
        assert_eq!(hits.get(), 0, "disabled macro sites must not evaluate args");
        let mut b = TraceBuf::active(0, 0, ClockDomain::Virtual);
        span!(b, "s", tick(1), tick(2));
        span!(b, "s2", tick(3), tick(4), seq = 7);
        count!(b, "c", tick(5), 9);
        assert_eq!(hits.get(), 5);
        assert_eq!(b.events().len(), 3);
        assert_eq!(b.events()[1].seq, 7);
        assert_eq!(b.events()[2].val, 9);
    }

    #[test]
    fn chain_id_is_injective_over_engine_ranges() {
        let mut seen = std::collections::HashSet::new();
        for w in [0u64, 1, 7, 127] {
            for s in [0u64, 1, 3] {
                for q in [0u64, 1, 1000, 0xffff_ffff - 1] {
                    assert!(seen.insert(chain_id(w, s, q)));
                }
            }
        }
    }

    #[test]
    fn global_flag_gates_cli_construction_only() {
        // never toggled concurrently with other tests' record paths:
        // for_cli reads it once at construction.
        set_enabled(true);
        let b = TraceBuf::for_cli(0, 0, ClockDomain::Virtual);
        set_enabled(false);
        assert!(b.is_active(), "flag is latched at construction");
        let b2 = TraceBuf::for_cli(0, 0, ClockDomain::Virtual);
        assert!(!b2.is_active());
    }
}
