//! Configuration system (TOML-subset, hand-rolled — no serde offline).
//!
//! Supports the subset real deployments need: `[section]` headers,
//! `key = value` with string / integer / float / bool / string-array
//! values, `#` comments. CLI flags override file values (see [`crate::cli`]).

mod parser;

pub use parser::{ConfigError, ConfigFile, Value};

use crate::coordinator::SchemeKind;

/// Default routing batch size — the single source of truth shared by
/// [`Config::default`] and [`crate::engine::rt::RtOptions::default`].
pub const DEFAULT_BATCH: usize = 256;

/// Default partial-aggregate flush interval in milliseconds (wall ms in
/// the runtime engine, virtual ms in the simulator) — shared by
/// [`Config::default`] and [`crate::engine::rt::RtOptions::default`].
pub const DEFAULT_AGG_FLUSH_MS: u64 = 1;

/// Fully-resolved experiment configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Grouping scheme under test.
    pub scheme: SchemeKind,
    /// Workload name: `zf`, `mt` or `am`.
    pub workload: String,
    /// Number of tuples to stream.
    pub tuples: usize,
    /// Zipf exponent for `zf`.
    pub zipf_z: f64,
    /// Number of sources.
    pub sources: usize,
    /// Number of workers.
    pub workers: usize,
    /// Worker capacity multipliers (cycled if shorter than `workers`);
    /// 1.0 = baseline; 2.0 = twice as fast.
    pub capacities: Vec<f64>,
    /// FISH / D-C / W-C: max tracked keys `K_max`.
    pub key_capacity: usize,
    /// FISH: epoch size `N_epoch` in tuples.
    pub epoch: usize,
    /// FISH: decay factor `α`.
    pub alpha: f64,
    /// Hot-key threshold numerator: θ = `theta_num / workers`
    /// (paper default 1/4 → θ = 1/(4n)).
    pub theta_num: f64,
    /// FISH: minimum workers per hot key `d_min`.
    pub d_min: usize,
    /// FISH: HWA estimation interval `T` (virtual ticks / ns).
    pub interval: u64,
    /// Virtual nodes per worker on the consistent-hash ring.
    pub vnodes: usize,
    /// PRNG seed.
    pub seed: u64,
    /// Mean per-tuple service time in ns (runtime engine) / ticks (sim).
    pub service_ns: u64,
    /// Mean tuple inter-arrival in ns per source.
    pub interarrival_ns: u64,
    /// Identifier backend: `native` (pure Rust Alg. 1) or `xla-cms`
    /// (AOT Pallas epoch_stats via PJRT).
    pub identifier: String,
    /// Directory holding the AOT artifacts.
    pub artifacts_dir: String,
    /// Routing batch size: tuples per `route_batch` call in both engines
    /// (and per channel send in the runtime engine).
    pub batch: usize,
    /// Rebalance baseline: `max/mean − 1` local-load imbalance that
    /// triggers a hot-key migration round.
    pub rebalance_threshold: f64,
    /// Two-phase aggregation: per-worker partial-flush interval in
    /// milliseconds (wall ms in the runtime engine, virtual ms in the
    /// simulator). 0 = flush only at end of stream. Smaller = fresher
    /// merged results but more aggregation traffic (`--agg_flush_ms`).
    pub agg_flush_ms: u64,
    /// Number of stage-two merge shards (`--agg_shards`). 1 = the
    /// single-aggregator topology; >1 partitions the merged key space
    /// by key range over a consistent-hash ring and (in the runtime
    /// engine) runs one aggregator thread per shard. Merged results are
    /// shard-count-invariant — only parallelism and the per-shard
    /// ledgers change.
    pub agg_shards: usize,
    /// Windowed aggregation: tumbling-pane length in milliseconds of
    /// *event time* (`--agg_window_ms`; virtual ms in the simulator,
    /// trace-emit ms in the runtime engine). 0 = unwindowed, exactly
    /// today's all-time fold. When > 0, closed panes retire on
    /// watermark advance into per-window exact counts + per-window
    /// top-k (`SimResult::windows` / `RtResult::windows`); per-window
    /// results are invariant under scheme, shard count, flush cadence
    /// and engine.
    pub agg_window_ms: u64,
    /// Watermark slack before pane retirement, in milliseconds of event
    /// time (`--agg_lateness_ms`). Panes stay open until the watermark
    /// passes `pane end + slack`, so bounded disorder absorbs in place
    /// instead of taking the retire-reopen-remerge path (the re-merged
    /// tuple mass is reported as `late reopen mass`). 0 = retire the
    /// instant the watermark passes a pane's end. Never changes
    /// per-window results — only retirement timing and the lifecycle
    /// ledger.
    pub agg_lateness_ms: u64,
    /// Runtime-engine lane backend (`--transport`): `loopback`
    /// (in-process channels, the default), `uds` or `tcp` (socket lanes
    /// carrying the length-prefixed wire format with credit-based flow
    /// control). Merged counts, windows and top-k are
    /// transport-invariant; the simulator ignores this.
    pub transport: String,
    /// Multi-process deployment (`deploy --processes N`): 0 = threads
    /// in one process (the default); N > 0 runs N worker processes plus
    /// one process per merge shard, sources staying in the coordinator.
    /// Loopback transport is promoted to a socket kind for the
    /// process-crossing lanes.
    pub processes: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            scheme: SchemeKind::Fish,
            workload: "zf".into(),
            tuples: 1_000_000,
            zipf_z: 1.5,
            sources: 4,
            workers: 32,
            capacities: vec![1.0],
            key_capacity: 1000,
            epoch: 1000,
            alpha: 0.2,
            theta_num: 0.25,
            d_min: 2,
            interval: 10_000_000, // 10ms in ns (paper: 10s at cluster scale)
            vnodes: 64,
            seed: 42,
            service_ns: 1_000,
            interarrival_ns: 100,
            identifier: "native".into(),
            artifacts_dir: "artifacts".into(),
            batch: DEFAULT_BATCH,
            rebalance_threshold: 0.2,
            agg_flush_ms: DEFAULT_AGG_FLUSH_MS,
            agg_shards: 1,
            agg_window_ms: 0,
            agg_lateness_ms: 0,
            transport: "loopback".into(),
            processes: 0,
        }
    }
}

impl Config {
    /// Per-worker capacity vector of length `workers` (cycling the
    /// configured multipliers).
    pub fn capacity_vec(&self) -> Vec<f64> {
        (0..self.workers)
            .map(|w| self.capacities[w % self.capacities.len()])
            .collect()
    }

    /// Hot-key threshold θ (fraction of total stream frequency).
    pub fn theta(&self) -> f64 {
        self.theta_num / self.workers as f64
    }

    /// Load from a config file, then apply `overrides` (flag, value) pairs.
    pub fn from_file(path: &str) -> Result<Config, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::Io(format!("{path}: {e}")))?;
        let file = ConfigFile::parse(&text)?;
        let mut cfg = Config::default();
        cfg.apply_file(&file)?;
        Ok(cfg)
    }

    /// Apply a parsed file onto this config.
    pub fn apply_file(&mut self, f: &ConfigFile) -> Result<(), ConfigError> {
        for (section, key, value) in f.entries() {
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            self.set(&full, value)?;
        }
        Ok(())
    }

    /// Set a single dotted key from a parsed [`Value`].
    pub fn set(&mut self, key: &str, v: &Value) -> Result<(), ConfigError> {
        let err = |what: &str| ConfigError::Type(format!("{key}: expected {what}, got {v:?}"));
        match key {
            "scheme" | "run.scheme" => {
                self.scheme = v
                    .as_str()
                    .ok_or_else(|| err("string"))?
                    .parse()
                    .map_err(ConfigError::Type)?;
            }
            "workload" | "run.workload" => {
                self.workload = v.as_str().ok_or_else(|| err("string"))?.to_string()
            }
            "tuples" | "run.tuples" => self.tuples = v.as_int().ok_or_else(|| err("int"))? as usize,
            "zipf_z" | "run.zipf_z" => self.zipf_z = v.as_float().ok_or_else(|| err("float"))?,
            "sources" | "topology.sources" => {
                self.sources = v.as_int().ok_or_else(|| err("int"))? as usize
            }
            "workers" | "topology.workers" => {
                self.workers = v.as_int().ok_or_else(|| err("int"))? as usize
            }
            "capacities" | "topology.capacities" => {
                let arr = v.as_array().ok_or_else(|| err("array"))?;
                let mut caps = Vec::new();
                for item in arr {
                    caps.push(item.as_float().ok_or_else(|| err("float array"))?);
                }
                if caps.is_empty() {
                    return Err(ConfigError::Type("capacities: empty".into()));
                }
                self.capacities = caps;
            }
            "key_capacity" | "fish.key_capacity" => {
                self.key_capacity = v.as_int().ok_or_else(|| err("int"))? as usize
            }
            "epoch" | "fish.epoch" => self.epoch = v.as_int().ok_or_else(|| err("int"))? as usize,
            "alpha" | "fish.alpha" => self.alpha = v.as_float().ok_or_else(|| err("float"))?,
            "theta_num" | "fish.theta_num" => {
                self.theta_num = v.as_float().ok_or_else(|| err("float"))?
            }
            "d_min" | "fish.d_min" => self.d_min = v.as_int().ok_or_else(|| err("int"))? as usize,
            "interval" | "fish.interval" => {
                self.interval = v.as_int().ok_or_else(|| err("int"))? as u64
            }
            "vnodes" | "fish.vnodes" => self.vnodes = v.as_int().ok_or_else(|| err("int"))? as usize,
            "identifier" | "fish.identifier" => {
                self.identifier = v.as_str().ok_or_else(|| err("string"))?.to_string()
            }
            "seed" | "run.seed" => self.seed = v.as_int().ok_or_else(|| err("int"))? as u64,
            "service_ns" | "topology.service_ns" => {
                self.service_ns = v.as_int().ok_or_else(|| err("int"))? as u64
            }
            "interarrival_ns" | "topology.interarrival_ns" => {
                self.interarrival_ns = v.as_int().ok_or_else(|| err("int"))? as u64
            }
            "artifacts_dir" | "run.artifacts_dir" => {
                self.artifacts_dir = v.as_str().ok_or_else(|| err("string"))?.to_string()
            }
            "batch" | "run.batch" => self.batch = v.as_int().ok_or_else(|| err("int"))? as usize,
            "rebalance_threshold" | "rebalance.threshold" => {
                self.rebalance_threshold = v.as_float().ok_or_else(|| err("float"))?
            }
            "agg_flush_ms" | "aggregate.flush_ms" => {
                self.agg_flush_ms = v.as_int().ok_or_else(|| err("int"))? as u64
            }
            "agg_shards" | "aggregate.shards" => {
                self.agg_shards = v.as_int().ok_or_else(|| err("int"))? as usize
            }
            "agg_window_ms" | "aggregate.window_ms" => {
                self.agg_window_ms = v.as_int().ok_or_else(|| err("int"))? as u64
            }
            "agg_lateness_ms" | "aggregate.lateness_ms" => {
                self.agg_lateness_ms = v.as_int().ok_or_else(|| err("int"))? as u64
            }
            "transport" | "deploy.transport" => {
                self.transport = v.as_str().ok_or_else(|| err("string"))?.to_string()
            }
            "processes" | "deploy.processes" => {
                self.processes = v.as_int().ok_or_else(|| err("int"))? as usize
            }
            other => return Err(ConfigError::UnknownKey(other.to_string())),
        }
        Ok(())
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.workers == 0 {
            return Err(ConfigError::Type("workers must be > 0".into()));
        }
        if self.sources == 0 {
            return Err(ConfigError::Type("sources must be > 0".into()));
        }
        if self.epoch == 0 {
            return Err(ConfigError::Type("epoch must be > 0".into()));
        }
        if !(0.0..=1.0).contains(&self.alpha) {
            return Err(ConfigError::Type("alpha must be in [0,1]".into()));
        }
        if self.capacities.iter().any(|&c| c <= 0.0) {
            return Err(ConfigError::Type("capacities must be positive".into()));
        }
        if self.identifier != "native" && self.identifier != "xla-cms" {
            return Err(ConfigError::Type(format!(
                "identifier must be native|xla-cms, got {}",
                self.identifier
            )));
        }
        // upper bound also catches negative CLI ints wrapped via `as usize`
        if self.batch == 0 || self.batch > (1 << 24) {
            return Err(ConfigError::Type(format!(
                "batch must be in 1..={}, got {}",
                1usize << 24,
                self.batch
            )));
        }
        if self.rebalance_threshold < 0.0 {
            return Err(ConfigError::Type("rebalance_threshold must be >= 0".into()));
        }
        // flush intervals are ms→ns multiplied; bound well below overflow
        // (also catches negative CLI ints wrapped via `as u64`)
        if self.agg_flush_ms > 3_600_000 {
            return Err(ConfigError::Type(format!(
                "agg_flush_ms must be <= 3600000 (1h), got {}",
                self.agg_flush_ms
            )));
        }
        // same ms→ns overflow bound (and negative-int wrap catch) as
        // agg_flush_ms; 0 = unwindowed is valid
        if self.agg_window_ms > 3_600_000 {
            return Err(ConfigError::Type(format!(
                "agg_window_ms must be <= 3600000 (1h), got {}",
                self.agg_window_ms
            )));
        }
        // upper bound also catches negative CLI ints wrapped via `as usize`
        if self.agg_shards == 0 || self.agg_shards > 4096 {
            return Err(ConfigError::Type(format!(
                "agg_shards must be in 1..=4096, got {}",
                self.agg_shards
            )));
        }
        // same ms→ns overflow bound (and negative-int wrap catch) as
        // agg_window_ms; 0 = strict retirement is valid
        if self.agg_lateness_ms > 3_600_000 {
            return Err(ConfigError::Type(format!(
                "agg_lateness_ms must be <= 3600000 (1h), got {}",
                self.agg_lateness_ms
            )));
        }
        if crate::transport::TransportKind::parse(&self.transport).is_none() {
            return Err(ConfigError::Type(format!(
                "transport must be loopback|uds|tcp, got {}",
                self.transport
            )));
        }
        // upper bound also catches negative CLI ints wrapped via `as usize`
        if self.processes > 256 {
            return Err(ConfigError::Type(format!(
                "processes must be <= 256, got {}",
                self.processes
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_roundtrip() {
        let text = r#"
# experiment
[run]
scheme = "fish"
workload = "zf"
tuples = 500000
zipf_z = 1.4

[topology]
workers = 64
capacities = [1.0, 2.0]

[fish]
alpha = 0.3
epoch = 2000
"#;
        let f = ConfigFile::parse(text).unwrap();
        let mut cfg = Config::default();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.workers, 64);
        assert_eq!(cfg.tuples, 500_000);
        assert_eq!(cfg.alpha, 0.3);
        assert_eq!(cfg.epoch, 2000);
        assert_eq!(cfg.capacity_vec()[..4], [1.0, 2.0, 1.0, 2.0]);
        cfg.validate().unwrap();
    }

    #[test]
    fn unknown_key_rejected() {
        let f = ConfigFile::parse("bogus = 1").unwrap();
        let mut cfg = Config::default();
        assert!(matches!(cfg.apply_file(&f), Err(ConfigError::UnknownKey(_))));
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = Config::default();
        cfg.alpha = 1.5;
        assert!(cfg.validate().is_err());
        cfg.alpha = 0.2;
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn batch_and_rebalance_threshold_configurable() {
        let f = ConfigFile::parse(
            "[run]\nbatch = 512\n[rebalance]\nthreshold = 0.35\n",
        )
        .unwrap();
        let mut cfg = Config::default();
        assert_eq!(cfg.batch, 256);
        assert!((cfg.rebalance_threshold - 0.2).abs() < 1e-12);
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.batch, 512);
        assert!((cfg.rebalance_threshold - 0.35).abs() < 1e-12);
        cfg.validate().unwrap();
        cfg.batch = 0;
        assert!(cfg.validate().is_err());
        // a negative CLI int wraps to a huge usize; validation must catch it
        cfg.batch = (-1i64) as usize;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn agg_flush_ms_configurable_and_bounded() {
        let f = ConfigFile::parse("[aggregate]\nflush_ms = 25\n").unwrap();
        let mut cfg = Config::default();
        assert_eq!(cfg.agg_flush_ms, DEFAULT_AGG_FLUSH_MS);
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.agg_flush_ms, 25);
        cfg.validate().unwrap();
        cfg.agg_flush_ms = 0; // 0 = flush only at end: valid
        cfg.validate().unwrap();
        // a negative CLI int wraps to a huge u64; validation must catch it
        cfg.agg_flush_ms = (-1i64) as u64;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn agg_window_ms_configurable_and_bounded() {
        let f = ConfigFile::parse("[aggregate]\nwindow_ms = 250\n").unwrap();
        let mut cfg = Config::default();
        assert_eq!(cfg.agg_window_ms, 0, "unwindowed by default");
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.agg_window_ms, 250);
        cfg.validate().unwrap();
        cfg.agg_window_ms = 0; // unwindowed: valid
        cfg.validate().unwrap();
        // a negative CLI int wraps to a huge u64; validation must catch it
        cfg.agg_window_ms = (-1i64) as u64;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn agg_shards_configurable_and_bounded() {
        let f = ConfigFile::parse("[aggregate]\nshards = 8\n").unwrap();
        let mut cfg = Config::default();
        assert_eq!(cfg.agg_shards, 1);
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.agg_shards, 8);
        cfg.validate().unwrap();
        cfg.agg_shards = 0;
        assert!(cfg.validate().is_err());
        // a negative CLI int wraps to a huge usize; validation must catch it
        cfg.agg_shards = (-1i64) as usize;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn agg_lateness_ms_configurable_and_bounded() {
        let f = ConfigFile::parse("[aggregate]\nlateness_ms = 5\n").unwrap();
        let mut cfg = Config::default();
        assert_eq!(cfg.agg_lateness_ms, 0, "strict retirement by default");
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.agg_lateness_ms, 5);
        cfg.validate().unwrap();
        // a negative CLI int wraps to a huge u64; validation must catch it
        cfg.agg_lateness_ms = (-1i64) as u64;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn transport_and_processes_configurable_and_validated() {
        let f = ConfigFile::parse("[deploy]\ntransport = \"tcp\"\nprocesses = 2\n").unwrap();
        let mut cfg = Config::default();
        assert_eq!(cfg.transport, "loopback");
        assert_eq!(cfg.processes, 0);
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.transport, "tcp");
        assert_eq!(cfg.processes, 2);
        cfg.validate().unwrap();
        cfg.transport = "uds".into();
        cfg.validate().unwrap();
        cfg.transport = "carrier-pigeon".into();
        assert!(cfg.validate().is_err());
        cfg.transport = "loopback".into();
        // a negative CLI int wraps to a huge usize; validation must catch it
        cfg.processes = (-1i64) as usize;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn theta_follows_paper_formula() {
        let mut cfg = Config::default();
        cfg.workers = 128;
        cfg.theta_num = 0.25;
        assert!((cfg.theta() - 0.25 / 128.0).abs() < 1e-15);
    }
}
