//! Minimal TOML-subset parser: sections, scalars, flat arrays, comments.

use std::fmt;

/// A parsed configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Flat array of values.
    Array(Vec<Value>),
}

impl Value {
    /// As string slice, if `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As integer (exact `Int` only).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// As float (`Float` or lossless `Int`).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }

    /// Parse one scalar/array token.
    pub fn parse_token(tok: &str) -> Result<Value, ConfigError> {
        let tok = tok.trim();
        if tok.starts_with('"') && tok.ends_with('"') && tok.len() >= 2 {
            return Ok(Value::Str(tok[1..tok.len() - 1].to_string()));
        }
        if tok == "true" {
            return Ok(Value::Bool(true));
        }
        if tok == "false" {
            return Ok(Value::Bool(false));
        }
        if tok.starts_with('[') && tok.ends_with(']') {
            let inner = &tok[1..tok.len() - 1];
            let mut items = Vec::new();
            if !inner.trim().is_empty() {
                for part in inner.split(',') {
                    items.push(Value::parse_token(part)?);
                }
            }
            return Ok(Value::Array(items));
        }
        if let Ok(i) = tok.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = tok.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        Err(ConfigError::Parse(format!("cannot parse value '{tok}'")))
    }
}

/// Errors from parsing or applying configuration.
#[derive(Debug)]
pub enum ConfigError {
    /// File read failure.
    Io(String),
    /// Syntax error.
    Parse(String),
    /// Type mismatch applying a value.
    Type(String),
    /// Key not recognised by [`super::Config::set`].
    UnknownKey(String),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Io(m) => write!(f, "config io error: {m}"),
            ConfigError::Parse(m) => write!(f, "config parse error: {m}"),
            ConfigError::Type(m) => write!(f, "config type error: {m}"),
            ConfigError::UnknownKey(k) => write!(f, "unknown config key: {k}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// A parsed config file: ordered (section, key, value) triples.
#[derive(Debug, Clone, Default)]
pub struct ConfigFile {
    entries: Vec<(String, String, Value)>,
}

impl ConfigFile {
    /// Parse the TOML-subset text.
    pub fn parse(text: &str) -> Result<ConfigFile, ConfigError> {
        let mut section = String::new();
        let mut entries = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                // only strip comments outside quotes (good enough: our
                // string values never contain '#')
                Some(i) if !raw[..i].contains('"') || raw[..i].matches('"').count() % 2 == 0 => {
                    &raw[..i]
                }
                _ => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| {
                ConfigError::Parse(format!("line {}: expected 'key = value'", lineno + 1))
            })?;
            let key = line[..eq].trim().to_string();
            if key.is_empty() {
                return Err(ConfigError::Parse(format!("line {}: empty key", lineno + 1)));
            }
            let value = Value::parse_token(&line[eq + 1..])?;
            entries.push((section.clone(), key, value));
        }
        Ok(ConfigFile { entries })
    }

    /// Ordered entries.
    pub fn entries(&self) -> impl Iterator<Item = (&str, &str, &Value)> {
        self.entries.iter().map(|(s, k, v)| (s.as_str(), k.as_str(), v))
    }

    /// Look up `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries
            .iter()
            .rev()
            .find(|(s, k, _)| s == section && k == key)
            .map(|(_, _, v)| v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_arrays() {
        assert_eq!(Value::parse_token("42").unwrap(), Value::Int(42));
        assert_eq!(Value::parse_token("-1").unwrap(), Value::Int(-1));
        assert_eq!(Value::parse_token("1.5").unwrap(), Value::Float(1.5));
        assert_eq!(Value::parse_token("true").unwrap(), Value::Bool(true));
        assert_eq!(
            Value::parse_token("\"abc\"").unwrap(),
            Value::Str("abc".into())
        );
        assert_eq!(
            Value::parse_token("[1, 2.5]").unwrap(),
            Value::Array(vec![Value::Int(1), Value::Float(2.5)])
        );
        assert_eq!(Value::parse_token("[]").unwrap(), Value::Array(vec![]));
        assert!(Value::parse_token("@nope").is_err());
    }

    #[test]
    fn sections_and_comments() {
        let f = ConfigFile::parse(
            "top = 1\n[a]\nx = 2 # trailing\n# whole line\n[b]\nx = \"s\"\n",
        )
        .unwrap();
        assert_eq!(f.get("", "top"), Some(&Value::Int(1)));
        assert_eq!(f.get("a", "x"), Some(&Value::Int(2)));
        assert_eq!(f.get("b", "x"), Some(&Value::Str("s".into())));
        assert_eq!(f.get("a", "missing"), None);
    }

    #[test]
    fn syntax_errors_reported() {
        assert!(ConfigFile::parse("novalue").is_err());
        assert!(ConfigFile::parse("= 3").is_err());
        assert!(ConfigFile::parse("k = @").is_err());
    }
}
