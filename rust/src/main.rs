//! `fish` — leader entrypoint / CLI.
//!
//! ```text
//! fish sim     --scheme fish --workload zf --workers 64 ...   simulator run
//! fish deploy  --scheme fish --workload mt --workers 32 ...   threaded runtime run
//! fish compare --workload zf --workers 16,32,64,128           all schemes side by side
//! fish lint    [--src rust/src] [--json]                      determinism lint suite
//! fish info                                                   artifact + platform info
//! ```
//!
//! Every flag mirrors a [`fish::config::Config`] field; `--config
//! path.toml` loads a file first, flags override.

use fish::cli::Args;
use fish::config::Config;
use fish::coordinator::{Grouper, SchemeKind};
use fish::engine::Pipeline;
use fish::report::{f2, ns, ratio, Table};

fn load_config(args: &Args) -> anyhow::Result<Config> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::default(),
    };
    args.apply_to_config(&mut cfg)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    cfg.validate()?;
    Ok(cfg)
}

/// `--trace-out FILE` / `--metrics-out FILE`: arm the span recorder /
/// sampler registry before the pipeline is built (engines consult the
/// flag when they construct their buffers). Returns the two paths.
fn obs_outputs(args: &Args) -> (Option<String>, Option<String>) {
    let trace_out = args.get("trace-out").map(str::to_string);
    let metrics_out = args.get("metrics-out").map(str::to_string);
    if trace_out.is_some() || metrics_out.is_some() {
        fish::obs::set_enabled(true);
    }
    (trace_out, metrics_out)
}

/// Write the merged Chrome-trace timeline and/or the telemetry JSONL
/// a run produced (no-ops for paths that weren't requested).
fn write_obs(
    trace_out: &Option<String>,
    metrics_out: &Option<String>,
    blobs: &[fish::obs::TraceBlob],
    samples: &[fish::obs::Sample],
) -> anyhow::Result<()> {
    if let Some(path) = trace_out {
        std::fs::write(path, fish::obs::chrome_trace_json(blobs))
            .map_err(|e| anyhow::anyhow!("--trace-out {path}: {e}"))?;
        println!("trace written to {path} ({} thread timelines)", blobs.len());
    }
    if let Some(path) = metrics_out {
        std::fs::write(path, fish::obs::sample::jsonl(samples))
            .map_err(|e| anyhow::anyhow!("--metrics-out {path}: {e}"))?;
        println!("metrics written to {path} ({} samples)", samples.len());
    }
    Ok(())
}

/// Build per-source groupers, honouring `--identifier xla-cms` for FISH.
fn build_sources(cfg: &Config) -> anyhow::Result<Vec<Box<dyn Grouper>>> {
    if cfg.scheme == SchemeKind::Fish && cfg.identifier == "xla-cms" {
        eprintln!("[fish] XLA identifier: PJRT CPU service per source (artifacts: {})", cfg.artifacts_dir);
        (0..cfg.sources)
            .map(|_| {
                fish::runtime::make_fish_xla(cfg).map(|f| Box::new(f) as Box<dyn Grouper>)
            })
            .collect()
    } else {
        Ok((0..cfg.sources).map(|s| fish::coordinator::make_scheme(cfg, s)).collect())
    }
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let (trace_out, metrics_out) = obs_outputs(args);
    let sources = build_sources(&cfg)?;
    let mut job = Pipeline::builder()
        .config(cfg.clone())
        .with_sources(sources)
        .build_sim();
    let start = std::time::Instant::now();
    let r = job.run();
    let wall = start.elapsed();

    let (mean, p50, p95, p99) = r.latency.summary();
    let mut t = Table::new(
        &format!(
            "sim: {} on {} ({} tuples, {} workers)",
            cfg.scheme, cfg.workload, r.tuples, cfg.workers
        ),
        &["metric", "value"],
    );
    t.row(&["makespan".into(), ns(r.makespan)]);
    t.row(&["latency mean".into(), ns(mean as u64)]);
    t.row(&["latency p50".into(), ns(p50)]);
    t.row(&["latency p95".into(), ns(p95)]);
    t.row(&["latency p99".into(), ns(p99)]);
    t.row(&["imbalance max/mean-1".into(), f2(r.imbalance().relative)]);
    t.row(&["state entries".into(), r.entries.to_string()]);
    t.row(&["distinct keys".into(), r.distinct_keys.to_string()]);
    t.row(&["memory vs FG".into(), ratio(r.memory_normalized)]);
    t.row(&["control entries".into(), r.control_entries.to_string()]);
    t.row(&["agg flushes".into(), r.agg.flushes.to_string()]);
    t.row(&["agg messages".into(), r.agg.messages.to_string()]);
    t.row(&["agg payload".into(), format!("{} B", r.agg.bytes)]);
    t.row(&["agg merge time (wall)".into(), ns(r.agg.merge_ns)]);
    t.row(&["agg shards".into(), r.shard_agg.n_shards().to_string()]);
    t.row(&["shard imbalance max/mean-1".into(), f2(r.shard_agg.imbalance().relative)]);
    // sim flush latency is *virtual* delta staleness, not wall transit;
    // the unit tag comes from the histogram itself (satellite: no more
    // hardcoded clock-domain labels)
    t.row(&[
        format!("agg staleness p99 ({})", r.agg_latency.unit_label()),
        ns(r.agg_latency.quantile(0.99)),
    ]);
    if cfg.agg_window_ms > 0 {
        t.row(&["agg window".into(), format!("{} ms", cfg.agg_window_ms)]);
        t.row(&["windows retired".into(), r.windows.len().to_string()]);
        t.row(&["pane retirements (pane-shard)".into(), r.window_stats.panes_retired.to_string()]);
        t.row(&["late pane reopens".into(), r.window_stats.late_reopens.to_string()]);
        t.row(&["late reopen mass (tuples)".into(), r.window_stats.late_reopen_mass.to_string()]);
        t.row(&["peak open panes/shard".into(), r.window_stats.max_open_panes.to_string()]);
        t.row(&["peak open-pane entries".into(), r.window_stats.max_open_entries.to_string()]);
    }
    // per-epoch telemetry (only sampled when --metrics-out/--trace-out
    // armed the registry): sparkline-style min/avg/max per series
    for (name, row) in fish::obs::sample::summary_rows(&r.samples) {
        t.row(&[name, row]);
    }
    t.row(&["wall time".into(), format!("{wall:.2?}")]);
    t.print();
    write_obs(&trace_out, &metrics_out, &r.trace_blobs, &r.samples)?;
    let top = r.top_k(5);
    if !top.is_empty() {
        let mut tt = Table::new("hottest keys (exact merged counts, all time)", &["key", "count"]);
        for (k, c) in top {
            tt.row(&[k.to_string(), c.to_string()]);
        }
        tt.print();
    }
    if let Some(last) = r.windows.last() {
        let mut tt = Table::new(
            &format!("trending keys (last {} ms window, exact)", cfg.agg_window_ms),
            &["key", "count"],
        );
        for (k, c) in last.top_k(5) {
            tt.row(&[k.to_string(), c.to_string()]);
        }
        tt.print();
    }
    Ok(())
}

fn cmd_deploy(args: &Args) -> anyhow::Result<()> {
    let mut cfg = load_config(args)?;
    // --processes N: N worker processes (plus one per merge shard)
    if cfg.processes > 0 {
        cfg.workers = cfg.processes;
    }
    let (trace_out, metrics_out) = obs_outputs(args);
    let sources = build_sources(&cfg)?;
    let job = Pipeline::builder()
        .config(cfg.clone())
        .with_sources(sources)
        .build_rt();
    let n_tuples = job.trace().len();
    let trace = std::sync::Arc::clone(job.trace());
    // --chaos kill-worker:<n|mid>,kill-shard:<ms|mid>: scripted mid-run
    // kills; `mid` anchors to the paced stream duration
    let chaos = match args.get("chaos") {
        Some(spec) => {
            let stream_ns = n_tuples as u64 * cfg.interarrival_ns;
            fish::transport::launch::ChaosPlan::parse(spec, stream_ns)
                .map_err(|e| anyhow::anyhow!("--chaos: {e}"))?
        }
        None => fish::transport::launch::ChaosPlan::default(),
    };
    if chaos.armed() && cfg.processes == 0 {
        anyhow::bail!("--chaos requires --processes N (kills are real child processes)");
    }
    if chaos.kill_shard_after_ns.is_some() && cfg.agg_window_ms == 0 {
        anyhow::bail!(
            "--chaos kill-shard requires --agg_window_ms > 0 (windowed flushes reach every \
             shard each round, so the respawned victim is guaranteed reconnections)"
        );
    }
    let r = if cfg.processes > 0 {
        job.run_multiprocess_chaos(&chaos)?
    } else {
        job.try_run().map_err(|e| anyhow::anyhow!("deploy failed: {e}"))?
    };
    let (mean, p50, p95, p99) = r.latency.summary();
    let mut t = Table::new(
        &format!(
            "deploy: {} on {} ({} tuples, {} sources, {} workers)",
            cfg.scheme, cfg.workload, n_tuples, cfg.sources, cfg.workers
        ),
        &["metric", "value"],
    );
    let topology = if cfg.processes > 0 {
        format!(
            "{} ({} worker + {} shard processes)",
            fish::transport::launch::process_kind(
                fish::transport::TransportKind::parse(&cfg.transport).unwrap_or_default()
            ),
            cfg.workers,
            cfg.agg_shards
        )
    } else {
        format!("{} (threads)", cfg.transport)
    };
    t.row(&["transport".into(), topology]);
    t.row(&["throughput".into(), format!("{:.0} tuples/s", r.throughput)]);
    t.row(&["latency mean".into(), ns(mean as u64)]);
    t.row(&["latency p50".into(), ns(p50)]);
    t.row(&["latency p95".into(), ns(p95)]);
    t.row(&["latency p99".into(), ns(p99)]);
    t.row(&["state entries".into(), r.entries.to_string()]);
    t.row(&["memory vs FG".into(), ratio(r.memory_normalized())]);
    t.row(&["agg flushes".into(), r.agg.flushes.to_string()]);
    t.row(&["agg msgs/sec".into(), format!("{:.0}", r.agg.messages_per_sec(r.wall_ns))]);
    t.row(&["agg payload".into(), format!("{} B", r.agg.bytes)]);
    t.row(&["agg shards".into(), r.shard_agg.n_shards().to_string()]);
    t.row(&["shard imbalance max/mean-1".into(), f2(r.shard_agg.imbalance().relative)]);
    // rt flush latency is wall-clock flush→merge transit per shard
    // batch; the unit tag comes from the histogram itself
    t.row(&[
        format!("agg flush p99 ({})", r.agg_latency.unit_label()),
        ns(r.agg_latency.quantile(0.99)),
    ]);
    if r.wire.any() {
        // socket / multi-process lanes: what the wire actually carried
        t.row(&["wire frames out/in".into(), format!("{}/{}", r.wire.frames_out, r.wire.frames_in)]);
        t.row(&["wire bytes out/in".into(), format!("{}/{} B", r.wire.bytes_out, r.wire.bytes_in)]);
        t.row(&[
            "wire throughput".into(),
            format!("{:.1} MB/s", r.wire.bytes_per_sec(r.wall_ns) / 1e6),
        ]);
        t.row(&["serialize".into(), format!("{:.0} ns/tuple", r.wire.encode_ns_per_tuple())]);
        t.row(&["deserialize".into(), format!("{:.0} ns/tuple", r.wire.decode_ns_per_tuple())]);
    }
    if cfg.agg_window_ms > 0 {
        t.row(&["agg window".into(), format!("{} ms", cfg.agg_window_ms)]);
        if cfg.agg_lateness_ms > 0 {
            t.row(&["agg lateness slack".into(), format!("{} ms", cfg.agg_lateness_ms)]);
        }
        t.row(&["windows retired".into(), r.windows.len().to_string()]);
        t.row(&["pane retirements (pane-shard)".into(), r.window_stats.panes_retired.to_string()]);
        t.row(&["late pane reopens".into(), r.window_stats.late_reopens.to_string()]);
        t.row(&["late reopen mass (tuples)".into(), r.window_stats.late_reopen_mass.to_string()]);
        t.row(&["peak open panes/shard".into(), r.window_stats.max_open_panes.to_string()]);
        t.row(&["peak open-pane entries".into(), r.window_stats.max_open_entries.to_string()]);
    }
    if r.recovery.any() {
        // exactly-once recovery activity (docs/RECOVERY.md): all zeros
        // on a fault-free run, so these rows only appear under chaos
        t.row(&["restarts worker/shard".into(), format!(
            "{}/{}",
            r.recovery.worker_restarts, r.recovery.shard_restarts
        )]);
        t.row(&["recovery wall".into(), ns(r.recovery.recovery_wall_ns)]);
        t.row(&["replayed flush batches".into(), r.recovery.replayed_batches.to_string()]);
        t.row(&["deduped flush batches".into(), r.recovery.deduped_batches.to_string()]);
        t.row(&["replayed tuples".into(), r.recovery.replayed_tuples.to_string()]);
        t.row(&["replay ratio".into(), f2(r.recovery.replay_ratio(r.agg.flushes))]);
        t.row(&["snapshots (bytes)".into(), format!(
            "{} ({} B)",
            r.recovery.snapshots, r.recovery.snapshot_bytes
        )]);
        t.row(&["snapshot restores".into(), r.recovery.restores.to_string()]);
    }
    // per-epoch telemetry (only sampled when --metrics-out/--trace-out
    // armed the registry): sparkline-style min/avg/max per series
    for (name, row) in fish::obs::sample::summary_rows(&r.samples) {
        t.row(&[name, row]);
    }
    t.row(&["wall time".into(), ns(r.wall_ns)]);
    t.print();
    write_obs(&trace_out, &metrics_out, &r.trace_blobs, &r.samples)?;

    // --recovery-json PATH: machine-readable recovery metrics (the CI
    // chaos lane uploads this and gates on it via scripts/check_perf.py)
    if let Some(path) = args.get("recovery-json") {
        let rec = &r.recovery;
        let json = format!(
            "{{\n  \"wall_ns\": {},\n  \"worker_restarts\": {},\n  \"shard_restarts\": {},\n  \
             \"recovery_wall_ns\": {},\n  \"replayed_batches\": {},\n  \"deduped_batches\": {},\n  \
             \"buffered_batches\": {},\n  \"replayed_tuples\": {},\n  \"snapshots\": {},\n  \
             \"snapshot_bytes\": {},\n  \"restores\": {},\n  \"absorbed_flushes\": {},\n  \
             \"replay_ratio\": {:.6}\n}}\n",
            r.wall_ns,
            rec.worker_restarts,
            rec.shard_restarts,
            rec.recovery_wall_ns,
            rec.replayed_batches,
            rec.deduped_batches,
            rec.buffered_batches,
            rec.replayed_tuples,
            rec.snapshots,
            rec.snapshot_bytes,
            rec.restores,
            r.agg.flushes,
            rec.replay_ratio(r.agg.flushes),
        );
        std::fs::write(path, json)
            .map_err(|e| anyhow::anyhow!("--recovery-json {path}: {e}"))?;
        println!("recovery metrics written to {path}");
    }

    // --verify: re-run the same trace through the in-process loopback
    // engine and insist every transport-invariant output matches
    if args.has("verify") {
        let mut ref_cfg = cfg.clone();
        ref_cfg.processes = 0;
        ref_cfg.transport = "loopback".into();
        let reference = Pipeline::builder()
            .config(ref_cfg.clone())
            .with_sources(build_sources(&ref_cfg)?)
            .trace(trace)
            .build_rt()
            .run();
        fish::transport::launch::verify_against_reference(&r, &reference)
            .map_err(|e| anyhow::anyhow!("verify failed: {e}"))?;
        println!(
            "verify: OK — merged counts, windows and top-k match the in-process reference"
        );
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let base = load_config(args)?;
    let worker_counts: Vec<usize> = args
        .get_list("worker-counts", &[16usize, 32, 64, 128])
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    // two-stage cost columns: aggregation traffic (msgs the merge fabric
    // absorbed), merged-count staleness (virtual flush p99 — how far the
    // merged view trails the workers), shard imbalance across the
    // --agg_shards merge shards, and — when --agg_window_ms > 0 — how
    // many windows the run retired ("-" when unwindowed)
    let mut t = Table::new(
        &format!(
            "compare on {} ({} tuples, {} agg shards, window {} ms)",
            base.workload, base.tuples, base.agg_shards, base.agg_window_ms
        ),
        &[
            "workers",
            "scheme",
            "exec (vs SG)",
            "p99",
            "mem (vs FG)",
            "agg msgs",
            "flush p99 (virt)",
            "shard imb",
            "windows",
        ],
    );
    for &w in &worker_counts {
        let mut sg_makespan = 0u64;
        for kind in SchemeKind::all() {
            let mut cfg = base.clone();
            cfg.scheme = kind;
            cfg.workers = w;
            cfg.interarrival_ns = (cfg.service_ns / w as u64).max(1);
            let r = Pipeline::builder().config(cfg).build_sim().run();
            if kind == SchemeKind::Shuffle {
                sg_makespan = r.makespan;
            }
            let exec = if sg_makespan > 0 {
                ratio(r.makespan as f64 / sg_makespan as f64)
            } else {
                "-".into()
            };
            let windows = if base.agg_window_ms > 0 {
                r.windows.len().to_string()
            } else {
                "-".into()
            };
            t.row(&[
                w.to_string(),
                kind.name().into(),
                exec,
                ns(r.latency.quantile(0.99)),
                ratio(r.memory_normalized),
                r.agg.messages.to_string(),
                ns(r.agg_latency.quantile(0.99)),
                f2(r.shard_agg.imbalance().relative),
                windows,
            ]);
        }
    }
    t.print();
    Ok(())
}

fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    let src = args.get("src").unwrap_or("rust/src");
    let report = fish::analysis::lint_tree(std::path::Path::new(src))
        .map_err(|e| anyhow::anyhow!("lint: cannot walk {src}: {e}"))?;
    if args.has("json") {
        println!("{}", report.to_json());
    } else {
        for f in &report.findings {
            println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
            println!("    {}", f.snippet);
        }
        println!(
            "fish lint: {} finding(s), {} file(s) scanned, {} documented suppression(s)",
            report.findings.len(),
            report.files_scanned,
            report.suppressions
        );
    }
    if !report.findings.is_empty() {
        std::process::exit(1);
    }
    Ok(())
}

/// One `fish model` run: a protocol config checked exhaustively,
/// either honest (must be clean; counts are pinned in the tier-1
/// tests) or seeded with a mutation (must produce a counterexample).
struct ModelRun {
    protocol: &'static str,
    config: String,
    mutation: Option<&'static str>,
    ok: bool,
    states: u64,
    transitions: u64,
    depth: u64,
    finals: u64,
    violation: Option<String>,
    trace_len: usize,
}

fn model_run(
    protocol: &'static str,
    config: String,
    mutation: Option<&'static str>,
    res: Result<fish::analysis::ModelStats, fish::analysis::Counterexample>,
) -> ModelRun {
    match res {
        Ok(stats) => ModelRun {
            protocol,
            config,
            mutation,
            // an honest run must be clean; a mutated run that scans
            // clean means the checker missed the seeded bug
            ok: mutation.is_none(),
            states: stats.states,
            transitions: stats.transitions,
            depth: stats.depth,
            finals: stats.finals,
            violation: None,
            trace_len: 0,
        },
        Err(cx) => ModelRun {
            protocol,
            config,
            mutation,
            ok: mutation.is_some(),
            states: 0,
            transitions: 0,
            depth: 0,
            finals: 0,
            violation: Some(cx.violation.to_string()),
            trace_len: cx.trace.len(),
        },
    }
}

fn cmd_model(args: &Args) -> anyhow::Result<()> {
    use fish::analysis::{
        check_credit, check_recovery, CheckOptions, CreditConfig, CreditMutation,
        RecoveryConfig, RecoveryMutation,
    };

    let which = args.get("protocol").unwrap_or("all");
    if !matches!(which, "all" | "credit" | "recovery") {
        anyhow::bail!("model: unknown --protocol {which} (expected credit or recovery)");
    }
    let with_mutations = args.has("all");
    let opts = CheckOptions::default();
    let started = std::time::Instant::now();
    let mut runs: Vec<ModelRun> = Vec::new();

    // Honest sweeps. Exact state/transition counts for every config
    // here are pinned in rust/tests/credit_model.rs and
    // rust/tests/recovery_model.rs — this command re-proves them and
    // CI gates on the totals (scripts/check_perf.py --model).
    const CREDIT: &[(usize, u32, u32, u32)] = &[
        (1, 2, 6, 1),
        (1, 4, 8, 2),
        (1, 5, 10, 5),
        (2, 2, 3, 1),
        (2, 3, 4, 2),
        (2, 4, 4, 2),
        (3, 2, 3, 1),
        (3, 2, 4, 1),
    ];
    const RECOVERY: &[(usize, usize, u64, u64, u32, u32)] = &[
        (2, 2, 2, 1, 1, 1),
        (2, 2, 3, 2, 1, 1),
        (2, 2, 3, 3, 1, 1),
        (3, 2, 2, 2, 1, 0),
    ];

    if which != "recovery" {
        for &(n, w, t, c) in CREDIT {
            let cfg = CreditConfig {
                n_senders: n,
                window: w,
                tuples_per_sender: t,
                chunk: c,
                mutation: CreditMutation::None,
            };
            runs.push(model_run(
                "credit",
                format!("n{n} w{w} t{t} c{c}"),
                None,
                check_credit(&cfg, &opts),
            ));
        }
        if with_mutations {
            let seeded: &[(&'static str, CreditMutation, (usize, u32, u32, u32))] = &[
                ("skip-credit-flush", CreditMutation::SkipCreditFlush, (1, 5, 10, 5)),
                ("double-grant", CreditMutation::DoubleGrant, (1, 4, 8, 2)),
                ("drop-credit", CreditMutation::DropCredit, (1, 4, 8, 2)),
                ("reorder-data", CreditMutation::ReorderData, (1, 4, 8, 2)),
            ];
            for &(name, mutation, (n, w, t, c)) in seeded {
                let cfg = CreditConfig {
                    n_senders: n,
                    window: w,
                    tuples_per_sender: t,
                    chunk: c,
                    mutation,
                };
                runs.push(model_run(
                    "credit",
                    format!("n{n} w{w} t{t} c{c}"),
                    Some(name),
                    check_credit(&cfg, &opts),
                ));
            }
        }
    }
    if which != "credit" {
        for &(w, s, t, k, wk, sk) in RECOVERY {
            let cfg = RecoveryConfig {
                n_workers: w,
                n_shards: s,
                tuples_per_worker: t,
                snapshot_every: k,
                worker_kills: wk,
                shard_kills: sk,
                mutation: RecoveryMutation::None,
            };
            runs.push(model_run(
                "recovery",
                format!("w{w} s{s} t{t} k{k} wk{wk} sk{sk}"),
                None,
                check_recovery(&cfg, &opts),
            ));
        }
        if with_mutations {
            let seeded: &[(&'static str, RecoveryMutation, (usize, usize, u64, u64, u32, u32))] = &[
                ("skip-snapshot-fsync", RecoveryMutation::SkipSnapshotFsync, (2, 2, 2, 1, 1, 1)),
                ("resume-off-by-one", RecoveryMutation::ResumeOffByOne, (2, 2, 2, 1, 1, 1)),
                (
                    "replay-from-wrong-cursor",
                    RecoveryMutation::ReplayFromWrongCursor,
                    (2, 2, 2, 1, 1, 1),
                ),
                (
                    "dedup-window-truncation",
                    RecoveryMutation::DedupWindowTruncation,
                    (2, 2, 3, 1, 1, 1),
                ),
            ];
            for &(name, mutation, (w, s, t, k, wk, sk)) in seeded {
                let cfg = RecoveryConfig {
                    n_workers: w,
                    n_shards: s,
                    tuples_per_worker: t,
                    snapshot_every: k,
                    worker_kills: wk,
                    shard_kills: sk,
                    mutation,
                };
                runs.push(model_run(
                    "recovery",
                    format!("w{w} s{s} t{t} k{k} wk{wk} sk{sk}"),
                    Some(name),
                    check_recovery(&cfg, &opts),
                ));
            }
        }
    }

    let wall_ms = started.elapsed().as_millis() as u64;
    let ok = runs.iter().all(|r| r.ok);
    // totals cover the honest sweeps only — mutation runs stop at
    // their counterexample, so their partial counts are not meaningful
    let total_states: u64 = runs.iter().filter(|r| r.mutation.is_none()).map(|r| r.states).sum();
    let total_transitions: u64 =
        runs.iter().filter(|r| r.mutation.is_none()).map(|r| r.transitions).sum();

    if args.has("json") {
        fn jesc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
        }
        let mut out = String::from("{\"runs\":[");
        for (i, r) in runs.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let mutation = match r.mutation {
                Some(m) => format!("\"{}\"", jesc(m)),
                None => "null".to_string(),
            };
            let violation = match &r.violation {
                Some(v) => format!("\"{}\"", jesc(v)),
                None => "null".to_string(),
            };
            out.push_str(&format!(
                "{{\"protocol\":\"{}\",\"config\":\"{}\",\"mutation\":{},\"ok\":{},\
                 \"states\":{},\"transitions\":{},\"depth\":{},\"finals\":{},\
                 \"violation\":{},\"trace_len\":{}}}",
                r.protocol,
                jesc(&r.config),
                mutation,
                r.ok,
                r.states,
                r.transitions,
                r.depth,
                r.finals,
                violation,
                r.trace_len
            ));
        }
        out.push_str(&format!(
            "],\"total_states\":{total_states},\"total_transitions\":{total_transitions},\
             \"wall_ms\":{wall_ms},\"ok\":{ok}}}"
        ));
        println!("{out}");
    } else {
        for r in &runs {
            match (r.mutation, &r.violation) {
                (None, None) => println!(
                    "model {:<8} {:<22} ok: {} states, {} transitions, depth {}, {} finals",
                    r.protocol, r.config, r.states, r.transitions, r.depth, r.finals
                ),
                (None, Some(v)) => println!(
                    "model {:<8} {:<22} VIOLATION: {} ({} steps)",
                    r.protocol, r.config, v, r.trace_len
                ),
                (Some(m), Some(v)) => println!(
                    "model {:<8} {:<22} [{m}] counterexample as expected: {} ({} steps)",
                    r.protocol, r.config, v, r.trace_len
                ),
                (Some(m), None) => println!(
                    "model {:<8} {:<22} [{m}] MISSED: mutated protocol scanned clean",
                    r.protocol, r.config
                ),
            }
        }
        println!(
            "fish model: {} run(s), {} honest states, {} honest transitions, {} ms{}",
            runs.len(),
            total_states,
            total_transitions,
            wall_ms,
            if ok { "" } else { " — FAILED" }
        );
    }
    if !ok {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    println!("fish {} — FISH grouping for time-evolving streams", env!("CARGO_PKG_VERSION"));
    match fish::runtime::Runtime::new(&cfg.artifacts_dir) {
        Ok(rt) => {
            println!("PJRT platform : {}", rt.platform());
            for v in rt.variants() {
                println!(
                    "artifact      : {} (N={}, C={}, sketch {}x{})",
                    v.name, v.n, v.c, v.depth, v.width
                );
            }
        }
        Err(e) => println!("artifacts     : unavailable ({e}) — run `make artifacts`"),
    }
    println!("schemes       : sg fg pkg dc wc fish");
    println!("workloads     : zf (synthetic Zipf), mt (MemeTracker-like), am (AmazonMovie-like)");
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: fish <sim|deploy|compare|lint|model|info> [--config file.toml] [--scheme S] \
         [--workload zf|mt|am] [--tuples N] [--workers N] [--zipf_z Z] [--batch N] \
         [--agg_flush_ms N] [--agg_shards N] [--agg_window_ms N] [--agg_lateness_ms N] \
         [--transport loopback|uds|tcp] [--rebalance_threshold F] \
         [--identifier native|xla-cms] [--seed N] ...\n       \
         sim and deploy take [--trace-out FILE] (merged Chrome-trace timeline — open \
         in Perfetto) and [--metrics-out FILE] (per-epoch telemetry JSONL; also adds \
         min/avg/max rows to the report) — see docs/OBSERVABILITY.md\n       \
         deploy also takes [--processes N] (N worker processes + one per merge \
         shard), [--verify] (check against the in-process reference), \
         [--chaos kill-worker:<n|mid>,kill-shard:<ms|mid>] (scripted mid-run kills; \
         recovery must still verify exactly) and [--recovery-json PATH]\n       \
         lint takes [--src DIR] (default rust/src) and [--json]; exits 1 on findings\n       \
         model takes [--all] (also run the seeded-mutation suite), [--json] and \
         [--protocol credit|recovery]; exhaustively checks the flow-control and \
         exactly-once recovery protocols (docs/MODEL.md); exits 1 on any violation"
    );
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    // hidden child-process entry points for `deploy --processes N`
    let raw: Vec<String> = std::env::args().skip(1).collect();
    match raw.first().map(|s| s.as_str()) {
        Some("__worker") => return fish::transport::launch::worker_child(&raw[1..]).map_err(Into::into),
        Some("__shard") => return fish::transport::launch::shard_child(&raw[1..]).map_err(Into::into),
        _ => {}
    }
    let args = Args::parse(raw, true).unwrap_or_else(|e| {
        eprintln!("{e}");
        usage()
    });
    match args.command.as_deref() {
        Some("sim") => cmd_sim(&args),
        Some("deploy") => cmd_deploy(&args),
        Some("compare") => cmd_compare(&args),
        Some("lint") => cmd_lint(&args),
        Some("model") => cmd_model(&args),
        Some("info") => cmd_info(&args),
        _ => usage(),
    }
}
