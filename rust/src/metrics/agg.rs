//! Aggregation-cost accounting — what the two-phase topology spends.
//!
//! Key splitting buys load balance at the price of downstream
//! aggregation traffic (the PKG paper's explicit trade-off). This
//! ledger makes that price visible next to the load and memory
//! metrics: flush batches and `(key, partial)` entries shipped from
//! workers to the merge stage, payload bytes on the wire, and the wall
//! time the aggregator spent merging.

/// Cost ledger for one run's aggregation stage.
///
/// Deliberately *not* `PartialEq`: `merge_ns`/`max_merge_ns` are wall
/// clock even in the virtual-time simulator, so whole-struct equality
/// would be nondeterministic across same-seed runs. Compare the
/// deterministic fields (`flushes`, `messages`, `bytes`) individually.
#[derive(Debug, Clone, Copy, Default)]
pub struct AggStats {
    /// Flush batches absorbed by the merge stage.
    pub flushes: u64,
    /// `(key, partial)` entries shipped downstream (aggregation
    /// messages — the traffic charged against key splitting).
    pub messages: u64,
    /// Payload bytes shipped downstream.
    pub bytes: u64,
    /// Total wall time spent merging (ns).
    pub merge_ns: u64,
    /// Worst single merge (ns).
    pub max_merge_ns: u64,
}

impl AggStats {
    /// Record one absorbed flush batch.
    pub fn record_merge(&mut self, entries: usize, payload_bytes: usize, ns: u64) {
        self.flushes += 1;
        self.messages += entries as u64;
        self.bytes += payload_bytes as u64;
        self.merge_ns += ns;
        self.max_merge_ns = self.max_merge_ns.max(ns);
    }

    /// Aggregation messages per second over a run of `wall_ns`.
    pub fn messages_per_sec(&self, wall_ns: u64) -> f64 {
        if wall_ns == 0 {
            0.0
        } else {
            self.messages as f64 / (wall_ns as f64 / 1e9)
        }
    }

    /// Mean merge time per flush batch (ns), 0 when nothing flushed.
    pub fn mean_merge_ns(&self) -> f64 {
        if self.flushes == 0 {
            0.0
        } else {
            self.merge_ns as f64 / self.flushes as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates() {
        let mut s = AggStats::default();
        s.record_merge(10, 160, 500);
        s.record_merge(2, 32, 1_500);
        assert_eq!(s.flushes, 2);
        assert_eq!(s.messages, 12);
        assert_eq!(s.bytes, 192);
        assert_eq!(s.merge_ns, 2_000);
        assert_eq!(s.max_merge_ns, 1_500);
        assert!((s.mean_merge_ns() - 1_000.0).abs() < 1e-9);
    }

    #[test]
    fn rates_handle_degenerate_inputs() {
        let s = AggStats::default();
        assert_eq!(s.messages_per_sec(0), 0.0);
        assert_eq!(s.mean_merge_ns(), 0.0);
        let mut s = AggStats::default();
        s.record_merge(100, 1_600, 10);
        assert!((s.messages_per_sec(1_000_000_000) - 100.0).abs() < 1e-9);
    }
}
