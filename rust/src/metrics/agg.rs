//! Aggregation-cost accounting — what the two-phase topology spends.
//!
//! Key splitting buys load balance at the price of downstream
//! aggregation traffic (the PKG paper's explicit trade-off). This
//! ledger makes that price visible next to the load and memory
//! metrics: flush batches and `(key, partial)` entries shipped from
//! workers to the merge stage, payload bytes on the wire, and the wall
//! time the aggregator spent merging.
//!
//! With the merge stage sharded (`--agg_shards`,
//! [`crate::aggregate::ShardedMerge`]), each shard keeps its own
//! [`AggStats`]; [`ShardAggStats`] holds the per-shard ledgers plus the
//! shard-imbalance summary (max/mean absorbed tuples) that tells you
//! whether the aggregation stage itself is skewed.
//!
//! **Units.** `merge_ns`/`max_merge_ns` are *wall-clock* nanoseconds in
//! **both** engines (the simulator really spends that time merging,
//! virtual time just doesn't advance for it). Flush-*latency*
//! histograms are engine-specific and live on the results, not here:
//! `SimResult::agg_latency` is **virtual** ns (delta staleness at each
//! flush), `RtResult::agg_latency` is **wall** ns (flush→merge
//! transit); the report tables label each accordingly.

use super::imbalance::Imbalance;

/// Cost ledger for one run's aggregation stage.
///
/// Deliberately *not* `PartialEq`: `merge_ns`/`max_merge_ns` are wall
/// clock even in the virtual-time simulator, so whole-struct equality
/// would be nondeterministic across same-seed runs. Compare the
/// deterministic fields (`flushes`, `messages`, `bytes`) individually.
#[derive(Debug, Clone, Copy, Default)]
pub struct AggStats {
    /// Flush batches absorbed by the merge stage.
    pub flushes: u64,
    /// `(key, partial)` entries shipped downstream (aggregation
    /// messages — the traffic charged against key splitting).
    pub messages: u64,
    /// Payload bytes shipped downstream.
    pub bytes: u64,
    /// Total wall time spent merging (ns).
    pub merge_ns: u64,
    /// Worst single merge (ns).
    pub max_merge_ns: u64,
}

impl AggStats {
    /// Record one absorbed flush batch.
    pub fn record_merge(&mut self, entries: usize, payload_bytes: usize, ns: u64) {
        self.flushes += 1;
        self.messages += entries as u64;
        self.bytes += payload_bytes as u64;
        self.merge_ns += ns;
        self.max_merge_ns = self.max_merge_ns.max(ns);
    }

    /// Aggregation messages per second over a run of `wall_ns`.
    pub fn messages_per_sec(&self, wall_ns: u64) -> f64 {
        if wall_ns == 0 {
            0.0
        } else {
            self.messages as f64 / (wall_ns as f64 / 1e9)
        }
    }

    /// Mean merge time per flush batch (ns), 0 when nothing flushed.
    pub fn mean_merge_ns(&self) -> f64 {
        if self.flushes == 0 {
            0.0
        } else {
            self.merge_ns as f64 / self.flushes as f64
        }
    }

    /// Fold another ledger into this one (shard totals, engine joins).
    pub fn absorb(&mut self, other: &AggStats) {
        self.flushes += other.flushes;
        self.messages += other.messages;
        self.bytes += other.bytes;
        self.merge_ns += other.merge_ns;
        self.max_merge_ns = self.max_merge_ns.max(other.max_merge_ns);
    }
}

/// Windowed-aggregation ledger: pane lifecycle counts and open-pane
/// memory for one [`crate::aggregate::WindowedMerge`] shard (fold
/// across shards with [`WindowStats::absorb`]).
///
/// Granularity is **pane × shard**: a window pane that received deltas
/// on 3 merge shards opens (and later retires) 3 pane-shards, exactly
/// like `AggStats::flushes` counts per-shard sub-batches. The engine
/// results expose the fabric-wide distinct-pane view separately (the
/// assembled `windows` list).
#[derive(Debug, Clone, Copy, Default)]
pub struct WindowStats {
    /// Pane-shards opened (first delta for a window on a shard).
    pub panes_opened: u64,
    /// Pane-shards retired (finalized results flushed downstream),
    /// including the end-of-stream drain.
    pub panes_retired: u64,
    /// Deltas that arrived for an already-retired pane and reopened it
    /// (possible only under the runtime engine's heuristic watermarks;
    /// reopened panes re-finalize and merge exactly).
    pub late_reopens: u64,
    /// Accumulator mass re-merged through late reopens — for `Count`,
    /// the number of tuples whose counts landed after retirement. A
    /// pane that reopens once for a 1 000-tuple delta costs far more
    /// re-merge work than one reopening for a single straggler;
    /// `late_reopens` alone cannot tell them apart.
    pub late_reopen_mass: u64,
    /// Peak panes open at once on any single shard.
    pub max_open_panes: u64,
    /// Peak `(key, acc)` entries held in open panes — the windowed
    /// stage's working-set memory (summed across shards by `absorb`).
    pub max_open_entries: u64,
}

impl WindowStats {
    /// Fold another shard's ledger into this one: event counts and
    /// memory peaks sum (per-shard peaks add up to a fabric-wide memory
    /// bound); `max_open_panes` takes the max (pane ids are shared
    /// across shards, so summing would multiply-count the same pane).
    pub fn absorb(&mut self, other: &WindowStats) {
        self.panes_opened += other.panes_opened;
        self.panes_retired += other.panes_retired;
        self.late_reopens += other.late_reopens;
        self.late_reopen_mass += other.late_reopen_mass;
        self.max_open_panes = self.max_open_panes.max(other.max_open_panes);
        self.max_open_entries += other.max_open_entries;
    }
}

/// Per-shard cost ledgers for a sharded merge fabric, indexed by shard
/// id — the observable that turns "is stage two itself skewed?" from a
/// guess into a metric.
#[derive(Debug, Clone, Default)]
pub struct ShardAggStats {
    /// One ledger per merge shard.
    pub per_shard: Vec<AggStats>,
}

impl ShardAggStats {
    /// Ledger for a single-shard (unsharded) fabric.
    pub fn single(stats: AggStats) -> Self {
        ShardAggStats { per_shard: vec![stats] }
    }

    /// Number of shards accounted for.
    pub fn n_shards(&self) -> usize {
        self.per_shard.len()
    }

    /// Whole-fabric totals (sum of every shard's ledger; worst single
    /// merge is the max across shards).
    pub fn total(&self) -> AggStats {
        let mut out = AggStats::default();
        for s in &self.per_shard {
            out.absorb(s);
        }
        out
    }

    /// Shard-load imbalance over absorbed tuples (`messages` per
    /// shard): `relative` is the max/mean − 1 figure the report tables
    /// print. 0 for a single shard by construction.
    pub fn imbalance(&self) -> Imbalance {
        let msgs: Vec<u64> = self.per_shard.iter().map(|s| s.messages).collect();
        Imbalance::of_counts(&msgs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates() {
        let mut s = AggStats::default();
        s.record_merge(10, 160, 500);
        s.record_merge(2, 32, 1_500);
        assert_eq!(s.flushes, 2);
        assert_eq!(s.messages, 12);
        assert_eq!(s.bytes, 192);
        assert_eq!(s.merge_ns, 2_000);
        assert_eq!(s.max_merge_ns, 1_500);
        assert!((s.mean_merge_ns() - 1_000.0).abs() < 1e-9);
    }

    #[test]
    fn rates_handle_degenerate_inputs() {
        let s = AggStats::default();
        assert_eq!(s.messages_per_sec(0), 0.0);
        assert_eq!(s.mean_merge_ns(), 0.0);
        let mut s = AggStats::default();
        s.record_merge(100, 1_600, 10);
        assert!((s.messages_per_sec(1_000_000_000) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn shard_stats_total_sums_and_maxes() {
        let mut a = AggStats::default();
        a.record_merge(10, 160, 500);
        let mut b = AggStats::default();
        b.record_merge(30, 480, 2_000);
        b.record_merge(20, 320, 100);
        let stats = ShardAggStats { per_shard: vec![a, b] };
        assert_eq!(stats.n_shards(), 2);
        let t = stats.total();
        assert_eq!(t.flushes, 3);
        assert_eq!(t.messages, 60);
        assert_eq!(t.bytes, 960);
        assert_eq!(t.merge_ns, 2_600);
        assert_eq!(t.max_merge_ns, 2_000);
    }

    #[test]
    fn window_stats_fold_sums_events_and_memory_but_maxes_panes() {
        let a = WindowStats {
            panes_opened: 4,
            panes_retired: 3,
            late_reopens: 1,
            late_reopen_mass: 40,
            max_open_panes: 2,
            max_open_entries: 100,
        };
        let b = WindowStats {
            panes_opened: 6,
            panes_retired: 6,
            late_reopens: 0,
            late_reopen_mass: 0,
            max_open_panes: 3,
            max_open_entries: 250,
        };
        let mut folded = a;
        folded.absorb(&b);
        assert_eq!(folded.panes_opened, 10);
        assert_eq!(folded.panes_retired, 9);
        assert_eq!(folded.late_reopens, 1);
        assert_eq!(folded.late_reopen_mass, 40);
        assert_eq!(folded.max_open_panes, 3);
        assert_eq!(folded.max_open_entries, 350);
    }

    #[test]
    fn shard_imbalance_reflects_absorbed_tuples() {
        let mut hot = AggStats::default();
        hot.record_merge(90, 1_440, 1);
        let mut cold = AggStats::default();
        cold.record_merge(10, 160, 1);
        let stats = ShardAggStats { per_shard: vec![hot, cold] };
        // max/mean = 90/50 → relative 0.8
        assert!((stats.imbalance().relative - 0.8).abs() < 1e-12);
        let single = ShardAggStats::single(hot);
        assert_eq!(single.imbalance().relative, 0.0);
        assert_eq!(single.n_shards(), 1);
    }
}
