//! Wire-transport ledger: frames, bytes, tuples and serialization
//! time crossing lane boundaries.
//!
//! Socket lanes share one [`WireLedger`] per endpoint set (an
//! `Arc<WireLedger>` cloned into every tx/rx and reader thread);
//! loopback lanes record nothing, so an all-loopback run reports a
//! zero [`WireStats`]. Multi-process children snapshot their ledger
//! into the `Done` frame they return and the coordinator folds the
//! copies together with [`WireStats::absorb`].

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared, thread-safe wire counters for one set of transport
/// endpoints.
#[derive(Debug, Default)]
pub struct WireLedger {
    frames_out: AtomicU64,
    bytes_out: AtomicU64,
    tuples_out: AtomicU64,
    encode_ns: AtomicU64,
    frames_in: AtomicU64,
    bytes_in: AtomicU64,
    tuples_in: AtomicU64,
    decode_ns: AtomicU64,
}

impl WireLedger {
    /// Fresh zeroed ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one encoded and sent frame: its full size on the wire,
    /// the stream tuples it carries, and the encode time.
    pub fn record_out(&self, bytes: u64, tuples: u64, encode_ns: u64) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
        self.bytes_out.fetch_add(bytes, Ordering::Relaxed);
        self.tuples_out.fetch_add(tuples, Ordering::Relaxed);
        self.encode_ns.fetch_add(encode_ns, Ordering::Relaxed);
    }

    /// Record one received and decoded frame.
    pub fn record_in(&self, bytes: u64, tuples: u64, decode_ns: u64) {
        self.frames_in.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(bytes, Ordering::Relaxed);
        self.tuples_in.fetch_add(tuples, Ordering::Relaxed);
        self.decode_ns.fetch_add(decode_ns, Ordering::Relaxed);
    }

    /// Point-in-time copy of the counters.
    pub fn snapshot(&self) -> WireStats {
        WireStats {
            frames_out: self.frames_out.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            tuples_out: self.tuples_out.load(Ordering::Relaxed),
            encode_ns: self.encode_ns.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            tuples_in: self.tuples_in.load(Ordering::Relaxed),
            decode_ns: self.decode_ns.load(Ordering::Relaxed),
        }
    }
}

/// A foldable snapshot of one endpoint set's wire traffic.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireStats {
    /// Frames encoded and sent.
    pub frames_out: u64,
    /// Bytes written to the wire (headers included).
    pub bytes_out: u64,
    /// Stream tuples serialized (data tuples + flush entries).
    pub tuples_out: u64,
    /// Total serialization time in ns.
    pub encode_ns: u64,
    /// Frames received and decoded.
    pub frames_in: u64,
    /// Bytes read from the wire (headers included).
    pub bytes_in: u64,
    /// Stream tuples deserialized.
    pub tuples_in: u64,
    /// Total deserialization time in ns.
    pub decode_ns: u64,
}

impl WireStats {
    /// Mean serialization cost per tuple sent (ns; 0 when idle).
    pub fn encode_ns_per_tuple(&self) -> f64 {
        if self.tuples_out == 0 {
            0.0
        } else {
            self.encode_ns as f64 / self.tuples_out as f64
        }
    }

    /// Mean deserialization cost per tuple received (ns; 0 when idle).
    pub fn decode_ns_per_tuple(&self) -> f64 {
        if self.tuples_in == 0 {
            0.0
        } else {
            self.decode_ns as f64 / self.tuples_in as f64
        }
    }

    /// Total wire traffic rate (both directions) over a wall-clock
    /// interval.
    pub fn bytes_per_sec(&self, wall_ns: u64) -> f64 {
        if wall_ns == 0 {
            0.0
        } else {
            (self.bytes_out + self.bytes_in) as f64 * 1e9 / wall_ns as f64
        }
    }

    /// True when any frame crossed a wire (all-loopback runs stay
    /// false, so reports can skip the wire rows).
    pub fn any(&self) -> bool {
        self.frames_out > 0 || self.frames_in > 0
    }

    /// Fold another endpoint set's stats into this one.
    pub fn absorb(&mut self, other: &WireStats) {
        self.frames_out += other.frames_out;
        self.bytes_out += other.bytes_out;
        self.tuples_out += other.tuples_out;
        self.encode_ns += other.encode_ns;
        self.frames_in += other.frames_in;
        self.bytes_in += other.bytes_in;
        self.tuples_in += other.tuples_in;
        self.decode_ns += other.decode_ns;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_records_and_snapshots() {
        let ledger = WireLedger::new();
        ledger.record_out(100, 4, 50);
        ledger.record_out(60, 2, 30);
        ledger.record_in(100, 4, 20);
        let s = ledger.snapshot();
        assert_eq!(s.frames_out, 2);
        assert_eq!(s.bytes_out, 160);
        assert_eq!(s.tuples_out, 6);
        assert_eq!(s.frames_in, 1);
        assert!(s.any());
        assert!((s.encode_ns_per_tuple() - 80.0 / 6.0).abs() < 1e-9);
        assert!((s.decode_ns_per_tuple() - 5.0).abs() < 1e-9);
        // 260 bytes over 1s
        assert!((s.bytes_per_sec(1_000_000_000) - 260.0).abs() < 1e-9);
    }

    #[test]
    fn stats_fold_and_idle_rates_are_zero() {
        let idle = WireStats::default();
        assert!(!idle.any());
        assert_eq!(idle.encode_ns_per_tuple(), 0.0);
        assert_eq!(idle.decode_ns_per_tuple(), 0.0);
        assert_eq!(idle.bytes_per_sec(0), 0.0);

        let mut a = WireStats { frames_out: 1, bytes_out: 10, tuples_out: 2, encode_ns: 8, ..Default::default() };
        let b = WireStats { frames_in: 3, bytes_in: 30, tuples_in: 6, decode_ns: 12, ..Default::default() };
        a.absorb(&b);
        assert_eq!(a.frames_out, 1);
        assert_eq!(a.frames_in, 3);
        assert_eq!(a.bytes_out + a.bytes_in, 40);
    }
}
