//! Log-bucketed latency histogram (HdrHistogram-style, hand-rolled).
//!
//! Values are u64 (nanoseconds in the runtime engine, virtual ticks in the
//! simulator). Buckets have ≤ ~2% relative width: 64 linear sub-buckets
//! per power of two, so percentile queries are accurate enough for the
//! p50/p95/p99 figures while the recorder is a branch-free O(1) insert.

/// Which clock the recorded values came from. Carried *by the
/// histogram* (and through its byte codec) so report tables derive
/// their "(virtual)"/"(wall)" labels from the data instead of
/// per-call-site strings that can silently mislabel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TimeUnit {
    /// Simulator virtual nanoseconds (deterministic ticks).
    #[default]
    VirtualNs,
    /// Wall-clock nanoseconds from `transport::Clock`.
    WallNs,
}

impl TimeUnit {
    /// Stable lowercase label for report rows.
    pub fn label(&self) -> &'static str {
        match self {
            TimeUnit::VirtualNs => "virtual",
            TimeUnit::WallNs => "wall",
        }
    }
}

/// Log-bucketed histogram of non-negative u64 samples.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    max: u64,
    min: u64,
    unit: TimeUnit,
}

const SUB_BITS: u32 = 6; // 64 sub-buckets per octave
const SUB: u64 = 1 << SUB_BITS;

#[inline]
fn bucket_of(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as u64;
    let shift = msb - SUB_BITS as u64;
    let sub = (v >> shift) - SUB; // position within octave, [0, SUB)
    ((msb - SUB_BITS as u64 + 1) * SUB + sub) as usize
}

#[inline]
fn bucket_low(b: usize) -> u64 {
    let b = b as u64;
    if b < SUB {
        return b;
    }
    let octave = (b / SUB) - 1 + SUB_BITS as u64;
    let sub = b % SUB;
    (SUB + sub) << (octave - SUB_BITS as u64)
}

impl Histogram {
    /// Empty histogram of virtual-time samples (the sim default).
    pub fn new() -> Self {
        Self::with_unit(TimeUnit::VirtualNs)
    }

    /// Empty histogram of wall-clock samples (the rt/deploy default).
    pub fn wall() -> Self {
        Self::with_unit(TimeUnit::WallNs)
    }

    /// Empty histogram with an explicit unit tag.
    pub fn with_unit(unit: TimeUnit) -> Self {
        Histogram {
            counts: vec![0; ((64 - SUB_BITS as usize) + 1) * SUB as usize],
            total: 0,
            sum: 0,
            max: 0,
            min: u64::MAX,
            unit,
        }
    }

    /// The clock domain the samples came from.
    pub fn unit(&self) -> TimeUnit {
        self.unit
    }

    /// Report label for the unit ("virtual" / "wall").
    pub fn unit_label(&self) -> &'static str {
        self.unit.label()
    }

    /// Record one sample.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_of(v)] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.max = self.max.max(v);
        self.min = self.min.min(v);
    }

    /// Merge another histogram into this one. An empty accumulator
    /// adopts the other side's unit; merging two non-empty histograms
    /// from different clock domains is a caller bug.
    pub fn merge(&mut self, other: &Histogram) {
        if self.total == 0 {
            self.unit = other.unit;
        }
        debug_assert!(
            other.total == 0 || self.unit == other.unit,
            "merging {:?} samples into a {:?} histogram",
            other.unit,
            self.unit
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Maximum recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        if self.total == 0 { 0 } else { self.max }
    }

    /// Minimum recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.total == 0 { 0 } else { self.min }
    }

    /// Value at quantile `q ∈ [0, 1]` (lower bucket bound; 0 when empty).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= rank {
                return bucket_low(b).max(self.min).min(self.max);
            }
        }
        self.max
    }

    /// (mean, p50, p95, p99) convenience tuple.
    pub fn summary(&self) -> (f64, u64, u64, u64) {
        (self.mean(), self.quantile(0.50), self.quantile(0.95), self.quantile(0.99))
    }

    /// Serialize to a sparse little-endian byte layout (only non-zero
    /// buckets travel): used by multi-process children to ship latency
    /// histograms back to the coordinator inside `Done` frames.
    pub fn to_bytes(&self, buf: &mut Vec<u8>) {
        let nonzero: u32 = self.counts.iter().filter(|&&c| c != 0).count() as u32;
        buf.extend_from_slice(&nonzero.to_le_bytes());
        for (b, &c) in self.counts.iter().enumerate() {
            if c != 0 {
                buf.extend_from_slice(&(b as u32).to_le_bytes());
                buf.extend_from_slice(&c.to_le_bytes());
            }
        }
        buf.extend_from_slice(&self.total.to_le_bytes());
        buf.extend_from_slice(&(self.sum as u64).to_le_bytes());
        buf.extend_from_slice(&((self.sum >> 64) as u64).to_le_bytes());
        buf.extend_from_slice(&self.max.to_le_bytes());
        buf.extend_from_slice(&self.min.to_le_bytes());
        buf.push(match self.unit {
            TimeUnit::VirtualNs => 0,
            TimeUnit::WallNs => 1,
        });
    }

    /// Rebuild from [`Histogram::to_bytes`] output; `None` on any
    /// truncation or an out-of-range bucket index.
    pub fn from_bytes(bytes: &[u8]) -> Option<Histogram> {
        let mut r = crate::transport::wire::Reader::new(bytes);
        let mut h = Histogram::new();
        let nonzero = r.u32().ok()? as usize;
        for _ in 0..nonzero {
            let b = r.u32().ok()? as usize;
            let c = r.u64().ok()?;
            *h.counts.get_mut(b)? = c;
        }
        h.total = r.u64().ok()?;
        let lo = r.u64().ok()? as u128;
        let hi = r.u64().ok()? as u128;
        h.sum = (hi << 64) | lo;
        h.max = r.u64().ok()?;
        h.min = r.u64().ok()?;
        h.unit = match r.u8().ok()? {
            0 => TimeUnit::VirtualNs,
            1 => TimeUnit::WallNs,
            _ => return None,
        };
        Some(h)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_roundtrip_bounds() {
        for v in [0u64, 1, 63, 64, 65, 100, 1_000, 123_456, u32::MAX as u64, 1 << 40] {
            let b = bucket_of(v);
            let lo = bucket_low(b);
            let hi = bucket_low(b + 1);
            assert!(lo <= v && v < hi, "v={v} lo={lo} hi={hi}");
        }
    }

    #[test]
    fn exact_under_64() {
        let mut h = Histogram::new();
        for v in 0..64u64 {
            h.record(v);
        }
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.quantile(1.0), 63);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 63);
        assert!((h.mean() - 31.5).abs() < 1e-9);
    }

    #[test]
    fn quantiles_within_bucket_error() {
        let mut h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        for (q, want) in [(0.5, 50_000.0), (0.95, 95_000.0), (0.99, 99_000.0)] {
            let got = h.quantile(q) as f64;
            assert!(
                (got - want).abs() / want < 0.03,
                "q={q}: got {got} want {want}"
            );
        }
    }

    #[test]
    fn merge_equals_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        let mut rng = crate::util::Rng::new(1);
        for _ in 0..10_000 {
            let v = rng.gen_range(1_000_000);
            a.record(v);
            c.record(v);
        }
        for _ in 0..10_000 {
            let v = rng.gen_range(500);
            b.record(v);
            c.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.quantile(0.99), c.quantile(0.99));
        assert_eq!(a.max(), c.max());
    }

    #[test]
    fn byte_round_trip_preserves_every_statistic() {
        let mut h = Histogram::new();
        let mut rng = crate::util::Rng::new(7);
        for _ in 0..5_000 {
            h.record(rng.gen_range(10_000_000));
        }
        let mut buf = Vec::new();
        h.to_bytes(&mut buf);
        let back = Histogram::from_bytes(&buf).expect("round trip");
        assert_eq!(back.count(), h.count());
        assert_eq!(back.min(), h.min());
        assert_eq!(back.max(), h.max());
        assert_eq!(back.mean(), h.mean());
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(back.quantile(q), h.quantile(q));
        }
        // empty histograms survive too (min sentinel intact)
        let mut empty_buf = Vec::new();
        Histogram::new().to_bytes(&mut empty_buf);
        let empty = Histogram::from_bytes(&empty_buf).expect("empty");
        assert_eq!(empty.count(), 0);
        assert_eq!(empty.min(), 0);
        // truncated input is rejected, never a panic
        assert!(Histogram::from_bytes(&buf[..buf.len() - 1]).is_none());
        assert!(Histogram::from_bytes(&[]).is_none());
    }

    #[test]
    fn unit_tag_survives_codec_and_merge() {
        assert_eq!(Histogram::new().unit_label(), "virtual");
        let mut w = Histogram::wall();
        assert_eq!(w.unit(), TimeUnit::WallNs);
        w.record(42);
        let mut buf = Vec::new();
        w.to_bytes(&mut buf);
        let back = Histogram::from_bytes(&buf).expect("round trip");
        assert_eq!(back.unit(), TimeUnit::WallNs);
        // a bad tag byte is rejected, not misread
        *buf.last_mut().unwrap() = 9;
        assert!(Histogram::from_bytes(&buf).is_none());
        // empty accumulators adopt the first merged unit
        let mut acc = Histogram::new();
        acc.merge(&back);
        assert_eq!(acc.unit(), TimeUnit::WallNs);
        assert_eq!(acc.unit_label(), "wall");
        // merging an empty histogram never flips a tagged one
        acc.merge(&Histogram::new());
        assert_eq!(acc.unit(), TimeUnit::WallNs);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0);
    }
}
