//! Crash-recovery ledger: what exactly-once costs when something dies.
//!
//! The recovery protocol (docs/RECOVERY.md) has three moving parts —
//! worker-side flush replay logs, shard-side sequencer dedup, and
//! periodic shard snapshots — and each is metered here. Socket lanes
//! and shard loops share one [`RecoveryLedger`] per process (an
//! `Arc<RecoveryLedger>` cloned into every endpoint, exactly like
//! [`crate::metrics::WireLedger`]); multi-process children snapshot
//! their ledger into the `Done` frame and the coordinator folds the
//! copies — plus its own restart/wall-time observations — with
//! [`RecoveryStats::absorb`]. A run with no faults injected reports an
//! all-zero [`RecoveryStats`], so report tables can skip the recovery
//! rows entirely.

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared, thread-safe recovery counters for one process.
#[derive(Debug, Default)]
pub struct RecoveryLedger {
    replayed_batches: AtomicU64,
    deduped_batches: AtomicU64,
    buffered_batches: AtomicU64,
    replayed_tuples: AtomicU64,
    snapshots: AtomicU64,
    snapshot_bytes: AtomicU64,
    restores: AtomicU64,
}

impl RecoveryLedger {
    /// Fresh zeroed ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// A worker re-sent one flush batch from its replay log.
    pub fn record_replayed_batch(&self) {
        self.replayed_batches.fetch_add(1, Ordering::Relaxed);
    }

    /// A shard sequencer dropped one already-absorbed batch.
    pub fn record_deduped_batch(&self) {
        self.deduped_batches.fetch_add(1, Ordering::Relaxed);
    }

    /// A shard sequencer parked one ahead-of-gap batch.
    pub fn record_buffered_batch(&self) {
        self.buffered_batches.fetch_add(1, Ordering::Relaxed);
    }

    /// A source re-sent `n` tuples to a respawned worker.
    pub fn record_replayed_tuples(&self, n: u64) {
        self.replayed_tuples.fetch_add(n, Ordering::Relaxed);
    }

    /// A shard wrote one snapshot of `bytes` serialized bytes.
    pub fn record_snapshot(&self, bytes: u64) {
        self.snapshots.fetch_add(1, Ordering::Relaxed);
        self.snapshot_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// A restarted shard reinstated state from a snapshot.
    pub fn record_restore(&self) {
        self.restores.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time copy of the counters (restart and wall-time
    /// fields zero — those are coordinator observations).
    pub fn snapshot(&self) -> RecoveryStats {
        RecoveryStats {
            replayed_batches: self.replayed_batches.load(Ordering::Relaxed),
            deduped_batches: self.deduped_batches.load(Ordering::Relaxed),
            buffered_batches: self.buffered_batches.load(Ordering::Relaxed),
            replayed_tuples: self.replayed_tuples.load(Ordering::Relaxed),
            snapshots: self.snapshots.load(Ordering::Relaxed),
            snapshot_bytes: self.snapshot_bytes.load(Ordering::Relaxed),
            restores: self.restores.load(Ordering::Relaxed),
            worker_restarts: 0,
            shard_restarts: 0,
            recovery_wall_ns: 0,
        }
    }
}

/// A foldable snapshot of one run's recovery activity.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoveryStats {
    /// Flush batches re-sent from worker replay logs after a shard
    /// restart (or a worker restart resuming mid-stream).
    pub replayed_batches: u64,
    /// Replayed batches the shard sequencers dropped as already
    /// absorbed — every one of these would have been a double count.
    pub deduped_batches: u64,
    /// Batches parked ahead of a sequence gap until the gap filled.
    pub buffered_batches: u64,
    /// Source→worker tuples re-sent to a respawned worker.
    pub replayed_tuples: u64,
    /// Shard snapshots written.
    pub snapshots: u64,
    /// Serialized snapshot bytes written.
    pub snapshot_bytes: u64,
    /// Snapshot loads (restarts that recovered persisted state).
    pub restores: u64,
    /// Worker processes killed and respawned (coordinator-observed).
    pub worker_restarts: u64,
    /// Shard processes killed and respawned (coordinator-observed).
    pub shard_restarts: u64,
    /// Wall time from kill to mesh rejoin, summed over restarts
    /// (coordinator-observed; 0 for in-process sim kills, which are
    /// instantaneous in virtual time).
    pub recovery_wall_ns: u64,
}

impl RecoveryStats {
    /// True when any recovery machinery fired (fault-free runs stay
    /// false, so reports can skip the recovery rows).
    pub fn any(&self) -> bool {
        *self != RecoveryStats::default()
    }

    /// Replayed batches as a fraction of batches a shard absorbed
    /// (`flushes` from the aggregation ledger) — the wasted-work ratio
    /// the perf gate bounds.
    pub fn replay_ratio(&self, absorbed_flushes: u64) -> f64 {
        if absorbed_flushes == 0 {
            0.0
        } else {
            self.replayed_batches as f64 / absorbed_flushes as f64
        }
    }

    /// Fold another process's stats into this one.
    pub fn absorb(&mut self, other: &RecoveryStats) {
        self.replayed_batches += other.replayed_batches;
        self.deduped_batches += other.deduped_batches;
        self.buffered_batches += other.buffered_batches;
        self.replayed_tuples += other.replayed_tuples;
        self.snapshots += other.snapshots;
        self.snapshot_bytes += other.snapshot_bytes;
        self.restores += other.restores;
        self.worker_restarts += other.worker_restarts;
        self.shard_restarts += other.shard_restarts;
        self.recovery_wall_ns += other.recovery_wall_ns;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_records_and_snapshots() {
        let ledger = RecoveryLedger::new();
        ledger.record_replayed_batch();
        ledger.record_replayed_batch();
        ledger.record_deduped_batch();
        ledger.record_buffered_batch();
        ledger.record_replayed_tuples(128);
        ledger.record_snapshot(4_096);
        ledger.record_snapshot(4_200);
        ledger.record_restore();
        let s = ledger.snapshot();
        assert_eq!(s.replayed_batches, 2);
        assert_eq!(s.deduped_batches, 1);
        assert_eq!(s.buffered_batches, 1);
        assert_eq!(s.replayed_tuples, 128);
        assert_eq!(s.snapshots, 2);
        assert_eq!(s.snapshot_bytes, 8_296);
        assert_eq!(s.restores, 1);
        assert!(s.any());
        assert!((s.replay_ratio(100) - 0.02).abs() < 1e-12);
    }

    #[test]
    fn fault_free_runs_report_nothing() {
        let s = RecoveryLedger::new().snapshot();
        assert!(!s.any());
        assert_eq!(s.replay_ratio(0), 0.0);
        assert_eq!(s, RecoveryStats::default());
    }

    #[test]
    fn stats_fold_across_processes() {
        let mut a = RecoveryStats { replayed_batches: 3, snapshots: 2, ..Default::default() };
        let b = RecoveryStats {
            replayed_batches: 1,
            deduped_batches: 4,
            shard_restarts: 1,
            recovery_wall_ns: 5_000,
            ..Default::default()
        };
        a.absorb(&b);
        assert_eq!(a.replayed_batches, 4);
        assert_eq!(a.deduped_batches, 4);
        assert_eq!(a.snapshots, 2);
        assert_eq!(a.shard_restarts, 1);
        assert_eq!(a.recovery_wall_ns, 5_000);
    }
}
