//! Load-imbalance statistics over per-worker load vectors.

/// Summary of a per-worker load distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Imbalance {
    /// Maximum worker load.
    pub max: f64,
    /// Mean worker load.
    pub mean: f64,
    /// `max/mean − 1` — 0 when perfectly balanced (the PKG papers' metric).
    pub relative: f64,
    /// Coefficient of variation (σ/μ).
    pub cv: f64,
}

impl Imbalance {
    /// Compute imbalance over worker loads (`loads[w]` = work on worker w).
    pub fn of(loads: &[f64]) -> Imbalance {
        if loads.is_empty() {
            return Imbalance { max: 0.0, mean: 0.0, relative: 0.0, cv: 0.0 };
        }
        let n = loads.len() as f64;
        let mean = loads.iter().sum::<f64>() / n;
        let max = loads.iter().copied().fold(f64::MIN, f64::max);
        let var = loads.iter().map(|l| (l - mean).powi(2)).sum::<f64>() / n;
        let (relative, cv) = if mean > 0.0 {
            (max / mean - 1.0, var.sqrt() / mean)
        } else {
            (0.0, 0.0)
        };
        Imbalance { max, mean, relative, cv }
    }

    /// Compute over integer tuple counts.
    pub fn of_counts(counts: &[u64]) -> Imbalance {
        let loads: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
        Imbalance::of(&loads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_is_zero() {
        let i = Imbalance::of(&[5.0, 5.0, 5.0, 5.0]);
        assert_eq!(i.relative, 0.0);
        assert_eq!(i.cv, 0.0);
        assert_eq!(i.max, 5.0);
    }

    #[test]
    fn skewed_detected() {
        let i = Imbalance::of(&[10.0, 0.0, 0.0, 0.0]);
        assert!((i.relative - 3.0).abs() < 1e-12); // max/mean = 10/2.5
        assert!(i.cv > 1.0);
    }

    #[test]
    fn empty_and_zero_are_safe() {
        assert_eq!(Imbalance::of(&[]).relative, 0.0);
        assert_eq!(Imbalance::of(&[0.0, 0.0]).relative, 0.0);
    }
}
