//! Memory-overhead accounting — the paper's scalability metric.
//!
//! In key-grouped stream processing each worker keeps per-key state (the
//! word-count partials). Replicating a key across `m` workers costs `m`
//! state entries; the paper's "memory overhead" is the total number of
//! (key, worker) state entries across the cluster, normalised to FG
//! (= exactly one entry per distinct key).

use crate::{Key, WorkerId};
use std::collections::HashSet;

/// Tracks which (key, worker) pairs hold state.
#[derive(Debug, Clone)]
pub struct MemoryTracker {
    pairs: HashSet<(Key, WorkerId)>,
    distinct_keys: HashSet<Key>,
}

impl MemoryTracker {
    /// Empty tracker.
    pub fn new() -> Self {
        MemoryTracker { pairs: HashSet::new(), distinct_keys: HashSet::new() }
    }

    /// Record that `worker` processed (and therefore holds state for) `key`.
    #[inline]
    pub fn touch(&mut self, key: Key, worker: WorkerId) {
        self.pairs.insert((key, worker));
        self.distinct_keys.insert(key);
    }

    /// Total state entries across all workers.
    pub fn entries(&self) -> usize {
        self.pairs.len()
    }

    /// Distinct keys seen (the FG-optimal entry count).
    pub fn distinct_keys(&self) -> usize {
        self.distinct_keys.len()
    }

    /// Overhead normalised to FG: `entries / distinct_keys` (1.0 = optimal).
    pub fn normalized(&self) -> f64 {
        if self.distinct_keys.is_empty() {
            1.0
        } else {
            self.pairs.len() as f64 / self.distinct_keys.len() as f64
        }
    }

    /// Entries currently held on workers matching `pred`.
    pub fn entries_on(&self, pred: impl Fn(WorkerId) -> bool) -> usize {
        self.pairs.iter().filter(|(_, w)| pred(*w)).count()
    }

    /// State entries migrated when worker set changes: entries whose worker
    /// no longer owns the key under `new_owner`. Used by the consistent-
    /// hashing churn experiment (paper Fig. 17).
    pub fn remap_cost(&self, new_owner: impl Fn(Key) -> Option<WorkerId>) -> usize {
        self.pairs
            .iter()
            .filter(|(k, w)| new_owner(*k).map(|nw| nw != *w).unwrap_or(true))
            .count()
    }
}

impl Default for MemoryTracker {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fg_like_assignment_is_optimal() {
        let mut m = MemoryTracker::new();
        for k in 0..100u64 {
            m.touch(k, (k % 8) as usize);
            m.touch(k, (k % 8) as usize); // idempotent
        }
        assert_eq!(m.entries(), 100);
        assert_eq!(m.distinct_keys(), 100);
        assert!((m.normalized() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sg_like_assignment_replicates() {
        let mut m = MemoryTracker::new();
        for k in 0..10u64 {
            for w in 0..8usize {
                m.touch(k, w);
            }
        }
        assert_eq!(m.entries(), 80);
        assert!((m.normalized() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn remap_cost_counts_moved_entries() {
        let mut m = MemoryTracker::new();
        for k in 0..10u64 {
            m.touch(k, 0);
        }
        // all keys move to worker 1 => all 10 entries remap
        assert_eq!(m.remap_cost(|_| Some(1)), 10);
        // nobody moves
        assert_eq!(m.remap_cost(|_| Some(0)), 0);
        // owner unknown counts as a move
        assert_eq!(m.remap_cost(|_| None), 10);
    }
}
