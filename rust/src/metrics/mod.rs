//! Measurement substrates: latency histograms, memory accounting,
//! imbalance statistics.

pub mod histogram;
pub mod imbalance;
pub mod memory;

pub use histogram::Histogram;
pub use imbalance::Imbalance;
pub use memory::MemoryTracker;
