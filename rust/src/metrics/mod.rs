//! Measurement substrates: latency histograms, memory accounting,
//! imbalance statistics, aggregation-cost ledgers.

pub mod agg;
pub mod histogram;
pub mod imbalance;
pub mod memory;
pub mod recovery;
pub mod wire;

pub use agg::{AggStats, ShardAggStats, WindowStats};
pub use histogram::{Histogram, TimeUnit};
pub use imbalance::Imbalance;
pub use memory::MemoryTracker;
pub use recovery::{RecoveryLedger, RecoveryStats};
pub use wire::{WireLedger, WireStats};
