//! Hashing primitives shared by the groupers and the consistent-hash ring.

/// FNV-1a 64-bit over a byte slice. Used for key interning and the
/// Field-Grouping / PKG key hashes (seeded variants via `mix64`).
#[inline]
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Strong 64-bit finalizer (splitmix64 mix). `mix64(key ^ seed)` gives an
/// independent hash family member per seed — this is how PKG derives its
/// two choices and D/W-Choices derive d candidates from one key.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Hash of `key` under family member `seed`, reduced to `[0, n)`.
#[inline]
pub fn hash_to(key: u64, seed: u64, n: usize) -> usize {
    debug_assert!(n > 0);
    (mix64(key ^ mix64(seed)) % n as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        // Reference values for FNV-1a 64.
        assert_eq!(fnv1a64(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xAF63_DC4C_8601_EC8C);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171F73967E8);
    }

    #[test]
    fn mix64_bijective_sample() {
        // distinct inputs -> distinct outputs over a sample
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            assert!(seen.insert(mix64(i)));
        }
    }

    #[test]
    fn hash_to_in_range_and_seed_dependent() {
        for n in [1usize, 2, 7, 128] {
            for k in 0..200u64 {
                assert!(hash_to(k, 0, n) < n);
            }
        }
        let same = (0..1000u64)
            .filter(|&k| hash_to(k, 1, 128) == hash_to(k, 2, 128))
            .count();
        assert!(same < 30, "hash family members too correlated: {same}");
    }
}
