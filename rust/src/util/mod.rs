//! Small shared utilities: deterministic PRNG, hashing helpers.

pub mod hash;
pub mod rng;

pub use hash::{fnv1a64, mix64};
pub use rng::Rng;
