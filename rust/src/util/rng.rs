//! Deterministic PRNG (xoshiro256** seeded via splitmix64).
//!
//! The vendored crate set has no `rand`, and determinism matters anyway:
//! every experiment in EXPERIMENTS.md is reproducible from its seed.

/// xoshiro256** generator. Passes BigCrush; plenty for workload synthesis.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-source generators).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map({
            let mut r = Rng::new(42);
            move |_| r.next_u64()
        }).collect();
        let b: Vec<u64> = (0..8).map({
            let mut r = Rng::new(42);
            move |_| r.next_u64()
        }).collect();
        assert_eq!(a, b);
        let mut r2 = Rng::new(43);
        assert_ne!(a[0], r2.next_u64());
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.gen_range(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn gen_f64_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(9);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
