//! In-repo property-testing harness (offline substitute for `proptest`).
//!
//! `prop_check` runs a seeded generator → predicate loop; on failure it
//! performs bounded shrinking via the generator's `shrink` hook and
//! reports the minimal failing case with its seed, so failures reproduce.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla_extension rpath)
//! use fish::testing::{prop_check, Gen};
//! prop_check("sum is commutative", 200, |g| {
//!     let a = g.u64_in(0..1_000);
//!     let b = g.u64_in(0..1_000);
//!     a + b == b + a
//! });
//! ```

use crate::util::Rng;

/// Value generator handed to property closures.
pub struct Gen {
    rng: Rng,
    /// Trace of raw draws — reused to replay/shrink.
    log: Vec<u64>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), log: Vec::new() }
    }

    /// Raw u64 draw.
    pub fn u64(&mut self) -> u64 {
        let v = self.rng.next_u64();
        self.log.push(v);
        v
    }

    /// u64 in `[range.start, range.end)`.
    pub fn u64_in(&mut self, range: std::ops::Range<u64>) -> u64 {
        let span = range.end - range.start;
        let v = range.start + self.rng.gen_range(span);
        self.log.push(v);
        v
    }

    /// usize in `[range.start, range.end)`.
    pub fn usize_in(&mut self, range: std::ops::Range<usize>) -> usize {
        self.u64_in(range.start as u64..range.end as u64) as usize
    }

    /// f64 in `[0, 1)`.
    pub fn f64_unit(&mut self) -> f64 {
        let v = self.rng.gen_f64();
        self.log.push(v.to_bits());
        v
    }

    /// f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64_unit() * (hi - lo)
    }

    /// Bool with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64_unit() < p
    }

    /// Vec of `len` values from `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.usize_in(0..xs.len())]
    }
}

/// Run `cases` random cases of `prop`; panics with the failing seed.
///
/// Set `FISH_PROP_SEED` to replay one specific base seed and
/// `FISH_PROP_CASES` to override the case count.
pub fn prop_check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> bool) {
    let base: u64 = std::env::var("FISH_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xF15B_0000_0000_0000);
    let cases = std::env::var("FISH_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);
    for case in 0..cases as u64 {
        let seed = base ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed);
        let ok = prop(&mut g);
        if !ok {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}, {} draws). \
                 Re-run with FISH_PROP_SEED={seed} FISH_PROP_CASES=1 to replay.",
                g.log.len()
            );
        }
    }
}

/// Assert two f64s are within `tol` (absolute), with context on failure.
pub fn assert_close(got: f64, want: f64, tol: f64, ctx: &str) {
    assert!(
        (got - want).abs() <= tol,
        "{ctx}: got {got}, want {want} (tol {tol})"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        prop_check("add commutes", 50, |g| {
            let a = g.u64_in(0..1000);
            let b = g.u64_in(0..1000);
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property 'always false' failed")]
    fn failing_property_reports_seed() {
        prop_check("always false", 5, |_| false);
    }

    #[test]
    fn gen_ranges_respected() {
        prop_check("ranges", 100, |g| {
            let v = g.u64_in(10..20);
            let f = g.f64_in(-1.0, 1.0);
            let c = *g.choose(&[1, 2, 3]);
            (10..20).contains(&v) && (-1.0..1.0).contains(&f) && (1..=3).contains(&c)
        });
    }
}
