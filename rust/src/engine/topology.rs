//! Cluster topology: worker set, capacities, and scripted churn.

use crate::config::Config;
use crate::WorkerId;

/// A scripted worker-set change (paper §6.5's dynamic scenario).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnEvent {
    /// Bring a new worker online.
    Add(WorkerId),
    /// Remove (crash/decommission) a worker.
    Remove(WorkerId),
}

/// The cluster as the engines see it.
#[derive(Debug, Clone)]
pub struct Topology {
    /// Alive worker ids, ascending.
    workers: Vec<WorkerId>,
    /// `P_w`: per-tuple processing time, indexed by worker id (slots for
    /// workers that may join later are pre-sized).
    per_tuple_time: Vec<f64>,
    /// Scripted churn: (tuple index, event), ascending by index.
    churn: Vec<(usize, ChurnEvent)>,
    next_churn: usize,
}

impl Topology {
    /// Homogeneous-or-cycled capacities from `cfg` (capacity `c` means
    /// per-tuple time `service_ns / c`).
    pub fn from_config(cfg: &Config) -> Self {
        let caps = cfg.capacity_vec();
        let per_tuple_time: Vec<f64> =
            caps.iter().map(|&c| cfg.service_ns as f64 / c).collect();
        Topology {
            workers: (0..cfg.workers).collect(),
            per_tuple_time,
            churn: Vec::new(),
            next_churn: 0,
        }
    }

    /// Explicit construction (tests, ablations).
    pub fn new(workers: Vec<WorkerId>, per_tuple_time: Vec<f64>) -> Self {
        assert!(workers.iter().all(|&w| w < per_tuple_time.len()));
        Topology { workers, per_tuple_time, churn: Vec::new(), next_churn: 0 }
    }

    /// Script churn events (must be sorted by tuple index). Added workers
    /// get `per_tuple_time` extended with `time` if their id is new.
    pub fn with_churn(mut self, churn: Vec<(usize, ChurnEvent)>, new_worker_time: f64) -> Self {
        for &(_, ev) in &churn {
            if let ChurnEvent::Add(w) = ev {
                if w >= self.per_tuple_time.len() {
                    self.per_tuple_time.resize(w + 1, new_worker_time);
                } else {
                    self.per_tuple_time[w] = new_worker_time;
                }
            }
        }
        debug_assert!(churn.windows(2).all(|p| p[0].0 <= p[1].0));
        self.churn = churn;
        self
    }

    /// Alive workers.
    pub fn workers(&self) -> &[WorkerId] {
        &self.workers
    }

    /// `P_w` table (index by worker id).
    pub fn per_tuple_time(&self) -> &[f64] {
        &self.per_tuple_time
    }

    /// Array sizing for per-worker state.
    pub fn n_slots(&self) -> usize {
        self.per_tuple_time.len()
    }

    /// Apply any churn events due at `tuple_idx`; returns true if the
    /// membership changed (callers must notify groupers).
    pub fn apply_churn(&mut self, tuple_idx: usize) -> bool {
        let mut changed = false;
        while self.next_churn < self.churn.len() && self.churn[self.next_churn].0 <= tuple_idx {
            match self.churn[self.next_churn].1 {
                ChurnEvent::Add(w) => {
                    if !self.workers.contains(&w) {
                        self.workers.push(w);
                        self.workers.sort_unstable();
                        changed = true;
                    }
                }
                ChurnEvent::Remove(w) => {
                    let before = self.workers.len();
                    self.workers.retain(|&x| x != w);
                    changed |= self.workers.len() != before;
                }
            }
            self.next_churn += 1;
        }
        changed
    }

    /// Remaining scripted events.
    pub fn pending_churn(&self) -> usize {
        self.churn.len() - self.next_churn
    }

    /// Tuple index of the next pending scripted event, if any. The
    /// batched simulator caps each routing batch at this index so
    /// membership changes still land on exact tuple boundaries.
    pub fn next_churn_at(&self) -> Option<usize> {
        self.churn.get(self.next_churn).map(|&(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_config_cycles_capacities() {
        let mut cfg = Config::default();
        cfg.workers = 4;
        cfg.service_ns = 1_000;
        cfg.capacities = vec![1.0, 2.0];
        let t = Topology::from_config(&cfg);
        assert_eq!(t.per_tuple_time(), &[1_000.0, 500.0, 1_000.0, 500.0]);
        assert_eq!(t.workers(), &[0, 1, 2, 3]);
    }

    #[test]
    fn churn_applies_in_order() {
        let mut t = Topology::new(vec![0, 1, 2], vec![1.0; 3]).with_churn(
            vec![(100, ChurnEvent::Remove(1)), (200, ChurnEvent::Add(3))],
            2.0,
        );
        assert!(!t.apply_churn(50));
        assert!(t.apply_churn(150));
        assert_eq!(t.workers(), &[0, 2]);
        assert!(t.apply_churn(250));
        assert_eq!(t.workers(), &[0, 2, 3]);
        assert_eq!(t.per_tuple_time()[3], 2.0);
        assert_eq!(t.pending_churn(), 0);
    }

    #[test]
    fn next_churn_at_tracks_pending_events() {
        let mut t = Topology::new(vec![0, 1, 2], vec![1.0; 3]).with_churn(
            vec![(100, ChurnEvent::Remove(1)), (200, ChurnEvent::Add(3))],
            1.0,
        );
        assert_eq!(t.next_churn_at(), Some(100));
        t.apply_churn(150);
        assert_eq!(t.next_churn_at(), Some(200));
        t.apply_churn(250);
        assert_eq!(t.next_churn_at(), None);
    }

    #[test]
    fn duplicate_ops_are_idempotent() {
        let mut t = Topology::new(vec![0, 1], vec![1.0; 2]).with_churn(
            vec![(10, ChurnEvent::Remove(1)), (20, ChurnEvent::Remove(1))],
            1.0,
        );
        assert!(t.apply_churn(15));
        assert!(!t.apply_churn(25)); // already gone: no change
        assert_eq!(t.workers(), &[0]);
    }
}
