//! Batch-first pipeline construction: one fluent entry point that both
//! engines, the CLI, the examples and the benches build jobs through.
//!
//! Before this builder existed there were three divergent wirings —
//! `make_scheme` + hand-built [`Topology`] + [`Simulator`] in the CLI,
//! another copy in every bench, and a third in the runtime path. The
//! builder owns that wiring once:
//!
//! ```no_run
//! use fish::coordinator::SchemeKind;
//! use fish::engine::Pipeline;
//!
//! let result = Pipeline::builder()
//!     .workload("zf")
//!     .scheme(SchemeKind::Fish)
//!     .sources(4)
//!     .workers(32)
//!     .batch(1024)
//!     .tuples(200_000)
//!     .build_sim()
//!     .run();
//! println!("makespan {}", result.makespan);
//! ```
//!
//! `build_sim()` produces a [`SimJob`] (deterministic discrete-event
//! run), `build_rt()` a [`RtJob`] (threaded deployment run). Escape
//! hatches cover the ablation studies: [`PipelineBuilder::with_sources`]
//! injects pre-built groupers (XLA identifier, CHK/HWA ablations),
//! [`PipelineBuilder::trace`] reuses one materialised trace across
//! schemes, and [`PipelineBuilder::configure`] tweaks any
//! [`Config`] field without a dedicated setter.

use super::rt::{self, RtOptions, RtResult};
use super::sim::{SimResult, Simulator};
use super::topology::{ChurnEvent, Topology};
use crate::config::Config;
use crate::coordinator::{make_scheme, Grouper, SchemeKind};
use crate::workload::{by_name, materialise, Generator, Trace};
use std::sync::Arc;

/// Namespace for [`Pipeline::builder`].
pub struct Pipeline;

impl Pipeline {
    /// Start building a job from the default [`Config`].
    pub fn builder() -> PipelineBuilder {
        PipelineBuilder::default()
    }
}

/// Fluent builder for simulator and runtime jobs.
pub struct PipelineBuilder {
    cfg: Config,
    churn: Vec<(usize, ChurnEvent)>,
    queue_depth: Option<usize>,
    per_tuple_ns: Option<Vec<f64>>,
    groupers: Option<Vec<Box<dyn Grouper>>>,
    trace: Option<Arc<Trace>>,
}

impl Default for PipelineBuilder {
    fn default() -> Self {
        PipelineBuilder {
            cfg: Config::default(),
            churn: Vec::new(),
            queue_depth: None,
            per_tuple_ns: None,
            groupers: None,
            trace: None,
        }
    }
}

impl PipelineBuilder {
    /// Replace the whole config (e.g. one resolved from file + flags).
    pub fn config(mut self, cfg: Config) -> Self {
        self.cfg = cfg;
        self
    }

    /// Workload name: `zf`, `mt` or `am`.
    pub fn workload(mut self, name: &str) -> Self {
        self.cfg.workload = name.to_string();
        self
    }

    /// Grouping scheme under test.
    pub fn scheme(mut self, kind: SchemeKind) -> Self {
        self.cfg.scheme = kind;
        self
    }

    /// Number of tuples to stream.
    pub fn tuples(mut self, n: usize) -> Self {
        self.cfg.tuples = n;
        self
    }

    /// Zipf exponent for the `zf` workload.
    pub fn zipf_z(mut self, z: f64) -> Self {
        self.cfg.zipf_z = z;
        self
    }

    /// Number of sources (one grouper instance each).
    pub fn sources(mut self, n: usize) -> Self {
        self.cfg.sources = n;
        self
    }

    /// Number of workers.
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n;
        self
    }

    /// Worker capacity multipliers (cycled across workers).
    pub fn capacities(mut self, caps: Vec<f64>) -> Self {
        self.cfg.capacities = caps;
        self
    }

    /// Routing batch size (tuples per `route_batch` call).
    pub fn batch(mut self, n: usize) -> Self {
        self.cfg.batch = n;
        self
    }

    /// Partial-aggregate flush interval in ms (wall ms in the runtime
    /// engine, virtual ms in the simulator; 0 = flush only at end).
    pub fn agg_flush_ms(mut self, ms: u64) -> Self {
        self.cfg.agg_flush_ms = ms;
        self
    }

    /// Stage-two merge-shard count (1 = single aggregator). The runtime
    /// engine runs one aggregator thread per shard; the simulator
    /// scatters virtual-time flushes across the fabric. Never changes
    /// merged results — only parallelism and the per-shard ledgers.
    pub fn agg_shards(mut self, n: usize) -> Self {
        self.cfg.agg_shards = n;
        self
    }

    /// Windowed aggregation: tumbling-pane length in event-time ms
    /// (0 = unwindowed). Closed panes retire into per-window exact
    /// counts + per-window top-k in `SimResult::windows` /
    /// `RtResult::windows`; all-time merged results are unchanged.
    pub fn agg_window_ms(mut self, ms: u64) -> Self {
        self.cfg.agg_window_ms = ms;
        self
    }

    /// Watermark slack (ms) before pane retirement: panes stay open
    /// until the watermark passes `pane end + slack`, so bounded
    /// event-time disorder absorbs in place instead of taking the
    /// late-reopen path. 0 = retire immediately (the strict default).
    pub fn agg_lateness_ms(mut self, ms: u64) -> Self {
        self.cfg.agg_lateness_ms = ms;
        self
    }

    /// Lane backend for the runtime engine's source→worker and
    /// worker→shard traffic (loopback, UDS or TCP); the simulator
    /// ignores it.
    pub fn transport(mut self, kind: crate::transport::TransportKind) -> Self {
        self.cfg.transport = kind.name().to_string();
        self
    }

    /// PRNG seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Mean per-tuple service time (ns / virtual ticks).
    pub fn service_ns(mut self, ns: u64) -> Self {
        self.cfg.service_ns = ns;
        self
    }

    /// Mean tuple inter-arrival gap (ns); 0 = as fast as possible.
    pub fn interarrival_ns(mut self, ns: u64) -> Self {
        self.cfg.interarrival_ns = ns;
        self
    }

    /// FISH / D-C / W-C tracked-key capacity `K_max`.
    pub fn key_capacity(mut self, cap: usize) -> Self {
        self.cfg.key_capacity = cap;
        self
    }

    /// HWA re-estimation interval `T`.
    pub fn interval(mut self, interval: u64) -> Self {
        self.cfg.interval = interval;
        self
    }

    /// Arbitrary config tweak for fields without a dedicated setter.
    pub fn configure(mut self, f: impl FnOnce(&mut Config)) -> Self {
        f(&mut self.cfg);
        self
    }

    /// Scripted worker churn (simulator only; sorted by tuple index).
    pub fn churn(mut self, events: Vec<(usize, ChurnEvent)>) -> Self {
        self.churn = events;
        self
    }

    /// Bounded per-worker queue depth in tuples (runtime only).
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = Some(depth);
        self
    }

    /// Override the runtime per-tuple CPU burn vector (default: derived
    /// from `service_ns` and the capacity multipliers).
    pub fn per_tuple_ns(mut self, ns: Vec<f64>) -> Self {
        self.per_tuple_ns = Some(ns);
        self
    }

    /// Inject pre-built groupers instead of `make_scheme` instances —
    /// the hook the XLA identifier backend and the ablation studies
    /// (candidate-mode, CHK-mode, count-based HWA) plug into.
    pub fn with_sources(mut self, groupers: Vec<Box<dyn Grouper>>) -> Self {
        self.groupers = Some(groupers);
        self
    }

    /// Reuse a materialised trace (runtime only) so several schemes can
    /// run over byte-identical input.
    pub fn trace(mut self, trace: Arc<Trace>) -> Self {
        self.trace = Some(trace);
        self
    }

    fn take_groupers(groupers: Option<Vec<Box<dyn Grouper>>>, cfg: &Config) -> Vec<Box<dyn Grouper>> {
        match groupers {
            Some(g) => {
                assert!(!g.is_empty(), "with_sources: need at least one grouper");
                g
            }
            None => (0..cfg.sources).map(|s| make_scheme(cfg, s)).collect(),
        }
    }

    /// Build a deterministic simulator job (paper Figs. 2–17).
    ///
    /// Panics if a runtime-only option (`trace`, `per_tuple_ns`,
    /// `queue_depth`) was set — silently ignoring it would run a
    /// different experiment than the caller asked for.
    pub fn build_sim(self) -> SimJob {
        let PipelineBuilder { cfg, churn, queue_depth, per_tuple_ns, groupers, trace } = self;
        assert!(trace.is_none(), "trace(..) only applies to build_rt()");
        assert!(per_tuple_ns.is_none(), "per_tuple_ns(..) only applies to build_rt()");
        assert!(queue_depth.is_none(), "queue_depth(..) only applies to build_rt()");
        if let Err(e) = cfg.validate() {
            panic!("invalid pipeline config: {e}");
        }
        let mut topology = Topology::from_config(&cfg);
        if !churn.is_empty() {
            topology = topology.with_churn(churn, cfg.service_ns as f64);
        }
        let sources = Self::take_groupers(groupers, &cfg);
        let sim = Simulator::new(topology, sources, cfg.interarrival_ns)
            .with_batch(cfg.batch)
            .with_agg_flush(cfg.agg_flush_ms.saturating_mul(1_000_000))
            .with_agg_shards(cfg.agg_shards)
            .with_agg_window(cfg.agg_window_ms.saturating_mul(1_000_000))
            .with_agg_lateness(cfg.agg_lateness_ms.saturating_mul(1_000_000))
            .with_trace(crate::obs::enabled());
        let gen = by_name(&cfg.workload, cfg.tuples, cfg.zipf_z, cfg.seed);
        SimJob { sim, gen }
    }

    /// Build a threaded runtime job (paper Figs. 18–20).
    ///
    /// Panics if a simulator-only option (`churn`) was set — the
    /// runtime engine has no scripted-churn support (yet).
    pub fn build_rt(self) -> RtJob {
        let PipelineBuilder { cfg, churn, queue_depth, per_tuple_ns, groupers, trace } = self;
        assert!(churn.is_empty(), "churn(..) only applies to build_sim()");
        if let Err(e) = cfg.validate() {
            panic!("invalid pipeline config: {e}");
        }
        let sources = Self::take_groupers(groupers, &cfg);
        let trace = trace.unwrap_or_else(|| {
            let mut gen = by_name(&cfg.workload, cfg.tuples, cfg.zipf_z, cfg.seed);
            Arc::new(materialise(gen.as_mut(), cfg.interarrival_ns))
        });
        let per_tuple_ns = per_tuple_ns.unwrap_or_else(|| {
            cfg.capacity_vec()
                .iter()
                .map(|&c| cfg.service_ns as f64 / c)
                .collect()
        });
        let opts = RtOptions {
            queue_depth: queue_depth.unwrap_or(1024),
            per_tuple_ns,
            interarrival_ns: cfg.interarrival_ns,
            batch: cfg.batch,
            agg_flush_ns: cfg.agg_flush_ms.saturating_mul(1_000_000),
            agg_shards: cfg.agg_shards,
            agg_window_ns: cfg.agg_window_ms.saturating_mul(1_000_000),
            agg_lateness_ns: cfg.agg_lateness_ms.saturating_mul(1_000_000),
            transport: crate::transport::TransportKind::parse(&cfg.transport)
                .unwrap_or_default(),
        };
        RtJob { trace, sources, workers: cfg.workers, opts }
    }
}

/// A ready-to-run simulator job.
pub struct SimJob {
    sim: Simulator,
    gen: Box<dyn Generator + Send>,
}

impl SimJob {
    /// Run the simulation to completion.
    pub fn run(&mut self) -> SimResult {
        self.sim.run(self.gen.as_mut())
    }
}

/// A ready-to-run threaded runtime job.
pub struct RtJob {
    trace: Arc<Trace>,
    sources: Vec<Box<dyn Grouper>>,
    workers: usize,
    opts: RtOptions,
}

impl RtJob {
    /// The trace this job will stream.
    pub fn trace(&self) -> &Arc<Trace> {
        &self.trace
    }

    /// Run the deployment to completion, panicking on lane-mesh setup
    /// failure (tests and benches; the CLI uses [`RtJob::try_run`]).
    pub fn run(self) -> RtResult {
        rt::run(&self.trace, self.sources, self.workers, &self.opts)
    }

    /// Run the deployment to completion, surfacing socket-mesh setup
    /// failures as [`crate::transport::LaneError`] instead of
    /// panicking.
    pub fn try_run(self) -> Result<RtResult, crate::transport::LaneError> {
        rt::try_run(&self.trace, self.sources, self.workers, &self.opts)
    }

    /// Run the deployment as child processes — one per worker, one per
    /// merge shard — via [`crate::transport::launch::run_multiprocess`]
    /// (`deploy --processes N`). The sources stay in this process.
    pub fn run_multiprocess(self) -> std::io::Result<RtResult> {
        self.run_multiprocess_chaos(&crate::transport::launch::ChaosPlan::default())
    }

    /// [`RtJob::run_multiprocess`] with scripted kills: an armed
    /// [`crate::transport::launch::ChaosPlan`] crashes victims mid-run
    /// and the fabric must still converge exactly (`deploy --chaos`).
    pub fn run_multiprocess_chaos(
        self,
        chaos: &crate::transport::launch::ChaosPlan,
    ) -> std::io::Result<RtResult> {
        crate::transport::launch::run_multiprocess(
            &self.trace,
            self.sources,
            self.workers,
            &self.opts,
            chaos,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::make_kind;

    #[test]
    fn builder_sim_matches_manual_wiring() {
        let mut cfg = Config::default();
        cfg.scheme = SchemeKind::Pkg;
        cfg.workers = 8;
        cfg.tuples = 15_000;
        cfg.sources = 2;
        cfg.interarrival_ns = 150;

        let manual = {
            let topology = Topology::from_config(&cfg);
            let sources: Vec<Box<dyn Grouper>> =
                (0..cfg.sources).map(|s| make_scheme(&cfg, s)).collect();
            let mut sim =
                Simulator::new(topology, sources, cfg.interarrival_ns).with_batch(cfg.batch);
            let mut gen = by_name(&cfg.workload, cfg.tuples, cfg.zipf_z, cfg.seed);
            sim.run(gen.as_mut())
        };
        let built = Pipeline::builder().config(cfg).build_sim().run();
        assert_eq!(manual.worker_counts, built.worker_counts);
        assert_eq!(manual.makespan, built.makespan);
        assert_eq!(manual.entries, built.entries);
    }

    #[test]
    fn fluent_setters_reach_the_config() {
        let mut job = Pipeline::builder()
            .workload("zf")
            .scheme(SchemeKind::Shuffle)
            .sources(2)
            .workers(4)
            .batch(64)
            .tuples(5_000)
            .zipf_z(1.2)
            .seed(9)
            .interarrival_ns(100)
            .build_sim();
        let r = job.run();
        assert_eq!(r.tuples, 5_000);
        assert_eq!(r.worker_counts.iter().sum::<u64>(), 5_000);
        assert_eq!(r.worker_counts.len(), 4);
    }

    #[test]
    fn builder_rt_runs_and_respects_injected_sources() {
        let cfg = {
            let mut c = Config::default();
            c.workers = 4;
            c.sources = 2;
            c.tuples = 10_000;
            c.interarrival_ns = 0;
            c
        };
        let sources: Vec<Box<dyn Grouper>> = (0..2)
            .map(|s| make_kind(SchemeKind::Shuffle, &cfg, s))
            .collect();
        let r = Pipeline::builder()
            .config(cfg)
            .with_sources(sources)
            .per_tuple_ns(vec![0.0])
            .build_rt()
            .run();
        assert_eq!(r.worker_counts.iter().sum::<u64>(), 10_000);
        // shuffle spreads evenly: every worker saw traffic
        assert!(r.worker_counts.iter().all(|&c| c > 0));
    }

    #[test]
    fn builder_wires_agg_flush_into_both_engines() {
        // flush cadence must not change the merged result, only traffic
        let run_sim = |ms: u64| {
            Pipeline::builder()
                .workload("zf")
                .scheme(SchemeKind::Pkg)
                .sources(2)
                .workers(4)
                .tuples(10_000)
                .interarrival_ns(150)
                .agg_flush_ms(ms)
                .build_sim()
                .run()
        };
        let (a, b) = (run_sim(0), run_sim(2));
        assert_eq!(a.merged_counts, b.merged_counts);
        assert!(a.agg.flushes <= b.agg.flushes);

        let rt = Pipeline::builder()
            .workload("zf")
            .scheme(SchemeKind::Pkg)
            .sources(2)
            .workers(4)
            .tuples(10_000)
            .agg_flush_ms(2)
            .configure(|c| c.interarrival_ns = 0)
            .build_rt()
            .run();
        assert_eq!(rt.merged.iter().map(|&(_, c)| c).sum::<u64>(), 10_000);
    }

    #[test]
    fn builder_wires_agg_shards_into_both_engines() {
        let sim = Pipeline::builder()
            .workload("zf")
            .scheme(SchemeKind::Pkg)
            .sources(2)
            .workers(4)
            .tuples(10_000)
            .interarrival_ns(150)
            .agg_shards(3)
            .build_sim()
            .run();
        assert_eq!(sim.shard_agg.n_shards(), 3);
        assert_eq!(sim.merged_counts.iter().map(|&(_, c)| c).sum::<u64>(), 10_000);

        let rt = Pipeline::builder()
            .workload("zf")
            .scheme(SchemeKind::Pkg)
            .sources(2)
            .workers(4)
            .tuples(10_000)
            .agg_shards(3)
            .per_tuple_ns(vec![0.0])
            .configure(|c| c.interarrival_ns = 0)
            .build_rt()
            .run();
        assert_eq!(rt.shard_agg.n_shards(), 3);
        assert_eq!(rt.merged, sim.merged_counts);
    }

    #[test]
    fn builder_wires_agg_window_into_both_engines() {
        // identical trace timing (trace ts == sim arrival time), so the
        // per-window counts must agree byte for byte across engines
        let sim = Pipeline::builder()
            .workload("zf")
            .scheme(SchemeKind::Pkg)
            .sources(2)
            .workers(4)
            .tuples(10_000)
            .interarrival_ns(500)
            .agg_window_ms(1)
            .build_sim()
            .run();
        assert_eq!(sim.windows.len(), 5, "10k tuples × 500ns = 5ms = 5 panes");
        assert_eq!(sim.windows.iter().map(|w| w.total()).sum::<u64>(), 10_000);

        let rt = Pipeline::builder()
            .workload("zf")
            .scheme(SchemeKind::Pkg)
            .sources(2)
            .workers(4)
            .tuples(10_000)
            .interarrival_ns(500)
            .agg_window_ms(1)
            .per_tuple_ns(vec![0.0])
            .build_rt()
            .run();
        assert_eq!(rt.windows.len(), sim.windows.len());
        for (a, b) in sim.windows.iter().zip(&rt.windows) {
            assert_eq!(a.window, b.window);
            assert_eq!(a.counts, b.counts, "pane {}", a.window);
        }
        // the unwindowed all-time result is untouched by windowing
        assert_eq!(rt.merged, sim.merged_counts);
    }

    #[test]
    #[should_panic(expected = "invalid pipeline config")]
    fn zero_agg_shards_is_rejected() {
        let _ = Pipeline::builder().agg_shards(0).build_sim();
    }

    #[test]
    fn builder_wires_churn_into_the_topology() {
        let r = Pipeline::builder()
            .workload("zf")
            .scheme(SchemeKind::Fish)
            .sources(2)
            .workers(8)
            .tuples(30_000)
            .interarrival_ns(150)
            .churn(vec![(10_000, ChurnEvent::Remove(3)), (20_000, ChurnEvent::Add(8))])
            .build_sim()
            .run();
        assert_eq!(r.worker_counts.iter().sum::<u64>(), 30_000);
        assert!(r.worker_counts[8] > 0, "late-joining worker got no tuples");
    }

    #[test]
    #[should_panic(expected = "invalid pipeline config")]
    fn invalid_config_is_rejected() {
        let _ = Pipeline::builder().workers(0).build_sim();
    }

    #[test]
    #[should_panic(expected = "only applies to build_rt()")]
    fn sim_rejects_runtime_only_options() {
        let _ = Pipeline::builder().per_tuple_ns(vec![1.0]).build_sim();
    }

    #[test]
    #[should_panic(expected = "only applies to build_sim()")]
    fn rt_rejects_sim_only_options() {
        let _ = Pipeline::builder()
            .churn(vec![(10, ChurnEvent::Remove(0))])
            .build_rt();
    }
}
