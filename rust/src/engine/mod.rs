//! The DSPE substrate (the paper runs on Apache Storm; we build the
//! equivalent from scratch — DESIGN.md §5).
//!
//! * [`sim`] — deterministic discrete-event simulator: virtual clock,
//!   per-worker FIFO queues, heterogeneous capacities, worker churn.
//!   Reproduces the paper's simulation experiments (Figs. 2–17) exactly
//!   and repeatably.
//! * [`rt`] — the "practical deployment" (paper §6.6): a real
//!   multithreaded pipeline — source threads route through the grouping
//!   scheme into bounded per-worker channels (backpressure), worker
//!   threads run the actual word-count aggregation — measuring
//!   wall-clock latency percentiles and throughput (Figs. 18–20).
//! * [`topology`] — shared cluster description + churn scripting.

pub mod rt;
pub mod sim;
pub mod topology;

pub use sim::{SimResult, Simulator};
pub use topology::{ChurnEvent, Topology};
