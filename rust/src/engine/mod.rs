//! The DSPE substrate (the paper runs on Apache Storm; we build the
//! equivalent from scratch — DESIGN.md §5).
//!
//! * [`pipeline`] — the [`Pipeline`] builder: the single batch-first
//!   construction path both engines, the CLI, the examples and the
//!   benches share.
//! * [`sim`] — deterministic discrete-event simulator: virtual clock,
//!   per-worker FIFO queues, heterogeneous capacities, worker churn.
//!   Reproduces the paper's simulation experiments (Figs. 2–17),
//!   bit-repeatably for a given (seed, batch size). Note the batched
//!   drain stamps each routing view at the batch-head arrival, so
//!   time-sensitive schemes (FISH's HWA re-estimation) see virtual
//!   time at batch granularity rather than per-tuple.
//! * [`rt`] — the "practical deployment" (paper §6.6): a real
//!   multithreaded pipeline — source threads route tuple batches
//!   through the grouping scheme and ship per-worker chunks into
//!   bounded channels (backpressure), worker threads run the actual
//!   word-count aggregation — measuring wall-clock latency percentiles
//!   and throughput (Figs. 18–20).
//! * [`topology`] — shared cluster description + churn scripting.
//!
//! Both engines drain tuples in micro-batches through
//! [`crate::coordinator::Grouper::route_batch`]; the batch size comes
//! from [`crate::config::Config::batch`] (`--batch` on the CLI).
//!
//! Both engines also run the **two-stage topology** from
//! [`crate::aggregate`]: per-worker partial aggregates are periodically
//! flushed to a downstream merge fabric of
//! [`crate::config::Config::agg_shards`] key-range shards (one real
//! aggregator thread per shard in [`rt`], a deterministic virtual-time
//! flush scatter in [`sim`]), so the per-worker partials every
//! key-splitting scheme produces are reassembled into exact merged
//! counts — shard-count-invariantly. The flush cadence is
//! [`crate::config::Config::agg_flush_ms`] (`--agg_flush_ms`), snapped
//! to one shared boundary grid ([`crate::aggregate::next_boundary`]) in
//! both engines; the traffic it costs lands in `SimResult::agg` /
//! `RtResult::agg`, with per-shard ledgers and the shard-imbalance
//! summary in `shard_agg` and global approximate top-k behind the
//! scatter-gather [`crate::aggregate::TopKGather`] front-end.
//!
//! With [`crate::config::Config::agg_window_ms`] (`--agg_window_ms`)
//! set, the fabric also runs **windowed**: tuples land in tumbling
//! event-time panes (virtual arrival time in [`sim`], trace emit time
//! in [`rt`]), watermark advance retires closed panes into per-window
//! exact counts + per-window top-k (`SimResult::windows` /
//! `RtResult::windows`, pane lifecycle in `window_stats`), and
//! [`crate::aggregate::sliding`] composes sliding windows from the
//! panes.

pub mod pipeline;
pub mod rt;
pub mod sim;
pub mod topology;

pub use pipeline::{Pipeline, PipelineBuilder, RtJob, SimJob};
pub use sim::{FaultPoint, SimResult, Simulator};
pub use topology::{ChurnEvent, Topology};
