//! Deterministic discrete-event DSPE simulator.
//!
//! Model (paper §6.1 "Simulation Settings"): tuples arrive at the
//! sources via shuffle grouping (round-robin over sources), each source
//! routes through its own grouping-scheme instance, and each worker is a
//! FIFO queue with a fixed per-tuple service time `P_w` (heterogeneous
//! capacities = different `P_w`). Virtual time advances with tuple
//! arrivals (`interarrival_ns` apart); a tuple's completion is
//!
//! ```text
//! done_w ← max(done_w, arrival) + P_w        latency = done_w − arrival
//! ```
//!
//! Outputs: the paper's three metrics — *execution time* (makespan =
//! when the last worker drains, Figs. 9–16), *latency* distribution
//! (Fig. 2), and *memory overhead* (distinct (key, worker) state entries,
//! Figs. 3, 11–17) — plus imbalance diagnostics.
//!
//! The simulated topology is **two-stage**: every worker keeps a
//! [`WindowedPartial`] of its per-(pane, key) counts and flushes the
//! deltas downstream whenever virtual time crosses an `agg_flush`
//! boundary (plus a final drain, and an eager drain of any worker
//! removed by churn). Stage two is a fabric of per-shard
//! [`WindowedMerge`] stages (`--agg_shards` key-range merge shards; one
//! shard ≡ the single aggregator): each pane's flush batch is scattered
//! across the shards deterministically, with a [`TopKGather`] absorbing
//! the same deltas for bounded-memory approximate all-time top-k. The
//! merged counts are exact regardless of how a scheme split keys *or*
//! how many shards merged them — the end-to-end correctness oracle —
//! and the flush traffic is metered per shard in
//! [`SimResult::shard_agg`], modelling the aggregation cost the PKG
//! paper charges against key splitting.
//!
//! With `--agg_window_ms > 0` the fabric runs **windowed**: tuples are
//! assigned to tumbling panes by arrival (event) time, each periodic
//! flush advances the watermark (exact here — virtual time is global),
//! closed panes retire into [`SimResult::windows`] with exact
//! per-window counts and a per-window top-k gather, and pane lifecycle
//! is accounted in [`SimResult::window_stats`].
//!
//! **Chaos**: the fabric speaks the same exactly-once flush protocol as
//! the deployed mesh — every worker→shard lane carries a monotonic
//! `seq`, each shard runs a [`FlushSequencer`], and shards snapshot
//! through the real [`ShardSnapshot`] codec. Scripted [`FaultPoint`]s
//! ([`Simulator::with_faults`]) kill workers (the un-flushed delta dies
//! and the source replays the since-last-flush suffix) or shards (state
//! is dropped, restored from the last snapshot bytes, and the workers
//! replay their logged flushes from the Resume cursors) at deterministic
//! virtual-time points, so recovery is bit-reproducible and the oracle
//! can assert chaos runs converge byte-identically (docs/RECOVERY.md).

use super::topology::Topology;
use crate::aggregate::{
    self, resume_cursor, Count, FlushSequencer, SeqDecision, ShardRouter, TopKGather, TopKSketch,
    WindowSnapshot, WindowedMerge, WindowedPartial,
};
use crate::coordinator::{ClusterView, Grouper};
use crate::metrics::{
    AggStats, Histogram, Imbalance, MemoryTracker, RecoveryStats, ShardAggStats, WindowStats,
};
use crate::obs::{
    chain_id, ClockDomain, Sample, Sampler, TraceBlob, TraceBuf, DEFAULT_INTERVAL_NS, NO_SEQ,
};
use crate::state::{snapshot_due, ShardSnapshot};
use crate::transport::wire::FlushMsg;
use crate::workload::Generator;
use crate::{Key, WorkerId};

/// Everything a simulation run produces.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Per-tuple queueing latency (virtual ns).
    pub latency: Histogram,
    /// Virtual time at which the last worker finished.
    pub makespan: u64,
    /// Tuples processed per worker id.
    pub worker_counts: Vec<u64>,
    /// Busy time per worker id (virtual ns).
    pub worker_busy: Vec<f64>,
    /// State-replication accounting.
    pub entries: usize,
    /// Distinct keys observed (FG-optimal entry count).
    pub distinct_keys: usize,
    /// Memory overhead normalised to FG.
    pub memory_normalized: f64,
    /// Control-plane entries tracked by the groupers (sketches, memos).
    pub control_entries: usize,
    /// Tuples simulated.
    pub tuples: usize,
    /// State entries that resided on workers removed by churn and thus
    /// had to migrate (Fig. 17 cost component).
    pub churn_migrations: usize,
    /// Stage-two output: exact merged per-key counts, ascending by key.
    /// Element-wise equal to a single-worker reference for every scheme
    /// and every shard count (the aggregation oracle).
    pub merged_counts: Vec<(Key, u64)>,
    /// Whole-fabric aggregation-traffic ledger (flushes, messages,
    /// bytes, merge time) — the totals across [`SimResult::shard_agg`].
    pub agg: AggStats,
    /// Per-shard ledgers + shard-imbalance summary (max/mean absorbed
    /// tuples across the `--agg_shards` merge shards).
    pub shard_agg: ShardAggStats,
    /// Flush staleness in **virtual** ns: at each worker flush, the age
    /// of the oldest delta it could be carrying (time since that
    /// worker's previous flush). The sim analogue of the runtime
    /// engine's wall-clock flush→merge latency — how far the merged
    /// view can trail the workers.
    pub agg_latency: Histogram,
    /// Scatter-gather top-k front-end: per-shard SpaceSaving summaries
    /// of the flush mass, queryable via [`TopKGather::top`] with an
    /// explicit rank-error bound.
    pub gather: TopKGather,
    /// Windowed aggregation output (`--agg_window_ms > 0`; empty when
    /// unwindowed): one [`WindowSnapshot`] per tumbling event-time pane,
    /// ascending — exact per-window counts (byte-identical across
    /// schemes, shard counts, flush cadences and engines) plus the
    /// per-window top-k gather. "Trending in the last N ms" is
    /// `windows.last().top_k(k)`; [`aggregate::sliding`] composes
    /// longer sliding windows from these panes.
    pub windows: Vec<WindowSnapshot>,
    /// Pane-lifecycle ledger (retirements, late reopens, open-pane
    /// memory peaks), folded across the merge shards; all zeros when
    /// unwindowed.
    pub window_stats: WindowStats,
    /// Exactly-once recovery ledger: scripted-fault restarts, replayed /
    /// deduplicated flush batches, replayed source tuples, snapshots
    /// serialized and restores performed. All zeros on a fault-free run
    /// ([`crate::metrics::RecoveryStats::any`] gates report rows).
    pub recovery: RecoveryStats,
    /// Virtual-time trace buffers ([`Simulator::with_trace`]; empty when
    /// tracing is off): the main-loop thread plus the merge fabric,
    /// renderable via [`crate::obs::chrome_trace_json`]. Byte-identical
    /// run-to-run — the trace itself is oracle-testable.
    pub trace_blobs: Vec<TraceBlob>,
    /// Per-epoch telemetry rows (same flag; empty when tracing is off).
    pub samples: Vec<Sample>,
}

/// One scripted crash in the simulated topology. Faults fire at
/// deterministic points in virtual time (a worker's Nth processed tuple,
/// a shard's Nth accepted flush batch), so chaos runs are exactly as
/// reproducible as fault-free ones.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPoint {
    /// Kill worker `worker` right after it has processed `at_tuple`
    /// tuples: its un-flushed windowed delta is lost with it, and the
    /// sources re-feed the unacked suffix observed since its last flush
    /// (at-least-once replay). Flushed panes are never re-sent — their
    /// lane seqs are already absorbed downstream — so the merge stays
    /// exactly-once.
    KillWorker {
        /// Victim worker slot.
        worker: usize,
        /// Fires once `worker` has processed this many tuples.
        at_tuple: u64,
    },
    /// Kill merge shard `shard` right after its current incarnation has
    /// accepted `at_flush` flush batches: live state is dropped, the
    /// last snapshot bytes (if any) are decoded through the real
    /// [`ShardSnapshot`] codec, and the workers replay their logged
    /// flushes from the shard's Resume cursors — the socket lanes'
    /// reconnect protocol, in virtual time.
    KillShard {
        /// Victim merge shard.
        shard: usize,
        /// Fires once the incarnation has accepted this many batches.
        at_flush: u64,
    },
}

impl SimResult {
    /// Load imbalance over worker busy-time.
    pub fn imbalance(&self) -> Imbalance {
        Imbalance::of(&self.worker_busy)
    }

    /// Mean latency in virtual ns.
    pub fn mean_latency(&self) -> f64 {
        self.latency.mean()
    }

    /// The `k` hottest keys by merged count, descending (exact).
    pub fn top_k(&self, k: usize) -> Vec<(Key, u64)> {
        aggregate::top_k(&self.merged_counts, k)
    }
}

/// Default routing batch size (see [`crate::config::Config::batch`]).
pub use crate::config::DEFAULT_BATCH;

/// One simulated merge shard: the windowed merge stage, this shard's
/// slice of the gather sketch, the flush sequencer, and the chaos
/// bookkeeping a kill needs (the union of the workers' replay logs for
/// this shard, and the last serialized snapshot).
struct SimShard {
    stage: WindowedMerge<Count>,
    sketch: TopKSketch,
    sequencer: FlushSequencer<FlushMsg>,
    /// Per-worker watermark high-water marks (mirrors the rt shard).
    worker_wm: Vec<u64>,
    /// Every message delivered to this incarnation, in delivery order.
    /// Only retained while a shard kill is armed — it stands in for the
    /// senders' replay logs, pre-split per shard.
    log: Vec<FlushMsg>,
    /// Flush batches accepted by this incarnation (fault triggers and
    /// snapshot cadence count these, not raw deliveries).
    accepted: u64,
    since_snapshot: u64,
    last_snapshot: Option<Vec<u8>>,
}

impl SimShard {
    fn new(window_ns: u64, lateness_ns: u64, n_slots: usize) -> Self {
        SimShard {
            stage: WindowedMerge::new(Count, window_ns, aggregate::DEFAULT_GATHER_CAPACITY)
                .with_lateness(lateness_ns),
            sketch: TopKSketch::new(aggregate::DEFAULT_GATHER_CAPACITY),
            sequencer: FlushSequencer::new(n_slots),
            worker_wm: vec![0; n_slots],
            log: Vec::new(),
            accepted: 0,
            since_snapshot: 0,
            last_snapshot: None,
        }
    }

    /// Absorb one sequencer-accepted flush batch into the merge stage
    /// and the gather sketch.
    fn absorb(&mut self, msg: FlushMsg) {
        if msg.watermark > self.worker_wm[msg.worker] {
            self.worker_wm[msg.worker] = msg.watermark;
        }
        for (win, entries) in msg.panes {
            for &(k, c) in &entries {
                self.sketch.absorb(k, c);
            }
            self.stage.absorb(win, entries);
        }
        self.accepted += 1;
        self.since_snapshot += 1;
    }
}

/// Stage-two state for one simulation run: per-shard windowed merge
/// stages + gather sketches behind one shard router (a pane of
/// `agg_window_ns`; 0 = one eternal pane = the unwindowed fabric), the
/// per-lane flush seqs and per-shard sequencers of the exactly-once
/// protocol, the staleness bookkeeping every flush site shares
/// (periodic, churn drain, end-of-stream drain), and the armed shard
/// kills.
struct StageTwo {
    router: ShardRouter,
    shards: Vec<SimShard>,
    /// `seqs[worker][shard]`: next flush seq on that lane. Incremented
    /// only when the shard actually receives a message, exactly like
    /// the rt engine, so the per-shard received stream is gap-free.
    seqs: Vec<Vec<u64>>,
    /// Virtual-ns staleness recorded at each worker flush.
    staleness: Histogram,
    /// Per-slot virtual time of the previous flush.
    last_flush: Vec<u64>,
    window_ns: u64,
    lateness_ns: u64,
    n_slots: usize,
    /// Serialize a shard snapshot every N accepted batches (0 = never).
    snapshot_every: u64,
    /// Armed [`FaultPoint::KillShard`]s as `(shard, at_flush)`.
    shard_faults: Vec<(usize, u64)>,
    /// Shard chaos armed at run start — gates replay-log retention.
    chaos: bool,
    recovery: RecoveryStats,
    /// Virtual-time trace of the merge fabric (pid 0, tid 1): flush
    /// sends, absorbs, dedups, pane lifecycle, snapshots, kills.
    trace: TraceBuf,
    /// Per-epoch telemetry, sampled at watermark advances.
    sampler: Sampler,
}

impl StageTwo {
    fn new(
        n_shards: usize,
        n_slots: usize,
        window_ns: u64,
        lateness_ns: u64,
        snapshot_every: u64,
        shard_faults: Vec<(usize, u64)>,
        observe: bool,
    ) -> Self {
        let chaos = !shard_faults.is_empty();
        StageTwo {
            router: ShardRouter::new(n_shards),
            shards: (0..n_shards).map(|_| SimShard::new(window_ns, lateness_ns, n_slots)).collect(),
            seqs: vec![vec![0; n_shards]; n_slots],
            staleness: Histogram::new(),
            last_flush: vec![0; n_slots],
            window_ns,
            lateness_ns,
            n_slots,
            snapshot_every,
            shard_faults,
            chaos,
            recovery: RecoveryStats::default(),
            trace: if observe {
                TraceBuf::active(0, 1, ClockDomain::Virtual)
            } else {
                TraceBuf::disabled()
            },
            sampler: if observe {
                Sampler::active(0, DEFAULT_INTERVAL_NS)
            } else {
                Sampler::disabled()
            },
        }
    }

    /// Flush worker `w`'s partial at virtual time `now` (no-op when the
    /// partial is empty): record the delta's staleness, split each
    /// pane's batch across the shards, and deliver one seq-stamped
    /// [`FlushMsg`] per shard that received any panes this round.
    fn flush(&mut self, w: usize, now: u64, partial: &mut WindowedPartial<Count>) {
        if partial.is_empty() {
            return;
        }
        self.staleness.record(now.saturating_sub(self.last_flush[w]));
        // one span per flush: the interval this delta accumulated over
        crate::obs::span!(self.trace, "flush", self.last_flush[w], now);
        self.last_flush[w] = now;
        let mut per_shard: Vec<Vec<(u64, Vec<(Key, u64)>)>> =
            (0..self.shards.len()).map(|_| Vec::new()).collect();
        for (win, batch) in partial.flush() {
            for (s, sub) in self.router.split(batch).into_iter().enumerate() {
                if !sub.is_empty() {
                    per_shard[s].push((win, sub));
                }
            }
        }
        for (s, panes) in per_shard.into_iter().enumerate() {
            if panes.is_empty() {
                continue;
            }
            let msg =
                FlushMsg { worker: w, seq: self.seqs[w][s], emit_ns: now, watermark: now, panes };
            if self.trace.is_active() {
                self.trace.instant_seq("flush_send", now, chain_id(w as u64, s as u64, msg.seq));
            }
            self.seqs[w][s] += 1;
            self.deliver(s, msg);
        }
    }

    /// Deliver one flush message to shard `s`: log it (while chaos is
    /// armed), sequence it, snapshot on cadence, then fire any scripted
    /// kill that has come due.
    fn deliver(&mut self, s: usize, msg: FlushMsg) {
        let now = msg.emit_ns;
        if self.chaos {
            self.shards[s].log.push(msg.clone());
        }
        self.offer(s, msg);
        if snapshot_due(self.shards[s].since_snapshot, self.snapshot_every) {
            self.snapshot(s, now);
        }
        if let Some(pos) = self
            .shard_faults
            .iter()
            .position(|&(fs, at)| fs == s && self.shards[s].accepted >= at)
        {
            self.shard_faults.swap_remove(pos);
            self.kill_shard(s, now);
        }
    }

    /// Run one message through shard `s`'s sequencer: absorb accepted
    /// batches (plus any parked successors they unblock), meter
    /// duplicates and reorders.
    fn offer(&mut self, s: usize, msg: FlushMsg) {
        let (worker, seq, emit) = (msg.worker, msg.seq, msg.emit_ns);
        match self.shards[s].sequencer.offer(worker, seq, msg) {
            SeqDecision::Accept(batch) => {
                for m in batch {
                    if self.trace.is_active() {
                        let cid = chain_id(m.worker as u64, s as u64, m.seq);
                        self.trace.instant_seq("merge_absorb", m.emit_ns, cid);
                    }
                    self.shards[s].absorb(m);
                }
            }
            SeqDecision::Replayed => {
                self.recovery.deduped_batches += 1;
                if self.trace.is_active() {
                    let cid = chain_id(worker as u64, s as u64, seq);
                    self.trace.instant_seq("flush_dedup", emit, cid);
                }
            }
            SeqDecision::Buffered => {
                self.recovery.buffered_batches += 1;
                if self.trace.is_active() {
                    let cid = chain_id(worker as u64, s as u64, seq);
                    self.trace.instant_seq("flush_buffered", emit, cid);
                }
            }
        }
    }

    /// Serialize shard `s` through the real [`ShardSnapshot`] codec —
    /// the exact bytes a deployed shard would persist — and retain them
    /// for the next kill.
    fn snapshot(&mut self, s: usize, now: u64) {
        let shard = &mut self.shards[s];
        shard.since_snapshot = 0;
        let snap = ShardSnapshot {
            shard: s as u64,
            expected_seq: shard.sequencer.expected_all().to_vec(),
            worker_wm: shard.worker_wm.clone(),
            merge: shard.stage.snapshot(),
            sketch_entries: super::rt::sketch_parts_sorted(&shard.sketch),
            sketch_error: shard.sketch.merged_error(),
            buffered: shard.sequencer.parked().into_iter().map(|(_, _, m)| m.clone()).collect(),
            latency: Histogram::new(),
            recovery: RecoveryStats::default(),
        };
        let bytes = snap.to_bytes();
        self.recovery.snapshots += 1;
        self.recovery.snapshot_bytes += bytes.len() as u64;
        if self.trace.is_active() {
            self.trace.instant_full("snapshot", now, NO_SEQ, bytes.len() as u64);
        }
        shard.last_snapshot = Some(bytes);
    }

    /// Scripted shard kill: drop the live incarnation, restore from the
    /// last snapshot bytes (none → cold start), then replay every logged
    /// message at or above the restored Resume cursors — exactly the
    /// socket lanes' reconnect protocol, in virtual time.
    fn kill_shard(&mut self, s: usize, now: u64) {
        self.recovery.shard_restarts += 1;
        if self.trace.is_active() {
            self.trace.instant_full("kill_shard", now, NO_SEQ, s as u64);
        }
        let log = std::mem::take(&mut self.shards[s].log);
        let snap_bytes = self.shards[s].last_snapshot.take();
        self.shards[s] = SimShard::new(self.window_ns, self.lateness_ns, self.n_slots);
        let mut resume = vec![0u64; self.n_slots];
        if let Some(bytes) = &snap_bytes {
            let snap = ShardSnapshot::from_bytes(bytes)
                .expect("in-memory snapshot bytes round-trip through the codec");
            self.recovery.restores += 1;
            if self.trace.is_active() {
                self.trace.instant_full("restore", now, NO_SEQ, s as u64);
            }
            resume = snap.expected_seq.clone();
            let shard = &mut self.shards[s];
            // parked-ahead batches from the snapshot re-enter through
            // the shared restore rule (the in-order sim never parks
            // any, but the restore path is protocol-complete and is
            // exactly what the recovery model explores)
            let (restored, replay_accepted) = FlushSequencer::restore_replaying(
                snap.expected_seq,
                snap.buffered.into_iter().map(|m| (m.worker, m.seq, m)),
            );
            shard.sequencer = restored;
            for (dst, src) in shard.worker_wm.iter_mut().zip(&snap.worker_wm) {
                *dst = *src;
            }
            shard.sketch = TopKSketch::from_parts(
                aggregate::DEFAULT_GATHER_CAPACITY,
                &snap.sketch_entries,
                snap.sketch_error,
            );
            shard.stage.restore(snap.merge);
            for m in replay_accepted {
                shard.absorb(m);
            }
        }
        self.shards[s].last_snapshot = snap_bytes;
        let mut replayed = 0u64;
        for msg in log {
            if msg.seq < resume_cursor(&resume, msg.worker) {
                // below the shard's Resume answer: the lane never re-sends
                continue;
            }
            self.recovery.replayed_batches += 1;
            replayed += 1;
            self.shards[s].log.push(msg.clone());
            self.offer(s, msg);
        }
        if replayed > 0 && self.trace.is_active() {
            self.trace.instant_full("replay_batches", now, NO_SEQ, replayed);
        }
    }

    /// Fold the per-shard pane-lifecycle ledgers (trace/sampling only —
    /// the report-facing fold happens in [`StageTwo::into_results`]).
    fn fold_stats(&self) -> WindowStats {
        let mut w = WindowStats::default();
        for shard in &self.shards {
            w.absorb(&shard.stage.window_stats());
        }
        w
    }

    /// Advance the fabric watermark to virtual time `now`, retiring
    /// closed panes. Exact in the simulator: every tuple arriving
    /// before `now` has been serviced and flushed by the time this is
    /// called, so no late deltas (and no pane reopens) are possible.
    /// `tuples` = tuples serviced so far, for the telemetry sampler.
    fn advance(&mut self, now: u64, tuples: u64) {
        let before = if self.trace.is_active() { Some(self.fold_stats()) } else { None };
        for shard in self.shards.iter_mut() {
            shard.stage.advance(now);
        }
        if let Some(before) = before {
            let after = self.fold_stats();
            let retired = after.panes_retired - before.panes_retired;
            if retired > 0 {
                self.trace.instant_full("pane_retire", now, NO_SEQ, retired);
            }
            let reopened = after.late_reopens - before.late_reopens;
            if reopened > 0 {
                self.trace.instant_full("pane_late_reopen", now, NO_SEQ, reopened);
            }
            let open: usize = self.shards.iter().map(|s| s.stage.open_panes()).sum();
            self.trace.count("open_panes", now, open as u64);
        }
        if self.sampler.due(now) {
            let sum: u64 = self.shards.iter().map(|s| s.accepted).sum();
            let max = self.shards.iter().map(|s| s.accepted).max().unwrap_or(0);
            let stats = self.fold_stats();
            self.sampler.record(Sample {
                ts_ns: now,
                tuples,
                open_panes: self.shards.iter().map(|s| s.stage.open_panes() as u64).sum(),
                open_entries: stats.max_open_entries,
                absorbed: sum,
                // integer max/mean ratio x1000 keeps the row deterministic
                imbalance_x1000: if sum > 0 {
                    max * 1000 * self.shards.len() as u64 / sum
                } else {
                    0
                },
                replay_backlog: self.shards.iter().map(|s| s.log.len() as u64).sum(),
                ..Sample::default()
            });
        }
    }

    /// Finish: all-time merged counts, per-shard ledgers, assembled
    /// window snapshots (empty when unwindowed), the folded
    /// pane-lifecycle stats, and the shard-side recovery ledger.
    #[allow(clippy::type_complexity)]
    fn into_results(
        self,
    ) -> (
        Vec<(Key, u64)>,
        ShardAggStats,
        Vec<WindowSnapshot>,
        WindowStats,
        TopKGather,
        Histogram,
        RecoveryStats,
        TraceBuf,
        Sampler,
    ) {
        let StageTwo { shards, staleness, window_ns, recovery, trace, sampler, .. } = self;
        let n_shards = shards.len();
        let mut merged_counts: Vec<(Key, u64)> = Vec::new();
        let mut per_shard = Vec::with_capacity(n_shards);
        let mut per_shard_windows = Vec::with_capacity(n_shards);
        let mut sketches = Vec::with_capacity(n_shards);
        let mut window_stats = WindowStats::default();
        for shard in shards {
            let SimShard { stage, sketch, .. } = shard;
            let out = stage.finish();
            merged_counts.extend(out.all_time);
            per_shard.push(out.stats);
            window_stats.absorb(&out.window_stats);
            per_shard_windows.push(out.windows);
            sketches.push(sketch);
        }
        // shards partition the key space: concat + sort reproduces the
        // single-aggregator ordering byte for byte
        merged_counts.sort_unstable_by_key(|&(k, _)| k);
        let windows = if window_ns > 0 {
            aggregate::assemble_windows(
                window_ns,
                n_shards,
                aggregate::DEFAULT_GATHER_CAPACITY,
                per_shard_windows,
            )
        } else {
            window_stats = WindowStats::default();
            Vec::new()
        };
        let gather = TopKGather::from_shards(sketches);
        (
            merged_counts,
            ShardAggStats { per_shard },
            windows,
            window_stats,
            gather,
            staleness,
            recovery,
            trace,
            sampler,
        )
    }
}

/// The simulator: drives one workload through one scheme, draining
/// tuples in micro-batches through [`Grouper::route_batch`].
pub struct Simulator {
    topology: Topology,
    sources: Vec<Box<dyn Grouper>>,
    interarrival_ns: u64,
    batch: usize,
    /// Partial-flush interval in virtual ns; 0 = flush only at end.
    agg_flush_ns: u64,
    /// Stage-two merge shards (1 = single aggregator).
    agg_shards: usize,
    /// Tumbling-pane length in virtual ns; 0 = unwindowed.
    agg_window_ns: u64,
    /// Watermark slack before pane retirement (virtual ns). Sim
    /// watermarks are exact, so this only delays retirement — it can
    /// never create or absorb late deltas here — but keeping the knob
    /// engine-uniform lets one config drive both engines.
    agg_lateness_ns: u64,
    /// Scripted crashes; empty = fault-free.
    faults: Vec<FaultPoint>,
    /// Shard-snapshot cadence in accepted batches (0 = never snapshot;
    /// a kill then recovers by full log replay).
    snapshot_every: u64,
    /// Record virtual-time traces + telemetry samples into
    /// [`SimResult::trace_blobs`] / [`SimResult::samples`].
    trace: bool,
}

impl Simulator {
    /// `sources` — one grouper per source (they route independently,
    /// exactly like Storm tasks). Routes in batches of [`DEFAULT_BATCH`]
    /// tuples; override with [`Simulator::with_batch`]. Partial
    /// aggregates flush every [`crate::config::DEFAULT_AGG_FLUSH_MS`]
    /// of virtual time; override with [`Simulator::with_agg_flush`].
    pub fn new(topology: Topology, sources: Vec<Box<dyn Grouper>>, interarrival_ns: u64) -> Self {
        assert!(!sources.is_empty());
        Simulator {
            topology,
            sources,
            interarrival_ns,
            batch: DEFAULT_BATCH,
            agg_flush_ns: crate::config::DEFAULT_AGG_FLUSH_MS * 1_000_000,
            agg_shards: 1,
            agg_window_ns: 0,
            agg_lateness_ns: 0,
            faults: Vec::new(),
            snapshot_every: 0,
            trace: false,
        }
    }

    /// Set the routing batch size (tuples per `route_batch` call).
    pub fn with_batch(mut self, batch: usize) -> Self {
        assert!(batch > 0, "batch must be > 0");
        self.batch = batch;
        self
    }

    /// Set the partial-flush interval in virtual ns (0 = only the final
    /// end-of-stream drain). Flush cadence never changes the merged
    /// counts — only the traffic pattern charged to [`SimResult::agg`].
    pub fn with_agg_flush(mut self, ns: u64) -> Self {
        self.agg_flush_ns = ns;
        self
    }

    /// Set the stage-two shard count (1 = single aggregator). Shard
    /// count never changes the merged counts — only parallelism and the
    /// per-shard ledgers in [`SimResult::shard_agg`].
    pub fn with_agg_shards(mut self, n: usize) -> Self {
        assert!(n > 0, "agg_shards must be > 0");
        self.agg_shards = n;
        self
    }

    /// Set the tumbling-pane length in virtual ns (0 = unwindowed).
    /// Tuples are assigned to panes by arrival time, so per-window
    /// merged counts in [`SimResult::windows`] are invariant under
    /// flush cadence, shard count and grouping scheme.
    pub fn with_agg_window(mut self, ns: u64) -> Self {
        self.agg_window_ns = ns;
        self
    }

    /// Set the watermark slack (virtual ns) panes stay open past their
    /// end before retiring (`--agg_lateness_ms`; 0 = retire exactly at
    /// the pane end).
    pub fn with_agg_lateness(mut self, ns: u64) -> Self {
        self.agg_lateness_ns = ns;
        self
    }

    /// Arm scripted crashes (the in-process fault-point registry). Each
    /// fault fires exactly once at its deterministic trigger; the run's
    /// recovery work lands in [`SimResult::recovery`] and the outputs
    /// must still match a fault-free run byte for byte.
    pub fn with_faults(mut self, faults: Vec<FaultPoint>) -> Self {
        self.faults = faults;
        self
    }

    /// Snapshot each merge shard every `every` accepted flush batches
    /// through the real [`ShardSnapshot`] codec (0 = never; shard kills
    /// then recover by replaying the whole flush log).
    pub fn with_snapshot_every(mut self, every: u64) -> Self {
        self.snapshot_every = every;
        self
    }

    /// Record virtual-time traces and telemetry samples (`--trace-out` /
    /// `--metrics-out`). Off by default; tracing never changes any other
    /// output, and the trace itself is byte-identical run-to-run.
    pub fn with_trace(mut self, on: bool) -> Self {
        self.trace = on;
        self
    }

    /// Run `gen` to completion.
    ///
    /// Tuples are drained in batches: each batch shares one
    /// [`ClusterView`] (stamped at the batch-head arrival), is split
    /// round-robin across the sources exactly like the per-tuple engine,
    /// routed via [`Grouper::route_batch`], and then serviced in arrival
    /// order so the queueing model is unchanged. Batches never span a
    /// scripted churn event, so membership changes keep per-tuple
    /// precision.
    pub fn run(&mut self, gen: &mut (dyn Generator + Send)) -> SimResult {
        let n = gen.len();
        let n_slots = self.topology.n_slots();
        let mut done: Vec<u64> = vec![0; n_slots]; // worker available-at
        let mut counts: Vec<u64> = vec![0; n_slots];
        let mut busy: Vec<f64> = vec![0.0; n_slots];
        let mut latency = Histogram::new();
        let mut memory = MemoryTracker::new();
        let mut churn_migrations = 0usize;
        let n_sources = self.sources.len();

        // scripted faults, split by stage: worker kills fire in the
        // service loop, shard kills inside the merge fabric
        let mut worker_faults: Vec<(usize, u64)> = Vec::new();
        let mut shard_faults: Vec<(usize, u64)> = Vec::new();
        for f in &self.faults {
            match *f {
                FaultPoint::KillWorker { worker, at_tuple } => {
                    worker_faults.push((worker, at_tuple))
                }
                FaultPoint::KillShard { shard, at_flush } => shard_faults.push((shard, at_flush)),
            }
        }
        // source-side replay buffers: each worker's observed tuples since
        // its last flush (the unacked suffix a respawn would be re-fed).
        // Only tracked while a worker kill is armed — fault-free runs pay
        // nothing.
        let track_replay = !worker_faults.is_empty();
        let mut since_flush: Vec<Vec<(Key, u64)>> = (0..n_slots).map(|_| Vec::new()).collect();
        let mut worker_recovery = RecoveryStats::default();

        // stage two: per-worker (windowed) partial aggregates + the
        // windowed merge-shard fabric
        let mut partials: Vec<WindowedPartial<Count>> =
            (0..n_slots).map(|_| WindowedPartial::new(Count, self.agg_window_ns)).collect();
        let mut stage2 = StageTwo::new(
            self.agg_shards,
            n_slots,
            self.agg_window_ns,
            self.agg_lateness_ns,
            self.snapshot_every,
            shard_faults,
            self.trace,
        );
        let mut next_flush = self.agg_flush_ns;
        // main-loop trace (pid 0, tid 0): routing, service, source-side
        // recovery; the merge fabric records on its own tid-1 buffer
        let mut trace = if self.trace {
            TraceBuf::active(0, 0, ClockDomain::Virtual)
        } else {
            TraceBuf::disabled()
        };

        let mut keys: Vec<crate::Key> = Vec::with_capacity(self.batch);
        let mut assigned: Vec<WorkerId> = vec![0; self.batch];
        let mut src_keys: Vec<crate::Key> = Vec::with_capacity(self.batch);
        let mut src_out: Vec<WorkerId> = vec![0; self.batch];

        let mut start = 0usize;
        while start < n {
            // scripted churn (paper §6.5) due at the batch head
            if self.topology.pending_churn() > 0 && self.topology.apply_churn(start) {
                let view = ClusterView {
                    now: start as u64 * self.interarrival_ns,
                    workers: self.topology.workers(),
                    per_tuple_time: self.topology.per_tuple_time(),
                    n_slots: self.topology.n_slots(),
                };
                for s in self.sources.iter_mut() {
                    s.on_membership_change(&view);
                }
                // entries stranded on now-dead workers must migrate
                let alive: std::collections::HashSet<WorkerId> =
                    self.topology.workers().iter().copied().collect();
                churn_migrations += memory.entries_on(|w| !alive.contains(&w));
                // a decommissioned worker drains its partial aggregate
                // downstream before it disappears — no counts are lost
                for (w, p) in partials.iter_mut().enumerate() {
                    if !alive.contains(&w) {
                        stage2.flush(w, view.now, p);
                        since_flush[w].clear();
                    }
                }
            }

            // batch extent: full batch, capped at the next churn event
            let mut end = (start + self.batch).min(n);
            if let Some(c) = self.topology.next_churn_at() {
                debug_assert!(c > start, "due churn must have been applied");
                end = end.min(c);
            }

            keys.clear();
            for i in start..end {
                keys.push(gen.key_at(i));
            }

            let view = ClusterView {
                now: start as u64 * self.interarrival_ns,
                workers: self.topology.workers(),
                per_tuple_time: self.topology.per_tuple_time(),
                n_slots,
            };

            // route per source over its round-robin share (tuple i goes
            // to source i % n_sources, exactly like the per-tuple engine)
            for s in 0..n_sources {
                let first = start + (s + n_sources - start % n_sources) % n_sources;
                if first >= end {
                    continue;
                }
                src_keys.clear();
                let mut i = first;
                while i < end {
                    src_keys.push(keys[i - start]);
                    i += n_sources;
                }
                let m = src_keys.len();
                self.sources[s].route_batch(&src_keys, &mut src_out[..m], &view);
                for (j, &w) in src_out[..m].iter().enumerate() {
                    assigned[first + j * n_sources - start] = w;
                }
            }
            if trace.is_active() {
                trace.instant_full("route_batch", view.now, NO_SEQ, (end - start) as u64);
            }

            // service in arrival order: the queueing model is untouched
            for i in start..end {
                let w = assigned[i - start];
                debug_assert!(self.topology.workers().contains(&w), "routed to dead worker {w}");
                let arrival = i as u64 * self.interarrival_ns;
                let p = self.topology.per_tuple_time()[w];
                let begin = done[w].max(arrival);
                let finish = begin + p as u64;
                latency.record(finish - arrival);
                done[w] = finish;
                counts[w] += 1;
                busy[w] += p;
                memory.touch(keys[i - start], w);
                // panes are assigned by *arrival* (event) time — worker
                // choice and queueing delay never move a tuple's window
                partials[w].observe(keys[i - start], 1, arrival);
                if track_replay {
                    since_flush[w].push((keys[i - start], arrival));
                    if let Some(pos) =
                        worker_faults.iter().position(|&(fw, at)| fw == w && counts[w] >= at)
                    {
                        // scripted worker kill: the un-flushed delta dies
                        // with the worker, the source re-feeds the unacked
                        // suffix, and the respawn rebuilds the identical
                        // partial — flushed panes are never re-sent (their
                        // lane seqs are already absorbed downstream)
                        worker_faults.swap_remove(pos);
                        worker_recovery.worker_restarts += 1;
                        worker_recovery.replayed_tuples += since_flush[w].len() as u64;
                        if trace.is_active() {
                            trace.instant_full("kill_worker", arrival, NO_SEQ, w as u64);
                            let n_replay = since_flush[w].len() as u64;
                            trace.instant_full("replay_tuples", arrival, NO_SEQ, n_replay);
                        }
                        let buf = std::mem::take(&mut since_flush[w]);
                        partials[w] = WindowedPartial::new(Count, self.agg_window_ns);
                        for &(k, t) in &buf {
                            partials[w].observe(k, 1, t);
                        }
                        since_flush[w] = buf;
                    }
                }
            }
            if trace.is_active() {
                // service of this batch, spanning its arrival interval
                let last = (end - 1) as u64 * self.interarrival_ns;
                trace.span_full("worker_absorb", view.now, last, NO_SEQ, (end - start) as u64);
            }

            // periodic partial flush when virtual time crosses a flush
            // boundary (checked at batch granularity, like the routing
            // views — the merged result is cadence-invariant)
            if self.agg_flush_ns > 0 {
                let now = end as u64 * self.interarrival_ns;
                if now >= next_flush {
                    for (w, p) in partials.iter_mut().enumerate() {
                        stage2.flush(w, now, p);
                        since_flush[w].clear();
                    }
                    // every arrival before `now` is now flushed, so the
                    // watermark is exact: closed panes retire here
                    stage2.advance(now, end as u64);
                    next_flush = aggregate::next_boundary(now, self.agg_flush_ns);
                }
            }

            start = end;
        }

        // end-of-stream drain: every remaining partial reaches the merge
        let end_of_stream = n as u64 * self.interarrival_ns;
        if trace.is_active() {
            trace.instant_full("end_of_stream_drain", end_of_stream, NO_SEQ, n as u64);
        }
        for (w, p) in partials.iter_mut().enumerate() {
            stage2.flush(w, end_of_stream, p);
        }
        let (
            merged_counts,
            shard_agg,
            windows,
            window_stats,
            gather,
            staleness,
            mut recovery,
            s2_trace,
            sampler,
        ) = stage2.into_results();
        recovery.absorb(&worker_recovery);
        if trace.is_active() {
            trace.instant_full("gather", end_of_stream, NO_SEQ, self.agg_shards as u64);
        }
        let mut trace_blobs = Vec::new();
        if trace.is_active() {
            trace_blobs.push(trace.to_blob());
        }
        if s2_trace.is_active() {
            trace_blobs.push(s2_trace.to_blob());
        }
        let samples = sampler.into_samples();

        let makespan = done.iter().copied().max().unwrap_or(0);
        SimResult {
            latency,
            makespan,
            worker_counts: counts,
            worker_busy: busy,
            entries: memory.entries(),
            distinct_keys: memory.distinct_keys(),
            memory_normalized: memory.normalized(),
            control_entries: self.sources.iter().map(|s| s.tracked_entries()).sum(),
            tuples: n,
            churn_migrations,
            merged_counts,
            agg: shard_agg.total(),
            shard_agg,
            agg_latency: staleness,
            gather,
            windows,
            window_stats,
            recovery,
            trace_blobs,
            samples,
        }
    }
}

/// Convenience: run one (scheme, workload) pair from a
/// [`crate::config::Config`] through the [`crate::engine::Pipeline`]
/// builder.
pub fn run_config(cfg: &crate::config::Config) -> SimResult {
    crate::engine::Pipeline::builder()
        .config(cfg.clone())
        .build_sim()
        .run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::SchemeKind;

    fn run(kind: SchemeKind, workers: usize, tuples: usize, z: f64) -> SimResult {
        let mut cfg = Config::default();
        cfg.scheme = kind;
        cfg.workers = workers;
        cfg.tuples = tuples;
        cfg.zipf_z = z;
        cfg.sources = 2;
        // arrival rate ≈ service rate × workers: contention without overload
        cfg.service_ns = 1_000;
        cfg.interarrival_ns = 1_000 / workers as u64 + 20;
        run_config(&cfg)
    }

    #[test]
    fn sg_balances_fg_does_not_on_skew() {
        let sg = run(SchemeKind::Shuffle, 16, 60_000, 1.8);
        let fg = run(SchemeKind::Field, 16, 60_000, 1.8);
        assert!(sg.imbalance().relative < 0.05, "SG imbalance {}", sg.imbalance().relative);
        assert!(
            fg.imbalance().relative > 1.0,
            "FG should be badly imbalanced, got {}",
            fg.imbalance().relative
        );
        assert!(fg.makespan > sg.makespan);
    }

    #[test]
    fn fg_is_memory_optimal_sg_is_not() {
        let sg = run(SchemeKind::Shuffle, 16, 100_000, 1.6);
        let fg = run(SchemeKind::Field, 16, 100_000, 1.6);
        assert!((fg.memory_normalized - 1.0).abs() < 1e-9);
        // bounded below by the repeated-key mass; singletons keep the
        // normalised value well under the 16x worst case at this scale.
        assert!(sg.memory_normalized > 2.5, "SG normalized {}", sg.memory_normalized);
    }

    #[test]
    fn fish_close_to_sg_latency_and_fg_memory() {
        // The paper's headline: FISH ≈ SG execution time at ≈ FG memory.
        let sg = run(SchemeKind::Shuffle, 16, 80_000, 1.6);
        let fg = run(SchemeKind::Field, 16, 80_000, 1.6);
        let fish = run(SchemeKind::Fish, 16, 80_000, 1.6);
        let exec_ratio = fish.makespan as f64 / sg.makespan as f64;
        assert!(exec_ratio < 1.6, "FISH/SG makespan {exec_ratio}");
        assert!(fish.makespan < fg.makespan, "FISH should beat FG");
        // compare replication *overhead above FG-optimal* (mem − 1):
        // FISH must stay within a third of SG's overhead.
        let fish_over = fish.memory_normalized - 1.0;
        let sg_over = sg.memory_normalized - 1.0;
        assert!(
            fish_over < sg_over / 3.0,
            "FISH overhead {fish_over} vs SG {sg_over}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(SchemeKind::Fish, 8, 20_000, 1.4);
        let b = run(SchemeKind::Fish, 8, 20_000, 1.4);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.worker_counts, b.worker_counts);
        assert_eq!(a.entries, b.entries);
        assert_eq!(a.merged_counts, b.merged_counts);
        assert_eq!(a.agg.flushes, b.agg.flushes);
        assert_eq!(a.agg.messages, b.agg.messages);
    }

    #[test]
    fn merged_counts_reassemble_the_exact_stream_histogram() {
        // The two-stage topology's whole point: whatever a scheme did to
        // split keys across workers, the merge stage reassembles the
        // exact per-key stream counts.
        for kind in SchemeKind::all() {
            let r = run(kind, 8, 15_000, 1.5);
            let mut truth: std::collections::HashMap<crate::Key, u64> =
                std::collections::HashMap::new();
            let mut gen = crate::workload::by_name("zf", 15_000, 1.5, Config::default().seed);
            for i in 0..15_000 {
                *truth.entry(gen.key_at(i)).or_insert(0) += 1;
            }
            assert_eq!(r.merged_counts.len(), truth.len(), "{kind}");
            for &(k, c) in &r.merged_counts {
                assert_eq!(c, truth[&k], "{kind} key {k}");
            }
            assert_eq!(r.merged_counts.iter().map(|&(_, c)| c).sum::<u64>(), 15_000, "{kind}");
            assert!(r.agg.flushes > 0, "{kind}");
            assert_eq!(r.agg.messages as usize, r.agg.bytes as usize / 16, "{kind}");
        }
    }

    #[test]
    fn flush_cadence_changes_traffic_not_results() {
        let run_with = |flush_ms: u64| {
            let mut cfg = Config::default();
            cfg.scheme = SchemeKind::Pkg;
            cfg.workers = 8;
            cfg.tuples = 30_000;
            cfg.sources = 2;
            cfg.interarrival_ns = 150;
            cfg.agg_flush_ms = flush_ms;
            run_config(&cfg)
        };
        let eager = run_with(1);
        let lazy = run_with(0); // end-of-stream drain only
        assert_eq!(eager.merged_counts, lazy.merged_counts);
        assert!(
            eager.agg.flushes > lazy.agg.flushes,
            "eager {} vs lazy {}",
            eager.agg.flushes,
            lazy.agg.flushes
        );
        // lazy ships each worker's state exactly once
        assert!(lazy.agg.flushes <= 8);
        assert_eq!(eager.top_k(3).len(), 3);
    }

    #[test]
    fn sharded_stage_two_matches_single_aggregator() {
        let run_with = |shards: usize| {
            let mut cfg = Config::default();
            cfg.scheme = SchemeKind::Fish;
            cfg.workers = 8;
            cfg.tuples = 20_000;
            cfg.sources = 2;
            cfg.interarrival_ns = 150;
            cfg.agg_shards = shards;
            run_config(&cfg)
        };
        let single = run_with(1);
        let sharded = run_with(4);
        // the fabric never changes the answer, only who merged what
        assert_eq!(single.merged_counts, sharded.merged_counts);
        assert_eq!(single.agg.messages, sharded.agg.messages);
        assert_eq!(single.agg.bytes, sharded.agg.bytes);
        assert_eq!(single.shard_agg.n_shards(), 1);
        assert_eq!(sharded.shard_agg.n_shards(), 4);
        assert_eq!(single.shard_agg.imbalance().relative, 0.0);
        assert_eq!(
            sharded.shard_agg.per_shard.iter().map(|s| s.messages).sum::<u64>(),
            sharded.agg.messages
        );
        // every flush recorded a virtual staleness sample
        assert!(sharded.agg_latency.count() > 0);
        // the gather tracked the flush mass on both topologies
        assert_eq!(single.gather.top(5).top[0].0, sharded.gather.top(5).top[0].0);
    }

    #[test]
    fn windowed_panes_partition_the_stream_and_rebuild_the_totals() {
        let mut cfg = Config::default();
        cfg.scheme = SchemeKind::Fish;
        cfg.workers = 8;
        cfg.tuples = 30_000;
        cfg.sources = 2;
        cfg.interarrival_ns = 500; // 15ms of virtual time
        cfg.agg_window_ms = 2; // → ~8 panes
        let r = run_config(&cfg);
        assert!(!r.windows.is_empty());
        assert_eq!(r.windows.len(), 8, "ceil(15ms / 2ms)");
        // panes partition the stream exactly…
        assert_eq!(r.windows.iter().map(|w| w.total()).sum::<u64>(), 30_000);
        // …and sum back to the all-time merged counts
        let mut rebuilt: std::collections::HashMap<crate::Key, u64> =
            std::collections::HashMap::new();
        for w in &r.windows {
            for &(k, c) in &w.counts {
                *rebuilt.entry(k).or_insert(0) += c;
            }
        }
        for &(k, c) in &r.merged_counts {
            assert_eq!(rebuilt.get(&k), Some(&c), "key {k}");
        }
        // each pane covers exactly 2ms of virtual time, 4000 arrivals
        for (i, w) in r.windows.iter().enumerate() {
            assert_eq!(w.window, i as u64);
            assert_eq!(w.end_ns() - w.start_ns(), 2_000_000);
            if w.end_ns() <= 15_000_000 {
                assert_eq!(w.total(), 4_000, "pane {i}");
            }
        }
        // panes were retired by watermark advance, not only at the drain
        assert!(r.window_stats.panes_retired >= r.windows.len() as u64);
        assert!(r.window_stats.max_open_panes >= 1);
        assert_eq!(r.window_stats.late_reopens, 0, "sim watermarks are exact");
    }

    #[test]
    fn unwindowed_run_reports_no_windows() {
        let r = run(SchemeKind::Fish, 8, 10_000, 1.5);
        assert!(r.windows.is_empty());
        assert_eq!(r.window_stats.panes_retired, 0);
        assert_eq!(r.window_stats.max_open_entries, 0);
    }

    #[test]
    fn all_schemes_route_every_tuple() {
        for kind in SchemeKind::all() {
            let r = run(kind, 8, 10_000, 1.5);
            assert_eq!(r.worker_counts.iter().sum::<u64>(), 10_000, "{kind}");
            assert_eq!(r.tuples, 10_000);
            assert!(r.makespan > 0);
        }
    }

    #[test]
    fn batch_size_does_not_change_results() {
        // View-independent schemes advance their routing state key by
        // key, so any batch size must produce identical simulations.
        for kind in [SchemeKind::Shuffle, SchemeKind::Pkg, SchemeKind::DChoices] {
            let mut cfg = Config::default();
            cfg.scheme = kind;
            cfg.workers = 8;
            cfg.tuples = 20_000;
            cfg.sources = 3;
            cfg.interarrival_ns = 150;
            let run_with = |batch: usize| {
                let topology = Topology::from_config(&cfg);
                let sources: Vec<Box<dyn Grouper>> = (0..cfg.sources)
                    .map(|s| crate::coordinator::make_scheme(&cfg, s))
                    .collect();
                let mut sim =
                    Simulator::new(topology, sources, cfg.interarrival_ns).with_batch(batch);
                let mut gen = crate::workload::by_name("zf", cfg.tuples, 1.5, cfg.seed);
                sim.run(gen.as_mut())
            };
            let a = run_with(1);
            let b = run_with(1024);
            assert_eq!(a.worker_counts, b.worker_counts, "{kind}");
            assert_eq!(a.makespan, b.makespan, "{kind}");
            assert_eq!(a.entries, b.entries, "{kind}");
        }
    }

    /// One windowed chaos-capable run: PKG over 8 workers, 3 merge
    /// shards, 2ms panes over 15ms of virtual time.
    fn chaos_run(faults: Vec<FaultPoint>, snapshot_every: u64) -> SimResult {
        let mut cfg = Config::default();
        cfg.scheme = SchemeKind::Pkg;
        cfg.workers = 8;
        cfg.tuples = 30_000;
        cfg.sources = 2;
        cfg.interarrival_ns = 500;
        let topology = Topology::from_config(&cfg);
        let sources: Vec<Box<dyn Grouper>> = (0..cfg.sources)
            .map(|s| crate::coordinator::make_scheme(&cfg, s))
            .collect();
        let mut sim = Simulator::new(topology, sources, cfg.interarrival_ns)
            .with_agg_shards(3)
            .with_agg_window(2_000_000)
            .with_faults(faults)
            .with_snapshot_every(snapshot_every);
        let mut gen = crate::workload::by_name("zf", cfg.tuples, 1.5, cfg.seed);
        sim.run(gen.as_mut())
    }

    #[test]
    fn fault_free_run_reports_zero_recovery() {
        let r = chaos_run(Vec::new(), 0);
        assert!(!r.recovery.any());
        assert_eq!(r.recovery.snapshots, 0);
    }

    #[test]
    fn scripted_kills_converge_byte_identically() {
        let clean = chaos_run(Vec::new(), 0);
        assert!(!clean.recovery.any());
        let chaos = chaos_run(
            vec![
                FaultPoint::KillWorker { worker: 2, at_tuple: 1_000 },
                // shard 1 dies before its first snapshot (cold restart,
                // full log replay); shard 0 dies after one (snapshot
                // restore + suffix replay)
                FaultPoint::KillShard { shard: 1, at_flush: 3 },
                FaultPoint::KillShard { shard: 0, at_flush: 5 },
            ],
            4,
        );
        // the exactly-once oracle: crashes moved work around, never
        // results — every output is byte-identical to the clean run
        assert_eq!(chaos.merged_counts, clean.merged_counts);
        assert_eq!(chaos.top_k(10), clean.top_k(10));
        assert_eq!(chaos.windows.len(), clean.windows.len());
        for (c, r) in chaos.windows.iter().zip(&clean.windows) {
            assert_eq!(c.window, r.window);
            assert_eq!(c.counts, r.counts, "window {}", r.window);
        }
        assert_eq!(chaos.window_stats.panes_retired, clean.window_stats.panes_retired);
        // the traffic ledger is exactly-once too: replayed batches land
        // in restored-from-snapshot or fresh stages, never double-count
        assert_eq!(chaos.agg.messages, clean.agg.messages);
        assert_eq!(chaos.agg.bytes, clean.agg.bytes);
        assert_eq!(chaos.worker_counts, clean.worker_counts);
        assert_eq!(chaos.makespan, clean.makespan);
        // …and the recovery ledger shows the crashes actually happened
        assert_eq!(chaos.recovery.worker_restarts, 1);
        assert_eq!(chaos.recovery.shard_restarts, 2);
        assert!(chaos.recovery.replayed_tuples > 0, "worker kill re-fed its suffix");
        assert!(chaos.recovery.replayed_batches > 0, "shard kills replayed the logs");
        assert!(chaos.recovery.snapshots > 0, "cadence-4 snapshots fired");
        assert!(chaos.recovery.restores >= 1, "at least one warm restore");
    }

    #[test]
    fn churn_mid_stream_keeps_invariants() {
        use crate::engine::topology::ChurnEvent;
        let mut cfg = Config::default();
        cfg.scheme = SchemeKind::Fish;
        cfg.workers = 8;
        cfg.tuples = 30_000;
        cfg.sources = 2;
        cfg.interarrival_ns = 150;
        let topology = Topology::from_config(&cfg).with_churn(
            vec![(10_000, ChurnEvent::Remove(3)), (20_000, ChurnEvent::Add(8))],
            cfg.service_ns as f64,
        );
        let sources: Vec<Box<dyn Grouper>> = (0..cfg.sources)
            .map(|s| crate::coordinator::make_scheme(&cfg, s))
            .collect();
        let mut sim = Simulator::new(topology, sources, cfg.interarrival_ns);
        let mut gen = crate::workload::by_name("zf", cfg.tuples, 1.5, cfg.seed);
        let r = sim.run(gen.as_mut());
        assert_eq!(r.worker_counts.iter().sum::<u64>(), 30_000);
        // worker 8 only exists after tuple 20k; worker 3 stops at 10k
        assert!(r.worker_counts[8] > 0);
        // the removed worker's partial was drained, not lost: the merge
        // still accounts for every tuple
        assert_eq!(r.merged_counts.iter().map(|&(_, c)| c).sum::<u64>(), 30_000);
    }
}
