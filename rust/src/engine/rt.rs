//! The "practical deployment" engine — our Apache-Storm stand-in
//! (paper §6.6, Figs. 18–20), running batch-first.
//!
//! Real threads, real queues, real clocks:
//!
//! * one thread per **source**: pulls its round-robin share of the
//!   trace, accumulates up to [`RtOptions::batch`] tuples, routes them
//!   in one [`Grouper::route_batch`] call, and ships one `Vec<Msg>`
//!   chunk per destination worker down that worker's **tuple lane**
//!   (blocking, credit-gated send = backpressure, exactly like Storm's
//!   max.spout.pending). Chunked sends amortise the per-tuple
//!   synchronisation that dominated the old per-tuple path.
//! * one thread per **worker**: drains chunks, updates its word-count
//!   state (a real per-key `HashMap` — its final size *is* the
//!   memory-overhead metric), optionally burns `P_w` of CPU per tuple
//!   to model operator cost / heterogeneity, and records the
//!   end-to-end latency (source-emit → processing-complete) in a local
//!   histogram. Each worker also keeps a delta [`WindowedPartial`]
//!   (per-pane when `--agg_window_ms > 0`, a single eternal pane
//!   otherwise) and scatters it across the aggregator shards every
//!   [`RtOptions::agg_flush_ns`] — on the boundary-snapped grid shared
//!   with the simulator — plus a final drain at shutdown.
//! * one **aggregator thread per merge shard** ([`RtOptions::agg_shards`];
//!   1 = the classic single aggregator): the topology's second stage as
//!   a fabric. Workers scatter each flush batch by key range
//!   ([`crate::aggregate::ShardRouter`]) and ship the per-shard
//!   sub-batches over dedicated worker→shard flush lanes; each shard
//!   absorbs into its own [`WindowedMerge`] (per-pane merge stages,
//!   metering flush traffic, payload bytes, merge time, and
//!   flush→merge latency) and keeps a [`TopKSketch`] of its flush mass
//!   for the scatter-gather top-k front-end
//!   ([`crate::aggregate::TopKGather`]). Windowed, flush messages
//!   carry per-worker event-time watermarks (workers poll with a
//!   timeout so watermark-only flushes flow even when their data
//!   lane is quiet) and shards retire closed panes when the min
//!   across progress-reporting workers passes a pane's end plus the
//!   `--agg_lateness_ms` slack — a heuristic whose misfires take the
//!   late-reopen path and re-merge exactly.
//!
//! Both data paths are written against the [`crate::transport`] lane
//! traits, so the same topology runs over in-process loopback lanes
//! (the default — byte-identical to the pre-transport engine), UDS or
//! TCP streams ([`RtOptions::transport`]), or across process
//! boundaries (`deploy --processes N`, [`crate::transport::launch`],
//! which reuses [`worker_loop`] / [`shard_loop`] / [`source_loop`]
//! verbatim in the child processes). Merged counts, per-window
//! snapshots and exact top-k are transport-invariant; socket lanes
//! additionally meter frames, bytes and serialization time into
//! [`RtResult::wire`].
//!
//! No source↔worker communication happens besides the data lanes —
//! FISH's worker-state inference gets no hidden help.

use crate::aggregate::{
    self, Count, FlushSequencer, SeqDecision, ShardRouter, TopKGather, TopKSketch, WindowSnapshot,
    WindowedMerge, WindowedOutput, WindowedPartial,
};
use crate::coordinator::{ClusterView, Grouper};
use crate::metrics::{
    AggStats, Histogram, RecoveryLedger, RecoveryStats, ShardAggStats, WindowStats, WireLedger,
    WireStats,
};
use crate::obs::{
    chain_id, ClockDomain, Sample, Sampler, TraceBlob, TraceBuf, DEFAULT_INTERVAL_NS, NO_SEQ,
};
use crate::state::{snapshot_due, ShardSnapshot};
use crate::transport::wire::{FlushMsg, Msg};
use crate::transport::{
    loopback, socket, Clock, FlushRx, FlushTx, LaneError, TransportKind, TupleRecv, TupleRx,
    TupleTx,
};
use crate::workload::Trace;
use crate::Key;
use std::path::PathBuf;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Result of a runtime deployment run.
#[derive(Debug, Clone)]
pub struct RtResult {
    /// End-to-end tuple latency (ns).
    pub latency: Histogram,
    /// Tuples processed per worker.
    pub worker_counts: Vec<u64>,
    /// Distinct keys held per worker (state size).
    pub worker_state: Vec<usize>,
    /// Total wall-clock duration (ns).
    pub wall_ns: u64,
    /// Overall throughput (tuples/sec).
    pub throughput: f64,
    /// Total state entries across workers.
    pub entries: usize,
    /// Distinct keys overall.
    pub distinct_keys: usize,
    /// Stage-two output: exact merged per-key counts, ascending by key
    /// (shard-count-invariant — the aggregation oracle).
    pub merged: Vec<(Key, u64)>,
    /// Whole-fabric aggregation-traffic ledger (flushes, messages,
    /// bytes, merge time) — the totals across [`RtResult::shard_agg`].
    pub agg: AggStats,
    /// Per-shard ledgers + shard-imbalance summary (max/mean absorbed
    /// tuples across the `--agg_shards` aggregator threads).
    pub shard_agg: ShardAggStats,
    /// Flush→merge latency per shard flush batch (**wall** ns): how
    /// stale the merged view runs behind the workers. (The simulator's
    /// counterpart, `SimResult::agg_latency`, is virtual ns.)
    pub agg_latency: Histogram,
    /// Scatter-gather top-k front-end assembled from the per-shard
    /// sketches, queryable with an explicit rank-error bound.
    pub gather: TopKGather,
    /// Windowed aggregation output (`--agg_window_ms > 0`; empty when
    /// unwindowed): one [`WindowSnapshot`] per tumbling event-time
    /// pane, ascending. Panes are assigned by the tuples' trace emit
    /// times, so per-window counts are byte-identical to the
    /// simulator's for the same trace — thread interleaving and
    /// wall-clock flush timing only move *when* panes retire, never
    /// what they contain.
    pub windows: Vec<WindowSnapshot>,
    /// Pane-lifecycle ledger folded across the aggregator shards
    /// (retirements, late reopens and their re-merged tuple mass,
    /// open-pane memory peaks); all zeros when unwindowed.
    pub window_stats: WindowStats,
    /// Wire-transport traffic and serialization time. All zeros on
    /// loopback (nothing is serialized); socket and multi-process runs
    /// meter every frame both directions.
    pub wire: WireStats,
    /// Exactly-once recovery activity — flush-batch replays and dedups,
    /// snapshots, restores, restarts (docs/RECOVERY.md). All zeros on a
    /// fault-free run, so [`RecoveryStats::any`] gates the report rows.
    pub recovery: RecoveryStats,
    /// Wall-clock trace buffers, one per engine thread (sources,
    /// workers, shards — plus, multi-process, every child's buffers
    /// shipped home in its `Done` payload). Empty unless tracing was
    /// enabled (`obs::set_enabled` / `--trace-out`).
    pub trace_blobs: Vec<TraceBlob>,
    /// Per-epoch telemetry rows from every actor (same gate; empty when
    /// tracing is off).
    pub samples: Vec<Sample>,
}

impl RtResult {
    /// Memory overhead normalised to FG (= 1 entry/key).
    pub fn memory_normalized(&self) -> f64 {
        if self.distinct_keys == 0 {
            1.0
        } else {
            self.entries as f64 / self.distinct_keys as f64
        }
    }

    /// The `k` hottest keys by merged count, descending (exact).
    pub fn top_k(&self, k: usize) -> Vec<(Key, u64)> {
        aggregate::top_k(&self.merged, k)
    }
}

/// Runtime engine configuration (decoupled from [`crate::config::Config`]
/// so benches can drive it directly).
#[derive(Debug, Clone)]
pub struct RtOptions {
    /// Bounded per-worker queue depth in **tuples** (backpressure knob,
    /// like Storm's max.spout.pending). Loopback lanes enforce it with
    /// shared tuple credits; socket lanes with a per-stream credit
    /// window of the same size (credits return as `Credit` frames).
    /// With several sources the bound is approximate (each may overshoot
    /// by up to one chunk, exactly like concurrent spouts).
    pub queue_depth: usize,
    /// Per-tuple CPU burn per worker id (ns); empty = no burn.
    pub per_tuple_ns: Vec<f64>,
    /// Pace sources to this inter-arrival gap (ns); 0 = as fast as possible.
    pub interarrival_ns: u64,
    /// Tuples routed per `route_batch` call; each batch ships at most
    /// one chunk per destination worker.
    pub batch: usize,
    /// Partial-aggregate flush interval (wall ns); 0 = each worker
    /// flushes only once, at shutdown. See
    /// [`crate::config::Config::agg_flush_ms`].
    pub agg_flush_ns: u64,
    /// Stage-two merge shards — one aggregator thread each. See
    /// [`crate::config::Config::agg_shards`].
    pub agg_shards: usize,
    /// Tumbling-pane length in event-time ns (0 = unwindowed). See
    /// [`crate::config::Config::agg_window_ns`].
    pub agg_window_ns: u64,
    /// Watermark slack before pane retirement (event-time ns): panes
    /// stay open until the watermark passes `pane end + slack`, so
    /// bounded disorder absorbs in place instead of reopening retired
    /// panes. See [`crate::config::Config::agg_lateness_ms`].
    pub agg_lateness_ns: u64,
    /// Which lane backend carries source→worker and worker→shard
    /// traffic (in-process): loopback channels (default), UDS or TCP.
    pub transport: TransportKind,
}

impl Default for RtOptions {
    fn default() -> Self {
        RtOptions {
            queue_depth: 1024,
            per_tuple_ns: Vec::new(),
            interarrival_ns: 0,
            batch: crate::config::DEFAULT_BATCH,
            agg_flush_ns: crate::config::DEFAULT_AGG_FLUSH_MS * 1_000_000,
            agg_shards: 1,
            agg_window_ns: 0,
            agg_lateness_ns: 0,
            transport: TransportKind::Loopback,
        }
    }
}

/// Spin-burn approximately `ns` nanoseconds of CPU (models operator cost;
/// sleep granularity is far too coarse at µs scales).
#[inline]
fn burn(ns: f64) {
    if ns <= 0.0 {
        return;
    }
    let start = Instant::now();
    while (start.elapsed().as_nanos() as f64) < ns {
        std::hint::spin_loop();
    }
}

/// Scatter one drained (per-pane) flush across the shard fabric: each
/// shard gets the panes' sub-batches it owns, on its worker→shard
/// flush lane, stamped with the same emit time, the worker's
/// event-time watermark, and the next per-(worker, shard) sequence
/// number (`seqs[s]`, advanced only when shard `s` actually gets a
/// message — the shard's sequencer expects the *received* stream to be
/// gap-free). Unwindowed, shards with nothing to absorb are skipped
/// (today's traffic shape); windowed, every shard gets the message —
/// an empty one still advances the worker's watermark so panes can
/// retire. Send errors are ignored — a gone shard only happens at
/// shutdown (a *restarted* shard is handled inside the recovering
/// socket lane, which re-dials and replays before reporting failure).
#[allow(clippy::too_many_arguments)]
fn send_flush(
    router: &ShardRouter,
    shard_txs: &mut [Box<dyn FlushTx>],
    seqs: &mut [u64],
    worker: usize,
    emit_ns: u64,
    watermark: u64,
    flushed: Vec<(u64, Vec<(Key, u64)>)>,
    windowed: bool,
    obs: &mut TraceBuf,
) {
    let mut per_shard: Vec<Vec<(u64, Vec<(Key, u64)>)>> =
        (0..shard_txs.len()).map(|_| Vec::new()).collect();
    for (win, batch) in flushed {
        for (s, sub) in router.split(batch).into_iter().enumerate() {
            if !sub.is_empty() {
                per_shard[s].push((win, sub));
            }
        }
    }
    for (s, panes) in per_shard.into_iter().enumerate() {
        if windowed || !panes.is_empty() {
            if obs.is_active() {
                obs.instant_seq("flush_send", emit_ns, chain_id(worker as u64, s as u64, seqs[s]));
            }
            let _ = shard_txs[s].send(FlushMsg {
                worker,
                seq: seqs[s],
                emit_ns,
                watermark,
                panes,
            });
            seqs[s] += 1;
        }
    }
}

/// One source's whole life, over any tuple-lane backend: pull the
/// round-robin share of the trace, route in batches under one cluster
/// view, ship one chunk per destination worker down its (credit-gated,
/// blocking) lane. Shared verbatim by the in-process engine and the
/// multi-process coordinator.
#[allow(clippy::too_many_arguments)]
pub(crate) fn source_loop(
    s: usize,
    n_sources: usize,
    mut grouper: Box<dyn Grouper>,
    trace: &Trace,
    batch: usize,
    gap: u64,
    clock: Clock,
    per_tuple: &[f64],
    workers_list: &[usize],
    mut txs: Vec<Box<dyn TupleTx>>,
    obs: &mut TraceBuf,
) {
    let n = trace.len();
    // pace relative to when this source actually starts (≈0 in-process;
    // multi-process, handshakes already spent some of the epoch)
    let mut next_emit = clock.now_ns() + (s as u64) * gap / n_sources.max(1) as u64;
    let mut keys: Vec<crate::Key> = Vec::with_capacity(batch);
    let mut emits: Vec<u64> = Vec::with_capacity(batch);
    let mut tss: Vec<u64> = Vec::with_capacity(batch);
    let mut routed: Vec<usize> = vec![0; batch];
    let mut chunks: Vec<Vec<Msg>> = (0..txs.len()).map(|_| Vec::new()).collect();
    let mut i = s;
    'stream: while i < n {
        // accumulate tuples for one routing batch; under pacing,
        // flush whatever is buffered instead of sitting on it
        // while waiting for the next emit slot (keeps end-to-end
        // latency free of artificial batching delay)
        keys.clear();
        emits.clear();
        tss.clear();
        while i < n && keys.len() < batch {
            let t = trace.tuples()[i];
            if gap > 0 {
                if clock.now_ns() < next_emit && !keys.is_empty() {
                    break; // ship the partial batch, then pace
                }
                // pace the stream
                while clock.now_ns() < next_emit {
                    std::hint::spin_loop();
                }
                next_emit += gap;
            }
            keys.push(t.key);
            emits.push(clock.now_ns());
            tss.push(t.ts); // event time: the trace's scheduled emit
            i += n_sources;
        }

        // one route_batch call under one cluster view
        let now = clock.now_ns();
        let view = ClusterView {
            now,
            workers: workers_list,
            per_tuple_time: per_tuple,
            n_slots: per_tuple.len(),
        };
        let m = keys.len();
        grouper.route_batch(&keys, &mut routed[..m], &view);
        if obs.is_active() && m > 0 {
            obs.span_full("route_batch", now, clock.now_ns(), NO_SEQ, m as u64);
            obs.instant_full("source_emit", emits[0], NO_SEQ, m as u64);
        }

        // one chunk send per destination worker (vs one send per
        // tuple): this is the lane-contention win
        for j in 0..m {
            chunks[routed[j]].push(Msg { key: keys[j], emit_ns: emits[j], ts: tss[j] });
        }
        for (w, chunk) in chunks.iter_mut().enumerate() {
            if chunk.is_empty() {
                continue;
            }
            // blocking, credit-gated send: the lane waits for the
            // worker's unprocessed count to leave room, and reports a
            // vanished worker as an error so the source stops
            // streaming instead of blocking forever
            let t0 = if obs.is_active() { clock.now_ns() } else { 0 };
            let len = chunk.len() as u64;
            if txs[w].send(std::mem::take(chunk)).is_err() {
                break 'stream; // worker gone (shutdown)
            }
            if obs.is_active() {
                // the span's length is the backpressure stall: a send
                // that found credit returns in nanoseconds
                obs.span_full("credit_wait", t0, clock.now_ns(), NO_SEQ, len);
            }
        }
    }
    for tx in txs.iter_mut() {
        tx.close();
    }
}

/// One worker's whole life, over any lane backend: drain tuple chunks,
/// fold the word-count state and the windowed delta, return processed
/// credits, scatter periodic partial flushes, drain at shutdown.
/// Returns `(latency histogram, tuples processed, state entries)`.
/// Shared verbatim by the in-process engine and multi-process worker
/// children.
///
/// Flush batches are stamped with per-(worker, shard) sequence
/// numbers, seeded from each lane's [`FlushTx::resume_from`] — 0 on a
/// fresh lane, the shard's next expected seq when this worker is a
/// chaos respawn rejoining mid-stream (docs/RECOVERY.md).
///
/// `crash_after_flushes` is the chaos harness's cooperative kill
/// switch: after the Nth periodic flush round the worker pushes its
/// owed backpressure credits out (so the source never replays tuples
/// that are already flushed — the `acked ⊆ flushed` invariant), then
/// exits the process without `Done`/`Eof`. Only the multi-process
/// launcher arms it; the in-process engine always passes `None`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn worker_loop(
    w: usize,
    cost: f64,
    agg_flush_ns: u64,
    agg_window_ns: u64,
    clock: Clock,
    router: &ShardRouter,
    mut rx: Box<dyn TupleRx>,
    mut flush_txs: Vec<Box<dyn FlushTx>>,
    crash_after_flushes: Option<u64>,
    obs: &mut TraceBuf,
    sampler: &mut Sampler,
) -> (Histogram, u64, usize) {
    let windowed = agg_window_ns > 0;
    let mut hist = Histogram::wall();
    let mut count = 0u64;
    let mut state: std::collections::HashMap<Key, u64> = std::collections::HashMap::new();
    let mut delta = WindowedPartial::new(Count, agg_window_ns);
    let mut watermark = 0u64;
    let mut next_flush = agg_flush_ns;
    let mut seqs: Vec<u64> = flush_txs.iter().map(|tx| tx.resume_from()).collect();
    let mut flush_rounds = 0u64;
    // windowed, the worker polls with a timeout so watermark-only
    // flushes keep flowing even when its data lane goes quiet
    // — otherwise a worker idle mid-run would pin every shard's
    // min-watermark and stall pane retirement until shutdown
    let poll = windowed && agg_flush_ns > 0;
    loop {
        let timeout = if poll { Some(Duration::from_nanos(agg_flush_ns)) } else { None };
        let chunk = match rx.recv(timeout) {
            TupleRecv::Chunk(c) => Some(c),
            TupleRecv::Timeout => None,
            TupleRecv::Closed => break,
        };
        let t0 = if obs.is_active() && chunk.is_some() { clock.now_ns() } else { 0 };
        let before = count;
        for msg in chunk.into_iter().flatten() {
            // the actual operator: word count
            *state.entry(msg.key).or_insert(0) += 1;
            delta.observe(msg.key, 1, msg.ts);
            if msg.ts > watermark {
                watermark = msg.ts;
            }
            burn(cost);
            let done_ns = clock.now_ns();
            hist.record(done_ns.saturating_sub(msg.emit_ns));
            count += 1;
            // release one backpressure credit per processed tuple
            rx.ack(1);
        }
        if obs.is_active() && count > before {
            obs.span_full("worker_absorb", t0, clock.now_ns(), NO_SEQ, count - before);
        }
        // partial flush: scatter the delta across the shard
        // fabric once per interval (checked at chunk granularity
        // — the flush itself is off the per-tuple path). The
        // schedule snaps to the interval's boundary grid
        // (`next_boundary`, shared with the simulator) instead
        // of `now + interval`, so cadence cannot drift by
        // per-chunk processing time. Windowed, empty flushes
        // still ship: they carry the watermark panes retire on.
        if agg_flush_ns > 0 {
            let now = clock.now_ns();
            if now >= next_flush {
                if windowed || !delta.is_empty() {
                    let batch = delta.flush();
                    send_flush(
                        router, &mut flush_txs, &mut seqs, w, now, watermark, batch, windowed, obs,
                    );
                    flush_rounds += 1;
                    // cooperative crash point: die exactly at a flush
                    // boundary, where every acked tuple is flushed.
                    // Push owed credits out first, then exit without
                    // Done/Eof — the sources replay the unacked suffix
                    // to this worker's replacement.
                    if crash_after_flushes.is_some_and(|n| flush_rounds >= n) {
                        let _ = rx.recv(Some(Duration::ZERO));
                        std::process::exit(0);
                    }
                }
                next_flush = aggregate::next_boundary(now, agg_flush_ns);
                if sampler.due(now) {
                    sampler.record(Sample { ts_ns: now, tuples: count, ..Sample::default() });
                }
            }
        }
    }
    // shutdown drain: whatever accumulated since the last flush,
    // with the watermark pinned open — this worker is done, it
    // can never hold a pane back again
    if windowed || !delta.is_empty() {
        let now = clock.now_ns();
        let batch = delta.flush();
        send_flush(router, &mut flush_txs, &mut seqs, w, now, u64::MAX, batch, windowed, obs);
    }
    // explicit close: a recovering lane whose shard restarted under the
    // drain re-dials and replays before Eof, so the drain above cannot
    // be lost to a dead socket (no-op on loopback lanes)
    for tx in flush_txs.iter_mut() {
        tx.close();
    }
    (hist, count, state.len())
}

/// Control inputs for one merge shard: identity, recovery ledger,
/// snapshot cadence, and (for a chaos respawn) the snapshot to resume
/// from. [`ShardControl::fresh`] is the no-chaos default the
/// in-process engine uses.
pub(crate) struct ShardControl {
    /// Shard index (stamped into snapshots).
    pub shard: u64,
    /// Recovery ledger this shard meters into (under `deploy`, shared
    /// with the rest of the child process's lanes).
    pub ledger: Arc<RecoveryLedger>,
    /// Snapshot every N accepted flush batches (0 = never).
    pub snapshot_every: u64,
    /// Where snapshots persist; `None` serializes and meters without
    /// writing (exercises the codec at zero I/O cost).
    pub snapshot_path: Option<PathBuf>,
    /// Snapshot to resume from — a restarted shard rejoining the mesh.
    pub resume: Option<ShardSnapshot>,
}

impl ShardControl {
    /// No chaos: fresh state, private ledger, no snapshots.
    pub fn fresh(shard: u64) -> Self {
        ShardControl {
            shard,
            ledger: Arc::new(RecoveryLedger::new()),
            snapshot_every: 0,
            snapshot_path: None,
            resume: None,
        }
    }
}

/// Everything one merge shard hands back at shutdown.
pub(crate) struct ShardOutput {
    /// Windowed-merge output (all-time counts, windows, ledgers).
    pub out: WindowedOutput,
    /// The shard's gather sketch (scatter-gather top-k front-end).
    pub sketch: TopKSketch,
    /// Flush→merge transit latency.
    pub latency: Histogram,
    /// Per-worker tuple mass absorbed (accepted batches only). Under
    /// chaos the coordinator reconstructs a killed worker's processed
    /// count from these — the worker itself died without reporting.
    pub absorbed: Vec<u64>,
    /// Recovery activity, cumulative across this shard's incarnations.
    pub recovery: RecoveryStats,
}

/// The shard's gather-sketch parts in snapshot order (ascending by
/// key, so snapshot bytes are deterministic for a given sketch state).
pub(crate) fn sketch_parts_sorted(sketch: &TopKSketch) -> Vec<(Key, f64)> {
    let mut v: Vec<(Key, f64)> = sketch.tracked().collect();
    v.sort_unstable_by(|a, b| a.0.cmp(&b.0));
    v
}

/// Fold one *accepted* flush batch into a shard's state: latency
/// sample, per-pane absorb into the merge stage and the gather sketch,
/// per-worker absorbed mass and watermark high-water mark. The caller
/// guarantees `flush.worker` is in range.
fn absorb_flush(
    stage: &mut WindowedMerge<Count>,
    sketch: &mut TopKSketch,
    lat: &mut Histogram,
    worker_wm: &mut [u64],
    absorbed: &mut [u64],
    clock: Clock,
    flush: FlushMsg,
) {
    if !flush.panes.is_empty() {
        let recv_ns = clock.now_ns();
        lat.record(recv_ns.saturating_sub(flush.emit_ns));
    }
    let worker = flush.worker;
    for (win, entries) in flush.panes {
        for &(key, delta) in &entries {
            sketch.absorb(key, delta);
            absorbed[worker] += delta;
        }
        stage.absorb(win, entries);
    }
    if flush.watermark > worker_wm[worker] {
        worker_wm[worker] = flush.watermark;
    }
}

/// One merge shard's whole life, over any lane backend: sequence every
/// arriving flush batch (accept-next / buffer-ahead / drop-replayed —
/// the dedup half of exactly-once), absorb accepted batches into the
/// windowed merge stage and the shard's top-k sketch, advance the
/// min-across-workers watermark, retire panes, snapshot periodically,
/// finish. Shared verbatim by the in-process engine and multi-process
/// shard children; a respawned shard passes the loaded snapshot in
/// [`ShardControl::resume`] and converges byte-identically
/// (docs/RECOVERY.md).
pub(crate) fn shard_loop(
    n_workers: usize,
    agg_window_ns: u64,
    agg_lateness_ns: u64,
    clock: Clock,
    mut rx: Box<dyn FlushRx>,
    ctl: ShardControl,
    obs: &mut TraceBuf,
    sampler: &mut Sampler,
) -> ShardOutput {
    let mut stage = WindowedMerge::new(Count, agg_window_ns, aggregate::DEFAULT_GATHER_CAPACITY)
        .with_lateness(agg_lateness_ns);
    let mut sketch = TopKSketch::new(aggregate::DEFAULT_GATHER_CAPACITY);
    let mut lat = Histogram::wall();
    // per-worker event-time high-water marks; panes retire when
    // the min across workers passes their end (plus lateness slack)
    let mut worker_wm = vec![0u64; n_workers];
    let mut sequencer: FlushSequencer<FlushMsg> = FlushSequencer::new(n_workers);
    let mut absorbed = vec![0u64; n_workers];
    // recovery counters carried over from previous incarnations — the
    // ledger meters only this incarnation's activity on top
    let mut carried = RecoveryStats::default();
    let mut accepted_since_snapshot = 0u64;
    if let Some(snap) = ctl.resume {
        ctl.ledger.record_restore();
        if obs.is_active() {
            obs.instant_full("restore", clock.now_ns(), NO_SEQ, ctl.shard);
        }
        // restore the sequencer cursors and re-offer the batches the
        // predecessor had parked ahead of a sequence gap (a batch the
        // restored cursors no longer block absorbs below, once the
        // stage itself is restored; a stale one drops silently) — the
        // shared shard-restore rule the recovery model checks
        let (restored, replay_accepted) = FlushSequencer::restore_replaying(
            snap.expected_seq,
            snap.buffered.into_iter().map(|m| (m.worker, m.seq, m)),
        );
        sequencer = restored;
        for (dst, src) in worker_wm.iter_mut().zip(&snap.worker_wm) {
            *dst = *src;
        }
        // the gather sketch is not reconstructible from replay (batches
        // below the expected seqs are never re-sent) — rebuild it from
        // its serialized parts, which round-trip exactly
        sketch = TopKSketch::from_parts(
            aggregate::DEFAULT_GATHER_CAPACITY,
            &snap.sketch_entries,
            snap.sketch_error,
        );
        lat = snap.latency;
        carried = snap.recovery;
        stage.restore(snap.merge);
        for m in replay_accepted {
            absorb_flush(
                &mut stage, &mut sketch, &mut lat, &mut worker_wm, &mut absorbed, clock, m,
            );
        }
    }
    while let Some(flush) = rx.recv() {
        let (worker, seq) = (flush.worker, flush.seq);
        if worker >= n_workers {
            continue; // foreign or corrupt frame: never absorb
        }
        match sequencer.offer(worker, seq, flush) {
            SeqDecision::Accept(batch) => {
                for msg in batch {
                    if obs.is_active() {
                        // the flush chain's receive half: emit → absorb,
                        // keyed by the same (worker, shard, seq) as the
                        // sender's flush_send instant
                        let cid = chain_id(msg.worker as u64, ctl.shard, msg.seq);
                        obs.span_seq("merge_absorb", msg.emit_ns, clock.now_ns(), cid);
                    }
                    absorb_flush(
                        &mut stage, &mut sketch, &mut lat, &mut worker_wm, &mut absorbed,
                        clock, msg,
                    );
                    accepted_since_snapshot += 1;
                }
            }
            SeqDecision::Replayed => {
                // already absorbed before the sender's restart —
                // dropping it here is the double count exactly-once
                // promises never happens
                ctl.ledger.record_deduped_batch();
                if obs.is_active() {
                    let cid = chain_id(worker as u64, ctl.shard, seq);
                    obs.instant_seq("flush_dedup", clock.now_ns(), cid);
                }
                continue;
            }
            SeqDecision::Buffered => {
                ctl.ledger.record_buffered_batch();
                if obs.is_active() {
                    let cid = chain_id(worker as u64, ctl.shard, seq);
                    obs.instant_seq("flush_buffered", clock.now_ns(), cid);
                }
                continue;
            }
        }
        // min over workers that have reported event-time progress:
        // a worker that never sees a tuple (e.g. an FG worker whose
        // key arc is empty) would otherwise pin the fabric at 0 and
        // stall every retirement until shutdown. If a silent worker
        // does speak up later, its deltas take the late-reopen path
        // and re-merge exactly — the heuristic moves retirement
        // timing, never the final counts.
        let wm = worker_wm.iter().copied().filter(|&w| w > 0).min().unwrap_or(0);
        let before = if obs.is_active() { Some(stage.window_stats()) } else { None };
        stage.advance(wm);
        if let Some(before) = before {
            let after = stage.window_stats();
            let now = clock.now_ns();
            let retired = after.panes_retired - before.panes_retired;
            if retired > 0 {
                obs.instant_full("pane_retire", now, NO_SEQ, retired);
            }
            let reopened = after.late_reopens - before.late_reopens;
            if reopened > 0 {
                obs.instant_full("pane_late_reopen", now, NO_SEQ, reopened);
            }
            obs.count("open_panes", now, stage.open_panes() as u64);
        }
        if sampler.is_active() {
            let now = clock.now_ns();
            if sampler.due(now) {
                let stats = stage.window_stats();
                sampler.record(Sample {
                    ts_ns: now,
                    absorbed: absorbed.iter().sum(),
                    open_panes: stage.open_panes() as u64,
                    open_entries: stats.max_open_entries,
                    ..Sample::default()
                });
            }
        }
        if snapshot_due(accepted_since_snapshot, ctl.snapshot_every) {
            accepted_since_snapshot = 0;
            let snap = ShardSnapshot {
                shard: ctl.shard,
                expected_seq: sequencer.expected_all().to_vec(),
                worker_wm: worker_wm.clone(),
                merge: stage.snapshot(),
                sketch_entries: sketch_parts_sorted(&sketch),
                sketch_error: sketch.merged_error(),
                buffered: sequencer.parked().into_iter().map(|(_, _, m)| m.clone()).collect(),
                latency: lat.clone(),
                recovery: {
                    let mut r = carried;
                    r.absorb(&ctl.ledger.snapshot());
                    r
                },
            };
            let persisted = match &ctl.snapshot_path {
                Some(path) => {
                    // persist errors are survivable: the shard keeps
                    // merging, recovery just falls back to the previous
                    // snapshot plus a longer replay
                    snap.persist(path).ok()
                }
                None => Some(snap.to_bytes().len() as u64),
            };
            if let Some(bytes) = persisted {
                ctl.ledger.record_snapshot(bytes);
                if obs.is_active() {
                    obs.instant_full("snapshot", clock.now_ns(), NO_SEQ, bytes);
                }
            }
        }
    }
    let mut recovery = carried;
    recovery.absorb(&ctl.ledger.snapshot());
    ShardOutput { out: stage.finish(), sketch, latency: lat, absorbed, recovery }
}

/// Run-level fields assembled from the fabric's per-shard outputs.
pub(crate) struct Assembled {
    /// Exact merged counts, ascending by key.
    pub merged: Vec<(Key, u64)>,
    /// Per-shard ledgers.
    pub shard_agg: ShardAggStats,
    /// Per-window snapshots (empty when unwindowed).
    pub windows: Vec<WindowSnapshot>,
    /// Folded pane-lifecycle stats.
    pub window_stats: WindowStats,
    /// Scatter-gather top-k front-end.
    pub gather: TopKGather,
    /// Flush→merge latency folded across shards.
    pub agg_latency: Histogram,
    /// Per-worker tuple mass absorbed across every shard — under chaos
    /// this reconstructs a killed worker's processed count (the worker
    /// died without reporting; Count partials make shard-side mass
    /// exactly the tuples it processed).
    pub absorbed: Vec<u64>,
    /// Folded recovery activity across the fabric.
    pub recovery: RecoveryStats,
}

/// Assemble the fabric's per-shard outputs into the run-level result
/// fields: exact merged counts (concat + sort — shards partition the
/// key space), per-shard ledgers, window snapshots (empty when
/// unwindowed), the folded pane-lifecycle stats, and the folded
/// recovery ledgers. Shared with the multi-process coordinator, which
/// gets the same outputs back over `Done` frames instead of thread
/// joins.
pub(crate) fn assemble_shards(agg_window_ns: u64, shard_outs: Vec<ShardOutput>) -> Assembled {
    let n_shards = shard_outs.len();
    let mut merged: Vec<(Key, u64)> = Vec::new();
    let mut per_shard: Vec<AggStats> = Vec::with_capacity(n_shards);
    let mut per_shard_windows: Vec<Vec<aggregate::WindowResult>> = Vec::with_capacity(n_shards);
    let mut window_stats = WindowStats::default();
    let mut sketches: Vec<TopKSketch> = Vec::with_capacity(n_shards);
    let mut agg_latency = Histogram::wall();
    let mut absorbed: Vec<u64> = Vec::new();
    let mut recovery = RecoveryStats::default();
    for so in shard_outs {
        merged.extend(so.out.all_time);
        per_shard.push(so.out.stats);
        window_stats.absorb(&so.out.window_stats);
        per_shard_windows.push(so.out.windows);
        sketches.push(so.sketch);
        agg_latency.merge(&so.latency);
        if absorbed.len() < so.absorbed.len() {
            absorbed.resize(so.absorbed.len(), 0);
        }
        for (dst, src) in absorbed.iter_mut().zip(&so.absorbed) {
            *dst += *src;
        }
        recovery.absorb(&so.recovery);
    }
    merged.sort_unstable_by_key(|&(k, _)| k);
    let windows = if agg_window_ns > 0 {
        aggregate::assemble_windows(
            agg_window_ns,
            n_shards,
            aggregate::DEFAULT_GATHER_CAPACITY,
            per_shard_windows,
        )
    } else {
        window_stats = WindowStats::default();
        Vec::new()
    };
    let gather = TopKGather::from_shards(sketches);
    Assembled {
        merged,
        shard_agg: ShardAggStats { per_shard },
        windows,
        window_stats,
        gather,
        agg_latency,
        absorbed,
        recovery,
    }
}

/// Normalise the per-worker burn table to `n_workers` entries.
pub(crate) fn per_tuple_table(opts: &RtOptions, n_workers: usize) -> Vec<f64> {
    if opts.per_tuple_ns.is_empty() {
        vec![0.0; n_workers]
    } else {
        (0..n_workers)
            .map(|w| opts.per_tuple_ns[w % opts.per_tuple_ns.len()])
            .collect()
    }
}

/// Run `trace` through `sources` grouper instances onto `n_workers`
/// worker threads, over the lane backend [`RtOptions::transport`]
/// selects. Panics if the lane mesh cannot be built; callers that can
/// surface setup failures (the deploy path) use [`try_run`].
pub fn run(
    trace: &Arc<Trace>,
    sources: Vec<Box<dyn Grouper>>,
    n_workers: usize,
    opts: &RtOptions,
) -> RtResult {
    match try_run(trace, sources, n_workers, opts) {
        Ok(result) => result,
        Err(e) => panic!("rt transport setup failed: {e}"),
    }
}

/// Fallible [`run`]: socket-mesh construction errors (bind, connect,
/// accept, clone) come back as [`LaneError`] instead of panicking —
/// all in one process; `deploy --processes N` is
/// [`crate::transport::launch::run_multiprocess`].
pub fn try_run(
    trace: &Arc<Trace>,
    mut sources: Vec<Box<dyn Grouper>>,
    n_workers: usize,
    opts: &RtOptions,
) -> Result<RtResult, LaneError> {
    assert!(!sources.is_empty() && n_workers > 0);
    let per_tuple = per_tuple_table(opts, n_workers);

    // queue_depth is tuples; chunks vary in size (partial flushes under
    // pacing, per-worker splits), so the bound is enforced with tuple
    // credits rather than lane slots. Chunks are clamped ≤ queue_depth
    // so a single chunk can always be admitted.
    let queue_depth = opts.queue_depth.max(1);
    let batch = opts.batch.max(1).min(queue_depth);
    let n_sources = sources.len();
    let n_shards = opts.agg_shards.max(1);
    let agg_window_ns = opts.agg_window_ns;
    let agg_lateness_ns = opts.agg_lateness_ns;
    let agg_flush_ns = opts.agg_flush_ns;

    // ---- lanes ---------------------------------------------------------
    // Loopback lanes are channels + atomic credits (no serialization,
    // ledger stays zero); socket lanes carry the wire format with
    // per-stream credit windows and meter every frame.
    let ledger = Arc::new(WireLedger::new());
    let (tuple_txs, tuple_rxs) = match opts.transport {
        TransportKind::Loopback => loopback::tuple_lanes(n_sources, n_workers, queue_depth),
        kind => socket::tuple_mesh(kind, n_sources, n_workers, queue_depth, &ledger)?,
    };
    let (flush_txs, flush_rxs) = match opts.transport {
        TransportKind::Loopback => loopback::flush_lanes(n_workers, n_shards),
        kind => socket::flush_mesh(kind, n_workers, n_shards, &ledger)?,
    };

    let clock = Clock::mono();
    let router = Arc::new(ShardRouter::new(n_shards));

    // ---- aggregator fabric (stage two) ---------------------------------
    // One thread per merge shard. Flush lanes are uncredited: flush
    // traffic is orders of magnitude below the data path, and an
    // ungated lane cannot deadlock against the tuple-credit loop.
    let mut shard_handles = Vec::with_capacity(n_shards);
    for (s, rx) in flush_rxs.into_iter().enumerate() {
        let ctl = ShardControl::fresh(s as u64);
        shard_handles.push(thread::spawn(move || {
            // in-process actors share pid 0; tids follow the deploy id
            // scheme (200+shard) so merged timelines read the same way
            let mut obs = TraceBuf::for_cli(0, 200 + s as u32, ClockDomain::Wall);
            let mut sampler = Sampler::for_cli(200 + s as u32, DEFAULT_INTERVAL_NS);
            let out = shard_loop(
                n_workers,
                agg_window_ns,
                agg_lateness_ns,
                clock,
                rx,
                ctl,
                &mut obs,
                &mut sampler,
            );
            (out, obs, sampler)
        }));
    }

    // ---- workers -------------------------------------------------------
    let mut worker_handles = Vec::with_capacity(n_workers);
    for (w, (rx, txs)) in tuple_rxs.into_iter().zip(flush_txs).enumerate() {
        let cost = per_tuple[w];
        let router = Arc::clone(&router);
        worker_handles.push(thread::spawn(move || {
            let mut obs = TraceBuf::for_cli(0, 100 + w as u32, ClockDomain::Wall);
            let mut sampler = Sampler::for_cli(100 + w as u32, DEFAULT_INTERVAL_NS);
            let out = worker_loop(
                w,
                cost,
                agg_flush_ns,
                agg_window_ns,
                clock,
                &router,
                rx,
                txs,
                None,
                &mut obs,
                &mut sampler,
            );
            (out, obs, sampler)
        }));
    }

    // ---- sources -------------------------------------------------------
    let workers_list: Vec<usize> = (0..n_workers).collect();
    let mut source_handles = Vec::with_capacity(n_sources);
    for (s, (grouper, txs)) in sources.drain(..).zip(tuple_txs).enumerate() {
        let trace = Arc::clone(trace);
        let workers_list = workers_list.clone();
        let per_tuple = per_tuple.clone();
        let gap = opts.interarrival_ns * n_sources as u64;
        source_handles.push(thread::spawn(move || {
            let mut obs = TraceBuf::for_cli(0, 10 + s as u32, ClockDomain::Wall);
            source_loop(
                s,
                n_sources,
                grouper,
                &trace,
                batch,
                gap,
                clock,
                &per_tuple,
                &workers_list,
                txs,
                &mut obs,
            );
            obs
        }));
    }

    let mut trace_blobs: Vec<TraceBlob> = Vec::new();
    let mut samples: Vec<Sample> = Vec::new();
    for h in source_handles {
        let obs = h.join().expect("source thread panicked");
        if obs.is_active() {
            trace_blobs.push(obs.to_blob());
        }
    }

    let mut latency = Histogram::wall();
    let mut counts = Vec::with_capacity(n_workers);
    let mut states = Vec::with_capacity(n_workers);
    for h in worker_handles {
        let ((hist, count, state_len), obs, sampler) =
            h.join().expect("worker thread panicked");
        latency.merge(&hist);
        counts.push(count);
        states.push(state_len);
        if obs.is_active() {
            trace_blobs.push(obs.to_blob());
        }
        samples.extend(sampler.samples());
    }
    // gather the fabric: shard results arrive in shard-id order, keys
    // are disjoint across shards, so concat + sort reproduces the
    // single-aggregator ordering byte for byte
    let mut shard_outs = Vec::with_capacity(n_shards);
    for h in shard_handles {
        let (out, obs, sampler) = h.join().expect("aggregator shard thread panicked");
        shard_outs.push(out);
        if obs.is_active() {
            trace_blobs.push(obs.to_blob());
        }
        samples.extend(sampler.samples());
    }
    let assembled = assemble_shards(agg_window_ns, shard_outs);
    let agg = assembled.shard_agg.total();
    let wall_ns = clock.now_ns();
    let total: u64 = counts.iter().sum();
    let entries: usize = states.iter().sum();
    // distinct keys = key_space actually touched; recompute from trace
    let mut seen = std::collections::HashSet::new();
    for t in trace.tuples() {
        seen.insert(t.key);
    }

    Ok(RtResult {
        latency,
        worker_counts: counts,
        worker_state: states,
        wall_ns,
        throughput: total as f64 / (wall_ns as f64 / 1e9),
        entries,
        distinct_keys: seen.len(),
        merged: assembled.merged,
        agg,
        shard_agg: assembled.shard_agg,
        agg_latency: assembled.agg_latency,
        gather: assembled.gather,
        windows: assembled.windows,
        window_stats: assembled.window_stats,
        wire: ledger.snapshot(),
        recovery: assembled.recovery,
        trace_blobs,
        samples,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::{make_kind, SchemeKind};
    use crate::workload::{by_name, materialise};

    fn small_trace() -> Arc<Trace> {
        let mut gen = by_name("zf", 20_000, 1.5, 7);
        Arc::new(materialise(gen.as_mut(), 0))
    }

    fn run_scheme(kind: SchemeKind, workers: usize, trace: &Arc<Trace>) -> RtResult {
        let mut cfg = Config::default();
        cfg.workers = workers;
        cfg.scheme = kind;
        cfg.interval = 2_000_000; // 2ms HWA interval at wall-clock scale
        let sources: Vec<Box<dyn Grouper>> =
            (0..2).map(|s| make_kind(kind, &cfg, s)).collect();
        run(trace, sources, workers, &RtOptions::default())
    }

    #[test]
    fn processes_every_tuple_exactly_once() {
        let trace = small_trace();
        for kind in [SchemeKind::Shuffle, SchemeKind::Field, SchemeKind::Fish] {
            let r = run_scheme(kind, 4, &trace);
            assert_eq!(r.worker_counts.iter().sum::<u64>(), 20_000, "{kind}");
            assert!(r.throughput > 0.0);
            assert_eq!(r.latency.count(), 20_000);
        }
    }

    #[test]
    fn merged_counts_reassemble_the_trace_exactly() {
        // Even under shuffle grouping — every key scattered over every
        // worker — the aggregator's merged counts equal the trace's
        // per-key histogram, element for element.
        let trace = small_trace();
        let mut truth: std::collections::HashMap<Key, u64> = std::collections::HashMap::new();
        for t in trace.tuples() {
            *truth.entry(t.key).or_insert(0) += 1;
        }
        for kind in [SchemeKind::Shuffle, SchemeKind::Pkg, SchemeKind::Fish] {
            let r = run_scheme(kind, 4, &trace);
            assert_eq!(r.merged.len(), truth.len(), "{kind}");
            for &(k, c) in &r.merged {
                assert_eq!(c, truth[&k], "{kind} key {k}");
            }
            assert!(r.agg.flushes > 0, "{kind}");
            assert_eq!(r.agg_latency.count(), r.agg.flushes, "{kind}");
            // loopback lanes serialize nothing
            assert!(!r.wire.any(), "{kind}");
            // no faults injected → no recovery machinery fires
            assert!(!r.recovery.any(), "{kind}");
        }
    }

    #[test]
    fn restored_shard_converges_byte_identically() {
        // drive one shard over loopback lanes, snapshotting every 2
        // accepted batches; "crash" it after 4, bring up a replacement
        // from the persisted snapshot, and replay the full flush log —
        // the sequencer drops the already-absorbed prefix and the final
        // output is byte-identical to a shard that never crashed
        let msgs: Vec<FlushMsg> = (0..6u64)
            .map(|i| FlushMsg {
                worker: 0,
                seq: i,
                emit_ns: 10 * i,
                watermark: 100 * (i + 1),
                panes: vec![(i % 2, vec![(i + 1, 2), (7, 1)])],
            })
            .collect();
        let drive = |ctl: ShardControl, feed: Vec<FlushMsg>| {
            let (mut txs, mut rxs) = loopback::flush_lanes(1, 1);
            let rx = rxs.remove(0);
            let mut tx = txs.remove(0).remove(0);
            let clock = Clock::mono();
            let h = thread::spawn(move || {
                let mut obs = TraceBuf::disabled();
                let mut sam = Sampler::disabled();
                shard_loop(1, 200, 0, clock, rx, ctl, &mut obs, &mut sam)
            });
            for m in feed {
                tx.send(m).expect("loopback send");
            }
            drop(tx);
            h.join().expect("shard thread")
        };
        let reference = drive(ShardControl::fresh(0), msgs.clone());
        let path = std::env::temp_dir()
            .join(format!("fish-rt-restore-{}.snap", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut ctl = ShardControl::fresh(0);
        ctl.snapshot_every = 2;
        ctl.snapshot_path = Some(path.clone());
        let first = drive(ctl, msgs[..4].to_vec());
        assert_eq!(first.recovery.snapshots, 2);
        assert!(first.recovery.snapshot_bytes > 0);
        let snap = crate::state::ShardSnapshot::load(&path)
            .expect("load")
            .expect("snapshot present");
        let _ = std::fs::remove_file(&path);
        assert_eq!(snap.expected_seq, vec![4]);
        let mut ctl = ShardControl::fresh(0);
        ctl.resume = Some(snap);
        let restored = drive(ctl, msgs.clone()); // full replay: seqs 0..6
        assert_eq!(restored.out.all_time, reference.out.all_time);
        assert_eq!(
            restored
                .out
                .windows
                .iter()
                .map(|w| (w.window, w.counts.clone()))
                .collect::<Vec<_>>(),
            reference
                .out
                .windows
                .iter()
                .map(|w| (w.window, w.counts.clone()))
                .collect::<Vec<_>>(),
        );
        assert_eq!(restored.sketch.top(10), reference.sketch.top(10));
        // only post-restore mass lands in `absorbed` (2 batches × 3)
        assert_eq!(restored.absorbed, vec![6]);
        assert_eq!(restored.recovery.deduped_batches, 4);
        assert_eq!(restored.recovery.restores, 1);
        // the second snapshot's recovery field was captured before its
        // own persist landed in the ledger, so the carried count is 1
        assert_eq!(restored.recovery.snapshots, 1);
        assert_eq!(restored.latency.count(), reference.latency.count());
    }

    #[test]
    fn shard_buffers_ahead_and_accepts_when_gap_fills() {
        // deliver seqs 0, 2, 3 (gap at 1), then 1 — everything absorbs
        // exactly once, in order, and the ledger shows 2 parked batches
        let feed: Vec<FlushMsg> = [0u64, 2, 3, 1]
            .iter()
            .map(|&i| FlushMsg {
                worker: 0,
                seq: i,
                emit_ns: 0,
                watermark: 0,
                panes: vec![(0, vec![(i + 1, 1)])],
            })
            .collect();
        let (mut txs, mut rxs) = loopback::flush_lanes(1, 1);
        let rx = rxs.remove(0);
        let mut tx = txs.remove(0).remove(0);
        let clock = Clock::mono();
        let h = thread::spawn(move || {
            let mut obs = TraceBuf::disabled();
            let mut sam = Sampler::disabled();
            shard_loop(1, 0, 0, clock, rx, ShardControl::fresh(0), &mut obs, &mut sam)
        });
        for m in feed {
            tx.send(m).expect("loopback send");
        }
        drop(tx);
        let out = h.join().expect("shard thread");
        assert_eq!(out.out.all_time, vec![(1, 1), (2, 1), (3, 1), (4, 1)]);
        assert_eq!(out.recovery.buffered_batches, 2);
        assert_eq!(out.recovery.deduped_batches, 0);
        assert_eq!(out.absorbed, vec![4]);
    }

    #[test]
    fn sharded_fabric_merges_identically_to_single_aggregator() {
        let trace = small_trace();
        let run_with = |shards: usize| {
            let mut cfg = Config::default();
            cfg.workers = 4;
            let sources: Vec<Box<dyn Grouper>> =
                (0..2).map(|s| make_kind(SchemeKind::Pkg, &cfg, s)).collect();
            let opts = RtOptions { agg_shards: shards, ..Default::default() };
            run(&trace, sources, 4, &opts)
        };
        let single = run_with(1);
        let sharded = run_with(4);
        // wall-clock flush timing varies run to run, but the merged
        // output is exact either way — and byte-identical across fabrics
        assert_eq!(single.merged, sharded.merged);
        assert_eq!(single.top_k(10), sharded.top_k(10));
        assert_eq!(single.shard_agg.n_shards(), 1);
        assert_eq!(sharded.shard_agg.n_shards(), 4);
        for r in [&single, &sharded] {
            assert_eq!(
                r.shard_agg.per_shard.iter().map(|s| s.messages).sum::<u64>(),
                r.agg.messages
            );
            assert_eq!(r.agg_latency.count(), r.agg.flushes);
            assert_eq!(r.gather.n_shards(), r.shard_agg.n_shards());
        }
        // every shard that absorbed traffic is visible in the ledger
        assert!(sharded.shard_agg.per_shard.iter().any(|s| s.messages > 0));
    }

    #[test]
    fn socket_transport_matches_loopback_merged_output() {
        // the loopback ≡ socket oracle, in miniature (the integration
        // test covers UDS/TCP × windowed × sharded): same trace, same
        // schemes, real TCP lanes — identical merged counts and top-k,
        // and the wire ledger actually metered the traffic
        let trace = small_trace();
        let run_with = |transport: TransportKind| {
            let mut cfg = Config::default();
            cfg.workers = 4;
            let sources: Vec<Box<dyn Grouper>> =
                (0..2).map(|s| make_kind(SchemeKind::Pkg, &cfg, s)).collect();
            let opts = RtOptions { transport, agg_shards: 2, ..Default::default() };
            run(&trace, sources, 4, &opts)
        };
        let loopback = run_with(TransportKind::Loopback);
        let tcp = run_with(TransportKind::Tcp);
        assert_eq!(loopback.merged, tcp.merged);
        assert_eq!(loopback.top_k(10), tcp.top_k(10));
        assert_eq!(tcp.worker_counts.iter().sum::<u64>(), 20_000);
        assert!(!loopback.wire.any());
        assert!(tcp.wire.any());
        assert_eq!(tcp.wire.tuples_out, 20_000 + tcp.agg.messages);
        assert!(tcp.wire.bytes_out > 0 && tcp.wire.bytes_in > 0);
    }

    #[test]
    fn windowed_rt_panes_partition_the_trace_by_event_time() {
        // materialise with a real inter-arrival so the trace carries
        // meaningful event times (500ns × 20k tuples = 10ms of stream)
        let mut gen = by_name("zf", 20_000, 1.5, 7);
        let trace = Arc::new(materialise(gen.as_mut(), 500));
        let mut cfg = Config::default();
        cfg.workers = 4;
        let sources: Vec<Box<dyn Grouper>> =
            (0..2).map(|s| make_kind(SchemeKind::Pkg, &cfg, s)).collect();
        let opts = RtOptions {
            agg_shards: 3,
            agg_window_ns: 2_000_000, // 2ms panes → 5 panes
            ..Default::default()
        };
        let r = run(&trace, sources, 4, &opts);
        assert_eq!(r.windows.len(), 5);
        assert_eq!(r.windows.iter().map(|w| w.total()).sum::<u64>(), 20_000);
        for (i, w) in r.windows.iter().enumerate() {
            assert_eq!(w.window, i as u64);
            assert_eq!(w.total(), 4_000, "each 2ms pane holds 4000 scheduled emits");
            // the pane's exact counts match the trace slice it covers
            let mut truth: std::collections::HashMap<Key, u64> = std::collections::HashMap::new();
            for t in trace.tuples() {
                if t.ts >= w.start_ns() && t.ts < w.end_ns() {
                    *truth.entry(t.key).or_insert(0) += 1;
                }
            }
            assert_eq!(w.counts.len(), truth.len(), "pane {i}");
            for &(k, c) in &w.counts {
                assert_eq!(c, truth[&k], "pane {i} key {k}");
            }
        }
        assert!(r.window_stats.panes_retired > 0);
    }

    #[test]
    fn unwindowed_rt_reports_no_windows() {
        let trace = small_trace();
        let r = run_scheme(SchemeKind::Pkg, 4, &trace);
        assert!(r.windows.is_empty());
        assert_eq!(r.window_stats.panes_retired, 0);
    }

    #[test]
    fn final_only_flush_still_merges_everything() {
        let trace = small_trace();
        let mut cfg = Config::default();
        cfg.workers = 4;
        let sources: Vec<Box<dyn Grouper>> =
            (0..2).map(|s| make_kind(SchemeKind::Pkg, &cfg, s)).collect();
        let opts = RtOptions { agg_flush_ns: 0, ..Default::default() };
        let r = run(&trace, sources, 4, &opts);
        assert_eq!(r.merged.iter().map(|&(_, c)| c).sum::<u64>(), 20_000);
        // one shutdown drain per worker that saw traffic
        assert!(r.agg.flushes <= 4, "flushes {}", r.agg.flushes);
    }

    #[test]
    fn fg_state_is_partitioned_sg_state_is_replicated() {
        let trace = small_trace();
        let fg = run_scheme(SchemeKind::Field, 8, &trace);
        let sg = run_scheme(SchemeKind::Shuffle, 8, &trace);
        assert_eq!(fg.entries, fg.distinct_keys);
        assert!((fg.memory_normalized() - 1.0).abs() < 1e-9);
        assert!(
            sg.memory_normalized() > 1.5 * fg.memory_normalized(),
            "SG {}",
            sg.memory_normalized()
        );
    }

    #[test]
    fn backpressure_bounds_queues() {
        // tiny queues must not deadlock or drop tuples
        let trace = small_trace();
        let mut cfg = Config::default();
        cfg.workers = 4;
        cfg.scheme = SchemeKind::Shuffle;
        let sources: Vec<Box<dyn Grouper>> =
            (0..2).map(|s| make_kind(SchemeKind::Shuffle, &cfg, s)).collect();
        let opts = RtOptions { queue_depth: 2, ..Default::default() };
        let r = run(&trace, sources, 4, &opts);
        assert_eq!(r.worker_counts.iter().sum::<u64>(), 20_000);
    }

    #[test]
    fn tiny_batches_still_process_everything() {
        let trace = small_trace();
        let mut cfg = Config::default();
        cfg.workers = 4;
        let sources: Vec<Box<dyn Grouper>> =
            (0..3).map(|s| make_kind(SchemeKind::Pkg, &cfg, s)).collect();
        let opts = RtOptions { batch: 1, ..Default::default() };
        let r = run(&trace, sources, 4, &opts);
        assert_eq!(r.worker_counts.iter().sum::<u64>(), 20_000);
        assert_eq!(r.latency.count(), 20_000);
    }

    #[test]
    fn heterogeneous_burn_shifts_load_under_fish() {
        let trace = small_trace();
        let mut cfg = Config::default();
        cfg.workers = 4;
        cfg.scheme = SchemeKind::Fish;
        cfg.interval = 1_000_000;
        let sources: Vec<Box<dyn Grouper>> =
            (0..2).map(|s| make_kind(SchemeKind::Fish, &cfg, s)).collect();
        let opts = RtOptions {
            queue_depth: 256,
            per_tuple_ns: vec![4_000.0, 4_000.0, 1_000.0, 1_000.0],
            ..Default::default()
        };
        let r = run(&trace, sources, 4, &opts);
        assert_eq!(r.worker_counts.iter().sum::<u64>(), 20_000);
        let slow = r.worker_counts[0] + r.worker_counts[1];
        let fast = r.worker_counts[2] + r.worker_counts[3];
        assert!(fast > slow, "fast workers should absorb more: {fast} vs {slow}");
    }
}
