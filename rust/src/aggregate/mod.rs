//! Two-phase aggregation — the downstream stage that turns per-worker
//! partials into exact merged results.
//!
//! Every multi-choice grouping scheme in this repo (PKG, D-Choices,
//! W-Choices, FISH) deliberately splits a hot key across several
//! workers, so the per-worker counts the engines produce are *partial*
//! results. The PKG and D-C/W-C papers are explicit that a downstream
//! aggregation stage is required for correctness and is the price paid
//! for key splitting; this module is that stage:
//!
//! * [`Combiner`] — the per-key reduction algebra ([`Count`], [`Sum`],
//!   and approximate top-k via [`TopKSketch`], which reuses
//!   [`crate::sketch::SpaceSaving`] with weighted observes).
//! * [`PartialAgg`] — stage one: per-worker accumulators, drained into
//!   flush batches on a configurable interval
//!   ([`crate::config::Config::agg_flush_ms`], `--agg_flush_ms`).
//! * [`MergeStage`] — stage two: absorbs flush batches into the final
//!   merged map while metering the traffic key splitting costs
//!   ([`crate::metrics::AggStats`]: flushes, entries, payload bytes,
//!   merge time).
//! * [`shard`] — stage two *at scale*: a fabric of key-range-partitioned
//!   merge shards ([`ShardedMerge`] over a consistent-hash
//!   [`ShardRouter`], `--agg_shards`) with a scatter-gather top-k
//!   front-end ([`TopKGather`]) and per-shard imbalance accounting
//!   ([`crate::metrics::ShardAggStats`]).
//! * [`window`] — stage two *in time*: tumbling event-time panes over
//!   the fabric ([`WindowedPartial`] / [`WindowedMerge`],
//!   `--agg_window_ms`; 0 = unwindowed), retired by watermark advance
//!   into per-window exact counts + per-window [`TopKGather`]
//!   ([`WindowSnapshot`]), with [`sliding`] windows composed from
//!   panes and pane-lifecycle accounting in
//!   [`crate::metrics::WindowStats`]. [`next_boundary`] is the shared
//!   flush/pane cadence grid both engines snap to.
//!
//! Both engines wire this in: the simulator scatters virtual-time
//! flushes across the fabric deterministically, the runtime engine runs
//! one real aggregator thread per shard fed by per-worker-to-shard
//! flush channels. The `aggregation_oracle` integration tests pin the
//! end-to-end guarantee: merged counts — and, windowed, *per-window*
//! merged counts — are element-wise equal to a single-worker
//! Field-Grouping reference for every scheme, every flush cadence,
//! every shard count, and both engines.

pub mod combiner;
pub mod merge;
pub mod shard;
pub mod window;

pub use combiner::{Combiner, Count, Sum, TopKSketch};
pub use merge::{
    classify_seq, resume_cursor, top_k, FlushSequencer, MergeStage, PartialAgg, SeqClass,
    SeqDecision,
};
pub use shard::{GatherResult, ShardRouter, ShardedMerge, TopKGather, DEFAULT_GATHER_CAPACITY};
pub use window::{
    assemble_windows, next_boundary, sliding, window_of, MergeSnapshot, PaneState, WindowId,
    WindowResult, WindowSnapshot, WindowedMerge, WindowedOutput, WindowedPartial,
};
