//! The two stages themselves: per-worker partial state and the
//! downstream merge stage.
//!
//! Stage one ([`PartialAgg`]) lives wherever tuples are processed — a
//! worker thread in the runtime engine, a per-worker slot in the
//! simulator — and is periodically *flushed*: drained into a batch of
//! `(key, accumulator)` deltas shipped downstream. Stage two
//! ([`MergeStage`]) absorbs those batches into the final per-key
//! result and keeps the cost ledger ([`AggStats`]): how many flush
//! batches and entries crossed the stage boundary, the payload bytes,
//! and the wall time spent merging. This is the aggregation traffic
//! the PKG paper charges against key splitting — without it, the
//! per-worker counts every multi-choice scheme produces are only
//! partial results.

use super::combiner::Combiner;
use crate::metrics::AggStats;
use crate::Key;
use std::collections::{BTreeMap, HashMap};
use std::time::Instant;

/// Wire size of a key on the flush path.
const KEY_BYTES: usize = std::mem::size_of::<Key>();

/// Stage one: per-key partial accumulators since the last flush.
pub struct PartialAgg<C: Combiner> {
    combiner: C,
    state: HashMap<Key, C::Acc>,
}

impl<C: Combiner> PartialAgg<C> {
    /// Empty partial state folding through `combiner`.
    pub fn new(combiner: C) -> Self {
        PartialAgg { combiner, state: HashMap::new() }
    }

    /// Fold one tuple occurrence of `key` carrying `value`.
    #[inline]
    pub fn observe(&mut self, key: Key, value: u64) {
        let combiner = &self.combiner;
        let acc = self.state.entry(key).or_insert_with(|| combiner.identity());
        combiner.accumulate(acc, value);
    }

    /// Distinct keys accumulated since the last flush.
    pub fn len(&self) -> usize {
        self.state.len()
    }

    /// True when there is nothing to flush.
    pub fn is_empty(&self) -> bool {
        self.state.is_empty()
    }

    /// Current partial-state payload size in bytes (what a flush now
    /// would ship) — the partial-state-bytes metric.
    pub fn payload_bytes(&self) -> usize {
        self.state.len() * (KEY_BYTES + self.combiner.acc_bytes())
    }

    /// Drain the partial state into a flush batch, **ascending by key**.
    /// The partial is empty afterwards; accumulation starts over (delta
    /// semantics, so flushes at any cadence merge to the same final
    /// result).
    ///
    /// The sort is a determinism requirement, not cosmetics: `HashMap`
    /// drain order varies per instance (random hasher seeds), and once a
    /// downstream bounded sketch ([`crate::aggregate::TopKSketch`]) is
    /// at capacity, admission depends on arrival order — unsorted
    /// batches made gather rankings vary between identically-seeded
    /// runs. Flushing is off the per-tuple hot path, so the O(n log n)
    /// is paid where it is cheap.
    pub fn flush(&mut self) -> Vec<(Key, C::Acc)> {
        // sorted by key on the next line. lint: sorted-ok
        let mut batch: Vec<(Key, C::Acc)> = self.state.drain().collect();
        batch.sort_unstable_by_key(|&(k, _)| k);
        batch
    }
}

/// Stage two: the downstream aggregator state.
pub struct MergeStage<C: Combiner> {
    combiner: C,
    merged: HashMap<Key, C::Acc>,
    stats: AggStats,
}

impl<C: Combiner> MergeStage<C> {
    /// Empty merge stage folding through `combiner`.
    pub fn new(combiner: C) -> Self {
        MergeStage { combiner, merged: HashMap::new(), stats: AggStats::default() }
    }

    /// Absorb one flush batch, recording its traffic and merge time.
    pub fn absorb(&mut self, batch: Vec<(Key, C::Acc)>) {
        if batch.is_empty() {
            return;
        }
        let start = Instant::now();
        let entries = batch.len();
        for (key, acc) in batch {
            match self.merged.entry(key) {
                std::collections::hash_map::Entry::Occupied(mut o) => {
                    self.combiner.merge(o.get_mut(), &acc);
                }
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert(acc);
                }
            }
        }
        let bytes = entries * (KEY_BYTES + self.combiner.acc_bytes());
        self.stats.record_merge(entries, bytes, start.elapsed().as_nanos() as u64);
    }

    /// Distinct keys merged so far.
    pub fn len(&self) -> usize {
        self.merged.len()
    }

    /// True when nothing has been merged yet.
    pub fn is_empty(&self) -> bool {
        self.merged.is_empty()
    }

    /// Merged accumulator for `key`, if any flush mentioned it.
    pub fn get(&self, key: Key) -> Option<&C::Acc> {
        self.merged.get(&key)
    }

    /// Cost ledger so far.
    pub fn stats(&self) -> &AggStats {
        &self.stats
    }

    /// Finish: the merged map plus the cost ledger.
    pub fn into_parts(self) -> (HashMap<Key, C::Acc>, AggStats) {
        (self.merged, self.stats)
    }

    /// Finish into the canonical result shape: `(key, acc)` ascending by
    /// key (deterministic, directly comparable across runs and engines).
    pub fn into_sorted(self) -> (Vec<(Key, C::Acc)>, AggStats) {
        let (map, stats) = self.into_parts();
        let mut v: Vec<(Key, C::Acc)> = map.into_iter().collect();
        v.sort_unstable_by_key(|&(k, _)| k);
        (v, stats)
    }

    /// Snapshot export: the merged map as `(key, acc)` ascending by key
    /// *without* consuming the stage — the crash-recovery snapshot path,
    /// taken periodically while the stage keeps absorbing.
    pub fn sorted(&self) -> Vec<(Key, C::Acc)> {
        // sorted by key on the next line. lint: sorted-ok
        let mut v: Vec<(Key, C::Acc)> =
            self.merged.iter().map(|(&k, a)| (k, a.clone())).collect();
        v.sort_unstable_by_key(|&(k, _)| k);
        v
    }

    /// Rebuild a stage from a snapshot (`sorted` export + cost ledger).
    /// Restoring the ledger too keeps the deterministic stat fields
    /// (flushes/messages/bytes) of a recovered run equal to a run that
    /// never crashed.
    pub fn from_parts(combiner: C, entries: Vec<(Key, C::Acc)>, stats: AggStats) -> Self {
        MergeStage { combiner, merged: entries.into_iter().collect(), stats }
    }
}

/// Where one arriving sequence number falls relative to a stream's
/// `expected` cursor: the pure cursor-advance rule behind
/// [`FlushSequencer::offer`], shared verbatim with the recovery model
/// in [`crate::analysis::recovery`] so code and model cannot drift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqClass {
    /// `seq < expected`: already accepted once — a replay, drop it.
    Replay,
    /// `seq > expected`: ahead of a sequence gap — park it.
    Ahead,
    /// `seq == expected`: next in sequence — accept and advance.
    Next,
}

/// Classify `seq` against a stream's `expected` cursor.
#[inline]
pub fn classify_seq(expected: u64, seq: u64) -> SeqClass {
    if seq < expected {
        SeqClass::Replay
    } else if seq > expected {
        SeqClass::Ahead
    } else {
        SeqClass::Next
    }
}

/// The shard's `Resume` answer for `worker`: the first sequence number
/// it has not absorbed, from the restored per-worker cursor vector. A
/// worker the vector does not cover (topology grew since the snapshot)
/// replays from 0 — nothing of its stream was ever absorbed.
///
/// Shared verbatim by the socket `Resume` handshake, the simulator's
/// replay filter, and [`crate::analysis::recovery`].
#[inline]
pub fn resume_cursor(expected: &[u64], worker: usize) -> u64 {
    expected.get(worker).copied().unwrap_or(0)
}

/// What [`FlushSequencer::offer`] decided about one flush batch.
#[derive(Debug, PartialEq, Eq)]
pub enum SeqDecision<T> {
    /// Next-in-sequence: absorb the offered batch, then every parked
    /// successor it unblocked, in the order returned.
    Accept(Vec<T>),
    /// A batch with this sequence number was already accepted — a
    /// replay (a worker resending its flush log after a shard
    /// restart). Drop it; absorbing again would double count.
    Replayed,
    /// Ahead of a sequence gap: parked until the gap fills.
    Buffered,
}

/// Per-worker flush-stream sequencing at a merge shard: the dedup /
/// reorder half of the exactly-once guarantee (docs/RECOVERY.md).
///
/// Every worker numbers the flush batches it sends to each shard with
/// a per-(worker, shard) monotonic `seq` (see
/// [`crate::transport::FlushMsg`]). The shard offers each arriving
/// batch here before absorbing it: exactly `seq == expected` is
/// accepted (advancing `expected`), `seq > expected` is buffered until
/// the gap fills (cannot happen on one healthy FIFO stream, but
/// replays interleaved with live traffic after a reconnect can race),
/// and `seq < expected` is dropped as a replay. Absorb-side state plus
/// the `expected` vector are snapshotted together, so a restored shard
/// answers `Resume` with exactly the first seq it has not absorbed.
///
/// The derives matter beyond convenience: the recovery model checker
/// ([`crate::analysis::recovery`]) embeds `FlushSequencer` directly
/// inside its hashed protocol states, so the *production* cursor logic
/// is what gets exhaustively explored.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct FlushSequencer<T> {
    expected: Vec<u64>,
    ahead: Vec<BTreeMap<u64, T>>,
}

impl<T> FlushSequencer<T> {
    /// Fresh streams from `n_workers` workers, all expecting seq 0.
    pub fn new(n_workers: usize) -> Self {
        Self::restore(vec![0; n_workers])
    }

    /// Rebuild from a snapshot's per-worker expected-seq vector.
    pub fn restore(expected: Vec<u64>) -> Self {
        let n = expected.len();
        FlushSequencer { expected, ahead: (0..n).map(|_| BTreeMap::new()).collect() }
    }

    /// Next sequence number expected from `worker`.
    pub fn expected(&self, worker: usize) -> u64 {
        self.expected[worker]
    }

    /// The full per-worker expected-seq vector (snapshot payload).
    pub fn expected_all(&self) -> &[u64] {
        &self.expected
    }

    /// Batches currently parked ahead of a gap, across all workers.
    pub fn buffered(&self) -> usize {
        self.ahead.iter().map(|m| m.len()).sum()
    }

    /// Borrow every parked batch as `(worker, seq, &batch)`, ascending
    /// by (worker, seq) — the non-destructive view a periodic snapshot
    /// serializes while the sequencer keeps running.
    pub fn parked(&self) -> Vec<(usize, u64, &T)> {
        let mut out = Vec::new();
        for (w, m) in self.ahead.iter().enumerate() {
            for (seq, msg) in m {
                out.push((w, *seq, msg));
            }
        }
        out
    }

    /// Drain every parked batch as `(worker, seq, batch)`, ascending by
    /// (worker, seq) — the snapshot payload for in-flight reorder state.
    pub fn drain_buffered(&mut self) -> Vec<(usize, u64, T)> {
        let mut out = Vec::new();
        for (w, m) in self.ahead.iter_mut().enumerate() {
            for (seq, msg) in std::mem::take(m) {
                out.push((w, seq, msg));
            }
        }
        out
    }

    /// Classify one arriving batch from `worker` carrying `seq`.
    pub fn offer(&mut self, worker: usize, seq: u64, msg: T) -> SeqDecision<T> {
        match classify_seq(self.expected[worker], seq) {
            SeqClass::Replay => SeqDecision::Replayed,
            SeqClass::Ahead => {
                // a replayed duplicate of an already-parked seq just
                // overwrites its twin — same payload, absorbed once
                // either way
                self.ahead[worker].insert(seq, msg);
                SeqDecision::Buffered
            }
            SeqClass::Next => {
                self.expected[worker] = seq + 1;
                let mut out = vec![msg];
                while let Some(next) = self.ahead[worker].remove(&self.expected[worker]) {
                    self.expected[worker] += 1;
                    out.push(next);
                }
                SeqDecision::Accept(out)
            }
        }
    }

    /// Rebuild a sequencer from a snapshot's cursor vector and re-offer
    /// the batches the previous incarnation had parked ahead of a gap,
    /// in ascending `(worker, seq)` order (the order [`Self::parked`]
    /// serializes). Returns the restored sequencer plus every batch the
    /// re-offer accepted, in absorb order: a parked batch the restored
    /// cursors no longer block absorbs immediately, a stale one drops
    /// silently, and entries for workers outside the cursor vector
    /// (topology shrank) are skipped.
    ///
    /// This is the shard-restore rule — shared verbatim by the rt shard
    /// loop, the simulator's `kill_shard`, and the recovery model.
    pub fn restore_replaying(
        expected: Vec<u64>,
        parked: impl IntoIterator<Item = (usize, u64, T)>,
    ) -> (Self, Vec<T>) {
        let n = expected.len();
        let mut seq = Self::restore(expected);
        let mut accepted = Vec::new();
        for (worker, s, msg) in parked {
            if worker >= n {
                continue;
            }
            if let SeqDecision::Accept(batch) = seq.offer(worker, s, msg) {
                accepted.extend(batch);
            }
        }
        (seq, accepted)
    }
}

/// Exact top-k over a merged count vector: highest count first, ties
/// broken by key ascending (total order ⇒ deterministic rankings).
pub fn top_k(counts: &[(Key, u64)], k: usize) -> Vec<(Key, u64)> {
    let mut v = counts.to_vec();
    v.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    v.truncate(k);
    v
}

#[cfg(test)]
mod tests {
    use super::super::combiner::{Count, Sum};
    use super::*;

    #[test]
    fn flush_drains_and_merge_reassembles() {
        let mut p = PartialAgg::new(Count);
        for k in [1u64, 2, 1, 3, 1, 2] {
            p.observe(k, 1);
        }
        assert_eq!(p.len(), 3);
        assert_eq!(p.payload_bytes(), 3 * 16);

        let mut m = MergeStage::new(Count);
        m.absorb(p.flush());
        assert!(p.is_empty());
        // second wave through the same partial
        p.observe(1, 1);
        p.observe(4, 1);
        m.absorb(p.flush());

        assert_eq!(m.get(1), Some(&4));
        assert_eq!(m.get(2), Some(&2));
        assert_eq!(m.get(4), Some(&1));
        let (sorted, stats) = m.into_sorted();
        assert_eq!(sorted, vec![(1, 4), (2, 2), (3, 1), (4, 1)]);
        assert_eq!(stats.flushes, 2);
        assert_eq!(stats.messages, 5);
        assert_eq!(stats.bytes, 5 * 16);
    }

    #[test]
    fn merge_is_flush_cadence_invariant() {
        // Same stream, different flush points → identical merged output.
        let keys: Vec<Key> = (0..500u64).map(|i| i % 13).collect();
        let run = |flush_every: usize| {
            let mut p = PartialAgg::new(Count);
            let mut m = MergeStage::new(Count);
            for (i, &k) in keys.iter().enumerate() {
                p.observe(k, 1);
                if (i + 1) % flush_every == 0 {
                    m.absorb(p.flush());
                }
            }
            m.absorb(p.flush());
            m.into_sorted().0
        };
        assert_eq!(run(1), run(7));
        assert_eq!(run(7), run(500));
    }

    #[test]
    fn partials_from_many_workers_merge_to_stream_totals() {
        // Scatter a stream over 4 "workers" round-robin (worst-case key
        // splitting) and check the merge reassembles exact totals.
        let mut workers: Vec<PartialAgg<Count>> = (0..4).map(|_| PartialAgg::new(Count)).collect();
        let mut truth: HashMap<Key, u64> = HashMap::new();
        for i in 0..1_000u64 {
            let k = i % 17;
            workers[(i % 4) as usize].observe(k, 1);
            *truth.entry(k).or_insert(0) += 1;
        }
        let mut m = MergeStage::new(Count);
        for w in workers.iter_mut() {
            m.absorb(w.flush());
        }
        let (merged, stats) = m.into_sorted();
        assert_eq!(merged.len(), truth.len());
        for &(k, c) in &merged {
            assert_eq!(c, truth[&k], "key {k}");
        }
        assert_eq!(stats.flushes, 4);
    }

    #[test]
    fn flush_batches_are_sorted_by_key() {
        // Two identically-fed partials are distinct HashMap instances
        // (different hasher seeds), so only the sort makes their flush
        // batches — and therefore downstream sketch admission — agree.
        let feed = || {
            let mut p = PartialAgg::new(Count);
            for k in [9u64, 1, 5, 1, 3, 9, 7, 2] {
                p.observe(k, 1);
            }
            p.flush()
        };
        let (a, b) = (feed(), feed());
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].0 < w[1].0), "not key-ascending: {a:?}");
    }

    #[test]
    fn sum_combiner_flows_values_through_both_stages() {
        let mut p = PartialAgg::new(Sum);
        p.observe(9, 10);
        p.observe(9, 32);
        let mut m = MergeStage::new(Sum);
        m.absorb(p.flush());
        p.observe(9, 58);
        m.absorb(p.flush());
        assert_eq!(m.get(9), Some(&100));
    }

    #[test]
    fn empty_flushes_cost_nothing() {
        let mut m = MergeStage::new(Count);
        m.absorb(Vec::new());
        assert_eq!(m.stats().flushes, 0);
        assert_eq!(m.stats().messages, 0);
        assert!(m.is_empty());
    }

    #[test]
    fn sequencer_accepts_next_buffers_ahead_drops_replayed() {
        let mut s: FlushSequencer<&str> = FlushSequencer::new(2);
        assert_eq!(s.offer(0, 0, "a"), SeqDecision::Accept(vec!["a"]));
        // ahead of the gap: parked, not absorbed
        assert_eq!(s.offer(0, 2, "c"), SeqDecision::Buffered);
        assert_eq!(s.buffered(), 1);
        // the gap fills: both come back, in order
        assert_eq!(s.offer(0, 1, "b"), SeqDecision::Accept(vec!["b", "c"]));
        assert_eq!(s.expected(0), 3);
        assert_eq!(s.buffered(), 0);
        // replays of anything already accepted are dropped
        for seq in 0..3 {
            assert_eq!(s.offer(0, seq, "dup"), SeqDecision::Replayed);
        }
        // streams are independent per worker
        assert_eq!(s.expected(1), 0);
        assert_eq!(s.offer(1, 0, "x"), SeqDecision::Accept(vec!["x"]));
        assert_eq!(s.expected_all(), &[3, 1]);
    }

    #[test]
    fn sequencer_restores_from_snapshot_vector() {
        let mut s: FlushSequencer<u32> = FlushSequencer::restore(vec![5, 0]);
        // a worker replaying its whole log after the shard restored:
        // everything below the snapshot point is deduped, the rest flows
        for seq in 0..5 {
            assert_eq!(s.offer(0, seq, seq as u32), SeqDecision::Replayed);
        }
        assert_eq!(s.offer(0, 5, 5), SeqDecision::Accept(vec![5]));
        // parked batches drain for snapshotting, ascending by seq
        assert_eq!(s.offer(1, 2, 92), SeqDecision::Buffered);
        assert_eq!(s.offer(1, 1, 91), SeqDecision::Buffered);
        assert_eq!(s.drain_buffered(), vec![(1, 1, 91), (1, 2, 92)]);
        assert_eq!(s.buffered(), 0);
    }

    #[test]
    fn classify_seq_is_the_cursor_advance_rule() {
        assert_eq!(classify_seq(3, 2), SeqClass::Replay);
        assert_eq!(classify_seq(3, 3), SeqClass::Next);
        assert_eq!(classify_seq(3, 4), SeqClass::Ahead);
        assert_eq!(classify_seq(0, 0), SeqClass::Next);
    }

    #[test]
    fn resume_cursor_answers_from_the_vector_and_zero_beyond_it() {
        let expected = vec![5u64, 0, 2];
        assert_eq!(resume_cursor(&expected, 0), 5);
        assert_eq!(resume_cursor(&expected, 1), 0);
        assert_eq!(resume_cursor(&expected, 2), 2);
        // a worker the snapshot never saw replays from scratch
        assert_eq!(resume_cursor(&expected, 3), 0);
        assert_eq!(resume_cursor(&[], 0), 0);
    }

    #[test]
    fn restore_replaying_reoffers_parked_batches() {
        let parked = vec![
            (0usize, 1u64, "stale"), // below the restored cursor: dropped
            (0, 2, "next"),          // exactly the cursor: absorbs
            (0, 4, "gap"),           // still ahead of a gap: re-parked
            (1, 0, "w1"),            // other stream, next-in-seq
            (5, 0, "oob"),           // worker outside the vector: skipped
        ];
        let (seq, accepted) = FlushSequencer::restore_replaying(vec![2, 0], parked);
        assert_eq!(accepted, vec!["next", "w1"]);
        assert_eq!(seq.expected_all(), &[3, 1]);
        assert_eq!(seq.buffered(), 1);
        assert_eq!(seq.parked(), vec![(0, 4, &"gap")]);
    }

    #[test]
    fn top_k_orders_by_count_then_key() {
        let counts = vec![(5u64, 3u64), (1, 7), (9, 3), (2, 1)];
        assert_eq!(top_k(&counts, 3), vec![(1, 7), (5, 3), (9, 3)]);
        assert_eq!(top_k(&counts, 0), Vec::<(Key, u64)>::new());
        assert_eq!(top_k(&counts, 99).len(), 4);
    }
}
