//! The two stages themselves: per-worker partial state and the
//! downstream merge stage.
//!
//! Stage one ([`PartialAgg`]) lives wherever tuples are processed — a
//! worker thread in the runtime engine, a per-worker slot in the
//! simulator — and is periodically *flushed*: drained into a batch of
//! `(key, accumulator)` deltas shipped downstream. Stage two
//! ([`MergeStage`]) absorbs those batches into the final per-key
//! result and keeps the cost ledger ([`AggStats`]): how many flush
//! batches and entries crossed the stage boundary, the payload bytes,
//! and the wall time spent merging. This is the aggregation traffic
//! the PKG paper charges against key splitting — without it, the
//! per-worker counts every multi-choice scheme produces are only
//! partial results.

use super::combiner::Combiner;
use crate::metrics::AggStats;
use crate::Key;
use std::collections::HashMap;
use std::time::Instant;

/// Wire size of a key on the flush path.
const KEY_BYTES: usize = std::mem::size_of::<Key>();

/// Stage one: per-key partial accumulators since the last flush.
pub struct PartialAgg<C: Combiner> {
    combiner: C,
    state: HashMap<Key, C::Acc>,
}

impl<C: Combiner> PartialAgg<C> {
    /// Empty partial state folding through `combiner`.
    pub fn new(combiner: C) -> Self {
        PartialAgg { combiner, state: HashMap::new() }
    }

    /// Fold one tuple occurrence of `key` carrying `value`.
    #[inline]
    pub fn observe(&mut self, key: Key, value: u64) {
        let combiner = &self.combiner;
        let acc = self.state.entry(key).or_insert_with(|| combiner.identity());
        combiner.accumulate(acc, value);
    }

    /// Distinct keys accumulated since the last flush.
    pub fn len(&self) -> usize {
        self.state.len()
    }

    /// True when there is nothing to flush.
    pub fn is_empty(&self) -> bool {
        self.state.is_empty()
    }

    /// Current partial-state payload size in bytes (what a flush now
    /// would ship) — the partial-state-bytes metric.
    pub fn payload_bytes(&self) -> usize {
        self.state.len() * (KEY_BYTES + self.combiner.acc_bytes())
    }

    /// Drain the partial state into a flush batch, **ascending by key**.
    /// The partial is empty afterwards; accumulation starts over (delta
    /// semantics, so flushes at any cadence merge to the same final
    /// result).
    ///
    /// The sort is a determinism requirement, not cosmetics: `HashMap`
    /// drain order varies per instance (random hasher seeds), and once a
    /// downstream bounded sketch ([`crate::aggregate::TopKSketch`]) is
    /// at capacity, admission depends on arrival order — unsorted
    /// batches made gather rankings vary between identically-seeded
    /// runs. Flushing is off the per-tuple hot path, so the O(n log n)
    /// is paid where it is cheap.
    pub fn flush(&mut self) -> Vec<(Key, C::Acc)> {
        // sorted by key on the next line. lint: sorted-ok
        let mut batch: Vec<(Key, C::Acc)> = self.state.drain().collect();
        batch.sort_unstable_by_key(|&(k, _)| k);
        batch
    }
}

/// Stage two: the downstream aggregator state.
pub struct MergeStage<C: Combiner> {
    combiner: C,
    merged: HashMap<Key, C::Acc>,
    stats: AggStats,
}

impl<C: Combiner> MergeStage<C> {
    /// Empty merge stage folding through `combiner`.
    pub fn new(combiner: C) -> Self {
        MergeStage { combiner, merged: HashMap::new(), stats: AggStats::default() }
    }

    /// Absorb one flush batch, recording its traffic and merge time.
    pub fn absorb(&mut self, batch: Vec<(Key, C::Acc)>) {
        if batch.is_empty() {
            return;
        }
        let start = Instant::now();
        let entries = batch.len();
        for (key, acc) in batch {
            match self.merged.entry(key) {
                std::collections::hash_map::Entry::Occupied(mut o) => {
                    self.combiner.merge(o.get_mut(), &acc);
                }
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert(acc);
                }
            }
        }
        let bytes = entries * (KEY_BYTES + self.combiner.acc_bytes());
        self.stats.record_merge(entries, bytes, start.elapsed().as_nanos() as u64);
    }

    /// Distinct keys merged so far.
    pub fn len(&self) -> usize {
        self.merged.len()
    }

    /// True when nothing has been merged yet.
    pub fn is_empty(&self) -> bool {
        self.merged.is_empty()
    }

    /// Merged accumulator for `key`, if any flush mentioned it.
    pub fn get(&self, key: Key) -> Option<&C::Acc> {
        self.merged.get(&key)
    }

    /// Cost ledger so far.
    pub fn stats(&self) -> &AggStats {
        &self.stats
    }

    /// Finish: the merged map plus the cost ledger.
    pub fn into_parts(self) -> (HashMap<Key, C::Acc>, AggStats) {
        (self.merged, self.stats)
    }

    /// Finish into the canonical result shape: `(key, acc)` ascending by
    /// key (deterministic, directly comparable across runs and engines).
    pub fn into_sorted(self) -> (Vec<(Key, C::Acc)>, AggStats) {
        let (map, stats) = self.into_parts();
        let mut v: Vec<(Key, C::Acc)> = map.into_iter().collect();
        v.sort_unstable_by_key(|&(k, _)| k);
        (v, stats)
    }
}

/// Exact top-k over a merged count vector: highest count first, ties
/// broken by key ascending (total order ⇒ deterministic rankings).
pub fn top_k(counts: &[(Key, u64)], k: usize) -> Vec<(Key, u64)> {
    let mut v = counts.to_vec();
    v.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    v.truncate(k);
    v
}

#[cfg(test)]
mod tests {
    use super::super::combiner::{Count, Sum};
    use super::*;

    #[test]
    fn flush_drains_and_merge_reassembles() {
        let mut p = PartialAgg::new(Count);
        for k in [1u64, 2, 1, 3, 1, 2] {
            p.observe(k, 1);
        }
        assert_eq!(p.len(), 3);
        assert_eq!(p.payload_bytes(), 3 * 16);

        let mut m = MergeStage::new(Count);
        m.absorb(p.flush());
        assert!(p.is_empty());
        // second wave through the same partial
        p.observe(1, 1);
        p.observe(4, 1);
        m.absorb(p.flush());

        assert_eq!(m.get(1), Some(&4));
        assert_eq!(m.get(2), Some(&2));
        assert_eq!(m.get(4), Some(&1));
        let (sorted, stats) = m.into_sorted();
        assert_eq!(sorted, vec![(1, 4), (2, 2), (3, 1), (4, 1)]);
        assert_eq!(stats.flushes, 2);
        assert_eq!(stats.messages, 5);
        assert_eq!(stats.bytes, 5 * 16);
    }

    #[test]
    fn merge_is_flush_cadence_invariant() {
        // Same stream, different flush points → identical merged output.
        let keys: Vec<Key> = (0..500u64).map(|i| i % 13).collect();
        let run = |flush_every: usize| {
            let mut p = PartialAgg::new(Count);
            let mut m = MergeStage::new(Count);
            for (i, &k) in keys.iter().enumerate() {
                p.observe(k, 1);
                if (i + 1) % flush_every == 0 {
                    m.absorb(p.flush());
                }
            }
            m.absorb(p.flush());
            m.into_sorted().0
        };
        assert_eq!(run(1), run(7));
        assert_eq!(run(7), run(500));
    }

    #[test]
    fn partials_from_many_workers_merge_to_stream_totals() {
        // Scatter a stream over 4 "workers" round-robin (worst-case key
        // splitting) and check the merge reassembles exact totals.
        let mut workers: Vec<PartialAgg<Count>> = (0..4).map(|_| PartialAgg::new(Count)).collect();
        let mut truth: HashMap<Key, u64> = HashMap::new();
        for i in 0..1_000u64 {
            let k = i % 17;
            workers[(i % 4) as usize].observe(k, 1);
            *truth.entry(k).or_insert(0) += 1;
        }
        let mut m = MergeStage::new(Count);
        for w in workers.iter_mut() {
            m.absorb(w.flush());
        }
        let (merged, stats) = m.into_sorted();
        assert_eq!(merged.len(), truth.len());
        for &(k, c) in &merged {
            assert_eq!(c, truth[&k], "key {k}");
        }
        assert_eq!(stats.flushes, 4);
    }

    #[test]
    fn flush_batches_are_sorted_by_key() {
        // Two identically-fed partials are distinct HashMap instances
        // (different hasher seeds), so only the sort makes their flush
        // batches — and therefore downstream sketch admission — agree.
        let feed = || {
            let mut p = PartialAgg::new(Count);
            for k in [9u64, 1, 5, 1, 3, 9, 7, 2] {
                p.observe(k, 1);
            }
            p.flush()
        };
        let (a, b) = (feed(), feed());
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].0 < w[1].0), "not key-ascending: {a:?}");
    }

    #[test]
    fn sum_combiner_flows_values_through_both_stages() {
        let mut p = PartialAgg::new(Sum);
        p.observe(9, 10);
        p.observe(9, 32);
        let mut m = MergeStage::new(Sum);
        m.absorb(p.flush());
        p.observe(9, 58);
        m.absorb(p.flush());
        assert_eq!(m.get(9), Some(&100));
    }

    #[test]
    fn empty_flushes_cost_nothing() {
        let mut m = MergeStage::new(Count);
        m.absorb(Vec::new());
        assert_eq!(m.stats().flushes, 0);
        assert_eq!(m.stats().messages, 0);
        assert!(m.is_empty());
    }

    #[test]
    fn top_k_orders_by_count_then_key() {
        let counts = vec![(5u64, 3u64), (1, 7), (9, 3), (2, 1)];
        assert_eq!(top_k(&counts, 3), vec![(1, 7), (5, 3), (9, 3)]);
        assert_eq!(top_k(&counts, 0), Vec::<(Key, u64)>::new());
        assert_eq!(top_k(&counts, 99).len(), 4);
    }
}
