//! Combiners: the per-key reduction applied at both aggregation stages.
//!
//! A [`Combiner`] is the algebra of the two-phase topology — workers
//! fold tuples into per-key *partial* accumulators with
//! [`Combiner::accumulate`], and the downstream merge stage folds
//! flushed partials into the *final* accumulator with
//! [`Combiner::merge`]. Correctness of the split (PKG / D-C / W-C /
//! FISH all scatter one key over several workers) only needs `merge`
//! to be commutative, associative and identity-respecting; every
//! combiner here satisfies that, so merged results are independent of
//! flush timing and worker interleaving (pinned by the
//! `aggregation_oracle` integration tests).

use crate::sketch::SpaceSaving;
use crate::Key;

/// A commutative-monoid reduction over per-key accumulators.
pub trait Combiner: Send {
    /// Per-key accumulator state.
    type Acc: Clone + Send + 'static;

    /// Combiner identity (for reports).
    fn name(&self) -> &'static str;

    /// The neutral accumulator (`merge(identity, x) == x`).
    fn identity(&self) -> Self::Acc;

    /// Fold one tuple occurrence carrying `value` into `acc`
    /// (stage one: runs on the worker holding the partial).
    fn accumulate(&self, acc: &mut Self::Acc, value: u64);

    /// Fold a flushed partial into a downstream accumulator
    /// (stage two: runs on the aggregator).
    fn merge(&self, into: &mut Self::Acc, other: &Self::Acc);

    /// Wire size of one accumulator (payload accounting for the
    /// aggregation-traffic metric).
    fn acc_bytes(&self) -> usize {
        std::mem::size_of::<Self::Acc>()
    }

    /// Tuple mass an accumulator carries — the units of the
    /// late-reopen-mass ledger. Defaults to 1 (one re-merged entry);
    /// `Count` reports the tuple count itself so the ledger reads in
    /// tuples, not entries.
    fn acc_mass(&self, _acc: &Self::Acc) -> u64 {
        1
    }
}

/// Count tuples per key — the word-count topology both engines run.
#[derive(Debug, Clone, Copy, Default)]
pub struct Count;

impl Combiner for Count {
    type Acc = u64;

    fn name(&self) -> &'static str {
        "count"
    }

    fn identity(&self) -> u64 {
        0
    }

    fn accumulate(&self, acc: &mut u64, _value: u64) {
        *acc += 1;
    }

    fn merge(&self, into: &mut u64, other: &u64) {
        *into += *other;
    }

    fn acc_mass(&self, acc: &u64) -> u64 {
        *acc
    }
}

/// Sum tuple values per key (e.g. bytes, click weights).
#[derive(Debug, Clone, Copy, Default)]
pub struct Sum;

impl Combiner for Sum {
    type Acc = u64;

    fn name(&self) -> &'static str {
        "sum"
    }

    fn identity(&self) -> u64 {
        0
    }

    fn accumulate(&self, acc: &mut u64, value: u64) {
        *acc += value;
    }

    fn merge(&self, into: &mut u64, other: &u64) {
        *into += *other;
    }
}

/// Bounded-memory approximate top-k over merged flushes, reusing the
/// [`SpaceSaving`] counter set from [`crate::sketch`] with *weighted*
/// observes: one flushed partial `(key, n)` lands as a single
/// `observe_weighted(key, n)` instead of `n` unit observes, so the
/// aggregator can answer trending-key queries in O(K) memory even when
/// the merged key space is far larger than `capacity`.
///
/// SpaceSaving's overestimate guarantee survives weighting (a newcomer
/// inherits `c_min + w`), so a genuinely hot key is never under-ranked;
/// the `topk_trending` example cross-checks this against the exact
/// merged counts.
#[derive(Debug, Clone)]
pub struct TopKSketch {
    sketch: SpaceSaving,
    /// Overestimate carried in from [`TopKSketch::merge`]d sketches:
    /// a folded entry's estimate already includes the source sketch's
    /// error, which this sketch's own `min_count` knows nothing about,
    /// so the merged bound is the *sum* of both sides' bounds.
    merged_error: f64,
}

impl TopKSketch {
    /// Track at most `capacity` candidate keys.
    pub fn new(capacity: usize) -> Self {
        TopKSketch { sketch: SpaceSaving::new(capacity), merged_error: 0.0 }
    }

    /// Absorb one flushed partial: `key` gained `weight` mass.
    pub fn absorb(&mut self, key: Key, weight: u64) {
        if weight > 0 {
            self.sketch.observe_weighted(key, weight as f64);
        }
    }

    /// The `k` highest-mass keys, descending (estimates, not exact).
    pub fn top(&self, k: usize) -> Vec<(Key, f64)> {
        self.sketch.top_n(k)
    }

    /// Estimated mass of `key` (0 if untracked).
    pub fn estimate(&self, key: Key) -> f64 {
        self.sketch.estimate(key)
    }

    /// Tracked candidate entries (control-plane memory).
    pub fn entries(&self) -> usize {
        self.sketch.entries()
    }

    /// Fold another sketch's tracked mass into this one: each of
    /// `other`'s `(key, estimate)` entries lands as one weighted
    /// observe. Estimates stay overestimates, and `other`'s own
    /// overestimate bound is folded into [`TopKSketch::error_bound`]
    /// (a merged entry's estimate already carries the source sketch's
    /// error, which this side's `min_count` cannot see — the sound
    /// merged bound is the sum of both sides' bounds). Used when a
    /// reopened window pane re-finalizes into the first emission's
    /// sketch, and by sliding-window gather composition.
    pub fn merge(&mut self, other: &TopKSketch) {
        for (key, est) in other.sketch.iter() {
            if est > 0.0 {
                self.sketch.observe_weighted(key, est);
            }
        }
        self.merged_error += other.error_bound();
    }

    /// Counter-set capacity this sketch was built with.
    pub fn capacity(&self) -> usize {
        self.sketch.capacity()
    }

    /// Tracked `(key, estimate)` entries — the serializable state a
    /// multi-process shard ships back to the coordinator.
    pub fn tracked(&self) -> impl Iterator<Item = (Key, f64)> + '_ {
        self.sketch.iter()
    }

    /// Error inherited from merged sketches (travels next to the
    /// tracked entries when a sketch is serialized).
    pub fn merged_error(&self) -> f64 {
        self.merged_error
    }

    /// Rebuild a sketch from its serialized parts. Re-observing each
    /// tracked entry at its estimate is faithful: a sketch of the same
    /// capacity admits all of them without eviction, so estimates and
    /// the error bound come back exactly.
    pub fn from_parts(capacity: usize, entries: &[(Key, f64)], merged_error: f64) -> Self {
        let mut s = TopKSketch::new(capacity);
        for &(k, w) in entries {
            if w > 0.0 {
                s.sketch.observe_weighted(k, w);
            }
        }
        s.merged_error = merged_error;
        s
    }

    /// Overestimate bound for this sketch's estimates: 0 while under
    /// capacity (estimates are exact), else the minimum tracked count —
    /// plus the bounds inherited from any [`TopKSketch::merge`]d
    /// sketches. Every estimate `e` satisfies
    /// `true ≤ e ≤ true + error_bound()`, and any untracked key's true
    /// mass is ≤ `error_bound()`. This is the per-shard term in the
    /// scatter-gather rank-error bound
    /// ([`crate::aggregate::TopKGather::error_bound`]).
    pub fn error_bound(&self) -> f64 {
        let own = if self.sketch.at_capacity() { self.sketch.min_count() } else { 0.0 };
        own + self.merged_error
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_ignores_value_sum_uses_it() {
        let c = Count;
        let mut a = c.identity();
        c.accumulate(&mut a, 999);
        c.accumulate(&mut a, 0);
        assert_eq!(a, 2);

        let s = Sum;
        let mut b = s.identity();
        s.accumulate(&mut b, 999);
        s.accumulate(&mut b, 1);
        assert_eq!(b, 1000);
    }

    #[test]
    fn merge_is_commutative_and_respects_identity() {
        let c = Count;
        let (mut x, mut y) = (5u64, 9u64);
        let (xs, ys) = (x, y);
        c.merge(&mut x, &ys);
        c.merge(&mut y, &xs);
        assert_eq!(x, y);
        let mut id = c.identity();
        c.merge(&mut id, &x);
        assert_eq!(id, x);
    }

    #[test]
    fn topk_sketch_weighted_matches_unit_observes_on_hot_keys() {
        // Feeding (key, n) once must rank hot keys the same as feeding
        // the key n times — the property that makes flush-batch absorbs
        // sound.
        let mut weighted = TopKSketch::new(8);
        let mut exact: std::collections::HashMap<Key, u64> = std::collections::HashMap::new();
        let flushes: &[(Key, u64)] = &[(1, 50), (2, 30), (3, 5), (1, 25), (4, 2), (2, 10)];
        for &(k, n) in flushes {
            weighted.absorb(k, n);
            *exact.entry(k).or_insert(0) += n;
        }
        let top = weighted.top(2);
        assert_eq!(top[0].0, 1);
        assert_eq!(top[1].0, 2);
        assert!(weighted.estimate(1) >= exact[&1] as f64);
        assert!(weighted.estimate(2) >= exact[&2] as f64);
    }

    #[test]
    fn topk_sketch_merge_keeps_overestimates() {
        let mut a = TopKSketch::new(8);
        let mut b = TopKSketch::new(8);
        for (k, n) in [(1u64, 40), (2, 10)] {
            a.absorb(k, n);
        }
        for (k, n) in [(1u64, 5), (3, 20)] {
            b.absorb(k, n);
        }
        a.merge(&b);
        assert!(a.estimate(1) >= 45.0);
        assert!(a.estimate(2) >= 10.0);
        assert!(a.estimate(3) >= 20.0);
        assert_eq!(a.top(1)[0].0, 1);
    }

    #[test]
    fn topk_sketch_merge_bound_covers_both_sides_errors() {
        // Capacity-2 sketches: each side evicts, so each carries its own
        // overestimate; the merged bound must cover the sum — a merged
        // entry's estimate already includes the source sketch's error,
        // which the destination's min_count alone cannot see.
        let feed = |pairs: &[(Key, u64)]| {
            let mut s = TopKSketch::new(2);
            for &(k, n) in pairs {
                s.absorb(k, n);
            }
            s
        };
        let mut a = feed(&[(1, 10), (2, 4), (3, 6)]); // evicts: bound > 0
        let b = feed(&[(4, 8), (5, 3), (6, 5)]); // evicts: bound > 0
        let (a_bound, b_bound) = (a.error_bound(), b.error_bound());
        assert!(a_bound > 0.0 && b_bound > 0.0);
        a.merge(&b);
        assert!(
            a.error_bound() >= a_bound.max(b_bound),
            "merged bound {} must cover both sides' bounds ({a_bound}, {b_bound})",
            a.error_bound()
        );
        // the guarantee itself: every estimate within truth + bound
        let truth: std::collections::HashMap<Key, u64> =
            [(1u64, 10u64), (2, 4), (3, 6), (4, 8), (5, 3), (6, 5)].into_iter().collect();
        for (k, est) in [1u64, 2, 3, 4, 5, 6]
            .iter()
            .map(|&k| (k, a.estimate(k)))
            .filter(|&(_, e)| e > 0.0)
        {
            assert!(
                est <= truth[&k] as f64 + a.error_bound() + 1e-9,
                "key {k}: {est} exceeds {} + {}",
                truth[&k],
                a.error_bound()
            );
        }
    }

    #[test]
    fn topk_sketch_rebuilds_exactly_from_parts() {
        let mut orig = TopKSketch::new(4);
        for (k, n) in [(1u64, 40), (2, 10), (3, 7), (4, 3), (5, 9)] {
            orig.absorb(k, n);
        }
        let mut other = TopKSketch::new(4);
        other.absorb(9, 100);
        orig.merge(&other);
        let parts: Vec<(Key, f64)> = orig.tracked().collect();
        let back = TopKSketch::from_parts(orig.capacity(), &parts, orig.merged_error());
        assert_eq!(back.capacity(), orig.capacity());
        assert_eq!(back.entries(), orig.entries());
        assert_eq!(back.error_bound(), orig.error_bound());
        for &(k, est) in &parts {
            assert_eq!(back.estimate(k), est, "key {k}");
        }
        assert_eq!(back.top(4), orig.top(4));
    }

    #[test]
    fn topk_sketch_bounds_memory() {
        let mut t = TopKSketch::new(16);
        for k in 0..10_000u64 {
            t.absorb(k, 1 + k % 7);
        }
        assert!(t.entries() <= 16);
    }
}
