//! Combiners: the per-key reduction applied at both aggregation stages.
//!
//! A [`Combiner`] is the algebra of the two-phase topology — workers
//! fold tuples into per-key *partial* accumulators with
//! [`Combiner::accumulate`], and the downstream merge stage folds
//! flushed partials into the *final* accumulator with
//! [`Combiner::merge`]. Correctness of the split (PKG / D-C / W-C /
//! FISH all scatter one key over several workers) only needs `merge`
//! to be commutative, associative and identity-respecting; every
//! combiner here satisfies that, so merged results are independent of
//! flush timing and worker interleaving (pinned by the
//! `aggregation_oracle` integration tests).

use crate::sketch::SpaceSaving;
use crate::Key;

/// A commutative-monoid reduction over per-key accumulators.
pub trait Combiner: Send {
    /// Per-key accumulator state.
    type Acc: Clone + Send + 'static;

    /// Combiner identity (for reports).
    fn name(&self) -> &'static str;

    /// The neutral accumulator (`merge(identity, x) == x`).
    fn identity(&self) -> Self::Acc;

    /// Fold one tuple occurrence carrying `value` into `acc`
    /// (stage one: runs on the worker holding the partial).
    fn accumulate(&self, acc: &mut Self::Acc, value: u64);

    /// Fold a flushed partial into a downstream accumulator
    /// (stage two: runs on the aggregator).
    fn merge(&self, into: &mut Self::Acc, other: &Self::Acc);

    /// Wire size of one accumulator (payload accounting for the
    /// aggregation-traffic metric).
    fn acc_bytes(&self) -> usize {
        std::mem::size_of::<Self::Acc>()
    }
}

/// Count tuples per key — the word-count topology both engines run.
#[derive(Debug, Clone, Copy, Default)]
pub struct Count;

impl Combiner for Count {
    type Acc = u64;

    fn name(&self) -> &'static str {
        "count"
    }

    fn identity(&self) -> u64 {
        0
    }

    fn accumulate(&self, acc: &mut u64, _value: u64) {
        *acc += 1;
    }

    fn merge(&self, into: &mut u64, other: &u64) {
        *into += *other;
    }
}

/// Sum tuple values per key (e.g. bytes, click weights).
#[derive(Debug, Clone, Copy, Default)]
pub struct Sum;

impl Combiner for Sum {
    type Acc = u64;

    fn name(&self) -> &'static str {
        "sum"
    }

    fn identity(&self) -> u64 {
        0
    }

    fn accumulate(&self, acc: &mut u64, value: u64) {
        *acc += value;
    }

    fn merge(&self, into: &mut u64, other: &u64) {
        *into += *other;
    }
}

/// Bounded-memory approximate top-k over merged flushes, reusing the
/// [`SpaceSaving`] counter set from [`crate::sketch`] with *weighted*
/// observes: one flushed partial `(key, n)` lands as a single
/// `observe_weighted(key, n)` instead of `n` unit observes, so the
/// aggregator can answer trending-key queries in O(K) memory even when
/// the merged key space is far larger than `capacity`.
///
/// SpaceSaving's overestimate guarantee survives weighting (a newcomer
/// inherits `c_min + w`), so a genuinely hot key is never under-ranked;
/// the `topk_trending` example cross-checks this against the exact
/// merged counts.
#[derive(Debug, Clone)]
pub struct TopKSketch {
    sketch: SpaceSaving,
}

impl TopKSketch {
    /// Track at most `capacity` candidate keys.
    pub fn new(capacity: usize) -> Self {
        TopKSketch { sketch: SpaceSaving::new(capacity) }
    }

    /// Absorb one flushed partial: `key` gained `weight` mass.
    pub fn absorb(&mut self, key: Key, weight: u64) {
        if weight > 0 {
            self.sketch.observe_weighted(key, weight as f64);
        }
    }

    /// The `k` highest-mass keys, descending (estimates, not exact).
    pub fn top(&self, k: usize) -> Vec<(Key, f64)> {
        self.sketch.top_n(k)
    }

    /// Estimated mass of `key` (0 if untracked).
    pub fn estimate(&self, key: Key) -> f64 {
        self.sketch.estimate(key)
    }

    /// Tracked candidate entries (control-plane memory).
    pub fn entries(&self) -> usize {
        self.sketch.entries()
    }

    /// Overestimate bound for this sketch's estimates: 0 while under
    /// capacity (estimates are exact), else the minimum tracked count —
    /// every estimate `e` satisfies `true ≤ e ≤ true + error_bound()`,
    /// and any untracked key's true mass is ≤ `error_bound()`. This is
    /// the per-shard term in the scatter-gather rank-error bound
    /// ([`crate::aggregate::TopKGather::error_bound`]).
    pub fn error_bound(&self) -> f64 {
        if self.sketch.at_capacity() {
            self.sketch.min_count()
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_ignores_value_sum_uses_it() {
        let c = Count;
        let mut a = c.identity();
        c.accumulate(&mut a, 999);
        c.accumulate(&mut a, 0);
        assert_eq!(a, 2);

        let s = Sum;
        let mut b = s.identity();
        s.accumulate(&mut b, 999);
        s.accumulate(&mut b, 1);
        assert_eq!(b, 1000);
    }

    #[test]
    fn merge_is_commutative_and_respects_identity() {
        let c = Count;
        let (mut x, mut y) = (5u64, 9u64);
        let (xs, ys) = (x, y);
        c.merge(&mut x, &ys);
        c.merge(&mut y, &xs);
        assert_eq!(x, y);
        let mut id = c.identity();
        c.merge(&mut id, &x);
        assert_eq!(id, x);
    }

    #[test]
    fn topk_sketch_weighted_matches_unit_observes_on_hot_keys() {
        // Feeding (key, n) once must rank hot keys the same as feeding
        // the key n times — the property that makes flush-batch absorbs
        // sound.
        let mut weighted = TopKSketch::new(8);
        let mut exact: std::collections::HashMap<Key, u64> = std::collections::HashMap::new();
        let flushes: &[(Key, u64)] = &[(1, 50), (2, 30), (3, 5), (1, 25), (4, 2), (2, 10)];
        for &(k, n) in flushes {
            weighted.absorb(k, n);
            *exact.entry(k).or_insert(0) += n;
        }
        let top = weighted.top(2);
        assert_eq!(top[0].0, 1);
        assert_eq!(top[1].0, 2);
        assert!(weighted.estimate(1) >= exact[&1] as f64);
        assert!(weighted.estimate(2) >= exact[&2] as f64);
    }

    #[test]
    fn topk_sketch_bounds_memory() {
        let mut t = TopKSketch::new(16);
        for k in 0..10_000u64 {
            t.absorb(k, 1 + k % 7);
        }
        assert!(t.entries() <= 16);
    }
}
