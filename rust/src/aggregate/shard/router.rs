//! Key-range shard routing over the consistent-hash ring.
//!
//! The fabric partitions the merged key space across `n_shards`
//! aggregator shards by reusing [`crate::hashring::HashRing`] with
//! shard ids as the ring members. Consistent hashing is what makes the
//! shard count *elastic*: growing the fabric from `n` to `n + 1`
//! shards remaps only the arcs the new shard's virtual nodes land on
//! (≈ `1/(n+1)` of the key space), instead of rehashing every key the
//! way `key % n` would — the same monotonicity argument the paper
//! makes for worker churn (§5), applied one stage downstream.
//!
//! Routing is pure and deterministic: `shard_of(key)` depends only on
//! the key and the current shard set, never on observation order, so
//! both engines split flush batches identically for a given
//! `--agg_shards` and the per-shard ledgers are comparable across runs.

use crate::hashring::HashRing;
use crate::Key;

/// Virtual nodes per shard on the shard ring. Fixed (rather than
/// borrowing [`crate::config::Config::vnodes`]) so the worker→shard
/// mapping for a given `--agg_shards` is one deterministic function of
/// the key, identical in both engines and every test.
pub const SHARD_VNODES: usize = 64;

/// Index of an aggregator shard.
pub type ShardId = usize;

/// Key-range partitioner for the merge-shard fabric.
#[derive(Debug, Clone)]
pub struct ShardRouter {
    ring: HashRing,
    n_shards: usize,
}

impl ShardRouter {
    /// A router over shards `0..n_shards`.
    pub fn new(n_shards: usize) -> Self {
        assert!(n_shards > 0, "need at least one aggregator shard");
        ShardRouter {
            ring: HashRing::new(&(0..n_shards).collect::<Vec<_>>(), SHARD_VNODES),
            n_shards,
        }
    }

    /// Current shard count.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// The shard owning `key` (deterministic; single-shard fabrics skip
    /// the ring lookup entirely — the dominant production default).
    #[inline]
    pub fn shard_of(&self, key: Key) -> ShardId {
        if self.n_shards == 1 {
            return 0;
        }
        self.ring.owner(key).expect("shard ring is never empty")
    }

    /// Grow or shrink the fabric to `n` shards (ids `0..n`). Only the
    /// ring arcs owned by added/removed shards remap — the elasticity
    /// property [`ShardedMerge`](super::ShardedMerge) relies on for
    /// mid-run shard-count changes.
    pub fn set_shards(&mut self, n: usize) {
        assert!(n > 0, "need at least one aggregator shard");
        for s in self.n_shards..n {
            self.ring.add_worker(s);
        }
        for s in n..self.n_shards {
            self.ring.remove_worker(s);
        }
        self.n_shards = n;
    }

    /// Scatter one flush batch into per-shard sub-batches
    /// (`out[s]` = entries owned by shard `s`; some may be empty).
    pub fn split<A>(&self, batch: Vec<(Key, A)>) -> Vec<Vec<(Key, A)>> {
        if self.n_shards == 1 {
            return vec![batch];
        }
        let mut out: Vec<Vec<(Key, A)>> = (0..self.n_shards).map(|_| Vec::new()).collect();
        for (key, acc) in batch {
            out[self.shard_of(key)].push((key, acc));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic_and_total() {
        let r = ShardRouter::new(7);
        for k in 0..2_000u64 {
            let s = r.shard_of(k);
            assert_eq!(s, r.shard_of(k));
            assert!(s < 7);
        }
    }

    #[test]
    fn single_shard_short_circuits() {
        let r = ShardRouter::new(1);
        for k in 0..100u64 {
            assert_eq!(r.shard_of(k), 0);
        }
        let split = r.split(vec![(1u64, 2u64), (9, 1)]);
        assert_eq!(split.len(), 1);
        assert_eq!(split[0].len(), 2);
    }

    #[test]
    fn every_shard_owns_a_reasonable_share() {
        let r = ShardRouter::new(4);
        let mut counts = [0usize; 4];
        for k in 0..20_000u64 {
            counts[r.shard_of(k)] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            let share = c as f64 / 20_000.0;
            assert!((0.10..0.45).contains(&share), "shard {s} owns {share}");
        }
    }

    #[test]
    fn split_preserves_every_entry_on_its_owner_shard() {
        let r = ShardRouter::new(5);
        let batch: Vec<(Key, u64)> = (0..1_000u64).map(|k| (k, k + 1)).collect();
        let split = r.split(batch.clone());
        assert_eq!(split.len(), 5);
        assert_eq!(split.iter().map(|b| b.len()).sum::<usize>(), batch.len());
        for (s, sub) in split.iter().enumerate() {
            for &(k, v) in sub {
                assert_eq!(r.shard_of(k), s);
                assert_eq!(v, k + 1);
            }
        }
    }

    #[test]
    fn growing_the_fabric_remaps_only_a_bounded_arc() {
        // The elasticity claim: 8 → 9 shards moves keys only onto the
        // new shard, and only ≈ 1/9 of them.
        let mut r = ShardRouter::new(8);
        let before: Vec<ShardId> = (0..10_000u64).map(|k| r.shard_of(k)).collect();
        r.set_shards(9);
        let mut moved = 0usize;
        for (k, &was) in before.iter().enumerate() {
            let now = r.shard_of(k as u64);
            if now != was {
                assert_eq!(now, 8, "key {k} moved to an old shard");
                moved += 1;
            }
        }
        let frac = moved as f64 / 10_000.0;
        assert!(frac < 0.25, "grow remapped {frac} of the key space");
    }

    #[test]
    fn shrinking_only_remaps_the_removed_shards_keys() {
        let mut r = ShardRouter::new(6);
        let before: Vec<ShardId> = (0..10_000u64).map(|k| r.shard_of(k)).collect();
        r.set_shards(5); // drops shard 5
        for (k, &was) in before.iter().enumerate() {
            let now = r.shard_of(k as u64);
            if was != 5 {
                assert_eq!(now, was, "key {k} moved needlessly");
            } else {
                assert_ne!(now, 5);
            }
        }
    }
}
