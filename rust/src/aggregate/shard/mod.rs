//! Sharded aggregation fabric — stage two as a parallel subsystem.
//!
//! The two-phase topology (PR 3) made merged results exact, but its
//! merge path was a *single* [`crate::aggregate::MergeStage`]: every
//! flush from every worker funnelled through one fold — precisely the
//! downstream bottleneck the PKG and W-Choices papers identify as the
//! cost of key splitting, and the scalability ceiling the ROADMAP
//! flagged at 128-node scale. This module removes it:
//!
//! * [`ShardRouter`] — key-range partitioning over the consistent-hash
//!   ring ([`crate::hashring`]), so the shard count can change without
//!   remapping every key (elasticity, same argument as worker churn).
//! * [`ShardedMerge`] — the fabric: N merge shards, each with its own
//!   [`crate::metrics::AggStats`] ledger, absorbing scattered flush
//!   sub-batches. One shard ≡ the old single stage, byte for byte.
//! * [`TopKGather`] — scatter-gather front-end: per-shard
//!   [`crate::aggregate::TopKSketch`] summaries merged into a global
//!   top-k with an explicit rank-error bound.
//!
//! Both engines wire the fabric in (`--agg_shards`,
//! [`crate::config::Config::agg_shards`]): the simulator scatters
//! virtual-time flushes deterministically, the runtime engine runs one
//! real aggregator thread per shard fed by per-worker-to-shard flush
//! channels. Shard imbalance (max/mean absorbed tuples,
//! [`crate::metrics::ShardAggStats`]) is surfaced next to the routing
//! metrics so the aggregation stage's skew is comparable across
//! grouping schemes.

pub mod fabric;
pub mod gather;
pub mod router;

pub use fabric::ShardedMerge;
pub use gather::{GatherResult, TopKGather, DEFAULT_GATHER_CAPACITY};
pub use router::{ShardId, ShardRouter, SHARD_VNODES};
