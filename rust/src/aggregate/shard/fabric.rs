//! The merge-shard fabric: N key-range-partitioned [`MergeStage`]s
//! behind one absorb surface.
//!
//! PR 3's single `MergeStage` made stage two correct but serial — the
//! exact single-point bottleneck the PKG and W-Choices papers warn the
//! downstream aggregation stage becomes at scale. [`ShardedMerge`]
//! replaces it with a fabric of shards partitioned by key range via
//! [`ShardRouter`]: every flush batch is scattered into per-shard
//! sub-batches, each absorbed by its own [`MergeStage`] with its own
//! [`crate::metrics::AggStats`] ledger, so shard load (and therefore
//! aggregation-stage imbalance — max/mean absorbed tuples, see
//! [`crate::metrics::ShardAggStats::imbalance`]) is measurable per
//! grouping scheme instead of invisible inside one fold.
//!
//! A fabric of one shard is byte-identical to the old single stage
//! (routing short-circuits, one ledger), which is what keeps the
//! aggregation oracle's cross-shard-count equality checks meaningful.
//!
//! Shard count may change *mid-run* ([`ShardedMerge::set_shards`]):
//! consistent hashing remaps only the affected arcs, and the final
//! [`ShardedMerge::into_sorted`] re-merges any key whose deltas landed
//! on two shards across the change — exactness is preserved by the
//! combiner's commutative-monoid laws.

use super::router::ShardRouter;
use crate::aggregate::combiner::Combiner;
use crate::aggregate::merge::MergeStage;
use crate::metrics::ShardAggStats;
use crate::Key;
use std::collections::HashMap;

/// Key-range-sharded stage two: a fabric of merge shards.
pub struct ShardedMerge<C: Combiner + Clone> {
    combiner: C,
    router: ShardRouter,
    shards: Vec<MergeStage<C>>,
}

impl<C: Combiner + Clone> ShardedMerge<C> {
    /// A fabric of `n_shards` empty merge shards folding through
    /// `combiner`.
    pub fn new(combiner: C, n_shards: usize) -> Self {
        let shards = (0..n_shards).map(|_| MergeStage::new(combiner.clone())).collect();
        ShardedMerge { combiner, router: ShardRouter::new(n_shards), shards }
    }

    /// Current *routing* shard count. After a mid-run shrink, retired
    /// shards keep their merged history (and stay visible in
    /// [`ShardedMerge::shard_stats`]) but receive no new deltas.
    pub fn n_shards(&self) -> usize {
        self.router.n_shards()
    }

    /// Scatter one flush batch across the fabric: each entry lands on
    /// the shard owning its key range and is absorbed there (one
    /// [`crate::metrics::AggStats::record_merge`] per non-empty
    /// sub-batch).
    pub fn absorb(&mut self, batch: Vec<(Key, C::Acc)>) {
        if batch.is_empty() {
            return;
        }
        for (s, sub) in self.router.split(batch).into_iter().enumerate() {
            self.absorb_on(s, sub);
        }
    }

    /// Split a batch with the fabric's router *without* absorbing it —
    /// for callers that feed several per-shard consumers (merge shard +
    /// gather sketch) and want to pay the ring lookup once per entry.
    /// Feed each sub-batch back via [`ShardedMerge::absorb_on`].
    pub fn split(&self, batch: Vec<(Key, C::Acc)>) -> Vec<Vec<(Key, C::Acc)>> {
        self.router.split(batch)
    }

    /// Absorb one already-split sub-batch on shard `shard` (no-op when
    /// empty). `shard` must be a routing shard id (< the shard count
    /// the batch was [`ShardedMerge::split`] with).
    pub fn absorb_on(&mut self, shard: usize, sub: Vec<(Key, C::Acc)>) {
        if !sub.is_empty() {
            self.shards[shard].absorb(sub);
        }
    }

    /// Grow or shrink the fabric to `n` shards mid-run. Existing merged
    /// state stays where it is (new deltas for a remapped key go to its
    /// new owner; [`ShardedMerge::into_sorted`] re-merges the split) —
    /// resharding moves routing, not history.
    pub fn set_shards(&mut self, n: usize) {
        assert!(n > 0, "need at least one aggregator shard");
        self.router.set_shards(n);
        while self.shards.len() < n {
            self.shards.push(MergeStage::new(self.combiner.clone()));
        }
        // shrunk shards keep their merged state until the final gather;
        // the router just stops sending them new deltas
    }

    /// Distinct `(key, shard)` entries across the fabric. Equals the
    /// distinct-key count unless a mid-run reshard split a key's deltas
    /// across two shards (resolved by [`ShardedMerge::into_sorted`]).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    /// True when nothing has been merged anywhere.
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.is_empty())
    }

    /// Per-shard cost ledgers (indexed by shard id).
    pub fn shard_stats(&self) -> ShardAggStats {
        ShardAggStats { per_shard: self.shards.iter().map(|s| *s.stats()).collect() }
    }

    /// Finish: exact merged `(key, acc)` ascending by key — element-wise
    /// identical to a single [`MergeStage`] over the same flushes, for
    /// any shard count and any mid-run reshard history — plus the
    /// per-shard ledgers.
    pub fn into_sorted(self) -> (Vec<(Key, C::Acc)>, ShardAggStats) {
        let stats = self.shard_stats();
        let combiner = self.combiner;
        let mut merged: HashMap<Key, C::Acc> = HashMap::new();
        for shard in self.shards {
            let (map, _) = shard.into_parts();
            for (key, acc) in map {
                match merged.entry(key) {
                    std::collections::hash_map::Entry::Occupied(mut o) => {
                        combiner.merge(o.get_mut(), &acc);
                    }
                    std::collections::hash_map::Entry::Vacant(v) => {
                        v.insert(acc);
                    }
                }
            }
        }
        // sorted by key on the next line. lint: sorted-ok
        let mut v: Vec<(Key, C::Acc)> = merged.into_iter().collect();
        v.sort_unstable_by_key(|&(k, _)| k);
        (v, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregate::combiner::Count;
    use crate::aggregate::merge::PartialAgg;

    /// Drive the same flush schedule through a single stage and an
    /// n-shard fabric; return both sorted results.
    fn run_both(n_shards: usize, flush_every: usize) -> (Vec<(Key, u64)>, Vec<(Key, u64)>) {
        let keys: Vec<Key> = (0..4_000u64).map(|i| (i * i + 7) % 131).collect();
        let mut single = MergeStage::new(Count);
        let mut fabric = ShardedMerge::new(Count, n_shards);
        let mut p1 = PartialAgg::new(Count);
        let mut p2 = PartialAgg::new(Count);
        for (i, &k) in keys.iter().enumerate() {
            p1.observe(k, 1);
            p2.observe(k, 1);
            if (i + 1) % flush_every == 0 {
                single.absorb(p1.flush());
                fabric.absorb(p2.flush());
            }
        }
        single.absorb(p1.flush());
        fabric.absorb(p2.flush());
        (single.into_sorted().0, fabric.into_sorted().0)
    }

    #[test]
    fn fabric_is_byte_identical_to_single_stage() {
        for shards in [1usize, 2, 3, 7, 16] {
            let (single, sharded) = run_both(shards, 97);
            assert_eq!(single, sharded, "{shards} shards");
        }
    }

    #[test]
    fn per_shard_ledgers_sum_to_the_whole() {
        let mut fabric = ShardedMerge::new(Count, 4);
        let mut p = PartialAgg::new(Count);
        for k in 0..500u64 {
            p.observe(k % 37, 1);
        }
        fabric.absorb(p.flush());
        let stats = fabric.shard_stats();
        assert_eq!(stats.n_shards(), 4);
        let total = stats.total();
        assert_eq!(total.messages, 37);
        assert_eq!(total.bytes, 37 * 16);
        // one inbound batch scattered over however many shards own keys
        assert!((1..=4).contains(&total.flushes));
        assert!(stats.imbalance().relative >= 0.0);
    }

    #[test]
    fn empty_batches_cost_nothing() {
        let mut fabric: ShardedMerge<Count> = ShardedMerge::new(Count, 3);
        fabric.absorb(Vec::new());
        assert!(fabric.is_empty());
        assert_eq!(fabric.shard_stats().total().flushes, 0);
    }

    #[test]
    fn mid_run_reshard_keeps_exact_counts() {
        // Change the shard count mid-stream: a key's deltas may land on
        // two shards, and the final gather must still merge them back to
        // the exact totals, deterministically.
        let keys: Vec<Key> = (0..6_000u64).map(|i| (i * 31 + 5) % 211).collect();
        let run = |reshard_to: &[usize]| {
            let mut fabric = ShardedMerge::new(Count, 2);
            let mut p = PartialAgg::new(Count);
            for (i, &k) in keys.iter().enumerate() {
                p.observe(k, 1);
                if (i + 1) % 500 == 0 {
                    fabric.absorb(p.flush());
                }
                if (i + 1) % 2_000 == 0 {
                    let step = (i + 1) / 2_000 - 1;
                    if step < reshard_to.len() {
                        fabric.set_shards(reshard_to[step]);
                    }
                }
            }
            fabric.absorb(p.flush());
            fabric.into_sorted().0
        };
        let stable = run(&[]);
        let grown = run(&[5, 9]);
        let shrunk_grown = run(&[1, 6]);
        assert_eq!(stable.iter().map(|&(_, c)| c).sum::<u64>(), 6_000);
        assert_eq!(stable, grown);
        assert_eq!(stable, shrunk_grown);
        // determinism across repeated resharded runs
        assert_eq!(grown, run(&[5, 9]));
    }
}
