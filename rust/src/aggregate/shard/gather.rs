//! Scatter-gather top-k over the merge-shard fabric.
//!
//! With stage two sharded by key range, "what are the k hottest keys
//! globally?" becomes a scatter-gather: each shard keeps a bounded
//! [`TopKSketch`] (SpaceSaving over the flush mass it absorbed), and
//! [`TopKGather`] answers the global query by collecting each shard's
//! local top-k candidates and re-ranking the union.
//!
//! Because the router *partitions* keys, every key's whole mass lives
//! on exactly one shard — per-key estimates never need cross-shard
//! summation, and a shard's local ranking is over its complete share of
//! the stream. What sharding cannot remove is SpaceSaving's own
//! overestimate: [`TopKGather::error_bound`] reports the worst
//! per-shard bound (the shard's minimum tracked count once it is at
//! capacity), and every gathered estimate `e` satisfies
//! `true ≤ e ≤ true + error_bound` — the rank-error bound: two keys
//! whose estimates differ by more than the bound are ranked correctly,
//! closer pairs may swap, and a true top-k key can be crowded out of
//! the gathered list only by rivals within the bound of it.

use super::router::ShardRouter;
use crate::aggregate::combiner::TopKSketch;
use crate::Key;

/// Default per-shard candidate capacity for the engines' gather path —
/// control-plane memory, so sized generously (`n_shards × 1024`
/// counters total, still O(K) against millions of keys).
pub const DEFAULT_GATHER_CAPACITY: usize = 1024;

/// One answered global top-k query.
#[derive(Debug, Clone)]
pub struct GatherResult {
    /// The `k` highest-estimate keys, descending (ties broken by key
    /// ascending, so rankings are deterministic given the sketches).
    pub top: Vec<(Key, f64)>,
    /// Worst per-shard overestimate: every listed estimate `e`
    /// satisfies `true ≤ e ≤ true + error_bound`. A key missing from
    /// `top` either was never tracked by its shard's sketch (true mass
    /// ≤ this bound) or ranks at or below the k-th listed estimate —
    /// so only keys within the bound of each other can swap ranks.
    pub error_bound: f64,
}

/// Scatter-gather front-end: per-shard bounded top-k summaries plus
/// the global merge that answers queries over them.
#[derive(Debug, Clone)]
pub struct TopKGather {
    router: ShardRouter,
    shards: Vec<TopKSketch>,
}

impl TopKGather {
    /// A gather over `n_shards` empty sketches of `capacity` counters
    /// each, routed identically to the merge fabric.
    pub fn new(n_shards: usize, capacity: usize) -> Self {
        assert!(n_shards > 0, "need at least one aggregator shard");
        TopKGather {
            router: ShardRouter::new(n_shards),
            shards: (0..n_shards).map(|_| TopKSketch::new(capacity)).collect(),
        }
    }

    /// Assemble a gather from sketches the shards built themselves (the
    /// runtime engine's per-shard aggregator threads).
    pub fn from_shards(shards: Vec<TopKSketch>) -> Self {
        assert!(!shards.is_empty(), "need at least one shard sketch");
        TopKGather { router: ShardRouter::new(shards.len()), shards }
    }

    /// Shard count.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Absorb one flushed delta: `key` gained `weight` mass on its
    /// owner shard's sketch.
    pub fn absorb(&mut self, key: Key, weight: u64) {
        let s = self.router.shard_of(key);
        self.shards[s].absorb(key, weight);
    }

    /// Absorb a whole flush batch of `(key, delta)` entries.
    pub fn absorb_batch(&mut self, batch: &[(Key, u64)]) {
        for &(key, weight) in batch {
            self.absorb(key, weight);
        }
    }

    /// Absorb an already-routed sub-batch directly on shard `shard` —
    /// for engines that split a flush once (with the merge fabric's
    /// router, which maps identically) and feed both the merge shard
    /// and its sketch from the same split.
    pub fn absorb_on(&mut self, shard: usize, batch: &[(Key, u64)]) {
        for &(key, weight) in batch {
            self.shards[shard].absorb(key, weight);
        }
    }

    /// Fold another gather's per-shard sketches into this one,
    /// shard-wise (both must cover the same shard count — i.e. come
    /// from the same fabric). This is how pane-composed **sliding**
    /// windows assemble: the sliding query over the last `m` tumbling
    /// panes merges the panes' gathers, and because each key lives on
    /// the same shard in every pane, the merge never crosses shards.
    pub fn merge_from(&mut self, other: &TopKGather) {
        assert_eq!(
            self.shards.len(),
            other.shards.len(),
            "can only merge gathers from the same fabric"
        );
        for (mine, theirs) in self.shards.iter_mut().zip(&other.shards) {
            mine.merge(theirs);
        }
    }

    /// Estimated mass of `key` (0 if untracked on its owner shard).
    pub fn estimate(&self, key: Key) -> f64 {
        self.shards[self.router.shard_of(key)].estimate(key)
    }

    /// Worst per-shard overestimate bound (0 while every shard is under
    /// capacity — estimates are then exact).
    pub fn error_bound(&self) -> f64 {
        self.shards.iter().map(|s| s.error_bound()).fold(0.0, f64::max)
    }

    /// Tracked candidate entries across all shards (control-plane
    /// memory for the scalability metric).
    pub fn entries(&self) -> usize {
        self.shards.iter().map(|s| s.entries()).sum()
    }

    /// Answer the global top-k: each shard contributes its local top-k
    /// candidates, the union is re-ranked by estimate (descending, key
    /// ascending on ties) and truncated to `k`.
    pub fn top(&self, k: usize) -> GatherResult {
        let mut union: Vec<(Key, f64)> = Vec::with_capacity(k * self.shards.len());
        for shard in &self.shards {
            union.extend(shard.top(k));
        }
        union.sort_unstable_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
        });
        union.truncate(k);
        GatherResult { top: union, error_bound: self.error_bound() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    /// A skewed synthetic flush stream: key `k` gets `mass(k)` total,
    /// delivered as several deltas (like periodic partial flushes).
    fn feed(gather: &mut TopKGather, n_keys: u64) -> HashMap<Key, u64> {
        let mut truth = HashMap::new();
        for k in 0..n_keys {
            let mass = 1 + 10_000 / (k + 1); // Zipf-ish: key 0 hottest
            for piece in [mass / 2, mass - mass / 2] {
                if piece > 0 {
                    gather.absorb(k, piece);
                }
            }
            truth.insert(k, mass);
        }
        truth
    }

    #[test]
    fn exact_under_capacity_any_shard_count() {
        for shards in [1usize, 2, 7] {
            let mut g = TopKGather::new(shards, 4_096);
            let truth = feed(&mut g, 500);
            assert_eq!(g.error_bound(), 0.0, "{shards} shards under capacity");
            let top = g.top(10).top;
            assert_eq!(top.len(), 10);
            for &(k, est) in &top {
                assert_eq!(est, truth[&k] as f64, "{shards} shards, key {k}");
            }
            // exact estimates ⇒ exact ranking: key 0 is the hottest
            assert_eq!(top[0].0, 0);
        }
    }

    #[test]
    fn overestimates_stay_within_the_reported_bound() {
        let mut g = TopKGather::new(4, 64); // far under the 5k key space
        let truth = feed(&mut g, 5_000);
        let r = g.top(20);
        assert!(r.error_bound > 0.0, "evictions must raise the bound");
        for &(k, est) in &r.top {
            let t = truth[&k] as f64;
            assert!(est >= t, "key {k}: estimate {est} under truth {t}");
            assert!(est <= t + r.error_bound, "key {k}: {est} > {t} + {}", r.error_bound);
        }
        // the clearly-hot head (gaps ≫ bound) is still ranked correctly
        assert_eq!(r.top[0].0, 0);
        assert_eq!(r.top[1].0, 1);
    }

    #[test]
    fn gather_matches_single_sketch_semantics_on_one_shard() {
        let mut g = TopKGather::new(1, 128);
        let mut single = TopKSketch::new(128);
        for k in 0..300u64 {
            g.absorb(k, k + 1);
            single.absorb(k, k + 1);
        }
        assert_eq!(g.top(5).top, single.top(5));
        assert_eq!(g.entries(), single.entries());
    }

    #[test]
    fn merge_from_folds_pane_gathers_shard_wise() {
        let mut a = TopKGather::new(4, 64);
        let mut b = TopKGather::new(4, 64);
        a.absorb(7, 30);
        a.absorb(11, 5);
        b.absorb(7, 12);
        b.absorb(99, 40);
        a.merge_from(&b);
        assert!(a.estimate(7) >= 42.0);
        assert!(a.estimate(99) >= 40.0);
        // per-key mass still lives on exactly one shard after the merge
        let tracked = a.shards.iter().filter(|s| s.estimate(7) > 0.0).count();
        assert_eq!(tracked, 1);
    }

    #[test]
    fn partitioning_keeps_per_key_mass_on_one_shard() {
        let mut g = TopKGather::new(8, 1_024);
        for _ in 0..50 {
            g.absorb(42, 10);
        }
        assert_eq!(g.estimate(42), 500.0);
        // exactly one shard tracks the key
        let tracked = g.shards.iter().filter(|s| s.estimate(42) > 0.0).count();
        assert_eq!(tracked, 1);
    }
}
