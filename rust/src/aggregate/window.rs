//! Windowed aggregation over the merge fabric: tumbling event-time
//! panes with watermark retirement, and pane-composed sliding windows.
//!
//! FISH's premise is that hotness only means anything "within a bounded
//! distance of time interval" (paper §3) — yet an unwindowed stage two
//! folds the whole stream, so its top-k answers *all time*, not
//! *trending now*. This module adds time to the fabric:
//!
//! * every tuple is assigned to a **pane** by its *emit timestamp*
//!   (virtual arrival ns in the simulator, the source-stamped trace
//!   emit ns in the runtime engine): `pane = ts / window_ns`
//!   ([`window_of`]). Assignment by event time — not by flush time — is
//!   what makes per-pane counts invariant under flush cadence, shard
//!   count, grouping scheme and engine, the windowed half of the
//!   aggregation oracle.
//! * stage one keeps one [`PartialAgg`] per open pane per worker
//!   ([`WindowedPartial`]; the current pane is a direct field, so the
//!   unwindowed `window_ns = 0` case pays one branch over the old
//!   single-partial path and reproduces it byte for byte).
//! * stage two keeps per-pane [`MergeStage`]s (plus a per-pane
//!   [`TopKSketch`]) on each merge shard ([`WindowedMerge`]). When the
//!   shard's **watermark** passes a pane's end, the pane is *retired*:
//!   its finalized `(window, key, acc)` counts are flushed downstream
//!   as a [`WindowResult`] and its memory is released — open-pane
//!   memory and retirement counts land in
//!   [`crate::metrics::WindowStats`].
//! * the engines assemble per-shard results into global
//!   [`WindowSnapshot`]s ([`assemble_windows`]): exact per-window
//!   counts (keys are disjoint across shards, so concat + sort is
//!   byte-identical to a single-shard fold) plus a per-window
//!   [`TopKGather`] built from the panes' shard sketches.
//! * **sliding** windows are composed from panes ([`sliding`]): a
//!   window of `m` panes is the merge of `m` consecutive tumbling
//!   panes — the classic paired-pane construction, which the
//!   count-based [`crate::sketch::SlidingWindow`] baseline cross-checks
//!   in the oracle tests.
//!
//! Watermarks are exact in the simulator (virtual time is global) and
//! heuristic in the runtime engine (min over per-worker high-water
//! marks): a late delta there *reopens* its pane, and the reopened
//! emission is re-merged exactly at assembly — retirement timing is
//! best-effort, final per-window counts never are.
//!
//! [`next_boundary`] is the shared flush/pane cadence helper: both
//! engines snap their periodic flush schedule to the same boundary grid
//! (`now → now - now % interval + interval`), so flush cadence cannot
//! drift with per-chunk processing time the way the runtime engine's
//! old `now + interval` arithmetic did.

use super::combiner::{Combiner, TopKSketch};
use super::merge::{MergeStage, PartialAgg};
use super::shard::TopKGather;
use crate::metrics::{AggStats, WindowStats};
use crate::Key;
use std::collections::{BTreeMap, HashMap};

/// Identifier of a tumbling pane: `ts / window_ns` (pane `w` covers
/// `[w·window_ns, (w+1)·window_ns)`).
pub type WindowId = u64;

/// The pane owning event time `ts`; everything lands in pane 0 when
/// unwindowed (`window_ns == 0`).
#[inline]
pub fn window_of(ts: u64, window_ns: u64) -> WindowId {
    if window_ns == 0 {
        0
    } else {
        ts / window_ns
    }
}

/// End of `window`'s pane in event-time ns (exclusive).
#[inline]
fn pane_end(window: WindowId, window_ns: u64) -> u64 {
    (window + 1).saturating_mul(window_ns)
}

/// The next boundary of an `interval` grid strictly after `now`:
/// `now - now % interval + interval`. The one flush-cadence arithmetic
/// both engines (and pane retirement) share — scheduling the next flush
/// as `now + interval` instead lets the cadence drift by per-chunk
/// processing time, which is exactly the runtime-engine bug this
/// helper replaced.
#[inline]
pub fn next_boundary(now: u64, interval: u64) -> u64 {
    debug_assert!(interval > 0, "boundary grid needs a positive interval");
    now - now % interval + interval
}

/// Stage one with panes: per-(pane, key) partial accumulators on one
/// worker. The current (hottest) pane is a direct field so the
/// `window_ns = 0` configuration — a single eternal pane — runs the old
/// single-[`PartialAgg`] hot path with one extra branch; stragglers
/// from earlier panes (late deltas from a lagging source) go to a small
/// ordered side table.
pub struct WindowedPartial<C: Combiner + Clone> {
    combiner: C,
    window_ns: u64,
    cur_window: WindowId,
    cur: PartialAgg<C>,
    /// Panes older than `cur_window` that received tuples after the
    /// current pane advanced. Invariant: keys `< cur_window`, every
    /// entry non-empty.
    laggards: BTreeMap<WindowId, PartialAgg<C>>,
}

impl<C: Combiner + Clone> WindowedPartial<C> {
    /// Empty windowed partial folding through `combiner`;
    /// `window_ns == 0` = unwindowed (single pane 0).
    pub fn new(combiner: C, window_ns: u64) -> Self {
        WindowedPartial {
            cur: PartialAgg::new(combiner.clone()),
            combiner,
            window_ns,
            cur_window: 0,
            laggards: BTreeMap::new(),
        }
    }

    /// Fold one tuple occurrence of `key` carrying `value`, stamped
    /// with event time `ts`.
    #[inline]
    pub fn observe(&mut self, key: Key, value: u64, ts: u64) {
        let win = window_of(ts, self.window_ns);
        if win == self.cur_window {
            self.cur.observe(key, value);
        } else if win > self.cur_window {
            // pane advance: park the previous pane until the next flush
            let prev = std::mem::replace(&mut self.cur, PartialAgg::new(self.combiner.clone()));
            if !prev.is_empty() {
                self.laggards.insert(self.cur_window, prev);
            }
            self.cur_window = win;
            self.cur.observe(key, value);
        } else {
            self.laggards
                .entry(win)
                .or_insert_with(|| PartialAgg::new(self.combiner.clone()))
                .observe(key, value);
        }
    }

    /// True when there is nothing to flush.
    pub fn is_empty(&self) -> bool {
        self.cur.is_empty() && self.laggards.is_empty()
    }

    /// Distinct `(pane, key)` entries accumulated since the last flush.
    pub fn len(&self) -> usize {
        self.cur.len() + self.laggards.values().map(|p| p.len()).sum::<usize>()
    }

    /// Payload a flush now would ship, in bytes.
    pub fn payload_bytes(&self) -> usize {
        self.cur.payload_bytes() + self.laggards.values().map(|p| p.payload_bytes()).sum::<usize>()
    }

    /// Drain everything into per-pane flush batches, ascending by pane
    /// id, each batch ascending by key (see [`PartialAgg::flush`]).
    /// Empty afterwards.
    pub fn flush(&mut self) -> Vec<(WindowId, Vec<(Key, C::Acc)>)> {
        let mut out = Vec::with_capacity(self.laggards.len() + 1);
        for (win, mut p) in std::mem::take(&mut self.laggards) {
            out.push((win, p.flush()));
        }
        if !self.cur.is_empty() {
            out.push((self.cur_window, self.cur.flush()));
        }
        out
    }
}

/// One finalized pane on one merge shard: the exact counts for the
/// shard's key range within the pane, plus the pane's bounded top-k
/// summary — what window retirement "flushes downstream".
#[derive(Debug, Clone)]
pub struct WindowResult {
    /// Pane id (`[window·window_ns, (window+1)·window_ns)`).
    pub window: WindowId,
    /// Exact `(key, acc)` for this shard's key range, ascending by key.
    pub counts: Vec<(Key, u64)>,
    /// The pane's SpaceSaving summary on this shard (feeds the
    /// per-window [`TopKGather`] at assembly).
    pub sketch: TopKSketch,
}

impl WindowResult {
    /// Fold a reopened pane's second emission into the first: counts
    /// merge-join (both ascending, exact), sketches fold via
    /// [`TopKSketch::merge`].
    fn merge_from(&mut self, other: WindowResult, combiner: &impl Combiner<Acc = u64>) {
        debug_assert_eq!(self.window, other.window);
        let mut merged = Vec::with_capacity(self.counts.len() + other.counts.len());
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.counts.len() && j < other.counts.len() {
            match self.counts[i].0.cmp(&other.counts[j].0) {
                std::cmp::Ordering::Less => {
                    merged.push(self.counts[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    merged.push(other.counts[j]);
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    let mut acc = self.counts[i].1;
                    combiner.merge(&mut acc, &other.counts[j].1);
                    merged.push((self.counts[i].0, acc));
                    i += 1;
                    j += 1;
                }
            }
        }
        merged.extend_from_slice(&self.counts[i..]);
        merged.extend_from_slice(&other.counts[j..]);
        self.counts = merged;
        self.sketch.merge(&other.sketch);
    }
}

/// Everything one shard's windowed merge produced, returned by
/// [`WindowedMerge::finish`].
pub struct WindowedOutput {
    /// Finalized panes, ascending by pane id, at most one per pane
    /// (reopened emissions already re-merged); empty when unwindowed.
    pub windows: Vec<WindowResult>,
    /// All-time `(key, acc)` totals across every pane, ascending by key
    /// — byte-identical to what an unwindowed [`MergeStage`] over the
    /// same deltas produces.
    pub all_time: Vec<(Key, u64)>,
    /// The shard's aggregation-traffic ledger (folded across panes).
    pub stats: AggStats,
    /// Pane-lifecycle and open-pane-memory ledger.
    pub window_stats: WindowStats,
}

/// One open pane's state on a shard.
struct WindowPane<C: Combiner> {
    merge: MergeStage<C>,
    sketch: TopKSketch,
}

/// One pane's serializable state inside a [`MergeSnapshot`]: exact
/// counts ascending by key, the pane's merge-cost ledger (so a restored
/// run's deterministic stat fields match a run that never crashed), and
/// the pane sketch's parts ([`TopKSketch::from_parts`] shape, entries
/// ascending by key so snapshot bytes are deterministic).
#[derive(Debug, Clone)]
pub struct PaneState {
    /// Pane id.
    pub window: WindowId,
    /// Exact `(key, acc)`, ascending by key.
    pub counts: Vec<(Key, u64)>,
    /// The pane's merge ledger (default for retired panes, whose ledger
    /// already folded into the shard-wide retired ledger).
    pub stats: AggStats,
    /// Tracked sketch entries, ascending by key.
    pub sketch_entries: Vec<(Key, f64)>,
    /// The sketch's inherited merge error.
    pub sketch_error: f64,
}

/// Everything a [`WindowedMerge`] shard must persist to come back
/// byte-identical after a crash: watermark, open panes, already-retired
/// panes, and both stat ledgers. Captured by [`WindowedMerge::snapshot`]
/// without consuming the shard, reinstated by
/// [`WindowedMerge::restore`]; serialized by
/// [`crate::state::snapshot`]. Dedup/reorder state (the per-worker
/// expected-seq vector) travels next to this in the full shard
/// snapshot — see docs/RECOVERY.md.
#[derive(Debug, Clone, Default)]
pub struct MergeSnapshot {
    /// Highest watermark the shard advanced to.
    pub watermark: u64,
    /// Open panes, ascending by pane id.
    pub open: Vec<PaneState>,
    /// Retired panes, in retirement order (`stats` defaulted).
    pub retired: Vec<PaneState>,
    /// The shard-wide ledger folded out of retired panes.
    pub retired_stats: AggStats,
    /// Pane-lifecycle ledger.
    pub window_stats: WindowStats,
}

/// A [`TopKSketch`]'s parts with deterministic entry order.
fn sketch_parts(sketch: &TopKSketch) -> (Vec<(Key, f64)>, f64) {
    // sorted by key on the next line. lint: sorted-ok
    let mut entries: Vec<(Key, f64)> = sketch.tracked().collect();
    entries.sort_unstable_by_key(|&(k, _)| k);
    (entries, sketch.merged_error())
}

/// Stage two with panes: one shard of the windowed merge fabric. Each
/// open pane holds a [`MergeStage`] over the shard's key range plus a
/// bounded [`TopKSketch`]; [`WindowedMerge::advance`] retires panes the
/// watermark has passed. `window_ns == 0` degenerates to a single
/// never-retired pane — the unwindowed fabric, byte for byte.
pub struct WindowedMerge<C: Combiner<Acc = u64> + Clone> {
    combiner: C,
    window_ns: u64,
    /// Watermark slack (`--agg_lateness_ms`): a pane stays open until
    /// the watermark passes `pane_end + lateness_ns`, so bounded
    /// event-time disorder absorbs in place instead of forcing a
    /// retire-reopen-remerge cycle. 0 = retire the instant the
    /// watermark passes the pane end (the pre-slack behavior).
    lateness_ns: u64,
    sketch_capacity: usize,
    open: BTreeMap<WindowId, WindowPane<C>>,
    /// Running `(key, acc)` entry total across open panes — maintained
    /// incrementally so the per-absorb stat update is O(1), not a scan
    /// over every open pane.
    open_entries: usize,
    retired: Vec<WindowResult>,
    retired_stats: AggStats,
    watermark: u64,
    stats: WindowStats,
}

impl<C: Combiner<Acc = u64> + Clone> WindowedMerge<C> {
    /// An empty shard folding through `combiner`, with panes of
    /// `window_ns` (0 = unwindowed) and per-pane sketches of
    /// `sketch_capacity` counters.
    pub fn new(combiner: C, window_ns: u64, sketch_capacity: usize) -> Self {
        WindowedMerge {
            combiner,
            window_ns,
            lateness_ns: 0,
            sketch_capacity,
            open: BTreeMap::new(),
            open_entries: 0,
            retired: Vec::new(),
            retired_stats: AggStats::default(),
            watermark: 0,
            stats: WindowStats::default(),
        }
    }

    /// Keep panes open for `lateness_ns` of watermark slack past their
    /// end before retiring them (see the `lateness_ns` field).
    pub fn with_lateness(mut self, lateness_ns: u64) -> Self {
        self.lateness_ns = lateness_ns;
        self
    }

    /// Absorb one already-shard-routed flush sub-batch for `window`
    /// (no-op when empty). A sub-batch for a pane the watermark already
    /// retired *reopens* it (counted in
    /// [`WindowStats::late_reopens`]); the reopened emission re-merges
    /// exactly at [`WindowedMerge::finish`].
    pub fn absorb(&mut self, window: WindowId, sub: Vec<(Key, u64)>) {
        if sub.is_empty() {
            return;
        }
        let late = self.window_ns > 0
            && pane_end(window, self.window_ns).saturating_add(self.lateness_ns)
                <= self.watermark;
        // a late delta is a *reopen* only if the pane actually retired;
        // a pane whose first-ever delta arrives behind the watermark is
        // just opening late (it retires on the next advance). Rare path,
        // so the linear scan over retired results costs nothing.
        let reopen = late && self.retired.iter().any(|r| r.window == window);
        if reopen {
            // every delta landing in a reopened pane gets re-merged at
            // finish — charge its full tuple mass, not just the reopen
            // event, so a 1 000-tuple late batch is visible as such
            self.stats.late_reopen_mass +=
                sub.iter().map(|(_, acc)| self.combiner.acc_mass(acc)).sum::<u64>();
        }
        let pane = match self.open.entry(window) {
            std::collections::btree_map::Entry::Vacant(v) => {
                self.stats.panes_opened += 1;
                if reopen {
                    self.stats.late_reopens += 1;
                }
                v.insert(WindowPane {
                    // pane open happens once per window, not per batch —
                    // the combiner clone is amortized. lint: alloc-ok
                    merge: MergeStage::new(self.combiner.clone()),
                    sketch: TopKSketch::new(self.sketch_capacity),
                })
            }
            std::collections::btree_map::Entry::Occupied(o) => o.into_mut(),
        };
        let before = pane.merge.len();
        for &(key, delta) in &sub {
            pane.sketch.absorb(key, delta);
        }
        pane.merge.absorb(sub);
        self.open_entries += pane.merge.len() - before;
        self.stats.max_open_panes = self.stats.max_open_panes.max(self.open.len() as u64);
        self.stats.max_open_entries = self.stats.max_open_entries.max(self.open_entries as u64);
    }

    /// Advance the shard's watermark to `to` (monotone) and retire
    /// every open pane whose end (plus the configured lateness slack)
    /// it passed, oldest first. Returns the number of panes retired by
    /// this call. Never retires anything when unwindowed.
    pub fn advance(&mut self, to: u64) -> usize {
        if to > self.watermark {
            self.watermark = to;
        }
        if self.window_ns == 0 {
            return 0;
        }
        let mut retired = 0usize;
        while let Some(&window) = self.open.keys().next() {
            if pane_end(window, self.window_ns).saturating_add(self.lateness_ns) > self.watermark {
                break;
            }
            let pane = self.open.remove(&window).expect("pane key just observed");
            self.retire(window, pane);
            retired += 1;
        }
        retired
    }

    /// Current watermark (highest `advance` seen).
    pub fn watermark(&self) -> u64 {
        self.watermark
    }

    /// Panes currently open on this shard.
    pub fn open_panes(&self) -> usize {
        self.open.len()
    }

    /// Pane-lifecycle ledger so far.
    pub fn window_stats(&self) -> WindowStats {
        self.stats
    }

    /// Capture the shard's full windowed-merge state without consuming
    /// it — the periodic crash-recovery snapshot. Everything absorb
    /// order can influence is included, so [`WindowedMerge::restore`]
    /// followed by replaying the not-yet-absorbed flush batches
    /// converges byte-identically with a shard that never crashed.
    pub fn snapshot(&self) -> MergeSnapshot {
        let open = self
            .open
            .iter()
            .map(|(&window, pane)| {
                let (sketch_entries, sketch_error) = sketch_parts(&pane.sketch);
                PaneState {
                    window,
                    counts: pane.merge.sorted(),
                    stats: *pane.merge.stats(),
                    sketch_entries,
                    sketch_error,
                }
            })
            .collect();
        let retired = self
            .retired
            .iter()
            .map(|r| {
                let (sketch_entries, sketch_error) = sketch_parts(&r.sketch);
                PaneState {
                    window: r.window,
                    counts: r.counts.clone(),
                    stats: AggStats::default(),
                    sketch_entries,
                    sketch_error,
                }
            })
            .collect();
        MergeSnapshot {
            watermark: self.watermark,
            open,
            retired,
            retired_stats: self.retired_stats,
            window_stats: self.stats,
        }
    }

    /// Reinstate a [`MergeSnapshot`] into this (freshly built) shard,
    /// discarding whatever it held. The shard must be configured as the
    /// snapshotted one was (same `window_ns`, lateness and sketch
    /// capacity — all config-derived, so a respawned `fish __shard`
    /// satisfies this by construction).
    pub fn restore(&mut self, snap: MergeSnapshot) {
        self.watermark = snap.watermark;
        self.stats = snap.window_stats;
        self.retired_stats = snap.retired_stats;
        self.open.clear();
        self.open_entries = 0;
        for p in snap.open {
            self.open_entries += p.counts.len();
            self.open.insert(
                p.window,
                WindowPane {
                    merge: MergeStage::from_parts(self.combiner.clone(), p.counts, p.stats),
                    sketch: TopKSketch::from_parts(
                        self.sketch_capacity,
                        &p.sketch_entries,
                        p.sketch_error,
                    ),
                },
            );
        }
        self.retired = snap
            .retired
            .into_iter()
            .map(|p| WindowResult {
                window: p.window,
                counts: p.counts,
                sketch: TopKSketch::from_parts(
                    self.sketch_capacity,
                    &p.sketch_entries,
                    p.sketch_error,
                ),
            })
            .collect();
    }

    fn retire(&mut self, window: WindowId, pane: WindowPane<C>) {
        let WindowPane { merge, sketch } = pane;
        let (counts, stats) = merge.into_sorted();
        self.open_entries -= counts.len();
        self.retired_stats.absorb(&stats);
        self.stats.panes_retired += 1;
        self.retired.push(WindowResult { window, counts, sketch });
    }

    /// Finish the shard: retire every remaining pane, re-merge any
    /// reopened emissions, and fold the all-time totals. Unwindowed
    /// (`window_ns == 0`) there is exactly one eternal pane, whose
    /// counts *are* the all-time answer — they move out directly (no
    /// re-hash, no re-sort, no duplicate copy) and `windows` comes back
    /// empty, matching what the engines expose for unwindowed runs.
    pub fn finish(mut self) -> WindowedOutput {
        let open: Vec<(WindowId, WindowPane<C>)> = std::mem::take(&mut self.open).into_iter().collect();
        for (window, pane) in open {
            self.retire(window, pane);
        }
        // reopened panes emitted twice; stable sort groups them, then
        // adjacent same-window results merge exactly
        self.retired.sort_by_key(|r| r.window);
        let mut windows: Vec<WindowResult> = Vec::with_capacity(self.retired.len());
        for r in self.retired.drain(..) {
            match windows.last_mut() {
                Some(last) if last.window == r.window => last.merge_from(r, &self.combiner),
                _ => windows.push(r),
            }
        }
        if self.window_ns == 0 {
            let all_time = windows.pop().map(|r| r.counts).unwrap_or_default();
            return WindowedOutput {
                windows: Vec::new(),
                all_time,
                stats: self.retired_stats,
                window_stats: self.stats,
            };
        }
        let mut all: HashMap<Key, u64> = HashMap::new();
        for r in &windows {
            for &(k, c) in &r.counts {
                match all.entry(k) {
                    std::collections::hash_map::Entry::Occupied(mut o) => {
                        self.combiner.merge(o.get_mut(), &c);
                    }
                    std::collections::hash_map::Entry::Vacant(v) => {
                        v.insert(c);
                    }
                }
            }
        }
        // sorted by key on the next line. lint: sorted-ok
        let mut all_time: Vec<(Key, u64)> = all.into_iter().collect();
        all_time.sort_unstable_by_key(|&(k, _)| k);
        WindowedOutput {
            windows,
            all_time,
            stats: self.retired_stats,
            window_stats: self.stats,
        }
    }
}

/// One fabric-wide finalized window: exact counts assembled across
/// every merge shard, plus the scatter-gather top-k front-end over the
/// panes' per-shard sketches.
#[derive(Debug, Clone)]
pub struct WindowSnapshot {
    /// Pane id of the window's **last** (or only) pane.
    pub window: WindowId,
    /// Pane length in event-time ns.
    pub window_ns: u64,
    /// Tumbling panes this snapshot spans (1 for a plain pane; `m` for
    /// a [`sliding`] window of `m` panes).
    pub panes: u64,
    /// Exact merged `(key, acc)`, ascending by key — byte-identical for
    /// every shard count, flush cadence, scheme and engine.
    pub counts: Vec<(Key, u64)>,
    /// Approximate per-window top-k over the per-shard pane sketches,
    /// with the usual rank-error bound.
    pub gather: TopKGather,
}

impl WindowSnapshot {
    /// Window start in event-time ns (inclusive).
    pub fn start_ns(&self) -> u64 {
        (self.window + 1).saturating_sub(self.panes).saturating_mul(self.window_ns)
    }

    /// Window end in event-time ns (exclusive).
    pub fn end_ns(&self) -> u64 {
        pane_end(self.window, self.window_ns)
    }

    /// Total mass in the window.
    pub fn total(&self) -> u64 {
        self.counts.iter().map(|&(_, c)| c).sum()
    }

    /// The `k` hottest keys **within this window**, exact (highest
    /// count first, ties by key ascending).
    pub fn top_k(&self, k: usize) -> Vec<(Key, u64)> {
        super::merge::top_k(&self.counts, k)
    }
}

/// Assemble per-shard finalized panes into fabric-wide
/// [`WindowSnapshot`]s, ascending by pane id. Shards partition the key
/// space, so concatenating each shard's (already deduplicated) counts
/// and sorting by key reproduces the single-shard fold byte for byte;
/// the per-window gather keeps one sketch slot per fabric shard (empty
/// where a shard saw none of the pane) so its routing matches the
/// fabric's.
pub fn assemble_windows(
    window_ns: u64,
    n_shards: usize,
    sketch_capacity: usize,
    per_shard: Vec<Vec<WindowResult>>,
) -> Vec<WindowSnapshot> {
    assert_eq!(per_shard.len(), n_shards, "one result list per shard");
    let mut by_window: BTreeMap<WindowId, Vec<(usize, WindowResult)>> = BTreeMap::new();
    for (s, results) in per_shard.into_iter().enumerate() {
        for r in results {
            by_window.entry(r.window).or_default().push((s, r));
        }
    }
    by_window
        .into_iter()
        .map(|(window, parts)| {
            let mut counts = Vec::new();
            let mut sketches: Vec<TopKSketch> =
                (0..n_shards).map(|_| TopKSketch::new(sketch_capacity)).collect();
            for (s, r) in parts {
                counts.extend(r.counts);
                sketches[s] = r.sketch;
            }
            counts.sort_unstable_by_key(|&(k, _)| k);
            WindowSnapshot {
                window,
                window_ns,
                panes: 1,
                counts,
                gather: TopKGather::from_shards(sketches),
            }
        })
        .collect()
}

/// Compose sliding windows from tumbling panes: for every pane in
/// `panes` (ascending, as [`assemble_windows`] returns them), the
/// sliding window ending with that pane merges the up-to
/// `panes_per_window` consecutive panes covering
/// `((last+1-m)·window_ns, (last+1)·window_ns]`. The slide equals one
/// pane — the classic paired-pane construction, trading pane-grain
/// slide granularity for O(panes) state instead of the O(window
/// contents) a tuple-buffer baseline like
/// [`crate::sketch::SlidingWindow`] pays.
///
/// Counts roll incrementally — each pane is added once when it enters
/// the span and subtracted once when it leaves (exact: counts are
/// non-negative sums), so the whole sweep is O(total pane entries)
/// plus one sorted snapshot per output window. Gathers cannot be
/// subtracted (SpaceSaving has no inverse), so per-pane merged gather
/// summaries are cached in a [`GatherQueue`] — a two-stack FIFO with
/// running folds — and each output window's gather is composed from at
/// most two cached folds instead of re-merging every pane in the span.
pub fn sliding(panes: &[WindowSnapshot], panes_per_window: usize) -> Vec<WindowSnapshot> {
    assert!(panes_per_window > 0, "a sliding window needs at least one pane");
    let mut out = Vec::with_capacity(panes.len());
    let mut rolling: HashMap<Key, u64> = HashMap::new();
    let mut gathers = GatherQueue::default();
    let mut lo = 0usize;
    for p in panes {
        // evict panes that fell out of the span, add the entering one
        while panes[lo].window + panes_per_window as u64 <= p.window {
            for &(k, c) in &panes[lo].counts {
                match rolling.entry(k) {
                    std::collections::hash_map::Entry::Occupied(mut o) => {
                        *o.get_mut() -= c;
                        if *o.get() == 0 {
                            o.remove();
                        }
                    }
                    std::collections::hash_map::Entry::Vacant(_) => {
                        unreachable!("evicted pane key missing from rolling window")
                    }
                }
            }
            gathers.pop();
            lo += 1;
        }
        for &(k, c) in &p.counts {
            *rolling.entry(k).or_insert(0) += c;
        }
        gathers.push(&p.gather);
        // sorted by key on the next line. lint: sorted-ok
        let mut counts: Vec<(Key, u64)> = rolling.iter().map(|(&k, &c)| (k, c)).collect();
        counts.sort_unstable_by_key(|&(k, _)| k);
        out.push(WindowSnapshot {
            window: p.window,
            window_ns: p.window_ns,
            panes: panes_per_window as u64,
            counts,
            gather: gathers.fold(),
        });
    }
    out
}

/// FIFO queue of pane gathers with amortized-O(1) whole-queue folds —
/// the cache behind [`sliding`]'s per-window gather. The classic
/// two-stack aggregation queue: `back` collects pushed panes under one
/// running fold (`back_agg`); when a pop finds `front` empty, `back`
/// flips into `front` as cumulative *suffix* folds (so `front.last()`
/// always covers every un-popped flipped pane). Each pane's gather is
/// merged O(1) times amortized over a sweep, versus the O(span) merges
/// per output window a naive per-window refold pays.
#[derive(Default)]
struct GatherQueue {
    /// Pop side, newest at the bottom: `front[j]` is the fold of the
    /// flipped panes `j..` (in arrival order), so the oldest un-popped
    /// pane's cumulative fold sits on top.
    front: Vec<TopKGather>,
    /// Push side, raw pane gathers in arrival order.
    back: Vec<TopKGather>,
    /// Running fold of everything in `back`.
    back_agg: Option<TopKGather>,
}

impl GatherQueue {
    /// Enqueue one pane's gather.
    fn push(&mut self, gather: &TopKGather) {
        self.back.push(gather.clone());
        match &mut self.back_agg {
            Some(agg) => agg.merge_from(gather),
            None => self.back_agg = Some(gather.clone()),
        }
    }

    /// Dequeue the oldest pane, flipping the push side into cumulative
    /// suffix folds when the pop side runs dry.
    fn pop(&mut self) {
        if self.front.is_empty() {
            for g in std::mem::take(&mut self.back).into_iter().rev() {
                let mut cum = g;
                if let Some(newer) = self.front.last() {
                    cum.merge_from(newer);
                }
                self.front.push(cum);
            }
            self.back_agg = None;
        }
        self.front.pop();
    }

    /// Fold of every enqueued pane: at most one merge of the two sides'
    /// cached folds, never a walk over the panes.
    fn fold(&self) -> TopKGather {
        match (self.front.last(), &self.back_agg) {
            (Some(f), Some(b)) => {
                let mut all = f.clone();
                all.merge_from(b);
                all
            }
            (Some(f), None) => f.clone(),
            (None, Some(b)) => b.clone(),
            (None, None) => unreachable!("fold of an empty gather queue"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::combiner::Count;
    use super::super::shard::ShardRouter;
    use super::*;

    #[test]
    fn boundary_snap_lands_on_the_grid() {
        assert_eq!(next_boundary(0, 10), 10);
        assert_eq!(next_boundary(9, 10), 10);
        assert_eq!(next_boundary(10, 10), 20);
        assert_eq!(next_boundary(11, 10), 20);
        assert_eq!(next_boundary(1_000_000, 1_000_000), 2_000_000);
    }

    #[test]
    fn window_assignment_is_by_event_time() {
        assert_eq!(window_of(0, 100), 0);
        assert_eq!(window_of(99, 100), 0);
        assert_eq!(window_of(100, 100), 1);
        assert_eq!(window_of(1234, 0), 0, "unwindowed = one eternal pane");
    }

    #[test]
    fn windowed_partial_groups_flushes_by_pane() {
        let mut p = WindowedPartial::new(Count, 100);
        p.observe(1, 1, 10); // pane 0
        p.observe(1, 1, 50); // pane 0
        p.observe(2, 1, 150); // pane 1 (advance)
        p.observe(3, 1, 90); // pane 0 again (laggard)
        p.observe(2, 1, 260); // pane 2
        assert_eq!(p.len(), 4);
        let flushed = p.flush();
        assert!(p.is_empty());
        assert_eq!(
            flushed,
            vec![
                (0, vec![(1u64, 2u64), (3, 1)]),
                (1, vec![(2, 1)]),
                (2, vec![(2, 1)]),
            ]
        );
    }

    #[test]
    fn unwindowed_partial_is_a_single_pane() {
        let mut p = WindowedPartial::new(Count, 0);
        for (k, ts) in [(5u64, 0u64), (5, 999), (7, 123_456)] {
            p.observe(k, 1, ts);
        }
        let flushed = p.flush();
        assert_eq!(flushed, vec![(0, vec![(5, 2), (7, 1)])]);
    }

    #[test]
    fn watermark_retires_closed_panes_in_order() {
        let mut m = WindowedMerge::new(Count, 100, 64);
        m.absorb(0, vec![(1, 2), (2, 1)]);
        m.absorb(1, vec![(1, 1)]);
        assert_eq!(m.open_panes(), 2);
        assert_eq!(m.advance(150), 1, "pane 0 ends at 100 <= 150");
        assert_eq!(m.open_panes(), 1);
        assert_eq!(m.advance(150), 0, "idempotent");
        let out = m.finish();
        assert_eq!(out.windows.len(), 2);
        assert_eq!(out.windows[0].window, 0);
        assert_eq!(out.windows[0].counts, vec![(1, 2), (2, 1)]);
        assert_eq!(out.windows[1].window, 1);
        assert_eq!(out.windows[1].counts, vec![(1, 1)]);
        assert_eq!(out.all_time, vec![(1, 3), (2, 1)]);
        assert_eq!(out.window_stats.panes_opened, 2);
        assert_eq!(out.window_stats.panes_retired, 2);
        assert_eq!(out.window_stats.late_reopens, 0);
        assert_eq!(out.window_stats.late_reopen_mass, 0);
        assert_eq!(out.window_stats.max_open_panes, 2);
    }

    #[test]
    fn lateness_slack_delays_retirement_and_absorbs_stragglers() {
        let mut m = WindowedMerge::new(Count, 100, 64).with_lateness(50);
        m.absorb(0, vec![(1, 2)]);
        // pane 0 ends at 100, but 100 + 50 > 120: the slack holds it open
        assert_eq!(m.advance(120), 0);
        // so this straggler absorbs in place — no reopen, no late mass
        m.absorb(0, vec![(1, 3)]);
        assert_eq!(m.advance(150), 1, "100 + 50 <= 150 retires pane 0");
        // beyond the slack it is a genuine reopen, charged by tuple mass
        m.absorb(0, vec![(9, 4)]);
        let out = m.finish();
        assert_eq!(out.window_stats.late_reopens, 1);
        assert_eq!(out.window_stats.late_reopen_mass, 4);
        assert_eq!(out.windows.len(), 1);
        assert_eq!(out.windows[0].counts, vec![(1, 5), (9, 4)]);
    }

    #[test]
    fn late_delta_reopens_and_remerges_exactly() {
        let mut m = WindowedMerge::new(Count, 100, 64);
        m.absorb(0, vec![(1, 2)]);
        m.advance(250); // pane 0 retired
        m.absorb(0, vec![(1, 3), (9, 1)]); // late: reopens pane 0
        // a first-ever delta behind the watermark is a late *open*, not
        // a reopen — nothing was retired for pane 1
        m.absorb(1, vec![(7, 1)]);
        m.absorb(2, vec![(4, 1)]);
        let out = m.finish();
        assert_eq!(out.window_stats.late_reopens, 1);
        assert_eq!(out.window_stats.late_reopen_mass, 4, "3 + 1 tuples re-merged late");
        assert_eq!(out.windows.len(), 3, "reopened emissions re-merged");
        assert_eq!(out.windows[0].window, 0);
        assert_eq!(out.windows[0].counts, vec![(1, 5), (9, 1)]);
        assert!(out.windows[0].sketch.estimate(1) >= 5.0);
        assert_eq!(out.windows[1].counts, vec![(7, 1)]);
        assert_eq!(out.all_time, vec![(1, 5), (4, 1), (7, 1), (9, 1)]);
    }

    #[test]
    fn unwindowed_merge_never_retires_until_finish() {
        let mut m = WindowedMerge::new(Count, 0, 64);
        m.absorb(0, vec![(1, 1), (2, 2)]);
        assert_eq!(m.advance(u64::MAX - 1), 0);
        m.absorb(0, vec![(1, 4)]);
        let out = m.finish();
        assert!(out.windows.is_empty(), "unwindowed output exposes no panes");
        assert_eq!(out.all_time, vec![(1, 5), (2, 2)]);
        assert_eq!(out.stats.flushes, 2);
        assert_eq!(out.stats.messages, 3);
    }

    /// Crash a shard mid-run: snapshot → fresh shard → restore → replay
    /// the batches absorbed after the snapshot. Finish output must be
    /// byte-identical to the shard that never crashed, including the
    /// deterministic stat fields.
    #[test]
    fn snapshot_restore_replay_converges_byte_identically() {
        let feed: Vec<(WindowId, Vec<(Key, u64)>)> = (0..40u64)
            .map(|i| (i / 8, vec![(i % 5, i % 3 + 1), (10 + i % 7, 1)]))
            .collect();
        let drive = |m: &mut WindowedMerge<Count>, batches: &[(WindowId, Vec<(Key, u64)>)], base: u64| {
            for (i, (win, sub)) in batches.iter().enumerate() {
                m.absorb(*win, sub.clone());
                m.advance((base + i as u64) * 700);
            }
        };
        // reference: no crash
        let mut reference = WindowedMerge::new(Count, 1_000, 16).with_lateness(500);
        drive(&mut reference, &feed, 0);
        let ref_out = reference.finish();
        // crashed twin: snapshot at batch 25, restore into a fresh
        // shard, replay the suffix
        let mut crashed = WindowedMerge::new(Count, 1_000, 16).with_lateness(500);
        drive(&mut crashed, &feed[..25], 0);
        let snap = crashed.snapshot();
        drop(crashed);
        let mut restored = WindowedMerge::new(Count, 1_000, 16).with_lateness(500);
        restored.restore(snap);
        drive(&mut restored, &feed[25..], 25);
        let out = restored.finish();
        assert_eq!(out.all_time, ref_out.all_time);
        assert_eq!(out.windows.len(), ref_out.windows.len());
        for (a, b) in out.windows.iter().zip(&ref_out.windows) {
            assert_eq!(a.window, b.window);
            assert_eq!(a.counts, b.counts, "pane {}", a.window);
            assert_eq!(a.sketch.top(8), b.sketch.top(8), "pane {}", a.window);
            assert_eq!(a.sketch.error_bound(), b.sketch.error_bound());
        }
        // deterministic stat fields survive the crash
        assert_eq!(out.stats.flushes, ref_out.stats.flushes);
        assert_eq!(out.stats.messages, ref_out.stats.messages);
        assert_eq!(out.stats.bytes, ref_out.stats.bytes);
        assert_eq!(out.window_stats.panes_opened, ref_out.window_stats.panes_opened);
        assert_eq!(out.window_stats.panes_retired, ref_out.window_stats.panes_retired);
        assert_eq!(out.window_stats.max_open_entries, ref_out.window_stats.max_open_entries);
    }

    /// Drive the same windowed flush schedule through a 1-shard and an
    /// n-shard fabric; assembled snapshots must be byte-identical.
    #[test]
    fn assembled_windows_are_shard_count_invariant() {
        let run = |n_shards: usize| {
            let router = ShardRouter::new(n_shards);
            let mut shards: Vec<WindowedMerge<Count>> =
                (0..n_shards).map(|_| WindowedMerge::new(Count, 1_000, 64)).collect();
            let mut partial = WindowedPartial::new(Count, 1_000);
            for i in 0..6_000u64 {
                partial.observe((i * i + 3) % 97, 1, i * 7); // ts 0..42000 → 42 panes
                if (i + 1) % 500 == 0 {
                    for (win, batch) in partial.flush() {
                        for (s, sub) in router.split(batch).into_iter().enumerate() {
                            shards[s].absorb(win, sub);
                        }
                    }
                    for sh in shards.iter_mut() {
                        sh.advance(i * 7);
                    }
                }
            }
            for (win, batch) in partial.flush() {
                for (s, sub) in router.split(batch).into_iter().enumerate() {
                    shards[s].absorb(win, sub);
                }
            }
            let per_shard: Vec<Vec<WindowResult>> =
                shards.into_iter().map(|sh| sh.finish().windows).collect();
            assemble_windows(1_000, n_shards, 64, per_shard)
        };
        let single = run(1);
        let sharded = run(5);
        assert_eq!(single.len(), sharded.len());
        assert_eq!(single.len(), 42);
        for (a, b) in single.iter().zip(&sharded) {
            assert_eq!(a.window, b.window);
            assert_eq!(a.counts, b.counts, "pane {}", a.window);
            assert_eq!(a.top_k(5), b.top_k(5), "pane {}", a.window);
        }
        assert_eq!(single.iter().map(|w| w.total()).sum::<u64>(), 6_000);
    }

    #[test]
    fn sliding_windows_merge_consecutive_panes() {
        // three panes of 10ns with distinct keys
        let mk = |window: u64, counts: Vec<(Key, u64)>| {
            let mut gather = TopKGather::new(1, 16);
            for &(k, c) in &counts {
                gather.absorb(k, c);
            }
            WindowSnapshot { window, window_ns: 10, panes: 1, counts, gather }
        };
        let panes = vec![
            mk(0, vec![(1, 5)]),
            mk(1, vec![(1, 2), (2, 4)]),
            mk(2, vec![(3, 7)]),
        ];
        let slid = sliding(&panes, 2);
        assert_eq!(slid.len(), 3);
        // ramp-up window: just pane 0
        assert_eq!(slid[0].counts, vec![(1, 5)]);
        assert_eq!(slid[1].counts, vec![(1, 7), (2, 4)]);
        // pane 0's mass left the span; pane 1's share of key 1 remains
        assert_eq!(slid[2].counts, vec![(1, 2), (2, 4), (3, 7)]);
        assert_eq!(slid[2].panes, 2);
        assert_eq!(slid[2].start_ns(), 10);
        assert_eq!(slid[2].end_ns(), 30);
        assert!(slid[1].gather.estimate(1) >= 7.0);
        assert_eq!(slid[1].top_k(1), vec![(1, 7)]);
    }

    #[test]
    fn sliding_skips_panes_outside_the_span_even_with_gaps() {
        let mk = |window: u64, counts: Vec<(Key, u64)>| {
            let mut gather = TopKGather::new(1, 16);
            for &(k, c) in &counts {
                gather.absorb(k, c);
            }
            WindowSnapshot { window, window_ns: 10, panes: 1, counts, gather }
        };
        // pane 1 empty (absent): window of 2 panes ending at pane 2
        // must NOT include pane 0
        let panes = vec![mk(0, vec![(1, 5)]), mk(2, vec![(2, 3)])];
        let slid = sliding(&panes, 2);
        assert_eq!(slid[1].counts, vec![(2, 3)]);
    }

    #[test]
    fn sliding_gather_queue_matches_a_naive_refold() {
        // small key sets keep every sketch under capacity, where
        // estimates are exact regardless of merge order — so the cached
        // two-stack composition must agree with a pane-by-pane refold
        // to the digit, not just within the error bound
        let mk = |window: u64, counts: Vec<(Key, u64)>| {
            let mut gather = TopKGather::new(1, 16);
            for &(k, c) in &counts {
                gather.absorb(k, c);
            }
            WindowSnapshot { window, window_ns: 10, panes: 1, counts, gather }
        };
        let panes: Vec<WindowSnapshot> =
            (0..8u64).map(|w| mk(w, vec![(w % 3, w + 1), (10 + w, 2)])).collect();
        let slid = sliding(&panes, 3);
        assert_eq!(slid.len(), 8);
        for (i, s) in slid.iter().enumerate() {
            let lo = i.saturating_sub(2);
            let mut naive = panes[lo].gather.clone();
            for q in &panes[lo + 1..=i] {
                naive.merge_from(&q.gather);
            }
            for &(k, c) in &s.counts {
                assert_eq!(
                    s.gather.estimate(k),
                    naive.estimate(k),
                    "window {} key {k}",
                    s.window
                );
                assert!(s.gather.estimate(k) >= c as f64);
            }
        }
    }
}
